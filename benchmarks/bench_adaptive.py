"""Misprediction-recovery benchmark: adaptive serving vs a corrupted prior.

Setup: the format classifier is *deliberately corrupted* — for every matrix
it predicts the oracle-worst format and believes it beats everything (the
regressor under-estimates its latency 3x). Ground truth comes from the TPU
cost model; "measured" wall times are the true latencies plus measurement
noise, so the run is deterministic and CI-fast.

Two serving modes over the same round-robin request stream:

* **static**   — PR-1 behavior: the corrupted plan is cached and served
  forever; every request pays the full misprediction regret.
* **adaptive** — the telemetry bandit explores alternate formats within
  budget, the drift detector evicts the stale plan, the measured-best format
  is promoted, and the feedback loop refits the classifier from telemetry.

Reported: cumulative relative regret vs the oracle (sum of
``(served - best) / best``), requests until every cell's incumbent equals
the oracle-best format, drift invalidations, classifier accuracy before and
after the telemetry refit, and a restart check (the JSONL log replays into
identical aggregate counts).

Run via ``python -m benchmarks.run --only adaptive`` (or ``--smoke``).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import ART, print_table, save_result
from repro.core import (
    AutoSpMV,
    AutoSpmvPredictor,
    AutoSpmvSession,
    MatrixStats,
    PredictorConfig,
    TpuCostModel,
    TPU_V5E,
    extract_features,
)
from repro.core.predictor import OBJECTIVES
from repro.kernels.common import DEFAULT_SCHEDULE
from repro.kernels.ops import clear_kernel_memo
from repro.sparse.registry import format_names
from repro.sparse.generate import random_matrix
from repro.utils.logging import get_logger

log = get_logger("bench.adaptive")
from repro.telemetry import (
    AdaptiveConfig,
    AdaptiveFormatSelector,
    FeedbackLoop,
    TelemetryRecorder,
)

N_MATRICES = 3
NOISE = 0.03  # relative measurement noise on the simulated wall times


class _Env:
    """Analytic ground truth: per-(matrix, format) latency from the cost model."""

    def __init__(self, mats: list[np.ndarray]):
        model = TpuCostModel(TPU_V5E)
        self.feats = [extract_features(m) for m in mats]
        self._logvecs = np.stack([f.log_vector() for f in self.feats])
        self.true: list[dict[str, float]] = []
        for m in mats:
            stats = MatrixStats(m)
            row = {}
            for fmt in format_names():
                vals = model.evaluate(stats, fmt, DEFAULT_SCHEDULE)
                row[fmt] = vals.latency if vals.feasible else float("inf")
            self.true.append(row)
        self.best = [min(r, key=r.get) for r in self.true]
        self.worst = [
            max(((f, v) for f, v in r.items() if np.isfinite(v)), key=lambda kv: kv[1])[0]
            for r in self.true
        ]

    def index_of(self, feats) -> int:
        d = np.abs(self._logvecs - feats.log_vector()).sum(axis=1)
        return int(np.argmin(d))


class _WorstFormatClassifier:
    """The corrupted prior: always 'predicts' the oracle-worst format."""

    def __init__(self, env: _Env):
        self.env = env

    def predict(self, X):
        X = np.asarray(X)
        out = []
        for row in X:
            d = np.abs(self.env._logvecs - row).sum(axis=1)
            out.append(self.env.worst[int(np.argmin(d))])
        return np.array(out)


class _CorruptedPredictor(AutoSpmvPredictor):
    """Real predictor skeleton with a poisoned format stage.

    ``format_clf_`` picks the worst format and ``estimate_objective``
    under-estimates its cost 3x (the model is confidently wrong), while the
    refit path (``_fit_classifier`` via the model zoo) stays fully real —
    exactly what the telemetry feedback loop has to repair in production.
    """

    def __init__(self, env: _Env):
        super().__init__(PredictorConfig())
        self.env = env
        self.format_clf_ = {obj: _WorstFormatClassifier(env) for obj in OBJECTIVES}

    def predict_schedule(self, feats, objective):
        return DEFAULT_SCHEDULE

    def estimate_objective(self, feats, config, objective):
        i = self.env.index_of(feats)
        if config.fmt == self.env.worst[i]:
            return 0.3 * self.env.true[i][self.env.best[i]]  # flattering lie
        v = self.env.true[i][config.fmt]
        return v if np.isfinite(v) else 1e3


def _measure(env: _Env, mi: int, fmt: str, rng: np.random.Generator) -> float:
    return float(env.true[mi][fmt] * max(1.0 + NOISE * rng.standard_normal(), 0.1))


def run(scale_name: str = "paper") -> dict:
    n_requests = 150 if scale_name == "paper" else 90
    mats = [random_matrix(96 * (i + 1), 4.0 * (i + 1), "fem", seed=i) for i in range(N_MATRICES)]
    env = _Env(mats)
    rng = np.random.default_rng(0)
    order = [i % N_MATRICES for i in range(n_requests)]

    # ---- static: the corrupted plan is cached and served forever ----------
    static_session = AutoSpmvSession(AutoSpMV(_CorruptedPredictor(env), None))
    static_regret = 0.0
    static_fmts = []
    for mi in order:
        feats = env.feats[mi]
        bucket = static_session.cache.bucket_of(feats)
        fmt = static_session._incumbent_format(feats, bucket, "latency")
        static_fmts.append(fmt)
        static_regret += (env.true[mi][fmt] - env.true[mi][env.best[mi]]) / env.true[mi][env.best[mi]]

    # ---- adaptive: explore, detect drift, evict, promote, refit -----------
    clear_kernel_memo()
    log_path = ART / "adaptive_telemetry.jsonl"
    log_path.unlink(missing_ok=True)
    recorder = TelemetryRecorder(log_path=log_path, flush_every=16)
    selector = AdaptiveFormatSelector(
        AdaptiveConfig(exploration_fraction=0.3, drift_window=3, min_challenger_pulls=1)
    )
    predictor = _CorruptedPredictor(env)
    session = AutoSpmvSession(
        AutoSpMV(predictor, None), telemetry=recorder, adaptive=selector
    )
    feedback = FeedbackLoop(recorder)

    adaptive_regret = 0.0
    regret_curve = []
    incumbent_ok_at = None
    for t, mi in enumerate(order):
        plan = session.serve_optimize(mats[mi], "latency")
        measured = _measure(env, mi, plan.fmt, rng)
        session.observe(plan, measured)
        adaptive_regret += (env.true[mi][plan.fmt] - env.true[mi][env.best[mi]]) / env.true[mi][env.best[mi]]
        regret_curve.append(adaptive_regret)
        # reconvergence: every seen cell's incumbent is the oracle-best format
        ok = all(
            selector.incumbent(session.cache.bucket_of(env.feats[j]), "latency")
            == env.best[j]
            for j in set(order[: t + 1])
        )
        incumbent_ok_at = (t + 1) if ok and incumbent_ok_at is None else (incumbent_ok_at if ok else None)

    # ---- relearn: refit the poisoned classifier from telemetry ------------
    acc_before = np.mean(
        [predictor.predict_format(env.feats[i], "latency") == env.best[i] for i in range(N_MATRICES)]
    )
    refit = feedback.refit_format_classifier(predictor, objectives=("latency",))
    acc_after = np.mean(
        [predictor.predict_format(env.feats[i], "latency") == env.best[i] for i in range(N_MATRICES)]
    )

    # ---- restart: the JSONL log replays into the same aggregates ----------
    recorder.flush()
    reloaded = TelemetryRecorder(log_path=log_path)
    assert reloaded.total_observations() == recorder.total_observations(), (
        "telemetry log must replay losslessly"
    )

    rows = [
        ["static", static_regret, "-", "-", "-"],
        ["adaptive", adaptive_regret, incumbent_ok_at,
         session.stats.invalidations, session.stats.explorations],
    ]
    print_table(
        f"misprediction recovery over {n_requests} requests, {N_MATRICES} matrices",
        ["mode", "cum.regret", "reconverged@", "invalidations", "explorations"],
        rows,
    )
    log.info(
        "classifier accuracy (latency): %.2f -> %.2f after refit on %d "
        "telemetry labels; telemetry restart check: %d records replayed",
        acc_before,
        acc_after,
        refit.get("latency", 0),
        reloaded.total_observations(),
    )

    assert adaptive_regret < static_regret, "adaptive must beat the static misprediction"
    assert incumbent_ok_at is not None, "incumbents must reconverge to the oracle"

    payload = {
        "n_requests": n_requests,
        "static_regret": static_regret,
        "adaptive_regret": adaptive_regret,
        "reconverged_at": incumbent_ok_at,
        "invalidations": session.stats.invalidations,
        "explorations": session.stats.explorations,
        "acc_before": float(acc_before),
        "acc_after": float(acc_after),
        "oracle_best": env.best,
        "static_fmts": sorted(set(static_fmts)),
        "regret_curve_tail": regret_curve[-5:],
    }
    save_result("adaptive", payload)
    return payload


if __name__ == "__main__":
    run("ci")
