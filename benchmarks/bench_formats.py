"""Registered-format sweep: correctness + conversion/SpMV micro-latency for
every format in the registry, including the BCSR plugin.

This is the registry's smoke-tier bench: it activates the fifth format the
plugin way (an import), then walks ``format_names()`` with zero per-format
code — exactly the loop a new ``register_format()`` plugin joins for free.
Also reports the BELL vs BCSR stored-block comparison (the CMRS
row-compression argument) on a skewed matrix.
"""

from __future__ import annotations

import time

import numpy as np

import repro.sparse.bcsr  # noqa: F401  (plugin activation: registers "bcsr")
from repro.kernels.common import DEFAULT_SCHEDULE, KernelSchedule
from repro.sparse import format_names, get_format
from repro.sparse.generate import random_matrix
from repro.utils.logging import get_logger

log = get_logger("bench.formats")

SCALES = {
    "smoke": dict(n=256, avg=6.0, reps=1),
    "ci": dict(n=512, avg=8.0, reps=2),
    "paper": dict(n=2048, avg=12.0, reps=3),
}


def run(scale: str = "ci") -> dict:
    cfg = SCALES.get(scale, SCALES["ci"])
    n, avg, reps = cfg["n"], cfg["avg"], cfg["reps"]
    rng = np.random.default_rng(0)
    out = {}
    log.info("registered formats: %s", format_names())
    for pattern in ("fem", "powerlaw"):
        dense = random_matrix(n, avg, pattern, seed=7).astype(np.float32)
        x = rng.normal(size=dense.shape[1]).astype(np.float32)
        ref = dense @ x
        norm = np.abs(ref).max() + 1e-9
        print(f"\n[{pattern}] n={dense.shape[0]} nnz={(dense != 0).sum()}")
        print(f"{'format':8s} {'convert_ms':>10s} {'spmv_ms':>9s} {'rel_err':>9s} {'KiB':>8s}")
        for fmt in format_names():
            spec = get_format(fmt)
            t0 = time.perf_counter()
            mat = spec.prepare(dense, DEFAULT_SCHEDULE)
            t_conv = time.perf_counter() - t0
            y, t_spmv = None, 0.0
            for _ in range(reps):
                t0 = time.perf_counter()
                y = np.asarray(spec.spmv(mat, x, DEFAULT_SCHEDULE))
                t_spmv += time.perf_counter() - t0
            t_spmv /= reps
            err = float(np.abs(y - ref).max() / norm)
            assert err < 1e-3, f"{fmt} diverged on {pattern}: {err}"
            kib = mat.nbytes / 1024.0
            out[(pattern, fmt)] = dict(convert_s=t_conv, spmv_s=t_spmv, err=err)
            print(f"{fmt:8s} {t_conv*1e3:10.2f} {t_spmv*1e3:9.2f} {err:9.2e} {kib:8.1f}")

    # CMRS row-compression argument: BCSR stores only occupied blocks
    skew = random_matrix(max(n, 512), 3.0, "powerlaw", seed=2).astype(np.float32)
    sched = KernelSchedule(rows_per_block=8)
    bell = get_format("bell").prepare(skew, sched)
    bcsr = get_format("bcsr").prepare(skew, sched)
    ratio = bcsr.data.size / max(bell.data.size, 1)
    log.info(
        "BELL vs BCSR stored blocks on skewed occupancy: %d vs %d (%.0f%% of "
        "BELL storage)",
        bell.data.size // (8 * 128),
        bcsr.data.size // (8 * 128),
        100.0 * ratio,
    )
    out["bcsr_vs_bell_storage_ratio"] = ratio
    return out


if __name__ == "__main__":
    run("ci")
