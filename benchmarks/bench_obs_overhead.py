"""Observability overhead + active-SLO escalation benchmark.

Study 1 (gated by ``benchmarks/compare.py``): the per-request cost of the
observability layer. The same warm request stream is served twice through a
``SpmvServer`` — once with tracing + metrics enabled, once under
``set_obs_enabled(False)`` — and the compare gate bounds the ratio
``obs_on/per_request_s`` over ``obs_off/per_request_s``: the layer must stay
a bounded fraction of an already sub-millisecond serve path, or the "no-op
fast path" claim in ``repro/obs`` is broken.

Study 2 (the active-observability acceptance loop): a synthetic overload
drives an SLO class's latency burn through ok→firing; while the alert fires
the server escalates the class's requests from their native objective
(``energy`` for the energy-saving class) to the violated dimension's
(``latency``), and once healthy traffic cools the fast window the state
clears and requests return to the native objective. The loop is *checked*,
not just measured — a bench failure here means the escalation path broke.

Run via ``python -m benchmarks.run --only obs_overhead`` or directly.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import SCALES, get_predictor, print_table, save_result
from repro.core import AutoSpMV, AutoSpmvSession, OverheadPredictor, measure_overheads
from repro.kernels.ops import clear_kernel_memo
from repro.obs import set_obs_enabled
from repro.obs.slo import FIRING, OK, SloConfig, SloTarget, SloTracker
from repro.sparse.generate import MATRIX_NAMES, generate_by_name
from repro.train.serve import SpmvRequest, SpmvServer
from repro.utils.logging import get_logger

log = get_logger("bench.obs_overhead")

N_UNIQUE = 3  # distinct matrices in the pool
REPEATS = 3  # requests per matrix per pass
PASSES = 3  # timed passes per mode; best-of wins (noise floor)


def _requests(scale: float, *, slo: str | None = None) -> list[SpmvRequest]:
    rng = np.random.default_rng(0)
    reqs = []
    rid = 0
    for name in MATRIX_NAMES[:N_UNIQUE]:
        dense = generate_by_name(name, scale=scale)
        for _ in range(REPEATS):
            x = rng.normal(size=dense.shape[1]).astype(np.float32)
            reqs.append(SpmvRequest(rid=rid, dense=dense, x=x, slo=slo))
            rid += 1
    return reqs


def _timed_pass(server: SpmvServer, scale: float) -> float:
    reqs = _requests(scale)
    t0 = time.perf_counter()
    server.run(reqs)
    return (time.perf_counter() - t0) / len(reqs)


def _overhead_study(tuner, scale: float) -> dict:
    clear_kernel_memo()
    server = SpmvServer(AutoSpmvSession(tuner))
    server.run(_requests(scale))  # warm-up: plans + kernels off the clock
    on_s = min(_timed_pass(server, scale) for _ in range(PASSES))
    set_obs_enabled(False)
    try:
        off_s = min(_timed_pass(server, scale) for _ in range(PASSES))
    finally:
        set_obs_enabled(True)
    return {
        "obs_on": {"per_request_s": on_s},
        "obs_off": {"per_request_s": off_s},
        "overhead_ratio": on_s / max(off_s, 1e-12),
    }


def _escalation_study(tuner, scale: float) -> dict:
    """Close acceptance loop (a): overload → firing → escalation → recovery."""
    cfg = SloConfig(
        fast_window=8,
        slow_window=16,
        min_samples=4,
        targets={"energy-saving": SloTarget(p99_latency_s=2.0)},
    )
    tracker = SloTracker(cfg)
    transitions: list[tuple[str, str]] = []
    tracker.on_transition(lambda slo, old, new, dim: transitions.append((old, new)))
    server = SpmvServer(AutoSpmvSession(tuner), slo=tracker)

    done = server.run(_requests(scale, slo="energy-saving"))
    healthy_obj = {r.served_objective for r in done}
    if healthy_obj != {"energy"}:
        raise RuntimeError(f"healthy energy-saving traffic served as {healthy_obj}")
    if tracker.state("energy-saving") != OK:
        raise RuntimeError("healthy traffic should not trip the latency SLO")

    # synthetic overload: saturate both windows far past the p99 target
    for _ in range(cfg.slow_window):
        tracker.observe("energy-saving", latency_s=10.0)
    tracker.evaluate()
    if tracker.state("energy-saving") != FIRING:
        raise RuntimeError("sustained overload did not drive the SLO to firing")
    done = server.run(_requests(scale, slo="energy-saving"))
    escalated_obj = {r.served_objective for r in done}
    if escalated_obj != {"latency"}:
        raise RuntimeError(f"firing latency SLO served as {escalated_obj}")

    # recovery: healthy samples flush the fast window; the alert clears
    # straight to ok (hysteresis) and requests return to the native objective
    for _ in range(cfg.fast_window):
        tracker.observe("energy-saving", latency_s=1e-3)
    tracker.evaluate()
    if tracker.state("energy-saving") != OK:
        raise RuntimeError("healthy fast window did not clear the alert")
    done = server.run(_requests(scale, slo="energy-saving"))
    recovered_obj = {r.served_objective for r in done}
    if recovered_obj != {"energy"}:
        raise RuntimeError(f"cleared SLO still served as {recovered_obj}")

    snap = tracker.snapshot()["classes"]["energy-saving"]
    return {
        "fired": 1,
        "escalated": 1,
        "recovered": 1,
        "alerts": snap["alerts"],
        "transitions": len(transitions),
    }


def run(scale_name: str = "paper") -> dict:
    s = SCALES[scale_name]
    predictor = get_predictor(scale_name)
    overhead = OverheadPredictor().fit(
        [measure_overheads(generate_by_name(n, scale=s["scale"]), n)
         for n in MATRIX_NAMES[:4]]
    )
    tuner = AutoSpMV(predictor, overhead)

    overhead_payload = _overhead_study(tuner, s["scale"])
    slo_payload = _escalation_study(tuner, s["scale"])

    print_table(
        f"obs overhead: {N_UNIQUE * REPEATS} warm requests, best of {PASSES}",
        ["mode", "per-request s", "ratio"],
        [
            ["obs on", overhead_payload["obs_on"]["per_request_s"],
             overhead_payload["overhead_ratio"]],
            ["obs off", overhead_payload["obs_off"]["per_request_s"], 1.0],
        ],
    )
    log.info(
        "obs overhead ratio %.3f; slo loop closed (alerts=%d, transitions=%d)",
        overhead_payload["overhead_ratio"],
        slo_payload["alerts"],
        slo_payload["transitions"],
    )
    payload = {**overhead_payload, "slo": slo_payload}
    save_result("obs_overhead", payload)
    return payload


if __name__ == "__main__":
    run("ci")
