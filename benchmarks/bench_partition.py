"""Partitioned heterogeneous-format SpMV vs the best monolithic plan.

The acceptance study for ``repro.partition``: on a synthetic
block-heterogeneous matrix (a dense-band block stacked on a power-law block,
both from ``sparse/generate.py``) the composite plan must beat the best
single-format monolithic plan on the modeled latency objective, and a
homogeneous matrix must fall back to block count 1. Reports the modeled
margin, the measured (interpret-mode wall time) margin, per-block routing,
and a multi-device ``shard_map`` correctness pass on however many devices
the host exposes.

Two PR6 studies ride along:

- fused single-launch executor: the same composite plan lowered into ONE
  Pallas launch (merge-path work descriptor) must measure no slower than
  the sequential per-block dispatch AND the best monolithic kernel — the
  per-launch fixed cost it removes is real, not modeled.
- calibration: per-block (predicted, measured) pairs from ``timed_call``
  feed ``CalibratedCostModel.fit``; its mean relative error against the
  same measurements must be at most half the uncalibrated model's.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import print_table, save_result
from repro.core.objectives import CalibratedCostModel
from repro.core.session import build_tuner
from repro.kernels.ops import compile_spmv
from repro.partition import (
    compile_fused_partitioned,
    compile_partitioned,
    partition_rows,
    shard_partitioned,
)
from repro.telemetry import TelemetryRecorder
from repro.sparse.generate import MATRIX_NAMES, random_matrix
from repro.utils.logging import get_logger

log = get_logger("bench.partition")

SCALES = {
    "smoke": dict(n=512, band_avg=128.0, tail_avg=3.0, train_scale=0.0008,
                  train_names=4, reps=1),
    "ci": dict(n=512, band_avg=128.0, tail_avg=3.0, train_scale=0.0012,
               train_names=8, reps=2),
    "paper": dict(n=1024, band_avg=192.0, tail_avg=4.0, train_scale=0.0015,
                  train_names=12, reps=3),
}


def heterogeneous_matrix(n: int, band_avg: float, tail_avg: float) -> np.ndarray:
    """Dense-band top half stacked on a power-law bottom half."""
    top = random_matrix(n, band_avg, "denseband", seed=1)[: n // 2]
    bot = random_matrix(n, tail_avg, "powerlaw", seed=2)[n // 2 :]
    return np.vstack([top, bot]).astype(np.float32)


def _measure(kernel, x, reps: int) -> tuple[float, np.ndarray]:
    y = np.asarray(kernel(x))  # warmup (traces + compiles)
    t0 = time.perf_counter()
    for _ in range(max(reps, 1)):
        y = np.asarray(kernel(x))
    return (time.perf_counter() - t0) / max(reps, 1), y


def run(scale: str = "ci") -> dict:
    cfg = SCALES.get(scale, SCALES["ci"])
    n, reps = cfg["n"], cfg["reps"]
    tuner = build_tuner(
        scale=cfg["train_scale"],
        names=MATRIX_NAMES[: cfg["train_names"]],
        n_extra=0,
        fit_overhead=False,
    )
    rng = np.random.default_rng(0)
    out: dict = {"scale": scale, "n": n}

    # --- heterogeneous: composite plan must beat the best monolithic ------
    het = heterogeneous_matrix(n, cfg["band_avg"], cfg["tail_avg"])
    x = rng.normal(size=n).astype(np.float32)
    ref = het @ x
    plan = tuner.plan_partitioned(het, "latency")
    margin_pct = 100.0 * plan.gain()
    out["hetero"] = {
        "n_blocks": plan.n_blocks,
        "formats": list(plan.formats),
        "monolithic_fmt": plan.monolithic_fmt,
        "modeled_latency_s": plan.modeled.latency,
        "monolithic_latency_s": plan.monolithic.latency,
        "modeled_margin_pct": margin_pct,
    }
    assert plan.partitioned, (
        f"heterogeneous matrix failed to partition: k={plan.n_blocks}"
    )
    assert margin_pct > 0, f"partitioned plan lost by {margin_pct:.1f}%"

    part_kernel = compile_partitioned(het, plan)
    mono_kernel = compile_spmv(
        het, plan.monolithic_fmt, plan.monolithic_schedule or plan.blocks[0].schedule
    )
    t_part, y_part = _measure(part_kernel, x, reps)
    t_mono, y_mono = _measure(mono_kernel, x, reps)
    norm = np.abs(ref).max() + 1e-9
    err_part = float(np.abs(y_part - ref).max() / norm)
    err_mono = float(np.abs(y_mono - ref).max() / norm)
    assert err_part < 2e-2, f"partitioned output diverged: {err_part}"
    out["hetero"].update(
        measured_partitioned_s=t_part,
        measured_monolithic_s=t_mono,
        rel_err_partitioned=err_part,
        rel_err_monolithic=err_mono,
    )

    # --- fused single-launch executor vs sequential dispatch --------------
    fused_kernel = compile_fused_partitioned(het, plan)
    t_fused, y_fused = _measure(fused_kernel, x, reps)
    err_fused = float(np.abs(y_fused - ref).max() / norm)
    assert err_fused < 2e-2, f"fused output diverged: {err_fused}"
    out["hetero"].update(
        measured_fused_s=t_fused,
        rel_err_fused=err_fused,
        fused_n_tiles=fused_kernel.n_tiles,
        fused_tile=fused_kernel.kernel.tile,
    )
    assert t_fused <= t_part, (
        f"fused single launch ({t_fused*1e3:.2f} ms) slower than sequential "
        f"per-block dispatch ({t_part*1e3:.2f} ms)"
    )
    assert t_fused <= t_mono, (
        f"fused single launch ({t_fused*1e3:.2f} ms) slower than the best "
        f"monolithic kernel ({t_mono*1e3:.2f} ms)"
    )

    # --- calibration: measured block times halve the model's error --------
    recorder = TelemetryRecorder()
    for _ in range(max(reps, 3)):
        _, block_times = part_kernel.timed_call(x)
        for bp, t in zip(plan.blocks, block_times):
            recorder.observe(
                bucket=f"blk{bp.block.index}",
                objective="latency",
                fmt=bp.fmt,
                measured_s=t,
                predicted_s=max(bp.modeled.latency, 1e-9),
            )
    cal = CalibratedCostModel.fit_from_telemetry(recorder)
    errs_raw, errs_cal = [], []
    for fmt, pairs in recorder.calibration_samples().items():
        c = cal.corrections.get(fmt)
        for pred, meas in pairs:
            errs_raw.append(abs(pred - meas) / meas)
            fitted = c.launch_overhead_s + c.latency_scale * pred if c else pred
            errs_cal.append(abs(fitted - meas) / meas)
    mre_raw = float(np.mean(errs_raw))
    mre_cal = float(np.mean(errs_cal))
    # a calibrated re-plan now charges the measured per-launch fixed cost
    plan_cal = tuner.plan_partitioned(het, "latency", cost_model=cal)
    out["calibration"] = {
        "samples": sum(len(p) for p in recorder.calibration_samples().values()),
        "formats_fitted": len(cal.corrections),
        "mre_uncalibrated": mre_raw,
        "mre_calibrated": mre_cal,
        "calibrated_n_blocks": plan_cal.n_blocks,
    }
    assert mre_cal <= mre_raw / 2, (
        f"calibration did not halve the model error: "
        f"{mre_cal:.3f} vs raw {mre_raw:.3f}"
    )

    # --- homogeneous: must fall back to the monolithic plan ---------------
    homo = random_matrix(n, 12.0, "powerlaw", seed=5).astype(np.float32)
    plan_h = tuner.plan_partitioned(homo, "latency")
    out["homogeneous"] = {
        "n_blocks": plan_h.n_blocks,
        "formats": list(plan_h.formats),
        "modeled_latency_s": plan_h.modeled.latency,
        "monolithic_latency_s": plan_h.monolithic.latency,
    }
    assert not plan_h.partitioned, (
        f"homogeneous matrix partitioned: k={plan_h.n_blocks}"
    )
    assert plan_h.modeled.latency <= plan_h.monolithic.latency * (1 + 1e-9), (
        "monolithic fallback regressed its own baseline"
    )

    # --- multi-device shard_map path (however many devices exist) ---------
    import jax

    n_dev = len(jax.devices())
    sharded = shard_partitioned(het, partition_rows(het, n_dev))
    y_sh = sharded(x)
    err_sh = float(np.abs(y_sh - ref).max() / norm)
    assert err_sh < 2e-2, f"sharded output diverged: {err_sh}"
    out["sharded"] = {"devices": n_dev, "rel_err": err_sh}

    print_table(
        "Partitioned vs monolithic (modeled latency objective)",
        ["matrix", "k", "formats", "modeled_s", "mono_s", "margin %"],
        [
            ["hetero", plan.n_blocks, "+".join(plan.formats),
             plan.modeled.latency, plan.monolithic.latency, margin_pct],
            ["homo", plan_h.n_blocks, "+".join(set(plan_h.formats)),
             plan_h.modeled.latency, plan_h.monolithic.latency,
             100.0 * plan_h.gain()],
        ],
    )
    log.info(
        "hetero: measured %.2f ms fused vs %.2f ms sequential partitioned vs "
        "%.2f ms monolithic (interpret mode); sharded over %d device(s), rel "
        "err %.2e",
        t_fused * 1e3,
        t_part * 1e3,
        t_mono * 1e3,
        n_dev,
        err_sh,
    )
    log.info(
        "calibration: %d per-block samples, mean rel err %.2f uncalibrated "
        "-> %.2f calibrated; calibrated planner picks k=%d",
        out["calibration"]["samples"],
        mre_raw,
        mre_cal,
        plan_cal.n_blocks,
    )
    save_result("bench_partition", out)
    return out


if __name__ == "__main__":
    run("ci")
