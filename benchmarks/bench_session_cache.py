"""Session-cache benchmark: cold vs warm tuning throughput (§5.3 amortization).

Traffic model: a fleet repeatedly submits matrices drawn from a small pool
(solvers re-factor the same systems). Three passes over the same request
stream measure where the time goes:

* **cold**  — fresh session, empty caches: every unique matrix pays feature
  extraction + predictor inference + kernel specialization;
* **warm**  — same session: plans and kernels come from the caches;
* **reload** — new session restored from the JSON cache file (kernel memo
  still process-warm): the restart path a serving fleet takes.

Run via ``python -m benchmarks.run --only session_cache`` or directly.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import ART, SCALES, get_predictor, print_table, save_result
from repro.core import AutoSpMV, AutoSpmvSession, OverheadPredictor, measure_overheads
from repro.kernels.ops import clear_kernel_memo
from repro.sparse.generate import MATRIX_NAMES, generate_by_name
from repro.utils.logging import get_logger

log = get_logger("bench.session_cache")

N_UNIQUE = 5  # distinct matrices in the pool
REPEATS = 4  # each submitted this many times -> 20 requests minimum


def _request_stream(scale: float) -> tuple[list[np.ndarray], int]:
    names = MATRIX_NAMES[:N_UNIQUE]
    uniques = [generate_by_name(n, scale=scale) for n in names]
    mats = [m for m in uniques for _ in range(REPEATS)]
    rng = np.random.default_rng(0)
    order = rng.permutation(len(mats))
    return [mats[i] for i in order], len(uniques)


def _timed_pass(session: AutoSpmvSession, mats: list[np.ndarray]) -> dict:
    before = session.stats.as_dict()
    t0 = time.perf_counter()
    results = session.optimize_many(mats, "latency")
    dt = time.perf_counter() - t0
    after = session.stats.as_dict()
    assert all(r is not None for r in results)
    return {
        "seconds": dt,
        "matrices_per_s": len(mats) / dt,
        "feature_extractions": after["feature_extractions"] - before["feature_extractions"],
        "plans_computed": after["plans_computed"] - before["plans_computed"],
        "kernel_compiles": after["kernel_compiles"] - before["kernel_compiles"],
    }


def run(scale_name: str = "paper", cache_path: str | None = None) -> dict:
    s = SCALES[scale_name]
    predictor = get_predictor(scale_name)
    overhead = OverheadPredictor().fit(
        [measure_overheads(generate_by_name(n, scale=s["scale"]), n)
         for n in MATRIX_NAMES[:6]]
    )
    tuner = AutoSpMV(predictor, overhead)
    mats, n_unique = _request_stream(s["scale"])

    clear_kernel_memo()
    cache_path = cache_path or str(ART / "session_cache.json")
    cold_session = AutoSpmvSession(tuner, cache_path=None)
    cold = _timed_pass(cold_session, mats)
    warm = _timed_pass(cold_session, mats)
    cold_session.cache.save(cache_path)

    reload_session = AutoSpmvSession(tuner, cache_path=cache_path)
    reload_pass = _timed_pass(reload_session, mats)

    rows = [
        ["cold", cold["seconds"], cold["matrices_per_s"], cold["feature_extractions"],
         cold["plans_computed"], cold["kernel_compiles"]],
        ["warm", warm["seconds"], warm["matrices_per_s"], warm["feature_extractions"],
         warm["plans_computed"], warm["kernel_compiles"]],
        ["reload", reload_pass["seconds"], reload_pass["matrices_per_s"],
         reload_pass["feature_extractions"], reload_pass["plans_computed"],
         reload_pass["kernel_compiles"]],
    ]
    print_table(
        f"session cache: {len(mats)} requests over {n_unique} unique matrices",
        ["pass", "seconds", "mat/s", "f-extract", "plans", "compiles"],
        rows,
    )
    speedup = cold["seconds"] / max(warm["seconds"], 1e-9)
    log.info(
        "warm speedup over cold: %.1fx (plan inferences %d -> %d, kernel "
        "compiles %d -> %d)",
        speedup,
        cold["plans_computed"],
        warm["plans_computed"],
        cold["kernel_compiles"],
        warm["kernel_compiles"],
    )

    payload = {
        "n_requests": len(mats),
        "n_unique": n_unique,
        "cold": cold,
        "warm": warm,
        "reload": reload_pass,
        "warm_speedup": speedup,
    }
    save_result("session_cache", payload)
    return payload


if __name__ == "__main__":
    run("ci")
