"""Iterative solvers over the serving stack: amortization + adaptive SpMSpV.

The acceptance study for ``repro.solvers``:

- **PageRank** on the ``webgraph`` suite matrix must match the dense-NumPy
  reference ranks to 1e-5 while computing exactly ONE ``serve_optimize``
  plan for the whole solve (the §5.3 amortize-forever claim, counted);
- **CG** on an SPD fem operator must match ``np.linalg.solve`` to 1e-5
  with monotonically trending-down residuals;
- **adaptive SpMV↔SpMSpV** power iteration from a single seed vertex must
  beat the always-SpMV run on *total modeled work* (stored nonzeros
  touched) — the sparse-frontier iterations are the entire point of the
  SpMSpV path, and modeled work is deterministic where wall time is not.

Reported metrics include end-to-end solve latency, per-iteration p50, and
the adaptive/always per-iteration latency ratio — the second gated metric
in ``benchmarks/compare.py`` (both sides measured in the same process, so
the ratio cancels runner speed exactly like the fused/sequential gate).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import print_table, save_result
from repro.core.session import AutoSpmvSession, build_tuner
from repro.solvers import AdaptiveSpmvPolicy, cg, pagerank, power_iteration
from repro.solvers.pagerank import pagerank_reference
from repro.sparse.generate import MATRIX_NAMES, generate_by_name, random_matrix
from repro.telemetry import AdaptiveFormatSelector
from repro.utils.logging import get_logger

log = get_logger("bench.solvers")

SCALES = {
    "smoke": dict(web_scale=0.0003, fem_n=192, train_scale=0.0008,
                  train_names=4, pr_tol=1e-6, pr_iters=120, power_iters=12),
    "ci": dict(web_scale=0.0005, fem_n=256, train_scale=0.0012,
               train_names=8, pr_tol=1e-7, pr_iters=160, power_iters=16),
    "paper": dict(web_scale=0.001, fem_n=384, train_scale=0.0015,
                  train_names=12, pr_tol=1e-8, pr_iters=200, power_iters=20),
}


def spd_fem(n: int, seed: int = 3) -> np.ndarray:
    """Symmetrized diagonally-dominant fem matrix — CG's SPD contract."""
    F = random_matrix(n, 6.0, "fem", seed=seed).astype(np.float32)
    S = (F + F.T) / 2
    margin = float(np.abs(S).sum(axis=1).max()) + 1.0
    return (S + margin * np.eye(n, dtype=np.float32)).astype(np.float32)


def run(scale: str = "ci") -> dict:
    cfg = SCALES.get(scale, SCALES["ci"])
    tuner = build_tuner(
        scale=cfg["train_scale"],
        names=MATRIX_NAMES[: cfg["train_names"]],
        n_extra=0,
        fit_overhead=False,
    )
    session = AutoSpmvSession(tuner)
    out: dict = {"scale": scale}

    # --- PageRank on webgraph: reference ranks + one-plan amortization ----
    web = generate_by_name("webgraph", scale=cfg["web_scale"])
    plans_before = session.stats.plans_computed
    pr = pagerank(session, web, tol=cfg["pr_tol"], max_iters=cfg["pr_iters"])
    plans_pr = session.stats.plans_computed - plans_before
    ref = pagerank_reference(web, tol=1e-12)
    pr_err = float(np.abs(pr.value - ref).max())
    out["pagerank"] = {
        "n": int(web.shape[0]),
        "nnz": int((web != 0).sum()),
        "iterations": pr.iterations,
        "converged": pr.converged,
        "residual": pr.residual,
        "total_s": float(sum(pr.iteration_seconds)),
        "iter_p50_s": pr.iter_p50_s(),
        "max_err_vs_reference": pr_err,
        "plans_computed": plans_pr,
        "dangling_nodes": pr.extras["dangling_nodes"],
    }
    assert pr.converged, f"pagerank failed to converge: residual {pr.residual}"
    assert pr_err < 1e-5, f"pagerank diverged from dense reference: {pr_err}"
    assert plans_pr == 1, f"pagerank computed {plans_pr} plans; expected 1"

    # --- CG on SPD fem: reference solution + decreasing residuals ---------
    S = spd_fem(cfg["fem_n"])
    b = np.random.default_rng(0).standard_normal(cfg["fem_n"]).astype(np.float32)
    res_cg = cg(session, S, b, tol=1e-10, max_iters=300)
    x_ref = np.linalg.solve(S.astype(np.float64), b.astype(np.float64))
    cg_err = float(np.abs(res_cg.value - x_ref).max())
    out["cg"] = {
        "n": cfg["fem_n"],
        "iterations": res_cg.iterations,
        "converged": res_cg.converged,
        "residual": res_cg.residual,
        "total_s": float(sum(res_cg.iteration_seconds)),
        "iter_p50_s": res_cg.iter_p50_s(),
        "max_err_vs_solve": cg_err,
    }
    assert res_cg.converged, f"cg failed to converge: residual {res_cg.residual}"
    assert cg_err < 1e-5, f"cg diverged from np.linalg.solve: {cg_err}"

    # --- adaptive SpMV<->SpMSpV vs always-SpMV (power, sparse seed) -------
    # same matrix, same seed vertex, same iteration count; only the routing
    # policy differs, so the modeled-work and latency deltas are the policy's
    k = cfg["power_iters"]
    adaptive = power_iteration(
        session, web, tol=0.0, max_iters=k,
        policy=AdaptiveSpmvPolicy(selector=AdaptiveFormatSelector()),
    )
    always = power_iteration(session, web, tol=0.0, max_iters=k)
    ratio = adaptive.iter_p50_s() / max(always.iter_p50_s(), 1e-12)
    out["adaptive"] = {
        "iterations": adaptive.iterations,
        "spmv_calls": adaptive.spmv_calls,
        "spmspv_calls": adaptive.spmspv_calls,
        "modeled_work": adaptive.modeled_work,
        "iter_p50_s": adaptive.iter_p50_s(),
        "total_s": float(sum(adaptive.iteration_seconds)),
    }
    out["always"] = {
        "iterations": always.iterations,
        "modeled_work": always.modeled_work,
        "iter_p50_s": always.iter_p50_s(),
        "total_s": float(sum(always.iteration_seconds)),
    }
    out["adaptive_over_always_iter_ratio"] = float(ratio)
    assert adaptive.spmspv_calls > 0, (
        "adaptive policy never routed a sparse frontier through SpMSpV"
    )
    assert adaptive.modeled_work < always.modeled_work, (
        f"adaptive modeled work {adaptive.modeled_work} did not beat "
        f"always-SpMV {always.modeled_work}"
    )

    print_table(
        "Iterative solvers over one served plan",
        ["solver", "iters", "converged", "residual", "iter p50 ms", "max err"],
        [
            ["pagerank", pr.iterations, pr.converged, pr.residual,
             pr.iter_p50_s() * 1e3, pr_err],
            ["cg", res_cg.iterations, res_cg.converged, res_cg.residual,
             res_cg.iter_p50_s() * 1e3, cg_err],
        ],
    )
    log.info(
        "adaptive power: %d spmspv + %d spmv calls, modeled work %d vs "
        "always-SpMV %d (%.1f%% saved); iter p50 ratio %.3f",
        adaptive.spmspv_calls,
        adaptive.spmv_calls,
        adaptive.modeled_work,
        always.modeled_work,
        100.0 * (1 - adaptive.modeled_work / always.modeled_work),
        ratio,
    )
    save_result("bench_solvers", out)
    return out


if __name__ == "__main__":
    run("ci")
