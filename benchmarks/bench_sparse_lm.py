"""Sparse LM serving vs dense decode: tok/s, numerics, plan amortization.

The acceptance study for the sparse-serving path (``models/sparse_linear`` +
``BatchedServer(engine=...)``):

- **numerics**: one decode step served through planned SpMV kernels must
  match the dense decode on the SAME pruned params within fp32 tolerance;
- **amortization**: an entire multi-request, multi-token decode computes
  exactly one ``serve_optimize`` plan per (weight fingerprint, objective) —
  ``session.stats.requests`` must equal ``#matrices x #objectives`` and stay
  flat between the warmup and the measured run;
- **throughput**: dense and sparse servers decode the same request stream
  on the same pruned params (both warmed, so jit tracing is excluded); the
  per-token ratio is the gated metric in ``benchmarks/compare.py``. On this
  CPU container the interpret-mode SpMV route is expected to LOSE to the
  XLA dense matmul — the gate bounds the slowdown, it does not claim a win;
- **SLO accounting**: the mixed request stream must land per-objective
  energy cells (``<fmt>/<objective>/lm``) in the server summary.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table, save_result
from repro.configs import get_config
from repro.core.session import AutoSpmvSession, build_tuner
from repro.models import init_params, model_specs
from repro.models.model import decode_step, init_cache, prefill
from repro.models.sparse_linear import (
    SLO_PRIORITY,
    SparseInferenceEngine,
    prune_model_ffns,
)
from repro.train.serve import BatchedServer, Request, ServeConfig
from repro.utils.logging import get_logger

log = get_logger("bench.sparse_lm")

SCALES = {
    "smoke": dict(arch="qwen3-0.6b", requests=2, slots=1, new_tokens=3,
                  density=0.05, train_scale=0.0008, train_names=3),
    "ci": dict(arch="qwen3-0.6b", requests=4, slots=2, new_tokens=4,
               density=0.05, train_scale=0.0008, train_names=4),
    "paper": dict(arch="qwen3-0.6b", requests=8, slots=4, new_tokens=6,
                  density=0.05, train_scale=0.0012, train_names=8),
}


def _requests(n: int, new_tokens: int, vocab: int, seed: int) -> list[Request]:
    rng = np.random.default_rng(seed)
    return [
        Request(
            rid=i,
            prompt=rng.integers(0, vocab, size=int(rng.integers(4, 13))).tolist(),
            max_new_tokens=new_tokens,
            slo=SLO_PRIORITY[i % len(SLO_PRIORITY)],
        )
        for i in range(n)
    ]


def _serve(server: BatchedServer, reqs: list[Request]) -> tuple[int, float]:
    t0 = time.perf_counter()
    done = server.run(reqs)
    dt = time.perf_counter() - t0
    return sum(len(r.generated) for r in done), dt


def run(scale: str = "ci") -> dict:
    cfg_b = SCALES.get(scale, SCALES["ci"])
    cfg = get_config(cfg_b["arch"], reduced_config=True)
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0), cfg.param_dtype)

    from repro.sparse.generate import MATRIX_NAMES

    tuner = build_tuner(
        scale=cfg_b["train_scale"],
        names=MATRIX_NAMES[: cfg_b["train_names"]],
        n_extra=0,
        fit_overhead=False,
    )
    session = AutoSpmvSession(tuner)
    engine = SparseInferenceEngine(session)
    pruned = prune_model_ffns(params, cfg, engine, density=cfg_b["density"])
    out: dict = {"scale": scale, "arch": cfg.name,
                 "matrices": engine.stats.registered,
                 "density": cfg_b["density"]}

    # --- numerics: sparse-served decode == dense decode on pruned params --
    tokens = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size, (1, 6)), jnp.int32
    )
    cache = init_cache(cfg, 1, 64)
    logits, cache, _ = prefill(pruned, cfg, cache, tokens=tokens)
    nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    pos = jnp.full((1, 1), 6, jnp.int32)
    ld, _ = decode_step(pruned, cfg, cache, nxt, pos)
    engine.plan_all("latency")
    ls, _ = decode_step(
        pruned, cfg, cache, nxt, pos,
        unroll_layers=True, engine=engine.bind("latency"),
    )
    err = float(jnp.max(jnp.abs(ld - ls)))
    out["numerics_max_abs_diff"] = err
    assert err < 5e-4, f"sparse-served logits diverged from dense: {err}"

    # --- dense vs sparse serving on the SAME pruned params ----------------
    sc = ServeConfig(batch_slots=cfg_b["slots"], max_len=128,
                     max_new_tokens=cfg_b["new_tokens"])
    results: dict[str, dict] = {}
    for mode in ("dense", "sparse"):
        server = BatchedServer(
            pruned, cfg, sc, engine=engine if mode == "sparse" else None
        )
        # warm with the IDENTICAL request stream: scheduling is deterministic
        # (greedy argmax, fixed slot order), so the warmup traces exactly the
        # per-objective decode graphs and computes exactly the plans the
        # measured run will reuse — the gated per-token ratio is steady-state
        stream = lambda: _requests(  # noqa: E731
            cfg_b["requests"], cfg_b["new_tokens"], cfg.vocab_size, seed=0
        )
        _serve(server, stream())
        plans_before = session.stats.requests
        toks, dt = _serve(server, stream())
        assert toks > 0, f"{mode} serving generated no tokens"
        results[mode] = {
            "tokens": toks,
            "wall_s": dt,
            "tok_s": toks / max(dt, 1e-9),
            "per_token_s": dt / toks,
        }
        if mode == "sparse":
            # the whole measured decode reused warm plans: one plan per
            # (fingerprint, objective), computed before this run
            assert session.stats.requests == plans_before, (
                f"sparse serving computed {session.stats.requests - plans_before} "
                "new plans during the measured run; expected full reuse"
            )
            n_objectives = len({obj for (_, obj) in engine._plans})
            expected = engine.stats.spmv_layers * n_objectives
            assert session.stats.requests == expected, (
                f"{session.stats.requests} serve_optimize calls for "
                f"{engine.stats.spmv_layers} matrices x {n_objectives} objectives"
            )
            summary = server.summary()
            out["slo_classes"] = summary["slo_classes"]
            cells = summary.get("energy", {})
            out["energy_cells"] = {
                k: {"requests": v["requests"], "energy_j": v["energy_j"]}
                for k, v in cells.items()
            }
            objectives_seen = {k.split("/")[1] for k in cells}
            assert objectives_seen, "sparse serving produced no energy cells"
    out["dense"] = results["dense"]
    out["sparse"] = results["sparse"]
    ratio = results["sparse"]["per_token_s"] / results["dense"]["per_token_s"]
    out["sparse_over_dense_per_token"] = float(ratio)
    out["engine"] = engine.stats.as_dict()
    out["session_plan_requests"] = session.stats.requests

    print_table(
        "Sparse LM serving vs dense decode (same pruned params)",
        ["mode", "tokens", "wall s", "tok/s", "ms/token"],
        [
            [m, r["tokens"], r["wall_s"], r["tok_s"], r["per_token_s"] * 1e3]
            for m, r in results.items()
        ],
    )
    log.info(
        "sparse/dense per-token ratio %.2f; %d plans for %d matrices; "
        "energy cells %s",
        ratio, engine.stats.plans, engine.stats.registered,
        sorted(out["energy_cells"]),
    )
    save_result("bench_sparse_lm", out)
    return out


if __name__ == "__main__":
    run("ci")
