"""Shared benchmark infrastructure: cached dataset/predictors, table
printing, improvement math.

Scales: ``ci`` (fast, smoke-level), ``paper`` (default; full 30-matrix suite
at laptop scale). Artifacts land in ``artifacts/bench/``.
"""

from __future__ import annotations

import json
import os
from pathlib import Path

import numpy as np

from repro.core import (
    MINIMIZE,
    OBJECTIVES,
    AutoSpmvPredictor,
    PredictorConfig,
    TuningDataset,
    collect_dataset,
)
from repro.sparse.generate import MATRIX_NAMES

ART = Path(os.environ.get("REPRO_BENCH_DIR", "artifacts/bench"))

SCALES = {
    # matrix_scale, n_matrices, n_extra, regressor_samples
    "smoke": dict(scale=0.0008, names=MATRIX_NAMES[:4], n_extra=0, reg_samples=300),
    "ci": dict(scale=0.0012, names=MATRIX_NAMES[:10], n_extra=4, reg_samples=800),
    "paper": dict(scale=0.002, names=MATRIX_NAMES, n_extra=12, reg_samples=2500),
}


def get_dataset(scale_name: str = "paper", *, measure_cpu: bool = False) -> TuningDataset:
    """Collect (or load cached) the labelled tuning dataset."""
    ART.mkdir(parents=True, exist_ok=True)
    tag = "cpu" if measure_cpu else "model"
    cache = ART / f"dataset_{scale_name}_{tag}.json"
    if cache.exists():
        return TuningDataset.load(cache)
    s = SCALES[scale_name]
    ds = collect_dataset(
        scale=s["scale"], names=s["names"], n_extra=s["n_extra"], measure_cpu=measure_cpu
    )
    ds.save(cache)
    return ds


_PREDICTORS: dict = {}


def get_predictor(scale_name: str = "paper", *, tune: bool = False) -> AutoSpmvPredictor:
    key = (scale_name, tune)
    if key not in _PREDICTORS:
        ds = get_dataset(scale_name)
        cfg = PredictorConfig(
            tune=tune,
            n_trials=8,
            max_regressor_samples=SCALES[scale_name]["reg_samples"],
        )
        _PREDICTORS[key] = AutoSpmvPredictor(cfg).fit(ds)
    return _PREDICTORS[key]


def improvement_pct(default: float, best: float, objective: str) -> float:
    """Paper-style % improvement of `best` over `default` (positive = better)."""
    if MINIMIZE[objective]:
        return 100.0 * (default - best) / default
    return 100.0 * (best - default) / default


def print_table(title: str, headers: list[str], rows: list[list], fmt: str = "10.3g"):
    print(f"\n== {title} ==")
    widths = [max(len(str(h)), 12) for h in headers]
    print("  ".join(str(h).ljust(w) for h, w in zip(headers, widths)))
    for row in rows:
        cells = []
        for c, w in zip(row, widths):
            if isinstance(c, float):
                cells.append(f"{c:{fmt}}".ljust(w))
            else:
                cells.append(str(c).ljust(w))
        print("  ".join(cells))


def save_result(name: str, payload: dict):
    ART.mkdir(parents=True, exist_ok=True)
    (ART / f"{name}.json").write_text(json.dumps(payload, indent=1, default=float))
