"""Bench-regression gate: compare a fresh ``BENCH_<label>.json`` against the
committed baseline (``benchmarks/baseline/BENCH_smoke.json``).

Absolute wall times on shared CI runners are too noisy to gate on, so every
gate compares a *within-run latency ratio*: both sides of each ratio come
from the same process on the same machine, so runner speed cancels. Gated
ratios (lower = better):

* ``fused_vs_sequential`` — the partition bench's single-launch fused
  executor over its sequential per-block dispatch;
* ``solver_adaptive_vs_always`` — the solvers bench's per-iteration p50
  with the adaptive SpMV↔SpMSpV policy over the always-SpMV run;
* ``lm_sparse_per_token`` — sparse-served decode over dense decode;
* ``obs_overhead`` — warm serving with the observability layer on over the
  same path with it disabled.

Every check is evaluated and reported (``PASS``/``FAIL`` per line) before
the process exits nonzero — one regression never masks another in CI logs;
the final summary counts the failures by name.

A gate fails when its current ratio is more than ``--threshold`` (default
25%) worse than the baseline ratio AND the ratio has left the gate's
absolute comfort zone (``max_ok_ratio`` — e.g. the fused executor is no
longer 10× faster, or the adaptive solver is more than 25% slower per
iteration than always-SpMV). The absolute guard keeps ratio jitter that is
still well inside the win from failing CI.

A baseline that lacks a metric a gate references is itself a failure with
an explicit message naming the bench and metric — a silently skipped gate
is how regressions ship.

Also asserts every benchmark the baseline ran still exists and passed.

CLI::

    python -m benchmarks.compare artifacts/bench/BENCH_smoke.json \
        --baseline benchmarks/baseline/BENCH_smoke.json
"""

from __future__ import annotations

import argparse
import json
from dataclasses import dataclass
from pathlib import Path

from repro.utils.logging import get_logger

log = get_logger("bench.compare")

DEFAULT_BASELINE = Path(__file__).parent / "baseline" / "BENCH_smoke.json"

# kept as module constants: external tooling greps these key names
FUSED_KEY = "hetero/measured_fused_s"
SEQUENTIAL_KEY = "hetero/measured_partitioned_s"


@dataclass(frozen=True)
class RatioGate:
    """One gated within-run latency ratio (numerator / denominator)."""

    name: str
    bench: str  # benchmark record the metrics live in
    num_key: str
    den_key: str
    max_ok_ratio: float  # absolute comfort zone: never fail at or below this

    def keys(self) -> tuple[str, str]:
        return (self.num_key, self.den_key)


GATES = (
    RatioGate(
        name="fused_vs_sequential",
        bench="partition",
        num_key=FUSED_KEY,
        den_key=SEQUENTIAL_KEY,
        # historic min_margin=10x: jitter inside a 10x win never fails
        max_ok_ratio=0.1,
    ),
    RatioGate(
        name="solver_adaptive_vs_always",
        bench="solvers",
        num_key="adaptive/iter_p50_s",
        den_key="always/iter_p50_s",
        # adaptive routing must stay within 25% of always-SpMV per
        # iteration: the two runs execute mostly-identical SpMV work, so
        # their p50 ratio hovers near 1.0 with interpret-mode jitter either
        # side — only a structural slowdown pushes it past this
        max_ok_ratio=1.25,
    ),
    RatioGate(
        name="lm_sparse_per_token",
        bench="sparse_lm",
        num_key="sparse/per_token_s",
        den_key="dense/per_token_s",
        # sparse-served decode over dense decode on the same pruned params
        # (both warmed). The shared dense prefill dominates these tiny serving
        # runs, so the ratio measured on this container hovers near 1.0; the
        # guard bounds a structural blowup of the SpMV route (e.g. plans
        # recomputed per tick), not interpret-mode jitter
        max_ok_ratio=3.0,
    ),
    RatioGate(
        name="obs_overhead",
        bench="obs_overhead",
        num_key="obs_on/per_request_s",
        den_key="obs_off/per_request_s",
        # the observability layer (spans + counters + energy cells + burn
        # windows) over the identical warm serve path with obs disabled; the
        # layer claims a no-op fast path, so it must never double the
        # per-request cost
        max_ok_ratio=2.0,
    ),
)


def _bench_metrics(report: dict, name: str) -> dict | None:
    for bench in report.get("benchmarks", ()):
        if bench.get("name") == name:
            return bench.get("metrics") or {}
    return None


def gate_ratio(report: dict, gate: RatioGate) -> tuple[float | None, str | None]:
    """(ratio, problem): the gate's ratio in ``report``, or why it's absent.

    The problem string names the bench and metric precisely — it becomes
    the failure message when the *baseline* is the side missing it."""
    metrics = _bench_metrics(report, gate.bench)
    if metrics is None:
        return None, f"bench {gate.bench!r} not present"
    for key in gate.keys():
        if key not in metrics:
            return None, f"bench {gate.bench!r} lacks metric {key!r}"
    num, den = float(metrics[gate.num_key]), float(metrics[gate.den_key])
    if den <= 0 or num <= 0:
        return None, (
            f"bench {gate.bench!r} metric {gate.num_key!r}/{gate.den_key!r} "
            f"non-positive ({num:g}/{den:g})"
        )
    return num / den, None


def fused_ratio(report: dict) -> float | None:
    """fused / sequential latency of the partition bench (lower = better)."""
    return gate_ratio(report, GATES[0])[0]


@dataclass(frozen=True)
class Outcome:
    """One checked thing (gate or bench-presence) and how it went.

    Every outcome is evaluated and reported even after a failure — one
    regression must never mask another in the CI log."""

    name: str
    ok: bool
    detail: str


def compare(
    current: dict,
    baseline: dict,
    *,
    threshold: float = 0.25,
) -> tuple[bool, list[Outcome]]:
    """Evaluate every check; returns (all ok, one outcome per check)."""
    outcomes: list[Outcome] = []

    base_names = {b.get("name") for b in baseline.get("benchmarks", ())}
    cur_by_name = {b.get("name"): b for b in current.get("benchmarks", ())}
    for name in sorted(base_names):
        bench = cur_by_name.get(name)
        if bench is None:
            outcomes.append(Outcome(
                f"bench:{name}", False, f"baseline bench {name!r} was not run"
            ))
        elif not bench.get("ok"):
            outcomes.append(Outcome(
                f"bench:{name}", False, f"bench {name!r} did not pass"
            ))
        else:
            outcomes.append(Outcome(f"bench:{name}", True, "ran and passed"))

    for gate in GATES:
        base_ratio, base_problem = gate_ratio(baseline, gate)
        cur_ratio, cur_problem = gate_ratio(current, gate)
        if base_ratio is None:
            # a gate the baseline cannot anchor is a hard failure: regenerate
            # the committed baseline (benchmarks/baseline/BENCH_smoke.json)
            # with the current bench set instead of silently skipping
            outcomes.append(Outcome(
                gate.name, False,
                f"baseline missing metric: {base_problem}; regenerate the "
                f"committed baseline to include {gate.num_key!r} and "
                f"{gate.den_key!r}",
            ))
            continue
        if cur_ratio is None:
            outcomes.append(Outcome(
                gate.name, False,
                f"current run lost the measurement ({cur_problem})",
            ))
            continue
        rel = cur_ratio / base_ratio - 1.0
        detail = (
            f"ratio {cur_ratio:.4g} vs baseline {base_ratio:.4g} ({rel:+.1%})"
        )
        if rel > threshold and cur_ratio > gate.max_ok_ratio:
            outcomes.append(Outcome(
                gate.name, False,
                f"{detail}: degraded > {threshold:.0%} and exceeds the "
                f"absolute guard {gate.max_ok_ratio:g}",
            ))
        elif rel > threshold:
            outcomes.append(Outcome(
                gate.name, True,
                f"{detail}: degraded but still inside the absolute comfort "
                f"zone ({cur_ratio:.4g} <= {gate.max_ok_ratio:g}); treated "
                f"as noise",
            ))
        else:
            outcomes.append(Outcome(gate.name, True, detail))
    return all(o.ok for o in outcomes), outcomes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("results", help="fresh BENCH_<label>.json to check")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help="committed baseline results file")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max relative ratio degradation before failing")
    args = ap.parse_args(argv)

    current = json.loads(Path(args.results).read_text())
    baseline = json.loads(Path(args.baseline).read_text())
    ok, outcomes = compare(current, baseline, threshold=args.threshold)
    for o in outcomes:  # every outcome, pass or fail, before any exit
        (log.info if o.ok else log.error)(
            "%s [%s]: %s", "PASS" if o.ok else "FAIL", o.name, o.detail
        )
    failed = [o.name for o in outcomes if not o.ok]
    if failed:
        log.error(
            "bench regression gate: FAIL (%d of %d checks): %s",
            len(failed), len(outcomes), ", ".join(failed),
        )
        return 1
    log.info("bench regression gate: PASS (%d checks)", len(outcomes))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
