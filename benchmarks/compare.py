"""Bench-regression gate: compare a fresh ``BENCH_<label>.json`` against the
committed baseline (``benchmarks/baseline/BENCH_smoke.json``).

Absolute wall times on shared CI runners are too noisy to gate on, so the
gate compares the *fused-vs-sequential latency ratio* of the partition bench
— both measurements come from the same process on the same machine, so the
ratio cancels the runner's speed. A run fails when the current ratio is more
than ``--threshold`` (default 25%) worse than the baseline ratio AND the
fused executor is no longer at least ``--min-margin``× faster than the
sequential one (the margin guard keeps a 300×-faster kernel from failing CI
over ratio jitter that is still two orders of magnitude inside the win).

Also asserts every benchmark the baseline ran still exists and passed.

CLI::

    python -m benchmarks.compare artifacts/bench/BENCH_smoke.json \
        --baseline benchmarks/baseline/BENCH_smoke.json
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.utils.logging import get_logger

log = get_logger("bench.compare")

DEFAULT_BASELINE = Path(__file__).parent / "baseline" / "BENCH_smoke.json"

FUSED_KEY = "hetero/measured_fused_s"
SEQUENTIAL_KEY = "hetero/measured_partitioned_s"


def _bench_metrics(report: dict, name: str) -> dict | None:
    for bench in report.get("benchmarks", ()):
        if bench.get("name") == name:
            return bench.get("metrics") or {}
    return None


def fused_ratio(report: dict) -> float | None:
    """fused / sequential latency of the partition bench (lower = better)."""
    metrics = _bench_metrics(report, "partition")
    if not metrics:
        return None
    fused = metrics.get(FUSED_KEY)
    seq = metrics.get(SEQUENTIAL_KEY)
    if not fused or not seq or seq <= 0:
        return None
    return float(fused) / float(seq)


def compare(
    current: dict,
    baseline: dict,
    *,
    threshold: float = 0.25,
    min_margin: float = 10.0,
) -> tuple[bool, list[str]]:
    """Returns (ok, report lines)."""
    lines: list[str] = []
    ok = True

    base_names = {b.get("name") for b in baseline.get("benchmarks", ())}
    cur_by_name = {b.get("name"): b for b in current.get("benchmarks", ())}
    for name in sorted(base_names):
        bench = cur_by_name.get(name)
        if bench is None:
            ok = False
            lines.append(f"MISSING: baseline bench {name!r} was not run")
        elif not bench.get("ok"):
            ok = False
            lines.append(f"FAILED: bench {name!r} did not pass")

    cur_ratio, base_ratio = fused_ratio(current), fused_ratio(baseline)
    if base_ratio is None:
        lines.append("baseline has no fused/sequential measurement; ratio gate skipped")
    elif cur_ratio is None:
        ok = False
        lines.append("REGRESSION: current run lost the fused/sequential measurement")
    else:
        rel = cur_ratio / base_ratio - 1.0
        lines.append(
            f"fused/sequential ratio: {cur_ratio:.4g} vs baseline "
            f"{base_ratio:.4g} ({rel:+.1%})"
        )
        if rel > threshold and cur_ratio > 1.0 / min_margin:
            ok = False
            lines.append(
                f"REGRESSION: ratio degraded {rel:+.1%} (> {threshold:.0%}) and "
                f"fused is no longer {min_margin:g}x faster than sequential"
            )
        elif rel > threshold:
            lines.append(
                f"ratio degraded {rel:+.1%} but fused remains >{min_margin:g}x "
                "faster than sequential; inside the noise margin"
            )
    return ok, lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("results", help="fresh BENCH_<label>.json to check")
    ap.add_argument("--baseline", default=str(DEFAULT_BASELINE),
                    help="committed baseline results file")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max relative ratio degradation before failing")
    ap.add_argument("--min-margin", type=float, default=10.0,
                    help="never fail while fused stays this many times "
                         "faster than sequential")
    args = ap.parse_args(argv)

    current = json.loads(Path(args.results).read_text())
    baseline = json.loads(Path(args.baseline).read_text())
    ok, lines = compare(
        current, baseline, threshold=args.threshold, min_margin=args.min_margin
    )
    for line in lines:
        (log.info if ok else log.error)("%s", line)
    log.info("bench regression gate: %s", "PASS" if ok else "FAIL")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
