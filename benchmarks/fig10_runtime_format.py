"""Paper Fig. 10: run-time-mode (format selection) gains with compile
parameters already optimal, per matrix, per objective.

Paper findings reproduced: CSR is already best for latency/energy (gain ~0),
while average power and energy efficiency gain up to 34.6 % / 99.7 % from
switching formats."""

from __future__ import annotations

import numpy as np

from benchmarks.common import get_dataset, improvement_pct, print_table, save_result
from repro.core import OBJECTIVES
from repro.sparse import default_format


def run(scale_name: str = "paper") -> dict:
    ds = get_dataset(scale_name)
    suite = [m for m in ds.matrices if not m.startswith("synth")]
    payload = {"per_matrix": {}}
    rows = []
    for m in suite:
        gains, fmts = {}, {}
        for obj in OBJECTIVES:
            # compile params optimal, format held at the registry default
            csr_best = ds.best_record(m, obj, formats=(default_format(),))
            any_best = ds.best_record(m, obj)  # + format freedom
            gains[obj] = improvement_pct(
                csr_best.objective(obj), any_best.objective(obj), obj
            )
            fmts[obj] = any_best.config.fmt
        payload["per_matrix"][m] = {"gains": gains, "formats": fmts}
        rows.append([m] + [gains[o] for o in OBJECTIVES] + [fmts["efficiency"]])
    summary = {
        obj: {
            "max": float(max(p["gains"][obj] for p in payload["per_matrix"].values())),
            "mean": float(np.mean([p["gains"][obj] for p in payload["per_matrix"].values()])),
        }
        for obj in OBJECTIVES
    }
    payload["summary"] = summary
    print_table(
        "Fig.10 — run-time format gain (%) over best-CSR",
        ["matrix"] + list(OBJECTIVES) + ["eff_fmt"],
        rows,
        fmt="8.1f",
    )
    print_table(
        "Fig.10 summary (paper: ~0/~0/34.6/99.7 %)",
        ["objective", "max %", "mean %"],
        [[o, summary[o]["max"], summary[o]["mean"]] for o in OBJECTIVES],
        fmt="8.1f",
    )
    save_result("fig10", payload)
    return payload


if __name__ == "__main__":
    run()
