"""Paper Fig. 11: regression models estimating each objective.

All six regressor families (Table 4) are trained on (features + config
encoding) -> log(objective) and scored by R^2 / MSE on a held-out 20 %
split. The paper finds random forest best for energy/efficiency
(R^2 = 99.11/99.94 %), decision tree best for power (99.99 %), MLP best for
latency."""

from __future__ import annotations

import numpy as np

from benchmarks.common import SCALES, get_dataset, print_table, save_result
from repro.core import OBJECTIVES
from repro.core.predictor import _config_row
from repro.ml.metrics import mean_squared_error, r2_score
from repro.ml.model_zoo import REGRESSOR_ZOO


def _design(ds, cap, seed=0):
    recs = ds.feasible()
    if len(recs) > cap:
        idx = np.random.default_rng(seed).choice(len(recs), cap, replace=False)
        recs = [recs[i] for i in idx]
    X = np.stack(
        [np.concatenate([r.features.log_vector(), _config_row(r.config)]) for r in recs]
    )
    ys = {o: np.log(np.maximum(np.array([r.objective(o) for r in recs]), 1e-30))
          for o in OBJECTIVES}
    return X, ys


def run(scale_name: str = "paper", seed: int = 0) -> dict:
    ds = get_dataset(scale_name)
    cap = SCALES[scale_name]["reg_samples"]
    X, ys = _design(ds, cap, seed)
    n = X.shape[0]
    order = np.random.default_rng(seed).permutation(n)
    test, train = order[: n // 5], order[n // 5 :]
    payload, rows = {}, []
    for name, entry in REGRESSOR_ZOO.items():
        kw = dict(entry["defaults"])
        if name == "random_forest":
            kw.update(n_estimators=30)  # single-core budget
        if name == "mlp":
            kw.update(epochs=150, n_layers=3, hidden_layer_size=64)
        payload[name] = {}
        row = [name]
        for obj in OBJECTIVES:
            reg = entry["ctor"](**kw)
            reg.fit(X[train], ys[obj][train])
            pred = reg.predict(X[test])
            r2 = 100 * r2_score(ys[obj][test], pred)
            mse = mean_squared_error(ys[obj][test], pred)
            payload[name][obj] = {"r2": r2, "mse": mse}
            row.append(f"{r2:.2f}")
        rows.append(row)
    print_table(
        "Fig.11 — regressor R^2 (%) on held-out 20 % (log-objective)",
        ["model"] + list(OBJECTIVES),
        rows,
    )
    save_result("fig11", payload)
    return payload


if __name__ == "__main__":
    run()
