"""Paper Fig. 12: hardware sensitivity of the learned predictors.

The paper trains on a Turing GPU and validates the predicted configurations
on a Pascal GPU (<=2 % performance loss). We train the predictor on TPU v5e
cost-model labels and evaluate the *chosen configurations* under the TPU v4
cost model: performance loss = how much worse the v5e-chosen config is than
the true v4 optimum, on v4."""

from __future__ import annotations

import numpy as np

from benchmarks.common import SCALES, get_dataset, get_predictor, print_table, save_result
from repro.core import (
    MINIMIZE,
    OBJECTIVES,
    MatrixStats,
    TpuCostModel,
    TPU_V4,
    TuningConfig,
    full_space,
)
from repro.sparse.generate import SUITE, generate_by_name


def run(scale_name: str = "paper", n_matrices: int = 6, seed: int = 0) -> dict:
    ds = get_dataset(scale_name)
    pred = get_predictor(scale_name)  # trained on v5e labels
    v4 = TpuCostModel(TPU_V4)
    scale = SCALES[scale_name]["scale"]
    # the paper's Fig.12 subset: amazon0601, crankseg_2, bcsstk32, x104, il2010, Chevron3
    subset = [m for m in ("amazon0601", "crankseg_2", "bcsstk32", "x104", "il2010", "Chevron3")
              if m in ds.matrices][:n_matrices]
    if not subset:
        subset = ds.matrices[:n_matrices]
    space = list(full_space())
    payload, rows = {}, []
    for m in subset:
        dense = generate_by_name(m, scale=scale) if m in SUITE else None
        stats = MatrixStats(dense)
        feats = ds.for_matrix(m)[0].features
        losses = {}
        for obj in OBJECTIVES:
            # v5e-predicted configuration, evaluated on v4
            sched = pred.predict_schedule(feats, obj)
            fmt = pred.predict_format(feats, obj)
            chosen = v4.evaluate(stats, fmt, sched)
            # true v4 optimum over the space
            vals = [
                (v4.evaluate(stats, c.fmt, c.schedule), c) for c in space
            ]
            vals = [(v, c) for v, c in vals if v.feasible]
            best = (
                min(vals, key=lambda vc: vc[0].get(obj))
                if MINIMIZE[obj]
                else max(vals, key=lambda vc: vc[0].get(obj))
            )[0]
            if not chosen.feasible:
                loss = 100.0
            elif MINIMIZE[obj]:
                loss = 100 * (chosen.get(obj) - best.get(obj)) / best.get(obj)
            else:
                loss = 100 * (best.get(obj) - chosen.get(obj)) / best.get(obj)
            losses[obj] = loss
        payload[m] = losses
        rows.append([m] + [losses[o] for o in OBJECTIVES])
    mean_loss = {o: float(np.mean([payload[m][o] for m in payload])) for o in OBJECTIVES}
    payload["mean"] = mean_loss
    rows.append(["MEAN"] + [mean_loss[o] for o in OBJECTIVES])
    print_table(
        "Fig.12 — perf loss (%) of v5e-trained choices evaluated on v4 "
        "(paper: <=2 % Turing->Pascal)",
        ["matrix"] + list(OBJECTIVES),
        rows,
        fmt="8.1f",
    )
    save_result("fig12", payload)
    return payload


if __name__ == "__main__":
    run()
