"""Paper Fig. 3: Auto-SpMV vs default configuration on `consph`.

The paper reports >=2.04x latency, 2.07x energy, 1.08x power and 2.086x
efficiency over the default CUDA parameters (CSR + default compiler flags).
We report the same ratios on the TPU objective model: default = CSR +
default schedule; Auto-SpMV = the best (format, schedule) in the space.
"""

from __future__ import annotations

from benchmarks.common import get_dataset, print_table, save_result
from repro.core import OBJECTIVES, MINIMIZE


def run(scale_name: str = "paper") -> dict:
    ds = get_dataset(scale_name)
    matrix = "consph" if "consph" in ds.matrices else ds.matrices[0]
    default = ds.default_record(matrix)
    rows, payload = [], {"matrix": matrix}
    for obj in OBJECTIVES:
        best = ds.best_record(matrix, obj)
        d, b = default.objective(obj), best.objective(obj)
        ratio = d / b if MINIMIZE[obj] else b / d
        rows.append([obj, d, b, ratio, best.config.fmt,
                     f"rpb={best.config.schedule.rows_per_block}"])
        payload[obj] = {"default": d, "auto": b, "ratio": ratio,
                        "best_fmt": best.config.fmt}
    print_table(
        f"Fig.3 — Auto-SpMV vs default on {matrix} (ratio, higher=better)",
        ["objective", "default", "auto-spmv", "ratio", "fmt", "schedule"],
        rows,
    )
    save_result("fig3", payload)
    return payload


if __name__ == "__main__":
    run()
