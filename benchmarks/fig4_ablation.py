"""Paper Fig. 4: per-parameter ablation on `eu-2005`.

Improvement contributed by tuning each configuration knob in isolation
(all other knobs at default), per optimization objective. Reproduces the
paper's observation that compiler parameters (not just the storage format)
carry a large share of the attainable gain.
"""

from __future__ import annotations

from benchmarks.common import get_dataset, improvement_pct, print_table, save_result
from repro.core import KNOBS, MINIMIZE, OBJECTIVES, DEFAULT_CONFIG
from repro.core.tuning_space import TuningConfig
from repro.sparse.registry import default_format, format_names


def run(scale_name: str = "paper") -> dict:
    ds = get_dataset(scale_name)
    suite = [m for m in ds.matrices if not m.startswith("synth")]
    matrix = "eu-2005" if "eu-2005" in ds.matrices else suite[-1]
    recs = {r.config: r for r in ds.for_matrix(matrix) if r.feasible}
    default = ds.default_record(matrix)
    knob_axes = {**{k: v for k, v in KNOBS.items()}, "format": ("fmt", format_names())}
    rows, payload = [], {"matrix": matrix}
    for knob, (field, choices) in knob_axes.items():
        payload[knob] = {}
        row = [knob]
        for obj in OBJECTIVES:
            best = None
            for c in choices:
                if knob == "format":
                    cfg = TuningConfig(c, DEFAULT_CONFIG.schedule)
                else:
                    cfg = TuningConfig(
                        default_format(), DEFAULT_CONFIG.schedule.replace(**{field: c})
                    )
                r = recs.get(cfg)
                if r is None:
                    continue
                v = r.objective(obj)
                if best is None or (v < best if MINIMIZE[obj] else v > best):
                    best = v
            imp = improvement_pct(default.objective(obj), best, obj) if best else 0.0
            payload[knob][obj] = imp
            row.append(imp)
        rows.append(row)
    print_table(
        f"Fig.4 — per-knob improvement (%) on {matrix}",
        ["knob"] + list(OBJECTIVES),
        rows,
        fmt="8.1f",
    )
    save_result("fig4", payload)
    return payload


if __name__ == "__main__":
    run()
