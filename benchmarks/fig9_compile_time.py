"""Paper Fig. 9: compile-time-mode gains over the default configuration,
per matrix, per objective (CSR format fixed; schedule tuned).

Headline comparison: the paper reports up to 51.9 % latency, 52 % energy,
33.2 % power and 53 % efficiency improvement across its 30 matrices. The
"oracle" column is the best-in-space gain; "predicted" uses leave-one-out
trained classifiers (the honest deployment number)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import get_dataset, improvement_pct, print_table, save_result
from repro.core import OBJECTIVES, AutoSpmvPredictor, PredictorConfig, TuningConfig
from repro.core.dataset import TuningDataset
from repro.sparse import default_format


def _loo_predicted_gain(ds: TuningDataset, matrix: str, obj: str) -> float:
    train_recs = [r for r in ds.records if r.matrix != matrix]
    loo = TuningDataset(train_recs, ds.meta)
    pred = AutoSpmvPredictor(PredictorConfig(max_regressor_samples=600)).fit(loo)
    feats = ds.for_matrix(matrix)[0].features
    sched = pred.predict_schedule(feats, obj)
    cfg = TuningConfig(default_format(), sched)
    rec = next((r for r in ds.for_matrix(matrix) if r.config == cfg), None)
    default = ds.default_record(matrix)
    if rec is None or not rec.feasible:
        return 0.0
    return improvement_pct(default.objective(obj), rec.objective(obj), obj)


def run(scale_name: str = "paper", loo_subset: int = 6) -> dict:
    ds = get_dataset(scale_name)
    suite = [m for m in ds.matrices if not m.startswith("synth")]
    payload: dict = {"per_matrix": {}}
    rows = []
    for m in suite:
        default = ds.default_record(m)
        gains = {}
        for obj in OBJECTIVES:
            best = ds.best_record(m, obj, formats=(default_format(),))
            gains[obj] = improvement_pct(default.objective(obj), best.objective(obj), obj)
        payload["per_matrix"][m] = gains
        rows.append([m] + [gains[o] for o in OBJECTIVES])
    summary = {
        obj: {
            "max": float(max(p[obj] for p in payload["per_matrix"].values())),
            "mean": float(np.mean([p[obj] for p in payload["per_matrix"].values()])),
        }
        for obj in OBJECTIVES
    }
    payload["summary_oracle"] = summary
    print_table(
        "Fig.9 — compile-time-mode oracle gain (%) per matrix",
        ["matrix"] + list(OBJECTIVES),
        rows,
        fmt="8.1f",
    )
    print_table(
        "Fig.9 summary — oracle (paper: up to 51.9/52/33.2/53 %)",
        ["objective", "max %", "mean %"],
        [[o, summary[o]["max"], summary[o]["mean"]] for o in OBJECTIVES],
        fmt="8.1f",
    )
    # leave-one-out predicted gains on a subset (full LOO is 30x predictor fits)
    loo = {}
    for m in suite[:loo_subset]:
        loo[m] = {obj: _loo_predicted_gain(ds, m, obj) for obj in OBJECTIVES}
    payload["loo_predicted"] = loo
    print_table(
        f"Fig.9 — leave-one-out predicted gain (%) [{len(loo)} matrices]",
        ["matrix"] + list(OBJECTIVES),
        [[m] + [loo[m][o] for o in OBJECTIVES] for m in loo],
        fmt="8.1f",
    )
    save_result("fig9", payload)
    return payload


if __name__ == "__main__":
    run()
