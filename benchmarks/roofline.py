"""Roofline report (deliverable g): reads the dry-run artifacts and emits
the per-(arch x shape x mesh) table of compute/memory/collective terms,
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs utilization, and the roofline
fraction — written to artifacts/roofline.md and printed."""

from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import print_table, save_result
from repro.utils.logging import get_logger

log = get_logger("bench.roofline")

DRYRUN_DIR = Path("artifacts/dryrun")


def load_artifacts(dryrun_dir: Path = DRYRUN_DIR) -> list[dict]:
    arts = []
    for p in sorted(dryrun_dir.glob("*.json")):
        try:
            arts.append(json.loads(p.read_text()))
        except json.JSONDecodeError:
            continue
    return arts


def _one_sentence(art: dict) -> str:
    dom = art["roofline"]["dominant"]
    if dom == "memory":
        return "cut HBM traffic: larger fusions/remat policy, bf16 residuals"
    if dom == "collective":
        return "cut ICI bytes: reshard (fewer weight all-gathers), overlap collectives"
    return "raise MXU utilization: fuse small ops, larger per-device tiles"


def run(scale_name: str = "paper", dryrun_dir: Path = DRYRUN_DIR) -> dict:
    arts = load_artifacts(dryrun_dir)
    done = [a for a in arts if "roofline" in a and not a.get("tag")]
    skipped = [a for a in arts if "skipped" in a]
    rows, payload = [], {"cells": {}, "skipped": [f"{a['arch']}/{a['shape']}" for a in skipped]}
    for a in sorted(done, key=lambda x: (x["arch"], x["shape"], x["n_chips"])):
        r = a["roofline"]
        key = f"{a['arch']}|{a['shape']}|{a['n_chips']}"
        payload["cells"][key] = r
        rows.append([
            a["arch"], a["shape"], a["n_chips"],
            r["compute_s"], r["memory_s"], r["collective_s"],
            r["dominant"][:4],
            100 * r["useful_flops_ratio"],
            100 * r["roofline_fraction"],
            a.get("hbm_per_device_gb", 0.0),
        ])
    print_table(
        "Roofline — per (arch x shape x chips): term seconds, dominant, "
        "useful-FLOPs %, roofline %",
        ["arch", "shape", "chips", "compute_s", "memory_s", "coll_s", "dom",
         "useful%", "roofline%", "HBM GB/dev"],
        rows,
        fmt="9.3g",
    )
    if skipped:
        log.info("skipped cells (documented): %s", sorted(set(payload["skipped"])))
    tagged = [a for a in arts if "roofline" in a and a.get("tag")]
    if tagged:
        rows_t = []
        for a in sorted(tagged, key=lambda x: (x["arch"], x["shape"], x["tag"])):
            r = a["roofline"]
            rows_t.append([
                a["arch"], a["shape"], a["tag"], r["compute_s"], r["memory_s"],
                r["collective_s"], 100 * r["useful_flops_ratio"],
                100 * r["roofline_fraction"], a.get("hbm_per_device_gb", 0.0),
            ])
            payload["cells"][f"{a['arch']}|{a['shape']}|{a['n_chips']}|{a['tag']}"] = r
        print_table(
            "Perf-iteration cells (§Perf hillclimbs, tagged)",
            ["arch", "shape", "tag", "compute_s", "memory_s", "coll_s",
             "useful%", "roofline%", "HBM GB/dev"],
            rows_t,
            fmt="9.3g",
        )
    # markdown artifact
    md = ["| arch | shape | chips | compute s | memory s | collective s | dominant | useful % | roofline % | HBM GB/dev | next lever |",
          "|---|---|---|---|---|---|---|---|---|---|---|"]
    for a in sorted(done, key=lambda x: (x["arch"], x["shape"], x["n_chips"])):
        r = a["roofline"]
        md.append(
            f"| {a['arch']} | {a['shape']} | {a['n_chips']} | {r['compute_s']:.3g} "
            f"| {r['memory_s']:.3g} | {r['collective_s']:.3g} | {r['dominant']} "
            f"| {100*r['useful_flops_ratio']:.1f} | {100*r['roofline_fraction']:.2f} "
            f"| {a.get('hbm_per_device_gb', 0):.2f} | {_one_sentence(a)} |"
        )
    Path("artifacts").mkdir(exist_ok=True)
    Path("artifacts/roofline.md").write_text("\n".join(md) + "\n")
    save_result("roofline", payload)
    log.info(
        "%d cells analysed, %d documented skips; markdown -> "
        "artifacts/roofline.md",
        len(done),
        len(skipped),
    )
    return payload


if __name__ == "__main__":
    run()
