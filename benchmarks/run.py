"""Benchmark orchestrator: one module per paper table/figure + the roofline
report. ``python -m benchmarks.run [--scale ci|paper] [--only fig9,table5]``.

``--smoke`` is the sub-minute CI tier: only the benches tagged smoke-capable
(the session-cache, adaptive-telemetry, partition, and format-sweep ones,
which skip dataset-wide predictor sweeps) at the smallest scale.

Every run also writes a machine-readable ``BENCH_<label>.json`` next to the
other artifacts (``artifacts/bench/`` by default): one record per executed
benchmark with its name, scale, duration, and the numeric metrics flattened
out of the payload its ``run()`` returned. The label comes from ``--label``,
the ``BENCH_LABEL`` environment variable, or the current git short sha (CI
passes ``--label smoke``, so the artifact name is stable across PRs). CI
runs the smoke tier, uploads the artifact, and gates on
``benchmarks/compare.py`` against the committed baseline — the bench
trajectory is a queryable, regression-checked time series instead of log
text.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import time
import traceback

from repro.utils.logging import get_logger

log = get_logger("bench.run")

BENCHES = [
    ("fig3", "benchmarks.fig3_default_vs_auto", "Fig.3 default vs Auto-SpMV (consph)"),
    ("fig4", "benchmarks.fig4_ablation", "Fig.4 per-knob ablation (eu-2005)"),
    ("fig9", "benchmarks.fig9_compile_time", "Fig.9 compile-time-mode gains"),
    ("fig10", "benchmarks.fig10_runtime_format", "Fig.10 run-time format gains"),
    ("table5", "benchmarks.table5_classification", "Table 5 knob classifiers"),
    ("table6", "benchmarks.table6_comparison", "Table 6 vs prior-work proxies"),
    ("fig11", "benchmarks.fig11_regression", "Fig.11 objective regressors"),
    ("table7", "benchmarks.table7_overhead", "Table 7 + Fig.6 overheads"),
    ("session_cache", "benchmarks.bench_session_cache", "Session cache cold vs warm"),
    ("adaptive", "benchmarks.bench_adaptive", "Telemetry bandit misprediction recovery"),
    ("partition", "benchmarks.bench_partition", "Partitioned vs monolithic SpMV"),
    ("solvers", "benchmarks.bench_solvers", "Iterative solvers + adaptive SpMSpV"),
    ("sparse_lm", "benchmarks.bench_sparse_lm", "Sparse LM serving vs dense decode"),
    ("obs_overhead", "benchmarks.bench_obs_overhead",
     "Observability overhead + SLO escalation loop"),
    ("fig12", "benchmarks.fig12_sensitivity", "Fig.12 hardware sensitivity"),
    ("roofline", "benchmarks.roofline", "Roofline report (dry-run artifacts)"),
    # keep last: activates the bcsr plugin, which widens the registry for the
    # rest of the process
    ("formats", "benchmarks.bench_formats", "Registered-format sweep incl. bcsr plugin"),
]

SMOKE_BENCHES = (
    "session_cache", "adaptive", "partition", "solvers", "sparse_lm",
    "obs_overhead", "formats",
)

_MAX_METRICS = 400  # per bench: keep the artifact readable, not exhaustive


def default_label() -> str:
    """Artifact label when ``--label`` is omitted: env var, then git sha."""
    env = os.environ.get("BENCH_LABEL", "").strip()
    if env:
        return env
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
        ).stdout.strip()
        if sha:
            return sha
    except OSError:
        pass
    return "local"


def results_file(label: str) -> str:
    return f"BENCH_{label}.json"


def _numeric_metrics(payload, prefix: str = "", out: dict | None = None) -> dict:
    """Flatten a bench payload into "path/to/leaf" -> number entries.

    Non-numeric leaves are dropped; non-string keys (some benches key on
    tuples) are stringified. Bounded so a dataset-sized payload cannot bloat
    the artifact.
    """
    if out is None:
        out = {}
    if len(out) >= _MAX_METRICS:
        return out
    if isinstance(payload, bool):
        out[prefix] = int(payload)
    elif isinstance(payload, (int, float)) and not isinstance(payload, bool):
        out[prefix] = float(payload)
    elif isinstance(payload, dict):
        for k, v in payload.items():
            key = f"{prefix}/{k}" if prefix else str(k)
            _numeric_metrics(v, key, out)
    elif isinstance(payload, (list, tuple)):
        for i, v in enumerate(payload):
            _numeric_metrics(v, f"{prefix}/{i}" if prefix else str(i), out)
    return out


def write_results(
    records: list[dict], scale: str, total_s: float, label: str | None = None
) -> str:
    from benchmarks.common import ART

    label = label or default_label()
    ART.mkdir(parents=True, exist_ok=True)
    path = ART / results_file(label)
    path.write_text(
        json.dumps(
            {
                "label": label,
                "scale": scale,
                "total_s": total_s,
                "benchmarks": records,
            },
            indent=1,
            default=float,
        )
    )
    return str(path)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", choices=["smoke", "ci", "paper"], default="paper")
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    ap.add_argument("--smoke", action="store_true",
                    help="sub-minute tier: smoke benches at the smallest scale")
    ap.add_argument("--label", default=None,
                    help="results-artifact label: BENCH_<label>.json "
                         "(default: $BENCH_LABEL, then the git short sha)")
    args = ap.parse_args(argv)
    scale = "smoke" if args.smoke else args.scale
    if args.only:
        only = set(args.only.split(","))
    elif args.smoke:
        only = set(SMOKE_BENCHES)
    else:
        only = None

    failures, records = [], []
    t_all = time.time()
    for name, module, title in BENCHES:
        if only and name not in only:
            continue
        log.info("[%s] %s", name, title)
        t0 = time.time()
        record = {"name": name, "title": title, "scale": scale}
        try:
            import importlib

            mod = importlib.import_module(module)
            payload = mod.run(scale)
            record["ok"] = True
            record["metrics"] = _numeric_metrics(payload) if payload else {}
            log.info("[%s] done in %.1fs", name, time.time() - t0)
        except Exception:
            traceback.print_exc()
            failures.append(name)
            record["ok"] = False
            record["error"] = traceback.format_exc(limit=3)
        record["duration_s"] = time.time() - t0
        records.append(record)
    total_s = time.time() - t_all
    results_path = write_results(records, scale, total_s, args.label)
    log.info(
        "all benchmarks finished in %.1fs; results -> %s", total_s, results_path
    )
    if failures:
        log.error("FAILED: %s", failures)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
