"""Benchmark orchestrator: one module per paper table/figure + the roofline
report. ``python -m benchmarks.run [--scale ci|paper] [--only fig9,table5]``.

``--smoke`` is the sub-minute CI tier: only the benches tagged smoke-capable
(the session-cache and adaptive-telemetry ones, which skip dataset-wide
predictor sweeps) at the smallest scale.
"""

from __future__ import annotations

import argparse
import time
import traceback

BENCHES = [
    ("fig3", "benchmarks.fig3_default_vs_auto", "Fig.3 default vs Auto-SpMV (consph)"),
    ("fig4", "benchmarks.fig4_ablation", "Fig.4 per-knob ablation (eu-2005)"),
    ("fig9", "benchmarks.fig9_compile_time", "Fig.9 compile-time-mode gains"),
    ("fig10", "benchmarks.fig10_runtime_format", "Fig.10 run-time format gains"),
    ("table5", "benchmarks.table5_classification", "Table 5 knob classifiers"),
    ("table6", "benchmarks.table6_comparison", "Table 6 vs prior-work proxies"),
    ("fig11", "benchmarks.fig11_regression", "Fig.11 objective regressors"),
    ("table7", "benchmarks.table7_overhead", "Table 7 + Fig.6 overheads"),
    ("session_cache", "benchmarks.bench_session_cache", "Session cache cold vs warm"),
    ("adaptive", "benchmarks.bench_adaptive", "Telemetry bandit misprediction recovery"),
    ("fig12", "benchmarks.fig12_sensitivity", "Fig.12 hardware sensitivity"),
    ("roofline", "benchmarks.roofline", "Roofline report (dry-run artifacts)"),
    # keep last: activates the bcsr plugin, which widens the registry for the
    # rest of the process
    ("formats", "benchmarks.bench_formats", "Registered-format sweep incl. bcsr plugin"),
]

SMOKE_BENCHES = ("session_cache", "adaptive", "formats")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", choices=["smoke", "ci", "paper"], default="paper")
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    ap.add_argument("--smoke", action="store_true",
                    help="sub-minute tier: smoke benches at the smallest scale")
    args = ap.parse_args(argv)
    scale = "smoke" if args.smoke else args.scale
    if args.only:
        only = set(args.only.split(","))
    elif args.smoke:
        only = set(SMOKE_BENCHES)
    else:
        only = None

    failures = []
    t_all = time.time()
    for name, module, title in BENCHES:
        if only and name not in only:
            continue
        print(f"\n{'='*72}\n[{name}] {title}\n{'='*72}")
        t0 = time.time()
        try:
            import importlib

            mod = importlib.import_module(module)
            mod.run(scale)
            print(f"[{name}] done in {time.time()-t0:.1f}s")
        except Exception:
            traceback.print_exc()
            failures.append(name)
    print(f"\nall benchmarks finished in {time.time()-t_all:.1f}s")
    if failures:
        print(f"FAILED: {failures}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
