"""Paper Table 5: classification accuracy/F1 of the tuned decision tree for
each compile-time knob, per objective, on an 80/20 matrix split.

The paper reports 100 % accuracy for TB size / maxrregcount / memory on its
30-matrix suite (test split of 6 matrices). Our split has the same shape;
the knob vocabulary is the TPU analogue (DESIGN.md §2)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import get_dataset, print_table, save_result
from repro.core import ALL_KNOBS, KNOBS, OBJECTIVES
from repro.core.dataset import TuningDataset
from repro.core.hpo import tune_model
from repro.ml.metrics import accuracy_score, f1_score
from repro.ml.model_zoo import CLASSIFIER_ZOO
from repro.sparse import default_format


def _labels(ds: TuningDataset, matrices, obj, knob):
    field, _ = KNOBS[knob]
    X, y = [], []
    for m in matrices:
        X.append(ds.for_matrix(m)[0].features.log_vector())
        best = ds.best_record(m, obj, formats=(default_format(),)).config
        y.append(str(getattr(best.schedule, field)))
    return np.stack(X), np.array(y)


def run(scale_name: str = "paper", tune: bool = True, seed: int = 0) -> dict:
    ds = get_dataset(scale_name)
    matrices = ds.matrices
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(matrices))
    n_test = max(len(matrices) // 5, 1)
    test_m = [matrices[i] for i in order[:n_test]]
    train_m = [matrices[i] for i in order[n_test:]]

    entry = CLASSIFIER_ZOO["decision_tree"]
    payload, rows = {}, []
    for knob in ALL_KNOBS:
        row = [knob]
        payload[knob] = {}
        for obj in OBJECTIVES:
            Xtr, ytr = _labels(ds, train_m, obj, knob)
            Xte, yte = _labels(ds, test_m, obj, knob)
            kw = dict(entry["defaults"])
            if tune and len(np.unique(ytr)) > 1:
                res = tune_model(entry, Xtr, ytr, accuracy_score, n_trials=8, cv=3, seed=seed)
                kw.update(res.best_params)
            if len(np.unique(ytr)) == 1:
                pred = np.full(len(yte), ytr[0])
            else:
                clf = entry["ctor"](**kw)
                clf.fit(Xtr, ytr)
                pred = clf.predict(Xte)
            acc = 100 * accuracy_score(yte, pred)
            f1 = 100 * f1_score(yte, pred)
            payload[knob][obj] = {"acc": acc, "f1": f1}
            row.append(f"{acc:.0f}/{f1:.0f}")
        rows.append(row)
    print_table(
        "Table 5 — tuned decision-tree acc/F1 (%) per knob per objective "
        "(paper: 100 acc on TB/maxrreg/memory)",
        ["knob"] + list(OBJECTIVES),
        rows,
    )
    save_result("table5", payload)
    return payload


if __name__ == "__main__":
    run()
