"""Paper Table 6: Auto-SpMV vs state-of-the-art classifier baselines.

The prior works are unavailable; we compare against faithful *model-class*
proxies trained with default hyperparameters on the same features, exactly
the comparison the paper draws: BestSF ~ SVM, Dufrechou'21 ~ bagged trees
(random forest), Zhao'18 ~ neural classifier (MLP). Target: best format for
the latency objective (execution time column) and energy objective."""

from __future__ import annotations

import numpy as np

from benchmarks.common import get_dataset, print_table, save_result
from repro.core.hpo import tune_model
from repro.ml.metrics import accuracy_score
from repro.ml.model_zoo import CLASSIFIER_ZOO

BASELINES = [
    ("BestSF (SVM) [78]", "svm"),
    ("Dufrechou'21 (bagged trees) [74]", "random_forest"),
    ("Zhao'18 (neural) [32]", "mlp"),
    ("Auto-SpMV (tuned decision tree)", "decision_tree"),
]


def _format_labels(ds, matrices, obj):
    X = np.stack([ds.for_matrix(m)[0].features.log_vector() for m in matrices])
    y = np.array([ds.best_record(m, obj).config.fmt for m in matrices])
    return X, y


def _cv_accuracy(entry, X, y, tune, seed=0, folds=4):
    from repro.core.hpo import kfold_indices

    kw = dict(entry["defaults"])
    if entry["ctor"].__name__ == "MLPClassifier":
        kw.update(epochs=150, n_layers=3, hidden_layer_size=64)
    if tune:
        res = tune_model(entry, X, y, accuracy_score, n_trials=8, cv=3, seed=seed)
        kw.update(res.best_params)
    scores = []
    for tr, va in kfold_indices(len(y), folds, seed=seed):
        if len(np.unique(y[tr])) == 1:
            pred = np.full(len(va), y[tr][0])
        else:
            clf = entry["ctor"](**kw)
            clf.fit(X[tr], y[tr])
            pred = clf.predict(X[va])
        scores.append(accuracy_score(y[va], pred))
    return 100 * float(np.mean(scores))


def run(scale_name: str = "paper", seed: int = 0) -> dict:
    ds = get_dataset(scale_name)
    matrices = ds.matrices
    payload, rows = {}, []
    for label, model in BASELINES:
        tuned = model == "decision_tree"  # only ours gets the AutoML stage
        accs = {}
        for obj in ("latency", "energy"):
            X, y = _format_labels(ds, matrices, obj)
            accs[obj] = _cv_accuracy(CLASSIFIER_ZOO[model], X, y, tune=tuned, seed=seed)
        payload[label] = accs
        rows.append([label, accs["latency"], accs["energy"]])
    print_table(
        "Table 6 — format-selection accuracy (%), 4-fold CV "
        "(paper: 82/89/90 baselines vs 100/100 Auto-SpMV)",
        ["method", "acc (latency)", "acc (energy)"],
        rows,
        fmt="8.1f",
    )
    save_result("table6", payload)
    return payload


if __name__ == "__main__":
    run()
