"""Paper Table 7 + Fig. 6: run-time optimization overhead.

Measures the real host-side f_latency (feature extraction) and c_latency
(conversion to the latency-optimal format) per suite matrix, sorted by nnz
(Table 7), and scores the learned overhead estimators on a held-out split
(Fig. 6). Absolute times are smaller than the paper's (scaled matrices,
different host) — the protocol and the scaling trend are the artifact."""

from __future__ import annotations

import numpy as np

from benchmarks.common import SCALES, get_dataset, print_table, save_result
from repro.core import OverheadPredictor, measure_overheads
from repro.ml.metrics import r2_score
from repro.sparse.generate import generate_by_name


def run(scale_name: str = "paper") -> dict:
    ds = get_dataset(scale_name)
    scale = SCALES[scale_name]["scale"]
    suite = [m for m in ds.matrices if not m.startswith("synth")]
    samples = []
    for m in suite:
        dense = generate_by_name(m, scale=scale)
        samples.append(measure_overheads(dense, m))
    best_fmt = {m: ds.best_record(m, "latency").config.fmt for m in suite}
    rows = []
    payload = {"per_matrix": {}}
    order = sorted(samples, key=lambda s: s.features.nnz)
    for s in order:
        c = s.c_latency[best_fmt[s.matrix]]
        payload["per_matrix"][s.matrix] = {
            "nnz": s.features.nnz,
            "f_latency_s": s.f_latency,
            "c_latency_s": c,
            "total_s": s.f_latency + c,
        }
        rows.append([s.matrix, int(s.features.nnz), s.f_latency * 1e3, c * 1e3,
                     (s.f_latency + c) * 1e3])
    print_table(
        "Table 7 — measured overheads (ms), ascending nnz",
        ["matrix", "nnz", "f_latency", "c_latency", "f+c"],
        rows,
        fmt="9.2f",
    )
    # Fig. 6: estimator accuracy (held-out split over matrices)
    n_test = max(len(samples) // 4, 2)
    rng = np.random.default_rng(0)
    idx = rng.permutation(len(samples))
    test = [samples[i] for i in idx[:n_test]]
    train = [samples[i] for i in idx[n_test:]]
    op = OverheadPredictor().fit(train)
    f_true = [s.f_latency for s in test]
    f_pred = [op.predict_f(s.features) for s in test]
    c_true = [s.c_latency["ell"] for s in test]
    c_pred = [op.predict_c(s.features, "ell") for s in test]
    payload["fig6"] = {
        "f_r2": r2_score(f_true, f_pred),
        "c_r2_ell": r2_score(c_true, c_pred),
    }
    print_table(
        "Fig.6 — overhead-estimator accuracy (held-out)",
        ["estimator", "R^2"],
        [["f_latency", payload["fig6"]["f_r2"]], ["c_latency(ell)", payload["fig6"]["c_r2_ell"]]],
        fmt="8.3f",
    )
    save_result("table7", payload)
    return payload


if __name__ == "__main__":
    run()
