"""Run-time-mode demo over the full matrix suite (deliverable b):
per-objective format selection + conversion decisions, printed as the
paper's Fig. 5(b) pipeline would execute inside an iterative solver.

Tuning goes through ``AutoSpmvSession.optimize_many`` so the whole batch is
deduplicated and the decisions land in a cache (pass ``--cache`` to persist
them; a second run then starts warm and skips the predictor inferences).

  PYTHONPATH=src python examples/autotune_formats.py --objective efficiency
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.core import (
    AutoSpMV,
    AutoSpmvPredictor,
    AutoSpmvSession,
    OverheadPredictor,
    PredictorConfig,
    collect_dataset,
    measure_overheads,
)
from repro.sparse.generate import MATRIX_NAMES, generate_by_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--objective", default="efficiency",
                    choices=["latency", "energy", "power", "efficiency"])
    ap.add_argument("--scale", type=float, default=0.002)
    ap.add_argument("--iterations", type=int, default=2000)
    ap.add_argument("--n-matrices", type=int, default=12)
    ap.add_argument("--cache", default=None,
                    help="JSON path for the persistent tuning cache")
    args = ap.parse_args()

    names = MATRIX_NAMES[: args.n_matrices]
    ds = collect_dataset(scale=args.scale, names=names, n_extra=8)
    pred = AutoSpmvPredictor(PredictorConfig()).fit(ds)
    oh = OverheadPredictor().fit(
        [measure_overheads(generate_by_name(m, scale=args.scale), m) for m in names[:8]]
    )
    session = AutoSpmvSession(AutoSpMV(pred, oh), cache_path=args.cache)

    mats = [generate_by_name(m, scale=args.scale) for m in names]
    results = session.optimize_many(
        mats, args.objective, mode="run", n_iterations=args.iterations
    )
    print(f"{'matrix':22s} {'format':6s} {'convert':8s} {'gain/iter':>10s} {'overhead':>9s}")
    for m, rt in zip(names, results):
        print(f"{m:22s} {rt.best_format:6s} {str(rt.convert):8s} "
              f"{rt.predicted_gain_per_iter:10.3g} {rt.predicted_overhead*1e3:8.1f}ms")
    s = session.stats
    print(f"\nsession: {s.feature_extractions} feature passes, "
          f"{s.plans_computed} plans, {s.kernel_compiles} kernel compiles "
          f"for {s.requests} matrices")
    if args.cache:
        session.save()
        print(f"tuning cache saved to {args.cache}")


if __name__ == "__main__":
    main()
