"""Run-time-mode demo over the full matrix suite (deliverable b):
per-objective format selection + conversion decisions, printed as the
paper's Fig. 5(b) pipeline would execute inside an iterative solver.

  PYTHONPATH=src python examples/autotune_formats.py --objective efficiency
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.core import (
    AutoSpMV,
    AutoSpmvPredictor,
    OverheadPredictor,
    PredictorConfig,
    collect_dataset,
    measure_overheads,
)
from repro.sparse.generate import MATRIX_NAMES, generate_by_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--objective", default="efficiency",
                    choices=["latency", "energy", "power", "efficiency"])
    ap.add_argument("--scale", type=float, default=0.002)
    ap.add_argument("--iterations", type=int, default=2000)
    ap.add_argument("--n-matrices", type=int, default=12)
    args = ap.parse_args()

    names = MATRIX_NAMES[: args.n_matrices]
    ds = collect_dataset(scale=args.scale, names=names, n_extra=8)
    pred = AutoSpmvPredictor(PredictorConfig()).fit(ds)
    oh = OverheadPredictor().fit(
        [measure_overheads(generate_by_name(m, scale=args.scale), m) for m in names[:8]]
    )
    tuner = AutoSpMV(pred, oh)

    print(f"{'matrix':22s} {'format':6s} {'convert':8s} {'gain/iter':>10s} {'overhead':>9s}")
    for m in names:
        dense = generate_by_name(m, scale=args.scale)
        rt = tuner.run_time_optimize(dense, args.objective, n_iterations=args.iterations)
        print(f"{m:22s} {rt.best_format:6s} {str(rt.convert):8s} "
              f"{rt.predicted_gain_per_iter:10.3g} {rt.predicted_overhead*1e3:8.1f}ms")


if __name__ == "__main__":
    main()
