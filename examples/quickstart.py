"""Quickstart: Auto-SpMV end to end on one matrix.

  PYTHONPATH=src python examples/quickstart.py [--matrix consph] [--objective latency]

Flow (paper Fig. 5): build the tuning dataset -> train predictors ->
compile-time mode (predict the kernel schedule, specialize the Pallas CSR
kernel) -> run-time mode (predict the best format, check the conversion
overhead, convert) -> execute both kernels and verify against the dense
product.
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from repro.core import (
    AutoSpMV,
    AutoSpmvPredictor,
    OverheadPredictor,
    PredictorConfig,
    collect_dataset,
    measure_overheads,
)
from repro.sparse.generate import MATRIX_NAMES, generate_by_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--matrix", default="consph", choices=sorted(MATRIX_NAMES))
    ap.add_argument("--objective", default="latency",
                    choices=["latency", "energy", "power", "efficiency"])
    ap.add_argument("--scale", type=float, default=0.002)
    ap.add_argument("--iterations", type=int, default=5000,
                    help="solver iterations amortizing the conversion cost")
    args = ap.parse_args()

    print("[1/4] collecting tuning dataset (TPU cost model over the suite)...")
    t0 = time.time()
    ds = collect_dataset(scale=args.scale, names=MATRIX_NAMES[:16], n_extra=8)
    print(f"      {len(ds)} records in {time.time()-t0:.1f}s")

    print("[2/4] training predictors (decision tree, paper Table 5 winner)...")
    pred = AutoSpmvPredictor(PredictorConfig()).fit(ds)
    overhead = OverheadPredictor().fit(
        [measure_overheads(generate_by_name(m, scale=args.scale), m)
         for m in MATRIX_NAMES[:8]]
    )
    tuner = AutoSpMV(pred, overhead)

    dense = generate_by_name(args.matrix, scale=args.scale)
    x = np.random.default_rng(0).normal(size=dense.shape[1]).astype(np.float32)
    ref = dense @ x

    print(f"[3/4] compile-time mode ({args.objective}) on {args.matrix}...")
    ct = tuner.compile_time_optimize(dense, args.objective)
    y = np.asarray(ct.kernel(x))
    err = np.abs(y - ref).max() / (np.abs(ref).max() + 1e-9)
    print(f"      schedule: {ct.schedule}")
    print(f"      predicted objectives: "
          + ", ".join(f"{k}={v:.3g}" for k, v in ct.predicted.items()))
    print(f"      kernel correct: rel.err {err:.2e}")

    print(f"[4/4] run-time mode ({args.objective})...")
    rt = tuner.run_time_optimize(
        dense, args.objective, n_iterations=args.iterations
    )
    print(f"      best format: {rt.best_format}; convert: {rt.convert} "
          f"(gain/iter {rt.predicted_gain_per_iter:.3g}, "
          f"overhead {rt.predicted_overhead*1e3:.1f} ms)")
    if rt.kernel is not None:
        y2 = np.asarray(rt.kernel(x))
        err2 = np.abs(y2 - ref).max() / (np.abs(ref).max() + 1e-9)
        print(f"      converted kernel correct: rel.err {err2:.2e}")
    print("done.")


if __name__ == "__main__":
    main()
