"""Batched serving example (deliverable b): continuous-batching-lite over a
small model with KV/state caches.

  PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b \
      --requests 6 --slots 3
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import init_params, model_specs
from repro.train.serve import BatchedServer, Request, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=sorted(ARCH_IDS))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced_config=True)
    if cfg.prefix_len:
        cfg = cfg.replace(prefix_len=0, prefix_lm=False)  # text-only demo
    print(f"serving {cfg.name}: {cfg.param_counts()['total']/1e6:.1f}M params, "
          f"{args.slots} slots")
    params = init_params(model_specs(cfg), jax.random.PRNGKey(args.seed), cfg.param_dtype)
    server = BatchedServer(
        params, cfg,
        ServeConfig(batch_slots=args.slots, max_len=256,
                    max_new_tokens=args.max_new_tokens),
    )
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 20))).tolist(),
                max_new_tokens=args.max_new_tokens)
        for i in range(args.requests)
    ]
    t0 = time.time()
    done = server.run(reqs)
    dt = time.time() - t0
    total = sum(len(r.generated) for r in done)
    for r in done:
        print(f"  req {r.rid}: {len(r.prompt)}-token prompt -> {r.generated[:8]}...")
    print(f"{total} tokens in {dt:.1f}s ({total/dt:.1f} tok/s aggregate, "
          f"{args.slots}-way batched)")


if __name__ == "__main__":
    main()
