"""Batched serving example (deliverable b): continuous-batching-lite over a
small model with KV/state caches.

  PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-2b \
      --requests 6 --slots 3

``--sparse`` magnitude-prunes the FFN weights and serves their matmuls
through session-planned SpMV kernels (the Auto-SpMV sparse-serving path):
it first runs a one-step dense-vs-sparse numerics check on the same pruned
params, then serves the request stream with per-request SLO classes.

  PYTHONPATH=src python examples/serve_lm.py --sparse --requests 2 --slots 1
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import init_params, model_specs
from repro.train.serve import BatchedServer, Request, ServeConfig


def build_sparse_engine(cfg, params, density):
    """Cheap tuner + shared session + engine over the pruned FFN weights."""
    from repro.core.session import AutoSpmvSession, build_tuner
    from repro.models.sparse_linear import SparseInferenceEngine, prune_model_ffns
    from repro.sparse.generate import MATRIX_NAMES

    tuner = build_tuner(
        scale=0.0008, names=MATRIX_NAMES[:3], n_extra=0, fit_overhead=False
    )
    engine = SparseInferenceEngine(AutoSpmvSession(tuner))
    pruned = prune_model_ffns(params, cfg, engine, density=density)
    return engine, pruned


def check_numerics(cfg, params, engine):
    """One decode step, dense vs sparse-served, on the SAME pruned params:
    the SpMV route must reproduce the dense logits within fp32 tolerance."""
    from repro.models.model import decode_step, init_cache, prefill

    B, T = 1, 6
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (B, T)), jnp.int32
    )
    cache = init_cache(cfg, B, 64)
    logits, cache, _ = prefill(params, cfg, cache, tokens=tokens)
    nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    pos = jnp.full((B, 1), T, jnp.int32)
    ld, _ = decode_step(params, cfg, cache, nxt, pos)
    engine.plan_all("latency")
    ls, _ = decode_step(
        params, cfg, cache, nxt, pos,
        unroll_layers=True, engine=engine.bind("latency"),
    )
    err = float(jnp.max(jnp.abs(ld - ls)))
    print(f"dense-vs-sparse decode logits: max abs diff {err:.2e}")
    assert err < 5e-4, f"sparse-served logits diverged from dense: {err}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b", choices=sorted(ARCH_IDS))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--sparse", action="store_true",
                    help="serve FFN matmuls through planned SpMV kernels")
    ap.add_argument("--density", type=float, default=0.05,
                    help="with --sparse: kept-weight fraction per FFN matrix")
    ap.add_argument("--slo", default="mixed",
                    choices=["latency-critical", "power-capped", "balanced",
                             "energy-saving", "mixed"])
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced_config=True)
    if cfg.prefix_len:
        cfg = cfg.replace(prefix_len=0, prefix_lm=False)  # text-only demo
    if args.sparse and cfg.n_experts and cfg.dispatch_format != "dense":
        cfg = cfg.replace(dispatch_format="dense")  # engine needs dense dispatch
    print(f"serving {cfg.name}: {cfg.param_counts()['total']/1e6:.1f}M params, "
          f"{args.slots} slots")
    params = init_params(model_specs(cfg), jax.random.PRNGKey(args.seed), cfg.param_dtype)
    engine = None
    if args.sparse:
        engine, params = build_sparse_engine(cfg, params, args.density)
        print(f"sparse engine: {engine.stats.registered} FFN matrices pruned to "
              f"density {args.density} ({engine.stats.spmv_layers} SpMV-eligible)")
        check_numerics(cfg, params, engine)
    server = BatchedServer(
        params, cfg,
        ServeConfig(batch_slots=args.slots, max_len=256,
                    max_new_tokens=args.max_new_tokens),
        engine=engine,
    )
    rng = np.random.default_rng(args.seed)
    slos = ["latency-critical", "power-capped", "balanced", "energy-saving"]
    reqs = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 20))).tolist(),
                max_new_tokens=args.max_new_tokens,
                slo=slos[i % len(slos)] if args.slo == "mixed" else args.slo)
        for i in range(args.requests)
    ]
    t0 = time.time()
    done = server.run(reqs)
    dt = time.time() - t0
    total = sum(len(r.generated) for r in done)
    for r in done:
        print(f"  req {r.rid} [{r.slo}]: {len(r.prompt)}-token prompt -> "
              f"{r.generated[:8]}...")
    print(f"{total} tokens in {dt:.1f}s ({total/dt:.1f} tok/s aggregate, "
          f"{args.slots}-way batched)")
    if engine is not None:
        s = server.summary()
        print(f"slo classes: {s['slo_classes']}")
        print(f"engine plans: {s['engine']['stats']['plans']} "
              f"({s['session']['requests']} session plan requests)")
        print(f"energy cells: {sorted(s.get('energy', {}))}")


if __name__ == "__main__":
    main()
