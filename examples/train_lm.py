"""End-to-end training driver (deliverable b): train an LM for a few
hundred steps with checkpoint/resume, on this CPU host.

  PYTHONPATH=src python examples/train_lm.py --arch deepseek-moe-16b \
      --steps 200 --preset small

Presets scale the reduced config so CPU wall-time stays sane; the same
driver runs the full config on a real fleet (launch/train.py --full
--production-mesh). For MoE archs, the Auto-SpMV run-time mode selects the
dispatch format from the routing histogram after a calibration step.
"""

import argparse
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import DataConfig
from repro.models.moe import select_dispatch_format
from repro.optim import AdamWConfig, cosine_schedule
from repro.train import TrainConfig, Trainer, make_train_step
from repro.train.trainer import init_train_state

PRESETS = {
    # d_model, layers-multiplier, seq, batch  (~params of the tiny end-to-end run)
    "tiny": dict(d_model=64, seq=64, batch=4),
    "small": dict(d_model=128, seq=128, batch=8),
    "medium": dict(d_model=256, seq=256, batch=8),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-moe-16b", choices=sorted(ARCH_IDS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--preset", default="small", choices=sorted(PRESETS))
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm")
    ap.add_argument("--compress-frac", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    p = PRESETS[args.preset]

    cfg = get_config(args.arch, reduced_config=True)
    cfg = cfg.replace(
        d_model=p["d_model"],
        n_heads=max(2, p["d_model"] // 32),
        n_kv_heads=max(1, min(cfg.n_kv_heads, p["d_model"] // 32)),
        head_dim=32,
        d_ff=2 * p["d_model"] if cfg.d_ff else 0,
        d_ff_expert=p["d_model"] // 2 if cfg.d_ff_expert else 0,
        attn_chunk=64,
        vocab_size=2048 if cfg.vocab_size > 2048 else cfg.vocab_size,
    )
    print(f"training {cfg.name} preset={args.preset}: "
          f"{cfg.param_counts()['total']/1e6:.1f}M params, "
          f"{args.steps} steps, seq={p['seq']}, batch={p['batch']}")

    # Auto-SpMV run-time mode for MoE dispatch: run one calibration step with
    # the default (ell) format, read the routing histogram, pick the format.
    opt_cfg = AdamWConfig(
        learning_rate=cosine_schedule(args.lr, 20, args.steps),
        state_dtype=cfg.opt_state_dtype,
    )
    data_cfg = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=p["seq"], global_batch=p["batch"],
        seed=args.seed,
        embed_dim=cfg.d_model if (cfg.train_input == "embeds" or cfg.prefix_len) else 0,
        prefix_len=cfg.prefix_len,
    )
    if cfg.n_experts:
        from repro.train.trainer import make_loss_fn
        import jax.numpy as jnp

        params, _ = init_train_state(cfg, opt_cfg, seed=args.seed)
        from repro.data.pipeline import SyntheticLMDataset

        batch = SyntheticLMDataset(data_cfg).batch_at(0)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        _, aux = jax.jit(lambda p, b: make_loss_fn(cfg)(p, b))(params, batch)
        fmt = select_dispatch_format(aux["tokens_per_expert"])
        print(f"Auto-SpMV dispatch-format selection: routing histogram -> {fmt!r}")
        cfg = cfg.replace(dispatch_format=fmt)

    train_cfg = TrainConfig(
        steps=args.steps, log_every=20, ckpt_every=max(args.steps // 2, 50),
        ckpt_dir=args.ckpt_dir, compress_frac=args.compress_frac,
    )
    trainer = Trainer(cfg, data_cfg, opt_cfg, train_cfg)
    params, opt_state = init_train_state(
        cfg, opt_cfg, seed=args.seed, compress_frac=args.compress_frac
    )
    trainer.run(params, opt_state)
    losses = [h["loss"] for h in trainer.history]
    if losses:
        print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f} "
              f"({'improved' if losses[-1] < losses[0] else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
