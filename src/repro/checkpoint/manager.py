"""Checkpoint manager (orbax is not installed): atomic, keep-K, mesh-agnostic.

Layout: ``<dir>/step_<N>/arrays.npz`` + ``manifest.json``. Writes go to a
``tmp_`` directory first and are renamed atomically, so a preemption during
save never corrupts the latest checkpoint. Arrays are stored unsharded
(gathered to host), and ``restore`` re-shards onto whatever mesh/shardings
the caller passes — this is the elastic-rescale path: a checkpoint written
on a 16x16 mesh restores onto 2x16x16 or a single CPU equally (DESIGN.md §6).
bfloat16 leaves round-trip via a uint16 view (npz has no bf16 dtype).
"""

from __future__ import annotations

import json
import shutil
import time
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.logging import get_logger

log = get_logger("checkpoint")

_SEP = "//"


def _flatten_with_paths(tree: Any) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, extra: dict | None = None) -> Path:
        t0 = time.perf_counter()
        tmp = self.dir / f"tmp_{step}"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = _flatten_with_paths(tree)
        arrays, manifest = {}, {"step": step, "extra": extra or {}, "leaves": {}}
        for key, leaf in flat.items():
            arr = np.asarray(jax.device_get(leaf))
            dtype = str(arr.dtype)
            if arr.dtype == jnp.bfloat16:
                arr = arr.view(np.uint16)
                dtype = "bfloat16"
            arrays[key] = arr
            manifest["leaves"][key] = {"dtype": dtype, "shape": list(arr.shape)}
        np.savez(tmp / "arrays.npz", **arrays)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # atomic publish
        self._gc()
        log.info("saved step %d (%d leaves, %.2fs)", step, len(flat), time.perf_counter() - t0)
        return final

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        return sorted(
            int(p.name.split("_")[1]) for p in self.dir.glob("step_*") if p.is_dir()
        )

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        target_like: Any,
        step: int | None = None,
        shardings: Any = None,
    ) -> tuple[Any, dict]:
        """Restore into the structure of ``target_like``; if ``shardings``
        (same pytree structure, NamedSharding leaves) is given, place leaves
        accordingly — the mesh may differ from the one that saved."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = self.dir / f"step_{step:08d}"
        manifest = json.loads((path / "manifest.json").read_text())
        blob = np.load(path / "arrays.npz")
        flat_target = _flatten_with_paths(target_like)
        flat_shard = _flatten_with_paths(shardings) if shardings is not None else {}
        out = {}
        for key, like in flat_target.items():
            if key not in manifest["leaves"]:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = blob[key]
            if manifest["leaves"][key]["dtype"] == "bfloat16":
                arr = arr.view(jnp.bfloat16)
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(f"{key}: shape {arr.shape} != target {like.shape}")
            if key in flat_shard and flat_shard[key] is not None:
                out[key] = jax.device_put(arr, flat_shard[key])
            else:
                out[key] = jnp.asarray(arr)
        # rebuild original structure
        leaves_ordered = []
        for path_, _ in jax.tree_util.tree_flatten_with_path(target_like)[0]:
            key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_)
            leaves_ordered.append(out[key])
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(target_like), leaves_ordered
        )
        log.info("restored step %d from %s", step, path)
        return tree, manifest["extra"]
