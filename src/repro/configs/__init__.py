"""Config registry: ``--arch <id>`` resolution for every assigned
architecture (+ the paper's own SpMV matrix suite via repro.sparse.generate).
"""

from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig, reduced
from repro.configs.shapes import SHAPE_NAMES, SHAPES, WorkloadShape, applicable, cells_for

# arch id -> module name
_ARCH_MODULES = {
    "deepseek-moe-16b": "deepseek_moe_16b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "llama3-8b": "llama3_8b",
    "qwen3-0.6b": "qwen3_0_6b",
    "stablelm-12b": "stablelm_12b",
    "xlstm-1.3b": "xlstm_1_3b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "musicgen-large": "musicgen_large",
    "paligemma-3b": "paligemma_3b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(arch: str, *, reduced_config: bool = False) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {sorted(ARCH_IDS)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.REDUCED if reduced_config else mod.CONFIG


def all_configs(*, reduced_config: bool = False) -> dict[str, ModelConfig]:
    return {a: get_config(a, reduced_config=reduced_config) for a in ARCH_IDS}


__all__ = [
    "ModelConfig",
    "reduced",
    "WorkloadShape",
    "SHAPES",
    "SHAPE_NAMES",
    "applicable",
    "cells_for",
    "ARCH_IDS",
    "get_config",
    "all_configs",
]
