"""Architecture + workload-shape configuration system.

Every assigned architecture gets one module in this package defining
``CONFIG`` (the exact published configuration) and ``REDUCED`` (a
same-family shrink used by CPU smoke tests). Workload shapes (the assigned
input-shape set) live in ``shapes.py``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    # trunk
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # block layout: optional non-repeating prefix + repeating pattern unit.
    # block kinds: "attn" (global attn + mlp), "local" (windowed attn + mlp),
    # "moe" (attn + mixture FFN), "mlstm", "slstm", "rec" (RG-LRU block)
    pattern: tuple[str, ...] = ("attn",)
    first_blocks: tuple[str, ...] = ()
    # attention
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    window: int = 0  # local-attention window (block-local, DESIGN.md)
    attn_chunk: int = 512  # flash-chunk length (KV axis)
    prefix_lm: bool = False  # bidirectional attention over the prefix
    # FFN nonlinearity: swiglu (llama), geglu (gemma), gelu (2-matrix, musicgen)
    mlp_kind: str = "swiglu"
    # training input modality: "tokens" or "embeds" (stub frontends feed
    # precomputed frame/patch embeddings)
    train_input: str = "tokens"
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    dispatch_format: str = "ell"  # ell | sell | dense — the Auto-SpMV knob
    # recurrent families
    rnn_width: int = 0  # RG-LRU state width (0 -> d_model)
    conv1d_size: int = 4
    mlstm_chunk: int = 64  # chunkwise-parallel mLSTM chunk length
    # modality frontend stubs ([audio]/[vlm] backbones; DESIGN.md §5)
    prefix_len: int = 0  # stub prefix tokens (SigLIP patches / EnCodec frames)
    # numerics / memory
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    # recurrent/KV decode-state precision; bf16 halves the per-token state
    # traffic of state-bound decoders (xlstm) at a documented accuracy cost
    state_dtype: str = "float32"
    remat: bool = True
    logits_softcap: float = 0.0
    tie_embeddings: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_heads % max(self.n_kv_heads, 1):
            raise ValueError("n_heads must be divisible by n_kv_heads")
        n_rep = self.n_layers - len(self.first_blocks)
        if n_rep < 0:
            raise ValueError("first_blocks longer than n_layers")

    # ---- block layout helpers ------------------------------------------
    @property
    def n_pattern_layers(self) -> int:
        return self.n_layers - len(self.first_blocks)

    @property
    def n_groups(self) -> int:
        """Number of scanned repetitions of the full pattern unit."""
        return self.n_pattern_layers // len(self.pattern)

    @property
    def tail_blocks(self) -> tuple[str, ...]:
        r = self.n_pattern_layers % len(self.pattern)
        return self.pattern[:r]

    @property
    def block_kinds(self) -> tuple[str, ...]:
        return tuple(sorted(set(self.first_blocks) | set(self.pattern)))

    @property
    def rnn_dim(self) -> int:
        return self.rnn_width or self.d_model

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (roofline MODEL_FLOPS) ----------------------
    def param_counts(self) -> dict[str, float]:
        """Approximate parameter counts: total and active-per-token."""
        d, dh = self.d_model, self.head_dim
        attn = d * (self.n_heads * dh) + 2 * d * (self.n_kv_heads * dh) + (self.n_heads * dh) * d
        ffn_mats = 2 if self.mlp_kind == "gelu" else 3  # gated variants add one
        mlp = ffn_mats * d * self.d_ff
        moe_expert = 3 * d * self.d_ff_expert
        shared = self.n_shared_experts * moe_expert
        router = d * self.n_experts
        rec_w = self.rnn_dim
        rec = 2 * d * rec_w + rec_w * d + self.conv1d_size * rec_w + 2 * rec_w
        mlstm = 2 * d * 2 * d + 3 * (2 * d) * (2 * d) // 1  # up/down + qkv on 2d
        slstm = 4 * d * d
        per_block_total = {
            "attn": attn + mlp,
            "local": attn + mlp,
            "moe": attn + router + shared + self.n_experts * moe_expert,
            "rec": rec + mlp,
            "mlstm": mlstm,
            "slstm": slstm,
        }
        per_block_active = dict(per_block_total)
        per_block_active["moe"] = attn + router + shared + self.top_k * moe_expert
        blocks = list(self.first_blocks) + list(self.pattern) * self.n_groups + list(
            self.tail_blocks
        )
        total = sum(per_block_total[b] for b in blocks)
        active = sum(per_block_active[b] for b in blocks)
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return {
            "total": float(total + embed),
            "active": float(active + embed),
            "embed": float(embed),
        }


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Same-family shrink for CPU smoke tests: small width/depth, few
    experts, tiny vocab — structure preserved."""
    pat = len(cfg.pattern)
    kw = dict(
        n_layers=len(cfg.first_blocks) + max(pat, 2 if pat == 1 else pat),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        attn_chunk=64,
        window=min(cfg.window, 64) if cfg.window else 0,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        d_ff_expert=64 if cfg.d_ff_expert else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        rnn_width=64 if cfg.rnn_width else 0,
        mlstm_chunk=16,
        prefix_len=min(cfg.prefix_len, 8) if cfg.prefix_len else 0,
        param_dtype="float32",
        compute_dtype="float32",
        opt_state_dtype="float32",
        name=cfg.name + "-reduced",
    )
    kw.update(overrides)
    return cfg.replace(**kw)
