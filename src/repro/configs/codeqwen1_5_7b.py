"""codeqwen1.5-7b [hf:Qwen/CodeQwen1.5-7B]: 32L d=4096 32H MHA (kv=32)
d_ff=13440 vocab=92416."""

from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab_size=92_416,
    rope_theta=1_000_000.0,  # CodeQwen 64k context
    param_dtype="bfloat16",
)

REDUCED = reduced(CONFIG)
