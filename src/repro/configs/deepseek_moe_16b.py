"""deepseek-moe-16b [arXiv:2401.06066; hf]: 28L d=2048 16H (kv=16) vocab=102400,
fine-grained MoE: 64 routed experts (d_ff_expert=1408) top-6 + 2 shared;
layer 0 is a dense FFN (intermediate 10944, HF config)."""

from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,  # dense first layer (HF intermediate_size)
    d_ff_expert=1408,  # assignment-table d_ff: the fine-grained expert width
    vocab_size=102_400,
    first_blocks=("attn",),
    pattern=("moe",),
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    rope_theta=10_000.0,
    param_dtype="bfloat16",
    opt_state_dtype="bfloat16",
)

REDUCED = reduced(CONFIG)
