"""kimi-k2-1t-a32b [arXiv:2501.kimi2; unverified, paper-table]: 61L d=7168
64H (GQA kv=8, head_dim 128) vocab=163840, MoE 384 routed experts
(d_ff_expert=2048) top-8 + 1 shared; dense first layer. Trillion-parameter
class: bf16 params + bf16 optimizer state (DESIGN.md memory notes)."""

from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=18432,  # dense first layer
    d_ff_expert=2048,  # assignment-table d_ff: expert width
    vocab_size=163_840,
    first_blocks=("attn",),
    pattern=("moe",),
    n_experts=384,
    n_shared_experts=1,
    top_k=8,
    rope_theta=50_000.0,
    param_dtype="bfloat16",
    opt_state_dtype="bfloat16",
)

REDUCED = reduced(CONFIG)
