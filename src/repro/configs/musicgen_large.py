"""musicgen-large [arXiv:2306.05284; hf]: decoder-only backbone over EnCodec
tokens — 48L d=2048 32H MHA d_ff=8192 (plain GELU FFN) vocab=2048.
Modality frontend is a STUB: training consumes precomputed frame embeddings
(input_specs provides (B, S, d_model) floats); decode embeds the 2048-way
code tokens directly."""

from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    mlp_kind="gelu",
    train_input="embeds",
    param_dtype="bfloat16",
)

REDUCED = reduced(CONFIG, train_input="embeds")
