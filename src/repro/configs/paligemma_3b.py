"""paligemma-3b [arXiv:2407.07726; hf]: Gemma-2B text backbone — 18L d=2048
8H MQA (kv=1, head_dim 256) d_ff=16384 GeGLU vocab=257216 — behind a SigLIP
stub: input_specs provides 256 precomputed patch embeddings as a prefix with
bidirectional (prefix-LM) attention."""

from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257_216,
    mlp_kind="geglu",
    prefix_len=256,
    prefix_lm=True,
    tie_embeddings=True,
    param_dtype="bfloat16",
)

REDUCED = reduced(CONFIG, prefix_len=8, prefix_lm=True)
