"""recurrentgemma-2b [arXiv:2402.19427; hf]: 26L d=2560 10H MQA (kv=1),
d_ff=7680 (GeGLU), vocab=256000, RG-LRU width 2560, conv1d k=4, local
attention window 2048, layout (rec, rec, local) x8 + (rec, rec) tail.
Sub-quadratic: runs long_500k."""

from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    pattern=("rec", "rec", "local"),
    window=2048,
    rnn_width=2560,
    conv1d_size=4,
    mlp_kind="geglu",
    tie_embeddings=True,
)

REDUCED = reduced(CONFIG, n_layers=3, window=64)
