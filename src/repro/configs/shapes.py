"""Assigned workload shapes (arch x shape grid, 4 shapes per LM arch).

``train_*`` lowers ``train_step``; ``prefill_*`` lowers a full-sequence
``serve_prefill``; ``decode_*`` / ``long_*`` lower ``serve_step`` (one new
token against a KV cache/state of the stated length). ``long_500k`` requires
sub-quadratic sequence mixing and is only run for the SSM/hybrid archs
(DESIGN.md §5) — full-attention archs report the documented skip.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class WorkloadShape:
    name: str
    kind: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: dict[str, WorkloadShape] = {
    "train_4k": WorkloadShape("train_4k", "train", 4_096, 256),
    "prefill_32k": WorkloadShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": WorkloadShape("decode_32k", "decode", 32_768, 128),
    "long_500k": WorkloadShape("long_500k", "decode", 524_288, 1),
}

SHAPE_NAMES = tuple(SHAPES)

# families whose sequence mixing is sub-quadratic end-to-end
_SUBQUADRATIC_FAMILIES = {"ssm", "hybrid"}


def applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """(runs?, reason). The only skip rule: long_500k on pure full-attention
    archs (all ten assigned archs are decoder-only, so decode shapes apply
    everywhere else)."""
    if shape_name == "long_500k" and cfg.family not in _SUBQUADRATIC_FAMILIES:
        return False, (
            "long_500k skipped: pure full-attention arch (O(S) KV decode state "
            "at 500k exceeds the shape's intent; see DESIGN.md §5)"
        )
    return True, ""


def cells_for(cfg: ModelConfig) -> list[str]:
    return [s for s in SHAPE_NAMES if applicable(cfg, s)[0]]
