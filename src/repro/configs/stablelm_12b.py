"""stablelm-12b [hf:stabilityai/stablelm-2-12b]: 40L d=5120 32H GQA kv=8
d_ff=13824 vocab=100352."""

from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=100_352,
    param_dtype="bfloat16",
)

REDUCED = reduced(CONFIG)
