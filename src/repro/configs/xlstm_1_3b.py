"""xlstm-1.3b [arXiv:2405.04517]: 48 blocks d=2048, 4 heads, no separate FFN
(d_ff=0); xLSTM[7:1] layout — pattern unit of 7 mLSTM + 1 sLSTM blocks,
6 scanned groups. Sub-quadratic: runs long_500k."""

from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    pattern=("mlstm",) * 7 + ("slstm",),
    mlstm_chunk=64,
)

REDUCED = reduced(CONFIG, pattern=("mlstm", "slstm"), n_layers=2)
