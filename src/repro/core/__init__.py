"""The paper's primary contribution: the Auto-SpMV tuning framework.

features -> dataset -> predictors -> {compile-time, run-time} optimization,
plus the objective models (TPU cost model + measured CPU) and the AutoML
(HPO) stage.
"""

from repro.core.autotuner import (
    AutoSpMV,
    CompileTimePlan,
    CompileTimeResult,
    RunTimePlan,
    RunTimeResult,
    should_convert,
)
from repro.core.cache import CacheEntry, TuningCache, feature_bucket
from repro.core.dataset import TuningDataset, TuningRecord, collect_dataset
from repro.core.features import (
    FEATURE_NAMES,
    SparsityFeatures,
    extract_features,
    features_from_assignment_histogram,
    features_from_csr_indptr,
)
from repro.core.objectives import (
    HARDWARE,
    MINIMIZE,
    OBJECTIVES,
    MatrixStats,
    ObjectiveValues,
    TpuCostModel,
    TPU_V4,
    TPU_V5E,
    footprint,
    measure_cpu_formats,
)
from repro.core.overhead import OverheadPredictor, OverheadSample, measure_overheads
from repro.core.predictor import AutoSpmvPredictor, PredictorConfig
from repro.core.session import (
    AutoSpmvSession,
    PartitionedResult,
    ServedPlan,
    SessionStats,
    build_tuner,
)
from repro.core.tuning_space import (
    ALL_KNOBS,
    KNOBS,
    PAPER_KNOBS,
    TuningConfig,
    compile_time_space,
    full_space,
    knob_value,
    schedule_space,
)


def __getattr__(name):
    if name == "DEFAULT_CONFIG":
        # forwarded per access — see tuning_space.__getattr__
        from repro.core import tuning_space

        return tuning_space.DEFAULT_CONFIG
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "AutoSpMV",
    "AutoSpmvSession",
    "CacheEntry",
    "CompileTimePlan",
    "CompileTimeResult",
    "PartitionedResult",
    "RunTimePlan",
    "RunTimeResult",
    "ServedPlan",
    "SessionStats",
    "TuningCache",
    "build_tuner",
    "feature_bucket",
    "should_convert",
    "TuningDataset",
    "TuningRecord",
    "collect_dataset",
    "FEATURE_NAMES",
    "SparsityFeatures",
    "extract_features",
    "features_from_assignment_histogram",
    "features_from_csr_indptr",
    "HARDWARE",
    "MINIMIZE",
    "OBJECTIVES",
    "MatrixStats",
    "ObjectiveValues",
    "TpuCostModel",
    "TPU_V4",
    "TPU_V5E",
    "footprint",
    "measure_cpu_formats",
    "OverheadPredictor",
    "OverheadSample",
    "measure_overheads",
    "AutoSpmvPredictor",
    "PredictorConfig",
    "ALL_KNOBS",
    "DEFAULT_CONFIG",
    "KNOBS",
    "PAPER_KNOBS",
    "TuningConfig",
    "compile_time_space",
    "full_space",
    "knob_value",
    "schedule_space",
]
