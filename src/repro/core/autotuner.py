"""The Auto-SpMV optimizer: compile-time + run-time modes (paper §5, Fig. 5).

Compile-time mode (format fixed to CSR, §5.2):
  1. compute the sparsity features;
  2. predict the optimal kernel schedule (TPU compile-time parameters);
  3. convert to CSR and specialize the Pallas kernel with that schedule.

Run-time mode (§5.3):
  1. compute the sparsity features;
  2. predict the optimal sparse format for the target objective;
  3. estimate the optimization overhead (feature extraction + conversion +
     2 model inferences);
  4. convert only if the predicted gain over the remaining iterations
     exceeds the predicted overhead.

The feature->decision stage is factored out as ``plan_compile_time`` /
``plan_run_time`` so the session layer (core/session.py) can cache plans by
feature bucket and re-apply them without re-running the predictors.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.features import SparsityFeatures, extract_features
from repro.core.overhead import OverheadPredictor
from repro.core.predictor import AutoSpmvPredictor
from repro.core.tuning_space import TuningConfig
from repro.kernels.common import DEFAULT_SCHEDULE, KernelSchedule
from repro.kernels.ops import PreparedSpmv, compile_spmv
from repro.sparse.registry import default_format
from repro.utils.logging import get_logger

log = get_logger("core.autotuner")

PREDICTED_OBJECTIVES = ("latency", "energy", "power", "efficiency")


@dataclass(frozen=True)
class CompileTimePlan:
    """The pure decision of compile-time mode: schedule + objective estimates.

    Matrix-independent given the sparsity features — this is what the
    session's ``TuningCache`` persists per feature bucket.
    """

    schedule: KernelSchedule
    predicted: dict[str, float]  # estimated objective values


@dataclass(frozen=True)
class RunTimePlan:
    """The pure decision of run-time mode, before the conversion gate."""

    best_format: str
    gain_per_iter: float  # objective units per kernel invocation
    latency_gain_per_iter: float  # seconds per invocation (the gating unit)
    overhead_s: float  # predicted f + c + o + p
    convert_overhead_s: float = 0.0  # the c term alone (re-charged by the
    # session when the prepared kernel is not actually memoized)


def should_convert(
    plan: RunTimePlan,
    n_iterations: int,
    current_format: str,
    overhead_s: float | None = None,
) -> bool:
    """Paper §5.3 conversion gate. ``overhead_s`` overrides the plan's
    predicted overhead — the session passes 0.0 on a cache hit because the
    f + c + o + p cost was already paid when the plan was first computed."""
    oh = plan.overhead_s if overhead_s is None else overhead_s
    return (
        plan.best_format != current_format
        and plan.gain_per_iter > 0
        and plan.latency_gain_per_iter * n_iterations > oh
    )


@dataclass(frozen=True)
class CompileTimeResult:
    features: SparsityFeatures
    schedule: KernelSchedule
    kernel: PreparedSpmv  # CSR kernel specialized with the predicted schedule
    predicted: dict[str, float]  # estimated objective values


@dataclass(frozen=True)
class RunTimeResult:
    features: SparsityFeatures
    best_format: str
    convert: bool  # decision after the overhead check
    predicted_gain_per_iter: float  # objective units per kernel invocation
    predicted_overhead: float  # seconds (f + c + o + p)
    kernel: PreparedSpmv | None  # converted kernel when convert=True


@dataclass
class AutoSpMV:
    predictor: AutoSpmvPredictor
    overhead: OverheadPredictor | None = None
    interpret: bool = True
    dataset: object | None = None  # the §5.4 TuningDataset the predictor was
    # fit on, when the builder kept it — telemetry refits merge its labels so
    # a handful of fleet measurements never erase offline coverage

    # ------------------------------------------------------------- planning
    def plan_compile_time(
        self, feats: SparsityFeatures, objective: str = "latency"
    ) -> CompileTimePlan:
        schedule = self.predictor.predict_schedule(feats, objective)
        predicted = {
            obj: self.predictor.estimate_objective(
                feats, TuningConfig(default_format(), schedule), obj
            )
            for obj in PREDICTED_OBJECTIVES
        }
        return CompileTimePlan(schedule, predicted)

    def plan_run_time(
        self,
        feats: SparsityFeatures,
        objective: str = "latency",
        *,
        current_format: str | None = None,
        schedule: KernelSchedule = DEFAULT_SCHEDULE,
    ) -> RunTimePlan:
        current_format = current_format or default_format()
        best_fmt = self.predictor.predict_format(feats, objective)
        cur = self.predictor.estimate_objective(
            feats, TuningConfig(current_format, schedule), objective
        )
        new = self.predictor.estimate_objective(
            feats, TuningConfig(best_fmt, schedule), objective
        )
        # gain per kernel invocation, in the objective's native unit
        gain = (cur - new) if objective != "efficiency" else (new - cur)
        if self.overhead is not None:
            oh = self.overhead.total_overhead(feats, best_fmt)
            c_term = self.overhead.predict_c(feats, best_fmt)
        else:
            oh = c_term = 0.0
        # the decision rule compares time-like quantities; for non-latency
        # objectives the paper still gates on wall-clock overhead vs the
        # latency gain of the chosen config (§5.3) — reproduce that:
        lat_cur = self.predictor.estimate_objective(
            feats, TuningConfig(current_format, schedule), "latency"
        )
        lat_new = self.predictor.estimate_objective(
            feats, TuningConfig(best_fmt, schedule), "latency"
        )
        return RunTimePlan(best_fmt, gain, lat_cur - lat_new, oh, c_term)

    def plan_partitioned(
        self,
        dense: np.ndarray,
        objective: str = "latency",
        *,
        block_counts: tuple[int, ...] | None = None,
        cost_model=None,
    ):
        """Partitioned run-time mode: split the matrix into nnz-balanced row
        blocks, run the format/schedule predictors per block, and search
        block counts {1, 2, 4, 8} — the monolithic plan stays the baseline
        and wins ties, so homogeneous matrices keep one block. Returns a
        ``repro.partition.plan.CompositePlan``.

        Unlike ``plan_compile_time``/``plan_run_time`` this takes the dense
        matrix, not just features: block boundaries and per-block stats need
        the actual row histogram. The import is lazy — ``repro.partition``
        sits above ``repro.core`` in the layering.
        """
        from repro.partition.partitioner import SUPPORTED_BLOCK_COUNTS
        from repro.partition.plan import plan_partitioned

        counts = (
            tuple(block_counts) if block_counts is not None else SUPPORTED_BLOCK_COUNTS
        )
        return plan_partitioned(
            self.predictor, dense, objective, block_counts=counts,
            cost_model=cost_model,
        )

    # ------------------------------------------------------------ compile time
    def compile_time_optimize(
        self, dense: np.ndarray, objective: str = "latency"
    ) -> CompileTimeResult:
        feats = extract_features(dense)
        plan = self.plan_compile_time(feats, objective)
        kernel = compile_spmv(
            dense, default_format(), plan.schedule, interpret=self.interpret
        )
        log.info("compile-time: %s -> %s", objective, plan.schedule)
        return CompileTimeResult(feats, plan.schedule, kernel, plan.predicted)

    # ---------------------------------------------------------------- run time
    def run_time_optimize(
        self,
        dense: np.ndarray,
        objective: str = "latency",
        *,
        n_iterations: int = 1000,
        current_format: str | None = None,
        schedule: KernelSchedule = DEFAULT_SCHEDULE,
    ) -> RunTimeResult:
        current_format = current_format or default_format()
        feats = extract_features(dense)
        plan = self.plan_run_time(
            feats, objective, current_format=current_format, schedule=schedule
        )
        convert = should_convert(plan, n_iterations, current_format)
        kernel = (
            compile_spmv(dense, plan.best_format, schedule, interpret=self.interpret)
            if convert
            else None
        )
        log.info(
            "run-time: obj=%s fmt %s->%s gain/iter=%.3g overhead=%.3gs convert=%s",
            objective,
            current_format,
            plan.best_format,
            plan.gain_per_iter,
            plan.overhead_s,
            convert,
        )
        return RunTimeResult(
            feats,
            plan.best_format,
            convert,
            plan.gain_per_iter,
            plan.overhead_s,
            kernel,
        )
