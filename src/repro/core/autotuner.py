"""The Auto-SpMV optimizer: compile-time + run-time modes (paper §5, Fig. 5).

Compile-time mode (format fixed to CSR, §5.2):
  1. compute the sparsity features;
  2. predict the optimal kernel schedule (TPU compile-time parameters);
  3. convert to CSR and specialize the Pallas kernel with that schedule.

Run-time mode (§5.3):
  1. compute the sparsity features;
  2. predict the optimal sparse format for the target objective;
  3. estimate the optimization overhead (feature extraction + conversion +
     2 model inferences);
  4. convert only if the predicted gain over the remaining iterations
     exceeds the predicted overhead.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.features import SparsityFeatures, extract_features
from repro.core.overhead import OverheadPredictor
from repro.core.predictor import AutoSpmvPredictor
from repro.core.tuning_space import DEFAULT_CONFIG, TuningConfig
from repro.kernels.common import DEFAULT_SCHEDULE, KernelSchedule
from repro.kernels.ops import PreparedSpmv, compile_spmv
from repro.utils.logging import get_logger

log = get_logger("core.autotuner")


@dataclass(frozen=True)
class CompileTimeResult:
    features: SparsityFeatures
    schedule: KernelSchedule
    kernel: PreparedSpmv  # CSR kernel specialized with the predicted schedule
    predicted: dict[str, float]  # estimated objective values


@dataclass(frozen=True)
class RunTimeResult:
    features: SparsityFeatures
    best_format: str
    convert: bool  # decision after the overhead check
    predicted_gain_per_iter: float  # objective units per kernel invocation
    predicted_overhead: float  # seconds (f + c + o + p)
    kernel: PreparedSpmv | None  # converted kernel when convert=True


@dataclass
class AutoSpMV:
    predictor: AutoSpmvPredictor
    overhead: OverheadPredictor | None = None
    interpret: bool = True

    # ------------------------------------------------------------ compile time
    def compile_time_optimize(
        self, dense: np.ndarray, objective: str = "latency"
    ) -> CompileTimeResult:
        feats = extract_features(dense)
        schedule = self.predictor.predict_schedule(feats, objective)
        kernel = compile_spmv(dense, "csr", schedule, interpret=self.interpret)
        predicted = {
            obj: self.predictor.estimate_objective(
                feats, TuningConfig("csr", schedule), obj
            )
            for obj in ("latency", "energy", "power", "efficiency")
        }
        log.info("compile-time: %s -> %s", objective, schedule)
        return CompileTimeResult(feats, schedule, kernel, predicted)

    # ---------------------------------------------------------------- run time
    def run_time_optimize(
        self,
        dense: np.ndarray,
        objective: str = "latency",
        *,
        n_iterations: int = 1000,
        current_format: str = "csr",
        schedule: KernelSchedule = DEFAULT_SCHEDULE,
    ) -> RunTimeResult:
        feats = extract_features(dense)
        best_fmt = self.predictor.predict_format(feats, objective)
        cur = self.predictor.estimate_objective(
            feats, TuningConfig(current_format, schedule), objective
        )
        new = self.predictor.estimate_objective(
            feats, TuningConfig(best_fmt, schedule), objective
        )
        # gain per kernel invocation, in the objective's native unit
        gain = (cur - new) if objective != "efficiency" else (new - cur)
        if self.overhead is not None:
            oh = self.overhead.total_overhead(feats, best_fmt)
        else:
            oh = 0.0
        # the decision rule compares time-like quantities; for non-latency
        # objectives the paper still gates on wall-clock overhead vs the
        # latency gain of the chosen config (§5.3) — reproduce that:
        lat_cur = self.predictor.estimate_objective(
            feats, TuningConfig(current_format, schedule), "latency"
        )
        lat_new = self.predictor.estimate_objective(
            feats, TuningConfig(best_fmt, schedule), "latency"
        )
        benefit_s = (lat_cur - lat_new) * n_iterations
        convert = best_fmt != current_format and gain > 0 and benefit_s > oh
        kernel = (
            compile_spmv(dense, best_fmt, schedule, interpret=self.interpret)
            if convert
            else None
        )
        log.info(
            "run-time: obj=%s fmt %s->%s gain/iter=%.3g overhead=%.3gs convert=%s",
            objective,
            current_format,
            best_fmt,
            gain,
            oh,
            convert,
        )
        return RunTimeResult(feats, best_fmt, convert, gain, oh, kernel)
