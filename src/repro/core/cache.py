"""Persistent feature-bucketed tuning cache (the session substrate).

Auto-SpMV's economics (paper §5.3) only work if the tuning decision is paid
once and amortized: the cache maps a quantized sparsity-feature *bucket* to
the plan the predictors produced for it — the kernel schedule (compile-time
mode) or the chosen format + gain/overhead estimates (run-time mode).
Matrices whose Table-2 feature vectors round to the same bucket share one
plan; since the predictors themselves only see (log-scaled) features, equal
buckets get near-identical predictions anyway, so the cache trades an
epsilon of decision resolution for skipping both model inferences entirely.

Entries are plain JSON: ``save``/``load`` round-trips survive process
restarts, so a serving fleet warms its schedule decisions from disk. The
prepared Pallas kernels themselves are process-local (device buffers) and
live in the ``kernels.ops`` keyed memo, not here.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from pathlib import Path

import numpy as np

from repro.core.features import SparsityFeatures
from repro.kernels.common import KernelSchedule
from repro.obs.metrics import get_metrics
from repro.utils.io import atomic_write_text
from repro.utils.logging import get_logger

log = get_logger("core.cache")

# process-wide plan-cache counters: instrument handles are module-cached so
# the hot path pays one enabled-check + one add, nothing else
_HITS = get_metrics().counter("spmv_cache_hits_total")
_MISSES = get_metrics().counter("spmv_cache_misses_total")
_INVALIDATED = get_metrics().counter("spmv_cache_invalidations_total")

CACHE_FORMAT_VERSION = 1

# Bucket width in log1p feature units. 0.5 ≈ a 1.65x multiplicative band per
# feature: coarse enough that re-generated instances of the same matrix
# family collapse, fine enough that Fig.-7-dissimilar matrices stay apart.
DEFAULT_BUCKET_RESOLUTION = 0.5


def feature_bucket(
    feats: SparsityFeatures, resolution: float = DEFAULT_BUCKET_RESOLUTION
) -> str:
    """Quantize the log-feature vector into a stable string key."""
    q = np.floor(feats.log_vector() / resolution + 0.5).astype(np.int64)
    return "b" + "_".join(str(int(v)) for v in q)


@dataclass
class CacheEntry:
    """One cached tuning decision for a (bucket, objective, mode) key.

    ``mode`` is ``"compile"``, ``"run:<current_format>"`` — run-time plans
    depend on the format currently held, so it is part of the identity — or
    ``"part:max<k>"`` for partitioned composite plans (the block-count
    budget is part of the key, so sessions with different ``--max-blocks``
    never alias).
    """

    bucket: str
    objective: str
    mode: str
    fmt: str  # chosen format ("csr" in compile mode; "+".joined per-block
    # formats for partitioned entries)
    schedule: dict  # KernelSchedule.as_dict()
    predicted: dict[str, float] = field(default_factory=dict)
    gain_per_iter: float = 0.0
    latency_gain_per_iter: float = 0.0
    overhead_s: float = 0.0  # full predicted f + c + o + p at plan time
    convert_overhead_s: float = 0.0  # the c term alone: re-charged on hits
    # whose prepared kernel is not in the process memo (fresh process /
    # different matrix in the same bucket)
    hits: int = 0
    # partitioned composite plans (repro.partition): chosen block count and
    # the per-block decisions ({"fmt", "schedule", "latency"} dicts, in row
    # order). Bucket-mates replay these onto their own nnz-balanced row
    # boundaries — the *decisions* are bucket-level, the boundaries are not.
    n_blocks: int = 1
    blocks: list = field(default_factory=list)
    monolithic_fmt: str = ""  # the single-format baseline the plan beat

    def kernel_schedule(self) -> KernelSchedule:
        return KernelSchedule(**self.schedule)


class TuningCache:
    """In-memory map of tuning decisions with JSON persistence."""

    def __init__(self, resolution: float = DEFAULT_BUCKET_RESOLUTION):
        self.resolution = float(resolution)
        self._entries: dict[tuple[str, str, str], CacheEntry] = {}
        self.hits = 0
        self.misses = 0

    # ------------------------------------------------------------------ keys
    def bucket_of(self, feats: SparsityFeatures) -> str:
        return feature_bucket(feats, self.resolution)

    @staticmethod
    def _key(bucket: str, objective: str, mode: str) -> tuple[str, str, str]:
        return (bucket, objective, mode)

    # ---------------------------------------------------------------- access
    def get(self, bucket: str, objective: str, mode: str) -> CacheEntry | None:
        entry = self._entries.get(self._key(bucket, objective, mode))
        if entry is None:
            self.misses += 1
            _MISSES.inc()
            return None
        self.hits += 1
        _HITS.inc()
        entry.hits += 1
        return entry

    def put(self, entry: CacheEntry) -> CacheEntry:
        self._entries[self._key(entry.bucket, entry.objective, entry.mode)] = entry
        return entry

    def peek(self, bucket: str, objective: str, mode: str) -> CacheEntry | None:
        """get() without touching hit/miss accounting (for introspection)."""
        return self._entries.get(self._key(bucket, objective, mode))

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: tuple[str, str, str]) -> bool:
        return key in self._entries

    def entries(self) -> list[CacheEntry]:
        return list(self._entries.values())

    def invalidate(
        self,
        bucket: str,
        objective: str | None = None,
        mode: str | None = None,
    ) -> int:
        """Drop entries matching ``bucket`` (and, if given, objective/mode).

        The telemetry layer's drift detector calls this when measured
        behavior contradicts a cached plan: the stale decision is evicted so
        the next request re-plans against the (refit) predictors. Returns
        the number of entries removed.
        """
        doomed = [
            k
            for k in self._entries
            if k[0] == bucket
            and (objective is None or k[1] == objective)
            and (mode is None or k[2] == mode)
        ]
        for k in doomed:
            del self._entries[k]
        if doomed:
            _INVALIDATED.inc(len(doomed))
            log.info(
                "invalidated %d plan(s) for bucket=%s objective=%s mode=%s",
                len(doomed),
                bucket,
                objective or "*",
                mode or "*",
            )
        return len(doomed)

    def clear(self) -> None:
        self._entries.clear()
        self.hits = self.misses = 0

    def stats(self) -> dict[str, int]:
        return {"entries": len(self._entries), "hits": self.hits, "misses": self.misses}

    # ----------------------------------------------------------- persistence
    def save(self, path: str | Path) -> Path:
        path = Path(path)
        payload = {
            "version": CACHE_FORMAT_VERSION,
            "resolution": self.resolution,
            "entries": [asdict(e) for e in self._entries.values()],
        }
        # temp file + os.replace: an interrupted save must not corrupt the
        # cache a restarting fleet would otherwise warm from
        atomic_write_text(path, json.dumps(payload, indent=1))
        log.info("saved %d cache entries to %s", len(self._entries), path)
        return path

    @classmethod
    def load(cls, path: str | Path) -> "TuningCache":
        payload = json.loads(Path(path).read_text())
        if payload.get("version") != CACHE_FORMAT_VERSION:
            raise ValueError(
                f"cache version {payload.get('version')!r} != {CACHE_FORMAT_VERSION}"
            )
        cache = cls(resolution=payload["resolution"])
        for raw in payload["entries"]:
            cache.put(CacheEntry(**raw))
        log.info("loaded %d cache entries from %s", len(cache), path)
        return cache
