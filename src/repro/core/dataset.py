"""Dataset collection harness (paper §5.4 step 2, §6.1).

Builds the labelled tuning dataset: every (matrix x configuration) cell gets
the four objective values. The paper collected 15,520 records over 30
matrices on two GPUs (~70 M kernel runs); here each record is an analytical
TPU cost-model evaluation on exact storage statistics plus (optionally)
measured CPU wall-times of the per-format reference kernels. ``scale``
shrinks matrices for laptop-scale collection while preserving the feature
spread (generate.py).
"""

from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Sequence

import numpy as np

from repro.core.features import SparsityFeatures, extract_features
from repro.core.objectives import (
    MINIMIZE,
    OBJECTIVES,
    HardwareProfile,
    MatrixStats,
    TpuCostModel,
    TPU_V5E,
    measure_cpu_formats,
)
from repro.core.tuning_space import TuningConfig, full_space
from repro.sparse.generate import MATRIX_NAMES, PATTERN_NAMES, generate_by_name, random_matrix
from repro.utils.io import atomic_write_text
from repro.utils.logging import get_logger

log = get_logger("core.dataset")


@dataclass
class TuningRecord:
    matrix: str
    features: SparsityFeatures
    config: TuningConfig
    latency: float
    energy: float
    power: float
    efficiency: float
    feasible: bool
    source: str  # "model_<hw>" or "measured_cpu"

    def objective(self, name: str) -> float:
        return getattr(self, name)


@dataclass
class TuningDataset:
    records: list[TuningRecord] = field(default_factory=list)
    meta: dict = field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.records)

    @property
    def matrices(self) -> list[str]:
        seen: dict[str, None] = {}
        for r in self.records:
            seen.setdefault(r.matrix, None)
        return list(seen)

    def for_matrix(self, name: str) -> list[TuningRecord]:
        return [r for r in self.records if r.matrix == name]

    def feasible(self) -> list[TuningRecord]:
        return [r for r in self.records if r.feasible]

    # --- label construction ------------------------------------------------
    def best_record(
        self, matrix: str, objective: str, *, formats: Sequence[str] | None = None
    ) -> TuningRecord:
        cands = [
            r
            for r in self.for_matrix(matrix)
            if r.feasible and (formats is None or r.config.fmt in formats)
        ]
        if not cands:
            raise ValueError(f"no feasible record for {matrix}")
        key = lambda r: r.objective(objective)
        return min(cands, key=key) if MINIMIZE[objective] else max(cands, key=key)

    def default_record(self, matrix: str) -> TuningRecord:
        from repro.core.tuning_space import DEFAULT_CONFIG

        for r in self.for_matrix(matrix):
            if r.config == DEFAULT_CONFIG:
                return r
        raise ValueError(f"default config missing for {matrix}")

    # --- serialization -------------------------------------------------------
    def save(self, path: str | Path) -> None:
        path = Path(path)
        rows = []
        for r in self.records:
            row = {
                "matrix": r.matrix,
                "features": r.features.dict(),
                "config": r.config.as_dict(),
                "latency": r.latency,
                "energy": r.energy,
                "power": r.power,
                "efficiency": r.efficiency,
                "feasible": r.feasible,
                "source": r.source,
            }
            rows.append(row)
        atomic_write_text(path, json.dumps({"meta": self.meta, "records": rows}))

    @classmethod
    def load(cls, path: str | Path) -> "TuningDataset":
        from repro.kernels.common import KernelSchedule

        blob = json.loads(Path(path).read_text())
        records = []
        for row in blob["records"]:
            cfg = dict(row["config"])
            fmt = cfg.pop("fmt")
            records.append(
                TuningRecord(
                    matrix=row["matrix"],
                    features=SparsityFeatures(**row["features"]),
                    config=TuningConfig(fmt, KernelSchedule(**cfg)),
                    latency=row["latency"],
                    energy=row["energy"],
                    power=row["power"],
                    efficiency=row["efficiency"],
                    feasible=row["feasible"],
                    source=row["source"],
                )
            )
        return cls(records, blob.get("meta", {}))


def _suite_matrices(scale: float, names: Sequence[str]) -> dict[str, np.ndarray]:
    return {name: generate_by_name(name, scale=scale) for name in names}


def _extra_matrices(n_extra: int, seed: int = 100) -> dict[str, np.ndarray]:
    """Augmentation matrices: patterns x sizes x seeds (robustness; the
    paper's 30 unique feature vectors alone make thin training data)."""
    rng = np.random.default_rng(seed)
    out = {}
    for i in range(n_extra):
        pattern = PATTERN_NAMES[i % len(PATTERN_NAMES)]
        n = int(rng.integers(256, 3000))
        avg = float(rng.uniform(2, min(48, n / 8)))
        out[f"synth_{pattern}_{i}"] = random_matrix(n, avg, pattern, seed=int(rng.integers(1e9)))
    return out


def collect_dataset(
    *,
    scale: float = 0.002,
    names: Sequence[str] = MATRIX_NAMES,
    n_extra: int = 0,
    hw: HardwareProfile = TPU_V5E,
    space: Sequence[TuningConfig] | None = None,
    measure_cpu: bool = False,
    cpu_reps: int = 3,
) -> TuningDataset:
    """Evaluate every (matrix x config) cell; returns the labelled dataset."""
    space = list(space) if space is not None else list(full_space())
    matrices = _suite_matrices(scale, names)
    matrices.update(_extra_matrices(n_extra))
    model = TpuCostModel(hw)
    ds = TuningDataset(
        meta={
            "scale": scale,
            "hw": hw.name,
            "n_configs": len(space),
            "n_matrices": len(matrices),
            "collected_unix": time.time(),
        }
    )
    t0 = time.time()
    for mi, (name, dense) in enumerate(matrices.items()):
        feats = extract_features(dense)
        stats = MatrixStats(dense)
        for cfg in space:
            vals = model.evaluate(stats, cfg.fmt, cfg.schedule)
            ds.records.append(
                TuningRecord(
                    matrix=name,
                    features=feats,
                    config=cfg,
                    latency=vals.latency,
                    energy=vals.energy,
                    power=vals.power,
                    efficiency=vals.efficiency,
                    feasible=vals.feasible,
                    source=f"model_{hw.name}",
                )
            )
        if measure_cpu:
            times = measure_cpu_formats(dense, reps=cpu_reps)
            for fmt, t in times.items():
                from repro.kernels.common import DEFAULT_SCHEDULE

                # measured records carry the default schedule (the schedule
                # knobs do not exist for the jnp reference implementations)
                ds.records.append(
                    TuningRecord(
                        matrix=name,
                        features=feats,
                        config=TuningConfig(fmt, DEFAULT_SCHEDULE),
                        latency=t,
                        energy=float("nan"),
                        power=float("nan"),
                        efficiency=float("nan"),
                        feasible=True,
                        source="measured_cpu",
                    )
                )
        if (mi + 1) % 10 == 0:
            log.info("collected %d/%d matrices (%.1fs)", mi + 1, len(matrices), time.time() - t0)
    log.info(
        "dataset: %d records (%d matrices x %d configs) in %.1fs",
        len(ds),
        len(matrices),
        len(space),
        time.time() - t0,
    )
    return ds
