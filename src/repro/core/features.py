"""Sparsity-feature extraction (paper Table 2).

Eight features computed from the row-nonzero histogram of the input matrix:
``n, nnz, avg_nnz, var_nnz, ell_ratio, median, mode, std_nnz``. Selected by
the paper for (1) minimal run-time extraction cost and (2) reported
performance impact. Extraction is a host/CPU numpy computation — the paper
measures it as ``f_latency`` (Table 7), so this module is deliberately
side-effect-free and timeable.
"""

from __future__ import annotations

from dataclasses import dataclass, asdict, fields

import numpy as np

FEATURE_NAMES = (
    "n",
    "nnz",
    "avg_nnz",
    "var_nnz",
    "ell_ratio",
    "median",
    "mode",
    "std_nnz",
)


@dataclass(frozen=True)
class SparsityFeatures:
    n: float  # number of rows
    nnz: float  # number of nonzeros
    avg_nnz: float  # mean nonzeros per row
    var_nnz: float  # variance of nonzeros per row
    ell_ratio: float  # nnz / (n * max_nnz)  — ELL storage efficiency
    median: float  # median nonzeros per row
    mode: float  # most frequent nonzeros-per-row value
    std_nnz: float  # standard deviation of nonzeros per row

    def vector(self) -> np.ndarray:
        return np.array([getattr(self, f.name) for f in fields(self)], dtype=np.float64)

    def dict(self) -> dict[str, float]:
        return asdict(self)

    def log_vector(self) -> np.ndarray:
        """log1p-scaled vector — the learning-pipeline input representation.

        n and nnz span 5 decades across the suite (Fig. 7); log scaling keeps
        distance-based models (nearest centroid, RBF SVM) meaningful.
        """
        return np.log1p(np.maximum(self.vector(), 0.0))


def features_from_row_counts(counts: np.ndarray, n_rows: int) -> SparsityFeatures:
    """Compute Table-2 features from the nonzeros-per-row histogram."""
    counts = np.asarray(counts, dtype=np.int64)
    nnz = int(counts.sum())
    max_nnz = int(counts.max(initial=0))
    vals, freq = np.unique(counts, return_counts=True)
    mode = float(vals[np.argmax(freq)]) if vals.size else 0.0
    var = float(counts.var()) if counts.size else 0.0
    return SparsityFeatures(
        n=float(n_rows),
        nnz=float(nnz),
        avg_nnz=float(counts.mean()) if counts.size else 0.0,
        var_nnz=var,
        ell_ratio=float(nnz / (n_rows * max_nnz)) if max_nnz else 0.0,
        median=float(np.median(counts)) if counts.size else 0.0,
        mode=mode,
        std_nnz=float(np.sqrt(var)),
    )


def row_nnz_counts(dense: np.ndarray) -> np.ndarray:
    """Nonzeros per row of a dense-held matrix (int64, length ``n_rows``).

    The shared primitive under ``extract_features`` and the row partitioner
    (``repro.partition``): both need the same histogram, and the partitioner
    derives every per-block feature vector from slices of this one array, so
    the Table-7 ``f`` cost is paid once per matrix, not once per block.
    """
    return (np.asarray(dense) != 0).sum(axis=1).astype(np.int64)


def extract_features(dense: np.ndarray) -> SparsityFeatures:
    """Table-2 features of a dense-held matrix (run-time mode step 1)."""
    dense = np.asarray(dense)
    return features_from_row_counts(row_nnz_counts(dense), dense.shape[0])


def features_from_csr_indptr(indptr: np.ndarray) -> SparsityFeatures:
    """Features straight from CSR row pointers (no densification)."""
    indptr = np.asarray(indptr, dtype=np.int64)
    counts = np.diff(indptr)
    return features_from_row_counts(counts, counts.size)


def features_from_assignment_histogram(tokens_per_expert: np.ndarray) -> SparsityFeatures:
    """Features of an MoE token->expert assignment viewed as a sparse matrix.

    Rows = experts, nnz per row = tokens routed to that expert. This is the
    bridge that lets the paper's run-time mode select the MoE dispatch
    strategy (DESIGN.md §3): the routing histogram *is* the nnz-per-row
    histogram of the dispatch matrix.
    """
    t = np.asarray(tokens_per_expert, dtype=np.int64)
    return features_from_row_counts(t, t.size)
