"""Hyperparameter optimization — the AutoML stage (paper §5.4).

The paper uses Optuna with Bayesian (TPE) search. Optuna is unavailable
offline, so this module implements the same semantics from scratch:

* ``random_search`` — uniform sampling over the discrete space;
* ``tpe_search`` — Tree-structured Parzen Estimator over discrete choices:
  after a random warmup, candidates are scored by the ratio l(x)/g(x) of
  smoothed categorical densities fit to the best gamma-quantile trials (l)
  vs the rest (g), and the best-EI candidate is evaluated next. This is the
  standard TPE algorithm restricted to categorical dimensions — which is
  exactly the paper's Table 1 space (all choices are discrete).

``tune_model`` wires either search to a (model-zoo entry, dataset,
metric) triple with k-fold cross-validation on the training split.
"""

from __future__ import annotations

import itertools
import math
from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

SearchSpace = dict[str, list[Any]]
Objective = Callable[[dict[str, Any]], float]  # larger is better


@dataclass
class Trial:
    params: dict[str, Any]
    value: float


@dataclass
class StudyResult:
    best_params: dict[str, Any]
    best_value: float
    trials: list[Trial] = field(default_factory=list)

    @property
    def n_trials(self) -> int:
        return len(self.trials)


def _space_size(space: SearchSpace) -> int:
    return int(np.prod([len(v) for v in space.values()])) if space else 1


def _sample(space: SearchSpace, rng) -> dict[str, Any]:
    return {k: v[rng.integers(0, len(v))] for k, v in space.items()}


def grid_iter(space: SearchSpace):
    keys = list(space)
    for combo in itertools.product(*(space[k] for k in keys)):
        yield dict(zip(keys, combo))


def random_search(objective: Objective, space: SearchSpace, n_trials: int = 20,
                  seed: int = 0) -> StudyResult:
    rng = np.random.default_rng(seed)
    trials: list[Trial] = []
    seen: set[tuple] = set()
    budget = min(n_trials, _space_size(space))
    while len(trials) < budget:
        params = _sample(space, rng)
        key = tuple(sorted((k, str(v)) for k, v in params.items()))
        if key in seen and len(seen) < _space_size(space):
            continue
        seen.add(key)
        trials.append(Trial(params, float(objective(params))))
    best = max(trials, key=lambda t: t.value)
    return StudyResult(best.params, best.value, trials)


def tpe_search(objective: Objective, space: SearchSpace, n_trials: int = 30,
               n_warmup: int = 8, gamma: float = 0.25, n_candidates: int = 24,
               seed: int = 0) -> StudyResult:
    """Categorical TPE (Bergstra et al. 2011), maximizing ``objective``."""
    rng = np.random.default_rng(seed)
    trials: list[Trial] = []
    budget = min(n_trials, _space_size(space))

    def density(values: list[Any], choices: list[Any]) -> np.ndarray:
        # Laplace-smoothed categorical density over `choices`
        counts = np.ones(len(choices))  # prior
        index = {str(c): i for i, c in enumerate(choices)}
        for v in values:
            counts[index[str(v)]] += 1.0
        return counts / counts.sum()

    while len(trials) < budget:
        if len(trials) < n_warmup:
            params = _sample(space, rng)
        else:
            order = sorted(trials, key=lambda t: -t.value)
            n_good = max(1, int(math.ceil(gamma * len(order))))
            good, bad = order[:n_good], order[n_good:] or order[n_good - 1 :]
            # per-dimension densities
            l_d = {k: density([t.params[k] for t in good], space[k]) for k in space}
            g_d = {k: density([t.params[k] for t in bad], space[k]) for k in space}
            best_params, best_score = None, -np.inf
            for _ in range(n_candidates):
                cand = {}
                for k, choices in space.items():
                    cand[k] = choices[rng.choice(len(choices), p=l_d[k])]
                score = sum(
                    math.log(l_d[k][[str(c) for c in space[k]].index(str(cand[k]))])
                    - math.log(g_d[k][[str(c) for c in space[k]].index(str(cand[k]))])
                    for k in space
                )
                if score > best_score:
                    best_params, best_score = cand, score
            params = best_params
        trials.append(Trial(params, float(objective(params))))
    best = max(trials, key=lambda t: t.value)
    return StudyResult(best.params, best.value, trials)


# ---------------------------------------------------------------------------
# model-zoo integration
# ---------------------------------------------------------------------------


def kfold_indices(n: int, k: int, seed: int = 0):
    idx = np.random.default_rng(seed).permutation(n)
    folds = np.array_split(idx, k)
    for i in range(k):
        val = folds[i]
        train = np.concatenate([folds[j] for j in range(k) if j != i])
        yield train, val


def tune_model(
    zoo_entry: dict,
    X: np.ndarray,
    y: np.ndarray,
    metric: Callable[[np.ndarray, np.ndarray], float],
    *,
    n_trials: int = 20,
    cv: int = 3,
    method: str = "tpe",
    seed: int = 0,
) -> StudyResult:
    """Cross-validated HPO of one zoo model; returns the study result.

    ``metric(y_true, y_pred) -> float`` (larger better). The tuned params
    are merged over the zoo defaults, mirroring how Optuna-tuned values
    override scikit-learn defaults in the paper (§6.4).
    """
    X, y = np.asarray(X), np.asarray(y)
    n = X.shape[0]
    cv = max(2, min(cv, n))

    def objective(params: dict[str, Any]) -> float:
        kw = dict(zoo_entry["defaults"])
        kw.update(params)
        scores = []
        for tr, va in kfold_indices(n, cv, seed=seed):
            model = zoo_entry["ctor"](**kw)
            model.fit(X[tr], y[tr])
            scores.append(metric(y[va], model.predict(X[va])))
        return float(np.mean(scores))

    search = tpe_search if method == "tpe" else random_search
    return search(objective, zoo_entry["space"], n_trials=n_trials, seed=seed)
