"""Objective models: latency, energy, average power, energy efficiency.

The paper measures these four objectives with NVML power sensors on two GPUs
(§6.3). This container has neither GPU nor TPU, so objectives come from two
clearly-separated sources (DESIGN.md §2):

* ``measure_cpu_formats`` — *real* wall-time measurements of the jnp
  reference SpMV per format on the host CPU (the paper's repetition-and-
  average protocol). Used for the run-time (format-selection) labels.
* ``TpuCostModel`` — an analytical TPU v5e model evaluated on exact storage
  statistics. It models the resource trade-offs each schedule knob controls
  (grid-step overhead vs tile size, gather/scatter throughput, MXU vs VPU
  rates, VMEM feasibility, unroll ILP vs register-spill, accumulation
  precision) and produces all four objectives. Constants are documented
  estimates: the model's *orderings* (which config is best) drive the
  tuner, not its absolute numbers.

Energy accounting follows the paper's measurement protocol (§6.3): idle
power is EXCLUDED — E = FLOPs*e_flop + HBM_bytes*e_hbm + VMEM_touch*e_vmem +
grid_steps*e_step (dynamic only); avg power = E/t; efficiency = useful
MFLOP/s per watt, with *useful* = 2*nnz (padding compute costs energy but
adds no useful FLOPs — exactly why ELL loses efficiency on power-law
matrices, paper Fig. 10). ``p_static`` remains in the profile for TCO-style
studies but does not enter the four paper objectives.
"""

from __future__ import annotations

import json
import math
from dataclasses import dataclass
from pathlib import Path

import numpy as np

from repro.kernels.common import VMEM_BYTES, KernelSchedule
from repro.sparse.registry import (  # noqa: F401  (canonical home moved to the
    KernelFootprint,  # format registry; re-exported for backward compatibility)
    MatrixStats,
    get_format,
    format_names,
)

OBJECTIVES = ("latency", "energy", "power", "efficiency")
# for argmin-style selection: efficiency is maximized, the rest minimized
MINIMIZE = {"latency": True, "energy": True, "power": True, "efficiency": False}


@dataclass(frozen=True)
class HardwareProfile:
    name: str
    mxu_flops_bf16: float  # peak MXU FLOP/s, bf16 accumulate
    mxu_flops_f32: float
    vpu_flops_bf16: float  # vector-unit FLOP/s
    vpu_flops_f32: float
    hbm_bw: float  # bytes/s
    gather_rate: float  # in-kernel dynamic-gather elements/s
    scatter_rate: float  # in-kernel scatter-add elements/s
    grid_step_ns: float  # fixed per-grid-step cost
    vmem_bytes: int
    e_flop_bf16: float  # J/FLOP
    e_flop_f32: float
    e_hbm_byte: float  # J/byte
    e_vmem_byte: float
    e_grid_step: float  # J per grid step (control/DMA-descriptor energy;
    # what makes tiny-tile schedules power-hungry — the occupancy analogue)
    p_static: float  # W
    p_max: float  # W (package cap)


# TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM (assignment constants); the rest
# are engineering estimates with sources noted inline.
TPU_V5E = HardwareProfile(
    name="tpu_v5e",
    mxu_flops_bf16=197e12,
    mxu_flops_f32=197e12 / 8,  # fp32 via MXU passes
    vpu_flops_bf16=8e12,  # 8x128 VPU, ~940 MHz, FMA
    vpu_flops_f32=4e12,
    hbm_bw=819e9,
    gather_rate=7.5e9,  # ~8 lanes/cycle dynamic gather
    scatter_rate=1.9e9,  # serialized read-modify-write
    grid_step_ns=150.0,
    vmem_bytes=VMEM_BYTES,
    e_flop_bf16=0.5e-12,
    e_flop_f32=1.0e-12,
    e_hbm_byte=50e-12,  # ~6 pJ/bit HBM2e access
    e_vmem_byte=5e-12,
    e_grid_step=12e-9,
    p_static=70.0,
    p_max=220.0,
)

# TPU v4 for the hardware-sensitivity study (paper Fig. 12: Turing->Pascal);
# 275 TFLOP/s bf16, 1.2 TB/s HBM2.
TPU_V4 = HardwareProfile(
    name="tpu_v4",
    mxu_flops_bf16=275e12,
    mxu_flops_f32=275e12 / 8,
    vpu_flops_bf16=9e12,
    vpu_flops_f32=4.5e12,
    hbm_bw=1228e9,
    gather_rate=8.5e9,
    scatter_rate=2.1e9,
    grid_step_ns=180.0,
    vmem_bytes=VMEM_BYTES,
    e_flop_bf16=0.7e-12,
    e_flop_f32=1.4e-12,
    e_hbm_byte=55e-12,
    e_vmem_byte=6e-12,
    e_grid_step=15e-9,
    p_static=90.0,
    p_max=280.0,
)

HARDWARE = {"tpu_v5e": TPU_V5E, "tpu_v4": TPU_V4}


def footprint(
    stats: MatrixStats, fmt: str, schedule: KernelSchedule
) -> KernelFootprint:
    """Exact storage/work statistics for the cost model (no materialization).

    The per-format footprint models live on each registered ``FormatSpec``
    (``repro.sparse.registry``); this is the string-keyed entrypoint the
    cost model and benchmarks use."""
    return get_format(fmt).footprint(stats, schedule)


@dataclass(frozen=True)
class ObjectiveValues:
    latency: float  # seconds
    energy: float  # joules
    power: float  # watts (average)
    efficiency: float  # useful MFLOPS / watt
    feasible: bool = True

    def as_dict(self) -> dict[str, float]:
        return {
            "latency": self.latency,
            "energy": self.energy,
            "power": self.power,
            "efficiency": self.efficiency,
        }

    def get(self, objective: str) -> float:
        return self.as_dict()[objective]


INFEASIBLE = ObjectiveValues(math.inf, math.inf, math.inf, 0.0, feasible=False)


class TpuCostModel:
    def __init__(self, hw: HardwareProfile = TPU_V5E):
        self.hw = hw

    def evaluate(
        self, stats: MatrixStats, fmt: str, schedule: KernelSchedule
    ) -> ObjectiveValues:
        hw = self.hw
        fp = footprint(stats, fmt, schedule)
        if not fp.feasible:
            return INFEASIBLE
        bf16 = schedule.accum_dtype == "bfloat16"

        # --- compute time ------------------------------------------------
        mxu_rate = hw.mxu_flops_bf16 if bf16 else hw.mxu_flops_f32
        vpu_rate = hw.vpu_flops_bf16 if bf16 else hw.vpu_flops_f32
        # matvec keeps only ~1/16 of the MXU busy (one operand is a vector)
        mxu_eff_rate = mxu_rate / 16.0
        # unroll buys gather ILP until the VREG budget spills; bf16 packs
        # two elements per gather lane
        ilp = 1.0 + 0.18 * math.log2(schedule.unroll)
        live_regs = schedule.unroll * schedule.rows_per_block
        spill = 1.35 if live_regs > 2048 else 1.0
        g_rate = hw.gather_rate * ilp * (1.5 if bf16 else 1.0) / spill
        t_mxu = fp.mxu_fraction * fp.total_flops / mxu_eff_rate
        vpu_flops = (1.0 - fp.mxu_fraction) * fp.total_flops
        t_vpu = vpu_flops / vpu_rate
        t_gather = fp.gather_elems / g_rate
        t_scatter = fp.scatter_elems / (hw.scatter_rate * ilp / spill)
        t_compute = t_mxu + max(t_vpu, t_gather) + t_scatter

        # --- memory time ---------------------------------------------------
        t_mem = fp.hbm_bytes / hw.hbm_bw

        # --- grid overhead (occupancy analogue) ----------------------------
        # double-buffering hides overhead only when tiles are big enough
        pipeline_eff = min(1.0, fp.vmem_resident_bytes / (hw.vmem_bytes * 0.05) + 0.5)
        t_grid = fp.grid_steps * hw.grid_step_ns * 1e-9 / pipeline_eff

        latency = max(t_compute, t_mem) + t_grid

        # --- energy --------------------------------------------------------
        e_flop = hw.e_flop_bf16 if bf16 else hw.e_flop_f32
        elem_bytes = 2.0 if bf16 else 4.0
        vmem_touch = fp.total_flops * elem_bytes  # operand bytes touched in VMEM
        dyn = (
            fp.total_flops * e_flop
            + fp.hbm_bytes * hw.e_hbm_byte
            + vmem_touch * hw.e_vmem_byte
            + (fp.gather_elems + 3 * fp.scatter_elems) * 4.0 * hw.e_vmem_byte
            + fp.grid_steps * hw.e_grid_step
        )
        # idle power excluded, per the paper's §6.3 protocol
        energy = dyn
        power = min(energy / latency, hw.p_max - hw.p_static)
        mflops = fp.useful_flops / latency / 1e6
        return ObjectiveValues(latency, energy, power, mflops / power)


# ---------------------------------------------------------------------------
# measurement-calibrated cost model
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FormatCalibration:
    """Per-format affine correction: measured ≈ overhead + scale * modeled.

    The intercept is a real per-launch fixed cost (trace/dispatch/DMA setup —
    the term the analytical model omits and the reason it scores k launches
    as free); the slope absorbs systematic bytes/s / nnz/s misestimates.
    ``mean_rel_err`` is a fit diagnostic on the samples used, not a bound.
    """

    launch_overhead_s: float = 0.0
    latency_scale: float = 1.0
    samples: int = 0
    mean_rel_err: float = math.nan

    def as_dict(self) -> dict:
        return {
            "launch_overhead_s": self.launch_overhead_s,
            "latency_scale": self.latency_scale,
            "samples": self.samples,
            "mean_rel_err": self.mean_rel_err,
        }


class CalibratedCostModel(TpuCostModel):
    """``TpuCostModel`` with per-format affine corrections fit to telemetry.

    The analytical model's *orderings* drive the tuner, but the partition
    planner also needs absolute scale: choosing between 1 launch and k
    launches compares sums of latencies, so a missing per-launch fixed cost
    systematically favours more blocks (PR 5's modeled-vs-measured gap).
    Corrections are fit per format from (predicted, measured) latency pairs
    accumulated by the telemetry recorder, and applied inside ``evaluate`` —
    ``partition.plan.combine`` then charges k corrected launches against one
    corrected monolithic launch with no planner changes.

    With no corrections (or none for the requested format) evaluation is
    byte-identical to the base model, so the class is safe as a drop-in
    default. Energy stays modeled: wall-clock telemetry carries no power
    sensor, and rescaling energy by measured time would double-count the
    overhead in the power term.
    """

    def __init__(
        self,
        hw: HardwareProfile = TPU_V5E,
        corrections: dict[str, FormatCalibration] | None = None,
    ):
        super().__init__(hw)
        self.corrections = dict(corrections or {})

    def evaluate(
        self, stats: MatrixStats, fmt: str, schedule: KernelSchedule
    ) -> ObjectiveValues:
        base = super().evaluate(stats, fmt, schedule)
        cal = self.corrections.get(fmt)
        if cal is None or cal.samples <= 0 or not base.feasible:
            return base
        latency = cal.launch_overhead_s + cal.latency_scale * base.latency
        if latency <= 0.0 or not math.isfinite(latency):
            return base
        # energy is unchanged; power/efficiency re-derive from the corrected
        # wall time so the four objectives stay mutually consistent
        useful_flops = base.efficiency * base.power * base.latency * 1e6
        power = min(base.energy / latency, self.hw.p_max - self.hw.p_static)
        mflops = useful_flops / latency / 1e6
        return ObjectiveValues(latency, base.energy, power, mflops / power)

    # ------------------------------------------------------------------ fit
    @staticmethod
    def _fit_one(pairs: list[tuple[float, float]]) -> FormatCalibration | None:
        pts = [(p, m) for p, m in pairs if p > 0.0 and m > 0.0]
        if not pts:
            return None
        pred = np.asarray([p for p, _ in pts], dtype=np.float64)
        meas = np.asarray([m for _, m in pts], dtype=np.float64)
        if len(pts) >= 2 and float(np.ptp(pred)) > 0.0:
            scale, overhead = np.polyfit(pred, meas, 1)
        else:
            scale, overhead = float(meas.mean() / pred.mean()), 0.0
        if scale <= 0.0 or overhead < 0.0:
            # a negative intercept (or inverted slope) means the affine form
            # extrapolates below zero for small kernels; fall back to the
            # always-safe pure rescale
            scale, overhead = float(meas.mean() / pred.mean()), 0.0
        fitted = overhead + scale * pred
        rel_err = float(np.mean(np.abs(fitted - meas) / meas))
        return FormatCalibration(
            launch_overhead_s=float(overhead),
            latency_scale=float(scale),
            samples=len(pts),
            mean_rel_err=rel_err,
        )

    @classmethod
    def fit(
        cls,
        samples: dict[str, list[tuple[float, float]]],
        hw: HardwareProfile = TPU_V5E,
    ) -> "CalibratedCostModel":
        """Fit per-format corrections from (predicted_s, measured_s) pairs."""
        corrections = {}
        for fmt, pairs in samples.items():
            cal = cls._fit_one(list(pairs))
            if cal is not None:
                corrections[fmt] = cal
        return cls(hw, corrections)

    @classmethod
    def fit_from_telemetry(
        cls, recorder, hw: HardwareProfile = TPU_V5E
    ) -> "CalibratedCostModel":
        """Fit from a ``TelemetryRecorder``'s accumulated calibration pairs."""
        return cls.fit(recorder.calibration_samples(), hw)

    # -------------------------------------------------------------- persist
    def save(self, path) -> None:
        """Persist alongside the tuning cache (atomic, like the cache)."""
        from repro.utils.io import atomic_write_text

        payload = {
            "version": 1,
            "hardware": self.hw.name,
            "formats": {f: c.as_dict() for f, c in self.corrections.items()},
        }
        atomic_write_text(path, json.dumps(payload, indent=2, sort_keys=True))

    @classmethod
    def load(cls, path, hw: HardwareProfile | None = None) -> "CalibratedCostModel":
        raw = json.loads(Path(path).read_text())
        if raw.get("version") != 1:
            raise ValueError(f"unsupported calibration version: {raw.get('version')!r}")
        resolved = hw or HARDWARE.get(raw.get("hardware", ""), TPU_V5E)
        corrections = {
            fmt: FormatCalibration(
                launch_overhead_s=float(d["launch_overhead_s"]),
                latency_scale=float(d["latency_scale"]),
                samples=int(d["samples"]),
                mean_rel_err=float(d.get("mean_rel_err", math.nan)),
            )
            for fmt, d in raw.get("formats", {}).items()
        }
        return cls(resolved, corrections)


# ---------------------------------------------------------------------------
# measured (CPU wall-time) source — the run-time-mode ground truth
# ---------------------------------------------------------------------------


def measure_cpu_formats(
    dense: np.ndarray, reps: int = 3, warmup: int = 1, seed: int = 0
) -> dict[str, float]:
    """Mean wall-time (s) of the jit'd jnp SpMV per format on this host."""
    import jax.numpy as jnp

    from repro.sparse import from_dense, spmv
    from repro.utils.timing import measure_wall_time

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=dense.shape[1]).astype(np.float32))
    out = {}
    for fmt in format_names():
        mat = from_dense(dense, fmt)
        res = measure_wall_time(lambda: spmv(mat, x), warmup=warmup, reps=reps)
        out[fmt] = res["mean_s"]
    return out
