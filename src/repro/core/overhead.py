"""Run-time overhead measurement + prediction (paper §5.3, §7.5, Fig. 6).

Total run-time-mode overhead = f_latency (feature extraction) + o_latency
(overhead prediction) + p_latency (format prediction) + c_latency
(conversion). f and c dominate and scale with the matrix; o and p are
constant-time model inferences. Auto-SpMV converts only when the predicted
gain over the remaining solver iterations exceeds the predicted overhead.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.core.features import SparsityFeatures, extract_features
from repro.ml.linear import Ridge
from repro.sparse.formats import from_dense
from repro.sparse.registry import format_names
from repro.utils.logging import get_logger

log = get_logger("core.overhead")


@dataclass(frozen=True)
class OverheadSample:
    matrix: str
    features: SparsityFeatures
    f_latency: float
    c_latency: dict[str, float]  # per target format


def measure_overheads(dense: np.ndarray, name: str = "?") -> OverheadSample:
    """Wall-time the actual host-side feature extraction and conversions."""
    t0 = time.perf_counter()
    feats = extract_features(dense)
    f_latency = time.perf_counter() - t0
    c_latency = {}
    for fmt in format_names():
        t0 = time.perf_counter()
        from_dense(dense, fmt)
        c_latency[fmt] = time.perf_counter() - t0
    return OverheadSample(name, feats, f_latency, c_latency)


def _design_row(features: SparsityFeatures) -> np.ndarray:
    # overheads scale ~linearly in n and nnz; keep raw terms + log terms
    v = features.vector()
    return np.concatenate([v[:2] / 1e6, np.log1p(v)])


class OverheadPredictor:
    """Learned f_latency / c_latency estimators (one ridge per format)."""

    def __init__(self):
        self._f_model: Ridge | None = None
        self._c_models: dict[str, Ridge] = {}

    def fit(self, samples: list[OverheadSample]) -> "OverheadPredictor":
        X = np.stack([_design_row(s.features) for s in samples])
        self._f_model = Ridge(alpha=1e-3).fit(X, np.array([s.f_latency for s in samples]))
        # fit one model per format the samples actually measured (a plugin
        # registered after sampling has no c-latency column to learn from)
        fmts = sorted(set.intersection(*(set(s.c_latency) for s in samples)))
        for fmt in fmts:
            y = np.array([s.c_latency[fmt] for s in samples])
            self._c_models[fmt] = Ridge(alpha=1e-3).fit(X, y)
        return self

    def predict_f(self, features: SparsityFeatures) -> float:
        x = _design_row(features)[None, :]
        return float(max(self._f_model.predict(x)[0], 0.0))

    def predict_c(self, features: SparsityFeatures, fmt: str) -> float:
        x = _design_row(features)[None, :]
        model = self._c_models.get(fmt)
        if model is None:
            # format registered after the overhead samples were taken: be
            # conservative and charge the worst measured conversion cost
            return float(
                max(max(m.predict(x)[0] for m in self._c_models.values()), 0.0)
            )
        return float(max(model.predict(x)[0], 0.0))

    def total_overhead(
        self, features: SparsityFeatures, fmt: str, inference_latency: float = 2e-3
    ) -> float:
        """f + c + (o + p): o/p are constant model-inference costs (the
        paper measures ~20 ms on its host; ours are single ridge/tree
        inferences, defaulting to 2 ms)."""
        return self.predict_f(features) + self.predict_c(features, fmt) + 2 * inference_latency
