"""Learned predictors: the classification / regression stage (paper §5.4).

Per optimization objective, Auto-SpMV trains:

* one *format* classifier (run-time mode): features -> best sparse format;
* one classifier per compile-time *knob* (compile-time mode, format fixed to
  CSR): features -> best knob value (tb_size/rows_per_block, maxrregcount/
  unroll, memory/x_residency, + TPU extras nnz_tile, accum_dtype);
* optionally, *regressors* estimating the objective value of an arbitrary
  (features, config) pair — used for gain estimation in the conversion
  decision and for the paper's Fig. 11 study.

Models come from the zoo (paper Table 1/4) and can be HPO-tuned (hpo.py).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.dataset import TuningDataset
from repro.core.features import SparsityFeatures
from repro.core.hpo import tune_model
from repro.core.tuning_space import ALL_KNOBS, KNOBS, TuningConfig
from repro.kernels.common import KernelSchedule
from repro.ml.metrics import accuracy_score
from repro.ml.model_zoo import CLASSIFIER_ZOO, REGRESSOR_ZOO
from repro.sparse.registry import default_format, format_names
from repro.utils.logging import get_logger

log = get_logger("core.predictor")

OBJECTIVES = ("latency", "energy", "power", "efficiency")


def _feature_matrix(features_list: list[SparsityFeatures]) -> np.ndarray:
    return np.stack([f.log_vector() for f in features_list])


def _config_row(
    config: TuningConfig, fmt_names: tuple[str, ...] | None = None
) -> np.ndarray:
    s = config.schedule
    names = fmt_names if fmt_names is not None else format_names()
    # one-hot over the format vocabulary frozen at fit time: a format
    # registered *after* fitting encodes as all-zeros instead of shifting
    # the feature layout under a fitted regressor
    fmt_onehot = [1.0 if config.fmt == f else 0.0 for f in names]
    return np.array(
        fmt_onehot
        + [
            np.log2(s.rows_per_block),
            np.log2(s.nnz_tile),
            np.log2(s.unroll),
            1.0 if s.accum_dtype == "bfloat16" else 0.0,
            1.0 if s.x_residency == "stream" else 0.0,
        ]
    )


@dataclass
class PredictorConfig:
    model_name: str = "decision_tree"  # paper's winner (Table 5)
    # decision tree is the paper's winner for average power (Fig. 11) and is
    # CPU-cheap; benchmarks/fig11 sweeps the full regressor zoo incl. the
    # random forest that wins energy/efficiency.
    regressor_name: str = "decision_tree"
    regressor_max_depth: int | None = 14
    max_regressor_samples: int = 3000  # subsample cap for single-core fit
    tune: bool = False  # run TPE HPO per classifier (paper §5.4 step 3)
    n_trials: int = 12
    seed: int = 0


@dataclass
class AutoSpmvPredictor:
    config: PredictorConfig = field(default_factory=PredictorConfig)

    def fit(self, dataset: TuningDataset) -> "AutoSpmvPredictor":
        self.format_clf_: dict[str, object] = {}
        self.knob_clf_: dict[tuple[str, str], object] = {}
        self.regressor_: dict[str, object] = {}
        # freeze the format vocabulary for the regressors' config encoding
        self.format_names_: tuple[str, ...] = format_names()
        matrices = dataset.matrices

        feats, fmt_labels, knob_labels = [], {o: [] for o in OBJECTIVES}, {}
        for knob in ALL_KNOBS:
            for obj in OBJECTIVES:
                knob_labels[(obj, knob)] = []
        for m in matrices:
            feats.append(dataset.for_matrix(m)[0].features)
            for obj in OBJECTIVES:
                # run-time mode label: best format over the full space
                best_fmt = dataset.best_record(m, obj).config.fmt
                fmt_labels[obj].append(best_fmt)
                # compile-time mode labels: best knob values with the
                # default (held) format fixed
                best_cfg = dataset.best_record(
                    m, obj, formats=(default_format(),)
                ).config
                for knob in ALL_KNOBS:
                    field_, _ = KNOBS[knob]
                    knob_labels[(obj, knob)].append(
                        str(getattr(best_cfg.schedule, field_))
                    )
        X = _feature_matrix(feats)

        for obj in OBJECTIVES:
            self.format_clf_[obj] = self._fit_classifier(X, np.array(fmt_labels[obj]))
            for knob in ALL_KNOBS:
                y = np.array(knob_labels[(obj, knob)])
                self.knob_clf_[(obj, knob)] = self._fit_classifier(X, y)

        # regressors on the record set (features + config encoding); capped
        # subsample keeps single-core fit times in seconds
        recs = dataset.feasible()
        if len(recs) > self.config.max_regressor_samples:
            sel = np.random.default_rng(self.config.seed).choice(
                len(recs), self.config.max_regressor_samples, replace=False
            )
            recs = [recs[i] for i in sel]
        Xr = np.stack(
            [
                np.concatenate(
                    [r.features.log_vector(), _config_row(r.config, self.format_names_)]
                )
                for r in recs
            ]
        )
        for obj in OBJECTIVES:
            y = np.array([r.objective(obj) for r in recs])
            y = np.log(np.maximum(y, 1e-30))  # objectives span decades
            entry = REGRESSOR_ZOO[self.config.regressor_name]
            kw = dict(entry["defaults"])
            if "max_depth" in kw:
                kw["max_depth"] = self.config.regressor_max_depth
            reg = entry["ctor"](**kw)
            reg.fit(Xr, y)
            self.regressor_[obj] = reg
        return self

    # ------------------------------------------------------------------ fits
    def _fit_classifier(self, X: np.ndarray, y: np.ndarray):
        entry = CLASSIFIER_ZOO[self.config.model_name]
        if len(np.unique(y)) == 1:
            return _ConstantClassifier(y[0])
        kw = dict(entry["defaults"])
        if self.config.tune and len(y) >= 6:
            res = tune_model(
                entry,
                X,
                y,
                accuracy_score,
                n_trials=self.config.n_trials,
                cv=3,
                seed=self.config.seed,
            )
            kw.update(res.best_params)
        clf = entry["ctor"](**kw)
        clf.fit(X, y)
        return clf

    # -------------------------------------------------------------- predicts
    def predict_format(self, features: SparsityFeatures, objective: str) -> str:
        x = features.log_vector()[None, :]
        return str(self.format_clf_[objective].predict(x)[0])

    def predict_schedule(
        self, features: SparsityFeatures, objective: str
    ) -> KernelSchedule:
        x = features.log_vector()[None, :]
        kw = {}
        for knob, (field_, choices) in KNOBS.items():
            raw = str(self.knob_clf_[(objective, knob)].predict(x)[0])
            # decode back to the python type of the choice set
            decoded = next(c for c in choices if str(c) == raw)
            kw[field_] = decoded
        # unroll must divide nnz_tile; clamp if the per-knob predictions clash
        if kw["nnz_tile"] % kw["unroll"]:
            kw["unroll"] = 1
        return KernelSchedule(**kw)

    def estimate_objective(
        self, features: SparsityFeatures, config: TuningConfig, objective: str
    ) -> float:
        names = getattr(self, "format_names_", None)
        x = np.concatenate([features.log_vector(), _config_row(config, names)])[None, :]
        return float(np.exp(self.regressor_[objective].predict(x)[0]))


class _ConstantClassifier:
    """Degenerate single-class case (e.g. one knob value dominates)."""

    def __init__(self, value):
        self.value = value

    def fit(self, X, y):
        return self

    def predict(self, X):
        return np.array([self.value] * np.asarray(X).shape[0])
