"""Autotuning session: amortized, batched, restart-surviving Auto-SpMV.

``AutoSpmvSession`` wraps the one-shot ``AutoSpMV`` optimizer with the three
things a serving system needs (ROADMAP north star: caching, batching, faster
hot path):

1. **Plan cache** — decisions are memoized in a feature-bucketed
   ``TuningCache`` (core/cache.py) with JSON save/load, so the predictor
   inferences run once per (bucket, objective) per fleet, not once per call.
2. **Kernel memo** — prepared Pallas kernels are memoized process-wide by
   matrix fingerprint (kernels/ops.py), so repeated matrices skip format
   conversion and kernel specialization entirely.
3. **Batched tuning** — ``optimize_many`` deduplicates a batch of matrices
   by content fingerprint, tunes each unique matrix once, and fans the
   shared results back out in input order.

Amortized overhead accounting (paper §5.3): the run-time-mode conversion
gate charges the full ``f + c + o + p`` overhead only on a plan-cache
*miss*. On a hit the decision terms (f, o, p) were already paid when the
bucket was first tuned; the conversion term ``c`` is charged only when the
prepared kernel is actually absent from the process-wide kernel memo (fresh
process after a JSON reload, LRU eviction, or a different matrix landing in
the same feature bucket) — the gate always sees the true marginal cost.

Telemetry hooks (repro/telemetry): a session optionally carries a
``TelemetryRecorder`` and an ``AdaptiveFormatSelector``. ``serve_optimize``
consults the bandit for the format to serve (the cached plan is the
incumbent arm), ``observe`` feeds measured wall times back, and a sustained
drift verdict invalidates the stale cache entries so the next request
re-plans. Both collaborators are duck-typed — the session never imports the
telemetry package, so ``repro.core`` stays import-cycle-free.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from pathlib import Path

import numpy as np

from repro.core.autotuner import (
    AutoSpMV,
    CompileTimeResult,
    RunTimePlan,
    RunTimeResult,
    should_convert,
)
from repro.core.cache import CacheEntry, TuningCache
from repro.core.features import SparsityFeatures, extract_features
from repro.kernels.common import DEFAULT_SCHEDULE, KernelSchedule
from repro.kernels.ops import (
    compile_spmspv as _compile_spmspv_kernel,
    compile_spmv,
    kernel_memo_stats,
    kernel_memoized,
    matrix_fingerprint,
)
from repro.obs.trace import span as _span
from repro.sparse.registry import default_format, format_names
from repro.utils.logging import get_logger

log = get_logger("core.session")


@dataclass
class SessionStats:
    """What the session actually paid for vs. what it reused."""

    requests: int = 0
    feature_extractions: int = 0  # actual Table-2 passes (f term)
    plans_computed: int = 0  # actual predictor inferences (o + p terms)
    kernel_compiles: int = 0  # actual prepare+bind passes (c term)
    cache_hits: int = 0
    cache_misses: int = 0
    overhead_paid_s: float = 0.0  # predicted overhead charged on misses
    overhead_saved_s: float = 0.0  # predicted overhead skipped on hits
    observations: int = 0  # measured executions fed back via observe()
    explorations: int = 0  # bandit pulls served off the incumbent plan
    invalidations: int = 0  # drift-triggered cache evictions

    def as_dict(self) -> dict:
        return {
            "requests": self.requests,
            "feature_extractions": self.feature_extractions,
            "plans_computed": self.plans_computed,
            "kernel_compiles": self.kernel_compiles,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "overhead_paid_s": self.overhead_paid_s,
            "overhead_saved_s": self.overhead_saved_s,
            "observations": self.observations,
            "explorations": self.explorations,
            "invalidations": self.invalidations,
        }


def _run_mode_key(current_format: str, schedule: KernelSchedule) -> str:
    """Run-time plans depend on the held format (gain is measured against
    it) and, through the objective estimates, on the comparison schedule."""
    if schedule == DEFAULT_SCHEDULE:
        return f"run:{current_format}"
    tag = "_".join(f"{k}={v}" for k, v in sorted(schedule.as_dict().items()))
    return f"run:{current_format}:{tag}"


def _part_mode_key(max_blocks: int) -> str:
    """Partitioned plans are keyed by their block-count budget: sessions
    running with different ``--max-blocks`` must not alias entries."""
    return f"part:max{max_blocks}"


def _calibration_path(cache_path: Path) -> Path:
    """Where a session persists its fitted cost-model corrections: a sibling
    of the tuning cache, so the two artifacts travel (and restart) together."""
    return cache_path.with_name(cache_path.stem + ".calibration.json")


@dataclass(frozen=True)
class PartitionedResult:
    """What ``partitioned_optimize`` returns: the composite plan actually
    applied to this matrix, its executor, and enough identity for
    ``observe_partitioned`` to feed every (block, format) arm."""

    fingerprint: str
    features: SparsityFeatures
    bucket: str
    objective: str
    plan: object  # repro.partition.plan.CompositePlan
    kernel: object  # repro.partition.executor.PartitionedSpmv
    mode: str  # the cache mode key ("part:max<k>")
    cache_hit: bool = False
    served_formats: tuple[str, ...] = ()  # per block, after bandit swaps
    exploratory: tuple[bool, ...] = ()  # per block: served off the plan

    @property
    def n_blocks(self) -> int:
        return self.plan.n_blocks

    @property
    def formats(self) -> tuple[str, ...]:
        return self.served_formats or self.plan.formats


@dataclass(frozen=True)
class ServedPlan:
    """What ``serve_optimize`` hands the serving layer: the plan actually
    served this request, with enough identity for ``observe`` to attribute
    the measured outcome back to the right telemetry arm."""

    fingerprint: str
    features: SparsityFeatures
    bucket: str
    objective: str
    fmt: str  # format served (bandit may diverge from the cached plan)
    schedule: KernelSchedule
    kernel: object  # PreparedSpmv
    predicted: dict  # model objective estimates for the cached plan
    plan_id: str  # "bucket/objective/mode" string for the telemetry log
    exploratory: bool = False  # this pull was bandit exploration
    cache_hit: bool = False  # the schedule plan pre-existed this request
    predicted_s: float | None = None  # model latency estimate for the SERVED
    # format (drift detection compares measured against this, not against
    # the csr compile-plan estimate)


class AutoSpmvSession:
    """A long-lived tuning context sharing one cache across many matrices.

    Parameters
    ----------
    tuner:
        The wrapped ``AutoSpMV`` optimizer (predictors + overhead model).
    cache:
        An existing ``TuningCache`` to share; mutually exclusive with
        ``cache_path`` loading.
    cache_path:
        Optional JSON path. If the file exists the cache is warmed from it;
        ``save()`` writes back to the same path by default.
    telemetry:
        Optional ``repro.telemetry.TelemetryRecorder`` (duck-typed);
        ``observe`` forwards measured outcomes to it.
    adaptive:
        Optional ``repro.telemetry.AdaptiveFormatSelector`` (duck-typed);
        ``serve_optimize`` consults it and ``observe`` updates it, including
        drift-triggered cache invalidation.
    """

    def __init__(
        self,
        tuner: AutoSpMV,
        cache: TuningCache | None = None,
        cache_path: str | Path | None = None,
        *,
        telemetry=None,
        adaptive=None,
        cost_model=None,
    ):
        if cache is None:
            if cache_path is not None and Path(cache_path).exists():
                try:
                    cache = TuningCache.load(cache_path)
                except Exception as exc:  # corrupt/stale file: cold start
                    log.warning(
                        "ignoring unreadable tuning cache %s (%s); starting cold",
                        cache_path,
                        exc,
                    )
                    cache = TuningCache()
            else:
                cache = TuningCache()
        self.tuner = tuner
        self.cache = cache
        self.cache_path = Path(cache_path) if cache_path is not None else None
        self.telemetry = telemetry
        self.adaptive = adaptive
        if cost_model is None and self.cache_path is not None:
            cal_path = _calibration_path(self.cache_path)
            if cal_path.exists():
                try:
                    from repro.core.objectives import CalibratedCostModel

                    cost_model = CalibratedCostModel.load(cal_path)
                    log.info(
                        "loaded cost-model calibration from %s (%d formats)",
                        cal_path,
                        len(cost_model.corrections),
                    )
                except Exception as exc:  # advisory artifact: cold-start fine
                    log.warning(
                        "ignoring unreadable calibration %s (%s)", cal_path, exc
                    )
        self.cost_model = cost_model
        self.stats = SessionStats()
        # fingerprint -> (features, bucket): dedups the f term. LRU-bounded
        # like the kernel memo — a server streaming distinct matrices must
        # not grow per-matrix state forever (entries are small, so the
        # bound is generous).
        self._feat_memo: OrderedDict[str, tuple[SparsityFeatures, str]] = OrderedDict()
        self._feat_memo_limit = 8192
        # (bucket, objective, fmt) -> regressor latency estimate: one cheap
        # inference per arm per fleet, dropped with the bucket on invalidate
        self._pred_memo: dict[tuple[str, str, str], float] = {}

    # ------------------------------------------------------------- internals
    def _analyze(
        self, dense: np.ndarray, fingerprint: str | None = None
    ) -> tuple[str, SparsityFeatures, str]:
        fp = fingerprint if fingerprint is not None else matrix_fingerprint(dense)
        cached = self._feat_memo.get(fp)
        if cached is not None:
            self._feat_memo.move_to_end(fp)
            return fp, cached[0], cached[1]
        feats = extract_features(dense)
        self.stats.feature_extractions += 1
        bucket = self.cache.bucket_of(feats)
        self._feat_memo[fp] = (feats, bucket)
        while len(self._feat_memo) > self._feat_memo_limit:
            self._feat_memo.popitem(last=False)
        return fp, feats, bucket

    def _compile(
        self, dense: np.ndarray, fp: str, fmt: str, schedule: KernelSchedule
    ):
        before = kernel_memo_stats()["compiles"]
        kernel = compile_spmv(
            dense, fmt, schedule, interpret=self.tuner.interpret, memo_key=fp
        )
        self.stats.kernel_compiles += kernel_memo_stats()["compiles"] - before
        return kernel

    def compile_spmspv(
        self, dense: np.ndarray, schedule: KernelSchedule = DEFAULT_SCHEDULE
    ):
        """Session-accounted SpMSpV compilation (sparse-frontier twin path).

        Shares the matrix fingerprint (and thus the process kernel memo)
        with the SpMV plans for the same matrix, and books any real
        conversion into ``stats.kernel_compiles`` — so an iterative solver
        that lazily adds the SpMSpV path still shows up as exactly one
        extra compile in the amortization counters."""
        fp, _, _ = self._analyze(dense)
        before = kernel_memo_stats()["compiles"]
        prepared = _compile_spmspv_kernel(
            dense, schedule, interpret=self.tuner.interpret, memo_key=fp
        )
        self.stats.kernel_compiles += kernel_memo_stats()["compiles"] - before
        return prepared

    def plan_key(
        self,
        features: SparsityFeatures,
        objective: str,
        mode: str = "compile",
        *,
        current_format: str | None = None,
        schedule: KernelSchedule = DEFAULT_SCHEDULE,
    ) -> tuple[str, str, str]:
        """The cache key a request with these features resolves to.

        Callers (e.g. the SpMV server's hit reporting) should use this
        instead of re-deriving bucket/mode strings from cache internals."""
        current_format = current_format or default_format()
        m = mode if mode == "compile" else _run_mode_key(current_format, schedule)
        return (self.cache.bucket_of(features), objective, m)

    # ---------------------------------------------------------- compile time
    def compile_time_optimize(
        self,
        dense: np.ndarray,
        objective: str = "latency",
        *,
        fingerprint: str | None = None,
    ) -> CompileTimeResult:
        self.stats.requests += 1
        with _span("session.optimize", mode="compile", objective=objective) as sp:
            fp, feats, bucket = self._analyze(dense, fingerprint)
            with _span("cache.lookup", bucket=bucket, mode="compile"):
                entry = self.cache.get(bucket, objective, "compile")
            hit = entry is not None
            if entry is None:
                with _span("plan.compute", bucket=bucket, mode="compile"):
                    plan = self.tuner.plan_compile_time(feats, objective)
                self.stats.plans_computed += 1
                self.stats.cache_misses += 1
                entry = self.cache.put(
                    CacheEntry(
                        bucket=bucket,
                        objective=objective,
                        mode="compile",
                        fmt=default_format(),
                        schedule=plan.schedule.as_dict(),
                        predicted=dict(plan.predicted),
                    )
                )
                log.info("compile-time miss: bucket=%s -> %s", bucket, plan.schedule)
            else:
                self.stats.cache_hits += 1
            sp.set(bucket=bucket, cache_hit=hit)
            schedule = entry.kernel_schedule()
            kernel = self._compile(dense, fp, default_format(), schedule)
        return CompileTimeResult(feats, schedule, kernel, dict(entry.predicted))

    # -------------------------------------------------------------- run time
    def run_time_optimize(
        self,
        dense: np.ndarray,
        objective: str = "latency",
        *,
        n_iterations: int = 1000,
        current_format: str | None = None,
        schedule: KernelSchedule = DEFAULT_SCHEDULE,
        fingerprint: str | None = None,
    ) -> RunTimeResult:
        current_format = current_format or default_format()
        self.stats.requests += 1
        with _span("session.optimize", mode="run", objective=objective) as sp:
            fp, feats, bucket = self._analyze(dense, fingerprint)
            mode = _run_mode_key(current_format, schedule)
            with _span("cache.lookup", bucket=bucket, mode=mode):
                entry = self.cache.get(bucket, objective, mode)
            sp.set(bucket=bucket, cache_hit=entry is not None)
            if entry is None:
                with _span("plan.compute", bucket=bucket, mode=mode):
                    plan = self.tuner.plan_run_time(
                        feats,
                        objective,
                        current_format=current_format,
                        schedule=schedule,
                    )
                self.stats.plans_computed += 1
                self.stats.cache_misses += 1
                self.cache.put(
                    CacheEntry(
                        bucket=bucket,
                        objective=objective,
                        mode=mode,
                        fmt=plan.best_format,
                        schedule=schedule.as_dict(),
                        gain_per_iter=plan.gain_per_iter,
                        latency_gain_per_iter=plan.latency_gain_per_iter,
                        overhead_s=plan.overhead_s,
                        convert_overhead_s=plan.convert_overhead_s,
                    )
                )
                # first sight of this bucket: pay the decision terms, but
                # credit the conversion term if the kernel is already
                # memoized (e.g. a plan for another objective converted this
                # matrix earlier)
                overhead_eff = plan.overhead_s
                if kernel_memoized(
                    fp, plan.best_format, schedule, interpret=self.tuner.interpret
                ):
                    overhead_eff -= plan.convert_overhead_s
                self.stats.overhead_paid_s += overhead_eff
            else:
                self.stats.cache_hits += 1
                plan = RunTimePlan(
                    entry.fmt,
                    entry.gain_per_iter,
                    entry.latency_gain_per_iter,
                    entry.overhead_s,
                    entry.convert_overhead_s,
                )
                # §5.3 amortization: the decision terms (f, o, p) were paid
                # when the bucket was first tuned; conversion (c) only
                # re-applies if the prepared kernel is not actually memoized
                # in this process.
                if kernel_memoized(
                    fp, plan.best_format, schedule, interpret=self.tuner.interpret
                ):
                    overhead_eff = 0.0
                else:
                    overhead_eff = plan.convert_overhead_s
                self.stats.overhead_saved_s += plan.overhead_s - overhead_eff
            convert = should_convert(
                plan, n_iterations, current_format, overhead_s=overhead_eff
            )
            kernel = (
                self._compile(dense, fp, plan.best_format, schedule)
                if convert
                else None
            )
        log.info(
            "run-time(session): obj=%s bucket=%s fmt %s->%s overhead=%.3gs convert=%s",
            objective,
            bucket,
            current_format,
            plan.best_format,
            overhead_eff,
            convert,
        )
        return RunTimeResult(
            feats, plan.best_format, convert, plan.gain_per_iter, overhead_eff, kernel
        )

    # --------------------------------------------------------------- batched
    def optimize_many(
        self,
        mats: list[np.ndarray],
        objective: str = "latency",
        *,
        mode: str = "compile",
        **kwargs,
    ) -> list:
        """Tune a batch of matrices, deduplicated by content fingerprint.

        Each unique matrix is tuned once (feature extraction, plan lookup,
        kernel compile); duplicates receive the same result object. Results
        are returned in input order. ``mode`` is ``"compile"`` or ``"run"``;
        ``kwargs`` forward to the per-matrix optimize call.
        """
        if mode not in ("compile", "run"):
            raise ValueError(f"mode must be 'compile' or 'run', got {mode!r}")
        fps = [matrix_fingerprint(np.asarray(m)) for m in mats]
        unique: dict[str, object] = {}
        for fp, m in zip(fps, mats):
            if fp in unique:
                self.stats.requests += 1  # served entirely from the memo
                continue
            if mode == "compile":
                unique[fp] = self.compile_time_optimize(
                    m, objective, fingerprint=fp, **kwargs
                )
            else:
                unique[fp] = self.run_time_optimize(
                    m, objective, fingerprint=fp, **kwargs
                )
        log.info(
            "optimize_many: %d matrices -> %d unique (%s, %s)",
            len(mats),
            len(unique),
            mode,
            objective,
        )
        return [unique[fp] for fp in fps]

    # ------------------------------------------------------------ partitioned
    def _replay_partitioned(self, dense: np.ndarray, entry: CacheEntry):
        """Rebuild a ``CompositePlan`` for THIS matrix from a cached entry.

        The cached decisions are bucket-level (per-block format + schedule,
        in row order); the row boundaries are re-derived from this matrix's
        own nnz histogram, so a bucket-mate with a shifted hub row still gets
        balanced blocks. Returns None when the stored block count cannot be
        realized (fewer rows than blocks) — the caller re-plans."""
        from repro.partition.partitioner import partition_rows
        from repro.partition.plan import BlockPlan, CompositePlan

        from repro.core.objectives import ObjectiveValues

        part = partition_rows(dense, entry.n_blocks)
        if part.n_blocks != entry.n_blocks or len(entry.blocks) != entry.n_blocks:
            return None
        plans = tuple(
            BlockPlan(
                block=blk,
                fmt=raw["fmt"],
                schedule=KernelSchedule(**raw["schedule"]),
                # replayed plans carry the stored latency estimate only;
                # full ObjectiveValues live with the entry that planned them
                modeled=ObjectiveValues(raw.get("latency", 0.0), 0.0, 0.0, 0.0),
                predicted_fmt=raw.get("predicted_fmt", raw["fmt"]),
            )
            for blk, raw in zip(part.blocks, entry.blocks)
        )
        modeled = ObjectiveValues(entry.predicted.get("latency", 0.0), 0.0, 0.0, 0.0)
        monolithic = ObjectiveValues(
            entry.predicted.get("monolithic_latency", 0.0), 0.0, 0.0, 0.0
        )
        return CompositePlan(
            entry.objective, part, plans, modeled, monolithic,
            entry.monolithic_fmt or default_format(),
        )

    def partitioned_optimize(
        self,
        dense: np.ndarray,
        objective: str = "latency",
        *,
        max_blocks: int = 8,
        fused: bool = False,
        fingerprint: str | None = None,
    ) -> PartitionedResult:
        """Partitioned run-time mode through the plan cache.

        On a miss the tuner searches block counts {1, ..., max_blocks} and
        the winning composite plan (or the monolithic fallback) is cached
        per feature bucket; on a hit the stored per-block decisions replay
        onto this matrix's own nnz-balanced boundaries. Kernels compile
        through the process-wide memo, keyed per (matrix, row range).

        Planning uses the session's ``cost_model`` when one is set (a
        ``CalibratedCostModel`` after ``calibrate``), so block-count search
        charges the measured per-launch fixed cost. With ``fused=True`` the
        composite lowers to ONE Pallas launch (``compile_fused_partitioned``,
        one memo entry keyed on the whole plan) instead of per-block kernels
        — the fast serving path; per-block timing needs ``fused=False``."""
        from repro.partition.executor import (
            compile_fused_partitioned,
            compile_partitioned,
        )
        from repro.partition.partitioner import SUPPORTED_BLOCK_COUNTS

        self.stats.requests += 1
        with _span(
            "session.optimize", mode="partitioned", objective=objective, fused=fused
        ) as sp:
            fp, feats, bucket = self._analyze(dense, fingerprint)
            mode = _part_mode_key(max_blocks)
            with _span("cache.lookup", bucket=bucket, mode=mode):
                entry = self.cache.get(bucket, objective, mode)
            plan = self._replay_partitioned(dense, entry) if entry is not None else None
            cache_hit = plan is not None
            sp.set(bucket=bucket, cache_hit=cache_hit)
            if plan is None:
                block_counts = tuple(
                    k for k in SUPPORTED_BLOCK_COUNTS if k <= max_blocks
                ) or (1,)
                with _span("plan.compute", bucket=bucket, mode=mode):
                    plan = self.tuner.plan_partitioned(
                        dense, objective, block_counts=block_counts,
                        cost_model=self.cost_model,
                    )
                self.stats.plans_computed += 1
                self.stats.cache_misses += 1
                self.cache.put(
                    CacheEntry(
                        bucket=bucket,
                        objective=objective,
                        mode=mode,
                        fmt="+".join(plan.formats),
                        schedule=plan.blocks[0].schedule.as_dict(),
                        predicted={
                            "latency": plan.modeled.latency,
                            "monolithic_latency": plan.monolithic.latency,
                        },
                        n_blocks=plan.n_blocks,
                        blocks=[bp.as_dict() for bp in plan.blocks],
                        monolithic_fmt=plan.monolithic_fmt,
                    )
                )
                log.info(
                    "partitioned miss: bucket=%s -> k=%d formats=%s (gain %.1f%%)",
                    bucket,
                    plan.n_blocks,
                    "+".join(plan.formats),
                    100.0 * plan.gain(),
                )
            else:
                self.stats.cache_hits += 1
            before = kernel_memo_stats()["compiles"]
            if fused:
                kernel = compile_fused_partitioned(
                    dense, plan, interpret=self.tuner.interpret, memo_key=fp
                )
            else:
                kernel = compile_partitioned(
                    dense, plan, interpret=self.tuner.interpret, memo_key=fp
                )
            self.stats.kernel_compiles += kernel_memo_stats()["compiles"] - before
        return PartitionedResult(
            fingerprint=fp,
            features=feats,
            bucket=bucket,
            objective=objective,
            plan=plan,
            kernel=kernel,
            mode=mode,
            cache_hit=cache_hit,
        )

    def serve_partitioned(
        self,
        dense: np.ndarray,
        objective: str = "latency",
        *,
        max_blocks: int = 8,
        fingerprint: str | None = None,
    ) -> PartitionedResult:
        """Partitioned serving with per-(block, format) bandit arms.

        Each block's cell (``block_arm_bucket``) consults the adaptive
        selector with the composite plan's block format as incumbent, so
        individual blocks explore and drift independently — block 2 can be
        re-routed to SELL while block 0 keeps its plan. An infeasible
        exploratory pick is disabled for that block's cell and the planned
        kernel serves instead (a probe failure is paid once, not per
        request). Without an adaptive selector this is exactly
        ``partitioned_optimize``."""
        base = self.partitioned_optimize(
            dense, objective, max_blocks=max_blocks, fingerprint=fingerprint
        )
        if self.adaptive is None:
            return base
        from dataclasses import replace as dc_replace

        from repro.kernels.ops import compile_spmv_block
        from repro.telemetry.adaptive import block_arm_bucket

        served, exploratory, kernels = [], [], list(base.kernel.blocks)
        for i, (bp, bk) in enumerate(zip(base.plan.blocks, base.kernel.blocks)):
            cell = block_arm_bucket(base.bucket, bp.block.index, base.n_blocks)
            prior = bp.modeled.latency if bp.modeled.latency > 0 else None
            fmt, explore = self.adaptive.choose(
                cell, objective, bp.fmt, format_names(), prior_value=prior
            )
            if fmt != bp.fmt:
                try:
                    before = kernel_memo_stats()["compiles"]
                    swapped = compile_spmv_block(
                        dense,
                        bp.block.row_start,
                        bp.block.row_end,
                        fmt,
                        bp.schedule,
                        interpret=self.tuner.interpret,
                        memo_key=base.fingerprint,
                    )
                    self.stats.kernel_compiles += (
                        kernel_memo_stats()["compiles"] - before
                    )
                    kernels[i] = dc_replace(bk, fmt=fmt, kernel=swapped)
                except Exception as exc:
                    log.warning(
                        "serve: %s infeasible for block %d of bucket %s (%s)",
                        fmt,
                        bp.block.index,
                        base.bucket,
                        exc,
                    )
                    self.adaptive.disable(cell, objective, fmt, fallback=bp.fmt)
                    fmt, explore = bp.fmt, False
            if explore:
                self.stats.explorations += 1
            served.append(fmt)
            exploratory.append(explore)
        from repro.partition.executor import PartitionedSpmv

        kernel = PartitionedSpmv(kernels, base.plan.partition.n_rows)
        return PartitionedResult(
            fingerprint=base.fingerprint,
            features=base.features,
            bucket=base.bucket,
            objective=base.objective,
            plan=base.plan,
            kernel=kernel,
            mode=base.mode,
            cache_hit=base.cache_hit,
            served_formats=tuple(served),
            exploratory=tuple(exploratory),
        )

    def observe_partitioned(
        self, result: PartitionedResult, block_times_s: list[float]
    ) -> None:
        """Feed per-block measured wall times back: every (block, format)
        pair is its own telemetry/bandit arm, and a sustained drift verdict
        on ANY block evicts the composite plan for the bucket, so the next
        request re-plans (and the promoted block arm seeds its incumbent)."""
        if len(block_times_s) != result.n_blocks:
            raise ValueError(
                f"{len(block_times_s)} block times for {result.n_blocks} blocks"
            )
        self.stats.observations += 1
        if self.telemetry is None and self.adaptive is None:
            return
        from repro.telemetry.adaptive import block_arm_bucket

        formats = result.formats
        for bp, fmt, dt in zip(result.plan.blocks, formats, block_times_s):
            cell = block_arm_bucket(result.bucket, bp.block.index, result.n_blocks)
            predicted = bp.modeled.latency if bp.modeled.latency > 0 else None
            explored = bool(
                result.exploratory[bp.block.index] if result.exploratory else False
            )
            if self.telemetry is not None:
                self.telemetry.observe(
                    bucket=cell,
                    objective=result.objective,
                    fmt=fmt,
                    measured_s=dt,
                    predicted_s=predicted if fmt == bp.fmt else None,
                    plan_id=f"{cell}/{result.objective}/{result.mode}",
                    exploratory=explored,
                    schedule=bp.schedule.as_dict(),
                    features=bp.block.features.dict(),
                )
            if self.adaptive is None:
                continue
            self.adaptive.update(
                cell,
                result.objective,
                fmt,
                dt,
                predicted_s=predicted if fmt == bp.fmt else None,
            )
            challenger = self.adaptive.review(cell, result.objective)
            if challenger is not None:
                dropped = self.invalidate(result.bucket, result.objective, result.mode)
                self.adaptive.promote(cell, result.objective, challenger)
                log.info(
                    "drift: block %d of bucket=%s obj=%s %s -> %s "
                    "(%d composite plan(s) dropped)",
                    bp.block.index,
                    result.bucket,
                    result.objective,
                    fmt,
                    challenger,
                    dropped,
                )

    # ----------------------------------------------------- telemetry serving
    def _incumbent_format(
        self, feats: SparsityFeatures, bucket: str, objective: str
    ) -> str:
        """The cached run-time plan's format — the bandit's incumbent arm.

        Computed (and cached) via ``plan_run_time`` on first sight, so the
        classifier's opinion is the arm the bandit starts from."""
        mode = _run_mode_key(default_format(), DEFAULT_SCHEDULE)
        entry = self.cache.peek(bucket, objective, mode)
        if entry is None:
            plan = self.tuner.plan_run_time(feats, objective)
            self.stats.plans_computed += 1
            entry = self.cache.put(
                CacheEntry(
                    bucket=bucket,
                    objective=objective,
                    mode=mode,
                    fmt=plan.best_format,
                    schedule=DEFAULT_SCHEDULE.as_dict(),
                    gain_per_iter=plan.gain_per_iter,
                    latency_gain_per_iter=plan.latency_gain_per_iter,
                    overhead_s=plan.overhead_s,
                    convert_overhead_s=plan.convert_overhead_s,
                )
            )
        return entry.fmt

    def _predicted_latency(
        self,
        feats: SparsityFeatures,
        bucket: str,
        objective: str,
        fmt: str,
        schedule: KernelSchedule,
    ) -> float | None:
        """Regressor latency estimate for (features, fmt, schedule), memoized
        per (bucket, objective, fmt) so serving pays one inference per arm."""
        key = (bucket, objective, fmt)
        cached = self._pred_memo.get(key)
        if cached is not None:
            return cached
        try:
            from repro.core.tuning_space import TuningConfig

            est = float(
                self.tuner.predictor.estimate_objective(
                    feats, TuningConfig(fmt, schedule), "latency"
                )
            )
        except Exception:  # predictor without regressors: prior-less bandit
            return None
        self._pred_memo[key] = est
        return est

    def serve_optimize(
        self,
        dense: np.ndarray,
        objective: str = "latency",
        *,
        fingerprint: str | None = None,
    ) -> ServedPlan:
        """The telemetry-aware serving path: cached schedule + bandit format.

        The compile-time plan supplies the kernel schedule and objective
        estimates exactly as before; with an ``adaptive`` selector attached
        the *format* is the bandit's pick — the cached run-time plan as
        incumbent, alternates within the exploration budget. Without one
        this degrades to ``compile_time_optimize`` plus plan identity, so
        telemetry-only deployments record without changing any decision.
        """
        fp, feats, bucket = self._analyze(dense, fingerprint)
        key = self.plan_key(feats, objective)
        pre_existing = self.cache.peek(*key) is not None
        base = self.compile_time_optimize(dense, objective, fingerprint=fp)
        default_fmt = default_format()
        fmt, exploratory = default_fmt, False
        if self.adaptive is not None:
            incumbent = self._incumbent_format(feats, bucket, objective)
            fmt, exploratory = self.adaptive.choose(
                bucket,
                objective,
                incumbent,
                format_names(),
                prior_value=self._predicted_latency(
                    feats, bucket, objective, incumbent, base.schedule
                ),
            )
            if exploratory:
                self.stats.explorations += 1
        if fmt == default_fmt:
            kernel = base.kernel
        else:
            try:
                kernel = self._compile(dense, fp, fmt, base.schedule)
            except Exception as exc:
                # an exploratory format can be infeasible for this matrix
                # (storage blow-up, tile mismatch): serving must not fail on
                # a bandit probe — fall back to the compile-time default-
                # format kernel and retire the arm so the failure is paid
                # once, not per request
                log.warning(
                    "serve: %s infeasible for bucket %s (%s); serving %s",
                    fmt,
                    bucket,
                    exc,
                    default_fmt,
                )
                if self.adaptive is not None:
                    self.adaptive.disable(bucket, objective, fmt)
                fmt, exploratory, kernel = default_fmt, False, base.kernel
        return ServedPlan(
            fingerprint=fp,
            features=feats,
            bucket=bucket,
            objective=objective,
            fmt=fmt,
            schedule=base.schedule,
            kernel=kernel,
            predicted=dict(base.predicted),
            plan_id="/".join(key),
            exploratory=exploratory,
            cache_hit=pre_existing,
            predicted_s=self._predicted_latency(
                feats, bucket, objective, fmt, base.schedule
            ),
        )

    def observe(self, plan: ServedPlan, measured_s: float) -> None:
        """Feed one measured execution back: record, update the bandit, and
        evict the cached plan when drift is sustained (measure → relearn)."""
        self.stats.observations += 1
        predicted_s = plan.predicted_s
        if self.telemetry is not None:
            self.telemetry.observe(
                bucket=plan.bucket,
                objective=plan.objective,
                fmt=plan.fmt,
                measured_s=measured_s,
                predicted_s=predicted_s,
                plan_id=plan.plan_id,
                exploratory=plan.exploratory,
                schedule=plan.schedule.as_dict(),
                features=plan.features.dict(),
            )
        if self.adaptive is None:
            return
        self.adaptive.update(
            plan.bucket, plan.objective, plan.fmt, measured_s, predicted_s=predicted_s
        )
        challenger = self.adaptive.review(plan.bucket, plan.objective)
        if challenger is not None:
            dropped = self.invalidate(plan.bucket, plan.objective)
            self.adaptive.promote(plan.bucket, plan.objective, challenger)
            log.info(
                "drift: bucket=%s obj=%s %s -> %s (%d stale plans dropped)",
                plan.bucket,
                plan.objective,
                plan.fmt,
                challenger,
                dropped,
            )

    def invalidate(
        self, bucket: str, objective: str | None = None, mode: str | None = None
    ) -> int:
        """Evict cached plans for a bucket; the next request re-plans against
        the current predictors (which feedback may have refit meanwhile)."""
        dropped = self.cache.invalidate(bucket, objective, mode)
        if dropped:
            self.stats.invalidations += 1
        # the memoized regressor estimates belong to the evicted plans: a
        # refit predictor must be re-consulted for this bucket
        for key in [
            k
            for k in self._pred_memo
            if k[0] == bucket and (objective is None or k[1] == objective)
        ]:
            del self._pred_memo[key]
        return dropped

    def evict_format(self, fmt: str) -> int:
        """Invalidate every cached plan serving ``fmt`` — monolithic plans
        whose chosen format matches, and partitioned composites carrying it
        as any block's component. The anomaly watchdog's targeted eviction:
        a lying cost model poisons exactly the plans scored with its
        estimates for that format, so only those re-plan."""
        dropped = 0
        for entry in list(self.cache.entries()):
            if fmt in (entry.fmt or "").split("+"):
                dropped += self.invalidate(entry.bucket, entry.objective, entry.mode)
        if dropped:
            log.info("evicted %d cached plan(s) serving format %s", dropped, fmt)
        return dropped

    # ----------------------------------------------------------- calibration
    def calibrate(self, *, save: bool = True, min_samples: int = 1):
        """Fit a ``CalibratedCostModel`` from accumulated telemetry.

        The recorder's (predicted_s, measured_s) pairs become per-format
        affine corrections; the fitted model replaces the session's
        ``cost_model`` so subsequent partition planning charges the measured
        per-launch cost. Cached partitioned plans were scored by the old
        model and are evicted (any ``part:*`` mode, every bucket) — the next
        request re-plans against measured reality. Persisted as a sibling of
        the tuning cache so a restarted session auto-loads it.
        """
        if self.telemetry is None:
            raise ValueError("calibrate() requires a telemetry recorder")
        from repro.core.objectives import TPU_V5E, CalibratedCostModel

        hw = getattr(self.cost_model, "hw", None) or TPU_V5E
        model = CalibratedCostModel.fit_from_telemetry(self.telemetry, hw)
        model.corrections = {
            f: c for f, c in model.corrections.items() if c.samples >= min_samples
        }
        self.cost_model = model
        dropped = 0
        for entry in list(self.cache.entries()):
            if entry.mode.startswith("part:"):
                dropped += self.invalidate(entry.bucket, entry.objective, entry.mode)
        if save and self.cache_path is not None:
            model.save(_calibration_path(self.cache_path))
        log.info(
            "calibrated cost model: %d format(s), %d stale partitioned plan(s) "
            "dropped",
            len(model.corrections),
            dropped,
        )
        return model

    # ----------------------------------------------------------- persistence
    def save(self, path: str | Path | None = None) -> Path:
        """Persist the plan cache (kernels stay process-local)."""
        target = Path(path) if path is not None else self.cache_path
        if target is None:
            raise ValueError("no path given and session has no cache_path")
        return self.cache.save(target)


def build_tuner(
    scale: float = 0.0015,
    names: tuple[str, ...] | None = None,
    n_extra: int = 4,
    *,
    fit_overhead: bool = True,
    interpret: bool = True,
) -> AutoSpMV:
    """Convenience: collect a small dataset, fit predictors + overhead model.

    The quickest self-contained way to stand up a session (launcher demos,
    benchmarks); library users with a persisted dataset should fit
    ``AutoSpmvPredictor`` themselves and pass it to ``AutoSpMV`` directly.
    """
    from repro.core.dataset import collect_dataset
    from repro.core.overhead import OverheadPredictor, measure_overheads
    from repro.core.predictor import AutoSpmvPredictor, PredictorConfig
    from repro.sparse.generate import MATRIX_NAMES, generate_by_name

    names = tuple(names) if names is not None else MATRIX_NAMES[:8]
    ds = collect_dataset(scale=scale, names=names, n_extra=n_extra)
    pred = AutoSpmvPredictor(PredictorConfig(max_regressor_samples=1500)).fit(ds)
    overhead = None
    if fit_overhead:
        overhead = OverheadPredictor().fit(
            [measure_overheads(generate_by_name(n, scale=scale), n) for n in names]
        )
    return AutoSpMV(pred, overhead, interpret=interpret, dataset=ds)
