"""The Auto-SpMV configuration space (format x compile-time schedule).

``KNOBS`` maps each of the paper's tunable parameters to its TPU analogue on
``KernelSchedule`` (DESIGN.md §2): ``tb_size`` -> rows_per_block,
``maxrregcount`` -> unroll, ``memory`` -> x_residency; ``nnz_tile`` and
``accum_dtype`` are TPU-only extras reported separately in benchmarks.

The paper's *default* configuration (its comparison baseline) is the CSR
format with untuned compiler parameters; ours is CSR with the default
schedule.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Iterator, Sequence

from repro.kernels.common import (
    ACCUM_DTYPE_CHOICES,
    DEFAULT_SCHEDULE,
    NNZ_TILE_CHOICES,
    ROWS_PER_BLOCK_CHOICES,
    UNROLL_CHOICES,
    X_RESIDENCY_CHOICES,
    KernelSchedule,
)
from repro.sparse.registry import default_format, format_names


@dataclass(frozen=True)
class TuningConfig:
    fmt: str
    schedule: KernelSchedule

    def as_dict(self) -> dict:
        d = {"fmt": self.fmt}
        d.update(self.schedule.as_dict())
        return d


def __getattr__(name):
    if name == "DEFAULT_CONFIG":
        # resolved per access (PEP 562), not frozen at import: a plugin that
        # registers itself below the seeds' priority becomes the default
        # everywhere at once — including this baseline config
        return TuningConfig(default_format(), DEFAULT_SCHEDULE)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

# paper knob name -> (KernelSchedule field, choices)
KNOBS: dict[str, tuple[str, tuple]] = {
    "tb_size": ("rows_per_block", ROWS_PER_BLOCK_CHOICES),
    "maxrregcount": ("unroll", UNROLL_CHOICES),
    "memory": ("x_residency", X_RESIDENCY_CHOICES),
    "nnz_tile": ("nnz_tile", NNZ_TILE_CHOICES),
    "accum_dtype": ("accum_dtype", ACCUM_DTYPE_CHOICES),
}
PAPER_KNOBS = ("tb_size", "maxrregcount", "memory")  # Table 5 columns
ALL_KNOBS = tuple(KNOBS)


def schedule_space(
    rows_per_block=ROWS_PER_BLOCK_CHOICES,
    nnz_tile=NNZ_TILE_CHOICES,
    unroll=UNROLL_CHOICES,
    accum_dtype=ACCUM_DTYPE_CHOICES,
    x_residency=X_RESIDENCY_CHOICES,
) -> Iterator[KernelSchedule]:
    """All valid schedules in the (sub)space (invalid combos skipped)."""
    for rpb, nt, u, acc, xr in itertools.product(
        rows_per_block, nnz_tile, unroll, accum_dtype, x_residency
    ):
        if nt % u:
            continue  # unroll must divide the tile
        yield KernelSchedule(
            rows_per_block=rpb,
            nnz_tile=nt,
            unroll=u,
            accum_dtype=acc,
            x_residency=xr,
        )


def full_space(
    formats: Sequence[str] | None = None, **schedule_kw
) -> Iterator[TuningConfig]:
    """The run-time-mode space: format x schedule.

    ``formats`` defaults to every *registered* format (including plugins
    registered via ``repro.sparse.registry.register_format``)."""
    for fmt in format_names() if formats is None else formats:
        for sched in schedule_space(**schedule_kw):
            yield TuningConfig(fmt, sched)


def compile_time_space(**schedule_kw) -> Iterator[TuningConfig]:
    """The compile-time-mode space: the default (held) format fixed
    (paper §5.2 step 3 — CSR), schedule free."""
    return full_space(formats=(default_format(),), **schedule_kw)


def knob_value(config: TuningConfig, knob: str):
    field, _ = KNOBS[knob]
    return getattr(config.schedule, field)


def space_size(**kw) -> int:
    return sum(1 for _ in full_space(**kw))
