from repro.data.pipeline import DataConfig, SyntheticLMDataset, Prefetcher

__all__ = ["DataConfig", "SyntheticLMDataset", "Prefetcher"]
