"""Synthetic LM data pipeline: stateless, step-indexed, host-prefetched.

Fault-tolerance property: ``batch_at(step)`` is a pure function of
(seed, step), so a restarted or elastically-rescaled job resumes mid-stream
with zero pipeline state in the checkpoint. Batches follow a Zipf unigram
draw with short-range repetition structure, so small-model training loss
visibly decreases (the end-to-end example's acceptance check).
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    repeat_p: float = 0.35  # P(copy an earlier token) — learnable structure
    embed_dim: int = 0  # >0: also emit stub frontend embeddings
    prefix_len: int = 0


class SyntheticLMDataset:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # fixed unigram distribution (shared across steps)
        rng = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        probs = ranks ** (-cfg.zipf_a)
        self._probs = probs / probs.sum()
        self._perm = rng.permutation(cfg.vocab_size)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, T = cfg.global_batch, cfg.seq_len
        base = self._perm[rng.choice(cfg.vocab_size, size=(B, T + 1), p=self._probs)]
        # repetition structure: some tokens copy a recent one (lag 1..8)
        rep = rng.random((B, T + 1)) < cfg.repeat_p
        lag = rng.integers(1, 9, size=(B, T + 1))
        idx = np.maximum(np.arange(T + 1)[None, :] - lag, 0)
        copied = np.take_along_axis(base, idx, axis=1)
        seq = np.where(rep, copied, base).astype(np.int32)
        batch = {"tokens": seq[:, :-1], "labels": seq[:, 1:]}
        if cfg.embed_dim:
            # stub frontend: embeddings derived deterministically from ids
            emb_rng = np.random.default_rng((cfg.seed, step, 7))
            batch["embeds"] = emb_rng.normal(
                0, 0.02, size=(B, T, cfg.embed_dim)
            ).astype(np.float32)
        if cfg.prefix_len:
            pre_rng = np.random.default_rng((cfg.seed, step, 11))
            batch["prefix_embeds"] = pre_rng.normal(
                0, 0.02, size=(B, cfg.prefix_len, cfg.embed_dim or 1)
            ).astype(np.float32)
        return batch

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Host-side background prefetch (overlaps data gen with compute)."""

    def __init__(self, dataset: SyntheticLMDataset, start_step: int = 0, depth: int = 2):
        self._ds = dataset
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._ds.batch_at(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def next(self) -> tuple[int, dict]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
