"""Distribution substrate: logical-axis sharding rules + partition hints.

``sharding.py`` maps the logical axis vocabulary of ``models/param.py``
(vocab/embed/heads/kv/ffn/...) onto mesh axes (FSDP over the data axes, TP
over the model axis) with divisibility and no-reuse guards. ``partition.py``
provides the ambient-context ``hint`` that model code sprinkles on
activations; outside a ``sharding_context`` it is an identity, so the same
model code runs unmodified on a single CPU device.
"""

from repro.dist.partition import hint, sharding_context
from repro.dist.sharding import (
    RULE_SETS,
    abstract_mesh,
    batch_sharding,
    build_sharding,
    spec_for,
)

__all__ = [
    "RULE_SETS",
    "abstract_mesh",
    "batch_sharding",
    "build_sharding",
    "hint",
    "sharding_context",
    "spec_for",
]
