"""Ambient partition hints: ``hint(x, logical_axes)`` inside model code.

Model code annotates activations with logical axes only; the concrete mesh
and rule set come from the innermost ``sharding_context``. With no active
context (unit tests, single-device runs) ``hint`` is the identity, so the
same model source serves both the laptop and the fleet.
"""

from __future__ import annotations

from contextlib import contextmanager
from typing import Mapping

import jax
from jax.sharding import NamedSharding

from repro.dist.sharding import spec_for

_CONTEXT: list[tuple[object, Mapping | None]] = []


@contextmanager
def sharding_context(mesh, rules: Mapping | None = None):
    """Establish the ambient (mesh, rules) pair consumed by ``hint``."""
    _CONTEXT.append((mesh, rules))
    try:
        yield
    finally:
        _CONTEXT.pop()


def current_context() -> tuple[object, Mapping | None] | None:
    return _CONTEXT[-1] if _CONTEXT else None


def hint(x: jax.Array, axes: tuple[str | None, ...]) -> jax.Array:
    """Constrain ``x``'s sharding by logical axes; identity with no context."""
    ctx = current_context()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = spec_for(mesh, tuple(x.shape), axes, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
