"""Logical-axis -> mesh-axis sharding rules (FSDP + TP).

The logical vocabulary is documented in ``models/param.py``. Placement:

* data-like logical axes (``batch``, ``embed``) shard over every non-model
  mesh axis, in mesh order — ``("data",)`` on a 2D mesh, ``("pod", "data")``
  on a multi-pod mesh (ZeRO-3-style weight sharding over the full data
  extent);
* tensor-parallel logical axes (``vocab``, ``heads``, ``kv``, ``ffn``,
  ``rnn``) shard over the ``model`` axis;
* everything else (``experts``, ``layers``, ``seq``, ``None``) replicates.

Two guards make the mapping total: a dimension that does not divide the
mesh extent replicates instead (kv=8 on a 16-way model axis), and a mesh
axis is never assigned twice in one spec (the second ``embed`` of a square
weight replicates).
"""

from __future__ import annotations

import math
from typing import Any, Iterable, Mapping

import jax
from jax.sharding import AbstractMesh, NamedSharding, PartitionSpec as P

MODEL_AXIS = "model"

# logical axis -> placement class: "data" (all non-model axes), "model", or
# None (replicated). A rule set is a plain dict so variants can override.
TRAIN_RULES: dict[str, str | None] = {
    "batch": "data",
    "embed": "data",
    "vocab": MODEL_AXIS,
    "heads": MODEL_AXIS,
    "kv": MODEL_AXIS,
    "ffn": MODEL_AXIS,
    "rnn": MODEL_AXIS,
    "experts": None,
    "layers": None,
    "seq": None,
}

# Inference keeps weights TP-sharded but replicates embed (no ZeRO gather on
# the decode path; the per-chip weight residency is paid once).
INFER_RULES: dict[str, str | None] = dict(TRAIN_RULES, embed=None)

# Partitioned SpMV (repro.partition.executor): the stacked per-block sparse
# storage shards its leading "blocks" axis over the data axes (one row block
# per device); the dense X vector replicates, because every block may gather
# arbitrary columns; per-block Y keeps the "blocks" axis sharded so output
# shards stay local to the device that produced them.
SPMV_RULES: dict[str, str | None] = {
    "blocks": "data",
    "rows": None,
    "cols": None,
}

RULE_SETS: dict[str, dict[str, str | None]] = {
    "train": TRAIN_RULES,
    "infer": INFER_RULES,
    "spmv": SPMV_RULES,
}


def spmv_mesh(n_blocks: int | None = None):
    """1-D ``("data",)`` mesh over the first ``n_blocks`` local devices.

    The partitioned executor maps one row block per device, so the mesh
    extent is ``min(n_blocks, available devices)`` — on a single-device host
    this degrades to a 1-extent mesh and ``shard_map`` runs everything
    locally (same program, no collectives)."""
    import numpy as np

    devices = jax.devices()
    n = len(devices) if n_blocks is None else max(1, min(n_blocks, len(devices)))
    return jax.sharding.Mesh(np.asarray(devices[:n]), ("data",))


def abstract_mesh(axis_sizes: Iterable[int], axis_names: Iterable[str]) -> AbstractMesh:
    """Version-compatible ``AbstractMesh`` constructor.

    jax <= 0.4.x takes a single tuple of (name, size) pairs; newer releases
    take (axis_sizes, axis_names).
    """
    sizes, names = tuple(axis_sizes), tuple(axis_names)
    try:
        return AbstractMesh(tuple(zip(names, sizes)))
    except TypeError:
        return AbstractMesh(sizes, names)


def _mesh_axis_sizes(mesh) -> dict[str, int]:
    return dict(mesh.shape)


def _data_axes(mesh) -> tuple[str, ...]:
    return tuple(n for n in mesh.axis_names if n != MODEL_AXIS)


def spec_for(
    mesh,
    shape: tuple[int, ...],
    axes: tuple[str | None, ...],
    rules: Mapping[str, str | None] | None = None,
) -> P:
    """PartitionSpec for one array given its logical axes.

    Indivisible dims and already-used mesh axes fall back to replication;
    trailing replicated entries are stripped so specs compare canonically.
    """
    rules = TRAIN_RULES if rules is None else rules
    sizes = _mesh_axis_sizes(mesh)
    used: set[str] = set()
    entries: list[Any] = []
    for dim, logical in zip(shape, axes):
        placement = rules.get(logical) if logical is not None else None
        if placement is None:
            entries.append(None)
            continue
        names = _data_axes(mesh) if placement == "data" else (placement,)
        names = tuple(n for n in names if n in sizes and n not in used)
        extent = math.prod(sizes[n] for n in names) if names else 0
        if not names or dim % extent:
            entries.append(None)
            continue
        used.update(names)
        entries.append(names if len(names) > 1 else names[0])
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def build_sharding(mesh, spec_tree: Any, rules: Mapping | None = None) -> Any:
    """NamedSharding tree for a ParamSpec pytree (same structure)."""
    from repro.models.param import is_spec

    return jax.tree.map(
        lambda s: NamedSharding(mesh, spec_for(mesh, s.shape, s.axes, rules)),
        spec_tree,
        is_leaf=is_spec,
    )


def batch_sharding(mesh, batch: Any, rules: Mapping | None = None) -> Any:
    """Shard the leading (batch) axis of every leaf over the data axes."""

    def one(leaf):
        shape = tuple(leaf.shape)
        axes = ("batch",) + (None,) * (len(shape) - 1)
        return NamedSharding(mesh, spec_for(mesh, shape, axes, rules))

    return jax.tree.map(one, batch)
