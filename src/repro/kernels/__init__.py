"""Pallas TPU kernels for the paper's compute hot-spot: the SpMV kernel
itself (CSR / ELL / BELL / SELL), schedule-parameterized by the Auto-SpMV
compile-time mode. ``ops.py`` is the jit-facing wrapper; ``ref.py`` holds the
pure-jnp oracles."""

from repro.kernels.common import (
    DEFAULT_SCHEDULE,
    KernelSchedule,
    ROWS_PER_BLOCK_CHOICES,
    NNZ_TILE_CHOICES,
    UNROLL_CHOICES,
    ACCUM_DTYPE_CHOICES,
    X_RESIDENCY_CHOICES,
)
from repro.kernels.ops import (
    InfeasibleConfig,
    PreparedSpmspv,
    PreparedSpmv,
    clear_kernel_memo,
    compile_spmspv,
    compile_spmv,
    kernel_memo_limit,
    kernel_memo_size,
    kernel_memo_stats,
    kernel_memoized,
    matrix_fingerprint,
    prepare,
    set_kernel_memo_limit,
    spmm_pallas,
    spmspv,
    spmv_pallas,
)

__all__ = [
    "DEFAULT_SCHEDULE",
    "KernelSchedule",
    "ROWS_PER_BLOCK_CHOICES",
    "NNZ_TILE_CHOICES",
    "UNROLL_CHOICES",
    "ACCUM_DTYPE_CHOICES",
    "X_RESIDENCY_CHOICES",
    "InfeasibleConfig",
    "PreparedSpmspv",
    "PreparedSpmv",
    "clear_kernel_memo",
    "compile_spmspv",
    "compile_spmv",
    "kernel_memo_limit",
    "kernel_memo_size",
    "kernel_memo_stats",
    "kernel_memoized",
    "matrix_fingerprint",
    "prepare",
    "set_kernel_memo_limit",
    "spmm_pallas",
    "spmspv",
    "spmv_pallas",
]
