"""Pallas TPU kernel: BELL (blocked-ELL) SpMV with scalar-prefetch gather.

The TPU-native trick: the gather of X segments happens in the *pipeline*,
not the kernel body. ``block_cols`` is a scalar-prefetch operand, and the
BlockSpec index map of X reads it to DMA exactly the (bc,)-segment each
stored block needs. Each grid step is then a dense (br, bc) x (bc,) matvec
on MXU-aligned shapes — the reason BELL blocks are 8..256 x 128 here instead
of the paper's GPU 2x2 (DESIGN.md §2).

BELL is also the only format whose X access is *streamed* rather than
VMEM-resident, i.e. the ``x_residency='stream'`` point of the tuning space.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import CompilerParams, KernelSchedule


def _bell_kernel(bc_ref, d_ref, x_ref, y_ref, *, accum_dtype):
    del bc_ref  # consumed by the index maps
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    blk = d_ref[0, 0].astype(accum_dtype)  # (br, bc)
    xs = x_ref[0].astype(accum_dtype)  # (bc,)
    y = jnp.dot(blk, xs, preferred_element_type=accum_dtype)  # MXU matvec
    y_ref[...] += y.reshape(y_ref.shape).astype(y_ref.dtype)


def bell_spmv_pallas(
    data: jax.Array,
    block_cols: jax.Array,
    x_panels: jax.Array,
    schedule: KernelSchedule,
    *,
    interpret: bool = True,
) -> jax.Array:
    """SpMV over BELL storage.

    ``data: (nbr, mb, br, bc)``, ``block_cols: (nbr, mb)`` int32,
    ``x_panels: (n_col_blocks, bc)`` — X padded and reshaped into bc-panels
    (ops.py prepares it). Returns ``y: (nbr, br)``.
    """
    nbr, mb, br, bc = data.shape
    grid = (nbr, mb)
    kernel = functools.partial(_bell_kernel, accum_dtype=schedule.jnp_accum_dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, br, bc), lambda i, j, bcols: (i, j, 0, 0)),
            # the scalar-prefetch-driven gather: DMA the X panel this block needs
            pl.BlockSpec((1, bc), lambda i, j, bcols: (bcols[i, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, br), lambda i, j, bcols: (i, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nbr, br), x_panels.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=(schedule.dimension_semantics, "arbitrary"),
        ),
        interpret=interpret,
        name="bell_spmv",
    )(block_cols, data, x_panels)
