"""Shared kernel-schedule definition for the Pallas SpMV kernels.

``KernelSchedule`` is the TPU analogue of the paper's compile-time parameter
vector (DESIGN.md §2 table):

=====================  =========================  ============================
paper (CUDA)           ours (Pallas/TPU)          resource trade-off
=====================  =========================  ============================
thread-block size      ``rows_per_block``         work granularity / grid size
maxrregcount           ``unroll``                 VREG pressure vs ILP
L1/shared split        ``x_residency``            VMEM residency policy for X
(ILP per thread)       ``nnz_tile``               lane-aligned tile width
(precision)            ``accum_dtype``            MXU/VPU rate vs accuracy
(SM scheduling)        ``dimension_semantics``    grid-axis scheduling
=====================  =========================  ============================

All Pallas kernels accept a ``KernelSchedule`` and honour its tiling; the
schedule is what the Auto-SpMV compile-time mode predicts per input matrix.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases; kernels
# import the alias so either jax works.
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

LANE = 128  # TPU vector lane quantum — the single source of truth
SUBLANE = 8  # TPU sublane quantum (sparse/formats re-exports both)


class InfeasibleConfig(ValueError):
    """Raised when a (format, schedule) pair cannot be materialized.

    The tuner's search space contains invalid points (exactly as on GPU,
    where e.g. a thread-block size can exceed resource limits); the dataset
    harness records them as failures rather than crashing. Format plugins
    raise this from their ``prepare``/``spmv`` entrypoints (see
    ``repro.sparse.registry.FormatSpec``).
    """

# Discrete choice sets — the tuning space the classifiers predict over.
ROWS_PER_BLOCK_CHOICES = (8, 16, 32, 64, 128, 256, 512)
NNZ_TILE_CHOICES = (128, 256, 512, 1024)
UNROLL_CHOICES = (1, 2, 4, 8)
ACCUM_DTYPE_CHOICES = ("float32", "bfloat16")
X_RESIDENCY_CHOICES = ("vmem", "stream")
DIMENSION_SEMANTICS_CHOICES = ("parallel", "arbitrary")

# TPU v5e VMEM per core (bytes) — the hard budget the schedule must respect.
VMEM_BYTES = 128 * 1024 * 1024 // 2  # 64 MiB usable planning budget


@dataclass(frozen=True)
class KernelSchedule:
    rows_per_block: int = 64
    nnz_tile: int = LANE
    unroll: int = 1
    accum_dtype: str = "float32"
    x_residency: str = "vmem"
    dimension_semantics: str = "arbitrary"

    def __post_init__(self):
        if self.rows_per_block % SUBLANE:
            raise ValueError(f"rows_per_block must be a multiple of {SUBLANE}")
        if self.nnz_tile % LANE:
            raise ValueError(f"nnz_tile must be a multiple of {LANE}")
        if self.nnz_tile % self.unroll:
            raise ValueError("unroll must divide nnz_tile")
        if self.accum_dtype not in ACCUM_DTYPE_CHOICES:
            raise ValueError(f"accum_dtype must be one of {ACCUM_DTYPE_CHOICES}")
        if self.x_residency not in X_RESIDENCY_CHOICES:
            raise ValueError(f"x_residency must be one of {X_RESIDENCY_CHOICES}")

    @property
    def jnp_accum_dtype(self):
        return jnp.dtype(self.accum_dtype)

    def replace(self, **kw) -> "KernelSchedule":
        return dataclasses.replace(self, **kw)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


DEFAULT_SCHEDULE = KernelSchedule()

# Fused partitioned executor (kernels/fused.py): target grid length for the
# single-launch composite stream. The fused tile is derived from the total
# work so the one launch never degenerates into hundreds of tiny grid steps
# (the per-step overhead would hand the win straight back to the per-block
# launches it replaces).
MAX_FUSED_STEPS = 8


def fused_nnz_tile(total_elems: int, *, max_steps: int = MAX_FUSED_STEPS) -> int:
    """Lane-aligned flat tile for the fused composite nonzero stream.

    Sized so the whole stream fits in at most ``max_steps`` sequential grid
    steps, capped so one tile's three operand planes (values + columns +
    row ids, 4 B each) stay well inside the VMEM budget — a stream too large
    for the cap simply takes more grid steps.
    """
    tile = ceil_to(max(1, -(-int(total_elems) // max_steps)), LANE)
    cap = max(LANE, (VMEM_BYTES // 8 // 12) // LANE * LANE)
    return min(tile, cap)


def ceil_to(x: int, q: int) -> int:
    return ((x + q - 1) // q) * q


def pad_axis(a: np.ndarray, axis: int, to: int, fill=0) -> np.ndarray:
    """Pad ``a`` along ``axis`` up to length ``to`` with ``fill``."""
    cur = a.shape[axis]
    if cur >= to:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, to - cur)
    return np.pad(a, widths, constant_values=fill)
