"""Pallas TPU kernel: CSR SpMV (flat COO-tile segmented accumulation).

GPU scalar/vector-CSR does not map onto the TPU's 8x128 vector unit, so the
CSR kernel is re-thought (DESIGN.md §2): nonzeros are walked in lane-aligned
flat tiles along a *sequential* grid; each step forms the per-nonzero
products and scatter-accumulates them into the VMEM-resident output vector
by row id. Rows straddling a tile boundary are stitched for free because the
output block persists in VMEM across the sequential grid. Padding nonzeros
carry ``row_id == n_rows`` and fall into a spill slot that ops.py truncates.

This keeps CSR's no-padding storage property; the price — an in-VMEM
scatter-add per tile — is exactly the "CSR is hostile to wide SIMD" effect
the paper observes on GPU (finding 5), now in TPU form.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import CompilerParams, KernelSchedule


def _csr_kernel(d_ref, c_ref, r_ref, x_ref, y_ref, *, unroll: int, accum_dtype):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    xv = x_ref[...]
    nt = d_ref.shape[0]
    step = nt // unroll
    y = y_ref[...].astype(accum_dtype)
    for k in range(unroll):
        sl = slice(k * step, (k + 1) * step)
        prods = (d_ref[sl].astype(accum_dtype)) * jnp.take(xv, c_ref[sl]).astype(
            accum_dtype
        )
        y = y.at[r_ref[sl]].add(prods)
    y_ref[...] = y.astype(y_ref.dtype)


def csr_spmv_pallas(
    data: jax.Array,
    indices: jax.Array,
    row_ids: jax.Array,
    x: jax.Array,
    n_rows: int,
    schedule: KernelSchedule,
    *,
    interpret: bool = True,
) -> jax.Array:
    """SpMV over tile-aligned flat CSR/COO arrays.

    ``data/indices/row_ids: (nnz_pad,)`` with ``nnz_pad % nnz_tile == 0``;
    padding entries must have ``row_ids == n_rows``. Returns ``y: (n_rows+1,)``
    (last slot = padding spill, truncated by the wrapper).
    """
    (nnz_pad,) = data.shape
    nt = schedule.nnz_tile
    if nnz_pad % nt:
        raise ValueError(f"nnz {nnz_pad} not aligned to nnz_tile {nt}")
    grid = (nnz_pad // nt,)
    kernel = functools.partial(
        _csr_kernel, unroll=schedule.unroll, accum_dtype=schedule.jnp_accum_dtype
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((nt,), lambda i: (i,)),
            pl.BlockSpec((nt,), lambda i: (i,)),
            pl.BlockSpec((nt,), lambda i: (i,)),
            pl.BlockSpec(x.shape, lambda i: (0,)),
        ],
        # whole output vector resident in VMEM across the sequential grid
        out_specs=pl.BlockSpec((n_rows + 1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n_rows + 1,), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",),  # carried accumulation => sequential
        ),
        interpret=interpret,
        name="csr_spmv",
    )(data, indices, row_ids, x)
