"""Pallas TPU kernel: ELL SpMV.

Grid ``(row_blocks, width_tiles)``; each step loads a ``(rows_per_block,
nnz_tile)`` VMEM tile of the ELL value/column planes, gathers the matching X
entries from the VMEM-resident dense vector, and accumulates partial row sums
into the output block (revisited across the width grid axis, so the width
axis must be 'arbitrary'). ``unroll`` splits the tile into independent
accumulator chains — the VREG-pressure knob standing in for maxrregcount.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import CompilerParams, KernelSchedule


def _ell_kernel(d_ref, c_ref, x_ref, y_ref, *, unroll: int, accum_dtype):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    d = d_ref[...]  # (rpb, nt)
    c = c_ref[...]  # (rpb, nt)
    xv = x_ref[...]  # (n_cols,)
    step = d.shape[1] // unroll
    # independent accumulator chains (ILP / register-pressure analogue)
    accs = []
    for k in range(unroll):
        sl = slice(k * step, (k + 1) * step)
        dk = d[:, sl].astype(accum_dtype)
        xk = jnp.take(xv, c[:, sl], axis=0).astype(accum_dtype)
        accs.append(jnp.sum(dk * xk, axis=1))
    acc = functools.reduce(jnp.add, accs)
    y_ref[...] += acc.reshape(y_ref.shape).astype(y_ref.dtype)


def ell_spmv_pallas(
    data: jax.Array,
    cols: jax.Array,
    x: jax.Array,
    schedule: KernelSchedule,
    *,
    interpret: bool = True,
) -> jax.Array:
    """SpMV over padded ELL planes. Shapes must already be tile-aligned:
    ``data/cols: (R, W)`` with ``R % rows_per_block == 0`` and
    ``W % nnz_tile == 0`` (ops.py performs the padding). Returns ``y: (R,)``.
    """
    R, W = data.shape
    rpb, nt = schedule.rows_per_block, schedule.nnz_tile
    if R % rpb or W % nt:
        raise ValueError(f"ELL planes ({R},{W}) not aligned to ({rpb},{nt})")
    grid = (R // rpb, W // nt)
    kernel = functools.partial(
        _ell_kernel, unroll=schedule.unroll, accum_dtype=schedule.jnp_accum_dtype
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rpb, nt), lambda i, j: (i, j)),
            pl.BlockSpec((rpb, nt), lambda i, j: (i, j)),
            pl.BlockSpec(x.shape, lambda i, j: (0,)),  # X resident in VMEM
        ],
        out_specs=pl.BlockSpec((rpb,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((R,), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=(schedule.dimension_semantics, "arbitrary"),
        ),
        interpret=interpret,
        name="ell_spmv",
    )(data, cols, x)


def _ell_spmm_kernel(d_ref, c_ref, x_ref, y_ref, *, accum_dtype):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    d = d_ref[...].astype(accum_dtype)  # (rpb, nt)
    c = c_ref[...]
    xg = jnp.take(x_ref[...], c, axis=0).astype(accum_dtype)  # (rpb, nt, k)
    y_ref[...] += jnp.einsum(
        "rw,rwk->rk", d, xg, preferred_element_type=accum_dtype
    ).astype(y_ref.dtype)


def ell_spmm_pallas(
    data: jax.Array,
    cols: jax.Array,
    X: jax.Array,
    schedule: KernelSchedule,
    *,
    interpret: bool = True,
) -> jax.Array:
    """ELL SpMM (dense RHS ``X: (n_cols, k)``) — the MoE-dispatch shape."""
    R, W = data.shape
    rpb, nt = schedule.rows_per_block, schedule.nnz_tile
    if R % rpb or W % nt:
        raise ValueError(f"ELL planes ({R},{W}) not aligned to ({rpb},{nt})")
    k = X.shape[1]
    grid = (R // rpb, W // nt)
    kernel = functools.partial(_ell_spmm_kernel, accum_dtype=schedule.jnp_accum_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rpb, nt), lambda i, j: (i, j)),
            pl.BlockSpec((rpb, nt), lambda i, j: (i, j)),
            pl.BlockSpec(X.shape, lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rpb, k), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, k), X.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=(schedule.dimension_semantics, "arbitrary"),
        ),
        interpret=interpret,
        name="ell_spmm",
    )(data, cols, X)
