"""Pallas TPU kernel: fused single-launch partitioned SpMV.

PR 5's partitioned executor runs one Pallas launch per row block and
concatenates the outputs on the host — the per-launch fixed cost (trace,
grid setup, dispatch) times the block count is exactly the composite-format
overhead the SpMV survey flags for hybrid formats. This module fuses the
whole heterogeneous composite into ONE launch, the way merge-path/one-pass
composite kernels do on GPU:

* every block's *prepared* container (CSR / ELL / BELL / SELL / plugin) is
  lowered host-side to a flat ``(values, cols, global row ids)`` nonzero
  stream — the element ORDER stays format-specific (CSR row-major, SELL
  column-major slices, BELL block panels), so the chosen format still
  determines the memory-access pattern, while padding slots (stored zeros)
  are dropped so work assignment is nnz-balanced;
* the streams are padded to one lane-aligned tile quantum (sized from the
  TOTAL work, ``kernels.common.fused_nnz_tile``) and concatenated, and a
  prefix-sum **work descriptor** maps each program id to its (block, tile)
  work item; the descriptor rides in scalar-prefetch SMEM and drives the
  BlockSpec index maps;
* each program scatter-accumulates its tile straight into the one
  VMEM-resident ``(n_rows + 1,)`` output vector (the CSR flat-tile kernel's
  spill-slot convention) — every program writes its y shard in place, no
  ``jnp.concatenate``, no per-block dispatch.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (
    CompilerParams,
    KernelSchedule,
    ceil_to,
    fused_nnz_tile,
)
from repro.sparse.formats import BELL, CSR, ELL, SELL


# ---------------------------------------------------------------------------
# Host-side lowering: prepared container -> flat (values, cols, rows) stream
# ---------------------------------------------------------------------------


def _flatten_csr(mat: CSR) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    return (
        np.asarray(mat.data),
        np.asarray(mat.indices).astype(np.int32),
        np.asarray(mat.row_ids).astype(np.int32),
    )


def _flatten_ell(mat: ELL) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    data = np.asarray(mat.data)  # (R_pad, width), row-major
    width = data.shape[1]
    rows = np.repeat(np.arange(data.shape[0], dtype=np.int32), width)
    return data.ravel(), np.asarray(mat.cols).astype(np.int32).ravel(), rows


def _flatten_bell(mat: BELL) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    data = np.asarray(mat.data)  # (nbr, max_blocks, br, bc), panel order
    nbr, mb, br, bc = data.shape
    rows = (
        np.arange(nbr, dtype=np.int32)[:, None, None, None] * br
        + np.arange(br, dtype=np.int32)[None, None, :, None]
    )
    cols = (
        np.asarray(mat.block_cols).astype(np.int32)[:, :, None, None] * bc
        + np.arange(bc, dtype=np.int32)[None, None, None, :]
    )
    rows = np.broadcast_to(rows, data.shape).ravel()
    cols = np.broadcast_to(cols, data.shape).ravel()
    return data.ravel(), cols, rows


def _flatten_sell(mat: SELL) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    # column-major slice planes; padding row_ids (== n_rows) carry value 0
    # and are dropped by the caller's nonzero filter like any padding slot
    return (
        np.asarray(mat.data),
        np.asarray(mat.cols).astype(np.int32),
        np.asarray(mat.row_ids).astype(np.int32),
    )


def flatten_block(
    mat, row_start: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Lower one block's prepared container to its flat nonzero stream.

    Returns ``(values, cols, rows)`` with rows in GLOBAL coordinates
    (``row_start`` added). Padding slots — stored zeros, whatever layout the
    format keeps them in — are filtered out, so the stream length is the
    block's nnz and fused work assignment is nnz-balanced. Plugin containers
    without a dedicated lowering densify through their registered
    ``to_dense`` and flatten as COO.
    """
    if isinstance(mat, CSR):
        data, cols, rows = _flatten_csr(mat)
    elif isinstance(mat, ELL):
        data, cols, rows = _flatten_ell(mat)
    elif isinstance(mat, BELL):
        data, cols, rows = _flatten_bell(mat)
    elif isinstance(mat, SELL):
        data, cols, rows = _flatten_sell(mat)
    else:
        from repro.sparse.registry import spec_for

        dense = np.asarray(spec_for(mat).to_dense(mat))
        rows, cols = np.nonzero(dense)
        data = dense[rows, cols]
        rows, cols = rows.astype(np.int32), cols.astype(np.int32)
    keep = data != 0
    return (
        np.ascontiguousarray(data[keep]),
        np.ascontiguousarray(cols[keep]),
        np.ascontiguousarray(rows[keep] + np.int32(row_start)),
    )


# ---------------------------------------------------------------------------
# The single-launch kernel (CSR flat-tile scatter-add + work descriptor)
# ---------------------------------------------------------------------------


def _fused_kernel(tmap_ref, d_ref, c_ref, r_ref, x_ref, y_ref, *, unroll, accum_dtype):
    del tmap_ref  # consumed by the index maps
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    xv = x_ref[...]
    nt = d_ref.shape[0]
    step = nt // unroll
    y = y_ref[...].astype(accum_dtype)
    for k in range(unroll):
        sl = slice(k * step, (k + 1) * step)
        prods = (d_ref[sl].astype(accum_dtype)) * jnp.take(xv, c_ref[sl]).astype(
            accum_dtype
        )
        y = y.at[r_ref[sl]].add(prods)
    y_ref[...] = y.astype(y_ref.dtype)


def fused_spmv_pallas(
    data: jax.Array,
    cols: jax.Array,
    rows: jax.Array,
    tile_map: jax.Array,
    x: jax.Array,
    n_rows: int,
    tile: int,
    *,
    unroll: int = 1,
    accum_dtype="float32",
    interpret: bool = True,
) -> jax.Array:
    """One launch over the fused composite stream.

    ``data/cols/rows: (n_tiles * tile,)``; padding entries carry value 0,
    col 0, row ``n_rows`` (the spill slot). ``tile_map: (n_tiles,)`` is the
    prefix-sum work descriptor: program ``p`` processes flat tile
    ``tile_map[p]``. Returns ``y: (n_rows + 1,)`` (spill slot last).
    """
    n_tiles = int(tile_map.shape[0])
    if data.shape[0] != n_tiles * tile:
        raise ValueError(
            f"stream length {data.shape[0]} != n_tiles*tile {n_tiles * tile}"
        )
    kernel = functools.partial(
        _fused_kernel, unroll=unroll, accum_dtype=jnp.dtype(accum_dtype)
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i, tmap: (tmap[i],)),
            pl.BlockSpec((tile,), lambda i, tmap: (tmap[i],)),
            pl.BlockSpec((tile,), lambda i, tmap: (tmap[i],)),
            pl.BlockSpec(x.shape, lambda i, tmap: (0,)),
        ],
        # the whole output vector stays VMEM-resident across the sequential
        # grid: every program writes its y shard in place
        out_specs=pl.BlockSpec((n_rows + 1,), lambda i, tmap: (0,)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rows + 1,), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",),  # carried accumulation
        ),
        interpret=interpret,
        name="fused_partitioned_spmv",
    )(tile_map, data, cols, rows, x)


# ---------------------------------------------------------------------------
# Lowering a CompositePlan -> FusedSpmv
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FusedSpmv:
    """A composite plan lowered to one launch-ready fused stream."""

    data: jax.Array  # (n_tiles * tile,)
    cols: jax.Array  # (n_tiles * tile,) int32
    rows: jax.Array  # (n_tiles * tile,) int32, == n_rows on padding
    tile_map: jax.Array  # (n_tiles,) int32 work descriptor
    block_of_tile: tuple[int, ...]  # owning block index per work item
    formats: tuple[str, ...]  # per-block formats the streams were lowered from
    n_rows: int
    tile: int
    unroll: int
    accum_dtype: str
    interpret: bool = True

    @property
    def n_tiles(self) -> int:
        return int(self.tile_map.shape[0])

    def _jitted(self):
        # one launch -> one executable: the whole composite is a single
        # pallas_call, so the traced computation is cached per FusedSpmv and
        # repeat calls skip retracing entirely (the per-call fixed cost the
        # sequential per-block dispatch keeps paying k times)
        fn = self.__dict__.get("_jit_call")
        if fn is None:
            fn = jax.jit(
                functools.partial(
                    fused_spmv_pallas,
                    n_rows=self.n_rows,
                    tile=self.tile,
                    unroll=self.unroll,
                    accum_dtype=self.accum_dtype,
                    interpret=self.interpret,
                )
            )
            object.__setattr__(self, "_jit_call", fn)
        return fn

    def __call__(self, x: jax.Array) -> jax.Array:
        y = self._jitted()(
            self.data, self.cols, self.rows, self.tile_map, jnp.asarray(x)
        )
        return y[: self.n_rows]


def fused_schedule_params(schedules: list[KernelSchedule], tile: int) -> tuple[int, str]:
    """(unroll, accum_dtype) for the fused stream: the most conservative of
    the per-block schedules — smallest unroll that divides the tile, and
    float32 accumulation unless EVERY block asked for bfloat16."""
    unroll = min((s.unroll for s in schedules), default=1)
    while tile % unroll:
        unroll //= 2
    accum = (
        "bfloat16"
        if schedules and all(s.accum_dtype == "bfloat16" for s in schedules)
        else "float32"
    )
    return max(unroll, 1), accum


def lower_fused(dense: np.ndarray, plan, *, interpret: bool = True) -> FusedSpmv:
    """Lower every block of a ``CompositePlan`` into one fused stream.

    Each block's dense rows are prepared in the block's chosen format (the
    same conversion the sequential executor performs), flattened with
    ``flatten_block``, padded to the common tile quantum (value 0 / col 0 /
    row ``n_rows`` spill entries), and concatenated. The work descriptor is
    built from the prefix sums of the per-block tile counts.
    """
    from repro.kernels.ops import prepare  # lazy: ops imports this module

    dense = np.asarray(dense)
    n_rows = plan.partition.n_rows
    streams = []
    for bp in plan.blocks:
        block = dense[bp.block.row_start : bp.block.row_end]
        mat = prepare(block, bp.fmt, bp.schedule)
        streams.append(flatten_block(mat, bp.block.row_start))

    total = sum(d.size for d, _, _ in streams)
    tile = fused_nnz_tile(max(total, 1))
    val_dtype = streams[0][0].dtype if streams else np.float32

    datas, colss, rowss = [], [], []
    block_tiles: list[int] = []
    for d, c, r in streams:
        padded = ceil_to(d.size, tile)  # empty block -> zero tiles
        datas.append(np.pad(d, (0, padded - d.size)))
        colss.append(np.pad(c, (0, padded - c.size)))
        rowss.append(np.pad(r, (0, padded - r.size), constant_values=n_rows))
        block_tiles.append(padded // tile)
    if sum(block_tiles) == 0:  # fully empty matrix: one all-spill tile
        datas.append(np.zeros(tile, dtype=val_dtype))
        colss.append(np.zeros(tile, dtype=np.int32))
        rowss.append(np.full(tile, n_rows, dtype=np.int32))
        block_tiles[0] = 1

    # prefix-sum work descriptor: program id -> (block, tile) work item,
    # laid out as the flat tile index block_offset[b] + local tile
    offsets = np.concatenate([[0], np.cumsum(block_tiles)]).astype(np.int32)
    tile_map = np.concatenate(
        [offsets[b] + np.arange(k, dtype=np.int32) for b, k in enumerate(block_tiles)]
    )
    block_of_tile = tuple(
        int(b) for b, k in enumerate(block_tiles) for _ in range(k)
    )

    unroll, accum = fused_schedule_params([bp.schedule for bp in plan.blocks], tile)
    return FusedSpmv(
        data=jnp.asarray(np.concatenate(datas)),
        cols=jnp.asarray(np.concatenate(colss).astype(np.int32)),
        rows=jnp.asarray(np.concatenate(rowss).astype(np.int32)),
        tile_map=jnp.asarray(tile_map),
        block_of_tile=block_of_tile,
        formats=tuple(bp.fmt for bp in plan.blocks),
        n_rows=n_rows,
        tile=tile,
        unroll=unroll,
        accum_dtype=accum,
        interpret=interpret,
    )
