"""Public SpMV kernel API: schedule-aware preparation + dispatch.

``prepare`` converts a dense matrix into the requested format with storage
geometry matched to a ``KernelSchedule`` (the compile-time parameters the
Auto-SpMV predictor emits), and ``spmv_pallas`` runs the matching Pallas
kernel. Both are thin lookups into the pluggable format registry
(``repro.sparse.registry``): the per-format conversion, alignment padding,
feasibility checks, and kernel binding live on each ``FormatSpec``, so a
format registered at runtime is served here with no code change.

The registry import is deliberately lazy (inside the functions): this module
is imported by ``repro.kernels.__init__``, which the sparse substrate itself
imports for the tiling constants — a module-level registry import would
close that cycle during package initialization.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Hashable

import jax
import numpy as np

from repro.kernels.common import (
    DEFAULT_SCHEDULE,
    InfeasibleConfig,  # noqa: F401  (canonical home moved to kernels.common)
    KernelSchedule,
)
from repro.obs.metrics import get_metrics
from repro.obs.trace import span as _span

# memo counters mirrored into the process metrics registry so the /metrics
# scrape sees kernel-compile economics without importing this module
_M_HITS = get_metrics().counter("spmv_kernel_memo_hits_total")
_M_COMPILES = get_metrics().counter("spmv_kernel_memo_compiles_total")
_M_EVICTIONS = get_metrics().counter("spmv_kernel_memo_evictions_total")


def __getattr__(name):
    if name == "MAX_STORAGE_BYTES":
        # deprecated alias: the live bound moved to the format registry;
        # resolve it there so the two names can never drift apart
        from repro.sparse.registry import MAX_STORAGE_BYTES

        return MAX_STORAGE_BYTES
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def prepare(
    dense: np.ndarray, fmt: str, schedule: KernelSchedule = DEFAULT_SCHEDULE
) -> Any:
    """Convert ``dense`` to ``fmt`` with schedule-aligned storage geometry."""
    from repro.sparse.registry import get_format

    return get_format(fmt).prepare(np.asarray(dense), schedule)


def spmv_pallas(
    mat: Any,
    x: jax.Array,
    schedule: KernelSchedule = DEFAULT_SCHEDULE,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Run the Pallas SpMV kernel matching ``type(mat)``; returns y: (n_rows,)."""
    from repro.sparse.registry import spec_for

    return spec_for(mat).spmv(mat, x, schedule, interpret=interpret)


def spmm_pallas(
    mat: Any,
    X: jax.Array,
    schedule: KernelSchedule = DEFAULT_SCHEDULE,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Multi-vector SpMV (ELL only — the MoE-dispatch shape)."""
    import jax.numpy as jnp

    from repro.kernels.ell import ell_spmm_pallas
    from repro.sparse.formats import ELL

    if not isinstance(mat, ELL):
        raise TypeError("spmm_pallas currently supports ELL")
    n_rows = mat.shape[0]
    return ell_spmm_pallas(mat.data, mat.cols, jnp.asarray(X), schedule, interpret=interpret)[
        :n_rows
    ]


def spmspv(
    mat,
    active: np.ndarray,
    xvals: np.ndarray,
    schedule: KernelSchedule = DEFAULT_SCHEDULE,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Sparse-input-vector SpMV over a ``CscEll`` container.

    ``active`` holds the frontier's column indices and ``xvals`` the
    corresponding x values; work scales with the frontier's column nnz,
    not nnz(A). See ``repro.kernels.spmspv`` for the kernel design."""
    from repro.kernels.spmspv import CscEll, csc_spmspv

    if not isinstance(mat, CscEll):
        raise TypeError("spmspv expects a CscEll container (see prepare_spmspv)")
    return csc_spmspv(mat, active, xvals, schedule, interpret=interpret)


@dataclass(frozen=True)
class PreparedSpmv:
    """A (format, schedule)-specialized SpMV — what compile-time mode emits."""

    mat: Any  # a registered format container (CSR / ELL / BELL / SELL / plugin)
    schedule: KernelSchedule
    interpret: bool = True

    def __call__(self, x: jax.Array) -> jax.Array:
        return spmv_pallas(self.mat, x, self.schedule, interpret=self.interpret)


def matrix_fingerprint(dense: np.ndarray) -> str:
    """Content hash of a dense-held matrix — the kernel-memo identity.

    Two matrices with equal bytes/shape/dtype share every prepared kernel;
    the session layer uses this to deduplicate batched tuning requests.
    """
    a = np.ascontiguousarray(np.asarray(dense))
    h = hashlib.sha256()
    h.update(str((a.shape, str(a.dtype))).encode())
    h.update(a.tobytes())
    return h.hexdigest()[:32]


# Process-wide LRU memo of prepared kernels, keyed by (caller key, fmt,
# schedule, interpret). Opt-in via ``compile_spmv(..., memo_key=...)`` so
# one-off callers don't pin large format storage. Bounded: each entry holds
# the full converted matrix storage, so an unbounded memo on a serving path
# streaming distinct matrices would grow RSS until OOM. Fused composite
# kernels share the memo with a "fused:<fmt>+<fmt>..." format tag and the
# composite-plan signature in the schedule slot (one entry per plan).
_KERNEL_MEMO: "OrderedDict[tuple, object]" = OrderedDict()
_MEMO_STATS = {"hits": 0, "compiles": 0, "evictions": 0}
_MEMO_LIMIT = 256


def kernel_memo_stats() -> dict[str, int]:
    """Copy of the process-wide memo counters (hits / compiles / evictions)."""
    return dict(_MEMO_STATS)


def kernel_memo_size() -> int:
    return len(_KERNEL_MEMO)


def kernel_memo_limit() -> int:
    return _MEMO_LIMIT


def set_kernel_memo_limit(limit: int) -> None:
    """Resize the LRU bound (evicts immediately if shrinking)."""
    global _MEMO_LIMIT
    if limit < 1:
        raise ValueError("kernel memo limit must be >= 1")
    _MEMO_LIMIT = limit
    while len(_KERNEL_MEMO) > _MEMO_LIMIT:
        _KERNEL_MEMO.popitem(last=False)
        _MEMO_STATS["evictions"] += 1
        _M_EVICTIONS.inc()


def kernel_memoized(
    memo_key: Hashable,
    fmt: str,
    schedule: KernelSchedule = DEFAULT_SCHEDULE,
    *,
    interpret: bool = True,
) -> bool:
    """Whether ``compile_spmv`` with these arguments would be a memo hit.

    Lets the session's amortized-overhead accounting charge the conversion
    term only when conversion will actually run."""
    return (memo_key, fmt, schedule, interpret) in _KERNEL_MEMO


def clear_kernel_memo() -> None:
    _KERNEL_MEMO.clear()


def _fused_tag_contains(tag, fmt: str) -> bool:
    """Whether a fused memo tag ("fused:ell+csr+...") involves ``fmt``."""
    return (
        isinstance(tag, str)
        and tag.startswith(_FUSED_TAG_PREFIX)
        and fmt in tag[len(_FUSED_TAG_PREFIX) :].split("+")
    )


def evict_kernel_memo_format(fmt: str) -> int:
    """Drop every memoized kernel of one format.

    Called by the registry when a format is unregistered or re-registered:
    a memoized ``PreparedSpmv`` must not outlive the ``FormatSpec`` that
    built it (its container would no longer resolve in ``spec_for``, or
    would silently run the old implementation). Fused composite kernels are
    evicted when ANY of their block formats matches — their flattened
    streams were lowered through the retiring ``FormatSpec``."""
    stale = [
        k for k in _KERNEL_MEMO if k[1] == fmt or _fused_tag_contains(k[1], fmt)
    ]
    for k in stale:
        del _KERNEL_MEMO[k]
        _MEMO_STATS["evictions"] += 1
        _M_EVICTIONS.inc()
    return len(stale)


def compile_spmv(
    dense: np.ndarray,
    fmt: str,
    schedule: KernelSchedule = DEFAULT_SCHEDULE,
    *,
    interpret: bool = True,
    memo_key: Hashable | None = None,
) -> PreparedSpmv:
    """prepare + bind: the full compile-time-mode product.

    With ``memo_key`` (typically ``matrix_fingerprint(dense)``) the prepared
    kernel is memoized process-wide: repeated compilation requests for the
    same (matrix, format, schedule) return the existing ``PreparedSpmv``
    without re-running conversion — the ``c`` term of the §5.3 overhead
    model is paid once per unique matrix (until LRU eviction)."""
    if memo_key is not None:
        key = (memo_key, fmt, schedule, interpret)
        hit = _KERNEL_MEMO.get(key)
        if hit is not None:
            _MEMO_STATS["hits"] += 1
            _M_HITS.inc()
            _KERNEL_MEMO.move_to_end(key)
            return hit
    with _span("kernel.compile", fmt=fmt):
        prepared = PreparedSpmv(prepare(dense, fmt, schedule), schedule, interpret)
    if memo_key is not None:
        # counters cover memoized traffic only, so hits/(hits+compiles) is a
        # true memo hit rate (plain one-off compiles don't skew it)
        _MEMO_STATS["compiles"] += 1
        _M_COMPILES.inc()
        _KERNEL_MEMO[key] = prepared
        while len(_KERNEL_MEMO) > _MEMO_LIMIT:
            _KERNEL_MEMO.popitem(last=False)
            _MEMO_STATS["evictions"] += 1
            _M_EVICTIONS.inc()
    return prepared


def compile_spmv_block(
    dense: np.ndarray,
    row_start: int,
    row_end: int,
    fmt: str,
    schedule: KernelSchedule = DEFAULT_SCHEDULE,
    *,
    interpret: bool = True,
    memo_key: Hashable | None = None,
) -> PreparedSpmv:
    """``compile_spmv`` for one row block of a larger matrix.

    The memo identity composes the caller's whole-matrix key with the row
    range, so a partitioned executor's per-block kernels are memoized (and
    LRU-evicted, and format-evicted) exactly like whole-matrix kernels —
    two composite plans over the same matrix share every block they agree
    on, without colliding with the monolithic kernel for the same matrix.
    """
    block = np.asarray(dense)[row_start:row_end]
    key = (memo_key, row_start, row_end) if memo_key is not None else None
    return compile_spmv(block, fmt, schedule, interpret=interpret, memo_key=key)


_FUSED_TAG_PREFIX = "fused:"


def fused_plan_signature(plan) -> tuple:
    """Hashable identity of a ``CompositePlan``'s executable content.

    Two plans lower to the same fused stream iff their (row range, format,
    schedule) tuples agree per block — the memo key component that makes
    "one kernel memo entry keyed on the composite plan" precise."""
    return tuple(
        (bp.block.row_start, bp.block.row_end, bp.fmt, bp.schedule)
        for bp in plan.blocks
    )


def compile_spmv_fused(
    dense: np.ndarray,
    plan,
    *,
    interpret: bool = True,
    memo_key: Hashable | None = None,
):
    """Lower a ``CompositePlan`` to its single-launch fused kernel.

    The whole composite memoizes as ONE entry: the format slot carries a
    ``fused:<fmt>+<fmt>...`` tag (so ``evict_kernel_memo_format`` retires it
    with any constituent format) and the schedule slot carries the plan
    signature. Returns a ``repro.kernels.fused.FusedSpmv``."""
    from repro.kernels.fused import lower_fused

    key = None
    if memo_key is not None:
        tag = _FUSED_TAG_PREFIX + "+".join(bp.fmt for bp in plan.blocks)
        key = (memo_key, tag, fused_plan_signature(plan), interpret)
        hit = _KERNEL_MEMO.get(key)
        if hit is not None:
            _MEMO_STATS["hits"] += 1
            _M_HITS.inc()
            _KERNEL_MEMO.move_to_end(key)
            return hit
    with _span("kernel.compile", fused=True, formats="+".join(bp.fmt for bp in plan.blocks)):
        kernel = lower_fused(dense, plan, interpret=interpret)
    if key is not None:
        _MEMO_STATS["compiles"] += 1
        _M_COMPILES.inc()
        _KERNEL_MEMO[key] = kernel
        while len(_KERNEL_MEMO) > _MEMO_LIMIT:
            _KERNEL_MEMO.popitem(last=False)
            _MEMO_STATS["evictions"] += 1
            _M_EVICTIONS.inc()
    return kernel


_SPMSPV_TAG = "spmspv"


@dataclass(frozen=True)
class PreparedSpmspv:
    """A schedule-specialized SpMSpV — the sparse-frontier twin of
    ``PreparedSpmv``.

    Holds the column-slice storage plus the host-side per-column nnz
    vector, so the adaptive policy can price a frontier
    (``modeled_work``) without touching device memory.
    """

    mat: Any  # repro.kernels.spmspv.CscEll
    schedule: KernelSchedule
    interpret: bool = True
    col_nnz: Any = None  # np.ndarray (n_cols,) int64

    def call_frontier(self, active: np.ndarray, xvals: np.ndarray) -> jax.Array:
        return spmspv(
            self.mat, active, xvals, self.schedule, interpret=self.interpret
        )

    def __call__(self, x: jax.Array) -> jax.Array:
        """Dense-in/dense-out convenience: extracts the frontier host-side."""
        xh = np.asarray(x)
        active = np.flatnonzero(xh).astype(np.int32)
        return self.call_frontier(active, xh[active])

    def modeled_work(self, active: np.ndarray) -> int:
        """Stored nonzeros this frontier touches — the SpMSpV cost model."""
        if self.col_nnz is None:
            return 0
        return int(np.asarray(self.col_nnz)[np.asarray(active, dtype=np.int64)].sum())


def compile_spmspv(
    dense: np.ndarray,
    schedule: KernelSchedule = DEFAULT_SCHEDULE,
    *,
    interpret: bool = True,
    memo_key: Hashable | None = None,
) -> PreparedSpmspv:
    """prepare + bind the sparse-input-vector path.

    Memoizes alongside the SpMV kernels with the ``"spmspv"`` tag in the
    format slot — one extra entry per (matrix, schedule), subject to the
    same LRU bound and counters, so an iterative solve that uses both
    paths pays each conversion once."""
    from repro.kernels.spmspv import col_nnz as _col_nnz
    from repro.kernels.spmspv import csc_from_dense

    if memo_key is not None:
        key = (memo_key, _SPMSPV_TAG, schedule, interpret)
        hit = _KERNEL_MEMO.get(key)
        if hit is not None:
            _MEMO_STATS["hits"] += 1
            _M_HITS.inc()
            _KERNEL_MEMO.move_to_end(key)
            return hit
    with _span("kernel.compile", fmt=_SPMSPV_TAG):
        prepared = PreparedSpmspv(
            csc_from_dense(dense, schedule),
            schedule,
            interpret,
            _col_nnz(dense),
        )
    if memo_key is not None:
        _MEMO_STATS["compiles"] += 1
        _M_COMPILES.inc()
        _KERNEL_MEMO[key] = prepared
        while len(_KERNEL_MEMO) > _MEMO_LIMIT:
            _KERNEL_MEMO.popitem(last=False)
            _MEMO_STATS["evictions"] += 1
            _M_EVICTIONS.inc()
    return prepared
