"""Public SpMV kernel API: schedule-aware preparation + dispatch.

``prepare`` converts a dense matrix into the requested format with storage
geometry matched to a ``KernelSchedule`` (the compile-time parameters the
Auto-SpMV predictor emits), and ``spmv_pallas`` runs the matching Pallas
kernel. Alignment padding lives here so the kernels stay tile-exact.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass
from typing import Hashable, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.bell import bell_spmv_pallas
from repro.kernels.common import (
    LANE,
    DEFAULT_SCHEDULE,
    KernelSchedule,
    ceil_to,
    pad_axis,
)
from repro.kernels.csr import csr_spmv_pallas
from repro.kernels.ell import ell_spmm_pallas, ell_spmv_pallas
from repro.kernels.sell import sell_spmv_pallas
from repro.sparse.formats import (
    BELL,
    CSR,
    ELL,
    SELL,
    bell_from_dense,
    csr_from_dense,
    ell_from_dense,
    sell_from_dense,
)


class InfeasibleConfig(ValueError):
    """Raised when a (format, schedule) pair cannot be materialized.

    The tuner's search space contains invalid points (exactly as on GPU,
    where e.g. a thread-block size can exceed resource limits); the dataset
    harness records them as failures rather than crashing.
    """


MAX_STORAGE_BYTES = 512 * 1024 * 1024  # refuse >512 MiB single-format storage


def _check_bytes(estimate: int, what: str) -> None:
    if estimate > MAX_STORAGE_BYTES:
        raise InfeasibleConfig(f"{what} storage would be {estimate/1e6:.0f} MB")


def prepare(
    dense: np.ndarray, fmt: str, schedule: KernelSchedule = DEFAULT_SCHEDULE
) -> Union[CSR, ELL, BELL, SELL]:
    """Convert ``dense`` to ``fmt`` with schedule-aligned storage geometry."""
    dense = np.asarray(dense)
    n_rows, n_cols = dense.shape
    rpb, nt = schedule.rows_per_block, schedule.nnz_tile
    if fmt == "csr":
        return csr_from_dense(dense)
    if fmt == "ell":
        counts_max = int((dense != 0).sum(axis=1).max(initial=0))
        width = ceil_to(max(counts_max, 1), nt)
        _check_bytes(ceil_to(n_rows, rpb) * width * 8, "ELL")
        mat = ell_from_dense(dense, min_width=width)
        data = pad_axis(np.asarray(mat.data), 0, ceil_to(n_rows, rpb))
        cols = pad_axis(np.asarray(mat.cols), 0, ceil_to(n_rows, rpb))
        return ELL(jnp.asarray(data), jnp.asarray(cols), shape=mat.shape)
    if fmt == "bell":
        br = min(rpb, 256)
        nbr = ceil_to(n_rows, br) // br
        # upper-bound occupancy estimate before materializing
        occ_bound = min((dense != 0).sum(), nbr * (ceil_to(n_cols, LANE) // LANE))
        _check_bytes(int(occ_bound) * br * LANE * 8 // max(nbr, 1) * nbr, "BELL")
        return bell_from_dense(dense, br=br, bc=LANE)
    if fmt == "sell":
        return sell_from_dense(dense, C=rpb, q=nt)
    raise ValueError(f"unknown format {fmt!r}")


def spmv_pallas(
    mat: Union[CSR, ELL, BELL, SELL],
    x: jax.Array,
    schedule: KernelSchedule = DEFAULT_SCHEDULE,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Run the Pallas SpMV kernel matching ``type(mat)``; returns y: (n_rows,)."""
    n_rows, n_cols = mat.shape
    x = jnp.asarray(x)
    rpb, nt = schedule.rows_per_block, schedule.nnz_tile

    if isinstance(mat, ELL):
        R, W = mat.data.shape
        if R % rpb or W % nt:
            raise InfeasibleConfig(
                f"ELL planes ({R},{W}) not aligned to schedule ({rpb},{nt}); "
                "use prepare() with the same schedule"
            )
        y = ell_spmv_pallas(mat.data, mat.cols, x, schedule, interpret=interpret)
        return y[:n_rows]

    if isinstance(mat, CSR):
        nnz = mat.data.shape[0]
        nnz_pad = ceil_to(max(nnz, 1), nt)
        data = pad_axis(np.asarray(mat.data), 0, nnz_pad)
        indices = pad_axis(np.asarray(mat.indices), 0, nnz_pad)
        row_ids = pad_axis(np.asarray(mat.row_ids), 0, nnz_pad, fill=n_rows)
        y = csr_spmv_pallas(
            jnp.asarray(data),
            jnp.asarray(indices),
            jnp.asarray(row_ids),
            x,
            n_rows,
            schedule,
            interpret=interpret,
        )
        return y[:n_rows]

    if isinstance(mat, BELL):
        xp = jnp.zeros(ceil_to(n_cols, mat.bc), x.dtype).at[:n_cols].set(x)
        x_panels = xp.reshape(-1, mat.bc)
        y = bell_spmv_pallas(mat.data, mat.block_cols, x_panels, schedule, interpret=interpret)
        return y.reshape(-1)[:n_rows]

    if isinstance(mat, SELL):
        C = mat.C
        blk = nt * C
        sp = np.asarray(mat.slice_ptr)
        sw = np.asarray(mat.slice_width)
        if mat.data.shape[0] % blk or (sp % blk).any() or (sw % nt).any():
            raise InfeasibleConfig(
                f"SELL storage quantum mismatch with nnz_tile={nt}; "
                "convert with prepare(..., schedule) so widths are nt-aligned"
            )
        width_tiles = (sw // nt).astype(np.int32)
        tile_ptr = (sp[:-1] // blk).astype(np.int32)
        y = sell_spmv_pallas(
            mat.data,
            mat.cols,
            jnp.asarray(tile_ptr),
            jnp.asarray(width_tiles),
            x,
            n_slices=mat.n_slices,
            C=C,
            max_width_tiles=int(width_tiles.max(initial=1)),
            schedule=schedule,
            interpret=interpret,
        )
        return y.reshape(-1)[:n_rows]

    raise TypeError(f"unsupported format {type(mat)}")


def spmm_pallas(
    mat: ELL,
    X: jax.Array,
    schedule: KernelSchedule = DEFAULT_SCHEDULE,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Multi-vector SpMV (ELL only — the MoE-dispatch shape)."""
    if not isinstance(mat, ELL):
        raise TypeError("spmm_pallas currently supports ELL")
    n_rows = mat.shape[0]
    return ell_spmm_pallas(mat.data, mat.cols, jnp.asarray(X), schedule, interpret=interpret)[
        :n_rows
    ]


@dataclass(frozen=True)
class PreparedSpmv:
    """A (format, schedule)-specialized SpMV — what compile-time mode emits."""

    mat: Union[CSR, ELL, BELL, SELL]
    schedule: KernelSchedule
    interpret: bool = True

    def __call__(self, x: jax.Array) -> jax.Array:
        return spmv_pallas(self.mat, x, self.schedule, interpret=self.interpret)


def matrix_fingerprint(dense: np.ndarray) -> str:
    """Content hash of a dense-held matrix — the kernel-memo identity.

    Two matrices with equal bytes/shape/dtype share every prepared kernel;
    the session layer uses this to deduplicate batched tuning requests.
    """
    a = np.ascontiguousarray(np.asarray(dense))
    h = hashlib.sha256()
    h.update(str((a.shape, str(a.dtype))).encode())
    h.update(a.tobytes())
    return h.hexdigest()[:32]


# Process-wide LRU memo of prepared kernels, keyed by (caller key, fmt,
# schedule, interpret). Opt-in via ``compile_spmv(..., memo_key=...)`` so
# one-off callers don't pin large format storage. Bounded: each entry holds
# the full converted matrix storage, so an unbounded memo on a serving path
# streaming distinct matrices would grow RSS until OOM.
_KERNEL_MEMO: "OrderedDict[tuple, PreparedSpmv]" = OrderedDict()
_MEMO_STATS = {"hits": 0, "compiles": 0, "evictions": 0}
_MEMO_LIMIT = 256


def kernel_memo_stats() -> dict[str, int]:
    """Copy of the process-wide memo counters (hits / compiles / evictions)."""
    return dict(_MEMO_STATS)


def kernel_memo_size() -> int:
    return len(_KERNEL_MEMO)


def kernel_memo_limit() -> int:
    return _MEMO_LIMIT


def set_kernel_memo_limit(limit: int) -> None:
    """Resize the LRU bound (evicts immediately if shrinking)."""
    global _MEMO_LIMIT
    if limit < 1:
        raise ValueError("kernel memo limit must be >= 1")
    _MEMO_LIMIT = limit
    while len(_KERNEL_MEMO) > _MEMO_LIMIT:
        _KERNEL_MEMO.popitem(last=False)
        _MEMO_STATS["evictions"] += 1


def kernel_memoized(
    memo_key: Hashable,
    fmt: str,
    schedule: KernelSchedule = DEFAULT_SCHEDULE,
    *,
    interpret: bool = True,
) -> bool:
    """Whether ``compile_spmv`` with these arguments would be a memo hit.

    Lets the session's amortized-overhead accounting charge the conversion
    term only when conversion will actually run."""
    return (memo_key, fmt, schedule, interpret) in _KERNEL_MEMO


def clear_kernel_memo() -> None:
    _KERNEL_MEMO.clear()


def compile_spmv(
    dense: np.ndarray,
    fmt: str,
    schedule: KernelSchedule = DEFAULT_SCHEDULE,
    *,
    interpret: bool = True,
    memo_key: Hashable | None = None,
) -> PreparedSpmv:
    """prepare + bind: the full compile-time-mode product.

    With ``memo_key`` (typically ``matrix_fingerprint(dense)``) the prepared
    kernel is memoized process-wide: repeated compilation requests for the
    same (matrix, format, schedule) return the existing ``PreparedSpmv``
    without re-running conversion — the ``c`` term of the §5.3 overhead
    model is paid once per unique matrix (until LRU eviction)."""
    if memo_key is not None:
        key = (memo_key, fmt, schedule, interpret)
        hit = _KERNEL_MEMO.get(key)
        if hit is not None:
            _MEMO_STATS["hits"] += 1
            _KERNEL_MEMO.move_to_end(key)
            return hit
    prepared = PreparedSpmv(prepare(dense, fmt, schedule), schedule, interpret)
    if memo_key is not None:
        # counters cover memoized traffic only, so hits/(hits+compiles) is a
        # true memo hit rate (plain one-off compiles don't skew it)
        _MEMO_STATS["compiles"] += 1
        _KERNEL_MEMO[key] = prepared
        while len(_KERNEL_MEMO) > _MEMO_LIMIT:
            _KERNEL_MEMO.popitem(last=False)
            _MEMO_STATS["evictions"] += 1
    return prepared
