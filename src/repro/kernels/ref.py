"""Pure-jnp oracles for the Pallas kernels.

The canonical per-format implementations live in ``repro.sparse.spmv``; this
module re-exports them under the kernels/ contract (every kernel has a
``ref`` counterpart checked by ``assert_allclose`` in tests) and adds the
dense ground truth.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.sparse.spmv import (  # noqa: F401  (re-exported oracles)
    spmm_ell,
    spmv,
    spmv_bell,
    spmv_csr,
    spmv_ell,
    spmv_sell,
)


def spmv_dense(dense: np.ndarray, x) -> jnp.ndarray:
    """Ground truth: dense matvec."""
    return jnp.asarray(np.asarray(dense)) @ jnp.asarray(x)


def spmm_dense(dense: np.ndarray, X) -> jnp.ndarray:
    return jnp.asarray(np.asarray(dense)) @ jnp.asarray(X)
