"""Pallas TPU kernel: SELL (sliced-ELL) SpMV over true ragged storage.

Slices are stored column-major (formats.py), so width-tile ``j`` of slice
``s`` is the contiguous chunk ``[slice_ptr[s] + j*nnz_tile*C, +nnz_tile*C)``
— addressable by a flat BlockSpec whose index is computed from the
scalar-prefetched slice pointers. Raggedness is handled two ways at once:

* the *data movement* of out-of-range tiles is aliased to the slice's last
  valid tile (already VMEM-resident, so the re-DMA is free), and
* the *compute* of out-of-range tiles is masked off with ``pl.when``.

This is the SELL-C-sigma -> TPU adaptation: storage stays ragged (the whole
point of SELL), while every DMA stays tile-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import CompilerParams, KernelSchedule


def _sell_kernel(
    tptr_ref, wt_ref, d_ref, c_ref, x_ref, y_ref, *, C: int, unroll: int, accum_dtype
):
    del tptr_ref  # consumed by the index maps
    s, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    @pl.when(j < wt_ref[s])
    def _compute():
        nt = d_ref.shape[0] // C
        d = d_ref[...].reshape(nt, C)
        c = c_ref[...].reshape(nt, C)
        xv = x_ref[...]
        step = nt // unroll
        acc = jnp.zeros((C,), accum_dtype)
        for k in range(unroll):
            sl = slice(k * step, (k + 1) * step)
            dk = d[sl].astype(accum_dtype)
            xk = jnp.take(xv, c[sl], axis=0).astype(accum_dtype)
            acc = acc + jnp.sum(dk * xk, axis=0)
        y_ref[...] += acc.reshape(y_ref.shape).astype(y_ref.dtype)


def sell_spmv_pallas(
    data: jax.Array,
    cols: jax.Array,
    tile_ptr: jax.Array,
    width_tiles: jax.Array,
    x: jax.Array,
    n_slices: int,
    C: int,
    max_width_tiles: int,
    schedule: KernelSchedule,
    *,
    interpret: bool = True,
) -> jax.Array:
    """SpMV over flat SELL storage.

    ``data/cols: (total,)`` column-major ragged slices; ``tile_ptr[s]`` =
    ``slice_ptr[s] / (nnz_tile*C)`` (must divide exactly — ops.py re-pads
    widths when the schedule's nnz_tile exceeds the storage quantum);
    ``width_tiles[s]`` = stored width of slice s in nnz_tile units. Returns
    ``y: (n_slices, C)``.
    """
    nt = schedule.nnz_tile
    blk = nt * C
    if data.shape[0] % blk:
        raise ValueError(f"SELL storage {data.shape[0]} not aligned to {blk}")
    grid = (n_slices, max_width_tiles)
    kernel = functools.partial(
        _sell_kernel, C=C, unroll=schedule.unroll, accum_dtype=schedule.jnp_accum_dtype
    )

    def _tile_idx(s, j, tptr, wt):
        return (tptr[s] + jnp.minimum(j, wt[s] - 1),)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk,), _tile_idx),
            pl.BlockSpec((blk,), _tile_idx),
            pl.BlockSpec(x.shape, lambda s, j, tptr, wt: (0,)),
        ],
        out_specs=pl.BlockSpec((1, C), lambda s, j, tptr, wt: (s, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_slices, C), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=(schedule.dimension_semantics, "arbitrary"),
        ),
        interpret=interpret,
        name="sell_spmv",
    )(tile_ptr, width_tiles, data, cols, x)
