"""Pallas TPU kernel: SpMSpV — sparse matrix x SPARSE vector.

Iterative graph workloads (BFS-like frontiers, power iteration from a seed
vertex, personalized PageRank pushes) multiply the same matrix by a vector
whose nonzero count starts tiny and densifies across iterations. A dense
SpMV touches every stored nonzero of A regardless; SpMSpV touches only the
columns the frontier activates (Li et al., "Adaptive SpMV/SpMSpV on GPUs
for Input Vectors of Varied Sparsity", arXiv:2006.16767). This module is
the TPU form of that kernel:

* **Storage** (``CscEll``): column-major ELL — per-column value/row-id
  slices padded to a lane-aligned width ``W`` (the transpose of the ELL
  layout in ``sparse/formats.py``). One extra all-padding column at index
  ``n_cols`` is the *spill column*: frontier padding entries point at it
  and contribute exact zeros.
* **Kernel**: the frontier's column indices (and their x values) ride
  scalar-prefetch SMEM; grid step ``(i, j)`` DMAs width-tile ``j`` of
  column ``active[i]`` via a BlockSpec index map driven by the prefetched
  indices, multiplies by the SMEM-resident ``x[active[i]]``, and
  scatter-adds by row id into the one VMEM-resident ``(n_rows + 1)``
  output vector (CSR-kernel spill-slot convention: padding row ids equal
  ``n_rows`` and land in the last slot, truncated by the wrapper).

Work is therefore proportional to ``sum(col_nnz[frontier])`` (padded to
tiles), not ``nnz(A)`` — the asymmetry the density-threshold policy in
``repro.solvers.adaptive`` trades on. The frontier length is padded to the
next power of two (min ``SUBLANE``), so a solve whose frontier grows from
1 to n retraces at most ``log2(n)`` distinct kernel shapes.
"""

from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (
    SUBLANE,
    CompilerParams,
    DEFAULT_SCHEDULE,
    InfeasibleConfig,
    KernelSchedule,
    ceil_to,
)


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class CscEll:
    """Column-major ELL: padded per-column slices, kernel-ready for SpMSpV.

    ``data[c]`` / ``rows[c]`` hold column ``c``'s nonzero values and row
    ids, zero-/spill-padded to the shared lane-aligned width. Row index
    ``n_rows`` is the spill row (padding slots); column index ``n_cols``
    is the spill column (frontier padding) — all-zero by construction.
    """

    data: jax.Array  # (n_cols + 1, W) values, 0 on padding slots
    rows: jax.Array  # (n_cols + 1, W) int32 row ids, n_rows on padding
    shape: tuple[int, int] = dataclasses.field(metadata=dict(static=True))

    @property
    def width(self) -> int:
        return int(self.data.shape[1])

    @property
    def nbytes(self) -> int:
        return int(
            self.data.size * self.data.dtype.itemsize
            + self.rows.size * self.rows.dtype.itemsize
        )


def csc_from_dense(
    dense: np.ndarray, schedule: KernelSchedule = DEFAULT_SCHEDULE, dtype=np.float32
) -> CscEll:
    """Build the padded column-slice storage from a dense matrix.

    The slice width is the max column nnz rounded up to the schedule's
    ``nnz_tile`` so every column is a whole number of kernel tiles. A
    matrix whose hub column approaches ``n_rows`` pads toward dense
    storage; that blow-up is rejected against the registry's storage
    bound exactly like an infeasible format conversion.
    """
    dense = np.asarray(dense)
    n_rows, n_cols = dense.shape
    col_t = dense.T  # (n_cols, n_rows): nonzeros below are column-major
    c_idx, r_idx = np.nonzero(col_t)
    counts = np.bincount(c_idx, minlength=n_cols)
    W = ceil_to(max(int(counts.max(initial=0)), 1), schedule.nnz_tile)
    from repro.sparse.registry import MAX_STORAGE_BYTES  # lazy: import cycle

    nbytes = (n_cols + 1) * W * (np.dtype(dtype).itemsize + 4)
    if nbytes > MAX_STORAGE_BYTES:
        raise InfeasibleConfig(
            f"CscEll storage {nbytes} B exceeds bound {MAX_STORAGE_BYTES} B "
            f"(width {W} over {n_cols} columns)"
        )
    data = np.zeros((n_cols + 1, W), dtype=dtype)
    rows = np.full((n_cols + 1, W), n_rows, dtype=np.int32)
    # position of each nonzero within its column
    pos = np.arange(c_idx.size) - np.repeat(
        np.concatenate([[0], np.cumsum(counts)[:-1]]), counts
    )
    data[c_idx, pos] = col_t[c_idx, r_idx]
    rows[c_idx, pos] = r_idx
    return CscEll(
        data=jnp.asarray(data), rows=jnp.asarray(rows), shape=(n_rows, n_cols)
    )


def col_nnz(dense: np.ndarray) -> np.ndarray:
    """Per-column nonzero counts — the SpMSpV modeled-work vector."""
    return (np.asarray(dense) != 0).sum(axis=0).astype(np.int64)


def _frontier_pad(k: int) -> int:
    """Padded frontier length: next power of two, at least one sublane."""
    return max(SUBLANE, 1 << (max(k, 1) - 1).bit_length())


def _spmspv_kernel(act_ref, xv_ref, d_ref, r_ref, y_ref, *, unroll, accum_dtype):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    nt = d_ref.shape[1]
    step = nt // unroll
    xval = xv_ref[i].astype(accum_dtype)
    y = y_ref[...].astype(accum_dtype)
    for k in range(unroll):
        sl = slice(k * step, (k + 1) * step)
        y = y.at[r_ref[0, sl]].add(d_ref[0, sl].astype(accum_dtype) * xval)
    y_ref[...] = y.astype(y_ref.dtype)


def csc_spmspv_pallas(
    data: jax.Array,
    rows: jax.Array,
    active: jax.Array,
    xvals: jax.Array,
    n_rows: int,
    schedule: KernelSchedule,
    *,
    interpret: bool = True,
) -> jax.Array:
    """SpMSpV over padded column slices and a pre-padded frontier.

    ``data/rows: (n_cols + 1, W)`` with ``W % nnz_tile == 0``; ``active:
    (k_pad,)`` int32 column indices (padding entries == n_cols) and
    ``xvals: (k_pad,)`` their x values (padding entries == 0), both riding
    scalar-prefetch SMEM. Returns ``y: (n_rows + 1,)`` (last slot =
    padding spill, truncated by the wrapper).
    """
    W = data.shape[1]
    nt = schedule.nnz_tile
    if W % nt:
        raise InfeasibleConfig(
            f"CscEll width {W} not aligned to nnz_tile {nt}; re-prepare with "
            "this schedule"
        )
    grid = (int(active.shape[0]), W // nt)
    kernel = functools.partial(
        _spmspv_kernel, unroll=schedule.unroll, accum_dtype=schedule.jnp_accum_dtype
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, nt), lambda i, j, act, xv: (act[i], j)),
            pl.BlockSpec((1, nt), lambda i, j, act, xv: (act[i], j)),
        ],
        # whole output vector resident in VMEM across the sequential grid
        out_specs=pl.BlockSpec((n_rows + 1,), lambda i, j, act, xv: (0,)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rows + 1,), xvals.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary"),  # carried y
        ),
        interpret=interpret,
        name="csc_spmspv",
    )(active, xvals, data, rows)


def csc_spmspv(
    mat: CscEll,
    active: np.ndarray,
    xvals: np.ndarray,
    schedule: KernelSchedule = DEFAULT_SCHEDULE,
    *,
    interpret: bool = True,
) -> jax.Array:
    """Frontier-level wrapper: pads, dispatches, truncates the spill slot.

    ``active``/``xvals`` are the frontier's column indices and values at
    their true length ``k`` (any k from 0 to n_cols); an empty frontier
    short-circuits to zeros without a kernel launch.
    """
    n_rows, n_cols = mat.shape
    active = np.asarray(active, dtype=np.int32).reshape(-1)
    xvals = np.asarray(xvals, dtype=np.float32).reshape(-1)
    if active.shape != xvals.shape:
        raise ValueError(
            f"frontier mismatch: {active.shape[0]} indices, {xvals.shape[0]} values"
        )
    k = int(active.size)
    if k == 0:
        return jnp.zeros((n_rows,), dtype=jnp.float32)
    if active.min() < 0 or active.max() >= n_cols:
        raise ValueError("frontier indices out of range")
    k_pad = _frontier_pad(k)
    act = np.full(k_pad, n_cols, dtype=np.int32)  # spill column padding
    xv = np.zeros(k_pad, dtype=np.float32)
    act[:k], xv[:k] = active, xvals
    y = csc_spmspv_pallas(
        mat.data,
        mat.rows,
        jnp.asarray(act),
        jnp.asarray(xv),
        n_rows,
        schedule,
        interpret=interpret,
    )
    return y[:n_rows]
