import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x workload shape x mesh) cell:

1. **Proof + memory pass** — lower the full-depth (scan-over-layers) step
   with explicit in/out shardings, ``.lower().compile()``, print
   ``memory_analysis()`` / ``cost_analysis()``. A failure here (sharding
   mismatch, OOM at compile, unsupported collective) is a bug in the
   framework, not in the cell.
2. **Cost pass** — XLA's cost analysis counts loop bodies once, so exact
   FLOP/byte/collective numbers come from two *unrolled* lowerings at 1 and
   2 pattern repetitions, extrapolated linearly to the full depth (exact:
   step cost is affine in depth).
3. **Roofline terms** — compute / memory / collective seconds per §Roofline
   (TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI), dominant
   term, MODEL_FLOPS/HLO_FLOPs utilization ratio.

Artifacts land in ``artifacts/dryrun/<arch>__<shape>__<mesh>.json`` and feed
EXPERIMENTS.md §Dry-run / §Roofline and benchmarks/roofline.py.

NOTE: the XLA_FLAGS line above MUST run before any jax import — jax locks
the device count on first init. Do not set this flag globally.
"""

import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCH_IDS, SHAPES, applicable, get_config
from repro.configs.base import ModelConfig
from repro.dist.sharding import batch_sharding, build_sharding
from repro.launch.hlo_analysis import parse_collectives, summarize_collectives
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import abstract_opt_state, make_step_fn, model_flops
from repro.models import cache_specs, model_specs
from repro.utils.logging import get_logger

log = get_logger("dryrun")

# assignment §Roofline hardware constants (TPU v5e)
PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


def _shardings_for(cfg: ModelConfig, shape, mesh, args, rules=None):
    """in/out shardings matching make_step_fn's argument pytree."""
    pspecs = model_specs(cfg)
    param_sh = build_sharding(mesh, pspecs, rules)
    if shape.kind == "train":
        from repro.models.param import is_spec

        mv_sh = jax.tree.map(lambda s: s, param_sh)
        opt_sh = {
            "m": mv_sh,
            "v": jax.tree.map(lambda s: s, param_sh),
            "step": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        }
        batch_sh = batch_sharding(mesh, args[2])
        return (param_sh, opt_sh, batch_sh), (param_sh, opt_sh, None)
    cache_sh = build_sharding(mesh, cache_specs(cfg, shape.global_batch, shape.seq_len), rules)
    if shape.kind == "prefill":
        in_sh = (param_sh, cache_sh, batch_sharding(mesh, args[2]))
        return in_sh, (None, cache_sh)
    # decode
    tok_sh = batch_sharding(mesh, {"t": args[2]})["t"]
    pos_sh = batch_sharding(mesh, {"p": args[3]})["p"]
    return (param_sh, cache_sh, tok_sh, pos_sh), (None, cache_sh)


def _depth_config(cfg: ModelConfig, reps: int) -> ModelConfig:
    n = len(cfg.first_blocks) + len(cfg.pattern) * reps + len(cfg.tail_blocks)
    return cfg.replace(n_layers=n)


def _lower_compile(cfg, shape, mesh, *, unroll: bool, rules=None):
    from repro.dist.partition import sharding_context

    step, args = make_step_fn(cfg, shape, unroll=unroll)
    in_sh, out_sh = _shardings_for(cfg, shape, mesh, args, rules)
    t0 = time.time()
    with mesh, sharding_context(mesh, rules):
        lowered = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh).lower(*args)
        compiled = lowered.compile()
    return compiled, time.time() - t0


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             dispatch_format: str | None = None, tag: str = "",
             rules_name: str = "train") -> dict:
    cfg = get_config(arch)
    if dispatch_format and cfg.n_experts:
        cfg = cfg.replace(dispatch_format=dispatch_format)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")
    ok, reason = applicable(cfg, shape_name)
    artifact: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": [2, 16, 16] if multi_pod else [16, 16],
        "n_chips": 512 if multi_pod else 256,
        "tag": tag,
    }
    out_path = out_dir / f"{cell}.json"
    if not ok:
        artifact["skipped"] = reason
        out_path.write_text(json.dumps(artifact, indent=1))
        log.info("SKIP %s: %s", cell, reason)
        return artifact

    from repro.dist.sharding import RULE_SETS

    rules = RULE_SETS[rules_name]
    artifact["rules"] = rules_name
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = artifact["n_chips"]

    # ---- 1) full-depth proof + memory pass --------------------------------
    compiled, dt = _lower_compile(cfg, shape, mesh, unroll=False, rules=rules)
    ma = compiled.memory_analysis()
    print(f"[{cell}] memory_analysis:", ma)
    ca_raw = compiled.cost_analysis()
    print(f"[{cell}] cost_analysis (scan-rolled, loop bodies counted once):",
          {k: ca_raw.get(k) for k in ("flops", "bytes accessed")})
    artifact["compile_s_full"] = round(dt, 2)
    artifact["memory"] = {
        "argument_bytes_per_device": int(ma.argument_size_in_bytes),
        "output_bytes_per_device": int(ma.output_size_in_bytes),
        "temp_bytes_per_device": int(ma.temp_size_in_bytes),
        "code_bytes": int(ma.generated_code_size_in_bytes),
    }
    artifact["hbm_per_device_gb"] = round(
        (ma.argument_size_in_bytes + ma.output_size_in_bytes + ma.temp_size_in_bytes)
        / 2**30,
        3,
    )

    # ---- 2) cost pass: unrolled depth-1 / depth-2, linear extrapolation ----
    costs = {}
    for reps in (1, 2):
        cfg_g = _depth_config(cfg, reps)
        comp_g, dt_g = _lower_compile(cfg_g, shape, mesh, unroll=True, rules=rules)
        ca = comp_g.cost_analysis()
        coll = summarize_collectives(parse_collectives(comp_g.as_text()))
        costs[reps] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll_operand": float(coll["operand_bytes"]),
            "coll_ring": float(coll["ring_link_bytes"]),
            "coll_by_kind": coll["by_kind"],
            "compile_s": round(dt_g, 2),
        }
    G = cfg.n_groups
    def extrap(key):
        c1, c2 = costs[1][key], costs[2][key]
        return c1 + (G - 1) * (c2 - c1)

    flops_dev = extrap("flops")
    bytes_dev = extrap("bytes")
    coll_operand_dev = extrap("coll_operand")
    coll_ring_dev = extrap("coll_ring")
    artifact["cost_pass"] = {
        "per_rep": {str(k): {kk: vv for kk, vv in v.items() if kk != "coll_by_kind"}
                    for k, v in costs.items()},
        "collectives_by_kind_rep2": {
            k: {"count": v["count"], "operand_bytes": int(v["operand_bytes"])}
            for k, v in costs[2]["coll_by_kind"].items()
        },
        "extrapolated_per_device": {
            "flops": flops_dev,
            "bytes": bytes_dev,
            "collective_operand_bytes": coll_operand_dev,
            "collective_ring_link_bytes": coll_ring_dev,
        },
    }

    # ---- 3) roofline terms -------------------------------------------------
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_operand_dev / ICI_BW  # assignment formula
    collective_ring_s = coll_ring_dev / ICI_BW  # ring-schedule refinement
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_flops_global = flops_dev * n_chips
    artifact["roofline"] = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "collective_ring_s": collective_ring_s,
        "dominant": dominant,
        "model_flops_global": mf,
        "hlo_flops_global": hlo_flops_global,
        "useful_flops_ratio": mf / hlo_flops_global if hlo_flops_global else 0.0,
        "step_time_lower_bound_s": max(terms.values()),
        "roofline_fraction": (mf / n_chips / PEAK_FLOPS) / max(max(terms.values()), 1e-30),
    }
    out_path.write_text(json.dumps(artifact, indent=1))
    log.info(
        "%s: dominant=%s compute=%.3gs memory=%.3gs coll=%.3gs useful=%.2f%% roofline=%.1f%%",
        cell, dominant, compute_s, memory_s, collective_s,
        100 * artifact["roofline"]["useful_flops_ratio"],
        100 * artifact["roofline"]["roofline_fraction"],
    )
    return artifact


def _iter_cells(archs, shapes, meshes):
    for arch in archs:
        for shape in shapes:
            for mesh in meshes:
                yield arch, shape, mesh


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod1", "pod2", "both"], default="both")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--all", action="store_true", help="run every cell in subprocesses")
    ap.add_argument("--dispatch-format", default=None, help="MoE dispatch override")
    ap.add_argument("--tag", default="", help="artifact suffix for perf experiments")
    ap.add_argument("--rules", default="train", choices=["train", "serve", "train_sp"],
                    help="sharding rule set (serve = TP-only weights)")
    args = ap.parse_args(argv)
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    meshes = {"pod1": [False], "pod2": [True], "both": [False, True]}[args.mesh]
    if args.all:
        archs = [args.arch] if args.arch else list(ARCH_IDS)
        shapes = [args.shape] if args.shape else list(SHAPES)
        failures = []
        for arch, shape, mp in _iter_cells(archs, shapes, meshes):
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch, "--shape", shape,
                "--mesh", "pod2" if mp else "pod1", "--out", str(out_dir),
            ]
            if args.dispatch_format:
                cmd += ["--dispatch-format", args.dispatch_format]
            if args.tag:
                cmd += ["--tag", args.tag]
            r = subprocess.run(cmd, capture_output=True, text=True)
            if r.returncode != 0:
                failures.append((arch, shape, mp))
                log.error("FAILED %s %s %s:\n%s", arch, shape, mp, r.stderr[-2000:])
            else:
                log.info("ok %s %s %s", arch, shape, "pod2" if mp else "pod1")
        if failures:
            log.error("%d cells failed: %s", len(failures), failures)
            sys.exit(1)
        log.info("all cells passed")
        return

    assert args.arch and args.shape, "--arch and --shape required (or --all)"
    for mp in meshes:
        try:
            run_cell(args.arch, args.shape, mp, out_dir,
                     dispatch_format=args.dispatch_format, tag=args.tag,
                     rules_name=args.rules)
        except Exception:
            traceback.print_exc()
            sys.exit(1)


if __name__ == "__main__":
    main()
