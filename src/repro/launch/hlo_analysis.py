"""Post-SPMD HLO analysis: collective-traffic extraction.

``cost_analysis()`` has no collective accounting, so the dry-run parses the
compiled per-device HLO text and sums operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute (assignment
formula), plus a ring-model estimate of actual per-device link bytes.
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")

# `%name = bf16[8,128]{1,0} all-gather(...)` — result type then op
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^\s]*\s*(?:,\s*[a-z0-9]+\[[^\]]*\][^\s]*\s*)*(?:\))?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)(?:-start)?\("
)
_GROUPS_LITERAL_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


@dataclass
class CollectiveOp:
    kind: str
    dtype: str
    result_bytes: int
    operand_bytes: int
    group_size: int

    @property
    def ring_link_bytes(self) -> float:
        """Per-device bytes on the busiest link under a ring schedule."""
        g, n = self.group_size, self.operand_bytes
        if g <= 1:
            return 0.0
        if self.kind == "all-reduce":
            return 2.0 * n * (g - 1) / g
        if self.kind == "all-gather":
            return float(n) * (g - 1)
        if self.kind == "reduce-scatter":
            return n * (g - 1) / g
        if self.kind == "all-to-all":
            return n * (g - 1) / g
        return float(n)  # collective-permute


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> list[CollectiveOp]:
    ops: list[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        result_bytes = _shape_bytes(dtype, dims)
        g = 1
        lit = _GROUPS_LITERAL_RE.search(line)
        if lit:
            g = len([x for x in lit.group(1).split(",") if x.strip()])
        else:
            iota = _GROUPS_IOTA_RE.search(line)
            if iota:
                g = int(iota.group(2))
        if kind == "all-gather":
            operand = result_bytes // max(g, 1)
        elif kind == "reduce-scatter":
            operand = result_bytes * g
        else:
            operand = result_bytes
        ops.append(CollectiveOp(kind, dtype, result_bytes, operand, g))
    return ops


def summarize_collectives(ops: list[CollectiveOp]) -> dict:
    by_kind: dict[str, dict] = defaultdict(lambda: {"count": 0, "operand_bytes": 0, "ring_link_bytes": 0.0})
    for op in ops:
        s = by_kind[op.kind]
        s["count"] += 1
        s["operand_bytes"] += op.operand_bytes
        s["ring_link_bytes"] += op.ring_link_bytes
    total_operand = sum(s["operand_bytes"] for s in by_kind.values())
    total_ring = sum(s["ring_link_bytes"] for s in by_kind.values())
    return {
        "by_kind": dict(by_kind),
        "operand_bytes": total_operand,
        "ring_link_bytes": total_ring,
        "n_ops": sum(s["count"] for s in by_kind.values()),
    }
