"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. The dry-run driver forces 512 placeholder host
devices before any jax import; everything else sees the real device count.

Recommended XLA flags for real TPU fleets (documented here, applied by the
launch CLIs via REPRO_XLA_PERF_FLAGS=1):

  --xla_tpu_enable_latency_hiding_scheduler=true   overlap collectives with
                                                   compute (DESIGN.md §6)
  --xla_tpu_enable_async_collective_fusion=true
  --xla_tpu_overlap_compute_collective_tc=true
"""

from __future__ import annotations

import jax

PERF_XLA_FLAGS = (
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_overlap_compute_collective_tc=true"
)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np

    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)}; the dry-run "
            "driver must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import"
        )
    dev_grid = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev_grid, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh on the real local device (examples / tests)."""
    import numpy as np

    return jax.sharding.Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
