"""Serving launcher: ``python -m repro.launch.serve --arch <id>``.

Runs the batched continuous-batching-lite server on synthetic requests with
a reduced config (CPU container); the production path is exercised through
the decode/prefill dry-run cells.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import init_params, model_specs
from repro.train.serve import BatchedServer, Request, ServeConfig
from repro.utils.logging import get_logger

log = get_logger("launch.serve")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=sorted(ARCH_IDS))
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced_config=True)
    if cfg.prefix_len:
        cfg = cfg.replace(prefix_len=0, prefix_lm=False)  # text-only serving demo
    params = init_params(model_specs(cfg), jax.random.PRNGKey(args.seed), cfg.param_dtype)
    server = BatchedServer(
        params, cfg,
        ServeConfig(batch_slots=args.slots, max_len=args.max_len,
                    max_new_tokens=args.max_new_tokens),
    )
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 17))).tolist(),
            max_new_tokens=args.max_new_tokens,
        )
        for i in range(args.requests)
    ]
    done = server.run(reqs)
    for r in done:
        log.info("req %d: prompt %d toks -> %s", r.rid, len(r.prompt), r.generated)
    tput = sum(len(r.generated) for r in done) / max(done[0].latency_s, 1e-9)
    log.info("aggregate throughput: %.1f tok/s over %d requests", tput, len(done))
    return done


if __name__ == "__main__":
    main()
