"""Serving launcher: ``python -m repro.launch.serve --arch <id>`` (LM mode)
or ``python -m repro.launch.serve --spmv [--spmv-cache tuning.json]`` (SpMV
mode).

LM mode runs the batched continuous-batching-lite server on synthetic
requests with a reduced config (CPU container); the production path is
exercised through the decode/prefill dry-run cells.

SpMV mode runs the multi-matrix Auto-SpMV pipeline: synthetic traffic drawn
from the paper's matrix suite (with repeats, as real solver fleets resubmit
the same systems) flows through an ``AutoSpmvSession``-backed ``SpmvServer``.
With ``--spmv-cache`` the tuning decisions persist to JSON, so a relaunched
server starts warm and skips the predictor inferences.

Telemetry flags (SpMV mode): ``--telemetry`` times every served kernel and
aggregates per-(bucket, format) measurement arms; ``--telemetry-log`` makes
the records a restart-surviving JSONL append-log; ``--adaptive`` layers the
UCB bandit + drift detector on top (implies ``--telemetry``) so mispredicted
cached plans are explored, detected, evicted, and relearned while serving.

Partition flags (SpMV mode): ``--partition`` serves composite plans over
nnz-balanced row blocks — each block independently routed through the
format registry/predictors (``--max-blocks`` bounds the searched block
counts); with ``--adaptive`` every (block, format) pair becomes its own
bandit arm and drifted blocks are re-routed individually.

Active-observability flags: ``--slo-config`` attaches an ``SloTracker``
(burn-rate alerting + objective escalation; JSON overrides the per-class
targets) in both modes — in SpMV mode requests get SLO classes via
``--spmv-slo``; ``--anomaly`` runs the cost-model residual watchdog
(recalibrate + targeted eviction on sustained anomaly); ``--fleet-dir`` +
``--sync-every`` sync the bandit posterior with peer serve processes
through a shared shard directory (``obs/sync.py``), with a final sync at
shutdown.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core.session import AutoSpmvSession, build_tuner
from repro.models import init_params, model_specs
from repro.sparse.generate import MATRIX_NAMES, generate_by_name
from repro.sparse.registry import default_format, format_names
from repro.train.serve import (
    BatchedServer,
    Request,
    ServeConfig,
    SpmvRequest,
    SpmvServer,
)
from repro.utils.logging import get_logger

log = get_logger("launch.serve")


def _build_lm_engine(args, cfg, params):
    """Stand up the sparse serving stack: a cheap tuner + shared session +
    one ``SparseInferenceEngine`` holding the magnitude-pruned FFN weights.
    Returns (engine, pruned params)."""
    from repro.models.sparse_linear import SparseInferenceEngine, prune_model_ffns

    t0 = time.time()
    tuner = build_tuner(
        scale=0.0008, names=MATRIX_NAMES[:4], n_extra=0, fit_overhead=False
    )
    log.info("lm-sparse tuner ready in %.1fs", time.time() - t0)
    session = AutoSpmvSession(tuner)
    engine = SparseInferenceEngine(session)
    pruned = prune_model_ffns(params, cfg, engine, density=args.lm_density)
    log.info(
        "lm-sparse: %d FFN matrices registered (%d SpMV-eligible) at density %.3f",
        engine.stats.registered, engine.stats.spmv_layers, args.lm_density,
    )
    return engine, pruned


def serve_lm(args) -> list[Request]:
    from repro.models.sparse_linear import SLO_PRIORITY

    cfg = get_config(args.arch, reduced_config=True)
    if cfg.prefix_len:
        cfg = cfg.replace(prefix_len=0, prefix_lm=False)  # text-only serving demo
    engine = None
    if args.lm_sparse and cfg.n_experts and cfg.dispatch_format != "dense":
        # the engine's gate-masked per-expert path mirrors the dense
        # dispatch exactly; ell/sell drop capacity-overflow tokens
        cfg = cfg.replace(dispatch_format="dense")
    params = init_params(model_specs(cfg), jax.random.PRNGKey(args.seed), cfg.param_dtype)
    if args.lm_sparse:
        engine, params = _build_lm_engine(args, cfg, params)
    slo_tracker = None
    if args.slo_config:
        from repro.obs.slo import SloConfig, SloTracker

        slo_tracker = SloTracker(SloConfig.load(args.slo_config))
    server = BatchedServer(
        params, cfg,
        ServeConfig(batch_slots=args.slots, max_len=args.max_len,
                    max_new_tokens=args.max_new_tokens),
        engine=engine,
        slo=slo_tracker,
    )
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=int(rng.integers(4, 17))).tolist(),
            max_new_tokens=args.max_new_tokens,
            slo=SLO_PRIORITY[i % len(SLO_PRIORITY)] if args.slo == "mixed" else args.slo,
        )
        for i in range(args.requests)
    ]
    done = server.run(reqs)
    for r in done:
        log.info("req %d [%s]: prompt %d toks -> %s", r.rid, r.slo, len(r.prompt), r.generated)
    tput = sum(len(r.generated) for r in done) / max(done[0].latency_s, 1e-9)
    log.info("aggregate throughput: %.1f tok/s over %d requests", tput, len(done))
    summary = server.summary()
    log.info("server summary: %s", summary)
    if args.summary_export:
        import json

        from repro.utils.io import atomic_write_text

        atomic_write_text(
            args.summary_export, json.dumps(summary, indent=1, default=float)
        )
        log.info("summary -> %s", args.summary_export)
    return done


def serve_spmv(args) -> list[SpmvRequest]:
    if args.format_plugins:
        # plugin modules register extra sparse formats on import; they then
        # flow through the tuning space, bandit arms, and serving untouched
        import importlib

        for mod in args.format_plugins.split(","):
            importlib.import_module(mod.strip())
        log.info("format registry after plugins: %s", format_names())

    t0 = time.time()
    tuner = build_tuner(
        scale=args.spmv_scale, names=MATRIX_NAMES[: args.spmv_train_matrices]
    )
    log.info("tuner ready in %.1fs", time.time() - t0)

    # active-observability features imply their substrates: fleet sync needs
    # the bandit posterior, the anomaly watchdog needs calibration pairs
    want_adaptive = args.adaptive or args.fleet_dir is not None
    telemetry = adaptive = feedback = None
    if (
        args.telemetry
        or want_adaptive
        or args.telemetry_log
        or args.refit_every > 0
        or args.calibrate_every > 0
        or args.anomaly
    ):
        from repro.telemetry import (
            AdaptiveFormatSelector,
            FeedbackConfig,
            FeedbackLoop,
            TelemetryRecorder,
        )

        telemetry = TelemetryRecorder(log_path=args.telemetry_log)
        if telemetry.total_observations():
            log.info(
                "telemetry warm start: %s from %s",
                telemetry.summary(),
                args.telemetry_log,
            )
        if want_adaptive:
            adaptive = AdaptiveFormatSelector()
            seeded = adaptive.warm_start(telemetry)
            if seeded:
                log.info("bandit warm start: %d arms seeded from the log", seeded)
        if args.refit_every > 0:
            # base_dataset keeps the offline labels in every refit: a few
            # fleet measurements sharpen the classifier, never replace its
            # coverage of unmeasured feature regions
            feedback = FeedbackLoop(
                telemetry,
                base_dataset=tuner.dataset,
                config=FeedbackConfig(refit_every=args.refit_every),
            )

    session = AutoSpmvSession(
        tuner, cache_path=args.spmv_cache, telemetry=telemetry, adaptive=adaptive
    )
    if len(session.cache):
        log.info("warm start: %d cached plans from %s", len(session.cache), args.spmv_cache)

    spmv_slo = args.spmv_slo or ("mixed" if args.slo_config else None)
    slo_tracker = None
    if spmv_slo:
        from repro.obs.slo import SLO_CLASSES, SloConfig, SloTracker

        slo_cfg = SloConfig.load(args.slo_config) if args.slo_config else SloConfig()
        slo_tracker = SloTracker(slo_cfg)
        log.info(
            "slo tracking on %d class(es), windows %d/%d",
            len(slo_cfg.targets), slo_cfg.fast_window, slo_cfg.slow_window,
        )
    fleet = None
    if args.fleet_dir is not None:
        from repro.obs.sync import FleetSync

        fleet = FleetSync(
            session,
            args.fleet_dir,
            instance=args.obs_instance,
            sync_every=args.sync_every,
        )
        log.info(
            "fleet sync [%s]: shard %s, every %d request(s)",
            args.obs_instance, fleet.shard_path, args.sync_every,
        )
    server = SpmvServer(
        session,
        feedback=feedback,
        partition=args.partition,
        max_blocks=args.max_blocks,
        fused=args.fused,
        calibrate_every=args.calibrate_every,
        slo=slo_tracker,
        anomaly=args.anomaly,
        fleet=fleet,
    )
    if args.metrics_port is not None:
        server.start_metrics_server(args.metrics_port)
    if args.partition:
        log.info(
            "partitioned serving: composite plans up to %d nnz-balanced row "
            "blocks per matrix (monolithic fallback when partitioning loses)%s",
            args.max_blocks,
            ", fused single-launch executor" if args.fused else "",
        )

    # synthetic traffic: suite matrices with repeats (fleet-like resubmission)
    rng = np.random.default_rng(args.seed)
    pool = MATRIX_NAMES[: max(args.requests // 4, 2)]
    reqs = []
    for i in range(args.requests):
        dense = generate_by_name(str(rng.choice(pool)), scale=args.spmv_scale)
        x = rng.normal(size=dense.shape[1]).astype(np.float32)
        slo = None
        if spmv_slo is not None:
            slo = SLO_CLASSES[i % len(SLO_CLASSES)] if spmv_slo == "mixed" else spmv_slo
        reqs.append(
            SpmvRequest(rid=i, dense=dense, x=x, objective=args.objective, slo=slo)
        )
    if args.profile_dir:
        from repro.obs import profile_capture

        with profile_capture(args.profile_dir):
            done = server.run(reqs)
    else:
        done = server.run(reqs)

    for r in done:
        ref = r.dense @ r.x
        err = np.abs(r.y - ref).max() / (np.abs(ref).max() + 1e-9)
        log.info(
            "req %d: hit=%s fmt=%s%s rel.err=%.2e %s",
            r.rid,
            r.cache_hit,
            r.fmt or default_format(),
            " (explore)" if r.exploratory else "",
            err,
            r.schedule,
        )
    stats = session.stats
    log.info(
        "served %d requests with %d feature passes, %d plans, %d kernel compiles; cache %s",
        len(done),
        stats.feature_extractions,
        stats.plans_computed,
        stats.kernel_compiles,
        session.cache.stats(),
    )
    log.info("server summary: %s", server.summary())
    if telemetry is not None:
        telemetry.flush()
        if args.telemetry_log:
            log.info("telemetry log flushed to %s", args.telemetry_log)
    if fleet is not None:
        # shutdown flush: export the final local posterior and absorb
        # whatever the peers wrote since the last periodic sync
        log.info("final fleet sync: %s", fleet.sync())
    if args.spmv_cache:
        session.save()
        log.info("tuning cache saved to %s", args.spmv_cache)
    if args.metrics_export:
        from repro.obs import get_metrics

        get_metrics().write_shard(args.metrics_export, args.obs_instance)
        log.info("metrics shard -> %s", args.metrics_export)
    if args.trace_export:
        from repro.obs import get_tracer

        n = get_tracer().export_jsonl(args.trace_export)
        log.info("trace shard -> %s (%d spans)", args.trace_export, n)
    if args.metrics_port is not None:
        server.stop_metrics_server()
    return done


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, choices=sorted(ARCH_IDS),
                    help="LM mode: model architecture to serve")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lm-sparse", action="store_true",
                    help="LM mode: magnitude-prune the FFN weights and route "
                         "their matmuls through session-planned SpMV kernels "
                         "(models/sparse_linear.py)")
    ap.add_argument("--lm-density", type=float, default=0.05,
                    help="with --lm-sparse: kept-weight fraction per FFN matrix")
    ap.add_argument("--slo", default="latency-critical",
                    choices=["latency-critical", "power-capped", "balanced",
                             "energy-saving", "mixed"],
                    help="LM mode: the SLO class stamped on every request "
                         "('mixed' cycles all four across the request stream)")
    ap.add_argument("--summary-export", default=None,
                    help="LM mode: write the server summary (SLO mix, engine "
                         "plans, energy cells) as JSON here")
    ap.add_argument("--spmv", action="store_true",
                    help="serve SpMV traffic through an AutoSpmvSession")
    ap.add_argument("--spmv-cache", default=None,
                    help="JSON path for the persistent tuning cache")
    ap.add_argument("--spmv-scale", type=float, default=0.0015)
    ap.add_argument("--spmv-train-matrices", type=int, default=8)
    ap.add_argument("--format-plugins", default=None,
                    help="comma-separated modules registering extra sparse "
                         "formats (e.g. repro.sparse.bcsr)")
    ap.add_argument("--partition", action="store_true",
                    help="partitioned SpMV serving: per-matrix composite "
                         "plans over nnz-balanced row blocks, each block "
                         "with its own format/schedule")
    ap.add_argument("--max-blocks", type=int, default=8,
                    help="block-count budget for --partition (searched over "
                         "{1, 2, 4, 8} up to this bound; 1 = monolithic)")
    ap.add_argument("--fused", action="store_true",
                    help="with --partition: run the composite plan as ONE "
                         "Pallas launch (merge-path work descriptor) instead "
                         "of per-block kernels; disables per-block bandit "
                         "timing")
    ap.add_argument("--calibrate-every", type=int, default=0,
                    help="refit the CalibratedCostModel from telemetry every "
                         "N served requests (0=off; needs --telemetry); the "
                         "fit persists next to --spmv-cache")
    ap.add_argument("--telemetry", action="store_true",
                    help="measure every served kernel and aggregate per-arm stats")
    ap.add_argument("--telemetry-log", default=None,
                    help="JSONL append-log path; replayed on restart "
                         "(implies --telemetry)")
    ap.add_argument("--adaptive", action="store_true",
                    help="UCB format bandit + drift-triggered cache invalidation "
                         "(implies --telemetry)")
    ap.add_argument("--refit-every", type=int, default=0,
                    help="refit the format classifier every N observations "
                         "(0=off; implies --telemetry)")
    ap.add_argument("--objective", default="latency",
                    choices=["latency", "energy", "power", "efficiency"])
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="SpMV mode: serve Prometheus /metrics (+ /healthz, "
                         "/obs) on this port from a daemon thread (0 = "
                         "ephemeral)")
    ap.add_argument("--metrics-export", default=None,
                    help="write the metrics registry as a JSONL shard here "
                         "after serving (obs/aggregate.py input)")
    ap.add_argument("--trace-export", default=None,
                    help="append the collected spans as a JSONL shard here "
                         "after serving (obs/aggregate.py input)")
    ap.add_argument("--obs-instance", default="serve",
                    help="instance label stamped into exported shards")
    ap.add_argument("--slo-config", default=None,
                    help="JSON overriding the per-class SLO targets; attaches "
                         "burn-rate alerting + objective escalation "
                         "(obs/slo.py) in either mode")
    ap.add_argument("--spmv-slo", default=None,
                    choices=["latency-critical", "power-capped", "balanced",
                             "energy-saving", "mixed"],
                    help="SpMV mode: SLO class stamped on requests ('mixed' "
                         "cycles all four); defaults to 'mixed' when "
                         "--slo-config is given")
    ap.add_argument("--anomaly", action="store_true",
                    help="SpMV mode: cost-model residual watchdog — on "
                         "sustained anomaly, drop the format's calibration "
                         "window, recalibrate, and evict its cached plans "
                         "(implies --telemetry)")
    ap.add_argument("--fleet-dir", default=None,
                    help="SpMV mode: shared directory of fleet shards; the "
                         "bandit posterior syncs with peer serve processes "
                         "through it (implies --adaptive)")
    ap.add_argument("--sync-every", type=int, default=8,
                    help="with --fleet-dir: sync after every N served "
                         "requests (plus a final sync at shutdown)")
    ap.add_argument("--profile-dir", default=None,
                    help="capture the serving run with jax.profiler into "
                         "this directory (Perfetto/TensorBoard viewable)")
    args = ap.parse_args(argv)

    if args.spmv:
        return serve_spmv(args)
    if args.arch is None:
        ap.error("--arch is required unless --spmv is given")
    return serve_lm(args)


if __name__ == "__main__":
    main()
