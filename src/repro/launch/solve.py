"""Iterative-solver launcher: ``python -m repro.launch.solve --solver
pagerank --matrix webgraph``.

The solve-side twin of ``launch.serve``: where serve streams single-shot
SpMV requests, this drives one *iterative solve* (PageRank / CG / power
iteration) through an ``AutoSpmvSession`` — one ``serve_optimize`` plan,
then every iteration replays the cached kernel with ``observe()``
feedback. With ``--adaptive-spmspv`` the per-iteration SpMV↔SpMSpV policy
is attached, backed by a UCB phase bandit
(``telemetry.adaptive.phase_arm_bucket``) that learns the density
crossover online.

``--matrix`` accepts a suite name (``repro.sparse.generate.SUITE``) or a
bare pattern name (``fem``, ``webgraph``, ...); suite names win. CG
symmetrizes the matrix into an SPD operator (``(A + Aᵀ)/2`` plus a
diagonal dominance margin) since CG's contract requires one.

Convergence metadata is always written as JSON (default
``artifacts/solve/SOLVE_<solver>_<matrix>.json``) so CI and fleets can
assert on the emitted artifact rather than parse logs.
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from repro.core.session import AutoSpmvSession, build_tuner
from repro.sparse.generate import (
    MATRIX_NAMES,
    PATTERN_NAMES,
    SUITE,
    generate_by_name,
    random_matrix,
)
from repro.utils.logging import get_logger

log = get_logger("launch.solve")

SOLVER_NAMES = ("pagerank", "cg", "power")


def resolve_matrix(name: str, scale: float, seed: int) -> np.ndarray:
    """Suite name or pattern name -> dense matrix (suite names win)."""
    if name in SUITE:
        return generate_by_name(name, scale=scale)
    if name in PATTERN_NAMES:
        n = max(int(200_000 * scale), 96)
        return random_matrix(n, avg_nnz=8.0, pattern=name, seed=seed)
    raise SystemExit(
        f"unknown matrix {name!r}: expected a suite name "
        f"({', '.join(MATRIX_NAMES[:4])}, ...) or a pattern "
        f"({', '.join(PATTERN_NAMES)})"
    )


def spd_operator(dense: np.ndarray) -> np.ndarray:
    """Symmetrize + diagonally dominate: the SPD system CG contracts for."""
    A = np.asarray(dense, dtype=np.float32)
    S = (A + A.T) / 2
    margin = float(np.abs(S).sum(axis=1).max()) + 1.0
    return (S + margin * np.eye(S.shape[0], dtype=np.float32)).astype(np.float32)


def run_solve(args):
    t0 = time.time()
    tuner = build_tuner(scale=args.scale, names=MATRIX_NAMES[: args.train_matrices])
    log.info("tuner ready in %.1fs", time.time() - t0)
    session = AutoSpmvSession(tuner, cache_path=args.cache)

    policy = None
    if args.adaptive_spmspv:
        from repro.solvers import AdaptiveSpmvPolicy
        from repro.telemetry import AdaptiveFormatSelector

        policy = AdaptiveSpmvPolicy(selector=AdaptiveFormatSelector())
        log.info(
            "adaptive SpMV<->SpMSpV routing: threshold prior %.0f%%, "
            "%d density phases under the UCB bandit",
            policy.threshold * 100,
            policy.n_phases,
        )

    dense = resolve_matrix(args.matrix, args.scale, args.seed)
    n = dense.shape[0]
    nnz = int((dense != 0).sum())
    log.info("matrix %s: n=%d nnz=%d", args.matrix, n, nnz)

    from repro.solvers import cg, pagerank, power_iteration

    if args.solver == "pagerank":
        result = pagerank(
            session,
            dense,
            damping=args.damping,
            tol=args.tol,
            max_iters=args.max_iters,
            policy=policy,
            objective=args.objective,
        )
    elif args.solver == "cg":
        rng = np.random.default_rng(args.seed)
        b = rng.standard_normal(n).astype(np.float32)
        result = cg(
            session,
            spd_operator(dense),
            b,
            tol=args.tol,
            max_iters=args.max_iters,
            policy=policy,
            objective=args.objective,
        )
    else:
        result = power_iteration(
            session,
            dense,
            tol=args.tol,
            max_iters=args.max_iters,
            policy=policy,
            objective=args.objective,
        )

    stats = session.stats
    log.info(
        "%s on %s: %d iters, converged=%s, residual=%.3g (p50 iter %.2f ms); "
        "%d plan(s) computed, %d kernel compiles, cache %s",
        args.solver,
        args.matrix,
        result.iterations,
        result.converged,
        result.residual,
        result.iter_p50_s() * 1e3,
        stats.plans_computed,
        stats.kernel_compiles,
        session.cache.stats(),
    )

    payload = {
        "matrix": args.matrix,
        "n": n,
        "nnz": nnz,
        "tol": args.tol,
        "max_iters": args.max_iters,
        "adaptive_spmspv": bool(args.adaptive_spmspv),
        **result.summary(),
        "session": {
            "plans_computed": stats.plans_computed,
            "kernel_compiles": stats.kernel_compiles,
            "cache_hits": stats.cache_hits,
            "observations": stats.observations,
        },
    }
    out = Path(
        args.json_out
        or f"artifacts/solve/SOLVE_{args.solver}_{args.matrix}.json"
    )
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(payload, indent=2, default=float) + "\n")
    log.info("solve metadata -> %s", out)

    if args.cache:
        session.save()
        log.info("tuning cache saved to %s", args.cache)
    if args.metrics_export:
        from repro.obs import get_metrics

        get_metrics().write_shard(args.metrics_export, args.obs_instance)
        log.info("metrics shard -> %s", args.metrics_export)
    if args.trace_export:
        from repro.obs import get_tracer

        nspans = get_tracer().export_jsonl(args.trace_export)
        log.info("trace shard -> %s (%d spans)", args.trace_export, nspans)
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--solver", required=True, choices=SOLVER_NAMES,
                    help="iterative solver to run")
    ap.add_argument("--matrix", default="webgraph",
                    help="suite matrix name or generator pattern")
    ap.add_argument("--scale", type=float, default=0.0008,
                    help="suite scale factor (matches the bench smoke tier)")
    ap.add_argument("--max-iters", type=int, default=100)
    ap.add_argument("--tol", type=float, default=1e-8,
                    help="convergence tolerance (solver-specific residual)")
    ap.add_argument("--damping", type=float, default=0.85,
                    help="pagerank damping factor")
    ap.add_argument("--adaptive-spmspv", action="store_true",
                    help="route each iteration SpMV vs SpMSpV by frontier "
                         "density, learned per density phase by the UCB "
                         "bandit")
    ap.add_argument("--cache", default=None,
                    help="JSON path for the persistent tuning cache")
    ap.add_argument("--train-matrices", type=int, default=4,
                    help="suite matrices used to fit the tuner's predictors")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--objective", default="latency",
                    choices=["latency", "energy", "power", "efficiency"])
    ap.add_argument("--json-out", default=None,
                    help="convergence-metadata JSON path (default "
                         "artifacts/solve/SOLVE_<solver>_<matrix>.json)")
    ap.add_argument("--metrics-export", default=None,
                    help="write the metrics registry as a JSONL shard here "
                         "after solving (obs/aggregate.py input)")
    ap.add_argument("--trace-export", default=None,
                    help="append the collected spans as a JSONL shard here "
                         "after solving (obs/aggregate.py input)")
    ap.add_argument("--obs-instance", default="solve",
                    help="instance label stamped into exported shards")
    args = ap.parse_args(argv)
    return run_solve(args)


if __name__ == "__main__":
    main()
