"""Abstract input specs + step builders for every (arch x workload-shape)
cell (ShapeDtypeStruct stand-ins: weak-type-correct, shardable, zero
allocation)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import WorkloadShape
from repro.models import abstract_params, cache_specs, decode_step, model_specs, prefill
from repro.models.param import ParamSpec
from repro.optim import AdamWConfig
from repro.train.trainer import make_train_step

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ModelConfig, shape: WorkloadShape) -> dict:
    B, S = shape.global_batch, shape.seq_len
    text = S - (cfg.prefix_len or 0)
    batch: dict = {}
    if cfg.train_input == "embeds":
        batch["embeds"] = SDS((B, text, cfg.d_model), jnp.bfloat16)
    else:
        batch["tokens"] = SDS((B, text), jnp.int32)
    if cfg.prefix_len:
        batch["prefix_embeds"] = SDS((B, cfg.prefix_len, cfg.d_model), jnp.bfloat16)
    batch["labels"] = SDS((B, text), jnp.int32)
    return batch


def prefill_input_specs(cfg: ModelConfig, shape: WorkloadShape) -> dict:
    B, S = shape.global_batch, shape.seq_len
    text = S - (cfg.prefix_len or 0)
    inputs: dict = {}
    if cfg.train_input == "embeds":
        inputs["embeds"] = SDS((B, text, cfg.d_model), jnp.bfloat16)
    else:
        inputs["tokens"] = SDS((B, text), jnp.int32)
    if cfg.prefix_len:
        inputs["prefix_embeds"] = SDS((B, cfg.prefix_len, cfg.d_model), jnp.bfloat16)
    return inputs


def decode_input_specs(cfg: ModelConfig, shape: WorkloadShape) -> dict:
    B = shape.global_batch
    return {
        "tokens": SDS((B, 1), jnp.int32),
        "positions": SDS((B, 1), jnp.int32),
    }


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    return abstract_params(cache_specs(cfg, batch, max_len), cfg.compute_dtype)


def abstract_opt_state(cfg: ModelConfig, param_specs) -> dict:
    from repro.models.param import is_spec

    dt = jnp.dtype(cfg.opt_state_dtype)
    mv = jax.tree.map(lambda s: SDS(s.shape, dt), param_specs, is_leaf=is_spec)
    return {"m": mv, "v": jax.tree.map(lambda x: x, mv), "step": SDS((), jnp.int32)}


def make_step_fn(cfg: ModelConfig, shape: WorkloadShape, *, unroll: bool = False):
    """(step_fn, example_args_pytree) for the cell's workload kind.

    ``unroll`` selects the cost-accurate lowering (unrolled layer groups +
    unrolled attention chunks) used by the dry-run's FLOP extrapolation.
    """
    if shape.kind == "train":
        opt_cfg = AdamWConfig(state_dtype=cfg.opt_state_dtype)
        step = make_train_step(cfg, opt_cfg, unroll_attn=unroll, unroll_layers=unroll)
        pspecs = model_specs(cfg)
        args = (
            abstract_params(pspecs, cfg.param_dtype),
            abstract_opt_state(cfg, pspecs),
            train_batch_specs(cfg, shape),
        )
        return step, args

    if shape.kind == "prefill":

        def prefill_step(params, cache, inputs):
            logits, cache, _ = prefill(
                params, cfg, cache, unroll_attn=unroll, unroll_layers=unroll, **inputs
            )
            return logits[:, -1, :], cache  # serving keeps last-token logits

        args = (
            abstract_params(model_specs(cfg), cfg.param_dtype),
            abstract_cache(cfg, shape.global_batch, shape.seq_len),
            prefill_input_specs(cfg, shape),
        )
        return prefill_step, args

    if shape.kind == "decode":

        def serve_step(params, cache, tokens, positions):
            return decode_step(params, cfg, cache, tokens, positions, unroll_layers=unroll)

        d = decode_input_specs(cfg, shape)
        args = (
            abstract_params(model_specs(cfg), cfg.param_dtype),
            abstract_cache(cfg, shape.global_batch, shape.seq_len),
            d["tokens"],
            d["positions"],
        )
        return serve_step, args

    raise ValueError(shape.kind)


def model_flops(cfg: ModelConfig, shape: WorkloadShape) -> float:
    """Analytic MODEL_FLOPS: 6*N*D for training (fwd+bwd), 2*N*D for
    inference, N = active params in matmuls (embedding-gather rows excluded,
    the logits matmul included)."""
    counts = cfg.param_counts()
    n_compute = counts["active"] - counts["embed"] + cfg.vocab_size * cfg.d_model
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_compute * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_compute * tokens
    return 2.0 * n_compute * shape.global_batch  # decode: one token per seq
