"""Training launcher: ``python -m repro.launch.train --arch <id> [options]``.

On this CPU container it runs reduced configs on a 1x1 mesh; on a real fleet
the same code paths run the full config on the production mesh (the
``--production-mesh`` flag lowers against ``make_production_mesh()``; it
requires 256/512 devices and is exercised by the dry-run driver instead).
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import DataConfig
from repro.dist.partition import sharding_context
from repro.dist.sharding import batch_sharding, build_sharding
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import model_specs
from repro.optim import AdamWConfig, cosine_schedule
from repro.train import TrainConfig, Trainer, make_train_step
from repro.train.trainer import init_train_state
from repro.utils.logging import get_logger

log = get_logger("launch.train")


def build_argparser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=sorted(ARCH_IDS))
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="use the reduced (CPU-scale) config [default]")
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-frac", type=float, default=0.0)
    ap.add_argument("--dispatch-format", default=None,
                    help="MoE dispatch: ell|sell|dense (Auto-SpMV run-time knob)")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def main(argv=None):
    args = build_argparser().parse_args(argv)
    cfg = get_config(args.arch, reduced_config=args.reduced)
    if args.dispatch_format and cfg.n_experts:
        cfg = cfg.replace(dispatch_format=args.dispatch_format)
    mesh = make_production_mesh() if args.production_mesh else make_host_mesh()
    log.info("arch=%s mesh=%s params~%.1fM", cfg.name, dict(mesh.shape),
             cfg.param_counts()["total"] / 1e6)

    opt_cfg = AdamWConfig(
        learning_rate=cosine_schedule(args.lr, args.warmup, args.steps),
        state_dtype=cfg.opt_state_dtype,
    )
    data_cfg = DataConfig(
        vocab_size=cfg.vocab_size,
        seq_len=args.seq_len,
        global_batch=args.batch,
        seed=args.seed,
        embed_dim=cfg.d_model if cfg.train_input == "embeds" or cfg.prefix_len else 0,
        prefix_len=cfg.prefix_len,
    )
    train_cfg = TrainConfig(
        steps=args.steps,
        ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir,
        compress_frac=args.compress_frac,
    )

    param_sh = build_sharding(mesh, model_specs(cfg))
    import jax.numpy as jnp

    def to_device(batch):
        spec = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
        sh = batch_sharding(mesh, spec)
        out = {}
        for k, v in batch.items():
            arr = v
            if k == "embeds" or k == "prefix_embeds":
                arr = arr.astype(jnp.dtype(cfg.compute_dtype))
            out[k] = jax.device_put(arr, sh[k])
        return out

    with mesh, sharding_context(mesh):
        step_fn = jax.jit(
            make_train_step(cfg, opt_cfg, compress_frac=train_cfg.compress_frac)
        )
        trainer = Trainer(cfg, data_cfg, opt_cfg, train_cfg,
                          jit_step=step_fn, to_device=to_device)
        params, opt_state = init_train_state(
            cfg, opt_cfg, seed=args.seed, compress_frac=train_cfg.compress_frac
        )
        params, opt_state = trainer.run(params, opt_state)
    if trainer.history:
        first, last = trainer.history[0]["loss"], trainer.history[-1]["loss"]
        log.info("done: loss %.4f -> %.4f over %d steps", first, last, len(trainer.history))
    return trainer


if __name__ == "__main__":
    main()
