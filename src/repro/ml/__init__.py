"""From-scratch ML substrate (scikit-learn is not available offline).

Implements exactly the model families the paper fine-tunes (Tables 1 and 4):
nearest centroid, decision tree, non-linear SVM, gradient boosting, random
forest and MLP for classification; Bayesian ridge, lasso, LARS, random
forest, decision tree and MLP for regression — with the hyperparameters the
paper searches over.
"""

from repro.ml.base import StandardScaler, train_test_split
from repro.ml.centroid import NearestCentroid
from repro.ml.tree import DecisionTreeClassifier, DecisionTreeRegressor
from repro.ml.forest import RandomForestClassifier, RandomForestRegressor
from repro.ml.boosting import GradientBoostingClassifier
from repro.ml.svm import NonlinearSVM
from repro.ml.mlp import MLPClassifier, MLPRegressor
from repro.ml.linear import BayesianRidge, Lars, Lasso, Ridge
from repro.ml.metrics import (
    accuracy_score,
    confusion_matrix,
    f1_score,
    mean_squared_error,
    r2_score,
)
from repro.ml.model_zoo import (
    CLASSIFIER_ZOO,
    REGRESSOR_ZOO,
    make_classifier,
    make_regressor,
)

__all__ = [
    "StandardScaler",
    "train_test_split",
    "NearestCentroid",
    "DecisionTreeClassifier",
    "DecisionTreeRegressor",
    "RandomForestClassifier",
    "RandomForestRegressor",
    "GradientBoostingClassifier",
    "NonlinearSVM",
    "MLPClassifier",
    "MLPRegressor",
    "BayesianRidge",
    "Lars",
    "Lasso",
    "Ridge",
    "accuracy_score",
    "confusion_matrix",
    "f1_score",
    "mean_squared_error",
    "r2_score",
    "CLASSIFIER_ZOO",
    "REGRESSOR_ZOO",
    "make_classifier",
    "make_regressor",
]
