"""Estimator plumbing shared by the from-scratch models."""

from __future__ import annotations

import numpy as np


class Estimator:
    """Minimal fit/predict protocol. Subclasses set ``fitted_`` in fit()."""

    def fit(self, X: np.ndarray, y: np.ndarray) -> "Estimator":  # pragma: no cover
        raise NotImplementedError

    def predict(self, X: np.ndarray) -> np.ndarray:  # pragma: no cover
        raise NotImplementedError

    def get_params(self) -> dict:
        return {k: v for k, v in vars(self).items() if not k.endswith("_")}

    def __repr__(self) -> str:
        args = ", ".join(f"{k}={v!r}" for k, v in self.get_params().items())
        return f"{type(self).__name__}({args})"


class ClassifierMixin:
    def score(self, X, y) -> float:
        return float(np.mean(self.predict(X) == np.asarray(y)))


class RegressorMixin:
    def score(self, X, y) -> float:
        from repro.ml.metrics import r2_score

        return r2_score(y, self.predict(X))


class StandardScaler:
    """Per-feature standardization (fit on train, reuse on validation)."""

    def fit(self, X: np.ndarray) -> "StandardScaler":
        X = np.asarray(X, dtype=np.float64)
        self.mean_ = X.mean(axis=0)
        self.scale_ = X.std(axis=0)
        self.scale_[self.scale_ == 0] = 1.0
        return self

    def transform(self, X: np.ndarray) -> np.ndarray:
        return (np.asarray(X, dtype=np.float64) - self.mean_) / self.scale_

    def fit_transform(self, X: np.ndarray) -> np.ndarray:
        return self.fit(X).transform(X)


def train_test_split(X, y, test_size: float = 0.2, seed: int = 0):
    """Shuffled split — the paper uses 80/20 (§6.4)."""
    X, y = np.asarray(X), np.asarray(y)
    n = X.shape[0]
    idx = np.random.default_rng(seed).permutation(n)
    n_test = max(int(round(n * test_size)), 1)
    test, train = idx[:n_test], idx[n_test:]
    return X[train], X[test], y[train], y[test]


def check_Xy(X, y):
    X = np.asarray(X, dtype=np.float64)
    y = np.asarray(y)
    if X.ndim != 2:
        raise ValueError(f"X must be 2-D, got {X.shape}")
    if y.shape[0] != X.shape[0]:
        raise ValueError(f"X/y length mismatch: {X.shape[0]} vs {y.shape[0]}")
    return X, y
