"""Gradient boosting classifier: multinomial deviance, regression-tree weak
learners (paper Table 1 space: n_estimators in {50,100,150,200}, learning
rate in {0.1, 0.01, 0.001})."""

from __future__ import annotations

import numpy as np

from repro.ml.base import ClassifierMixin, Estimator, check_Xy
from repro.ml.tree import DecisionTreeRegressor


def _softmax(z):
    z = z - z.max(axis=1, keepdims=True)
    e = np.exp(z)
    return e / e.sum(axis=1, keepdims=True)


class GradientBoostingClassifier(Estimator, ClassifierMixin):
    def __init__(self, n_estimators=100, learning_rate=0.1, max_depth=3, seed=0):
        self.n_estimators = n_estimators
        self.learning_rate = learning_rate
        self.max_depth = max_depth
        self.seed = seed

    def fit(self, X, y):
        X, y = check_Xy(X, y)
        self.classes_, y_enc = np.unique(y, return_inverse=True)
        n, k = X.shape[0], len(self.classes_)
        onehot = np.zeros((n, k))
        onehot[np.arange(n), y_enc] = 1.0
        self.init_ = np.log(np.maximum(onehot.mean(axis=0), 1e-12))
        F = np.tile(self.init_, (n, 1))
        self.stages_: list[list[DecisionTreeRegressor]] = []
        rng = np.random.default_rng(self.seed)
        for _ in range(self.n_estimators):
            P = _softmax(F)
            residual = onehot - P  # negative gradient of multinomial deviance
            stage = []
            for c in range(k):
                tree = DecisionTreeRegressor(
                    max_depth=self.max_depth, seed=int(rng.integers(0, 2**31 - 1))
                )
                tree.fit(X, residual[:, c])
                F[:, c] += self.learning_rate * tree.predict(X)
                stage.append(tree)
            self.stages_.append(stage)
        return self

    def decision_function(self, X):
        X = np.asarray(X, dtype=np.float64)
        F = np.tile(self.init_, (X.shape[0], 1))
        for stage in self.stages_:
            for c, tree in enumerate(stage):
                F[:, c] += self.learning_rate * tree.predict(X)
        return F

    def predict_proba(self, X):
        return _softmax(self.decision_function(X))

    def predict(self, X):
        return self.classes_[np.argmax(self.decision_function(X), axis=1)]
