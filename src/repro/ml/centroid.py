"""Nearest-centroid classifier (paper Table 1: metric in
{manhattan, euclidean, minkowski})."""

from __future__ import annotations

import numpy as np

from repro.ml.base import ClassifierMixin, Estimator, check_Xy


class NearestCentroid(Estimator, ClassifierMixin):
    def __init__(self, metric: str = "euclidean", p: float = 3.0):
        if metric not in ("manhattan", "euclidean", "minkowski"):
            raise ValueError(f"unknown metric {metric!r}")
        self.metric = metric
        self.p = p  # minkowski order

    def fit(self, X, y):
        X, y = check_Xy(X, y)
        self.classes_ = np.unique(y)
        self.centroids_ = np.stack([X[y == c].mean(axis=0) for c in self.classes_])
        return self

    def _dist(self, X):
        diff = X[:, None, :] - self.centroids_[None, :, :]
        if self.metric == "manhattan":
            return np.abs(diff).sum(axis=-1)
        if self.metric == "euclidean":
            return np.sqrt((diff**2).sum(axis=-1))
        return (np.abs(diff) ** self.p).sum(axis=-1) ** (1.0 / self.p)

    def predict(self, X):
        X = np.asarray(X, dtype=np.float64)
        return self.classes_[np.argmin(self._dist(X), axis=1)]
