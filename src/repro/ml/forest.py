"""Random forests: bootstrap-bagged CART trees with feature subsampling.

Paper Table 4 settings: 100 estimators, max depth 15 (classification) /
None (regression), criterion searched over {gini, entropy, log_loss}.
"""

from __future__ import annotations

import numpy as np

from repro.ml.base import ClassifierMixin, Estimator, RegressorMixin, check_Xy
from repro.ml.tree import DecisionTreeClassifier, DecisionTreeRegressor


class _BaseForest(Estimator):
    def __init__(self, n_estimators=100, max_depth=None, max_features="sqrt", seed=0,
                 **tree_kw):
        self.n_estimators = n_estimators
        self.max_depth = max_depth
        self.max_features = max_features
        self.seed = seed
        self.tree_kw = tree_kw

    def _resolve_max_features(self, n_features: int):
        mf = self.max_features
        if mf is None:
            return n_features
        if mf == "sqrt":
            return max(int(np.sqrt(n_features)), 1)
        if mf == "log2":
            return max(int(np.log2(n_features)), 1)
        return min(int(mf), n_features)

    def _fit_bagged(self, X, y, make_tree):
        rng = np.random.default_rng(self.seed)
        n = X.shape[0]
        self.trees_ = []
        for i in range(self.n_estimators):
            idx = rng.integers(0, n, size=n)  # bootstrap sample
            tree = make_tree(seed=int(rng.integers(0, 2**31 - 1)))
            tree.fit(X[idx], y[idx])
            self.trees_.append(tree)
        return self


class RandomForestClassifier(_BaseForest, ClassifierMixin):
    def __init__(self, criterion="gini", **kw):
        super().__init__(**kw)
        self.criterion = criterion

    def fit(self, X, y):
        X, y = check_Xy(X, y)
        self.classes_ = np.unique(y)
        mf = self._resolve_max_features(X.shape[1])

        def make_tree(seed):
            return DecisionTreeClassifier(
                criterion=self.criterion,
                max_depth=self.max_depth,
                max_features=mf,
                seed=seed,
                **self.tree_kw,
            )

        return self._fit_bagged(X, y, make_tree)

    def predict_proba(self, X):
        # trees were fit on the full label set (bootstraps may miss classes;
        # align by each tree's classes_)
        n_classes = len(self.classes_)
        index = {c: i for i, c in enumerate(self.classes_)}
        probs = np.zeros((np.asarray(X).shape[0], n_classes))
        for tree in self.trees_:
            p = tree.predict_proba(X)
            for j, c in enumerate(tree.classes_):
                probs[:, index[c]] += p[:, j]
        return probs / len(self.trees_)

    def predict(self, X):
        return self.classes_[np.argmax(self.predict_proba(X), axis=1)]


class RandomForestRegressor(_BaseForest, RegressorMixin):
    def fit(self, X, y):
        X, y = check_Xy(X, y)
        mf = self._resolve_max_features(X.shape[1])

        def make_tree(seed):
            return DecisionTreeRegressor(
                max_depth=self.max_depth, max_features=mf, seed=seed, **self.tree_kw
            )

        return self._fit_bagged(X, y, make_tree)

    def predict(self, X):
        return np.mean([t.predict(X) for t in self.trees_], axis=0)
