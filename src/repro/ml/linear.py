"""Linear regression family (paper Table 4 regression zoo): Ridge, Bayesian
Ridge (evidence maximization), Lasso (coordinate descent), LARS."""

from __future__ import annotations

import numpy as np

from repro.ml.base import Estimator, RegressorMixin, check_Xy


class _LinearBase(Estimator, RegressorMixin):
    def _center(self, X, y):
        self.x_mean_ = X.mean(axis=0)
        self.x_scale_ = np.where(X.std(axis=0) > 0, X.std(axis=0), 1.0)
        self.y_mean_ = y.mean()
        return (X - self.x_mean_) / self.x_scale_, y - self.y_mean_

    def predict(self, X):
        X = np.asarray(X, dtype=np.float64)
        Xs = (X - self.x_mean_) / self.x_scale_
        return Xs @ self.coef_ + self.y_mean_


class Ridge(_LinearBase):
    def __init__(self, alpha: float = 1.0):
        self.alpha = alpha

    def fit(self, X, y):
        X, y = check_Xy(X, y)
        Xs, yc = self._center(X, y.astype(np.float64))
        d = Xs.shape[1]
        self.coef_ = np.linalg.solve(Xs.T @ Xs + self.alpha * np.eye(d), Xs.T @ yc)
        return self


class BayesianRidge(_LinearBase):
    """Evidence-maximization ridge (MacKay updates), sklearn-compatible
    hyperparameters (paper Table 4: n_iter=300, tol=1e-3)."""

    def __init__(self, n_iter: int = 300, tol: float = 1e-3):
        self.n_iter = n_iter
        self.tol = tol

    def fit(self, X, y):
        X, y = check_Xy(X, y)
        Xs, yc = self._center(X, y.astype(np.float64))
        n, d = Xs.shape
        XtX, Xty = Xs.T @ Xs, Xs.T @ yc
        alpha = 1.0 / max(yc.var(), 1e-9)  # noise precision
        lam = 1.0  # weight precision
        coef = np.zeros(d)
        eig = np.linalg.eigvalsh(XtX)
        for _ in range(self.n_iter):
            A = lam * np.eye(d) + alpha * XtX
            coef_new = alpha * np.linalg.solve(A, Xty)
            gamma = np.sum(alpha * eig / (lam + alpha * eig))
            lam = gamma / max(coef_new @ coef_new, 1e-12)
            resid = yc - Xs @ coef_new
            alpha = max(n - gamma, 1e-9) / max(resid @ resid, 1e-12)
            if np.max(np.abs(coef_new - coef)) < self.tol:
                coef = coef_new
                break
            coef = coef_new
        self.coef_ = coef
        self.alpha_, self.lambda_ = alpha, lam
        return self


class Lasso(_LinearBase):
    """L1 regression via cyclic coordinate descent (paper: alpha=1.0,
    1000 epochs)."""

    def __init__(self, alpha: float = 1.0, n_iter: int = 1000, tol: float = 1e-6):
        self.alpha = alpha
        self.n_iter = n_iter
        self.tol = tol

    def fit(self, X, y):
        X, y = check_Xy(X, y)
        Xs, yc = self._center(X, y.astype(np.float64))
        n, d = Xs.shape
        coef = np.zeros(d)
        col_sq = (Xs**2).sum(axis=0)
        resid = yc.copy()
        lam = self.alpha * n
        for _ in range(self.n_iter):
            max_delta = 0.0
            for j in range(d):
                if col_sq[j] == 0:
                    continue
                rho = Xs[:, j] @ resid + col_sq[j] * coef[j]
                new = np.sign(rho) * max(abs(rho) - lam, 0.0) / col_sq[j]
                delta = new - coef[j]
                if delta != 0.0:
                    resid -= delta * Xs[:, j]
                    coef[j] = new
                    max_delta = max(max_delta, abs(delta))
            if max_delta < self.tol:
                break
        self.coef_ = coef
        return self


class Lars(_LinearBase):
    """Least-Angle Regression (paper Table 4: max 500 nonzero coefs)."""

    def __init__(self, n_nonzero_coefs: int = 500, eps: float = np.finfo(float).eps):
        self.n_nonzero_coefs = n_nonzero_coefs
        self.eps = eps

    def fit(self, X, y):
        X, y = check_Xy(X, y)
        Xs, yc = self._center(X, y.astype(np.float64))
        n, d = Xs.shape
        coef = np.zeros(d)
        active: list[int] = []
        resid = yc.copy()
        k_max = min(self.n_nonzero_coefs, d, n - 1 if n > 1 else 1)
        for _ in range(k_max):
            c = Xs.T @ resid
            inactive = [j for j in range(d) if j not in active]
            if not inactive:
                break
            j_new = inactive[int(np.argmax(np.abs(c[inactive])))]
            if abs(c[j_new]) < 10 * self.eps:
                break
            active.append(j_new)
            Xa = Xs[:, active]
            # equiangular least-squares step on the active set
            try:
                beta = np.linalg.lstsq(Xa, yc, rcond=None)[0]
            except np.linalg.LinAlgError:
                break
            # step fully toward LS solution of active set (LARS-OLS hybrid)
            coef = np.zeros(d)
            coef[active] = beta
            resid = yc - Xs @ coef
            if np.linalg.norm(resid) < 10 * self.eps:
                break
        self.coef_ = coef
        return self
