"""Evaluation metrics: accuracy + macro-F1 for classification (paper Table
5), R² + MSE for regression (paper Fig. 11)."""

from __future__ import annotations

import numpy as np


def accuracy_score(y_true, y_pred) -> float:
    y_true, y_pred = np.asarray(y_true), np.asarray(y_pred)
    return float(np.mean(y_true == y_pred)) if y_true.size else 0.0


def confusion_matrix(y_true, y_pred, labels=None) -> np.ndarray:
    y_true, y_pred = np.asarray(y_true), np.asarray(y_pred)
    if labels is None:
        labels = np.unique(np.concatenate([y_true, y_pred]))
    index = {l: i for i, l in enumerate(labels)}
    cm = np.zeros((len(labels), len(labels)), dtype=np.int64)
    for t, p in zip(y_true, y_pred):
        cm[index[t], index[p]] += 1
    return cm


def f1_score(y_true, y_pred, average: str = "macro") -> float:
    """Macro-averaged F1 (per-class F1, unweighted mean)."""
    cm = confusion_matrix(y_true, y_pred)
    tp = np.diag(cm).astype(np.float64)
    fp = cm.sum(axis=0) - tp
    fn = cm.sum(axis=1) - tp
    with np.errstate(divide="ignore", invalid="ignore"):
        prec = np.where(tp + fp > 0, tp / (tp + fp), 0.0)
        rec = np.where(tp + fn > 0, tp / (tp + fn), 0.0)
        f1 = np.where(prec + rec > 0, 2 * prec * rec / (prec + rec), 0.0)
    if average == "macro":
        return float(f1.mean())
    if average == "weighted":
        w = cm.sum(axis=1) / max(cm.sum(), 1)
        return float((f1 * w).sum())
    raise ValueError(f"unknown average {average!r}")


def mean_squared_error(y_true, y_pred) -> float:
    y_true, y_pred = np.asarray(y_true, np.float64), np.asarray(y_pred, np.float64)
    return float(np.mean((y_true - y_pred) ** 2))


def r2_score(y_true, y_pred) -> float:
    y_true, y_pred = np.asarray(y_true, np.float64), np.asarray(y_pred, np.float64)
    ss_res = np.sum((y_true - y_pred) ** 2)
    ss_tot = np.sum((y_true - y_true.mean()) ** 2)
    if ss_tot == 0:
        return 1.0 if ss_res == 0 else 0.0
    return float(1.0 - ss_res / ss_tot)
