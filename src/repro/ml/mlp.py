"""Multi-layer perceptron in JAX (classifier + regressor).

Paper search space (Table 1): hidden width {20..200}, depth {1..10},
activation {identity, logistic, tanh, relu}; tuned result (Table 4):
5 layers x 100 nodes, ReLU, Adam, lr 1e-3, 200 epochs. Training is
full-batch Adam under jit — the datasets here are small enough that
full-batch is both faster and deterministic on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.ml.base import ClassifierMixin, Estimator, RegressorMixin, check_Xy

_ACTIVATIONS = {
    "identity": lambda x: x,
    "logistic": jax.nn.sigmoid,
    "tanh": jnp.tanh,
    "relu": jax.nn.relu,
}


def _init_params(rng, sizes):
    params = []
    keys = jax.random.split(rng, len(sizes) - 1)
    for k, (din, dout) in zip(keys, zip(sizes[:-1], sizes[1:])):
        w = jax.random.normal(k, (din, dout)) * jnp.sqrt(2.0 / din)
        params.append({"w": w, "b": jnp.zeros(dout)})
    return params


def _forward(params, X, act):
    h = X
    for layer in params[:-1]:
        h = act(h @ layer["w"] + layer["b"])
    last = params[-1]
    return h @ last["w"] + last["b"]


@functools.partial(jax.jit, static_argnames=("act_name", "loss_kind", "epochs", "lr"))
def _train(params, X, y, *, act_name, loss_kind, epochs, lr):
    act = _ACTIVATIONS[act_name]

    def loss_fn(p):
        out = _forward(p, X, act)
        if loss_kind == "xent":
            logp = jax.nn.log_softmax(out, axis=-1)
            return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
        return jnp.mean((out.squeeze(-1) - y) ** 2)

    # Adam
    b1, b2, eps = 0.9, 0.999, 1e-8
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    def step(carry, t):
        p, m, v = carry
        g = jax.grad(loss_fn)(p)
        m = jax.tree.map(lambda m_, g_: b1 * m_ + (1 - b1) * g_, m, g)
        v = jax.tree.map(lambda v_, g_: b2 * v_ + (1 - b2) * g_**2, v, g)
        tt = t + 1
        mh = jax.tree.map(lambda m_: m_ / (1 - b1**tt), m)
        vh = jax.tree.map(lambda v_: v_ / (1 - b2**tt), v)
        p = jax.tree.map(lambda p_, mh_, vh_: p_ - lr * mh_ / (jnp.sqrt(vh_) + eps), p, mh, vh)
        return (p, m, v), loss_fn(p)

    (params, _, _), losses = jax.lax.scan(step, (params, m, v), jnp.arange(epochs, dtype=jnp.float32))
    return params, losses


class _BaseMLP(Estimator):
    def __init__(self, hidden_layer_size=100, n_layers=5, activation="relu",
                 learning_rate=1e-3, epochs=200, seed=0):
        if activation not in _ACTIVATIONS:
            raise ValueError(f"activation must be one of {sorted(_ACTIVATIONS)}")
        self.hidden_layer_size = hidden_layer_size
        self.n_layers = n_layers
        self.activation = activation
        self.learning_rate = learning_rate
        self.epochs = epochs
        self.seed = seed

    def _fit(self, X, y, out_dim, loss_kind):
        self.x_mean_ = X.mean(axis=0)
        self.x_scale_ = np.where(X.std(axis=0) > 0, X.std(axis=0), 1.0)
        Xs = (X - self.x_mean_) / self.x_scale_
        sizes = [X.shape[1]] + [self.hidden_layer_size] * self.n_layers + [out_dim]
        params = _init_params(jax.random.PRNGKey(self.seed), sizes)
        self.params_, self.loss_curve_ = _train(
            params,
            jnp.asarray(Xs, jnp.float32),
            jnp.asarray(y),
            act_name=self.activation,
            loss_kind=loss_kind,
            epochs=self.epochs,
            lr=self.learning_rate,
        )
        return self

    def _raw_predict(self, X):
        X = np.asarray(X, dtype=np.float64)
        Xs = (X - self.x_mean_) / self.x_scale_
        return np.asarray(
            _forward(self.params_, jnp.asarray(Xs, jnp.float32), _ACTIVATIONS[self.activation])
        )


class MLPClassifier(_BaseMLP, ClassifierMixin):
    def fit(self, X, y):
        X, y = check_Xy(X, y)
        self.classes_, y_enc = np.unique(y, return_inverse=True)
        return self._fit(X, jnp.asarray(y_enc, jnp.int32), len(self.classes_), "xent")

    def predict_proba(self, X):
        out = self._raw_predict(X)
        e = np.exp(out - out.max(axis=1, keepdims=True))
        return e / e.sum(axis=1, keepdims=True)

    def predict(self, X):
        return self.classes_[np.argmax(self._raw_predict(X), axis=1)]


class MLPRegressor(_BaseMLP, RegressorMixin):
    def fit(self, X, y):
        X, y = check_Xy(X, y)
        self.y_mean_ = float(np.mean(y))
        self.y_scale_ = float(np.std(y)) or 1.0
        ys = (y.astype(np.float64) - self.y_mean_) / self.y_scale_
        return self._fit(X, jnp.asarray(ys, jnp.float32), 1, "mse")

    def predict(self, X):
        return self._raw_predict(X).squeeze(-1) * self.y_scale_ + self.y_mean_
