"""Model registry + hyperparameter search spaces (paper Tables 1 and 4).

``CLASSIFIER_ZOO`` / ``REGRESSOR_ZOO`` map model names to (constructor,
search-space) pairs consumed by ``repro.core.hpo``. The search spaces are
the paper's Table 1 ranges verbatim; defaults are the paper's tuned Table 4
settings so un-tuned runs reproduce the reported models.
"""

from __future__ import annotations

from typing import Any, Callable

from repro.ml.boosting import GradientBoostingClassifier
from repro.ml.centroid import NearestCentroid
from repro.ml.forest import RandomForestClassifier, RandomForestRegressor
from repro.ml.linear import BayesianRidge, Lars, Lasso
from repro.ml.mlp import MLPClassifier, MLPRegressor
from repro.ml.svm import NonlinearSVM
from repro.ml.tree import DecisionTreeClassifier, DecisionTreeRegressor

SearchSpace = dict[str, list[Any]]


def _zoo_entry(ctor: Callable, space: SearchSpace, defaults: dict) -> dict:
    return {"ctor": ctor, "space": space, "defaults": defaults}


CLASSIFIER_ZOO: dict[str, dict] = {
    # Table 1 spaces; Table 4 tuned defaults
    "nearest_centroid": _zoo_entry(
        NearestCentroid,
        {"metric": ["manhattan", "euclidean", "minkowski"]},
        {"metric": "manhattan"},
    ),
    "decision_tree": _zoo_entry(
        DecisionTreeClassifier,
        {
            "criterion": ["gini", "entropy", "log_loss"],
            "splitter": ["best", "random"],
            "max_depth": [5, 9, 13, 15, None],
        },
        {"criterion": "gini", "splitter": "best", "max_depth": 13},
    ),
    "svm": _zoo_entry(
        NonlinearSVM,
        {"kernel": ["linear", "poly", "rbf", "sigmoid"], "C": [0.1, 1.0, 10.0]},
        {"kernel": "rbf", "C": 1.0, "degree": 3, "gamma": "scale"},
    ),
    "gradient_boosting": _zoo_entry(
        GradientBoostingClassifier,
        {
            "n_estimators": [50, 100, 150, 200],
            "learning_rate": [0.1, 0.01, 0.001],
        },
        {"n_estimators": 100, "learning_rate": 0.1},
    ),
    "random_forest": _zoo_entry(
        RandomForestClassifier,
        {"criterion": ["gini", "entropy", "log_loss"], "max_depth": [10, 15, None]},
        {"criterion": "gini", "n_estimators": 100, "max_depth": 15},
    ),
    "mlp": _zoo_entry(
        MLPClassifier,
        {
            "hidden_layer_size": [20, 50, 100, 150, 200],
            "n_layers": [1, 2, 3, 4, 5, 10],
            "activation": ["identity", "logistic", "tanh", "relu"],
        },
        {
            "hidden_layer_size": 100,
            "n_layers": 5,
            "activation": "relu",
            "epochs": 200,
            "learning_rate": 1e-3,
        },
    ),
}

REGRESSOR_ZOO: dict[str, dict] = {
    "bayesian_ridge": _zoo_entry(
        BayesianRidge, {"n_iter": [100, 300], "tol": [1e-3, 1e-4]}, {"n_iter": 300, "tol": 1e-3}
    ),
    "lasso": _zoo_entry(
        Lasso, {"alpha": [0.001, 0.01, 0.1, 1.0]}, {"alpha": 1.0, "n_iter": 1000}
    ),
    "lars": _zoo_entry(Lars, {"n_nonzero_coefs": [8, 64, 500]}, {"n_nonzero_coefs": 500}),
    "random_forest": _zoo_entry(
        RandomForestRegressor,
        {"n_estimators": [50, 100], "max_depth": [10, None]},
        {"n_estimators": 100, "max_depth": None},
    ),
    "decision_tree": _zoo_entry(
        DecisionTreeRegressor, {"max_depth": [5, 10, None]}, {"max_depth": None}
    ),
    "mlp": _zoo_entry(
        MLPRegressor,
        {
            "hidden_layer_size": [50, 100, 200],
            "n_layers": [2, 3, 5],
            "activation": ["relu", "tanh"],
        },
        {
            "hidden_layer_size": 200,
            "n_layers": 5,
            "activation": "relu",
            "epochs": 200,
            "learning_rate": 1e-4,
        },
    ),
}

CLASSIFIER_NAMES = tuple(CLASSIFIER_ZOO)
REGRESSOR_NAMES = tuple(REGRESSOR_ZOO)


def make_classifier(name: str, **overrides):
    entry = CLASSIFIER_ZOO[name]
    kw = dict(entry["defaults"])
    kw.update(overrides)
    return entry["ctor"](**kw)


def make_regressor(name: str, **overrides):
    entry = REGRESSOR_ZOO[name]
    kw = dict(entry["defaults"])
    kw.update(overrides)
    return entry["ctor"](**kw)
