"""Non-linear (kernel) SVM, one-vs-rest, trained in the representer form.

The decision function f(x) = sum_i alpha_i K(x_i, x) + b is optimized by
full-batch subgradient descent on the L2-regularized hinge loss — a compact,
deterministic stand-in for libsvm's SMO that is accurate at the dataset
sizes used here (hundreds-to-thousands of rows). Kernels follow the paper's
search space (Table 1): linear, poly, rbf, sigmoid. ("precomputed" is
accepted by passing a Gram matrix directly.)
"""

from __future__ import annotations

import numpy as np

from repro.ml.base import ClassifierMixin, Estimator, check_Xy


def _kernel_matrix(kind: str, A, B, gamma: float, degree: int, coef0: float):
    if kind == "linear":
        return A @ B.T
    if kind == "poly":
        return (gamma * (A @ B.T) + coef0) ** degree
    if kind == "rbf":
        a2 = (A**2).sum(axis=1)[:, None]
        b2 = (B**2).sum(axis=1)[None, :]
        return np.exp(-gamma * np.maximum(a2 + b2 - 2 * A @ B.T, 0.0))
    if kind == "sigmoid":
        return np.tanh(gamma * (A @ B.T) + coef0)
    if kind == "precomputed":
        return A
    raise ValueError(f"unknown kernel {kind!r}")


class NonlinearSVM(Estimator, ClassifierMixin):
    def __init__(self, kernel="rbf", C=1.0, degree=3, gamma="scale", coef0=0.0,
                 n_iter=300, lr=0.5, seed=0):
        self.kernel = kernel
        self.C = C
        self.degree = degree
        self.gamma = gamma
        self.coef0 = coef0
        self.n_iter = n_iter
        self.lr = lr
        self.seed = seed

    def _gamma_value(self, X):
        if self.gamma == "scale":
            var = X.var()
            return 1.0 / (X.shape[1] * var) if var > 0 else 1.0
        if self.gamma == "auto":
            return 1.0 / X.shape[1]
        return float(self.gamma)

    def fit(self, X, y):
        X, y = check_Xy(X, y)
        self.X_ = X
        self.classes_ = np.unique(y)
        self.gamma_ = self._gamma_value(X)
        K = _kernel_matrix(self.kernel, X, X, self.gamma_, self.degree, self.coef0)
        n = X.shape[0]
        n_cls = len(self.classes_)
        self.alpha_ = np.zeros((n_cls, n))
        self.b_ = np.zeros(n_cls)
        lam = 1.0 / (self.C * n)
        # Lipschitz-style step normalization: the hinge subgradient scales
        # with the Gram magnitude (large for unnormalized linear kernels)
        knorm = max(float(np.abs(np.diag(K)).mean()), 1.0)
        for ci, c in enumerate(self.classes_):
            t = np.where(y == c, 1.0, -1.0)
            alpha = np.zeros(n)
            b = 0.0
            for it in range(self.n_iter):
                f = K @ alpha + b
                margin = t * f
                viol = margin < 1.0
                # subgradient of mean hinge + lam/2 * alpha K alpha
                g_alpha = lam * (K @ alpha) - (K[:, viol] @ t[viol]) / n
                g_b = -t[viol].sum() / n
                step = self.lr / ((1.0 + 0.1 * it) * knorm)
                alpha -= step * g_alpha
                b -= step * g_b
            self.alpha_[ci], self.b_[ci] = alpha, b
        return self

    def decision_function(self, X):
        X = np.asarray(X, dtype=np.float64)
        K = _kernel_matrix(self.kernel, X, self.X_, self.gamma_, self.degree, self.coef0)
        return K @ self.alpha_.T + self.b_[None, :]

    def predict(self, X):
        scores = self.decision_function(X)
        if len(self.classes_) == 2:
            # one-vs-rest on 2 classes: pick larger margin (columns mirror)
            return self.classes_[np.argmax(scores, axis=1)]
        return self.classes_[np.argmax(scores, axis=1)]
