"""CART decision trees (classifier + regressor), from scratch.

The decision tree is the paper's best classifier (Table 5: 100 % accuracy
after tuning, depth=13); criteria and splitter follow the paper's search
space (Table 1: criterion in {gini, entropy, log_loss}, splitter in
{best, random}).

Split search is vectorized per feature: sort the column once, build prefix
class-count (or sum/sumsq) tables, and evaluate the impurity decrease at
every boundary between distinct values in O(n) after the sort.
"""

from __future__ import annotations

import numpy as np

from repro.ml.base import ClassifierMixin, Estimator, RegressorMixin, check_Xy

_EPS = 1e-12


def _gini(counts: np.ndarray) -> np.ndarray:
    # counts: (..., n_classes) -> impurity (...)
    tot = counts.sum(axis=-1, keepdims=True)
    p = counts / np.maximum(tot, _EPS)
    return 1.0 - (p**2).sum(axis=-1)


def _entropy(counts: np.ndarray) -> np.ndarray:
    tot = counts.sum(axis=-1, keepdims=True)
    p = counts / np.maximum(tot, _EPS)
    with np.errstate(divide="ignore", invalid="ignore"):
        logp = np.where(p > 0, np.log2(np.maximum(p, _EPS)), 0.0)
    return -(p * logp).sum(axis=-1)


# sklearn's "log_loss" criterion is entropy up to the log base
_CRITERIA = {"gini": _gini, "entropy": _entropy, "log_loss": _entropy}


class _Node:
    __slots__ = ("feature", "threshold", "left", "right", "value")

    def __init__(self, value):
        self.feature = -1
        self.threshold = 0.0
        self.left = None
        self.right = None
        self.value = value  # class-probability vector or mean

    @property
    def is_leaf(self):
        return self.left is None


class _BaseTree(Estimator):
    def __init__(self, max_depth=None, min_samples_split=2, min_samples_leaf=1,
                 splitter="best", max_features=None, seed=0):
        self.max_depth = max_depth
        self.min_samples_split = min_samples_split
        self.min_samples_leaf = min_samples_leaf
        self.splitter = splitter
        self.max_features = max_features
        self.seed = seed

    # --- subclass hooks -------------------------------------------------
    def _leaf_value(self, y):  # pragma: no cover
        raise NotImplementedError

    def _impurity_gain(self, x_sorted, y_sorted):  # pragma: no cover
        """Return (best_gain, best_threshold) for one feature column."""
        raise NotImplementedError

    # --- shared fit/predict ---------------------------------------------
    def _fit_arrays(self, X, y):
        self.n_features_ = X.shape[1]
        rng = np.random.default_rng(self.seed)
        max_feats = self.max_features or self.n_features_
        depth_cap = self.max_depth if self.max_depth is not None else np.inf

        def build(idx, depth):
            node = _Node(self._leaf_value(y[idx]))
            if (
                depth >= depth_cap
                or idx.size < self.min_samples_split
                or self._is_pure(y[idx])
            ):
                return node
            feats = (
                rng.choice(self.n_features_, size=max_feats, replace=False)
                if max_feats < self.n_features_
                else np.arange(self.n_features_)
            )
            if self.splitter == "random":
                feats = rng.permutation(feats)
            best = (0.0, -1, 0.0)  # gain, feature, threshold
            for f in feats:
                col = X[idx, f]
                order = np.argsort(col, kind="stable")
                gain, thr = self._impurity_gain(col[order], y[idx][order])
                if gain > best[0] + _EPS:
                    best = (gain, f, thr)
                    if self.splitter == "random" and gain > 0:
                        break  # first improving feature, à la random splitter
            gain, f, thr = best
            if f < 0:
                return node
            mask = X[idx, f] <= thr
            li, ri = idx[mask], idx[~mask]
            if li.size < self.min_samples_leaf or ri.size < self.min_samples_leaf:
                return node
            node.feature, node.threshold = int(f), float(thr)
            node.left = build(li, depth + 1)
            node.right = build(ri, depth + 1)
            return node

        self.root_ = build(np.arange(X.shape[0]), 0)
        return self

    def _predict_values(self, X):
        X = np.asarray(X, dtype=np.float64)
        out = []
        for row in X:
            node = self.root_
            while not node.is_leaf:
                node = node.left if row[node.feature] <= node.threshold else node.right
            out.append(node.value)
        return np.asarray(out)

    def depth(self) -> int:
        def d(node):
            return 0 if node.is_leaf else 1 + max(d(node.left), d(node.right))

        return d(self.root_)


class DecisionTreeClassifier(_BaseTree, ClassifierMixin):
    def __init__(self, criterion="gini", **kw):
        super().__init__(**kw)
        if criterion not in _CRITERIA:
            raise ValueError(f"criterion must be one of {sorted(_CRITERIA)}")
        self.criterion = criterion

    def fit(self, X, y):
        X, y = check_Xy(X, y)
        self.classes_, y_enc = np.unique(y, return_inverse=True)
        self.n_classes_ = len(self.classes_)
        self._imp = _CRITERIA[self.criterion]
        return self._fit_arrays(X, y_enc)

    def _is_pure(self, y):
        return np.all(y == y[0])

    def _leaf_value(self, y):
        return np.bincount(y, minlength=self.n_classes_) / max(y.size, 1)

    def _impurity_gain(self, x_sorted, y_sorted):
        n = y_sorted.size
        onehot = np.zeros((n, self.n_classes_))
        onehot[np.arange(n), y_sorted] = 1.0
        left = np.cumsum(onehot, axis=0)  # counts left of boundary i (inclusive)
        total = left[-1]
        # candidate boundaries: positions where x changes
        change = np.nonzero(np.diff(x_sorted) > _EPS)[0]
        if change.size == 0:
            return 0.0, 0.0
        nl = (change + 1).astype(np.float64)
        nr = n - nl
        cl = left[change]
        cr = total[None, :] - cl
        parent = self._imp(total[None, :])[0]
        child = (nl * self._imp(cl) + nr * self._imp(cr)) / n
        gains = parent - child
        k = int(np.argmax(gains))
        thr = 0.5 * (x_sorted[change[k]] + x_sorted[change[k] + 1])
        return float(gains[k]), float(thr)

    def predict_proba(self, X):
        return self._predict_values(X)

    def predict(self, X):
        return self.classes_[np.argmax(self._predict_values(X), axis=1)]


class DecisionTreeRegressor(_BaseTree, RegressorMixin):
    """Variance-reduction (MSE) regression tree."""

    def fit(self, X, y):
        X, y = check_Xy(X, y)
        return self._fit_arrays(X, y.astype(np.float64))

    def _is_pure(self, y):
        return y.size <= 1 or np.ptp(y) < _EPS

    def _leaf_value(self, y):
        return float(y.mean()) if y.size else 0.0

    def _impurity_gain(self, x_sorted, y_sorted):
        n = y_sorted.size
        csum = np.cumsum(y_sorted)
        csum2 = np.cumsum(y_sorted**2)
        change = np.nonzero(np.diff(x_sorted) > _EPS)[0]
        if change.size == 0:
            return 0.0, 0.0
        nl = (change + 1).astype(np.float64)
        nr = n - nl
        sl, sl2 = csum[change], csum2[change]
        sr, sr2 = csum[-1] - sl, csum2[-1] - sl2
        var_l = sl2 - sl**2 / nl
        var_r = sr2 - sr**2 / np.maximum(nr, _EPS)
        parent = csum2[-1] - csum[-1] ** 2 / n
        gains = (parent - (var_l + var_r)) / n
        k = int(np.argmax(gains))
        thr = 0.5 * (x_sorted[change[k]] + x_sorted[change[k] + 1])
        return float(gains[k]), float(thr)

    def predict(self, X):
        return self._predict_values(X)
