from repro.models.model import (
    block_specs,
    cache_specs,
    decode_step,
    forward,
    model_specs,
    prefill,
)
from repro.models.param import (
    ParamSpec,
    abstract_params,
    axes_tree,
    init_params,
    param_count,
    stack_specs,
)

__all__ = [
    "block_specs",
    "cache_specs",
    "decode_step",
    "forward",
    "model_specs",
    "prefill",
    "ParamSpec",
    "abstract_params",
    "axes_tree",
    "init_params",
    "param_count",
    "stack_specs",
]
