"""Shared transformer layers: RMSNorm, RoPE, chunked-flash GQA attention,
gated FFNs. Pure functions over param pytrees (models/param.py).

Attention is implemented flash-style in jnp: the KV axis is processed in
chunks with a running (max, denominator, accumulator) carry, bounding the
transient to S*chunk instead of S^2 — required for the 32k prefill cells.
``unroll=True`` fully unrolls the chunk scan so XLA cost analysis counts
every chunk (the dry-run's cost-accurate lowering; DESIGN.md roofline notes).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.partition import hint
from repro.models.param import ParamSpec

NEG_INF = -1e30


# --------------------------------------------------------------------- norms
def rmsnorm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), (None,), init="ones")


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------- RoPE
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (B, T, H, dh); positions: (B, T) or (1, T)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, T, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------- flash attention
def _mask(q_pos, kv_pos, kv_valid, *, window: int, prefix_len: int):
    """(B, Tq, C) boolean mask from positions.

    causal always; ``window`` > 0 limits lookback; ``prefix_len`` > 0 makes
    keys inside the prefix visible to every query (prefix-LM)."""
    qp = q_pos[:, :, None]  # (B, Tq, 1)
    kp = kv_pos[:, None, :]  # (B, 1, C)
    ok = kp <= qp
    if window > 0:
        ok &= kp > qp - window
    if prefix_len > 0:
        ok |= kp < prefix_len
    return ok & kv_valid[:, None, :]


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_pos: jax.Array,
    kv_pos: jax.Array,
    kv_valid: jax.Array,
    window: int = 0,
    prefix_len: int = 0,
    chunk: int = 512,
    unroll: bool = False,
) -> jax.Array:
    """q: (B,Tq,H,dh); k/v: (B,S,H,dh) (kv heads already repeated to H).
    Returns (B,Tq,H,dh)."""
    B, Tq, H, dh = q.shape
    S = k.shape[1]
    scale = dh**-0.5
    qf = q.astype(jnp.float32) * scale

    if Tq == 1 or S <= chunk:
        # single-block path (decode, short sequences): no scan needed
        scores = jnp.einsum(
            "bqhd,bkhd->bhqk", qf, k.astype(jnp.float32)
        )
        m = _mask(q_pos, kv_pos, kv_valid, window=window, prefix_len=prefix_len)
        scores = hint(
            jnp.where(m[:, None, :, :], scores, NEG_INF),
            ("batch", "heads", None, None),
        )
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, v.astype(jnp.float32))
        return out.astype(q.dtype)

    if S % chunk:
        # pad the KV axis to the chunk quantum; padded slots are invalid
        pad = chunk - S % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)))
        kv_valid = jnp.pad(kv_valid, ((0, 0), (0, pad)))
        S += pad
    nc = S // chunk
    ks = k.reshape(B, nc, chunk, H, dh).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nc, chunk, H, dh).transpose(1, 0, 2, 3, 4)
    kps = kv_pos.reshape(B, nc, chunk).transpose(1, 0, 2)
    kvs = kv_valid.reshape(B, nc, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        m_run, l_run, acc = carry
        k_c, v_c, kp_c, kv_c = xs
        scores = jnp.einsum("bqhd,bkhd->bhqk", qf, k_c.astype(jnp.float32))
        msk = _mask(q_pos, kp_c, kv_c, window=window, prefix_len=prefix_len)
        scores = hint(
            jnp.where(msk[:, None, :, :], scores, NEG_INF),
            ("batch", "heads", None, None),
        )
        m_new = jnp.maximum(m_run, scores.max(axis=-1))
        p = jnp.where(msk[:, None, :, :], jnp.exp(scores - m_new[..., None]), 0.0)
        alpha = jnp.exp(m_run - m_new)
        l_new = l_run * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, v_c.astype(jnp.float32)
        )
        return (m_new, l_new, acc), None

    init = (
        jnp.full((B, H, Tq), NEG_INF, jnp.float32),
        jnp.zeros((B, H, Tq), jnp.float32),
        jnp.zeros((B, H, Tq, dh), jnp.float32),
    )
    (m_run, l_run, acc), _ = jax.lax.scan(
        body, init, (ks, vs, kps, kvs), unroll=nc if unroll else 1
    )
    out = jnp.where(l_run[..., None] > 0, acc / jnp.maximum(l_run[..., None], 1e-30), 0.0)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)  # (B,Tq,H,dh)


# ------------------------------------------------------------- GQA attention
def attention_specs(cfg: ModelConfig) -> dict:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    specs = {
        "wq": ParamSpec((d, h, dh), ("embed", "heads", None)),
        "wk": ParamSpec((d, kv, dh), ("embed", "kv", None)),
        "wv": ParamSpec((d, kv, dh), ("embed", "kv", None)),
        "wo": ParamSpec((h, dh, d), ("heads", None, "embed")),
    }
    if cfg.qk_norm:
        specs["q_norm"] = rmsnorm_spec(dh)
        specs["k_norm"] = rmsnorm_spec(dh)
    return specs


def attention(
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    cache: dict | None = None,
    window: int = 0,
    unroll: bool = False,
) -> tuple[jax.Array, dict | None]:
    """x: (B, T, D); positions: (B, T). With ``cache`` (decode), writes the
    new K/V at ``positions`` and attends over the cache."""
    B, T, D = x.shape
    cd = jnp.dtype(cfg.compute_dtype)
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(cd))
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"].astype(cd))
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"].astype(cd))
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"])
        k = rmsnorm(k, params["k_norm"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if cache is None:
        kv_pos = positions
        kv_valid = jnp.ones((B, T), bool)
        k_all, v_all = k, v
        new_cache = None
    else:
        # scatter this step's K/V into the cache at `positions`
        S = cache["k"].shape[1]
        b_idx = jnp.arange(B)[:, None]
        k_all = cache["k"].at[b_idx, positions].set(k.astype(cache["k"].dtype))
        v_all = cache["v"].at[b_idx, positions].set(v.astype(cache["v"].dtype))
        new_cache = {"k": k_all, "v": v_all}
        kv_pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        kv_valid = kv_pos <= positions[:, -1:]
        k_all = k_all.astype(cd)
        v_all = v_all.astype(cd)

    n_rep = cfg.n_heads // cfg.n_kv_heads
    if n_rep > 1:
        # kv-repeat to the full head count; pin the result to the model axis
        # (kv alone may not divide tp; the repeated dim does)
        k_all = hint(jnp.repeat(k_all, n_rep, axis=2), ("batch", None, "heads", None))
        v_all = hint(jnp.repeat(v_all, n_rep, axis=2), ("batch", None, "heads", None))

    out = flash_attention(
        q,
        k_all,
        v_all,
        q_pos=positions,
        kv_pos=kv_pos,
        kv_valid=kv_valid,
        window=window,
        prefix_len=cfg.prefix_len if cfg.prefix_lm else 0,
        chunk=cfg.attn_chunk,
        unroll=unroll,
    )
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"].astype(cd))
    return y, new_cache


def attention_cache_spec(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    spec = ParamSpec((batch, max_len, kv, dh), ("batch", "kv_seq", "kv", None), init="zeros")
    return {"k": spec, "v": spec}


# ------------------------------------------------------------------ MLP / FFN
def mlp_specs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff if d_ff is not None else cfg.d_ff
    if cfg.mlp_kind == "gelu":
        return {
            "w_up": ParamSpec((d, f), ("embed", "ffn")),
            "w_down": ParamSpec((f, d), ("ffn", "embed")),
        }
    return {
        "w_gate": ParamSpec((d, f), ("embed", "ffn")),
        "w_up": ParamSpec((d, f), ("embed", "ffn")),
        "w_down": ParamSpec((f, d), ("ffn", "embed")),
    }


def mlp(
    params: dict, x: jax.Array, cfg: ModelConfig, *, engine=None, name: str = ""
) -> jax.Array:
    """Gated/gelu FFN. With ``engine`` (an ``EngineHandle`` from
    models/sparse_linear.py) every matmul dispatches through the sparse
    inference engine under the key ``{name}.mlp.<w>`` — planned SpMV kernels
    for registered pruned weights, dense contraction otherwise."""
    cd = jnp.dtype(cfg.compute_dtype)

    def mm(key, h, w):
        w = w.astype(cd)
        if engine is None:
            return jnp.einsum("btd,df->btf", h, w)
        return engine.matmul(f"{name}.mlp.{key}", h, w)

    if cfg.mlp_kind == "gelu":
        h = jax.nn.gelu(mm("w_up", x, params["w_up"]))
        return mm("w_down", h, params["w_down"])
    act = jax.nn.silu if cfg.mlp_kind == "swiglu" else jax.nn.gelu
    g = act(mm("w_gate", x, params["w_gate"]))
    u = mm("w_up", x, params["w_up"])
    return mm("w_down", g * u, params["w_down"])
