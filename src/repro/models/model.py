"""Decoder-only LM assembly over the block vocabulary.

Layer layout = optional ``first_blocks`` (unrolled) + ``pattern`` repeated
``n_groups`` times (jax.lax.scan over stacked params — keeps HLO size O(1)
in depth; a 61-layer 1T-param model lowers in seconds) + ``tail_blocks``
(unrolled remainder). ``unroll_layers=True`` unrolls the group scan for the
dry-run's cost-accurate lowering (launch/dryrun.py lowers depth 1 and 2 and
extrapolates — exact for depth-linear costs).

Three entry points: ``forward`` (train, no cache), ``prefill`` (fills the
serving cache over a full prompt) and ``decode_step`` (one token).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.partition import hint
from repro.models.layers import (
    attention,
    attention_cache_spec,
    attention_specs,
    mlp,
    mlp_specs,
    rmsnorm,
    rmsnorm_spec,
)
from repro.models.moe import moe_ffn, moe_specs
from repro.models.param import ParamSpec, stack_specs
from repro.models.recurrent import (
    mlstm_block,
    mlstm_cache_spec,
    mlstm_specs,
    rglru,
    rglru_cache_spec,
    rglru_specs,
    slstm_block,
    slstm_cache_spec,
    slstm_specs,
)

# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------


def block_specs(cfg: ModelConfig, kind: str) -> dict:
    d = cfg.d_model
    if kind in ("attn", "local"):
        return {
            "ln1": rmsnorm_spec(d),
            "attn": attention_specs(cfg),
            "ln2": rmsnorm_spec(d),
            "mlp": mlp_specs(cfg),
        }
    if kind == "moe":
        return {
            "ln1": rmsnorm_spec(d),
            "attn": attention_specs(cfg),
            "ln2": rmsnorm_spec(d),
            "moe": moe_specs(cfg),
        }
    if kind == "rec":
        return {
            "ln1": rmsnorm_spec(d),
            "rec": rglru_specs(cfg),
            "ln2": rmsnorm_spec(d),
            "mlp": mlp_specs(cfg),
        }
    if kind == "mlstm":
        return mlstm_specs(cfg)
    if kind == "slstm":
        return slstm_specs(cfg)
    raise ValueError(f"unknown block kind {kind!r}")


def model_specs(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    specs: dict[str, Any] = {
        "embed": ParamSpec((v, d), ("vocab", "embed"), scale=1.0),
        "head": tuple(block_specs(cfg, k) for k in cfg.first_blocks),
        "groups": tuple(
            stack_specs(block_specs(cfg, k), cfg.n_groups) for k in cfg.pattern
        )
        if cfg.n_groups
        else (),
        "tail": tuple(block_specs(cfg, k) for k in cfg.tail_blocks),
        "final_norm": rmsnorm_spec(d),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((d, v), ("embed", "vocab"))
    return specs


def block_cache_spec(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    if kind in ("attn", "moe"):
        return attention_cache_spec(cfg, batch, max_len)
    if kind == "local":
        w = min(cfg.window, max_len)
        spec = attention_cache_spec(cfg, batch, w)
        spec["pos"] = ParamSpec((batch, w), ("batch", None), init="zeros", dtype="int32")
        return spec
    if kind == "rec":
        return rglru_cache_spec(cfg, batch)
    if kind == "mlstm":
        return mlstm_cache_spec(cfg, batch)
    if kind == "slstm":
        return slstm_cache_spec(cfg, batch)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Materialize a fresh serving cache with correct initial values (e.g.
    the sLSTM normalizer starts at ones, attention K/V at zeros)."""
    from repro.models.param import init_params

    return init_params(cache_specs(cfg, batch, max_len), jax.random.PRNGKey(0), "float32")


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    return {
        "head": tuple(block_cache_spec(cfg, k, batch, max_len) for k in cfg.first_blocks),
        "groups": tuple(
            stack_specs(block_cache_spec(cfg, k, batch, max_len), cfg.n_groups)
            for k in cfg.pattern
        )
        if cfg.n_groups
        else (),
        "tail": tuple(block_cache_spec(cfg, k, batch, max_len) for k in cfg.tail_blocks),
    }


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------


def _local_attention(params, x, cfg, *, positions, cache, unroll):
    """Windowed attention; ring cache of width W on the serve path."""
    if cache is None:
        y, _ = attention(
            params, x, cfg, positions=positions, cache=None, window=cfg.window, unroll=unroll
        )
        return y, None
    # ring cache: keep the last W tokens' K/V with absolute positions
    from repro.models.layers import flash_attention, rope

    B, T, D = x.shape
    W = cache["k"].shape[1]
    cd = jnp.dtype(cfg.compute_dtype)
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(cd))
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"].astype(cd))
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"].astype(cd))
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"])
        k = rmsnorm(k, params["k_norm"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    keep = min(W, T)
    slots = positions[:, -keep:] % W
    b_idx = jnp.arange(B)[:, None]
    k_all = cache["k"].at[b_idx, slots].set(k[:, -keep:].astype(cache["k"].dtype))
    v_all = cache["v"].at[b_idx, slots].set(v[:, -keep:].astype(cache["v"].dtype))
    pos_all = cache["pos"].at[b_idx, slots].set(positions[:, -keep:].astype(jnp.int32) + 1)
    new_cache = {"k": k_all, "v": v_all, "pos": pos_all}
    if T > 1:
        # prefill: attend within the prompt itself (windowed)
        y, _ = attention(
            params, x, cfg, positions=positions, cache=None, window=cfg.window, unroll=unroll
        )
        return y, new_cache
    n_rep = cfg.n_heads // cfg.n_kv_heads
    kr = jnp.repeat(k_all.astype(cd), n_rep, axis=2) if n_rep > 1 else k_all.astype(cd)
    vr = jnp.repeat(v_all.astype(cd), n_rep, axis=2) if n_rep > 1 else v_all.astype(cd)
    out = flash_attention(
        q,
        kr,
        vr,
        q_pos=positions,
        kv_pos=pos_all - 1,
        kv_valid=pos_all > 0,
        window=cfg.window,
        chunk=cfg.attn_chunk,
    )
    y = jnp.einsum("bthk,hkd->btd", out, params["wo"].astype(cd))
    return y, new_cache


def _rglru_with_state(params, x, cfg, *, cache):
    """RG-LRU supporting prefill (T>1 with carried state)."""
    if cache is None or x.shape[1] == 1:
        return rglru(params, x, cfg, cache=cache)
    # prefill: fold the initial state into the first step, keep final state
    from repro.models.recurrent import _causal_conv1d, _lru_gates

    cd = jnp.dtype(cfg.compute_dtype)
    xb = jnp.einsum("btd,dr->btr", x, params["w_in"].astype(cd))
    gb = jnp.einsum("btd,dr->btr", x, params["w_gate"].astype(cd))
    xc, new_conv = _causal_conv1d(
        xb, params["conv_w"].astype(cd), params["conv_b"].astype(cd), cache["conv"]
    )
    a, bx = _lru_gates(params, xc, cfg)
    bx = bx.at[:, 0].add(a[:, 0] * cache["h"].astype(jnp.float32))

    def combine(u, v_):
        a1, b1 = u
        a2, b2 = v_
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    y = (h.astype(cd) * jax.nn.gelu(gb)).astype(cd)
    out = jnp.einsum("btr,rd->btd", y, params["w_out"].astype(cd))
    return out, {"h": h[:, -1], "conv": new_conv}


def apply_block(
    kind: str,
    params: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    cache: dict | None,
    unroll_attn: bool = False,
    engine=None,
    name: str = "",
):
    """Returns (x, new_cache, (moe_aux, tokens_per_expert)).

    ``engine``/``name`` route this block's FFN matmuls through the sparse
    inference engine (models/sparse_linear.py) under ``{name}.mlp.*`` /
    ``{name}.moe.*`` keys; attention and recurrence stay dense."""
    zero_aux = (jnp.zeros((), jnp.float32), jnp.zeros((max(cfg.n_experts, 1),), jnp.float32))
    if kind in ("attn", "moe"):
        a, new_cache = attention(
            params["attn"],
            rmsnorm(x, params["ln1"]),
            cfg,
            positions=positions,
            cache=cache,
            window=0,
            unroll=unroll_attn,
        )
        x = x + a
        h = rmsnorm(x, params["ln2"])
        if kind == "moe":
            y, aux, counts = moe_ffn(params["moe"], h, cfg, engine=engine, name=name)
            return x + y, new_cache, (aux, counts)
        return x + mlp(params["mlp"], h, cfg, engine=engine, name=name), new_cache, zero_aux
    if kind == "local":
        a, new_cache = _local_attention(
            params["attn"],
            rmsnorm(x, params["ln1"]),
            cfg,
            positions=positions,
            cache=cache,
            unroll=unroll_attn,
        )
        x = x + a
        y = mlp(params["mlp"], rmsnorm(x, params["ln2"]), cfg, engine=engine, name=name)
        return x + y, new_cache, zero_aux
    if kind == "rec":
        r, new_cache = _rglru_with_state(params["rec"], rmsnorm(x, params["ln1"]), cfg, cache=cache)
        x = x + r
        y = mlp(params["mlp"], rmsnorm(x, params["ln2"]), cfg, engine=engine, name=name)
        return x + y, new_cache, zero_aux
    if kind == "mlstm":
        x, new_cache = mlstm_block(params, x, cfg, cache=cache)
        return x, new_cache, zero_aux
    if kind == "slstm":
        x, new_cache = slstm_block(params, x, cfg, cache=cache)
        return x, new_cache, zero_aux
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# model entry points
# ---------------------------------------------------------------------------


def _embed(params, cfg, tokens=None, embeds=None, prefix_embeds=None):
    cd = jnp.dtype(cfg.compute_dtype)
    if embeds is not None:
        x = embeds.astype(cd)
    else:
        x = params["embed"][tokens].astype(cd)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cd), x], axis=1)
    return hint(x, ("batch", "seq", None))


def _logits(params, cfg, x):
    h = rmsnorm(x, params["final_norm"])
    if cfg.tie_embeddings:
        logits = jnp.einsum("btd,vd->btv", h, params["embed"].astype(h.dtype))
    else:
        logits = jnp.einsum("btd,dv->btv", h, params["lm_head"].astype(h.dtype))
    logits = hint(logits.astype(jnp.float32), ("batch", None, "vocab"))
    if cfg.logits_softcap:
        c = cfg.logits_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def _run_blocks(params, cfg, x, *, positions, cache, unroll_attn, unroll_layers, engine=None):
    if engine is not None and cfg.n_groups and not unroll_layers:
        raise ValueError(
            "a sparse inference engine dispatches per-layer host-planned "
            "kernels, which cannot live inside the group scan over stacked "
            "params — call with unroll_layers=True to serve sparse"
        )
    aux_l = jnp.zeros((), jnp.float32)
    aux_c = jnp.zeros((max(cfg.n_experts, 1),), jnp.float32)
    new_cache: dict[str, Any] = {"head": [], "groups": [], "tail": []}

    def run_list(kinds, plist, clist, x, aux_l, aux_c, out_key):
        for i, (kind, p, c) in enumerate(zip(kinds, plist, clist)):
            x, nc, (al, ac) = apply_block(
                kind, p, x, cfg, positions=positions, cache=c,
                unroll_attn=unroll_attn, engine=engine, name=f"{out_key}{i}",
            )
            aux_l, aux_c = aux_l + al, aux_c + ac
            new_cache[out_key].append(nc)
        return x, aux_l, aux_c

    head_caches = cache["head"] if cache else [None] * len(cfg.first_blocks)
    x, aux_l, aux_c = run_list(cfg.first_blocks, params["head"], head_caches, x, aux_l, aux_c, "head")

    for pi, kind in enumerate(cfg.pattern if cfg.n_groups else ()):
        pstack = params["groups"][pi]
        cstack = cache["groups"][pi] if cache else None

        def group_fn(carry, xs, kind=kind, eng=None, name=""):
            xx, al, ac = carry
            p, c = xs
            xx, nc, (dl, dc) = apply_block(
                kind, p, xx, cfg, positions=positions, cache=c,
                unroll_attn=unroll_attn, engine=eng, name=name,
            )
            xx = hint(xx, ("batch", "seq", None))
            return (xx, al + dl, ac + dc), nc

        body = jax.checkpoint(group_fn) if cfg.remat else group_fn
        if unroll_layers:
            ncs = []
            for g in range(cfg.n_groups):
                p_g = jax.tree.map(lambda a: a[g], pstack)
                c_g = jax.tree.map(lambda a: a[g], cstack) if cstack is not None else None
                if engine is None:
                    (x, aux_l, aux_c), nc = body((x, aux_l, aux_c), (p_g, c_g))
                else:
                    # host-planned kernels under remat could re-trace on the
                    # backward pass; the engine path is inference-only, so
                    # skip the checkpoint wrapper and name the layer
                    (x, aux_l, aux_c), nc = group_fn(
                        (x, aux_l, aux_c), (p_g, c_g), eng=engine, name=f"g{pi}x{g}"
                    )
                ncs.append(nc)
            nc_stacked = (
                jax.tree.map(lambda *a: jnp.stack(a), *ncs) if cache else None
            )
        else:
            (x, aux_l, aux_c), nc_stacked = jax.lax.scan(
                body, (x, aux_l, aux_c), (pstack, cstack)
            )
        new_cache["groups"].append(nc_stacked)

    tail_caches = cache["tail"] if cache else [None] * len(cfg.tail_blocks)
    x, aux_l, aux_c = run_list(cfg.tail_blocks, params["tail"], tail_caches, x, aux_l, aux_c, "tail")

    out_cache = (
        {
            "head": tuple(new_cache["head"]),
            "groups": tuple(new_cache["groups"]),
            "tail": tuple(new_cache["tail"]),
        }
        if cache
        else None
    )
    return x, out_cache, {"moe_aux": aux_l, "tokens_per_expert": aux_c}


def forward(
    params,
    cfg: ModelConfig,
    *,
    tokens=None,
    embeds=None,
    prefix_embeds=None,
    positions=None,
    unroll_attn: bool = False,
    unroll_layers: bool = False,
    engine=None,
):
    """Training forward: full sequence, no cache. Returns (logits, aux)."""
    x = _embed(params, cfg, tokens, embeds, prefix_embeds)
    B, T, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    x, _, aux = _run_blocks(
        params, cfg, x, positions=positions, cache=None,
        unroll_attn=unroll_attn, unroll_layers=unroll_layers, engine=engine,
    )
    return _logits(params, cfg, x), aux


def prefill(
    params,
    cfg: ModelConfig,
    cache,
    *,
    tokens=None,
    embeds=None,
    prefix_embeds=None,
    unroll_attn: bool = False,
    unroll_layers: bool = False,
    engine=None,
):
    """Serving prefill: runs the prompt, fills the cache.
    Returns (logits, cache, aux)."""
    x = _embed(params, cfg, tokens, embeds, prefix_embeds)
    B, T, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    x, cache, aux = _run_blocks(
        params, cfg, x, positions=positions, cache=cache,
        unroll_attn=unroll_attn, unroll_layers=unroll_layers, engine=engine,
    )
    return _logits(params, cfg, x), cache, aux


def decode_step(
    params,
    cfg: ModelConfig,
    cache,
    tokens,
    positions,
    *,
    unroll_layers: bool = False,
    engine=None,
):
    """One decoding step. tokens: (B, 1) int32; positions: (B, 1) int32 (the
    absolute index the new token occupies). Returns (logits, cache).

    ``engine`` routes the FFN matmuls through planned SpMV kernels (sparse
    serving); requires ``unroll_layers=True`` when the config has scanned
    layer groups."""
    x = _embed(params, cfg, tokens)
    x, cache, _ = _run_blocks(
        params, cfg, x, positions=positions, cache=cache,
        unroll_attn=False, unroll_layers=unroll_layers, engine=engine,
    )
    return _logits(params, cfg, x), cache
