"""Mixture-of-experts FFN with Auto-SpMV-selectable dispatch formats.

The router's token->expert assignment is a sparse matrix (rows = experts,
nnz per row = routed tokens). The three dispatch strategies are the paper's
storage formats in disguise (DESIGN.md §3):

* ``dense``  — every expert runs on every token, weighted by the routing
  probabilities (zeros computed, exactly like a dense SpMV). The paper's
  "dense formats are inefficient" baseline; only viable on small configs.
* ``ell``    — one fixed capacity C per expert; token ids are packed into an
  (E, C) index plane with zero-padding — ELLPACK on the assignment matrix.
* ``sell``   — two capacity classes: the hottest E/8 experts get 4C, the
  rest C/2 — a two-slice SELL that cuts padding on skewed routing while
  dropping fewer tokens on hot experts.

``repro.core.features.features_from_assignment_histogram`` turns the routing
histogram into Table-2 features so the run-time mode can pick the format.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.param import ParamSpec


def moe_specs(cfg: ModelConfig) -> dict:
    d, e, fe = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    specs = {
        "router": ParamSpec((d, e), ("embed", "experts"), dtype="float32"),
        "w_gate": ParamSpec((e, d, fe), ("experts", "embed", "ffn")),
        "w_up": ParamSpec((e, d, fe), ("experts", "embed", "ffn")),
        "w_down": ParamSpec((e, fe, d), ("experts", "ffn", "embed")),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * fe
        specs["shared"] = {
            "w_gate": ParamSpec((d, fs), ("embed", "ffn")),
            "w_up": ParamSpec((d, fs), ("embed", "ffn")),
            "w_down": ParamSpec((fs, d), ("ffn", "embed")),
        }
    return specs


def _capacity(T: int, cfg: ModelConfig) -> int:
    c = int(T * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max((c + 7) // 8 * 8, 8)


def _pack_by_expert(e_flat, t_flat, w_flat, n_rows: int, cap: int, row_of=None):
    """Pack flat (expert, token, weight) assignments into (n_rows, cap)
    planes — the ELL conversion of the assignment matrix. ``row_of`` maps an
    expert id to its output row (identity when None); assignments mapping to
    row -1 or overflowing the capacity land in spill slots and are dropped.
    """
    TK = e_flat.shape[0]
    order = jnp.argsort(e_flat, stable=True)
    e_s, t_s, w_s = e_flat[order], t_flat[order], w_flat[order]
    # position within each expert's run of the sorted assignment list
    first = jnp.searchsorted(e_s, e_s, side="left")
    pos = jnp.arange(TK) - first
    rows = e_s if row_of is None else row_of[e_s]
    ok = (pos < cap) & (rows >= 0)
    r_c = jnp.where(ok, rows, n_rows)  # spill row
    p_c = jnp.where(ok, pos, cap)  # spill col
    idx = jnp.zeros((n_rows + 1, cap + 1), jnp.int32).at[r_c, p_c].set(t_s)
    wgt = jnp.zeros((n_rows + 1, cap + 1), w_s.dtype).at[r_c, p_c].set(w_s)
    return idx[:n_rows, :cap], wgt[:n_rows, :cap]


def _expert_ffn(xg, w_gate, w_up, w_down, cd):
    """xg: (..., E, C, D) grouped tokens; expert-batched gated FFN."""
    g = jax.nn.silu(jnp.einsum("...ecd,edf->...ecf", xg, w_gate.astype(cd)))
    u = jnp.einsum("...ecd,edf->...ecf", xg, w_up.astype(cd))
    return jnp.einsum("...ecf,efd->...ecd", g * u, w_down.astype(cd))


def _route(params, x, cfg):
    """Router: fp32 softmax, top-k, renormalized weights."""
    logits = jnp.einsum("btd,de->bte", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, cfg.top_k)  # (B,T,K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    # GShard load-balance loss: E * sum_e fraction_e * prob_e
    K = cfg.top_k
    counts = jnp.zeros((x.shape[0], cfg.n_experts), jnp.float32)
    counts = jax.vmap(lambda c, e: c.at[e.reshape(-1)].add(1.0))(counts, top_e)
    frac = counts / (x.shape[1] * K)
    aux = cfg.n_experts * jnp.mean(jnp.sum(frac * probs.mean(axis=1), axis=-1))
    return top_e, top_w, counts, aux


def _gate_full(top_e, top_w, T: int, E: int, cd) -> jax.Array:
    """(B, T, E) dense routing weights: top_w scattered at top_e, 0 elsewhere."""
    B = top_e.shape[0]
    gate = jnp.zeros((B, T, E), cd)
    return jax.vmap(
        lambda g, e, w: g.at[jnp.arange(T)[:, None], e].set(w.astype(cd))
    )(gate, top_e, top_w)


def moe_ffn(
    params: dict, x: jax.Array, cfg: ModelConfig, *, engine=None, name: str = ""
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, T, D) -> (y, aux_loss, tokens_per_expert).

    With ``engine`` (sparse serving) every expert's pruned FFN slices run as
    planned SpMV matmuls under ``{name}.moe.<w>.<e>`` keys, weighted by the
    same dense gate the ``dispatch_format="dense"`` baseline uses — the two
    paths are exactly the same math, so sparse-served MoE logits match the
    dense reference. Requires ``dispatch_format="dense"``: ell/sell drop
    capacity-overflow tokens, which the per-expert loop does not reproduce.
    """
    B, T, D = x.shape
    cd = jnp.dtype(cfg.compute_dtype)
    E, K = cfg.n_experts, cfg.top_k
    top_e, top_w, counts, aux = _route(params, x, cfg)

    dispatch = cfg.dispatch_format
    if engine is not None and dispatch != "dense":
        raise ValueError(
            "sparse-expert serving needs dispatch_format='dense' (the gate-"
            f"masked per-expert path); got {dispatch!r} — override the config "
            "with .replace(dispatch_format='dense') when attaching an engine"
        )
    if engine is not None:
        gate_full = _gate_full(top_e, top_w, T, E, cd)
        xc = x.astype(cd)
        y = jnp.zeros((B, T, D), cd)
        for e in range(E):
            g = jax.nn.silu(
                engine.matmul(f"{name}.moe.w_gate.{e}", xc, params["w_gate"][e].astype(cd))
            )
            u = engine.matmul(f"{name}.moe.w_up.{e}", xc, params["w_up"][e].astype(cd))
            h = engine.matmul(f"{name}.moe.w_down.{e}", g * u, params["w_down"][e].astype(cd))
            y = y + h * gate_full[..., e : e + 1]
    elif dispatch == "dense":
        if T * E * cfg.d_ff_expert > (1 << 28):
            raise ValueError(
                "dense dispatch on a config this large would materialize "
                f"{T}x{E}x{cfg.d_ff_expert} activations; use ell/sell"
            )
        # every expert computes every token (the dense-format baseline)
        xe = jnp.broadcast_to(x[:, None, :, :], (B, E, T, D)).astype(cd)
        h = _expert_ffn(xe, params["w_gate"], params["w_up"], params["w_down"], cd)  # (B,E,T,D)
        gate_full = _gate_full(top_e, top_w, T, E, cd)
        y = jnp.einsum("betd,bte->btd", h, gate_full)
    elif dispatch in ("ell", "sell"):
        t_flat = jnp.broadcast_to(jnp.arange(T)[:, None], (T, K)).reshape(-1)

        def one_batch(xb, eb, wb, cb):
            e_flat = eb.reshape(-1)
            w_flat = wb.reshape(-1).astype(cd)
            pieces = []
            if dispatch == "ell":
                cap = _capacity(T, cfg)
                idx, wgt = _pack_by_expert(e_flat, t_flat, w_flat, E, cap)
                buckets = [(jnp.arange(E), idx, wgt)]
            else:
                base = _capacity(T, cfg)
                e_hot = max(E // 8, 1)
                cap_hot, cap_cold = 4 * base, max(base // 2, 8)
                hot_ids = jax.lax.top_k(cb, e_hot)[1]
                rank = jnp.full((E,), -1, jnp.int32).at[hot_ids].set(
                    jnp.arange(e_hot, dtype=jnp.int32)
                )
                idx_h, wgt_h = _pack_by_expert(e_flat, t_flat, w_flat, e_hot, cap_hot, row_of=rank)
                cold_row = jnp.where(rank >= 0, -1, jnp.arange(E, dtype=jnp.int32))
                idx_c, wgt_c = _pack_by_expert(e_flat, t_flat, w_flat, E, cap_cold, row_of=cold_row)
                buckets = [(hot_ids, idx_h, wgt_h), (jnp.arange(E), idx_c, wgt_c)]
            yb = jnp.zeros((T, D), cd)
            for ids, idx, wgt in buckets:
                xg = xb[idx]  # (rows, cap, D)
                h = _expert_ffn(
                    xg,
                    params["w_gate"][ids],
                    params["w_up"][ids],
                    params["w_down"][ids],
                    cd,
                )
                yb = yb.at[idx.reshape(-1)].add(
                    (h * wgt[..., None]).reshape(-1, D)
                )
            return yb

        y = jax.vmap(one_batch)(x.astype(cd), top_e, top_w, counts)
    else:
        raise ValueError(f"unknown dispatch format {dispatch!r}")

    if cfg.n_shared_experts:
        sh = params["shared"]
        if engine is None:
            g = jax.nn.silu(jnp.einsum("btd,df->btf", x, sh["w_gate"].astype(cd)))
            u = jnp.einsum("btd,df->btf", x, sh["w_up"].astype(cd))
            y = y + jnp.einsum("btf,fd->btd", g * u, sh["w_down"].astype(cd))
        else:
            g = jax.nn.silu(
                engine.matmul(f"{name}.moe.shared.w_gate", x, sh["w_gate"].astype(cd))
            )
            u = engine.matmul(f"{name}.moe.shared.w_up", x, sh["w_up"].astype(cd))
            y = y + engine.matmul(
                f"{name}.moe.shared.w_down", g * u, sh["w_down"].astype(cd)
            )
    return y.astype(x.dtype), aux, counts.sum(0)


def select_dispatch_format(tokens_per_expert) -> str:
    """Auto-SpMV run-time mode for MoE: pick the dispatch format from the
    routing histogram's sparsity features (host-side, between-step decision;
    jit specialization is per-format, like the paper's kernel selection)."""
    import numpy as np

    from repro.core.features import features_from_assignment_histogram

    f = features_from_assignment_histogram(np.asarray(tokens_per_expert))
    # skewed routing (low ELL efficiency) -> SELL two-slice dispatch
    if f.ell_ratio < 0.5 and f.std_nnz > 0.5 * max(f.avg_nnz, 1e-9):
        return "sell"
    return "ell"
