"""Parameter-spec trees: shapes + logical sharding axes, materializable
either as ShapeDtypeStructs (dry-run: no allocation) or as initialized
arrays (training). No flax in the environment — models are pure functions
over these pytrees.

Logical axis vocabulary (mapped to mesh axes by repro.dist.sharding):
  "vocab"    embedding rows / logits columns        -> model
  "embed"    d_model dim of weight matrices         -> data (FSDP / ZeRO-3)
  "heads"    fused attention-head dim               -> model
  "kv"       kv-head dim                            -> model if divisible
  "ffn"      feed-forward hidden                    -> model
  "experts"  expert dim of MoE weight stacks        -> (none; expert-TP via ffn)
  "rnn"      recurrent state width                  -> model
  "layers"   scanned layer-stack dim                -> (none)
  None       replicated
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"  # normal | zeros | ones
    scale: float = 1.0  # stddev multiplier for normal init
    dtype: str | None = None  # override the config param_dtype

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes} rank mismatch")


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def stack_specs(tree: Any, n: int, axis_name: str = "layers") -> Any:
    """Prepend a stacked (scanned) leading dim to every spec in the tree."""

    def add(spec: ParamSpec) -> ParamSpec:
        return dataclasses.replace(
            spec, shape=(n, *spec.shape), axes=(axis_name, *spec.axes)
        )

    return jax.tree.map(add, tree, is_leaf=is_spec)


def abstract_params(tree: Any, default_dtype: str) -> Any:
    """ShapeDtypeStruct tree — what the dry-run lowers against."""

    def conv(spec: ParamSpec):
        return jax.ShapeDtypeStruct(spec.shape, jnp.dtype(spec.dtype or default_dtype))

    return jax.tree.map(conv, tree, is_leaf=is_spec)


def axes_tree(tree: Any) -> Any:
    """Logical-axes tree (same structure, tuples at leaves)."""
    return jax.tree.map(lambda s: s.axes, tree, is_leaf=is_spec)


def init_params(tree: Any, rng: jax.Array, default_dtype: str) -> Any:
    """Materialize real parameters (smoke tests / the train example)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))

    def one(spec: ParamSpec, key):
        dtype = jnp.dtype(spec.dtype or default_dtype)
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dtype)
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = spec.scale / max(fan_in, 1) ** 0.5
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)

    return jax.tree.unflatten(treedef, [one(s, k) for s, k in zip(leaves, keys)])


def param_count(tree: Any) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_spec)
    total = 0
    for leaf in leaves:
        shape = leaf.shape
        n = 1
        for s in shape:
            n *= int(s)
        total += n
    return total


MapFn = Callable[[ParamSpec], Any]
