"""Recurrent sequence mixers: RG-LRU (RecurrentGemma), mLSTM + sLSTM (xLSTM).

TPU adaptation notes:
* RG-LRU is a diagonal linear recurrence -> ``jax.lax.associative_scan``
  (log-depth tree, fully counted by XLA cost analysis).
* mLSTM uses the chunkwise-parallel form: intra-chunk quadratic attention
  with decay + inter-chunk state combined by an associative scan over chunk
  summaries. All matmuls are batched over chunks (no sequential loop), so
  the MXU stays busy and HLO FLOPs are exact.
* sLSTM's recurrence is inherently sequential (the xLSTM paper says as
  much); it runs as a lax.scan over T. Its in-scan recurrent matmuls are
  undercounted by XLA cost analysis — the roofline tooling notes this and
  the analytic MODEL_FLOPS covers it.

Decode paths update O(1)-size states — why these archs run the long_500k
cell (DESIGN.md §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.param import ParamSpec

# ---------------------------------------------------------------------------
# RG-LRU block (Griffin / RecurrentGemma)
# ---------------------------------------------------------------------------


def rglru_specs(cfg: ModelConfig) -> dict:
    d, r, k = cfg.d_model, cfg.rnn_dim, cfg.conv1d_size
    h = cfg.n_heads
    rh = r // h
    return {
        "w_in": ParamSpec((d, r), ("embed", "rnn")),
        "w_gate": ParamSpec((d, r), ("embed", "rnn")),
        "conv_w": ParamSpec((k, r), (None, "rnn")),
        "conv_b": ParamSpec((r,), (None,), init="zeros"),
        "wa": ParamSpec((h, rh, rh), (None, None, None)),  # block-diag recurrence gate
        "ba": ParamSpec((r,), (None,), init="zeros"),
        "wx": ParamSpec((h, rh, rh), (None, None, None)),  # block-diag input gate
        "bx": ParamSpec((r,), (None,), init="zeros"),
        "lam": ParamSpec((r,), (None,), init="ones"),  # a = sigmoid(lam+4) ~ .98
        "w_out": ParamSpec((r, d), ("rnn", "embed")),
    }


def _causal_conv1d(x, w, b, state=None):
    """Depthwise causal conv along T. x: (B,T,R), w: (k,R).
    With ``state`` (B,k-1,R): single-step (T small) decode path; returns
    (y, new_state)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
        new_state = xp[:, -(k - 1) :, :] if k > 1 else None
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
        new_state = xp[:, -(k - 1) :, :] if k > 1 else None
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k)) + b
    return y.astype(x.dtype), new_state


def _lru_gates(params, xc, cfg):
    h = cfg.n_heads
    B, T, R = xc.shape
    xh = xc.reshape(B, T, h, R // h).astype(jnp.float32)
    r_t = jax.nn.sigmoid(
        jnp.einsum("bthr,hrs->bths", xh, params["wa"].astype(jnp.float32)).reshape(B, T, R)
        + params["ba"].astype(jnp.float32)
    )
    i_t = jax.nn.sigmoid(
        jnp.einsum("bthr,hrs->bths", xh, params["wx"].astype(jnp.float32)).reshape(B, T, R)
        + params["bx"].astype(jnp.float32)
    )
    # a_t = exp(-8 * softplus(lam) * r_t)   (Griffin eq. 4, c = 8)
    log_a = -8.0 * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r_t
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return a, beta * i_t * xc.astype(jnp.float32)


def rglru(params, x, cfg: ModelConfig, *, cache=None):
    """Full RG-LRU residual-block mixer. x: (B,T,D).
    cache: {"h": (B,R), "conv": (B,k-1,R)} for decode."""
    cd = jnp.dtype(cfg.compute_dtype)
    xb = jnp.einsum("btd,dr->btr", x, params["w_in"].astype(cd))
    gb = jnp.einsum("btd,dr->btr", x, params["w_gate"].astype(cd))
    conv_state = None if cache is None else cache["conv"]
    xc, new_conv = _causal_conv1d(xb, params["conv_w"].astype(cd), params["conv_b"].astype(cd), conv_state)
    a, bx = _lru_gates(params, xc, cfg)
    if cache is None:
        # diagonal linear recurrence via associative scan over T
        def combine(u, v):
            a1, b1 = u
            a2, b2 = v
            return a1 * a2, b1 * a2 + b2

        _, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
        new_cache = None
    else:
        h = a[:, 0] * cache["h"].astype(jnp.float32) + bx[:, 0]
        new_cache = {"h": h, "conv": new_conv}
        h = h[:, None, :]
    y = (h.astype(cd) * jax.nn.gelu(gb)).astype(cd)
    return jnp.einsum("btr,rd->btd", y, params["w_out"].astype(cd)), new_cache


def rglru_cache_spec(cfg: ModelConfig, batch: int) -> dict:
    r, k = cfg.rnn_dim, cfg.conv1d_size
    return {
        "h": ParamSpec((batch, r), ("batch", "rnn"), init="zeros", dtype="float32"),
        "conv": ParamSpec((batch, k - 1, r), ("batch", None, "rnn"), init="zeros"),
    }


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM) — chunkwise-parallel, sigma-gated variant
# ---------------------------------------------------------------------------


def mlstm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    m = 2 * d  # projection factor 2 (xLSTM-1.3B)
    h = cfg.n_heads
    dh = m // h
    return {
        "ln": ParamSpec((d,), (None,), init="ones"),
        "w_up": ParamSpec((d, 2 * m), ("embed", "ffn")),  # [mixer | gate] branches
        "wq": ParamSpec((m, h, dh), ("ffn", "heads", None)),
        "wk": ParamSpec((m, h, dh), ("ffn", "heads", None)),
        "wv": ParamSpec((m, h, dh), ("ffn", "heads", None)),
        "w_if": ParamSpec((m, 2 * h), ("ffn", None)),  # input/forget gates per head
        "out_norm": ParamSpec((m,), (None,), init="ones"),
        "w_down": ParamSpec((m, d), ("ffn", "embed")),
    }


def _mlstm_core(q, k, v, i_gate, f_gate, chunk: int, state=None):
    """Chunkwise linear attention with per-head scalar decay.

    q/k/v: (B,T,H,dh); i_gate/f_gate: (B,T,H) in (0,1).
    Returns (out (B,T,H,dh), final_state (C, n)).
    """
    B, T, H, dh = q.shape
    scale = dh**-0.5
    q = q.astype(jnp.float32) * scale
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    ki = k * i_gate[..., None]  # input gate scales the written key
    log_f = jnp.log(jnp.maximum(f_gate.astype(jnp.float32), 1e-9))

    if state is not None and T == 1:  # decode step
        C, n = state
        C = f_gate[:, 0, :, None, None] * C + jnp.einsum("bhk,bhv->bhkv", ki[:, 0], v[:, 0])
        n = f_gate[:, 0, :, None] * n + ki[:, 0]
        num = jnp.einsum("bhk,bhkv->bhv", q[:, 0], C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q[:, 0], n))[..., None], 1.0)
        return (num / den)[:, None], (C, n)

    T_orig = T
    if T % chunk:
        # pad with identity steps: f=1 (no decay), i=0 (nothing written)
        pad = chunk - T % chunk
        padT = lambda a, fill=0.0: jnp.pad(
            a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2), constant_values=fill
        )
        q, k, v = padT(q), padT(k), padT(v)
        ki = padT(ki)
        log_f = padT(log_f, 0.0)  # log f = 0 -> f = 1
        T += pad
    nc = T // chunk
    shp = (B, nc, chunk, H)
    qc = q.reshape(B, nc, chunk, H, dh)
    kc = ki.reshape(B, nc, chunk, H, dh)
    vc = v.reshape(B, nc, chunk, H, dh)
    lf = log_f.reshape(shp)
    cums = jnp.cumsum(lf, axis=2)  # inclusive cumulative log-decay
    total = cums[:, :, -1, :]  # (B,nc,H)

    # ---- chunk summaries: S_c = sum_s exp(total - cums_s) k_s v_s^T
    wk = jnp.exp(total[:, :, None, :] - cums)  # decay from step s to chunk end
    S_c = jnp.einsum("bnch,bnchk,bnchv->bnhkv", wk, kc, vc)
    n_c = jnp.einsum("bnch,bnchk->bnhk", wk, kc)

    # ---- inter-chunk recurrence over chunk axis (associative scan)
    def combine(u, x_):
        a1, S1, n1 = u
        a2, S2, n2 = x_
        return a1 * a2, S1 * a2[..., None, None] + S2, n1 * a2[..., None] + n2

    A = jnp.exp(total)
    _, S_pref, n_pref = jax.lax.associative_scan(combine, (A, S_c, n_c), axis=1)
    zeroS = jnp.zeros_like(S_pref[:, :1])
    zeron = jnp.zeros_like(n_pref[:, :1])
    S_prev = jnp.concatenate([zeroS, S_pref[:, :-1]], axis=1)  # state before chunk
    n_prev = jnp.concatenate([zeron, n_pref[:, :-1]], axis=1)
    if state is not None:
        C0, n0 = state
        pref_decay = jnp.concatenate([jnp.ones_like(A[:, :1]), jnp.cumprod(A, 1)[:, :-1]], 1)
        S_prev = S_prev + pref_decay[..., None, None] * C0[:, None]
        n_prev = n_prev + pref_decay[..., None] * n0[:, None]

    # ---- outputs: inter (q against carried state) + intra (masked attn)
    wq = jnp.exp(cums)  # decay from chunk start through step t
    inter = jnp.einsum("bnthk,bnhkv->bnthv", qc * wq[..., None], S_prev)
    inter_n = jnp.einsum("bnthk,bnhk->bnth", qc * wq[..., None], n_prev)
    # intra: D[t,s] = exp(cums_t - cums_s) for s <= t
    ld = cums[:, :, :, None, :] - cums[:, :, None, :, :]  # (B,nc,t,s,H)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    D = jnp.where(causal[None, None, :, :, None], jnp.exp(ld), 0.0)
    scores = jnp.einsum("bnthk,bnshk->bntsh", qc, kc) * D
    intra = jnp.einsum("bntsh,bnshv->bnthv", scores, vc)
    intra_n = jnp.sum(scores, axis=3)
    num = inter + intra
    den = jnp.maximum(jnp.abs(inter_n + intra_n)[..., None], 1.0)
    out = (num / den).reshape(B, T, H, dh)[:, :T_orig]

    C_fin, n_fin = S_pref[:, -1], n_pref[:, -1]
    if state is not None:
        totA = jnp.prod(A, axis=1)
        C_fin = C_fin + totA[..., None, None] * state[0]
        n_fin = n_fin + totA[..., None] * state[1]
    return out, (C_fin, n_fin)


def mlstm_block(params, x, cfg: ModelConfig, *, cache=None):
    """Pre-norm mLSTM block with gated output. x: (B,T,D)."""
    from repro.models.layers import rmsnorm

    cd = jnp.dtype(cfg.compute_dtype)
    B, T, D = x.shape
    h = cfg.n_heads
    xin = rmsnorm(x, params["ln"])
    up = jnp.einsum("btd,dm->btm", xin, params["w_up"].astype(cd))
    m = up.shape[-1] // 2
    xm, zg = up[..., :m], up[..., m:]
    q = jnp.einsum("btm,mhk->bthk", xm, params["wq"].astype(cd))
    k = jnp.einsum("btm,mhk->bthk", xm, params["wk"].astype(cd))
    v = jnp.einsum("btm,mhk->bthk", xm, params["wv"].astype(cd))
    gates = jax.nn.sigmoid(
        jnp.einsum("btm,mg->btg", xm, params["w_if"].astype(cd)).astype(jnp.float32)
    )
    i_g, f_g = gates[..., :h], gates[..., h:]
    # long-memory bias: keep forget gates near 1
    f_g = 0.9 + 0.1 * f_g
    state = None if cache is None else (cache["C"], cache["n"])
    out, (C_f, n_f) = _mlstm_core(q, k, v, i_g, f_g, cfg.mlstm_chunk, state)
    out = out.reshape(B, T, m).astype(cd)
    out = rmsnorm(out, params["out_norm"]) * jax.nn.silu(zg)
    y = jnp.einsum("btm,md->btd", out, params["w_down"].astype(cd))
    new_cache = (
        None
        if cache is None
        else {"C": C_f.astype(cache["C"].dtype), "n": n_f.astype(cache["n"].dtype)}
    )
    return x + y, new_cache


def mlstm_cache_spec(cfg: ModelConfig, batch: int) -> dict:
    h = cfg.n_heads
    dh = 2 * cfg.d_model // h
    # the matrix memory is the decode working set (dk x dv per head); its
    # value dim shards over the model axis ("ffn") — few heads alone cannot
    # cover a 16-way TP axis (EXPERIMENTS.md §Perf, xlstm decode iteration)
    return {
        "C": ParamSpec((batch, h, dh, dh), ("batch", "heads", None, "ffn"), init="zeros", dtype=cfg.state_dtype),
        "n": ParamSpec((batch, h, dh), ("batch", "heads", None), init="zeros", dtype=cfg.state_dtype),
    }


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM) — sequential scalar-memory recurrence
# ---------------------------------------------------------------------------


def slstm_specs(cfg: ModelConfig) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    return {
        "ln": ParamSpec((d,), (None,), init="ones"),
        "w_gates": ParamSpec((d, 4, h, dh), ("embed", None, "heads", None)),
        "r_gates": ParamSpec((4, h, dh, dh), (None, "heads", None, None), scale=0.5),
        "b_gates": ParamSpec((4, h, dh), (None, "heads", None), init="zeros"),
        "w_down": ParamSpec((d, d), ("embed", "embed")),
    }


def slstm_block(params, x, cfg: ModelConfig, *, cache=None):
    """x: (B,T,D). Stabilized exponential gating (xLSTM eqs. 13-19)."""
    from repro.models.layers import rmsnorm

    cd = jnp.dtype(cfg.compute_dtype)
    B, T, D = x.shape
    h = cfg.n_heads
    dh = D // h
    xin = rmsnorm(x, params["ln"])
    # input contributions for all steps upfront (B,T,4,H,dh)
    zx = jnp.einsum("btd,dghk->btghk", xin, params["w_gates"].astype(cd)).astype(jnp.float32)
    r_w = params["r_gates"].astype(jnp.float32)
    b = params["b_gates"].astype(jnp.float32)

    if cache is None:
        c0 = jnp.zeros((B, h, dh), jnp.float32)
        n0 = jnp.ones((B, h, dh), jnp.float32)
        m0 = jnp.zeros((B, h, dh), jnp.float32)
        h0 = jnp.zeros((B, h, dh), jnp.float32)
    else:
        c0, n0, m0, h0 = cache["c"], cache["n"], cache["m"], cache["h"]

    def step(carry, zt):
        c, n, m, hp = carry
        rec = jnp.einsum("bhk,ghks->bghs", hp, r_w)
        g = zt + rec + b  # (B,4,H,dh)
        zt_, it_, ft_, ot_ = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
        m_new = jnp.maximum(ft_ + m, it_)
        i_p = jnp.exp(it_ - m_new)
        f_p = jnp.exp(ft_ + m - m_new)
        c = f_p * c + i_p * jnp.tanh(zt_)
        n = f_p * n + i_p
        hv = jax.nn.sigmoid(ot_) * c / jnp.maximum(n, 1e-6)
        return (c, n, m_new, hv), hv

    (c_f, n_f, m_f, h_f), hs = jax.lax.scan(step, (c0, n0, m0, h0), zx.transpose(1, 0, 2, 3, 4))
    hs = hs.transpose(1, 0, 2, 3).reshape(B, T, D).astype(cd)
    y = jnp.einsum("btd,de->bte", hs, params["w_down"].astype(cd))
    new_cache = None if cache is None else {"c": c_f, "n": n_f, "m": m_f, "h": h_f}
    return x + y, new_cache


def slstm_cache_spec(cfg: ModelConfig, batch: int) -> dict:
    h = cfg.n_heads
    dh = cfg.d_model // h
    s = ParamSpec((batch, h, dh), ("batch", "heads", None), init="zeros", dtype="float32")
    return {"c": s, "n": ParamSpec((batch, h, dh), ("batch", "heads", None), init="ones", dtype="float32"), "m": s, "h": s}
