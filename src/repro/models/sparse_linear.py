"""Sparse LM inference: route pruned weight matmuls through AutoSpmvSession.

The paper tunes one kernel per matrix per objective; an LM forward pass is a
fleet of such matrices (FFN up/gate/down projections, MoE expert FFNs) that
never change between decode steps. ``SparseInferenceEngine`` is the bridge:

* ``register`` wraps a magnitude-pruned weight matrix as a ``SparseLinear``
  (transposed to the SpMV orientation, fingerprinted, density-gated);
* ``matmul`` is the single dispatch point model code calls — it routes the
  per-token vectors through a ``session.serve_optimize``-planned Pallas
  kernel, or falls back to a dense ``jnp`` contraction when the matrix is
  too dense, unregistered, or the token count exceeds the SpMV window;
* exactly **one plan per (weight fingerprint, objective)** is computed for
  the lifetime of the engine — every decode step of every request reuses it
  (the solver-style amortization contract, assertable via session counters).

Jit interplay: ``serve_optimize`` is host-side (numpy fingerprints, cache
lookups) and format conversion materializes device arrays, so plans must be
computed *eagerly* before a decode graph is traced (``plan_all``; a first
eager ``matmul`` also works) — the prepared interpret-mode Pallas kernels
are then traceable and live inside the jitted decode graph as constants.
This is also why the engine requires ``unroll_layers`` in
``models.model._run_blocks``: a ``lax.scan`` over stacked layer params
cannot hold per-layer host-planned kernels.

SLO routing: serving traffic carries an objective *class* per request
(``Request.slo``); ``SLO_OBJECTIVES`` maps the classes onto the paper's four
objectives and ``SLO_PRIORITY`` decides which class a shared decode batch is
served under (latency-critical dominates). ``obs/energy.py`` cells are keyed
by the request's own class, so mixed traffic shows who burned the joules.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import compile_spmv, matrix_fingerprint
from repro.obs.trace import span as _span
from repro.optim.compress import magnitude_prune
from repro.utils.logging import get_logger

log = get_logger("models.sparse_linear")

# Request SLO class -> the paper objective the planner optimizes for it.
SLO_OBJECTIVES = {
    "latency-critical": "latency",
    "power-capped": "power",
    "balanced": "efficiency",
    "energy-saving": "energy",
}

# Shared decode batches run under ONE objective per tick: the highest-
# priority class among the occupied slots wins (an energy-saving request
# sharing a tick with a latency-critical one is served latency-optimal and
# accounted under its own class).
SLO_PRIORITY = ("latency-critical", "power-capped", "balanced", "energy-saving")


def slo_objective(slo: str) -> str:
    """Map an SLO class to its paper objective, with a helpful error."""
    try:
        return SLO_OBJECTIVES[slo]
    except KeyError:
        raise ValueError(
            f"unknown SLO class {slo!r}; expected one of {sorted(SLO_OBJECTIVES)}"
        ) from None


@dataclass(frozen=True)
class SparseLinear:
    """One registered weight matrix in SpMV orientation.

    The model computes ``y = x @ W`` with ``W: (d_in, d_out)``; the SpMV
    kernels compute ``A @ v``, so the engine stores ``A = W.T`` and serves
    each token row as one SpMV: ``y_i = A @ x_i``.
    """

    name: str
    weight_t: np.ndarray  # (d_out, d_in) — the SpMV operand W.T
    fingerprint: str
    density: float
    d_in: int
    d_out: int
    spmv_eligible: bool  # False: always served by the dense fallback


@dataclass
class EngineStats:
    """What the engine planned vs. what it routed densely (trace-time
    counts: matmul counters increment once per traced call site, not once
    per executed decode step)."""

    registered: int = 0
    spmv_layers: int = 0  # registered AND below the density threshold
    plans: int = 0  # one per (fingerprint, objective), engine lifetime
    spmv_matmuls: int = 0
    dense_fallbacks: int = 0
    fp32_recompiles: int = 0

    def as_dict(self) -> dict:
        return {
            "registered": self.registered,
            "spmv_layers": self.spmv_layers,
            "plans": self.plans,
            "spmv_matmuls": self.spmv_matmuls,
            "dense_fallbacks": self.dense_fallbacks,
            "fp32_recompiles": self.fp32_recompiles,
        }


@dataclass(frozen=True)
class EngineHandle:
    """An engine bound to one objective — what model code receives.

    The handle is what per-objective jitted decode functions close over, so
    one ``BatchedServer`` can hold a latency-optimal and an energy-optimal
    decode graph against the same shared engine/session."""

    engine: "SparseInferenceEngine"
    objective: str

    def matmul(self, name: str, x, w):
        return self.engine.matmul(name, x, w, self.objective)


class SparseInferenceEngine:
    """One shared execution engine for every sparse matmul in inference.

    Parameters
    ----------
    session:
        The shared ``AutoSpmvSession``; plans and kernels flow through its
        feature-bucketed cache and the process-wide kernel memo.
    density_threshold:
        Registered matrices denser than this are served by the dense
        fallback — SpMV on a half-dense matrix loses to the MXU.
    max_spmv_tokens:
        Static token-count ceiling for the SpMV route. Decode steps batch a
        handful of per-token vectors; prefill traffic (tens to thousands of
        tokens) stays dense, where it is numerically identical because the
        weights themselves are pruned.
    force_fp32:
        Recompile a plan whose served schedule accumulates in bf16 with
        ``accum_dtype="float32"`` (same format/identity) so sparse-served
        logits match the dense fp32 reference — the solver-path guard.
    """

    def __init__(
        self,
        session,
        *,
        density_threshold: float = 0.5,
        max_spmv_tokens: int = 8,
        force_fp32: bool = True,
    ):
        self.session = session
        self.density_threshold = float(density_threshold)
        self.max_spmv_tokens = int(max_spmv_tokens)
        self.force_fp32 = force_fp32
        self.stats = EngineStats()
        self._by_name: dict[str, SparseLinear] = {}
        # (fingerprint, objective) -> (ServedPlan, kernel): THE amortization
        # dict — serve_optimize runs exactly once per key, ever.
        self._plans: dict[tuple[str, str], tuple[object, object]] = {}

    # --------------------------------------------------------- registration
    def register(self, name: str, weight: np.ndarray) -> SparseLinear:
        """Register a pruned ``(d_in, d_out)`` weight matrix under ``name``.

        Re-registering a name replaces the entry (plans are keyed by content
        fingerprint, so an identical re-registration costs nothing)."""
        w = np.ascontiguousarray(np.asarray(weight, dtype=np.float32))
        if w.ndim != 2:
            raise ValueError(f"{name}: expected a 2-D weight, got shape {w.shape}")
        a = np.ascontiguousarray(w.T)
        density = float(np.count_nonzero(a)) / max(a.size, 1)
        eligible = 0.0 < density <= self.density_threshold
        layer = SparseLinear(
            name=name,
            weight_t=a,
            fingerprint=matrix_fingerprint(a),
            density=density,
            d_in=a.shape[1],
            d_out=a.shape[0],
            spmv_eligible=eligible,
        )
        if name not in self._by_name:
            self.stats.registered += 1
            if eligible:
                self.stats.spmv_layers += 1
        self._by_name[name] = layer
        return layer

    def layer(self, name: str) -> SparseLinear | None:
        return self._by_name.get(name)

    def bind(self, objective: str) -> EngineHandle:
        return EngineHandle(self, objective)

    def handle_for_slo(self, slo: str) -> EngineHandle:
        return self.bind(slo_objective(slo))

    # ---------------------------------------------------------------- plans
    def plan(self, name: str, objective: str):
        """The (plan, kernel) pair for one registered matrix — computed via
        ``session.serve_optimize`` on first sight of (fingerprint,
        objective), reused for the engine's lifetime afterwards."""
        layer = self._by_name[name]
        key = (layer.fingerprint, objective)
        cached = self._plans.get(key)
        if cached is not None:
            return cached
        with _span("engine.plan", layer=name, objective=objective):
            served = self.session.serve_optimize(
                layer.weight_t, objective, fingerprint=layer.fingerprint
            )
            kernel = served.kernel
            if self.force_fp32 and served.schedule.accum_dtype != "float32":
                kernel = compile_spmv(
                    layer.weight_t,
                    served.fmt,
                    served.schedule.replace(accum_dtype="float32"),
                    interpret=self.session.tuner.interpret,
                    memo_key=layer.fingerprint,
                )
                self.stats.fp32_recompiles += 1
        self._plans[key] = (served, kernel)
        self.stats.plans = len(self._plans)
        log.info(
            "planned %s for %s: fmt=%s density=%.3f (%d plans total)",
            name, objective, served.fmt, layer.density, self.stats.plans,
        )
        return served, kernel

    def plan_all(self, objective: str) -> int:
        """Eagerly plan every SpMV-eligible registered matrix for one
        objective. Format conversion materializes device-resident storage
        through jnp ops, which must NOT first run under a jit trace (the
        storage would become tracers); serving paths call this before
        tracing a decode graph so ``matmul`` only ever sees warm plans."""
        n = 0
        for name, layer in self._by_name.items():
            if layer.spmv_eligible:
                self.plan(name, objective)
                n += 1
        return n

    # --------------------------------------------------------------- matmul
    def matmul(self, name: str, x, w, objective: str):
        """``x @ w`` with ``x: (..., d_in)`` — the single dispatch point.

        Routes through the planned SpMV kernel when ``name`` is registered,
        SpMV-eligible, and the (static) token count fits the SpMV window;
        otherwise contracts densely with the passed param leaf ``w`` (which
        holds the same pruned values, so both routes agree numerically)."""
        layer = self._by_name.get(name)
        tokens = int(np.prod(x.shape[:-1]))
        if (
            layer is None
            or not layer.spmv_eligible
            or tokens > self.max_spmv_tokens
        ):
            if layer is not None:
                self.stats.dense_fallbacks += 1
            return jnp.einsum("...d,df->...f", x, w)
        _, kernel = self.plan(name, objective)
        flat = x.reshape(-1, layer.d_in)
        ys = [kernel(flat[i].astype(jnp.float32)) for i in range(tokens)]
        y = jnp.stack(ys).reshape(*x.shape[:-1], layer.d_out)
        self.stats.spmv_matmuls += 1
        return y.astype(x.dtype)

    # ------------------------------------------------------------ accounting
    def plans_for(self, objective: str) -> list:
        return [p for (_, obj), (p, _) in self._plans.items() if obj == objective]

    def format_mix(self, objective: str) -> str:
        """The served formats under one objective, e.g. ``"csr"`` or
        ``"csr+ell"`` — the energy-cell fmt label for LM ticks."""
        fmts = sorted({p.fmt for p in self.plans_for(objective)})
        return "+".join(fmts) if fmts else "dense"

    def modeled_objectives(self, objective: str) -> dict:
        """Summed model estimates across this objective's plans — the
        modeled per-token cost of one pass over every planned matrix.
        Power/efficiency are re-derived so the triple stays consistent with
        how ``EnergyAccountant`` recovers useful work."""
        plans = self.plans_for(objective)
        lat = sum(float(p.predicted.get("latency") or 0.0) for p in plans)
        energy = sum(float(p.predicted.get("energy") or 0.0) for p in plans)
        useful = sum(
            float(p.predicted.get("efficiency") or 0.0)
            * float(p.predicted.get("power") or 0.0)
            * float(p.predicted.get("latency") or 0.0)
            * 1e6
            for p in plans
        )
        power = energy / lat if lat > 0 else 0.0
        eff = useful / (lat * power * 1e6) if lat > 0 and power > 0 else 0.0
        return {"latency": lat, "energy": energy, "power": power, "efficiency": eff}

    def summary(self) -> dict:
        objectives = sorted({obj for (_, obj) in self._plans})
        return {
            "registered": self.stats.registered,
            "spmv_layers": self.stats.spmv_layers,
            "stats": self.stats.as_dict(),
            "objectives": {
                obj: {
                    "plans": len(self.plans_for(obj)),
                    "formats": self.format_mix(obj),
                }
                for obj in objectives
            },
        }


# ---------------------------------------------------------------- pruning
def ffn_block_names(cfg) -> list[tuple[str, str]]:
    """(block name, kind) pairs in the canonical ``_run_blocks`` naming:
    ``head{i}`` / ``g{pattern_index}x{group}`` / ``tail{i}``."""
    out = [(f"head{i}", k) for i, k in enumerate(cfg.first_blocks)]
    for pi, kind in enumerate(cfg.pattern if cfg.n_groups else ()):
        out.extend((f"g{pi}x{g}", kind) for g in range(cfg.n_groups))
    out.extend((f"tail{i}", k) for i, k in enumerate(cfg.tail_blocks))
    return out


def prune_model_ffns(params, cfg, engine: SparseInferenceEngine, density: float):
    """Magnitude-prune every FFN weight matrix in ``params`` to ``density``
    and register the pruned matrices with ``engine`` under the canonical
    block names ``models.model._run_blocks`` threads to ``mlp``/``moe_ffn``.

    Prunes dense-FFN ``w_gate``/``w_up``/``w_down``, each MoE expert's
    slices, and shared-expert FFNs; attention, router, embeddings, and norms
    are untouched. Pruning happens in fp32 and the stored leaf is cast back
    to its original dtype, with the engine registering exactly the cast-back
    values — so the dense fallback and the SpMV route see identical weights.
    Returns a new params pytree (pruned leaves become host numpy arrays).
    """

    def prune_leaf(w, name):
        arr = np.asarray(w)
        pruned, _ = magnitude_prune(np.asarray(arr, np.float32), density)
        stored = pruned.astype(arr.dtype)
        engine.register(name, np.asarray(stored, np.float32))
        return stored

    def prune_block(block, name):
        block = dict(block)
        if "mlp" in block:
            sub = dict(block["mlp"])
            for k in ("w_gate", "w_up", "w_down"):
                if k in sub:
                    sub[k] = prune_leaf(sub[k], f"{name}.mlp.{k}")
            block["mlp"] = sub
        if "moe" in block:
            moe = dict(block["moe"])
            for k in ("w_gate", "w_up", "w_down"):
                stacked = np.asarray(moe[k])
                moe[k] = np.stack(
                    [
                        prune_leaf(stacked[e], f"{name}.moe.{k}.{e}")
                        for e in range(stacked.shape[0])
                    ]
                )
            if "shared" in moe:
                sh = dict(moe["shared"])
                for k in ("w_gate", "w_up", "w_down"):
                    if k in sh:
                        sh[k] = prune_leaf(sh[k], f"{name}.moe.shared.{k}")
                moe["shared"] = sh
            block["moe"] = moe
        return block

    import jax

    params = dict(params)
    params["head"] = tuple(
        prune_block(b, f"head{i}") for i, b in enumerate(params["head"])
    )
    new_groups = []
    for pi, pstack in enumerate(params["groups"]):
        layers = []
        for g in range(cfg.n_groups):
            p_g = jax.tree.map(lambda a: np.asarray(a)[g], pstack)
            layers.append(prune_block(p_g, f"g{pi}x{g}"))
        new_groups.append(jax.tree.map(lambda *xs: np.stack(xs), *layers))
    params["groups"] = tuple(new_groups)
    params["tail"] = tuple(
        prune_block(b, f"tail{i}") for i, b in enumerate(params["tail"])
    )
    log.info(
        "pruned FFN weights to density %.3f: %d matrices registered, %d SpMV-eligible",
        density, engine.stats.registered, engine.stats.spmv_layers,
    )
    return params
