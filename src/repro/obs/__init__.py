"""Unified observability: tracing, metrics, energy accounting, aggregation.

The paper's claims are about *measured* latency, energy, average power, and
efficiency; this package is how the serving reproduction observes all four
instead of just wall clock. Four pieces, threaded through every hot path:

* ``trace``   — nested spans (``session.optimize`` → ``cache.lookup`` →
  ``kernel.compile`` → ``kernel.execute``) with crash-tolerant JSONL export
  and an optional ``jax.profiler`` (Perfetto) capture hook;
* ``metrics`` — a process-wide registry of counters/gauges/histograms with
  JSON snapshot + Prometheus text export;
* ``energy``  — per-request modeled-energy / measured-latency accounting of
  the four paper objectives, per (format, objective, block);
* ``aggregate`` — merges JSONL metric/trace shards from N server instances
  into one fleet report; ``http`` serves ``/metrics`` + ``/healthz`` +
  ``/obs`` from a daemon thread.

``obs_enabled``/``set_obs_enabled`` gate the whole layer: disabled, a span
is one attribute read and a metric mutation is one boolean check — the
serving path's no-op fast path.
"""

from repro.obs.aggregate import merge_shards
from repro.obs.energy import EnergyAccountant, EnergyCell
from repro.obs.http import ObsHTTPServer
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    get_metrics,
    reset_metrics,
)
from repro.obs.trace import (
    Tracer,
    get_tracer,
    load_spans,
    profile_capture,
    span,
)


def set_obs_enabled(enabled: bool) -> None:
    """Flip tracing + metrics on/off process-wide (the no-op fast path)."""
    get_tracer().enabled = enabled
    get_metrics().enabled = enabled


def obs_enabled() -> bool:
    return get_tracer().enabled or get_metrics().enabled


__all__ = [
    "Counter",
    "EnergyAccountant",
    "EnergyCell",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "ObsHTTPServer",
    "Tracer",
    "get_metrics",
    "get_tracer",
    "load_spans",
    "merge_shards",
    "obs_enabled",
    "profile_capture",
    "reset_metrics",
    "set_obs_enabled",
    "span",
]
