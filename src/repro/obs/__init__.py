"""Unified observability: tracing, metrics, energy accounting, aggregation.

The paper's claims are about *measured* latency, energy, average power, and
efficiency; this package is how the serving reproduction observes all four
instead of just wall clock. Four pieces, threaded through every hot path:

* ``trace``   — nested spans (``session.optimize`` → ``cache.lookup`` →
  ``kernel.compile`` → ``kernel.execute``) with crash-tolerant JSONL export
  and an optional ``jax.profiler`` (Perfetto) capture hook;
* ``metrics`` — a process-wide registry of counters/gauges/histograms with
  JSON snapshot + Prometheus text export;
* ``energy``  — per-request modeled-energy / measured-latency accounting of
  the four paper objectives, per (format, objective, block);
* ``aggregate`` — merges JSONL metric/trace/posterior shards from N server
  instances into one fleet report; ``http`` serves ``/metrics`` +
  ``/healthz`` + ``/obs`` + ``/slo`` from a daemon thread.

On top of that passive layer sits the *active* one (alerting and reacting,
not just recording):

* ``slo``     — per-SLO-class targets with SRE-style multi-window burn-rate
  evaluation, an ok→warning→firing alert state machine, and objective
  escalation hooks the servers consume;
* ``anomaly`` — a cost-model residual watchdog over the recorder's
  calibration pairs that recalibrates + evicts when the model is lying;
* ``sync``    — live fleet posterior sync through a shared directory of
  shards (``FleetSync`` + ``AdaptiveFormatSelector.absorb``).

``obs_enabled``/``set_obs_enabled`` gate the whole layer: disabled, a span
is one attribute read and a metric mutation is one boolean check — the
serving path's no-op fast path.
"""

from repro.obs.aggregate import merge_shards
from repro.obs.anomaly import AnomalyConfig, CostModelWatchdog
from repro.obs.energy import EnergyAccountant, EnergyCell
from repro.obs.http import ObsHTTPServer
from repro.obs.slo import SloConfig, SloTarget, SloTracker
from repro.obs.sync import FleetSync, write_fleet_shard
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    get_metrics,
    reset_metrics,
)
from repro.obs.trace import (
    Tracer,
    get_tracer,
    load_spans,
    profile_capture,
    span,
)


def set_obs_enabled(enabled: bool) -> None:
    """Flip tracing + metrics on/off process-wide (the no-op fast path)."""
    get_tracer().enabled = enabled
    get_metrics().enabled = enabled


def obs_enabled() -> bool:
    return get_tracer().enabled or get_metrics().enabled


__all__ = [
    "AnomalyConfig",
    "CostModelWatchdog",
    "Counter",
    "EnergyAccountant",
    "EnergyCell",
    "FleetSync",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "ObsHTTPServer",
    "SloConfig",
    "SloTarget",
    "SloTracker",
    "Tracer",
    "get_metrics",
    "get_tracer",
    "load_spans",
    "merge_shards",
    "obs_enabled",
    "profile_capture",
    "reset_metrics",
    "set_obs_enabled",
    "span",
    "write_fleet_shard",
]
