"""Fleet aggregation: merge metric/trace JSONL shards from N instances.

Each serving instance exports its own shards (``MetricsRegistry.write_shard``
for metrics, ``Tracer.export_jsonl`` for spans); this module folds any
number of them into one report — the substrate the ROADMAP's fleet-scale
serving (shared bandit posteriors, cross-instance drift) needs before any
of that logic can exist. Merge semantics:

* counters — summed (fleet totals: cache hits, compiles, explore pulls);
* gauges   — averaged, with min/max retained (per-format power differs per
  instance; the report keeps the spread, not just one sample);
* histograms — counts and sums add, and percentiles are *recomputed over
  the concatenated recent windows* (averaging per-instance percentiles
  would be wrong for any skewed latency distribution);
* spans    — concatenated with their source instance attached, summarized
  per name (count, total/mean duration);
* posteriors (``obs/sync.py`` fleet shards) — per-(bucket, objective, fmt)
  arm pulls sum and values merge pull-weighted, with each instance's
  incumbent retained per cell (and a ``converged`` flag when they agree);
* calibration pairs — concatenated per format (bounded), with the fleet
  mean relative error recomputed over the merged pairs.

Lines that fail to parse (torn appends, foreign schemas) are counted and
skipped, matching the replay tolerance everywhere else in the repo.

CLI: ``python -m repro.obs.aggregate shard1.jsonl shard2.jsonl -o report.json``
— shard kind (metrics vs. trace) is detected per line, so mixed file lists
are fine.
"""

from __future__ import annotations

import argparse
import json
import math
from pathlib import Path

from repro.utils.timing import percentile as _pctl
from repro.obs.metrics import QUANTILES
from repro.utils.logging import get_logger

log = get_logger("obs.aggregate")


def _labels_key(name: str, labels: dict) -> str:
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return f"{name}{{{inner}}}"


def read_shard_lines(paths: list[str | Path]) -> tuple[list[dict], int]:
    """Parse every line of every shard; returns (records, dropped_lines).

    Reads line-by-line — a fleet of long-running instances produces shards
    far bigger than any single record, so the file never sits in memory
    whole. Torn lines (interrupted appends, foreign schemas) are counted
    and skipped, matching the replay tolerance everywhere else."""
    records, dropped = [], 0
    for path in paths:
        with open(path, "r", encoding="utf-8") as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except ValueError:
                    dropped += 1
                    continue
                if isinstance(rec, dict):
                    rec.setdefault("_shard", str(path))
                    records.append(rec)
                else:
                    dropped += 1
    return records, dropped


def merge_shards(paths: list[str | Path]) -> dict:
    """Fold metric + trace shard files into one fleet report."""
    records, dropped = read_shard_lines(paths)
    instances: set[str] = set()
    counters: dict[str, float] = {}
    gauges: dict[str, dict] = {}
    hists: dict[str, dict] = {}
    spans: list[dict] = []
    posteriors: dict[tuple[str, str], dict] = {}
    calibration: dict[str, dict] = {}

    for rec in records:
        kind = rec.get("kind")
        if kind == "meta":
            if rec.get("instance"):
                instances.add(rec["instance"])
            continue
        if kind in ("counter", "gauge", "histogram"):
            if rec.get("instance"):
                instances.add(rec["instance"])
            key = _labels_key(rec.get("name", "?"), rec.get("labels") or {})
            if kind == "counter":
                counters[key] = counters.get(key, 0.0) + float(rec.get("value") or 0.0)
            elif kind == "gauge":
                v = rec.get("value")
                if v is None or (isinstance(v, float) and math.isnan(v)):
                    continue
                cell = gauges.setdefault(
                    key, {"sum": 0.0, "n": 0, "min": math.inf, "max": -math.inf}
                )
                cell["sum"] += float(v)
                cell["n"] += 1
                cell["min"] = min(cell["min"], float(v))
                cell["max"] = max(cell["max"], float(v))
            else:
                cell = hists.setdefault(
                    key, {"count": 0, "sum": 0.0, "recent": []}
                )
                cell["count"] += int(rec.get("count") or 0)
                cell["sum"] += float(rec.get("sum") or 0.0)
                cell["recent"].extend(float(x) for x in rec.get("recent") or ())
        elif kind == "posterior":  # obs/sync.py fleet-shard bandit arm
            if rec.get("instance"):
                instances.add(rec["instance"])
            try:
                pulls = int(rec["pulls"])
                value = float(rec["value"])
                key = (str(rec["bucket"]), str(rec["objective"]))
                fmt = str(rec["fmt"])
            except (KeyError, TypeError, ValueError):
                dropped += 1
                continue
            cell = posteriors.setdefault(key, {"arms": {}, "incumbents": {}})
            arm = cell["arms"].setdefault(fmt, {"pulls": 0, "weighted": 0.0})
            arm["pulls"] += pulls
            arm["weighted"] += value * pulls  # pull-weighted value merge
            if rec.get("instance") and rec.get("incumbent"):
                cell["incumbents"][rec["instance"]] = rec["incumbent"]
        elif kind == "calibration":  # obs/sync.py (predicted, measured) pairs
            if rec.get("instance"):
                instances.add(rec["instance"])
            fmt = str(rec.get("fmt", "?"))
            cell = calibration.setdefault(fmt, {"samples": 0, "pairs": []})
            for pair in rec.get("pairs") or ():
                try:
                    p, m = float(pair[0]), float(pair[1])
                except (TypeError, ValueError, IndexError):
                    dropped += 1
                    continue
                cell["samples"] += 1
                if len(cell["pairs"]) < 256:  # bound the merged window
                    cell["pairs"].append((p, m))
        elif "name" in rec and "dur_s" in rec:  # a trace span line
            span = dict(rec)
            span["instance"] = rec.get("instance") or rec.get("_shard", "")
            spans.append(span)
        else:
            dropped += 1

    report = {
        "shards": len(set(str(p) for p in paths)),
        "instances": sorted(instances),
        "dropped_lines": dropped,
        "counters": dict(sorted(counters.items())),
        "gauges": {
            k: {
                "mean": c["sum"] / c["n"],
                "min": c["min"],
                "max": c["max"],
                "instances": c["n"],
            }
            for k, c in sorted(gauges.items())
        },
        "histograms": {},
        "spans": _span_summary(spans),
    }
    for key, cell in sorted(hists.items()):
        merged = {
            "count": cell["count"],
            "sum": cell["sum"],
            "mean": cell["sum"] / cell["count"] if cell["count"] else math.nan,
        }
        for q in QUANTILES:
            merged[f"p{int(q)}"] = _pctl(cell["recent"], q)
        merged["window_samples"] = len(cell["recent"])
        report["histograms"][key] = merged
    if posteriors:
        out_post = {}
        for (bucket, objective), cell in sorted(posteriors.items()):
            arms = {
                fmt: {
                    "pulls": a["pulls"],
                    "value": a["weighted"] / a["pulls"] if a["pulls"] else math.nan,
                }
                for fmt, a in sorted(cell["arms"].items())
            }
            incumbents = dict(sorted(cell["incumbents"].items()))
            out_post[f"{bucket}|{objective}"] = {
                "arms": arms,
                "pulls": sum(a["pulls"] for a in arms.values()),
                "incumbents": incumbents,
                "converged": len(set(incumbents.values())) <= 1,
            }
        report["posteriors"] = out_post
    if calibration:
        out_cal = {}
        for fmt, cell in sorted(calibration.items()):
            pairs = cell["pairs"]
            rel = [abs(m - p) / p for p, m in pairs if p > 0]
            out_cal[fmt] = {
                "samples": cell["samples"],
                "window_pairs": len(pairs),
                "mean_rel_err": sum(rel) / len(rel) if rel else math.nan,
            }
        report["calibration"] = out_cal
    return report


def _span_summary(spans: list[dict]) -> dict:
    by_name: dict[str, dict] = {}
    for s in spans:
        cell = by_name.setdefault(s["name"], {"count": 0, "total_s": 0.0})
        cell["count"] += 1
        cell["total_s"] += float(s.get("dur_s") or 0.0)
    for cell in by_name.values():
        cell["mean_s"] = cell["total_s"] / cell["count"]
    return {
        "total": len(spans),
        "instances": sorted({s.get("instance", "") for s in spans} - {""}),
        "by_name": dict(sorted(by_name.items())),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("shards", nargs="+", help="metric/trace JSONL shard files")
    ap.add_argument("-o", "--out", default=None, help="write the merged report JSON here")
    args = ap.parse_args(argv)
    report = merge_shards(args.shards)
    text = json.dumps(report, indent=1, default=float)
    if args.out:
        from repro.utils.io import atomic_write_text

        atomic_write_text(args.out, text)
        log.info(
            "merged %d shard(s) from %d instance(s) -> %s",
            report["shards"],
            len(report["instances"]),
            args.out,
        )
    else:
        print(text)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
