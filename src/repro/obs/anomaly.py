"""Cost-model residual watchdog: catch "the model is lying" before regret.

The bandit's drift signals (telemetry/adaptive.py) notice a *plan* serving
worse than its rivals — that takes many pulls per cell to accumulate. This
watchdog attacks the upstream failure directly: it streams the
(predicted_s, measured_s) calibration pairs the ``TelemetryRecorder``
already keeps per format, tracks an EWMA of the relative residual
``|measured - predicted| / predicted``, and compares it against a baseline
of healthy residuals (anomalous when the EWMA exceeds
``max(rel_threshold, baseline_mean + z_threshold * baseline_std)`` for
``sustain`` consecutive polls with fresh data).

On a sustained anomaly it assumes the cost model is lying about that format
and repairs the pipeline in one shot:

1. drop the format's calibration window (``recorder.reset_calibration``) —
   the lying era's pairs must not be least-squares'd into the next fit;
2. ``session.calibrate()`` — replaces the session's cost model with a fresh
   fit; with the anomalous format's window empty it falls back to the
   analytical base model for that format and relearns the affine correction
   from post-recovery measurements;
3. ``session.evict_format(fmt)`` — targeted drift eviction of every cached
   plan serving that format (monolithic or as a partitioned component), so
   the next request re-plans against the repaired model.

Consumption bookkeeping rides on ``recorder.calibration_totals()`` (a
monotonic per-format counter), so each poll judges only pairs it has not
seen — a bounded window alone cannot tell fresh pairs from replayed ones.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.obs.metrics import get_metrics
from repro.utils.logging import get_logger
from repro.utils.timing import RollingStats, ewma as _ewma

log = get_logger("obs.anomaly")


@dataclass(frozen=True)
class AnomalyConfig:
    ewma_alpha: float = 0.4  # residual EWMA: reactive, a few pairs to swing
    rel_threshold: float = 0.75  # absolute floor: EWMA residual below this
    # is never anomalous, however tight the healthy baseline ran
    z_threshold: float = 4.0  # sigmas above the healthy baseline mean
    sustain: int = 2  # consecutive anomalous polls (with fresh pairs) to fire
    min_samples: int = 6  # healthy residuals before the baseline can judge
    baseline_window: int = 128


@dataclass
class _FormatState:
    baseline: RollingStats  # healthy-era residuals only
    ewma: float | None = None
    consumed: int = 0  # vs recorder.calibration_totals()[fmt]
    strikes: int = 0
    anomalies: int = 0
    pairs_seen: int = 0


class CostModelWatchdog:
    """Per-format residual monitor bound to one ``AutoSpmvSession``."""

    def __init__(self, session, config: AnomalyConfig | None = None, registry=None):
        if session.telemetry is None:
            raise ValueError(
                "CostModelWatchdog needs a session with a telemetry recorder "
                "(the calibration pairs are its input)"
            )
        self.session = session
        self.config = config or AnomalyConfig()
        self.metrics = registry if registry is not None else get_metrics()
        self.recalibrations = 0
        self._formats: dict[str, _FormatState] = {}

    # ------------------------------------------------------------------ poll
    def poll(self) -> list[str]:
        """Consume fresh calibration pairs; returns the formats that fired."""
        cfg = self.config
        recorder = self.session.telemetry
        fired: list[str] = []
        for fmt, total in recorder.calibration_totals().items():
            st = self._formats.get(fmt)
            if st is None:
                st = self._formats[fmt] = _FormatState(
                    RollingStats(cfg.baseline_window)
                )
            fresh = total - st.consumed
            if fresh <= 0:
                continue
            pairs = recorder.calibration_samples(fmt)
            st.consumed = total
            take = pairs[-min(fresh, len(pairs)):] if pairs else []
            if not take:
                continue  # window was reset since those pairs were folded
            residuals = [
                abs(measured - predicted) / predicted
                for predicted, measured in take
            ]
            for r in residuals:
                st.ewma = _ewma(st.ewma, r, cfg.ewma_alpha)
            st.pairs_seen += len(residuals)
            self.metrics.gauge("costmodel_residual_ewma", fmt=fmt).set(st.ewma)
            if self._anomalous(st):
                st.strikes += 1
                self.metrics.gauge("costmodel_anomaly_strikes", fmt=fmt).set(
                    st.strikes
                )
                if st.strikes >= cfg.sustain:
                    self._fire(fmt, st)
                    fired.append(fmt)
            else:
                st.strikes = 0
                self.metrics.gauge("costmodel_anomaly_strikes", fmt=fmt).set(0)
                for r in residuals:  # healthy: teach the baseline
                    st.baseline.add(r)
        return fired

    def _anomalous(self, st: _FormatState) -> bool:
        cfg = self.config
        if st.ewma is None or st.baseline.count < cfg.min_samples:
            return False
        threshold = max(
            cfg.rel_threshold,
            st.baseline.mean + cfg.z_threshold * st.baseline.std,
        )
        return st.ewma > threshold

    # ------------------------------------------------------------------ fire
    def _fire(self, fmt: str, st: _FormatState) -> None:
        recorder = self.session.telemetry
        dropped_pairs = recorder.reset_calibration(fmt)
        self.session.calibrate()
        evicted = self.session.evict_format(fmt)
        self.recalibrations += 1
        st.anomalies += 1
        st.strikes = 0
        st.ewma = None
        st.consumed = recorder.calibration_totals().get(fmt, st.consumed)
        # the old baseline described the pre-anomaly model; relearn it
        st.baseline = RollingStats(self.config.baseline_window)
        self.metrics.counter("costmodel_anomalies_total", fmt=fmt).inc()
        self.metrics.counter("costmodel_recalibrations_total").inc()
        log.warning(
            "cost-model anomaly on %s: residual ewma blew past the healthy "
            "baseline; dropped %d lying calibration pairs, recalibrated, "
            "evicted %d cached plan(s)",
            fmt, dropped_pairs, evicted,
        )

    # --------------------------------------------------------------- summary
    def summary(self) -> dict:
        return {
            "formats": {
                fmt: {
                    "residual_ewma": st.ewma,
                    "baseline_mean": st.baseline.mean if st.baseline.count else None,
                    "baseline_samples": st.baseline.count,
                    "strikes": st.strikes,
                    "anomalies": st.anomalies,
                    "pairs_seen": st.pairs_seen,
                }
                for fmt, st in sorted(self._formats.items())
            },
            "recalibrations": self.recalibrations,
        }
