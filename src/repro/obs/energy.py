"""Per-request energy/power accounting: the paper's four objectives at serve
time.

The paper's claims span latency, energy, average power, and energy
efficiency (§6.3), but only latency is host-observable — there is no power
sensor in this container. The accountant reconstructs the other three the
way ``CalibratedCostModel`` does: energy stays *modeled* (the cost model's
dynamic-energy estimate for the served plan, the one signal wall-clock
cannot contaminate), while average power and efficiency are *re-derived
from the measured wall time* — P = E_model / t_measured, efficiency =
useful MFLOP/s per watt with useful = 2·nnz FLOPs. A plan whose kernel runs
slower than modeled therefore shows its true (lower) average power and
efficiency, which is exactly the §5 energy-efficiency story made visible
per request.

Accumulation is keyed per (format, objective, block): monolithic requests
fold under block ``""``; partitioned serving attributes each row block's
share to its own cell, so a heterogeneous composite shows which block is
burning the joules. Aggregates feed gauges in the metrics registry
(``spmv_energy_joules_total`` / ``spmv_avg_power_watts`` /
``spmv_efficiency_mflops_per_watt``) so the ``/metrics`` scrape carries the
energy story alongside the latency histograms.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field

from repro.obs.metrics import MetricsRegistry, get_metrics

EnergyKey = tuple[str, str, str]  # (fmt, objective, block)


@dataclass
class EnergyCell:
    """Accumulated accounting for one (fmt, objective, block) cell."""

    requests: int = 0
    latency_s: float = 0.0  # measured wall time, summed
    energy_j: float = 0.0  # modeled dynamic energy, summed
    useful_flops: float = 0.0  # 2*nnz work, summed (efficiency numerator)
    modeled_latency_s: float = 0.0  # the model's own latency claim, summed

    @property
    def avg_power_w(self) -> float:
        return self.energy_j / self.latency_s if self.latency_s > 0 else 0.0

    @property
    def efficiency_mflops_per_w(self) -> float:
        p = self.avg_power_w
        if p <= 0 or self.latency_s <= 0:
            return 0.0
        return self.useful_flops / self.latency_s / 1e6 / p

    def as_dict(self) -> dict:
        return {
            "requests": self.requests,
            "latency_s": self.latency_s,
            "energy_j": self.energy_j,
            "avg_power_w": self.avg_power_w,
            "efficiency_mflops_per_w": self.efficiency_mflops_per_w,
            "modeled_latency_s": self.modeled_latency_s,
        }


@dataclass
class EnergyAccountant:
    """Folds (modeled objectives, measured latency) pairs into per-cell
    aggregates and mirrors them into the metrics registry."""

    registry: MetricsRegistry | None = None
    _cells: dict[EnergyKey, EnergyCell] = field(default_factory=dict)
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    def observe(
        self,
        *,
        fmt: str,
        objective: str,
        measured_s: float,
        modeled: dict | None,
        block: str = "",
    ) -> EnergyCell:
        """Account one served execution.

        ``modeled`` is the plan's objective estimate dict (``latency`` /
        ``energy`` / ``power`` / ``efficiency`` — a ``ObjectiveValues
        .as_dict()`` or the predictor's estimate map). Missing or
        non-positive modeled values degrade gracefully: the cell still
        counts the request and its measured latency, contributing zero
        modeled energy."""
        modeled = modeled or {}
        energy = float(modeled.get("energy") or 0.0)
        m_lat = float(modeled.get("latency") or 0.0)
        m_pow = float(modeled.get("power") or 0.0)
        m_eff = float(modeled.get("efficiency") or 0.0)
        # invert efficiency = useful_MFLOPs / (t * P): the modeled triple
        # carries the useful-work numerator without re-deriving nnz here
        useful = m_eff * m_pow * m_lat * 1e6 if m_eff > 0 and m_pow > 0 else 0.0
        key: EnergyKey = (fmt, objective, block)
        with self._lock:
            cell = self._cells.get(key)
            if cell is None:
                cell = self._cells[key] = EnergyCell()
            cell.requests += 1
            cell.latency_s += max(float(measured_s), 0.0)
            cell.energy_j += energy
            cell.useful_flops += useful
            cell.modeled_latency_s += m_lat
        reg = self.registry if self.registry is not None else get_metrics()
        labels = {"fmt": fmt, "objective": objective}
        if block:
            labels["block"] = block
        reg.gauge("spmv_energy_joules_total", **labels).set(cell.energy_j)
        reg.gauge("spmv_avg_power_watts", **labels).set(cell.avg_power_w)
        reg.gauge("spmv_efficiency_mflops_per_watt", **labels).set(
            cell.efficiency_mflops_per_w
        )
        return cell

    # --------------------------------------------------------------- queries
    def cell(self, fmt: str, objective: str, block: str = "") -> EnergyCell | None:
        return self._cells.get((fmt, objective, block))

    def per_format(self) -> dict[str, EnergyCell]:
        """Cells folded over objectives and blocks — the summary() view."""
        out: dict[str, EnergyCell] = {}
        with self._lock:
            items = list(self._cells.items())
        for (fmt, _obj, _blk), cell in items:
            agg = out.setdefault(fmt, EnergyCell())
            agg.requests += cell.requests
            agg.latency_s += cell.latency_s
            agg.energy_j += cell.energy_j
            agg.useful_flops += cell.useful_flops
            agg.modeled_latency_s += cell.modeled_latency_s
        return out

    def summary(self) -> dict:
        """Per-format aggregates + the full per-cell breakdown."""
        return {
            "per_format": {f: c.as_dict() for f, c in self.per_format().items()},
            "cells": {
                "/".join(k): c.as_dict() for k, c in sorted(self._cells.items())
            },
        }

    def clear(self) -> None:
        with self._lock:
            self._cells.clear()
