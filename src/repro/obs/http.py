"""Scrape surface: ``/metrics`` (Prometheus text), ``/healthz``, ``/obs``,
``/slo``.

A stdlib ``ThreadingHTTPServer`` on a daemon thread — no dependency, no
event loop, good enough for a scraper hitting it once per interval. The
serving process stays the owner of all state; the handler only *reads*
(registry text dump, an optional ``extra`` callable for richer JSON like
``SpmvServer.dump_obs``, an optional ``slo`` callable for the tracker's
alert snapshot), so a slow scrape never blocks a request path. Request
logging goes through ``utils/logging.get_logger`` at debug level — the
stdlib default would spam stderr on every scrape.

``port=0`` binds an ephemeral port (tests and multi-instance fleets on one
host); the bound port is available as ``server.port`` after ``start()``.
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable

from repro.obs.metrics import MetricsRegistry, get_metrics
from repro.utils.logging import get_logger

log = get_logger("obs.http")


class ObsHTTPServer:
    """Daemon-thread HTTP server exposing the process observability state."""

    def __init__(
        self,
        registry: MetricsRegistry | None = None,
        *,
        extra: Callable[[], dict] | None = None,
        slo: Callable[[], dict] | None = None,
        host: str = "127.0.0.1",
        port: int = 0,
    ):
        self.registry = registry if registry is not None else get_metrics()
        self.extra = extra
        self.slo = slo
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def _send(self, code: int, body: bytes, ctype: str) -> None:
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def do_GET(self):  # noqa: N802 (http.server API)
                path = self.path.split("?", 1)[0]
                try:
                    if path == "/metrics":
                        self._send(
                            200,
                            outer.registry.to_prometheus().encode(),
                            "text/plain; version=0.0.4; charset=utf-8",
                        )
                    elif path == "/healthz":
                        self._send(
                            200, b'{"status": "ok"}\n', "application/json"
                        )
                    elif path == "/obs":
                        payload = {"metrics": outer.registry.snapshot()}
                        if outer.extra is not None:
                            payload.update(outer.extra())
                        self._send(
                            200,
                            (json.dumps(payload, default=str) + "\n").encode(),
                            "application/json",
                        )
                    elif path == "/slo":
                        if outer.slo is None:
                            self._send(
                                404, b"no slo tracker attached\n", "text/plain"
                            )
                        else:
                            self._send(
                                200,
                                (
                                    json.dumps(outer.slo(), default=str) + "\n"
                                ).encode(),
                                "application/json",
                            )
                    else:
                        self._send(404, b"not found\n", "text/plain")
                except Exception as exc:  # scrape must never kill the server
                    self._send(500, f"{exc}\n".encode(), "text/plain")

            def log_message(self, fmt, *args):  # route to our logger; the
                # stdlib default writes to stderr on every scrape. Format
                # eagerly and defensively: a %-literal in a request line must
                # not raise inside the logging machinery
                try:
                    msg = fmt % args
                except (TypeError, ValueError):
                    msg = " ".join((fmt, *map(str, args)))
                log.debug("http: %s", msg)

            log_error = log_message  # 4xx/5xx lines follow the same route

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self._thread: threading.Thread | None = None

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        host, port = self._httpd.server_address[:2]
        return f"http://{host}:{port}"

    def start(self) -> "ObsHTTPServer":
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="obs-http", daemon=True
        )
        self._thread.start()
        log.info(
            "observability endpoint on %s (/metrics /healthz /obs%s)",
            self.url,
            " /slo" if self.slo is not None else "",
        )
        return self

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None
