"""Process-wide metrics registry: counters, gauges, latency histograms.

One registry per process (``get_metrics``), shared by every instrumented
module — the plan cache counts hits/misses, the kernel memo counts
compiles/evictions, the bandit counts explore/exploit pulls, the server
feeds latency histograms. Two export surfaces:

* ``snapshot()`` — a JSON-able dict (the ``/obs`` endpoint and
  ``SpmvServer.dump_obs``);
* ``to_prometheus()`` — the text exposition format a Prometheus scraper
  accepts on ``/metrics`` (histograms render as summaries with
  p50/p90/p99 quantile labels, built on ``utils/timing.RollingStats``).

``write_shard``/JSONL lines are the fleet substrate: each server instance
dumps its instruments as one line per metric and ``obs/aggregate.py`` merges
N shards into one report (counters sum, gauges average, histogram windows
concatenate so fleet percentiles are recomputed over real samples).

Disabled mode (``registry.enabled = False``) turns every mutation into a
single attribute check — instrument handles stay valid, nothing accumulates.
``reset()`` zeroes instruments *in place* so module-level cached handles
survive test isolation.
"""

from __future__ import annotations

import json
import math
import re
import threading
import time
from pathlib import Path

from repro.utils.timing import RollingStats

METRICS_SCHEMA_VERSION = 1

# quantiles every histogram exports (summary-style), per the serving story:
# median, tail, and deep tail of request latency
QUANTILES = (50.0, 90.0, 99.0)

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    name = _NAME_RE.sub("_", name)
    if not name or not (name[0].isalpha() or name[0] in "_:"):
        name = "_" + name
    return name


def _prom_labels(labels: tuple[tuple[str, str], ...], extra: str = "") -> str:
    parts = [f'{_prom_name(k)}="{str(v).replace(chr(92), chr(92) * 2).replace(chr(34), chr(92) + chr(34))}"'
             for k, v in labels]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


class Counter:
    """Monotonic float counter."""

    __slots__ = ("registry", "name", "labels", "value")

    def __init__(self, registry: "MetricsRegistry", name: str, labels):
        self.registry = registry
        self.name = name
        self.labels = labels
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if self.registry.enabled:
            with self.registry._lock:
                self.value += n

    def _reset(self) -> None:
        self.value = 0.0


class Gauge:
    """Last-written value (set) with optional add/sub."""

    __slots__ = ("registry", "name", "labels", "value")

    def __init__(self, registry: "MetricsRegistry", name: str, labels):
        self.registry = registry
        self.name = name
        self.labels = labels
        self.value = math.nan

    def set(self, v: float) -> None:
        if self.registry.enabled:
            self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        if self.registry.enabled:
            with self.registry._lock:
                self.value = (0.0 if math.isnan(self.value) else self.value) + n

    def _reset(self) -> None:
        self.value = math.nan


class Histogram:
    """Latency histogram on ``RollingStats``: exact count/sum, windowed
    percentiles (p50/p90/p99 over the last ``window`` samples)."""

    __slots__ = ("registry", "name", "labels", "stats")

    def __init__(self, registry: "MetricsRegistry", name: str, labels,
                 window: int = 512):
        self.registry = registry
        self.name = name
        self.labels = labels
        self.stats = RollingStats(window=window)

    def observe(self, v: float) -> None:
        if self.registry.enabled:
            with self.registry._lock:
                self.stats.add(v)

    @property
    def count(self) -> int:
        return self.stats.count

    @property
    def sum(self) -> float:
        return self.stats.mean * self.stats.count

    def percentile(self, q: float) -> float:
        return self.stats.percentile(q)

    def quantiles(self) -> dict[str, float]:
        return {f"p{int(q)}": self.stats.percentile(q) for q in QUANTILES}

    def as_dict(self) -> dict:
        d = {"count": self.count, "sum": self.sum, "mean": self.stats.mean}
        d.update(self.quantiles())
        return d

    def recent(self) -> list[float]:
        """The windowed samples (shard export: fleet percentile merging)."""
        return [float(x) for x in self.stats._recent]

    def _reset(self) -> None:
        self.stats = RollingStats(window=self.stats.window)


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Get-or-create instrument store, keyed by (kind, name, labels)."""

    def __init__(self, *, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.RLock()
        self._instruments: dict[tuple, object] = {}

    # ------------------------------------------------------------ instruments
    def _get(self, kind: str, name: str, labels: dict, **kwargs):
        key = (kind, name, tuple(sorted(labels.items())))
        with self._lock:
            inst = self._instruments.get(key)
            if inst is None:
                inst = _KINDS[kind](self, name, key[2], **kwargs)
                self._instruments[key] = inst
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, window: int = 512, **labels) -> Histogram:
        return self._get("histogram", name, labels, window=window)

    def instruments(self, kind: str | None = None, name: str | None = None):
        """Registered instruments, optionally filtered by kind and/or name."""
        with self._lock:
            return [
                inst
                for (k, n, _), inst in self._instruments.items()
                if (kind is None or k == kind) and (name is None or n == name)
            ]

    def reset(self) -> None:
        """Zero every instrument IN PLACE (handles cached at call sites in
        hot-path modules stay valid across test isolation)."""
        with self._lock:
            for inst in self._instruments.values():
                inst._reset()

    # ----------------------------------------------------------------- export
    def snapshot(self) -> dict:
        """JSON-able view of every instrument (the ``/obs`` payload)."""
        out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
        with self._lock:
            items = list(self._instruments.items())
        for (kind, name, labels), inst in items:
            key = name + _prom_labels(labels)
            if kind == "counter":
                out["counters"][key] = inst.value
            elif kind == "gauge":
                out["gauges"][key] = inst.value
            else:
                out["histograms"][key] = inst.as_dict()
        return out

    def to_prometheus(self) -> str:
        """Prometheus text exposition format (``/metrics`` payload)."""
        by_name: dict[tuple[str, str], list] = {}
        with self._lock:
            items = list(self._instruments.items())
        for (kind, name, labels), inst in items:
            by_name.setdefault((kind, _prom_name(name)), []).append((labels, inst))
        lines = []
        for (kind, name), insts in sorted(by_name.items(), key=lambda kv: kv[0][1]):
            lines.append(f"# TYPE {name} {'summary' if kind == 'histogram' else kind}")
            for labels, inst in insts:
                if kind == "counter":
                    lines.append(f"{name}{_prom_labels(labels)} {inst.value:g}")
                elif kind == "gauge":
                    v = inst.value
                    lines.append(
                        f"{name}{_prom_labels(labels)} "
                        f"{'NaN' if math.isnan(v) else format(v, 'g')}"
                    )
                else:
                    for q in QUANTILES:
                        p = inst.percentile(q)
                        qlabel = 'quantile="{:g}"'.format(q / 100.0)
                        lines.append(
                            f"{name}{_prom_labels(labels, qlabel)} "
                            f"{'NaN' if math.isnan(p) else format(p, 'g')}"
                        )
                    lines.append(f"{name}_sum{_prom_labels(labels)} {inst.sum:g}")
                    lines.append(f"{name}_count{_prom_labels(labels)} {inst.count}")
        return "\n".join(lines) + "\n"

    def shard_lines(self, instance: str = "") -> list[str]:
        """One JSONL line per instrument — the fleet-aggregation shard."""
        lines = []
        with self._lock:
            items = list(self._instruments.items())
        header = {
            "kind": "meta",
            "schema": METRICS_SCHEMA_VERSION,
            "instance": instance,
            "ts": time.time(),
        }
        lines.append(json.dumps(header, sort_keys=True))
        for (kind, name, labels), inst in items:
            rec: dict = {"kind": kind, "name": name, "labels": dict(labels),
                         "instance": instance}
            if kind == "histogram":
                rec["count"] = inst.count
                rec["sum"] = inst.sum
                rec["recent"] = inst.recent()
            else:
                rec["value"] = inst.value
            lines.append(json.dumps(rec, sort_keys=True))
        return lines

    def write_shard(self, path: str | Path, instance: str = "") -> Path:
        """Atomically write this instance's metrics shard (JSONL)."""
        from repro.utils.io import atomic_write_text

        return atomic_write_text(
            path, "\n".join(self.shard_lines(instance)) + "\n"
        )


_REGISTRY = MetricsRegistry()


def get_metrics() -> MetricsRegistry:
    """The process-wide registry every instrumented module shares."""
    return _REGISTRY


def reset_metrics() -> None:
    """Zero the process registry in place (test isolation)."""
    _REGISTRY.reset()
