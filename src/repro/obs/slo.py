"""SLO burn-rate alerting over the paper's four objectives.

PR 9 mapped per-request SLO classes (latency-critical / power-capped /
balanced / energy-saving) onto Auto-SpMV's four tuning objectives; this
module *watches* whether served traffic is actually meeting them. Each SLO
class carries up to three targets — a p99 latency bound, an average-power
cap, and a per-request energy budget, i.e. the measurable faces of the
paper's latency/power/energy objectives (efficiency is their ratio and has
no independent target) — and every served request feeds one sample per
targeted dimension.

Evaluation is SRE-style multi-window burn rate: each (class, dimension)
pair keeps a *fast* and a *slow* ``RollingStats`` window, and the burn rate
is observed/target (windowed p99 over the target for latency, windowed mean
over the cap/budget for power and energy). The alert state machine per
class:

* ``ok`` → ``warning`` when a fast window alone burns hot (short spike, or
  the slow window still remembers healthy traffic);
* ``warning`` → ``firing`` when fast AND slow both burn ≥ 1.0 — the
  violation is sustained, not noise;
* ``firing`` holds while any fast burn stays above the warning threshold
  (hysteresis against flapping) and clears straight to ``ok`` below it.

States are exported as gauges (``slo_alert_state``, ``slo_burn_rate``),
served as JSON on the ``/slo`` endpoint, and consumed by the servers: while
a class is firing, ``effective_objective`` escalates its requests from the
class's native objective to the violated dimension's objective (an
energy-saving class blowing its latency SLO is served latency-tuned plans
until the burn clears). Registered ``on_transition`` hooks fire once per
state change.

Targets default from the paper-derived hardware envelope and are
overridable per class via ``--slo-config`` JSON::

    {"fast_window": 16, "fire_burn": 1.0,
     "targets": {"latency-critical": {"p99_latency_s": 0.05}}}
"""

from __future__ import annotations

import json
import math
from dataclasses import asdict, dataclass, field, replace
from pathlib import Path
from typing import Callable

from repro.obs.metrics import get_metrics
from repro.utils.logging import get_logger
from repro.utils.timing import RollingStats

log = get_logger("obs.slo")

OK, WARNING, FIRING = "ok", "warning", "firing"
STATE_LEVEL = {OK: 0, WARNING: 1, FIRING: 2}

# targetable dimensions, in escalation priority order; each name IS the
# paper objective a firing alert escalates the class to
DIMENSIONS = ("latency", "power", "energy")

# the four SLO classes of models/sparse_linear.SLO_OBJECTIVES (kept as
# literals here so importing the tracker never drags in jax; config loading
# validates against this set)
SLO_CLASSES = ("latency-critical", "power-capped", "balanced", "energy-saving")

TransitionHook = Callable[[str, str, str, str | None], None]


@dataclass(frozen=True)
class SloTarget:
    """Per-class targets; ``None`` leaves that dimension untracked."""

    p99_latency_s: float | None = None
    avg_power_w: float | None = None
    energy_per_request_j: float | None = None

    _FIELD_BY_DIMENSION = {
        "latency": "p99_latency_s",
        "power": "avg_power_w",
        "energy": "energy_per_request_j",
    }

    def limit(self, dimension: str) -> float | None:
        return getattr(self, self._FIELD_BY_DIMENSION[dimension])


# Defaults derived from the paper's objective set on the TPU_V5E envelope
# (repro.core.objectives: 220 W package cap): the latency-critical class gets
# the tightest p99, power-capped stays under ~2/3 of the package cap,
# energy-saving gets the leanest per-request joule budget. Absolute numbers
# are serving-environment defaults meant to be overridden via --slo-config.
DEFAULT_TARGETS: dict[str, SloTarget] = {
    "latency-critical": SloTarget(p99_latency_s=0.25),
    "power-capped": SloTarget(p99_latency_s=2.0, avg_power_w=150.0),
    "balanced": SloTarget(
        p99_latency_s=1.0, avg_power_w=200.0, energy_per_request_j=25.0
    ),
    "energy-saving": SloTarget(p99_latency_s=4.0, energy_per_request_j=5.0),
}


@dataclass(frozen=True)
class SloConfig:
    fast_window: int = 32  # samples: spikes show here first
    slow_window: int = 256  # samples: sustained violations show here
    min_samples: int = 8  # fast-window fill before a dimension may alert
    warn_burn: float = 0.85  # fast burn for warning; also the firing floor
    fire_burn: float = 1.0  # fast AND slow burn to fire
    targets: dict[str, SloTarget] = field(
        default_factory=lambda: dict(DEFAULT_TARGETS)
    )

    @classmethod
    def load(cls, path: str | Path) -> "SloConfig":
        """Build a config from JSON, merging per-class targets over the
        defaults. Unknown top-level keys, class names, or target fields are
        errors — a typo'd SLO config silently tracking nothing is worse
        than a crash at launch."""
        raw = json.loads(Path(path).read_text())
        if not isinstance(raw, dict):
            raise ValueError(f"SLO config must be a JSON object, got {type(raw)}")
        scalar_keys = {
            "fast_window", "slow_window", "min_samples", "warn_burn", "fire_burn"
        }
        unknown = set(raw) - scalar_keys - {"targets"}
        if unknown:
            raise ValueError(f"unknown SLO config key(s): {sorted(unknown)}")
        targets = dict(DEFAULT_TARGETS)
        for slo, fields_ in (raw.get("targets") or {}).items():
            if slo not in SLO_CLASSES:
                raise ValueError(
                    f"unknown SLO class {slo!r}; expected one of {sorted(SLO_CLASSES)}"
                )
            bad = set(fields_) - set(SloTarget._FIELD_BY_DIMENSION.values())
            if bad:
                raise ValueError(
                    f"unknown target field(s) for {slo!r}: {sorted(bad)}"
                )
            targets[slo] = replace(targets[slo], **fields_)
        scalars = {k: raw[k] for k in scalar_keys if k in raw}
        return cls(targets=targets, **scalars)


@dataclass
class _ClassState:
    """Windows + alert state for one SLO class."""

    windows: dict[str, tuple[RollingStats, RollingStats]]  # dim -> (fast, slow)
    state: str = OK
    firing_dimension: str | None = None
    samples: int = 0
    alerts: int = 0  # times this class entered FIRING
    transitions: list[dict] = field(default_factory=list)


class SloTracker:
    """Multi-window burn-rate evaluation + the ok→warning→firing machine.

    Single-owner mutation model (the serving thread observes/evaluates; the
    ``/slo`` scrape thread only reads via ``snapshot``), matching the rest
    of the obs layer.
    """

    def __init__(self, config: SloConfig | None = None, registry=None):
        self.config = config or SloConfig()
        self.metrics = registry if registry is not None else get_metrics()
        self._hooks: list[TransitionHook] = []
        self._classes: dict[str, _ClassState] = {}
        for slo, target in self.config.targets.items():
            windows = {
                dim: (
                    RollingStats(self.config.fast_window),
                    RollingStats(self.config.slow_window),
                )
                for dim in DIMENSIONS
                if target.limit(dim) is not None
            }
            if not windows:
                continue  # a class with every target nulled out: untracked
            self._classes[slo] = _ClassState(windows=windows)
            self.metrics.gauge("slo_alert_state", slo=slo).set(STATE_LEVEL[OK])

    # ----------------------------------------------------------------- hooks
    def on_transition(self, hook: TransitionHook) -> None:
        """Register ``hook(slo, old_state, new_state, dimension)``, called
        once per state change during ``evaluate``."""
        self._hooks.append(hook)

    # --------------------------------------------------------------- observe
    def observe(
        self,
        slo: str,
        *,
        latency_s: float,
        energy_j: float | None = None,
        power_w: float | None = None,
    ) -> None:
        """Feed one served request. ``power_w`` defaults to the energy
        accountant's convention (modeled energy over measured wall time)."""
        st = self._classes.get(slo)
        if st is None:
            return
        st.samples += 1
        if power_w is None and energy_j is not None and latency_s > 0:
            power_w = energy_j / latency_s
        samples = {"latency": latency_s, "power": power_w, "energy": energy_j}
        for dim, value in samples.items():
            pair = st.windows.get(dim)
            if pair is None or value is None:
                continue
            pair[0].add(float(value))
            pair[1].add(float(value))

    # ------------------------------------------------------------ burn rates
    def burn_rates(self, slo: str) -> dict[str, dict[str, float]]:
        """Per targeted dimension: {"fast": burn, "slow": burn} — observed
        over target, so 1.0 means exactly at the SLO boundary."""
        st = self._classes.get(slo)
        if st is None:
            return {}
        target = self.config.targets[slo]
        out: dict[str, dict[str, float]] = {}
        for dim, (fast, slow) in st.windows.items():
            limit = target.limit(dim)
            if not limit or limit <= 0 or fast.count == 0:
                continue
            out[dim] = {
                "fast": self._burn(fast, dim, limit),
                "slow": self._burn(slow, dim, limit),
            }
        return out

    @staticmethod
    def _burn(stats: RollingStats, dim: str, limit: float) -> float:
        if dim == "latency":
            observed = stats.percentile(99.0)
        else:  # power cap / energy budget are averages, not tails
            observed = stats.window_mean()
        if math.isnan(observed):
            return 0.0
        return observed / limit

    # -------------------------------------------------------------- evaluate
    def evaluate(self) -> list[dict]:
        """Advance every class's state machine; returns the transitions.

        Also refreshes the exported gauges, so calling this once per served
        batch keeps the scrape surface current."""
        cfg = self.config
        transitions: list[dict] = []
        for slo, st in self._classes.items():
            burns = self.burn_rates(slo)
            fire_dim = None
            warm = False
            worst_burn = 0.0
            for dim in DIMENSIONS:  # priority order decides the escalation
                b = burns.get(dim)
                if b is None or st.windows[dim][0].count < cfg.min_samples:
                    continue
                worst_burn = max(worst_burn, b["fast"])
                if (
                    fire_dim is None
                    and b["fast"] >= cfg.fire_burn
                    and b["slow"] >= cfg.fire_burn
                ):
                    fire_dim = dim
                if b["fast"] >= cfg.warn_burn:
                    warm = True
            if fire_dim is not None:
                new_state, new_dim = FIRING, fire_dim
            elif st.state == FIRING and warm:
                # hysteresis: hold the alert until the fast burn cools below
                # the warning threshold, then clear straight to ok
                new_state, new_dim = FIRING, st.firing_dimension
            elif warm:
                new_state, new_dim = WARNING, None
            else:
                new_state, new_dim = OK, None
            for dim, b in burns.items():
                self.metrics.gauge(
                    "slo_burn_rate", slo=slo, dimension=dim, window="fast"
                ).set(b["fast"])
                self.metrics.gauge(
                    "slo_burn_rate", slo=slo, dimension=dim, window="slow"
                ).set(b["slow"])
            self.metrics.gauge("slo_alert_state", slo=slo).set(
                STATE_LEVEL[new_state]
            )
            if new_state == st.state:
                st.firing_dimension = new_dim if new_state == FIRING else None
                continue
            old = st.state
            st.state = new_state
            st.firing_dimension = new_dim if new_state == FIRING else None
            if new_state == FIRING:
                st.alerts += 1
                self.metrics.counter("slo_alerts_total", slo=slo).inc()
            rec = {
                "slo": slo,
                "from": old,
                "to": new_state,
                "dimension": st.firing_dimension,
                "burn": worst_burn,
            }
            st.transitions.append(rec)
            del st.transitions[:-64]  # bounded history for the snapshot
            transitions.append(rec)
            log.log(
                30 if new_state == FIRING else 20,
                "slo %s: %s -> %s (dimension=%s, fast burn %.2f)",
                slo, old, new_state, st.firing_dimension, worst_burn,
            )
            for hook in self._hooks:
                hook(slo, old, new_state, st.firing_dimension)
        return transitions

    # ------------------------------------------------------------ escalation
    def state(self, slo: str) -> str:
        st = self._classes.get(slo)
        return st.state if st is not None else OK

    def effective_objective(self, slo: str) -> str:
        """The objective requests of this class should be served under *now*:
        the class's native objective, unless its alert is firing — then the
        violated dimension's objective takes over until the burn clears."""
        from repro.models.sparse_linear import slo_objective  # lazy: jax-heavy

        native = slo_objective(slo)
        st = self._classes.get(slo)
        if st is None or st.state != FIRING or st.firing_dimension is None:
            return native
        escalated = st.firing_dimension  # dimension names ARE objectives
        if escalated != native:
            self.metrics.counter("slo_escalated_requests_total", slo=slo).inc()
        return escalated

    # -------------------------------------------------------------- snapshot
    def snapshot(self) -> dict:
        """The ``/slo`` endpoint / server-summary payload."""
        classes = {}
        for slo, st in self._classes.items():
            classes[slo] = {
                "state": st.state,
                "firing_dimension": st.firing_dimension,
                "samples": st.samples,
                "alerts": st.alerts,
                "targets": {
                    k: v
                    for k, v in asdict(self.config.targets[slo]).items()
                    if v is not None
                },
                "burn_rates": self.burn_rates(slo),
                "transitions": list(st.transitions[-8:]),
            }
        return {
            "config": {
                "fast_window": self.config.fast_window,
                "slow_window": self.config.slow_window,
                "min_samples": self.config.min_samples,
                "warn_burn": self.config.warn_burn,
                "fire_burn": self.config.fire_burn,
            },
            "classes": classes,
        }
