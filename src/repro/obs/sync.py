"""Live fleet telemetry sync: one shared bandit posterior across instances.

The ROADMAP's fleet-scale item asks for telemetry that "aggregates across
server instances so the fleet shares one bandit posterior instead of
relearning per process". ``obs/aggregate.py`` merges shards *offline*; this
module closes the live loop:

* ``posterior_lines`` / ``calibration_lines`` serialize the
  ``AdaptiveFormatSelector`` posterior (per-cell arm pulls + measured mean
  values, plus the cell's incumbent) and the recorder's calibration pairs
  as ``kind``-discriminated JSONL records in the metrics shard schema, so
  a fleet shard drops straight into ``merge_shards``;
* ``FleetSync`` periodically writes this instance's shard into a shared
  ``--fleet-dir`` (atomic replace — peers never see torn shards) and folds
  every peer shard back into the local selector via
  ``AdaptiveFormatSelector.absorb``, then ``reconcile``s each touched cell:
  if the fleet's combined evidence beats the local incumbent by the drift
  margin, the measured-best format is promoted and the session's cached
  plans for that cell are dropped.

Exported shards carry only *locally measured* pulls (absorbed peer evidence
stays in the arms' ``absorbed_*`` fields), so the merged fleet posterior's
pull counts are exactly the sum over instances — syncing is idempotent and
evidence never echoes back amplified.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

from repro.obs.aggregate import read_shard_lines
from repro.obs.metrics import METRICS_SCHEMA_VERSION, get_metrics
from repro.utils.io import atomic_write_text
from repro.utils.logging import get_logger

log = get_logger("obs.sync")

POSTERIOR_KIND = "posterior"
CALIBRATION_KIND = "calibration"

# calibration pairs shipped per format per shard: enough for a peer to seed
# a fit, small enough that shards stay scrape-sized
MAX_SYNC_PAIRS = 64


def posterior_lines(selector, instance: str = "") -> list[str]:
    """One JSONL record per locally-measured arm of every bandit cell."""
    lines = []
    for (bucket, objective), cell in sorted(selector.cells().items()):
        for fmt, arm in sorted(cell.arms.items()):
            if not arm.pulls:  # locally measured evidence only — no echo
                continue
            lines.append(
                json.dumps(
                    {
                        "kind": POSTERIOR_KIND,
                        "bucket": bucket,
                        "objective": objective,
                        "fmt": fmt,
                        "pulls": arm.pulls,
                        "value": arm.stats.mean,
                        "incumbent": cell.incumbent,
                        "instance": instance,
                    },
                    sort_keys=True,
                )
            )
    return lines


def calibration_lines(
    recorder, instance: str = "", max_pairs: int = MAX_SYNC_PAIRS
) -> list[str]:
    """One JSONL record per format with its recent calibration pairs."""
    lines = []
    for fmt, pairs in sorted(recorder.calibration_samples().items()):
        if not pairs:
            continue
        lines.append(
            json.dumps(
                {
                    "kind": CALIBRATION_KIND,
                    "fmt": fmt,
                    "pairs": [[p, m] for p, m in pairs[-max_pairs:]],
                    "instance": instance,
                },
                sort_keys=True,
            )
        )
    return lines


def write_fleet_shard(
    path: str | Path,
    *,
    selector=None,
    recorder=None,
    registry=None,
    instance: str = "",
) -> Path:
    """Write one self-contained fleet shard (metrics + posterior +
    calibration records) with an atomic replace, so concurrently-reading
    peers only ever see a complete shard."""
    path = Path(path)
    if registry is not None:
        lines = registry.shard_lines(instance)  # includes the meta header
    else:
        lines = [
            json.dumps(
                {
                    "kind": "meta",
                    "schema": METRICS_SCHEMA_VERSION,
                    "instance": instance,
                    "ts": time.time(),
                },
                sort_keys=True,
            )
        ]
    if selector is not None:
        lines.extend(posterior_lines(selector, instance))
    if recorder is not None:
        lines.extend(calibration_lines(recorder, instance))
    atomic_write_text(path, "\n".join(lines) + "\n")
    return path


class FleetSync:
    """Periodic export + peer absorption bound to one serving session."""

    def __init__(
        self,
        session,
        fleet_dir: str | Path,
        *,
        instance: str = "serve",
        sync_every: int = 0,
        registry=None,
    ):
        if session.adaptive is None:
            raise ValueError(
                "FleetSync needs a session with an AdaptiveFormatSelector "
                "(the posterior is what the fleet shares)"
            )
        self.session = session
        self.fleet_dir = Path(fleet_dir)
        self.fleet_dir.mkdir(parents=True, exist_ok=True)
        self.instance = instance
        self.sync_every = int(sync_every)
        self.registry = registry if registry is not None else get_metrics()
        self.syncs = 0
        self.promotions = 0
        self.last: dict = {}
        self._since = 0

    @property
    def shard_path(self) -> Path:
        return self.fleet_dir / f"shard-{self.instance}.jsonl"

    # ------------------------------------------------------------------ sync
    def maybe_sync(self, served: int = 1) -> dict | None:
        """Count served requests; run a full sync every ``sync_every``."""
        if self.sync_every <= 0:
            return None
        self._since += served
        if self._since < self.sync_every:
            return None
        self._since = 0
        return self.sync()

    def sync(self) -> dict:
        """Export the local shard, absorb every peer shard, reconcile."""
        self.export()
        stats = self.absorb_peers()
        self.syncs += 1
        self.registry.counter("fleet_syncs_total").inc()
        self.last = stats
        log.info(
            "fleet sync #%d [%s]: %d peer shard(s), %d arm(s) absorbed, "
            "%d promotion(s)",
            self.syncs, self.instance, stats["peers"],
            stats["arms_absorbed"], stats["promotions"],
        )
        return stats

    def export(self) -> Path:
        return write_fleet_shard(
            self.shard_path,
            selector=self.session.adaptive,
            recorder=self.session.telemetry,
            registry=self.registry,
            instance=self.instance,
        )

    def absorb_peers(self) -> dict:
        """Fold every peer shard's posterior into the local selector.

        Peer totals are recomputed from the current shard set each call and
        installed via the ``absorb`` setter, so repeated absorption is
        idempotent. Cells whose combined evidence overturns the local
        incumbent are promoted and their cached plans invalidated."""
        peers = [
            p
            for p in sorted(self.fleet_dir.glob("shard-*.jsonl"))
            if p != self.shard_path
        ]
        merged: dict[tuple[str, str, str], list[float]] = {}
        dropped = 0
        if peers:
            records, dropped = read_shard_lines(peers)
            for rec in records:
                if rec.get("kind") != POSTERIOR_KIND:
                    continue
                try:
                    pulls = int(rec["pulls"])
                    value = float(rec["value"])
                    key = (str(rec["bucket"]), str(rec["objective"]), str(rec["fmt"]))
                except (KeyError, TypeError, ValueError):
                    dropped += 1
                    continue
                if pulls <= 0 or value <= 0:
                    continue
                cell = merged.setdefault(key, [0, 0.0])
                cell[0] += pulls
                cell[1] += value * pulls
        selector = self.session.adaptive
        promotions = 0
        touched: set[tuple[str, str]] = set()
        for (bucket, objective, fmt), (pulls, weighted) in merged.items():
            selector.absorb(
                bucket, objective, fmt, pulls=int(pulls), value=weighted / pulls
            )
            touched.add((bucket, objective))
        for bucket, objective in sorted(touched):
            promoted = selector.reconcile(bucket, objective)
            if promoted is not None:
                self.session.invalidate(bucket, objective)
                promotions += 1
                self.registry.counter("fleet_promotions_total").inc()
                log.info(
                    "fleet evidence promoted %s for bucket=%s objective=%s",
                    promoted, bucket, objective,
                )
        self.promotions += promotions
        self.registry.gauge("fleet_peer_shards").set(len(peers))
        self.registry.gauge("fleet_absorbed_arms").set(len(merged))
        return {
            "peers": len(peers),
            "arms_absorbed": len(merged),
            "promotions": promotions,
            "dropped_lines": dropped,
        }

    # --------------------------------------------------------------- summary
    def summary(self) -> dict:
        return {
            "instance": self.instance,
            "fleet_dir": str(self.fleet_dir),
            "sync_every": self.sync_every,
            "syncs": self.syncs,
            "promotions": self.promotions,
            "last": dict(self.last),
        }
