"""Structured tracing: nested spans with a thread-safe in-process collector.

Every hot-path section (``session.optimize`` → ``cache.lookup`` →
``kernel.compile`` → ``kernel.execute``) opens a *span*: a named, attributed
interval that records its parent from a per-thread stack, so one served
request becomes a small tree showing exactly where its wall time went —
plan-cache lookup vs. predictor inference vs. Pallas prepare vs. execution.
The paper's headline numbers are *measured* latencies (§6.3); a trace stream
is how a serving reproduction keeps that measurement methodology inspectable
per request instead of trusting aggregate counters.

Cost discipline: an enabled span is one ``perf_counter`` pair plus a dict
append into a bounded deque; a disabled tracer hands out a shared no-op
context manager, so instrumented code pays one attribute read. Export is a
JSONL append-log following ``telemetry/recorder.py``'s torn-line convention
(a crash mid-append leaves at most one unparseable trailing line, which
``load_spans`` skips), and ``profile_capture`` optionally wraps a region in
``jax.profiler`` so a fused-kernel launch can be opened in Perfetto.
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from pathlib import Path

from repro.utils.logging import get_logger

log = get_logger("obs.trace")

TRACE_SCHEMA_VERSION = 1


class _NoopSpan:
    """Shared do-nothing context manager: the disabled-tracer fast path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs) -> "_NoopSpan":
        return self


NOOP_SPAN = _NoopSpan()


class _Span:
    """One live span; becomes a plain dict in the collector on exit."""

    __slots__ = ("tracer", "name", "attrs", "span_id", "parent_id", "t0", "ts")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self.tracer = tracer
        self.name = name
        self.attrs = attrs

    def set(self, **attrs) -> "_Span":
        """Attach attributes discovered mid-span (e.g. hit/miss verdicts)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "_Span":
        tr = self.tracer
        self.span_id = tr._next_id()
        stack = tr._stack()
        self.parent_id = stack[-1] if stack else None
        stack.append(self.span_id)
        self.ts = time.time()
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, *exc) -> bool:
        dur = time.perf_counter() - self.t0
        stack = self.tracer._stack()
        if stack and stack[-1] == self.span_id:
            stack.pop()
        rec = {
            "name": self.name,
            "id": self.span_id,
            "parent": self.parent_id,
            "ts": self.ts,
            "dur_s": dur,
            "thread": threading.get_ident(),
        }
        if exc_type is not None:
            rec["error"] = exc_type.__name__
        if self.attrs:
            rec["attrs"] = self.attrs
        self.tracer._collect(rec)
        return False


class Tracer:
    """Thread-safe span collector with bounded memory and JSONL export.

    ``max_spans`` bounds the in-process buffer (oldest spans drop first —
    a serving loop must not grow RSS with its request count); ``drops``
    counts what the bound discarded so exports are honest about truncation.
    """

    def __init__(self, *, enabled: bool = True, max_spans: int = 65536):
        self.enabled = enabled
        self.max_spans = int(max_spans)
        self._spans: deque[dict] = deque(maxlen=self.max_spans)
        self._exported = 0  # spans already flushed to the JSONL log
        self.drops = 0
        self._lock = threading.Lock()
        self._local = threading.local()
        self._id_counter = 0

    # -------------------------------------------------------------- internals
    def _stack(self) -> list[int]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _next_id(self) -> int:
        with self._lock:
            self._id_counter += 1
            return self._id_counter

    def _collect(self, rec: dict) -> None:
        with self._lock:
            if len(self._spans) == self.max_spans:
                self.drops += 1
                if self._exported:  # the dropped span was the oldest
                    self._exported -= 1
            self._spans.append(rec)

    # -------------------------------------------------------------------- api
    def span(self, name: str, **attrs):
        """Open a nested span; use as ``with tracer.span("cache.lookup"):``."""
        if not self.enabled:
            return NOOP_SPAN
        return _Span(self, name, attrs)

    def spans(self) -> list[dict]:
        with self._lock:
            return list(self._spans)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()
            self._exported = 0
            self.drops = 0

    def summary(self) -> dict:
        """Per-name counts + total duration of the buffered spans."""
        by_name: dict[str, dict] = {}
        for rec in self.spans():
            cell = by_name.setdefault(rec["name"], {"count": 0, "total_s": 0.0})
            cell["count"] += 1
            cell["total_s"] += rec["dur_s"]
        return {"spans": sum(c["count"] for c in by_name.values()),
                "drops": self.drops, "by_name": by_name}

    # ------------------------------------------------------------ persistence
    def export_jsonl(self, path: str | Path) -> int:
        """Append spans not yet exported to a JSONL shard; returns lines.

        Same crash tolerance as the telemetry recorder: if the file's last
        byte is not a newline (a torn previous append), a newline is
        prepended so only that one already-torn line is lost on replay."""
        path = Path(path)
        with self._lock:
            fresh = list(self._spans)[self._exported:]
            self._exported = len(self._spans)
        if not fresh:
            return 0
        path.parent.mkdir(parents=True, exist_ok=True)
        chunk = "".join(json.dumps(r, sort_keys=True) + "\n" for r in fresh)
        if path.exists() and path.stat().st_size:
            with open(path, "rb") as f:
                f.seek(-1, os.SEEK_END)
                if f.read(1) != b"\n":
                    chunk = "\n" + chunk
        with open(path, "a") as f:
            f.write(chunk)
            f.flush()
            os.fsync(f.fileno())
        return len(fresh)


def load_spans(path: str | Path) -> list[dict]:
    """Replay a span JSONL shard, skipping torn/foreign lines."""
    out = []
    for line in Path(path).read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except ValueError:
            continue  # torn trailing line from an interrupted append
        if isinstance(rec, dict) and "name" in rec and "dur_s" in rec:
            out.append(rec)
    return out


def span_children(spans: list[dict], parent_id) -> list[dict]:
    """The direct children of one span (trace-tree navigation helper)."""
    return [s for s in spans if s.get("parent") == parent_id]


_TRACER = Tracer()


def get_tracer() -> Tracer:
    """The process-wide tracer every instrumented module shares."""
    return _TRACER


def span(name: str, **attrs):
    """Module-level convenience: ``with span("session.optimize"): ...``."""
    return _TRACER.span(name, **attrs)


class profile_capture:
    """Optionally wrap a region in ``jax.profiler`` (Perfetto/TensorBoard).

    ``with profile_capture("artifacts/profile"):`` captures every XLA/Pallas
    launch inside into a trace a real viewer can open. Failures (no
    profiler support in this jax build, a capture already running) degrade
    to a logged warning — profiling is diagnostic, never load-bearing."""

    def __init__(self, log_dir: str | Path):
        self.log_dir = str(log_dir)
        self._active = False

    def __enter__(self):
        try:
            import jax

            jax.profiler.start_trace(self.log_dir)
            self._active = True
            log.info("jax profiler capture -> %s", self.log_dir)
        except Exception as exc:
            log.warning("profiler capture unavailable (%s); continuing", exc)
        return self

    def __exit__(self, *exc) -> bool:
        if self._active:
            try:
                import jax

                jax.profiler.stop_trace()
            except Exception as stop_exc:
                log.warning("profiler stop failed (%s)", stop_exc)
        return False
