from repro.optim.adamw import AdamWConfig, apply_adamw, init_opt_state
from repro.optim.schedule import constant, cosine_schedule, linear_warmup
from repro.optim.compress import compress_gradients, init_error_feedback

__all__ = [
    "AdamWConfig",
    "apply_adamw",
    "init_opt_state",
    "constant",
    "cosine_schedule",
    "linear_warmup",
    "compress_gradients",
    "init_error_feedback",
]
