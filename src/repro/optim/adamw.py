"""AdamW from scratch (optax is not installed).

Decoupled weight decay, global-norm gradient clipping, optional low-precision
(bf16) first/second moments for the trillion-parameter archs — the moment
dtype is the dominant optimizer-memory knob at 512 chips (DESIGN.md §6).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float | Callable[[jax.Array], jax.Array] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0
    state_dtype: str = "float32"  # bf16 halves optimizer memory

    def lr_at(self, step: jax.Array) -> jax.Array:
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return jnp.asarray(self.learning_rate, jnp.float32)


def init_opt_state(params: Any, cfg: AdamWConfig) -> dict:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_adamw(
    params: Any, grads: Any, state: dict, cfg: AdamWConfig
) -> tuple[Any, dict, dict]:
    """One AdamW update. Returns (params, state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.grad_clip_norm > 0:
        scale = jnp.minimum(1.0, cfg.grad_clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    lr = cfg.lr_at(step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mh = m32 / b1c
        vh = v32 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (
            (p.astype(jnp.float32) - lr * delta).astype(p.dtype),
            m32.astype(sdt),
            v32.astype(sdt),
        )

    # explicit flatten/unflatten: the params tree may contain tuples as
    # structure, so a tuple-returning tree.map would be ambiguous
    p_leaves, treedef = jax.tree.flatten(params)
    results = [
        upd(p, g, m, v)
        for p, g, m, v in zip(
            p_leaves,
            jax.tree.leaves(grads),
            jax.tree.leaves(state["m"]),
            jax.tree.leaves(state["v"]),
        )
    ]
    new_params = treedef.unflatten([r[0] for r in results])
    new_m = treedef.unflatten([r[1] for r in results])
    new_v = treedef.unflatten([r[2] for r in results])
    return (
        new_params,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
