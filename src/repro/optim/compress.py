"""Top-k gradient compression with error feedback (Stich et al. 2018).

At 1000+-node scale the DP all-reduce dominates step time for small models;
top-k sparsification with error feedback keeps convergence while cutting
exchanged bytes ~1/k. Under pjit/GSPMD the all-reduce is emitted by XLA
inside the backward pass, so the compression here is applied at the
optimizer boundary: it is exact in semantics (error feedback carries the
residual) and becomes a true bandwidth saving when the DP reduce is staged
through a shard_map psum of the sparsified values — the integration point
is `trainer.make_train_step(compress_frac=...)`, and the bytes saved are
reported in the roofline collective term analysis.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _topk_sparsify(g: jax.Array, frac: float) -> jax.Array:
    flat = g.reshape(-1)
    k = max(int(flat.shape[0] * frac), 1)
    if k >= flat.shape[0]:
        return g
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0.0)


def compress_gradients(
    grads: Any, error: Any, frac: float = 0.1
) -> tuple[Any, Any, dict]:
    """Returns (compressed grads, new error feedback, metrics)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        sparse = _topk_sparsify(g32, frac)
        return sparse, g32 - sparse

    g_leaves, treedef = jax.tree.flatten(grads)
    results = [one(g, e) for g, e in zip(g_leaves, jax.tree.leaves(error))]
    comp = treedef.unflatten([r[0] for r in results])
    new_err = treedef.unflatten([r[1] for r in results])
    nnz = sum(jnp.sum(c != 0).astype(jnp.float32) for c in jax.tree.leaves(comp))
    tot = sum(c.size for c in jax.tree.leaves(comp))
    return comp, new_err, {"compress_density": nnz / tot}
