"""Top-k gradient compression with error feedback (Stich et al. 2018).

At 1000+-node scale the DP all-reduce dominates step time for small models;
top-k sparsification with error feedback keeps convergence while cutting
exchanged bytes ~1/k. Under pjit/GSPMD the all-reduce is emitted by XLA
inside the backward pass, so the compression here is applied at the
optimizer boundary: it is exact in semantics (error feedback carries the
residual) and becomes a true bandwidth saving when the DP reduce is staged
through a shard_map psum of the sparsified values — the integration point
is `trainer.make_train_step(compress_frac=...)`, and the bytes saved are
reported in the roofline collective term analysis.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _topk_sparsify(g: jax.Array, frac: float) -> jax.Array:
    """Keep the top-``frac`` fraction of entries by magnitude.

    ``frac`` is static (a Python float), so the edge cases resolve at trace
    time: ``frac <= 0`` keeps nothing (the error feedback then carries the
    full gradient forward), ``frac >= 1`` — or any ``frac`` whose k covers
    the whole tensor — returns ``g`` unchanged, and any positive ``frac``
    keeps at least one entry. Ties at the threshold magnitude are ALL kept
    (the compare is ``>=``), so the realized density can exceed ``frac`` on
    heavily tied tensors — by design: dropping an arbitrary subset of equal
    magnitudes would make the compression nondeterministic across backends.
    """
    if frac <= 0.0:
        return jnp.zeros_like(g)
    flat = g.reshape(-1)
    k = max(int(flat.shape[0] * frac), 1)
    if k >= flat.shape[0]:
        return g
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0.0)


def magnitude_prune(w: np.ndarray, density: float) -> tuple[np.ndarray, float]:
    """Export a magnitude-pruned weight as a dense array + density stat.

    Host-side numpy twin of ``_topk_sparsify`` for the sparse-serving path:
    keeps exactly ``k = round(density * size)`` entries with the largest
    magnitudes (deterministic tie-break: the earlier flat index wins — an
    exact-k contract, unlike the threshold compare above) and zeroes the
    rest. ``density <= 0`` zeroes everything; ``density >= 1`` returns a
    float32 copy unchanged. Returns ``(pruned float32 array, achieved
    density)`` — the achieved density can fall below the request when the
    input already holds zeros among its top-k magnitudes.
    """
    w = np.asarray(w, dtype=np.float32)
    size = int(w.size)
    if size == 0:
        return w.copy(), 0.0
    if density >= 1.0:
        return w.copy(), float(np.count_nonzero(w)) / size
    out = np.zeros_like(w)
    k = int(round(float(density) * size))
    if k <= 0:
        return out, 0.0
    order = np.argsort(-np.abs(w).reshape(-1), kind="stable")[:k]
    out_flat, w_flat = out.reshape(-1), w.reshape(-1)
    out_flat[order] = w_flat[order]
    return out, float(np.count_nonzero(out)) / size


def compress_gradients(
    grads: Any, error: Any, frac: float = 0.1
) -> tuple[Any, Any, dict]:
    """Returns (compressed grads, new error feedback, metrics)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        sparse = _topk_sparsify(g32, frac)
        return sparse, g32 - sparse

    g_leaves, treedef = jax.tree.flatten(grads)
    results = [one(g, e) for g, e in zip(g_leaves, jax.tree.leaves(error))]
    comp = treedef.unflatten([r[0] for r in results])
    new_err = treedef.unflatten([r[1] for r in results])
    nnz = sum(jnp.sum(c != 0).astype(jnp.float32) for c in jax.tree.leaves(comp))
    tot = sum(c.size for c in jax.tree.leaves(comp))
    return comp, new_err, {"compress_density": nnz / tot}
