"""Partitioned heterogeneous-format SpMV.

The paper's run-time mode picks one format for the whole matrix; this
subsystem runs it per row block. ``partitioner`` splits the row range into
nnz-balanced blocks (each with its own Table-2 feature vector), ``plan``
routes every block through the format registry + predictors + cost model
and searches block counts {1, 2, 4, 8} with a monolithic fallback, and
``executor`` runs the winning composite plan — heterogeneous per-block
Pallas kernels on one device, every block fused into ONE Pallas launch
(``compile_fused_partitioned``), or one block per device over a mesh
``data`` axis via ``shard_map`` (X gathered, Y shards local).

Session/cache/serving integration lives in ``repro.core.session``
(``partitioned_optimize``), ``repro.core.cache`` (per-block plan entries),
and ``repro.train.serve`` / ``repro.launch.serve`` (``--partition``).
"""

from repro.partition.executor import (
    BlockKernel,
    FusedPartitionedSpmv,
    PartitionedSpmv,
    ShardedPartitionedSpmv,
    compile_fused_partitioned,
    compile_partitioned,
    shard_partitioned,
)
from repro.partition.partitioner import (
    SUPPORTED_BLOCK_COUNTS,
    RowBlock,
    RowPartition,
    partition_rows,
)
from repro.partition.plan import (
    BlockPlan,
    CompositePlan,
    plan_for_partition,
    plan_partitioned,
    route_block,
)

__all__ = [
    "BlockKernel",
    "BlockPlan",
    "CompositePlan",
    "FusedPartitionedSpmv",
    "PartitionedSpmv",
    "RowBlock",
    "RowPartition",
    "SUPPORTED_BLOCK_COUNTS",
    "ShardedPartitionedSpmv",
    "compile_fused_partitioned",
    "compile_partitioned",
    "partition_rows",
    "plan_for_partition",
    "plan_partitioned",
    "route_block",
    "shard_partitioned",
]
