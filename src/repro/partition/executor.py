"""Composite execution of a partitioned plan.

Two execution paths, matching the two scales the ROADMAP cares about:

* ``PartitionedSpmv`` — single device. Each block's format-specific Pallas
  kernel (compiled through the ``FormatSpec`` registry and the process-wide
  kernel memo, keyed per row range) runs in sequence and the per-block
  outputs concatenate back into ``y``. Formats are fully heterogeneous —
  this is the paper's run-time mode, per block.

* ``ShardedPartitionedSpmv`` — multi device. Row blocks map one-per-device
  onto a mesh ``data`` axis via ``shard_map``. SPMD requires one program on
  every device, so the sharded path executes through a homogeneous *carrier*
  format (ELL planes, padded to a common per-block geometry and stacked on a
  leading "blocks" axis); the nnz-balanced partition is what keeps the
  per-device work even. Sharding follows ``repro.dist.sharding.SPMV_RULES``:
  the blocks axis shards over ``data``, X is gathered (replicated) to every
  device, and each Y shard stays local to the device that computed it.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Hashable

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import SPMV_RULES, spec_for as sharding_spec, spmv_mesh
from repro.kernels.common import DEFAULT_SCHEDULE, KernelSchedule, ceil_to, pad_axis
from repro.kernels.ell import ell_spmv_pallas
from repro.kernels.ops import PreparedSpmv, compile_spmv_block
from repro.obs.trace import span as _span
from repro.partition.partitioner import RowPartition
from repro.partition.plan import CompositePlan
from repro.sparse.registry import get_format
from repro.utils.logging import get_logger

log = get_logger("partition.executor")

CARRIER_FORMAT = "ell"  # dense-plane storage: stackable + shardable


@dataclass(frozen=True)
class BlockKernel:
    """One row block's prepared kernel, with enough identity to observe."""

    index: int
    row_start: int
    row_end: int
    fmt: str
    kernel: PreparedSpmv


class PartitionedSpmv:
    """Heterogeneous-format composite SpMV on one device.

    Calls each block's ``PreparedSpmv`` and concatenates the outputs in row
    order. ``timed_call`` additionally returns per-block wall times so the
    serving layer can feed every (block, format) arm its own measurement.
    """

    def __init__(self, blocks: list[BlockKernel], n_rows: int):
        if not blocks:
            raise ValueError("PartitionedSpmv needs at least one block")
        self.blocks = list(blocks)
        self.n_rows = n_rows
        self._warmed = False

    @property
    def n_blocks(self) -> int:
        return len(self.blocks)

    @property
    def formats(self) -> tuple[str, ...]:
        return tuple(b.fmt for b in self.blocks)

    def __call__(self, x: jax.Array) -> jax.Array:
        x = jnp.asarray(x)
        with _span(
            "kernel.execute",
            mode="partitioned",
            n_blocks=self.n_blocks,
            formats="+".join(self.formats),
        ):
            parts = [b.kernel(x) for b in self.blocks]
            return parts[0] if len(parts) == 1 else jnp.concatenate(parts)

    def timed_call(
        self, x: jax.Array, *, warmup: bool = True
    ) -> tuple[np.ndarray, list[float]]:
        """Execute block-by-block, timing each kernel — the measurement feed
        for per-block telemetry arms.

        The first ever call runs every block once untimed (``warmup``):
        without it the first sample's window includes trace + compile time,
        often orders of magnitude above steady state, and that poisoned
        sample seeds the bandit arms and the drift detector. Timing blocks
        on ``block_until_ready`` so only the kernel's own async work is in
        the window, not the host-side copy a full ``np.asarray`` adds."""
        x = jnp.asarray(x)
        if warmup and not self._warmed:
            for b in self.blocks:
                jax.block_until_ready(b.kernel(x))
            self._warmed = True
        parts, times = [], []
        for b in self.blocks:
            with _span("kernel.execute", mode="block", block=b.index, fmt=b.fmt):
                t0 = time.perf_counter()
                y = jax.block_until_ready(b.kernel(x))
                times.append(time.perf_counter() - t0)
            parts.append(np.asarray(y))
        return np.concatenate(parts), times


def compile_partitioned(
    dense: np.ndarray,
    plan: CompositePlan,
    *,
    interpret: bool = True,
    memo_key: Hashable | None = None,
) -> PartitionedSpmv:
    """Compile every block of ``plan`` through the registry + kernel memo."""
    dense = np.asarray(dense)
    blocks = [
        BlockKernel(
            index=bp.block.index,
            row_start=bp.block.row_start,
            row_end=bp.block.row_end,
            fmt=bp.fmt,
            kernel=compile_spmv_block(
                dense,
                bp.block.row_start,
                bp.block.row_end,
                bp.fmt,
                bp.schedule,
                interpret=interpret,
                memo_key=memo_key,
            ),
        )
        for bp in plan.blocks
    ]
    log.info(
        "compiled partitioned kernel: %d block(s), formats=%s",
        len(blocks),
        "+".join(b.fmt for b in blocks),
    )
    return PartitionedSpmv(blocks, plan.partition.n_rows)


class FusedPartitionedSpmv:
    """Heterogeneous composite SpMV in ONE Pallas launch.

    The sequential ``PartitionedSpmv`` pays one kernel launch per block plus
    a host-side concatenate; this wrapper holds the composite lowered to a
    single fused stream (``repro.kernels.fused``): program ids map to
    (block, tile) work items through the prefix-sum work descriptor, and
    every program scatter-writes its y shard in place into the one
    VMEM-resident output buffer. Exposes the same identity surface as the
    sequential executor (``formats`` / ``n_blocks``) so serving code can
    treat either interchangeably; per-block timing is structurally
    impossible here (one launch), so telemetry-driven paths keep the
    sequential executor.
    """

    def __init__(self, kernel, plan: CompositePlan):
        self.kernel = kernel  # repro.kernels.fused.FusedSpmv
        self.n_rows = plan.partition.n_rows
        self._formats = tuple(bp.fmt for bp in plan.blocks)
        self._block_ranges = tuple(
            (bp.block.row_start, bp.block.row_end) for bp in plan.blocks
        )

    @property
    def n_blocks(self) -> int:
        return len(self._formats)

    @property
    def formats(self) -> tuple[str, ...]:
        return self._formats

    @property
    def n_tiles(self) -> int:
        return self.kernel.n_tiles

    def descriptor(self) -> dict:
        """Work-descriptor layout (docs/diagnostics): tile size, the program
        id -> flat tile map, and each work item's owning block."""
        return {
            "tile": self.kernel.tile,
            "tile_map": np.asarray(self.kernel.tile_map).tolist(),
            "block_of_tile": list(self.kernel.block_of_tile),
            "block_ranges": list(self._block_ranges),
        }

    def __call__(self, x: jax.Array) -> jax.Array:
        with _span(
            "kernel.execute",
            mode="fused",
            n_blocks=self.n_blocks,
            formats="+".join(self.formats),
        ):
            return self.kernel(x)


def compile_fused_partitioned(
    dense: np.ndarray,
    plan: CompositePlan,
    *,
    interpret: bool = True,
    memo_key: Hashable | None = None,
) -> FusedPartitionedSpmv:
    """Lower ``plan`` to its single-launch executor (one memo entry)."""
    from repro.kernels.ops import compile_spmv_fused

    kernel = compile_spmv_fused(
        np.asarray(dense), plan, interpret=interpret, memo_key=memo_key
    )
    fused = FusedPartitionedSpmv(kernel, plan)
    log.info(
        "compiled fused partitioned kernel: %d block(s) -> %d work item(s) "
        "of %d elems, formats=%s",
        fused.n_blocks,
        fused.n_tiles,
        kernel.tile,
        "+".join(fused.formats),
    )
    return fused


class ShardedPartitionedSpmv:
    """SPMD multi-device composite SpMV (one row block per mesh device).

    ``sharded_call`` returns the raw ``(n_blocks, padded_rows)`` output with
    its Y shards still resident on the devices that computed them (callers
    composing further sharded work should stay in this form); ``__call__``
    gathers and concatenates the valid rows into a host ``(n_rows,)`` array.
    """

    def __init__(
        self,
        dense: np.ndarray,
        partition: RowPartition,
        *,
        schedule: KernelSchedule = DEFAULT_SCHEDULE,
        mesh=None,
        interpret: bool = True,
    ):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import NamedSharding

        dense = np.asarray(dense)
        self.partition = partition
        self.schedule = schedule
        self.mesh = mesh if mesh is not None else spmv_mesh(partition.n_blocks)
        axis_size = self.mesh.shape["data"]
        if partition.n_blocks != axis_size:
            raise ValueError(
                f"partition has {partition.n_blocks} blocks but the mesh "
                f"data axis has {axis_size} devices; partition with "
                f"n_blocks == mesh extent (spmv_mesh(n_blocks))"
            )

        # homogeneous ELL carrier: per-block planes padded to one geometry
        spec = get_format(CARRIER_FORMAT)
        mats = [
            spec.prepare(dense[b.row_start : b.row_end], schedule)
            for b in partition.blocks
        ]
        R = max(int(m.data.shape[0]) for m in mats)
        W = max(int(m.data.shape[1]) for m in mats)
        R, W = ceil_to(R, schedule.rows_per_block), ceil_to(W, schedule.nnz_tile)
        data = np.stack(
            [pad_axis(pad_axis(np.asarray(m.data), 0, R), 1, W) for m in mats]
        )
        cols = np.stack(
            [pad_axis(pad_axis(np.asarray(m.cols), 0, R), 1, W) for m in mats]
        )

        # dist.sharding rules: blocks axis -> data; X replicated; Y local
        plane_spec = sharding_spec(self.mesh, data.shape, ("blocks", None, None), SPMV_RULES)
        x_spec = sharding_spec(self.mesh, (partition.n_cols,), (None,), SPMV_RULES)
        y_spec = sharding_spec(self.mesh, (partition.n_blocks, R), ("blocks", None), SPMV_RULES)
        self.data = jax.device_put(data, NamedSharding(self.mesh, plane_spec))
        self.cols = jax.device_put(cols, NamedSharding(self.mesh, plane_spec))
        self._x_sharding = NamedSharding(self.mesh, x_spec)
        self.padded_rows = R

        def _block_body(d, c, x):
            # local shard: (1, R, W) planes + the replicated (gathered) x
            y = ell_spmv_pallas(d[0], c[0], x, schedule, interpret=interpret)
            return y[None, :]

        self._fn = jax.jit(
            shard_map(
                _block_body,
                mesh=self.mesh,
                in_specs=(plane_spec, plane_spec, x_spec),
                out_specs=y_spec,
                # pallas_call has no shard_map replication rule; the body is
                # purely local (no collectives), so the check adds nothing
                check_rep=False,
            )
        )

    @property
    def n_blocks(self) -> int:
        return self.partition.n_blocks

    def sharded_call(self, x: jax.Array) -> jax.Array:
        """Run the SPMD kernel; the result's Y shards stay device-local."""
        x = jax.device_put(jnp.asarray(x), self._x_sharding)
        return self._fn(self.data, self.cols, x)

    def __call__(self, x: jax.Array) -> np.ndarray:
        y = np.asarray(self.sharded_call(x))  # gathers shards to host
        return np.concatenate(
            [y[b.index, : b.n_rows] for b in self.partition.blocks]
        )


def shard_partitioned(
    dense: np.ndarray,
    plan_or_partition: CompositePlan | RowPartition,
    *,
    schedule: KernelSchedule | None = None,
    mesh=None,
    interpret: bool = True,
) -> ShardedPartitionedSpmv:
    """Build the multi-device executor from a plan or a bare partition.

    From a ``CompositePlan`` the (uniform) carrier schedule defaults to the
    first block's predicted schedule — per-block *formats* do not transfer to
    the SPMD path (one program per device), only the nnz-balanced row map.
    When the mesh (default: every local device) has a different extent than
    the partition, the rows are re-partitioned to one block per device.
    """
    if isinstance(plan_or_partition, CompositePlan):
        partition = plan_or_partition.partition
        if schedule is None:
            schedule = plan_or_partition.blocks[0].schedule
    else:
        partition = plan_or_partition
    from repro.partition.partitioner import partition_rows

    extent = (mesh if mesh is not None else spmv_mesh(partition.n_blocks)).shape["data"]
    if partition.n_blocks != extent:
        log.info(
            "re-partitioning %d block(s) -> %d device(s) for the SPMD path",
            partition.n_blocks,
            extent,
        )
        partition = partition_rows(dense, extent)
    return ShardedPartitionedSpmv(
        dense,
        partition,
        schedule=schedule or DEFAULT_SCHEDULE,
        mesh=mesh,
        interpret=interpret,
    )
