"""nnz-balanced row partitioning (the matrix side of partitioned SpMV).

Real matrices are locally heterogeneous: a FEM band stacked on a power-law
adjacency wants different formats in different row regions (Yang et al.,
"Fast SpMV on GPUs"; Li et al.'s adaptive SpMV/SpMSpV make the same case per
region). The partitioner splits the row range into ``n_blocks`` contiguous
blocks so each block can be planned and executed independently:

1. **Greedy nnz balance** — block boundaries land at the quantiles of the
   cumulative nnz curve, so every block carries ~``nnz/n_blocks`` nonzeros
   (row counts alone would leave one block holding every hub row).
2. **Segment-boundary refinement** — a local sweep nudges each internal
   boundary within its neighbours' span to the position that (a) minimizes
   the nnz imbalance of the two adjacent blocks and (b) among near-ties,
   snaps to the largest row-count discontinuity, so boundaries settle on
   structural seams (band -> power-law transitions) rather than mid-segment.

Each ``RowBlock`` carries its own Table-2 feature vector, computed from a
slice of the matrix's single nonzeros-per-row histogram
(``core.features.row_nnz_counts``) — the per-block ``f`` term is a slice,
not a fresh pass over the matrix.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.features import (
    SparsityFeatures,
    features_from_row_counts,
    row_nnz_counts,
)

# refinement stops when a full sweep moves no boundary (or after this cap)
_MAX_REFINE_SWEEPS = 4
# a candidate boundary within this relative imbalance of the best one may
# win on row-count discontinuity instead (the "segment seam" tie-break)
_SEAM_TOLERANCE = 0.05

SUPPORTED_BLOCK_COUNTS = (1, 2, 4, 8)


@dataclass(frozen=True)
class RowBlock:
    """One contiguous row block with its own sparsity feature vector."""

    index: int
    row_start: int
    row_end: int  # exclusive
    nnz: int
    features: SparsityFeatures

    @property
    def n_rows(self) -> int:
        return self.row_end - self.row_start


@dataclass(frozen=True)
class RowPartition:
    """A full cover of ``[0, n_rows)`` by contiguous, disjoint row blocks."""

    n_rows: int
    n_cols: int
    blocks: tuple[RowBlock, ...]

    @property
    def n_blocks(self) -> int:
        return len(self.blocks)

    @property
    def nnz(self) -> int:
        return sum(b.nnz for b in self.blocks)

    def boundaries(self) -> tuple[int, ...]:
        """Internal boundaries only: (b_1, ..., b_{k-1})."""
        return tuple(b.row_start for b in self.blocks[1:])

    def imbalance(self) -> float:
        """max block nnz / ideal block nnz (1.0 = perfectly balanced)."""
        ideal = self.nnz / max(self.n_blocks, 1)
        if ideal <= 0:
            return 1.0
        return max(b.nnz for b in self.blocks) / ideal


def _greedy_boundaries(cum: np.ndarray, n_blocks: int) -> list[int]:
    """Quantile cuts of the cumulative-nnz curve, forced strictly increasing."""
    n_rows = cum.size
    total = int(cum[-1]) if n_rows else 0
    bounds: list[int] = []
    prev = 0
    for k in range(1, n_blocks):
        if total > 0:
            b = int(np.searchsorted(cum, k * total / n_blocks, side="left")) + 1
        else:  # all-empty rows: fall back to an even row split
            b = round(k * n_rows / n_blocks)
        # keep room for the remaining blocks on both sides
        b = max(b, prev + 1)
        b = min(b, n_rows - (n_blocks - k))
        bounds.append(b)
        prev = b
    return bounds


def _refine_boundaries(
    counts: np.ndarray, cum: np.ndarray, bounds: list[int]
) -> list[int]:
    """Sweep internal boundaries toward balance, snapping to segment seams."""
    edges = np.diff(counts.astype(np.float64)) if counts.size > 1 else np.zeros(0)

    def block_nnz(lo: int, hi: int) -> int:
        return int(cum[hi - 1] - (cum[lo - 1] if lo else 0)) if hi > lo else 0

    for _ in range(_MAX_REFINE_SWEEPS):
        moved = False
        for i in range(len(bounds)):
            lo = bounds[i - 1] if i else 0
            hi = bounds[i + 1] if i + 1 < len(bounds) else counts.size
            span = np.arange(lo + 1, hi)
            if span.size <= 1 or block_nnz(lo, hi) == 0:
                continue  # nothing to balance: keep the even split
            # imbalance of the two blocks adjacent to this boundary
            left = np.array([block_nnz(lo, b) for b in span], dtype=np.float64)
            right = np.array([block_nnz(b, hi) for b in span], dtype=np.float64)
            imbalance = np.abs(left - right)
            tol = float(imbalance.min()) + _SEAM_TOLERANCE * float(left[-1] + right[0])
            near = imbalance <= tol
            # among near-balanced candidates, prefer the sharpest row-count
            # discontinuity: boundary b sits between rows b-1 and b
            seam = np.abs(edges[span - 1])
            cur_idx = int(bounds[i] - (lo + 1))
            if near[cur_idx] and seam[cur_idx] >= seam[near].max():
                continue  # current boundary is already optimal: stay put
            pick = int(span[near][int(np.argmax(seam[near]))])
            if pick != bounds[i]:
                bounds[i] = pick
                moved = True
        if not moved:
            break
    return bounds


def partition_rows(
    dense: np.ndarray,
    n_blocks: int,
    *,
    row_counts: np.ndarray | None = None,
    refine: bool = True,
) -> RowPartition:
    """Split ``dense``'s rows into ``n_blocks`` nnz-balanced blocks.

    ``n_blocks`` is clamped to ``[1, n_rows]`` (a block must own at least
    one row), so asking for more blocks than rows degrades gracefully. An
    empty or all-zero matrix partitions by even row split. ``row_counts``
    lets callers reuse an already-computed histogram.
    """
    dense = np.asarray(dense)
    if dense.ndim != 2:
        raise ValueError(f"expected a 2-D matrix, got shape {dense.shape}")
    n_rows, n_cols = dense.shape
    counts = (
        np.asarray(row_counts, dtype=np.int64)
        if row_counts is not None
        else row_nnz_counts(dense)
    )
    if counts.size != n_rows:
        raise ValueError(f"row_counts has {counts.size} entries for {n_rows} rows")
    if n_blocks < 1:
        raise ValueError(f"n_blocks must be >= 1, got {n_blocks}")
    n_blocks = min(n_blocks, max(n_rows, 1))

    if n_rows == 0:
        block = RowBlock(0, 0, 0, 0, features_from_row_counts(counts, 0))
        return RowPartition(0, n_cols, (block,))

    cum = np.cumsum(counts)
    bounds = _greedy_boundaries(cum, n_blocks)
    if refine and bounds:
        bounds = _refine_boundaries(counts, cum, bounds)

    starts = [0] + bounds
    ends = bounds + [n_rows]
    blocks = tuple(
        RowBlock(
            index=i,
            row_start=s,
            row_end=e,
            nnz=int(counts[s:e].sum()),
            features=features_from_row_counts(counts[s:e], e - s),
        )
        for i, (s, e) in enumerate(zip(starts, ends))
    )
    return RowPartition(n_rows, n_cols, blocks)
