"""Heterogeneous composite planning: the run-time mode, per row block.

Auto-SpMV's run-time mode (paper §5.3) picks ONE format for the whole
matrix. A partitioned plan runs that mode once per row block: each block's
own Table-2 features go through the format classifier and the schedule
classifiers, every registered ``FormatSpec`` is a candidate, and the
analytical cost model scores the result on the block's *exact* storage
statistics. The block-count search {1, 2, 4, 8} keeps the monolithic plan
(one block) in the candidate set, so partitioning must pay for its extra
grid launches and per-block X traffic before it wins — a homogeneous matrix
falls back to the monolithic plan by construction.

Scoring uses the same ``TpuCostModel`` that labelled the §5.4 dataset, so
"modeled objective" means one thing everywhere: per-block footprints are
evaluated on per-block ``MatrixStats`` and combined exactly (latency and
energy add across sequential block launches; power and efficiency are
re-derived from the sums, not averaged).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.features import row_nnz_counts
from repro.core.objectives import MINIMIZE, MatrixStats, ObjectiveValues, TpuCostModel
from repro.kernels.common import KernelSchedule
from repro.partition.partitioner import (
    SUPPORTED_BLOCK_COUNTS,
    RowBlock,
    RowPartition,
    partition_rows,
)
from repro.sparse.registry import format_names
from repro.utils.logging import get_logger

log = get_logger("partition.plan")

# the classifier's per-block pick survives unless another format's modeled
# value is better by more than this relative margin (the predictor routes;
# the cost model vetoes only clear mistakes)
PREDICTOR_TOLERANCE = 0.10
# a partitioned plan must beat the monolithic one by this relative margin
# before it replaces it — near-ties keep the simpler single-kernel plan
MIN_PARTITION_GAIN = 0.02


@dataclass(frozen=True)
class BlockPlan:
    """One block's routed decision: format + schedule + modeled objectives."""

    block: RowBlock
    fmt: str
    schedule: KernelSchedule
    modeled: ObjectiveValues
    predicted_fmt: str  # the classifier's raw pick (before the cost-model veto)

    def as_dict(self) -> dict:
        return {
            "row_start": self.block.row_start,
            "row_end": self.block.row_end,
            "nnz": self.block.nnz,
            "fmt": self.fmt,
            "schedule": self.schedule.as_dict(),
            "latency": self.modeled.latency,
            "predicted_fmt": self.predicted_fmt,
        }


@dataclass(frozen=True)
class CompositePlan:
    """A full partitioned decision for one matrix and objective."""

    objective: str
    partition: RowPartition
    blocks: tuple[BlockPlan, ...]
    modeled: ObjectiveValues  # combined modeled objectives of this plan
    monolithic: ObjectiveValues  # best single-format one-block baseline
    monolithic_fmt: str
    monolithic_schedule: KernelSchedule | None = None  # schedule the
    # baseline was scored at (executable comparisons must use this, not a
    # block's schedule)
    searched: tuple[int, ...] = SUPPORTED_BLOCK_COUNTS

    @property
    def n_blocks(self) -> int:
        return len(self.blocks)

    @property
    def partitioned(self) -> bool:
        return self.n_blocks > 1

    @property
    def formats(self) -> tuple[str, ...]:
        return tuple(b.fmt for b in self.blocks)

    def gain(self, objective: str | None = None) -> float:
        """Relative modeled improvement over the monolithic baseline
        (positive = the composite plan wins)."""
        obj = objective or self.objective
        base, ours = self.monolithic.get(obj), self.modeled.get(obj)
        if not np.isfinite(base) or base == 0:
            return 0.0
        rel = (base - ours) / abs(base)
        return rel if MINIMIZE[obj] else -rel


def combine(values: list[ObjectiveValues], useful_flops: float) -> ObjectiveValues:
    """Exact combination across sequential block launches on one device:
    latency and energy add; power and efficiency are re-derived from the
    sums (averaging per-block power would weight tiny blocks equally)."""
    if any(not v.feasible for v in values):
        from repro.core.objectives import INFEASIBLE

        return INFEASIBLE
    latency = sum(v.latency for v in values)
    energy = sum(v.energy for v in values)
    power = energy / latency if latency > 0 else 0.0
    mflops = useful_flops / latency / 1e6 if latency > 0 else 0.0
    efficiency = mflops / power if power > 0 else 0.0
    return ObjectiveValues(latency, energy, power, efficiency)


def _cost(values: ObjectiveValues, objective: str) -> float:
    """Sign-normalized score: lower is always better."""
    v = values.get(objective)
    return v if MINIMIZE[objective] else -v


def _schedule_candidates(predicted: KernelSchedule) -> tuple[KernelSchedule, ...]:
    """The per-block schedule search: the classifier's pick plus the default.

    A schedule predicted from whole-matrix training labels can be hostile to
    an entire format family on a small block (nnz_tile=1024 forces every
    ELL-family width to >= 1024), which would make the format comparison
    meaningless. Two candidates keep the search O(formats x 2) while letting
    each block escape a schedule that was tuned for a different population.
    """
    from repro.kernels.common import DEFAULT_SCHEDULE

    return (predicted,) if predicted == DEFAULT_SCHEDULE else (
        predicted,
        DEFAULT_SCHEDULE,
    )


def sweep_formats(
    stats: MatrixStats,
    schedules: tuple[KernelSchedule, ...],
    objective: str,
    cost_model: TpuCostModel,
) -> dict[str, tuple[KernelSchedule, ObjectiveValues]]:
    """Best (schedule, modeled objectives) per registered format."""
    out: dict[str, tuple[KernelSchedule, ObjectiveValues]] = {}
    for fmt in format_names():
        best = None
        for sched in schedules:
            v = cost_model.evaluate(stats, fmt, sched)
            if best is None or (
                v.feasible and _cost(v, objective) < _cost(best[1], objective)
            ):
                best = (sched, v)
        out[fmt] = best
    return out


def route_block(
    predictor,
    block: RowBlock,
    stats: MatrixStats,
    objective: str,
    cost_model: TpuCostModel,
) -> BlockPlan:
    """Run the run-time mode for ONE block: classifier-predicted format and
    schedule, cost-model-scored on the block's exact stats over the small
    per-block schedule search, with a registry sweep as the veto (an
    infeasible or clearly-losing pick is replaced by the best registered
    format at its best candidate schedule)."""
    feats = block.features
    fmt_pred = predictor.predict_format(feats, objective)
    sched_pred = predictor.predict_schedule(feats, objective)
    scored = sweep_formats(
        stats, _schedule_candidates(sched_pred), objective, cost_model
    )
    feasible = {f: sv for f, sv in scored.items() if sv[1].feasible}
    if not feasible:
        # nothing fits (degenerate schedule on a degenerate block): keep the
        # classifier's pick; the executor will surface InfeasibleConfig
        return BlockPlan(block, fmt_pred, sched_pred, scored[fmt_pred][1], fmt_pred)
    best_fmt = min(feasible, key=lambda f: _cost(feasible[f][1], objective))
    chosen = fmt_pred
    if fmt_pred not in feasible:
        chosen = best_fmt
    else:
        cp = _cost(feasible[fmt_pred][1], objective)
        cb = _cost(feasible[best_fmt][1], objective)
        if cp > cb + PREDICTOR_TOLERANCE * abs(cb):
            chosen = best_fmt
    schedule, modeled = scored[chosen]
    return BlockPlan(block, chosen, schedule, modeled, fmt_pred)


def plan_for_partition(
    predictor,
    dense: np.ndarray,
    part: RowPartition,
    objective: str,
    *,
    cost_model: TpuCostModel | None = None,
) -> tuple[tuple[BlockPlan, ...], ObjectiveValues]:
    """Route every block of one partition; returns plans + combined model."""
    cm = cost_model or TpuCostModel()
    dense = np.asarray(dense)
    plans = []
    for block in part.blocks:
        stats = MatrixStats(dense[block.row_start : block.row_end])
        plans.append(route_block(predictor, block, stats, objective, cm))
    useful = 2.0 * part.nnz
    return tuple(plans), combine([p.modeled for p in plans], useful)


def plan_partitioned(
    predictor,
    dense: np.ndarray,
    objective: str = "latency",
    *,
    block_counts: tuple[int, ...] = SUPPORTED_BLOCK_COUNTS,
    cost_model: TpuCostModel | None = None,
    min_gain: float = MIN_PARTITION_GAIN,
) -> CompositePlan:
    """Search block counts and return the winning composite plan.

    The monolithic baseline (the best single registered format at the full
    matrix's predicted schedule) always competes; a partitioned candidate
    replaces it only when its combined modeled objective wins by at least
    ``min_gain``, so homogeneous matrices keep block count 1.

    With a ``CalibratedCostModel`` the comparison also reflects the measured
    per-launch fixed cost: ``combine`` sums per-block latencies, so a k-block
    candidate is charged k calibrated launch overheads against the
    monolithic plan's one — exactly the term whose absence made the
    uncalibrated planner over-partition.
    """
    cm = cost_model or TpuCostModel()
    dense = np.asarray(dense)
    if 1 not in block_counts:
        block_counts = (1,) + tuple(block_counts)
    block_counts = tuple(sorted(set(block_counts)))

    candidates: dict[int, tuple[RowPartition, tuple[BlockPlan, ...], ObjectiveValues]] = {}
    counts = row_nnz_counts(dense)
    for k in block_counts:
        part = partition_rows(dense, k, row_counts=counts)
        if part.n_blocks in candidates:  # clamped duplicates (k > n_rows)
            continue
        plans, modeled = plan_for_partition(
            predictor, dense, part, objective, cost_model=cm
        )
        candidates[part.n_blocks] = (part, plans, modeled)

    _, mono_plans, _ = candidates[min(candidates)]
    # best single-format baseline: the full matrix, one block, every
    # registered format over the same schedule candidates the blocks get
    # (the predictor's full-matrix pick + the default) — partitioning must
    # beat the strongest monolithic plan, not a handicapped one
    full_stats = MatrixStats(dense)
    sched_full = predictor.predict_schedule(mono_plans[0].block.features, objective)
    mono_scores = sweep_formats(
        full_stats, _schedule_candidates(sched_full), objective, cm
    )
    mono_feasible = {
        f: sv for f, sv in mono_scores.items() if sv[1].feasible
    } or mono_scores
    monolithic_fmt = min(
        mono_feasible, key=lambda f: _cost(mono_feasible[f][1], objective)
    )
    monolithic = mono_scores[monolithic_fmt][1]

    best_k, best_cost = min(candidates), _cost(monolithic, objective)
    for k, (_, _, modeled) in sorted(candidates.items()):
        if k == 1 or not modeled.feasible:
            continue
        cost = _cost(modeled, objective)
        beats = (
            cost < best_cost - min_gain * abs(best_cost)
            if np.isfinite(best_cost)
            else np.isfinite(cost)
        )
        if beats:
            best_k, best_cost = k, cost

    if best_k == min(candidates):
        # fall back to the monolithic plan, pinned to the baseline format
        part, plans, _ = candidates[best_k]
        mono_sched = mono_scores[monolithic_fmt][0]
        plans = tuple(
            BlockPlan(p.block, monolithic_fmt, mono_sched, monolithic, p.predicted_fmt)
            for p in plans
        )
        chosen = CompositePlan(
            objective, part, plans, monolithic, monolithic, monolithic_fmt,
            monolithic_schedule=mono_sched, searched=block_counts,
        )
    else:
        part, plans, modeled = candidates[best_k]
        chosen = CompositePlan(
            objective, part, plans, modeled, monolithic, monolithic_fmt,
            monolithic_schedule=mono_scores[monolithic_fmt][0],
            searched=block_counts,
        )
    log.info(
        "partitioned plan: obj=%s searched=%s -> k=%d formats=%s gain=%.1f%% "
        "(monolithic %s, %s cost model)",
        objective,
        block_counts,
        chosen.n_blocks,
        "+".join(chosen.formats),
        100.0 * chosen.gain(),
        monolithic_fmt,
        "calibrated" if getattr(cm, "corrections", None) else "analytical",
    )
    return chosen
