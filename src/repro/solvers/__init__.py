"""Iterative solvers over the Auto-SpMV serving stack.

The paper's amortize-forever argument (§5.3) — pay compile-time tuning
once, reuse the kernel thousands of times — only materializes in iterative
workloads. This package is that workload class:

* ``iterate``  — the generic ``IterativeSolver`` driver: ONE
  ``serve_optimize`` plan per solve, then every ``y = A @ x`` runs through
  the cached prepared kernel with per-iteration ``observe()`` feedback,
  ``solver.iterate`` spans, and convergence bookkeeping;
* ``adaptive`` — the per-iteration SpMV↔SpMSpV policy (frontier density
  threshold, learnable per density phase via the telemetry UCB bandit);
* ``pagerank`` / ``cg`` / ``power`` — damped PageRank with dangling-node
  handling, conjugate gradient for SPD systems, and power iteration, each
  returning a structured ``SolveResult``.
"""

from repro.solvers.adaptive import AdaptiveSpmvPolicy, PolicyDecision
from repro.solvers.cg import cg
from repro.solvers.iterate import IterativeSolver, SolveResult
from repro.solvers.pagerank import pagerank
from repro.solvers.power import power_iteration

__all__ = [
    "AdaptiveSpmvPolicy",
    "IterativeSolver",
    "PolicyDecision",
    "SolveResult",
    "cg",
    "pagerank",
    "power_iteration",
]
