"""Per-iteration SpMV↔SpMSpV execution policy.

An iterative solve's input vector starts sparse (a seed vertex, a push
frontier) and densifies toward stationary. SpMSpV work scales with the
frontier's column nonzeros; dense SpMV always touches nnz(A); somewhere in
between lies a crossover. Li et al. (arXiv:2006.16767) switch on input
density online — this module does the same with two layers:

* a **threshold rule**: serve SpMSpV while ``frontier nnz / n_cols`` is
  below ``threshold`` (default 10%), SpMV after — the zero-state prior;
* an optional **phase bandit**: with an ``AdaptiveFormatSelector``
  attached, iterations are binned into density *phases*
  (``telemetry.adaptive.phase_arm_bucket``) and the two paths become UCB
  arms inside each phase cell, seeded by the threshold rule's pick as
  incumbent. Measured per-iteration wall times then learn the real
  crossover per matrix-family bucket instead of trusting the 10%.

The two arm names are execution *paths*, not sparse formats — they never
enter the format registry, and the bandit cells they occupy are keyed by
phase so they cannot collide with the format-selection cells for the same
bucket.
"""

from __future__ import annotations

import bisect
from dataclasses import dataclass, field

from repro.obs.metrics import get_metrics
from repro.telemetry.adaptive import phase_arm_bucket
from repro.utils.logging import get_logger

log = get_logger("solvers.adaptive")

SPMV = "spmv"
SPMSPV = "spmspv"
ARMS = (SPMV, SPMSPV)

# density-phase bin edges: phase i covers [edges[i-1], edges[i])
DEFAULT_PHASE_EDGES = (0.02, 0.05, 0.10, 0.25, 0.50)
DEFAULT_THRESHOLD = 0.10

_M_SPMV = get_metrics().counter("solver_policy_spmv_total")
_M_SPMSPV = get_metrics().counter("solver_policy_spmspv_total")


@dataclass(frozen=True)
class PolicyDecision:
    """One iteration's routing decision, kept for tests and benchmarks."""

    kind: str  # "spmv" | "spmspv"
    density: float
    phase: int
    exploratory: bool = False


@dataclass
class AdaptiveSpmvPolicy:
    """Density-threshold SpMV↔SpMSpV switch with an optional phase bandit.

    Parameters
    ----------
    threshold:
        Frontier density below which the prior picks SpMSpV.
    selector:
        Optional ``telemetry.AdaptiveFormatSelector``; when present, each
        density phase is a bandit cell whose incumbent is the threshold
        rule's pick and whose measurements may overturn it.
    bucket / objective:
        The matrix-family cell identity the phase buckets scope into —
        pass the session plan's ``bucket``/``objective`` so solver cells
        live alongside (not inside) the format-selection cells.
    """

    threshold: float = DEFAULT_THRESHOLD
    phase_edges: tuple[float, ...] = DEFAULT_PHASE_EDGES
    selector: object | None = None  # telemetry.AdaptiveFormatSelector
    bucket: str = "solver"
    objective: str = "latency"
    decisions: list[PolicyDecision] = field(default_factory=list)

    @property
    def n_phases(self) -> int:
        return len(self.phase_edges) + 1

    def phase_of(self, density: float) -> int:
        return bisect.bisect_right(self.phase_edges, float(density))

    def _cell(self, phase: int) -> tuple[str, str]:
        return (
            phase_arm_bucket(self.bucket, phase, self.n_phases),
            self.objective,
        )

    def prior_kind(self, density: float) -> str:
        return SPMSPV if density < self.threshold else SPMV

    def choose(self, density: float) -> PolicyDecision:
        """Route one iteration; records and returns the decision."""
        phase = self.phase_of(density)
        incumbent = self.prior_kind(density)
        kind, exploratory = incumbent, False
        if self.selector is not None:
            cell_bucket, objective = self._cell(phase)
            kind, exploratory = self.selector.choose(
                cell_bucket, objective, incumbent, ARMS
            )
        decision = PolicyDecision(kind, float(density), phase, exploratory)
        self.decisions.append(decision)
        (_M_SPMSPV if kind == SPMSPV else _M_SPMV).inc()
        return decision

    def update(self, decision: PolicyDecision, measured_s: float) -> None:
        """Feed the measured iteration time back into the phase cell."""
        if self.selector is None:
            return
        cell_bucket, objective = self._cell(decision.phase)
        self.selector.update(cell_bucket, objective, decision.kind, measured_s)
        challenger = self.selector.review(cell_bucket, objective)
        if challenger is not None:
            # no cache to drop for a path switch: promotion IS the whole fix
            self.selector.promote(cell_bucket, objective, challenger)
            log.info(
                "solver phase %d crossover: %s -> %s (bucket=%s)",
                decision.phase,
                decision.kind,
                challenger,
                self.bucket,
            )

    def kinds(self) -> list[str]:
        return [d.kind for d in self.decisions]
