"""Conjugate gradient for SPD systems over the served SpMV plan.

Textbook CG (Hestenes–Stiefel): one ``A @ p`` per iteration — the SpMV
amortization shape exactly — plus vector work done host-side in float64 so
the recurrences stay numerically honest while the kernel runs the served
schedule. Convergence is the relative residual ``||b - A x|| / ||b||``,
tracked by the recurrence residual and trusted because fp32 accumulation
is forced by the driver's precision guard.

The search direction ``p`` is dense from iteration 0 (it starts at ``r0 =
b``), so an attached adaptive policy will route CG through plain SpMV —
which is itself the point: the policy must not pay SpMSpV overheads on
workloads with no frontier structure.
"""

from __future__ import annotations

import numpy as np

from repro.solvers.adaptive import AdaptiveSpmvPolicy
from repro.solvers.iterate import IterativeSolver, SolveResult


def cg(
    session,
    dense: np.ndarray,
    b: np.ndarray,
    *,
    tol: float = 1e-8,
    max_iters: int = 200,
    policy: AdaptiveSpmvPolicy | None = None,
    x0: np.ndarray | None = None,
    objective: str = "latency",
) -> SolveResult:
    """Solve ``A x = b`` (A symmetric positive-definite) by CG."""
    A = np.asarray(dense, dtype=np.float32)
    b = np.asarray(b, dtype=np.float64).reshape(-1)
    n = b.size
    b_norm = float(np.linalg.norm(b)) or 1.0
    x = np.zeros(n) if x0 is None else np.asarray(x0, dtype=np.float64)
    driver = IterativeSolver(
        session,
        A,
        name="cg",
        objective=objective,
        tol=tol,
        max_iters=max_iters,
        policy=policy,
    )

    # state = (x, r, p, rr): solution, residual, direction, r·r
    if x0 is None:
        r = b.copy()
    else:
        driver.setup()
        r = b - driver.matvec(x).astype(np.float64)
    state0 = (x, r, r.copy(), float(r @ r))

    def step(matvec, state):
        x, r, p, rr = state
        Ap = matvec(p).astype(np.float64)
        pAp = float(p @ Ap)
        if pAp <= 0:  # matrix not SPD on this direction; stop descending
            return (x, r, p, rr), float(np.linalg.norm(r)) / b_norm
        alpha = rr / pAp
        x = x + alpha * p
        r = r - alpha * Ap
        rr_next = float(r @ r)
        p = r + (rr_next / rr) * p
        return (x, r, p, rr_next), float(np.sqrt(rr_next)) / b_norm

    return driver.solve(
        state0,
        step,
        value=lambda s: s[0],
        extras=lambda s: {"b_norm": b_norm},
    )
