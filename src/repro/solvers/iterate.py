"""Generic iterative-solve driver over an ``AutoSpmvSession``.

This is where the paper's §5.3 amortization argument becomes a measured
fact: ``setup()`` calls ``serve_optimize`` exactly ONCE per solve, and the
iteration loop replays the cached ``PreparedSpmv`` (plus, when the adaptive
policy routes a sparse frontier, the lazily-compiled SpMSpV twin) — the
session's ``plans_computed`` / ``kernel_compiles`` counters stay flat while
``observe()`` feeds every iteration's wall time back into the telemetry
bandit. Each iteration runs inside a nested ``solver.iterate`` span and
bumps ``solver_iterations_total``, so a trace of a 50-iteration solve shows
one ``session.serve`` and fifty iterate spans under it.

Solvers (``pagerank`` / ``cg`` / ``power``) express one iteration as a
``step(matvec, state) -> (state, residual)`` callable and hand the loop to
``solve``; the driver owns convergence, timing, spans, and the SpMV↔SpMSpV
routing so every solver gets them identically.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from time import perf_counter
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common import KernelSchedule
from repro.kernels.ops import compile_spmv
from repro.obs.metrics import get_metrics
from repro.obs.trace import span as _span
from repro.solvers.adaptive import SPMSPV, AdaptiveSpmvPolicy
from repro.utils.logging import get_logger

log = get_logger("solvers.iterate")


@dataclass
class SolveResult:
    """Structured outcome of one iterative solve."""

    solver: str
    value: np.ndarray  # the converged vector (ranks / solution / eigvec)
    iterations: int
    converged: bool
    residual: float  # final residual
    residuals: list[float]  # per-iteration residual history
    iteration_seconds: list[float]  # wall time per iteration (step incl. matvec)
    matvec_seconds: list[float]  # wall time of the kernel calls alone
    matvec_kinds: list[str]  # "spmv" | "spmspv" per matvec
    spmv_calls: int
    spmspv_calls: int
    modeled_work: int  # stored nonzeros actually touched across the solve
    spmv_work_equiv: int  # matvecs * nnz(A): the always-SpMV comparator
    plan_id: str = ""
    fmt: str = ""
    cache_hit: bool = False
    extras: dict = field(default_factory=dict)  # solver-specific scalars

    def iter_p50_s(self) -> float:
        if not self.iteration_seconds:
            return 0.0
        return float(np.median(self.iteration_seconds))

    def summary(self) -> dict:
        """JSON-ready convergence metadata (the ``launch.solve`` payload)."""
        return {
            "solver": self.solver,
            "iterations": self.iterations,
            "converged": self.converged,
            "residual": self.residual,
            "iter_p50_s": self.iter_p50_s(),
            "total_s": float(sum(self.iteration_seconds)),
            "spmv_calls": self.spmv_calls,
            "spmspv_calls": self.spmspv_calls,
            "modeled_work": self.modeled_work,
            "spmv_work_equiv": self.spmv_work_equiv,
            "plan_id": self.plan_id,
            "fmt": self.fmt,
            "cache_hit": self.cache_hit,
            **{k: v for k, v in self.extras.items()},
        }


class IterativeSolver:
    """Drives ``y = A @ x`` loops through one served Auto-SpMV plan.

    Parameters
    ----------
    session:
        The ``AutoSpmvSession`` that owns planning, caching, and telemetry.
    dense:
        The matrix actually multiplied each iteration (solvers pass the
        normalized / symmetrized operator, not the raw generator output).
    policy:
        Optional ``AdaptiveSpmvPolicy``; without one every matvec is SpMV.
    force_fp32:
        Solvers promise 1e-5 agreement with dense NumPy references, so a
        served plan whose schedule accumulates in bf16 is recompiled with
        ``accum_dtype="float32"`` (same format, same memo identity modulo
        schedule) before iterating. Set False to take the plan verbatim.
    """

    def __init__(
        self,
        session,
        dense: np.ndarray,
        *,
        name: str = "solver",
        objective: str = "latency",
        tol: float = 1e-8,
        max_iters: int = 100,
        policy: AdaptiveSpmvPolicy | None = None,
        force_fp32: bool = True,
    ):
        self.session = session
        self.dense = np.asarray(dense, dtype=np.float32)
        self.name = name
        self.objective = objective
        self.tol = float(tol)
        self.max_iters = int(max_iters)
        self.policy = policy
        self.force_fp32 = force_fp32
        self.nnz = int((self.dense != 0).sum())
        self.n_cols = int(self.dense.shape[1])
        self.plan = None
        self._spmv_kernel = None
        self._spmspv_kernel = None  # lazily compiled on first sparse frontier
        self.matvec_seconds: list[float] = []
        self.matvec_kinds: list[str] = []
        self.modeled_work = 0

    # -------------------------------------------------------------- planning
    def setup(self):
        """Serve the ONE plan this whole solve amortizes; idempotent."""
        if self.plan is not None:
            return self.plan
        plan = self.session.serve_optimize(self.dense, self.objective)
        kernel = plan.kernel
        if self.force_fp32 and plan.schedule.accum_dtype != "float32":
            sched = plan.schedule.replace(accum_dtype="float32")
            kernel = compile_spmv(
                self.dense,
                plan.fmt,
                sched,
                interpret=self.session.tuner.interpret,
                memo_key=plan.fingerprint,
            )
            log.info(
                "solver %s: plan schedule accumulates in %s; recompiled fp32",
                self.name,
                plan.schedule.accum_dtype,
            )
        self.plan = plan
        self._spmv_kernel = kernel
        if self.policy is not None:
            # scope the phase-bandit cells to this plan's matrix family
            self.policy.bucket = plan.bucket
            self.policy.objective = plan.objective
        return plan

    def _iter_schedule(self) -> KernelSchedule:
        sched = self.plan.schedule
        if self.force_fp32 and sched.accum_dtype != "float32":
            sched = sched.replace(accum_dtype="float32")
        return sched

    # --------------------------------------------------------------- matvec
    def matvec(self, x: np.ndarray) -> np.ndarray:
        """One ``A @ x`` through the served plan, routed by frontier density.

        SpMV iterations feed ``session.observe`` (the format bandit's
        signal); SpMSpV iterations feed only the policy's phase cell —
        crediting a sparse-frontier time to a dense-SpMV arm would corrupt
        the format statistics.
        """
        self.setup()
        x = np.ascontiguousarray(np.asarray(x, dtype=np.float32))
        active = np.flatnonzero(x).astype(np.int32)
        density = active.size / max(self.n_cols, 1)
        decision = self.policy.choose(density) if self.policy is not None else None
        if decision is not None and decision.kind == SPMSPV:
            kernel = self._ensure_spmspv()
            t0 = perf_counter()
            y = jax.block_until_ready(kernel.call_frontier(active, x[active]))
            dt = perf_counter() - t0
            self.modeled_work += kernel.modeled_work(active)
        else:
            t0 = perf_counter()
            y = jax.block_until_ready(self._spmv_kernel(jnp.asarray(x)))
            dt = perf_counter() - t0
            self.modeled_work += self.nnz
            self.session.observe(self.plan, dt)
        kind = decision.kind if decision is not None else "spmv"
        if decision is not None:
            self.policy.update(decision, dt)
        self.matvec_seconds.append(dt)
        self.matvec_kinds.append(kind)
        return np.asarray(y, dtype=np.float32)

    def _ensure_spmspv(self):
        if self._spmspv_kernel is None:
            self._spmspv_kernel = self.session.compile_spmspv(
                self.dense, self._iter_schedule()
            )
        return self._spmspv_kernel

    # ----------------------------------------------------------------- loop
    def solve(
        self,
        state: Any,
        step: Callable[[Callable, Any], tuple[Any, float]],
        value: Callable[[Any], np.ndarray] | None = None,
        extras: Callable[[Any], dict] | None = None,
    ) -> SolveResult:
        """Iterate ``step`` to convergence under spans/metrics/accounting."""
        self.setup()
        metrics = get_metrics()
        iters_total = metrics.counter("solver_iterations_total", solver=self.name)
        iter_hist = metrics.histogram("solver_iteration_seconds", solver=self.name)
        residuals: list[float] = []
        iter_seconds: list[float] = []
        converged = False
        it = 0
        with _span("solver.solve", solver=self.name, max_iters=self.max_iters):
            for it in range(1, self.max_iters + 1):
                t0 = perf_counter()
                with _span("solver.iterate", solver=self.name, iteration=it):
                    state, res = step(self.matvec, state)
                dt = perf_counter() - t0
                iters_total.inc()
                iter_hist.observe(dt)
                residuals.append(float(res))
                iter_seconds.append(dt)
                if res <= self.tol:
                    converged = True
                    break
        kinds = self.matvec_kinds
        result = SolveResult(
            solver=self.name,
            value=np.asarray(value(state) if value is not None else state),
            iterations=it,
            converged=converged,
            residual=residuals[-1] if residuals else float("inf"),
            residuals=residuals,
            iteration_seconds=iter_seconds,
            matvec_seconds=list(self.matvec_seconds),
            matvec_kinds=list(kinds),
            spmv_calls=sum(1 for k in kinds if k != SPMSPV),
            spmspv_calls=sum(1 for k in kinds if k == SPMSPV),
            modeled_work=self.modeled_work,
            spmv_work_equiv=len(kinds) * self.nnz,
            plan_id=self.plan.plan_id,
            fmt=self.plan.fmt,
            cache_hit=self.plan.cache_hit,
            extras=extras(state) if extras is not None else {},
        )
        log.info(
            "%s: %d iters, converged=%s, residual=%.3g, spmv=%d spmspv=%d",
            self.name,
            result.iterations,
            result.converged,
            result.residual,
            result.spmv_calls,
            result.spmspv_calls,
        )
        return result
