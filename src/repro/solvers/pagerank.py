"""Damped PageRank over the served SpMV plan.

Power-method PageRank on a column-stochastic transition matrix ``P``::

    r' = d * (P r + dangling_mass * v) + (1 - d) * v

where ``v`` is the (uniform by default) teleport distribution and
``dangling_mass = sum(r[j] for dangling j)`` redistributes the rank that
zero-out-degree nodes (dangling columns of ``P``) would otherwise leak —
the textbook fix that keeps ``sum(r) == 1`` exactly. Convergence is the L1
change between successive rank vectors, the standard PageRank criterion.

The multiplied operator is ``P`` (column-normalized), so callers can hand
either a raw adjacency matrix (``normalize=True``, the default, routes it
through ``sparse.generate.normalize_columns``) or an already-stochastic
one.
"""

from __future__ import annotations

import numpy as np

from repro.solvers.adaptive import AdaptiveSpmvPolicy
from repro.solvers.iterate import IterativeSolver, SolveResult


def pagerank_reference(
    dense: np.ndarray,
    damping: float = 0.85,
    tol: float = 1e-10,
    max_iters: int = 500,
) -> np.ndarray:
    """Dense-NumPy oracle: same recurrence, no kernels. For tests/benches."""
    from repro.sparse.generate import normalize_columns

    P = normalize_columns(np.asarray(dense, dtype=np.float64))
    n = P.shape[0]
    dangling = P.sum(axis=0) == 0
    v = np.full(n, 1.0 / n)
    r = v.copy()
    for _ in range(max_iters):
        r_next = damping * (P @ r + r[dangling].sum() * v) + (1.0 - damping) * v
        if np.abs(r_next - r).sum() <= tol:
            return r_next
        r = r_next
    return r


def pagerank(
    session,
    dense: np.ndarray,
    *,
    damping: float = 0.85,
    tol: float = 1e-8,
    max_iters: int = 100,
    policy: AdaptiveSpmvPolicy | None = None,
    normalize: bool = True,
    personalization: np.ndarray | None = None,
    x0: np.ndarray | None = None,
    objective: str = "latency",
) -> SolveResult:
    """Damped PageRank through one served plan; returns ranks summing to 1."""
    from repro.sparse.generate import normalize_columns

    A = np.asarray(dense, dtype=np.float32)
    P = normalize_columns(A) if normalize else A
    n = P.shape[0]
    dangling = np.flatnonzero(P.sum(axis=0) == 0)
    if personalization is None:
        v = np.full(n, 1.0 / n, dtype=np.float32)
    else:
        v = np.asarray(personalization, dtype=np.float32)
        v = v / v.sum()
    r0 = v.copy() if x0 is None else np.asarray(x0, dtype=np.float32)
    driver = IterativeSolver(
        session,
        P,
        name="pagerank",
        objective=objective,
        tol=tol,
        max_iters=max_iters,
        policy=policy,
    )

    def step(matvec, r):
        leak = float(r[dangling].sum()) if dangling.size else 0.0
        r_next = damping * (matvec(r) + leak * v) + (1.0 - damping) * v
        return r_next, float(np.abs(r_next - r).sum())

    return driver.solve(
        r0,
        step,
        extras=lambda r: {
            "damping": damping,
            "dangling_nodes": int(dangling.size),
            "rank_sum": float(np.sum(r)),
        },
    )
