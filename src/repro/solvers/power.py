"""Power iteration over the served plan — the frontier-densification probe.

Plain normalized power iteration toward the dominant eigenpair. Started
from a single seed coordinate (the default), the iterate's support is the
seed's k-hop out-neighborhood: it grows from one entry toward (near-)dense
across iterations, which is exactly the input-sparsity trajectory the
SpMV↔SpMSpV policy exists for — early iterations are SpMSpV wins, late
ones SpMV. The solver truncates entries below ``prune_tol`` after
normalization so the frontier stays *genuinely* sparse until mixing
actually spreads mass (fp32 rounding would otherwise densify it in one
step) — the standard push-style tolerance from frontier PageRank/BFS.

Residual: ``||A x - λ x||₂ / |λ|`` with λ the Rayleigh quotient.
"""

from __future__ import annotations

import numpy as np

from repro.solvers.adaptive import AdaptiveSpmvPolicy
from repro.solvers.iterate import IterativeSolver, SolveResult


def power_iteration(
    session,
    dense: np.ndarray,
    *,
    tol: float = 1e-6,
    max_iters: int = 100,
    policy: AdaptiveSpmvPolicy | None = None,
    x0: np.ndarray | None = None,
    seed_index: int = 0,
    prune_tol: float = 1e-7,
    objective: str = "latency",
) -> SolveResult:
    """Dominant eigenpair by power iteration; value is the unit eigenvector,
    ``extras["eigenvalue"]`` the Rayleigh estimate."""
    A = np.asarray(dense, dtype=np.float32)
    n = A.shape[0]
    if x0 is None:
        x = np.zeros(n, dtype=np.float64)
        x[seed_index % n] = 1.0
    else:
        x = np.asarray(x0, dtype=np.float64)
        x = x / (np.linalg.norm(x) or 1.0)
    driver = IterativeSolver(
        session,
        A,
        name="power",
        objective=objective,
        tol=tol,
        max_iters=max_iters,
        policy=policy,
    )

    # state = (x, lam)
    def step(matvec, state):
        x, _ = state
        y = matvec(x).astype(np.float64)
        lam = float(x @ y)  # Rayleigh quotient (x is unit-norm)
        norm = float(np.linalg.norm(y))
        if norm == 0.0:  # seed hit a sink; restart dense to keep iterating
            y = np.full(n, 1.0 / np.sqrt(n))
            norm = 1.0
        x_next = y / norm
        if prune_tol > 0:
            x_next = np.where(np.abs(x_next) >= prune_tol, x_next, 0.0)
            renorm = float(np.linalg.norm(x_next)) or 1.0
            x_next = x_next / renorm
        res = float(np.linalg.norm(y - lam * x)) / (abs(lam) or 1.0)
        return (x_next, lam), res

    return driver.solve(
        (x, 0.0),
        step,
        value=lambda s: s[0],
        extras=lambda s: {"eigenvalue": s[1]},
    )
