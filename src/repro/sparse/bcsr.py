"""BCSR: blocked compressed-sparse-row — the registry's fifth format.

A CMRS-spirited (Koza et al., arXiv:1203.2946) row-compressed relative of
BELL: storage is a *flat* list of occupied (br x 128) blocks with per-block
block-row / block-column ids, instead of BELL's ELL-style per-block-row
padding to ``max_blocks``. On matrices whose block occupancy is skewed
across block-rows (power-law graphs), BCSR stores only the occupied blocks
— the same padding-elimination argument CSR makes over ELL, one level up.

TPU adaptation mirrors the BELL kernel: ``block_cols`` is a scalar-prefetch
operand whose BlockSpec index map DMAs exactly the 128-wide X panel each
stored block needs, and each grid step is a dense (br, 128) x (128,) matvec
on MXU shapes. Row compression is handled like the CSR kernel handles
nonzeros: ``block_rows`` (also scalar-prefetched) scatter-accumulates each
block's partial product into the VMEM-resident output, which persists
across the sequential grid. Padding blocks carry ``block_row == n_block_rows``
and land in a spill row that the wrapper truncates.

This module is deliberately *plugin-shaped*: it touches none of the
dispatch layers (ops / tuning_space / objectives / session / adaptive).
Importing it (or calling ``register()``) is the entire integration — the
format then appears in ``full_space()``, the tuning dataset, classifier
labels, the bandit arm set, and serves through ``SpmvServer``.
"""

from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (
    LANE,
    SUBLANE,
    VMEM_BYTES,
    CompilerParams,
    InfeasibleConfig,
    KernelSchedule,
    ceil_to,
)
from repro.sparse.registry import (
    FormatSpec,
    KernelFootprint,
    MatrixStats,
    check_storage_bytes,
    register_format,
)

_VAL_B, _IDX_B = 4.0, 4.0


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class BCSR:
    """Blocked CSR: flat occupied (br x bc) blocks + block-row compression.

    ``data[k]`` is the k-th stored block (block-row-major order); its block
    coordinates are ``(block_rows[k], block_cols[k])``. Trailing padding
    blocks are all-zero with ``block_col == 0`` and ``block_row ==
    n_block_rows`` (the spill row). ``block_ptr`` is the CSR-style pointer
    over block-rows covering the *real* (unpadded) blocks.
    """

    data: jax.Array  # (n_blocks_pad, br, bc)
    block_cols: jax.Array  # (n_blocks_pad,) int32
    block_rows: jax.Array  # (n_blocks_pad,) int32
    block_ptr: jax.Array  # (n_block_rows + 1,) int32
    shape: tuple[int, int] = dataclasses.field(metadata=dict(static=True))
    br: int = dataclasses.field(metadata=dict(static=True))
    bc: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_block_rows(self) -> int:
        return int(self.block_ptr.shape[0] - 1)

    @property
    def n_blocks(self) -> int:
        """Real (unpadded) stored blocks."""
        return int(np.asarray(self.block_ptr)[-1])

    @property
    def nbytes_core(self) -> int:
        arrs = (self.data, self.block_cols, self.block_ptr)
        return int(sum(a.size * a.dtype.itemsize for a in arrs))

    @property
    def nbytes(self) -> int:
        return self.nbytes_core + int(
            self.block_rows.size * self.block_rows.dtype.itemsize
        )


# ---------------------------------------------------------------------------
# Host-side conversion (numpy; timeable as the paper's c_latency)
# ---------------------------------------------------------------------------


def bcsr_from_dense(
    dense: np.ndarray,
    br: int = SUBLANE,
    bc: int = LANE,
    dtype=np.float32,
    pad_blocks_to: int = 1,
) -> BCSR:
    dense = np.asarray(dense)
    n_rows, n_cols = dense.shape
    pr, pc = ceil_to(n_rows, br), ceil_to(n_cols, bc)
    padded = np.zeros((pr, pc), dtype=dtype)
    padded[:n_rows, :n_cols] = dense
    nbr, nbc = pr // br, pc // bc
    blocks = padded.reshape(nbr, br, nbc, bc).transpose(0, 2, 1, 3)  # (nbr, nbc, br, bc)
    occupied = (blocks != 0).any(axis=(2, 3))  # (nbr, nbc)
    rows_idx, cols_idx = np.nonzero(occupied)  # block-row-major order
    nb = rows_idx.size
    counts = np.bincount(rows_idx, minlength=nbr)
    block_ptr = np.zeros(nbr + 1, dtype=np.int32)
    np.cumsum(counts, out=block_ptr[1:])
    nb_pad = ceil_to(max(nb, 1), max(pad_blocks_to, 1))
    data = np.zeros((nb_pad, br, bc), dtype=dtype)
    block_cols = np.zeros(nb_pad, dtype=np.int32)
    block_rows = np.full(nb_pad, nbr, dtype=np.int32)  # padding -> spill row
    data[:nb] = blocks[rows_idx, cols_idx]
    block_cols[:nb] = cols_idx
    block_rows[:nb] = rows_idx
    return BCSR(
        data=jnp.asarray(data),
        block_cols=jnp.asarray(block_cols),
        block_rows=jnp.asarray(block_rows),
        block_ptr=jnp.asarray(block_ptr),
        shape=(n_rows, n_cols),
        br=br,
        bc=bc,
    )


def bcsr_to_dense(mat: BCSR) -> np.ndarray:
    n_rows, n_cols = mat.shape
    out = np.zeros((n_rows, n_cols), dtype=np.asarray(mat.data).dtype)
    data = np.asarray(mat.data)
    brow = np.asarray(mat.block_rows)
    bcol = np.asarray(mat.block_cols)
    nbr = mat.n_block_rows
    for k in range(data.shape[0]):
        if brow[k] >= nbr:  # padding block
            continue
        r0, c0 = int(brow[k]) * mat.br, int(bcol[k]) * mat.bc
        rr = min(mat.br, n_rows - r0)
        cc = min(mat.bc, n_cols - c0)
        if rr > 0 and cc > 0:
            out[r0 : r0 + rr, c0 : c0 + cc] += data[k][:rr, :cc]
    return out


# ---------------------------------------------------------------------------
# Pure-jnp oracle
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("bc", "nbr", "n_rows"))
def _bcsr_ref_impl(data, block_cols, block_rows, x, *, bc, nbr, n_rows):
    n_cols_pad = ((x.shape[0] + bc - 1) // bc) * bc
    xp = jnp.zeros(n_cols_pad, x.dtype).at[: x.shape[0]].set(x)
    xseg = xp.reshape(-1, bc)[block_cols]  # (nb_pad, bc)
    v = jnp.einsum("krc,kc->kr", data, xseg)  # per-block matvec (MXU shapes)
    y = jax.ops.segment_sum(v, block_rows, num_segments=nbr + 1)  # spill row
    return y[:nbr].reshape(-1)[:n_rows]


def spmv_bcsr(mat: BCSR, x: jax.Array) -> jax.Array:
    return _bcsr_ref_impl(
        mat.data,
        mat.block_cols,
        mat.block_rows,
        jnp.asarray(x),
        bc=mat.bc,
        nbr=mat.n_block_rows,
        n_rows=mat.shape[0],
    )


# ---------------------------------------------------------------------------
# Pallas TPU kernel
# ---------------------------------------------------------------------------


def _bcsr_kernel(bcols_ref, brows_ref, d_ref, x_ref, y_ref, *, accum_dtype):
    del bcols_ref  # consumed by the X index map
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    blk = d_ref[0].astype(accum_dtype)  # (br, bc)
    xs = x_ref[0].astype(accum_dtype)  # (bc,)
    v = jnp.dot(blk, xs, preferred_element_type=accum_dtype)  # MXU matvec
    r = brows_ref[i]  # scatter target: this block's block-row
    y = y_ref[...].astype(accum_dtype)
    y_ref[...] = y.at[r].add(v).astype(y_ref.dtype)


def bcsr_spmv_pallas(
    data: jax.Array,
    block_cols: jax.Array,
    block_rows: jax.Array,
    x_panels: jax.Array,
    n_block_rows: int,
    schedule: KernelSchedule,
    *,
    interpret: bool = True,
) -> jax.Array:
    """SpMV over flat BCSR storage.

    ``data: (nb_pad, br, bc)``, ``block_cols/block_rows: (nb_pad,)`` int32
    (padding blocks: col 0 / row ``n_block_rows``), ``x_panels:
    (n_col_blocks, bc)``. Returns ``y: (n_block_rows + 1, br)`` — the last
    row is the padding spill, truncated by the wrapper.
    """
    nb_pad, br, bc = data.shape
    kernel = functools.partial(_bcsr_kernel, accum_dtype=schedule.jnp_accum_dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nb_pad,),
        in_specs=[
            pl.BlockSpec((1, br, bc), lambda i, bcols, brows: (i, 0, 0)),
            # scalar-prefetch-driven gather: DMA the X panel this block needs
            pl.BlockSpec((1, bc), lambda i, bcols, brows: (bcols[i], 0)),
        ],
        # whole output resident in VMEM across the sequential grid (CSR-style
        # stitching: a block-row split across grid steps accumulates for free)
        out_specs=pl.BlockSpec(
            (n_block_rows + 1, br), lambda i, bcols, brows: (0, 0)
        ),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_block_rows + 1, br), x_panels.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",),  # carried accumulation
        ),
        interpret=interpret,
        name="bcsr_spmv",
    )(block_cols, block_rows, data, x_panels)


# ---------------------------------------------------------------------------
# FormatSpec entrypoints
# ---------------------------------------------------------------------------


def _blocks_per_tile(schedule: KernelSchedule) -> int:
    # nnz_tile is lane-quantized; one (br x 128) block consumes 128 lanes,
    # so the schedule's tile maps to a block-count storage quantum
    return max(schedule.nnz_tile // LANE, 1)


def _bcsr_prepare(dense: np.ndarray, schedule: KernelSchedule) -> BCSR:
    dense = np.asarray(dense)
    n_rows, n_cols = dense.shape
    br = min(schedule.rows_per_block, 256)
    nbr = ceil_to(n_rows, br) // br
    occ_bound = min((dense != 0).sum(), nbr * (ceil_to(n_cols, LANE) // LANE))
    check_storage_bytes(int(occ_bound) * br * LANE * 8, "BCSR")
    return bcsr_from_dense(
        dense, br=br, bc=LANE, pad_blocks_to=_blocks_per_tile(schedule)
    )


def _bcsr_spmv(mat: BCSR, x, schedule: KernelSchedule, *, interpret: bool = True):
    n_rows, n_cols = mat.shape
    bpt = _blocks_per_tile(schedule)
    if mat.data.shape[0] % bpt:
        raise InfeasibleConfig(
            f"BCSR block count {mat.data.shape[0]} not aligned to the "
            f"nnz_tile={schedule.nnz_tile} storage quantum ({bpt} blocks); "
            "convert with prepare(..., schedule)"
        )
    x = jnp.asarray(x)
    xp = jnp.zeros(ceil_to(n_cols, mat.bc), x.dtype).at[:n_cols].set(x)
    y = bcsr_spmv_pallas(
        mat.data,
        mat.block_cols,
        mat.block_rows,
        xp.reshape(-1, mat.bc),
        mat.n_block_rows,
        schedule,
        interpret=interpret,
    )
    return y[: mat.n_block_rows].reshape(-1)[:n_rows]


def _bcsr_footprint(stats: MatrixStats, schedule: KernelSchedule) -> KernelFootprint:
    n, m, nnz = stats.n_rows, stats.n_cols, stats.nnz
    x_bytes, y_bytes = m * _VAL_B, n * _VAL_B
    br, bc = min(schedule.rows_per_block, 256), LANE
    n_blocks, _ = stats.block_occupancy(br, bc)
    nb_pad = ceil_to(max(n_blocks, 1), _blocks_per_tile(schedule))
    nbr = ceil_to(n, br) // br
    stored = float(nb_pad) * br * bc  # row-compressed: occupied blocks only
    x_traffic = (
        float(nb_pad) * bc * _VAL_B  # streamed panels (scalar-prefetch DMA)
        if schedule.x_residency == "stream"
        else x_bytes
    )
    hbm = stored * _VAL_B + nb_pad * 2 * _IDX_B + x_traffic + y_bytes
    steps = float(nb_pad)
    tile_b = br * bc * _VAL_B + bc * _VAL_B
    # output resident across the sequential grid, like the CSR kernel's Y
    vmem = (
        2 * tile_b
        + (nbr + 1) * br * _VAL_B
        + (x_bytes if schedule.x_residency == "vmem" else 0)
    )
    return KernelFootprint(
        2.0 * nnz,
        2 * stored,
        hbm,
        0.0,
        float(nb_pad) * br,  # per-block scatter-accumulate into resident Y
        steps,
        1.0,
        vmem,
        vmem <= VMEM_BYTES,
    )


BCSR_SPEC = FormatSpec(
    name="bcsr",
    container=BCSR,
    from_dense=bcsr_from_dense,
    to_dense=bcsr_to_dense,
    prepare=_bcsr_prepare,
    spmv=_bcsr_spmv,
    reference=spmv_bcsr,
    footprint=_bcsr_footprint,
    priority=40,
    description="Blocked CSR: flat occupied 8x128 blocks, row-compressed",
)


def register() -> FormatSpec:
    """Idempotent activation: make BCSR a live format everywhere."""
    return register_format(BCSR_SPEC, overwrite=True)


register()
