"""Sparse matrix storage formats: CSR, ELL, BELL, SELL (paper §2.3).

Each format is a JAX pytree (registered dataclass) whose array fields are
device arrays and whose structural fields (shape, block size, slice height)
are static metadata. Conversion happens on the host in numpy — the paper's
run-time mode explicitly performs conversion on the CPU and *measures* it
(``c_latency``, Table 7), so converters are written to be timeable as-is.

TPU adaptation notes (DESIGN.md §2):

* ``CSR`` carries a ``row_ids`` companion (COO expansion of ``indptr``) —
  the flat segmented-sum kernel that replaces GPU scalar/vector-CSR needs
  per-nonzero row ids. ``nbytes_core`` excludes companions so that format
  size comparisons match the textbook definition.
* ``BELL`` blocks default to 8×128 (sublane × lane) instead of the paper's
  GPU 2×2, so a stored block times an X segment is an MXU-shaped matmul.
* ``SELL`` keeps true ragged storage (flat data + slice pointers); slice
  widths are padded to the TPU lane quantum (128) rather than 1 — the
  SELL-C-sigma adaptation for 8×128 vector registers.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Union

import jax
import numpy as np

# LANE/SUBLANE live in kernels/common.py (the single source of truth for TPU
# tiling constants); re-exported here for backward compatibility.
from repro.kernels.common import LANE, SUBLANE

# Deprecated: the four *seed* formats. New code should use
# ``repro.sparse.registry.format_names()``, which also covers plugins.
FORMAT_NAMES = ("csr", "ell", "bell", "sell")


def _ceil_to(x: int, q: int) -> int:
    return ((x + q - 1) // q) * q


def _nbytes(*arrays) -> int:
    return int(sum(a.size * a.dtype.itemsize for a in arrays))


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class CSR:
    """Compressed Sparse Row. ``row_ids`` is the kernel-facing companion."""

    data: jax.Array  # (nnz,) nonzero values
    indices: jax.Array  # (nnz,) column index per nonzero
    indptr: jax.Array  # (n_rows + 1,) row boundaries
    row_ids: jax.Array  # (nnz,) row index per nonzero (COO companion)
    shape: tuple[int, int] = dataclasses.field(metadata=dict(static=True))

    @property
    def nnz(self) -> int:
        return int(self.data.shape[0])

    @property
    def nbytes_core(self) -> int:
        return _nbytes(self.data, self.indices, self.indptr)

    @property
    def nbytes(self) -> int:
        return self.nbytes_core + _nbytes(self.row_ids)


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class ELL:
    """ELLPACK: row-major dense (n_rows, max_nnz) value/column planes.

    Padding slots hold value 0 and column 0 — a "safe gather" convention so
    kernels need no masking on the X gather (0 * x[0] == 0).
    """

    data: jax.Array  # (n_rows, width)
    cols: jax.Array  # (n_rows, width) int32
    shape: tuple[int, int] = dataclasses.field(metadata=dict(static=True))

    @property
    def width(self) -> int:
        return int(self.data.shape[1])

    @property
    def nbytes_core(self) -> int:
        return _nbytes(self.data, self.cols)

    nbytes = nbytes_core


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class BELL:
    """Blocked ELL: ELL over (br x bc) dense blocks.

    ``data[i, j]`` is the j-th stored block of block-row i; its block-column
    is ``block_cols[i, j]``. Padding blocks are all-zero with block-column 0.
    """

    data: jax.Array  # (n_block_rows, max_blocks, br, bc)
    block_cols: jax.Array  # (n_block_rows, max_blocks) int32
    shape: tuple[int, int] = dataclasses.field(metadata=dict(static=True))
    br: int = dataclasses.field(metadata=dict(static=True))
    bc: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_block_rows(self) -> int:
        return int(self.data.shape[0])

    @property
    def max_blocks(self) -> int:
        return int(self.data.shape[1])

    @property
    def nbytes_core(self) -> int:
        return _nbytes(self.data, self.block_cols)

    nbytes = nbytes_core


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class SELL:
    """Sliced ELL (SELL-C-q): slices of C rows, per-slice padded width.

    True ragged storage: ``data``/``cols`` are flat concatenations of
    *column-major* (width_s, C) slice planes — element (row r, k-th stored
    nonzero) of slice s lives at ``slice_ptr[s] + k * C + r``. Column-major
    slices make every width-tile of a slice a contiguous ``nnz_tile * C``
    chunk, which is what lets the Pallas kernel address tiles with a plain
    BlockSpec index driven by scalar-prefetched slice pointers (DESIGN.md
    §2). ``slice_ptr[s]`` is the flat element offset of slice s;
    ``slice_width[s] = (slice_ptr[s+1] - slice_ptr[s]) / C``. Widths are
    padded to the lane quantum ``q``. ``row_ids`` is the oracle-facing
    companion (row per element, == n_rows on padding slots).
    """

    data: jax.Array  # (total,)
    cols: jax.Array  # (total,) int32
    slice_ptr: jax.Array  # (n_slices + 1,) int32, element offsets
    slice_width: jax.Array  # (n_slices,) int32
    row_ids: jax.Array  # (total,) int32, == n_rows on padding slots
    shape: tuple[int, int] = dataclasses.field(metadata=dict(static=True))
    C: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_slices(self) -> int:
        return int(self.slice_width.shape[0])

    @property
    def nbytes_core(self) -> int:
        return _nbytes(self.data, self.cols, self.slice_ptr)

    @property
    def nbytes(self) -> int:
        return self.nbytes_core + _nbytes(self.slice_width, self.row_ids)


SparseFormat = Union[CSR, ELL, BELL, SELL]


# ---------------------------------------------------------------------------
# Host-side converters (numpy; timeable as the paper's c_latency)
# ---------------------------------------------------------------------------


def _row_counts(dense: np.ndarray) -> np.ndarray:
    return (dense != 0).sum(axis=1).astype(np.int64)


def csr_from_dense(dense: np.ndarray, dtype=np.float32) -> CSR:
    dense = np.asarray(dense)
    n_rows, n_cols = dense.shape
    rows, cols = np.nonzero(dense)
    data = dense[rows, cols].astype(dtype)
    counts = np.bincount(rows, minlength=n_rows)
    indptr = np.zeros(n_rows + 1, dtype=np.int32)
    np.cumsum(counts, out=indptr[1:])
    return CSR(
        data=jax.numpy.asarray(data),
        indices=jax.numpy.asarray(cols.astype(np.int32)),
        indptr=jax.numpy.asarray(indptr),
        row_ids=jax.numpy.asarray(rows.astype(np.int32)),
        shape=(n_rows, n_cols),
    )


def ell_from_dense(dense: np.ndarray, dtype=np.float32, min_width: int = 1) -> ELL:
    dense = np.asarray(dense)
    n_rows, n_cols = dense.shape
    counts = _row_counts(dense)
    width = max(int(counts.max(initial=0)), min_width)
    data = np.zeros((n_rows, width), dtype=dtype)
    cols = np.zeros((n_rows, width), dtype=np.int32)
    rows, cc = np.nonzero(dense)
    # position of each nonzero within its row
    pos = np.arange(rows.size) - np.repeat(np.concatenate([[0], np.cumsum(counts)[:-1]]), counts)
    data[rows, pos] = dense[rows, cc]
    cols[rows, pos] = cc
    return ELL(
        data=jax.numpy.asarray(data),
        cols=jax.numpy.asarray(cols),
        shape=(n_rows, n_cols),
    )


def bell_from_dense(
    dense: np.ndarray, br: int = SUBLANE, bc: int = LANE, dtype=np.float32
) -> BELL:
    dense = np.asarray(dense)
    n_rows, n_cols = dense.shape
    pr, pc = _ceil_to(n_rows, br), _ceil_to(n_cols, bc)
    padded = np.zeros((pr, pc), dtype=dtype)
    padded[:n_rows, :n_cols] = dense
    nbr, nbc = pr // br, pc // bc
    blocks = padded.reshape(nbr, br, nbc, bc).transpose(0, 2, 1, 3)  # (nbr, nbc, br, bc)
    occupied = (blocks != 0).any(axis=(2, 3))  # (nbr, nbc)
    max_blocks = max(int(occupied.sum(axis=1).max(initial=0)), 1)
    data = np.zeros((nbr, max_blocks, br, bc), dtype=dtype)
    block_cols = np.zeros((nbr, max_blocks), dtype=np.int32)
    for i in range(nbr):
        js = np.nonzero(occupied[i])[0]
        data[i, : js.size] = blocks[i, js]
        block_cols[i, : js.size] = js
    return BELL(
        data=jax.numpy.asarray(data),
        block_cols=jax.numpy.asarray(block_cols),
        shape=(n_rows, n_cols),
        br=br,
        bc=bc,
    )


def sell_from_dense(
    dense: np.ndarray, C: int = 4 * SUBLANE, q: int = LANE, dtype=np.float32
) -> SELL:
    dense = np.asarray(dense)
    n_rows, n_cols = dense.shape
    counts = _row_counts(dense)
    n_slices = (n_rows + C - 1) // C
    widths = np.zeros(n_slices, dtype=np.int32)
    for s in range(n_slices):
        w = int(counts[s * C : (s + 1) * C].max(initial=0))
        widths[s] = _ceil_to(max(w, 1), q)
    slice_ptr = np.zeros(n_slices + 1, dtype=np.int32)
    np.cumsum(widths.astype(np.int64) * C, out=slice_ptr[1:])
    total = int(slice_ptr[-1])
    data = np.zeros(total, dtype=dtype)
    cols = np.zeros(total, dtype=np.int32)
    row_ids = np.full(total, n_rows, dtype=np.int32)
    for s in range(n_slices):
        w = int(widths[s])
        base = int(slice_ptr[s])
        # build the (C, w) slice plane row-major, then store transposed
        plane_d = np.zeros((C, w), dtype=dtype)
        plane_c = np.zeros((C, w), dtype=np.int32)
        plane_r = np.full((C, w), n_rows, dtype=np.int32)
        for r_local in range(min(C, n_rows - s * C)):
            r = s * C + r_local
            cc = np.nonzero(dense[r])[0]
            plane_d[r_local, : cc.size] = dense[r, cc]
            plane_c[r_local, : cc.size] = cc
            plane_r[r_local, :] = r
        data[base : base + C * w] = plane_d.T.ravel()
        cols[base : base + C * w] = plane_c.T.ravel()
        row_ids[base : base + C * w] = plane_r.T.ravel()
    return SELL(
        data=jax.numpy.asarray(data),
        cols=jax.numpy.asarray(cols),
        slice_ptr=jax.numpy.asarray(slice_ptr),
        slice_width=jax.numpy.asarray(widths),
        row_ids=jax.numpy.asarray(row_ids),
        shape=(n_rows, n_cols),
        C=C,
    )


def _empty_dense(mat) -> np.ndarray:
    return np.zeros(mat.shape, dtype=np.asarray(mat.data).dtype)


def csr_to_dense(mat: CSR) -> np.ndarray:
    out = _empty_dense(mat)
    out[np.asarray(mat.row_ids), np.asarray(mat.indices)] = np.asarray(mat.data)
    return out


def ell_to_dense(mat: ELL) -> np.ndarray:
    out = _empty_dense(mat)
    n_rows = mat.shape[0]
    data, cols = np.asarray(mat.data), np.asarray(mat.cols)
    rows = np.repeat(np.arange(n_rows), data.shape[1])
    np.add.at(out, (rows, cols.ravel()), data.ravel())
    return out


def bell_to_dense(mat: BELL) -> np.ndarray:
    out = _empty_dense(mat)
    n_rows, n_cols = mat.shape
    data, bcols = np.asarray(mat.data), np.asarray(mat.block_cols)
    br, bc = mat.br, mat.bc
    for i in range(data.shape[0]):
        for j in range(data.shape[1]):
            r0, c0 = i * br, int(bcols[i, j]) * bc
            blk = data[i, j]
            rr = min(br, n_rows - r0)
            cc = min(bc, n_cols - c0)
            if rr > 0 and cc > 0:
                out[r0 : r0 + rr, c0 : c0 + cc] += blk[:rr, :cc]
    return out


def sell_to_dense(mat: SELL) -> np.ndarray:
    out = _empty_dense(mat)
    n_rows = mat.shape[0]
    rid = np.asarray(mat.row_ids)
    valid = rid < n_rows
    np.add.at(
        out,
        (rid[valid], np.asarray(mat.cols)[valid]),
        np.asarray(mat.data)[valid],
    )
    return out


def from_dense(dense: np.ndarray, fmt: str, **kwargs) -> SparseFormat:
    """Convert a dense matrix to the named (registered) format."""
    from repro.sparse.registry import get_format

    return get_format(fmt).from_dense(dense, **kwargs)


def to_dense(mat: SparseFormat) -> np.ndarray:
    """Densify any registered format (host-side; inverse of the converters)."""
    from repro.sparse.registry import spec_for

    return spec_for(mat).to_dense(mat)


def convert(mat: SparseFormat, fmt: str, **kwargs) -> SparseFormat:
    """Format-to-format conversion (via dense; host-side, timeable)."""
    return from_dense(to_dense(mat), fmt, **kwargs)
