"""Synthetic sparse-matrix suite mirroring the paper's 30 SuiteSparse matrices.

The container is offline, so SuiteSparse itself is unavailable. The paper
selected its matrices for (1) a wide range of n (14,340..1,489,752), (2) a
wide range of nnz (800,800..19,235,140) and (3) minimal similarity between
sparsity features (§6.1, Fig. 7). We reproduce those three properties with a
seeded generator: each Table-7 matrix name becomes a pattern preset whose
full-scale (n, nnz) equal the published values, and whose sparsity pattern
class (FEM/banded, power-law graph, block-structured, geometric, dense-row)
matches the real matrix's domain. A global ``scale`` shrinks n while
preserving avg_nnz so laptop-scale runs keep the feature *spread* of Fig. 7.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class MatrixSpec:
    name: str
    n: int  # full-scale rows (paper value)
    nnz: int  # full-scale nonzeros (paper Table 7 value)
    pattern: str  # generator family
    seed: int

    @property
    def avg_nnz(self) -> float:
        return self.nnz / self.n


# name, n, nnz (paper Table 7, ascending nnz), pattern class
_SUITE_RAW = [
    ("shar_te2-b3", 200_200, 800_800, "bipartite"),
    ("rim", 22_560, 1_014_951, "fem"),
    ("bcsstk32", 44_609, 1_029_655, "fem"),
    ("il2010", 451_554, 1_082_232, "geometric"),
    ("viscorocks", 37_762, 1_162_244, "fem"),
    ("cant", 62_451, 2_034_917, "fem"),
    ("parabolic_fem", 525_825, 2_100_225, "banded"),
    ("pkustk04", 55_590, 2_137_125, "block"),
    ("apache2", 715_176, 2_766_523, "banded"),
    ("consph", 83_334, 3_046_907, "fem"),
    ("wiki-talk-temporal", 1_140_149, 3_309_592, "powerlaw"),
    ("amazon0601", 403_394, 3_387_388, "powerlaw"),
    ("Chevron3", 381_689, 3_413_113, "banded"),
    ("xenon2", 157_464, 3_866_688, "fem"),
    ("x104", 108_384, 5_138_004, "block"),
    ("crankseg_1", 52_804, 5_333_507, "fem"),
    ("Si87H76", 240_369, 5_451_000, "denserows"),
    ("Hamrle3", 1_447_360, 5_514_242, "banded"),
    ("pwtk", 217_918, 5_926_171, "fem"),
    ("Chevron4", 711_450, 6_376_412, "banded"),
    ("Hardesty1", 938_905, 6_539_157, "banded"),
    ("rgg_n_2_20_s0", 1_048_576, 6_891_620, "geometric"),
    ("crankseg_2", 63_838, 7_106_348, "fem"),
    ("CurlCurl_3", 1_219_574, 7_382_096, "banded"),
    ("human_gene2", 14_340, 9_041_364, "denserows"),
    ("af_shell6", 504_855, 9_046_865, "fem"),
    ("atmosmodm", 1_489_752, 10_319_760, "banded"),
    ("kim2", 456_976, 11_330_020, "banded"),
    ("test1", 392_908, 12_968_200, "powerlaw"),
    ("eu-2005", 862_664, 19_235_140, "powerlaw"),
]

# Post-Table-7 extensions: in SUITE (name-addressable everywhere) but NOT in
# MATRIX_NAMES, which stays the paper's exact 30-matrix §6.1 selection used
# for dataset collection. Append-only — seeds are positional and must not
# shift for either list.
_EXTRA_RAW = [
    # web adjacency for the PageRank/power-iteration solver workload
    ("webgraph", 875_713, 5_105_039, "webgraph"),
    # magnitude-pruned LM FFN projection for the sparse-serving workload
    # (full-scale shape of a 7B-class gate/up projection at ~8 kept weights
    # per row; scaled copies keep the unstructured-topk row statistics)
    ("pruned-ffn", 11_008, 88_064, "prunedffn"),
]

SUITE: dict[str, MatrixSpec] = {
    name: MatrixSpec(name, n, nnz, pattern, seed=i + 1)
    for i, (name, n, nnz, pattern) in enumerate(_SUITE_RAW + _EXTRA_RAW)
}

MATRIX_NAMES = tuple(name for name, *_ in _SUITE_RAW)


def _scatter(n_rows: int, n_cols: int, rows: np.ndarray, cols: np.ndarray, rng) -> np.ndarray:
    dense = np.zeros((n_rows, n_cols), dtype=np.float32)
    vals = rng.uniform(0.1, 1.0, size=rows.size).astype(np.float32)
    dense[rows, cols] = vals  # duplicates collapse; nnz is approximate, as documented
    return dense


def _row_major_expand(counts: np.ndarray) -> np.ndarray:
    return np.repeat(np.arange(counts.size), counts)


def _gen_banded(n: int, avg: float, rng) -> np.ndarray:
    band = max(int(avg * 2), 4)
    counts = np.clip(rng.normal(avg, avg * 0.1, size=n).astype(np.int64), 1, band)
    rows = _row_major_expand(counts)
    offs = rng.integers(-band // 2, band // 2 + 1, size=rows.size)
    cols = np.clip(rows + offs, 0, n - 1)
    return _scatter(n, n, rows, cols, rng)


def _gen_fem(n: int, avg: float, rng) -> np.ndarray:
    # near-constant row counts, mostly banded with a few far couplings
    counts = np.clip(rng.normal(avg, max(avg * 0.05, 1.0), size=n).astype(np.int64), 1, None)
    rows = _row_major_expand(counts)
    band = max(int(avg * 3), 8)
    local = rng.integers(-band // 2, band // 2 + 1, size=rows.size)
    cols = np.clip(rows + local, 0, n - 1)
    far = rng.random(rows.size) < 0.05
    cols[far] = rng.integers(0, n, size=int(far.sum()))
    return _scatter(n, n, rows, cols, rng)


def _gen_powerlaw(n: int, avg: float, rng) -> np.ndarray:
    # Zipf row degrees: few hub rows, many near-empty rows (graph adjacency)
    raw = rng.zipf(1.7, size=n).astype(np.float64)
    counts = np.clip(raw * (avg / raw.mean()), 1, n // 2).astype(np.int64)
    rows = _row_major_expand(counts)
    cols = rng.integers(0, n, size=rows.size)
    return _scatter(n, n, rows, cols, rng)


def _gen_block(n: int, avg: float, rng) -> np.ndarray:
    # dense (br x bc) tiles scattered on a block grid (BELL-friendly)
    br, bc = 8, 8
    nbr, nbc = max(n // br, 1), max(n // bc, 1)
    blocks_per_row = max(int(round(avg / bc)), 1)
    dense = np.zeros((n, n), dtype=np.float32)
    for i in range(nbr):
        js = rng.integers(0, nbc, size=blocks_per_row)
        for j in js:
            r0, c0 = i * br, j * bc
            dense[r0 : r0 + br, c0 : c0 + bc] = rng.uniform(
                0.1, 1.0, size=(min(br, n - r0), min(bc, n - c0))
            )
    return dense


def _gen_geometric(n: int, avg: float, rng) -> np.ndarray:
    # random geometric graph: neighbors of grid-ordered points (narrow band
    # plus locality noise); row counts are Poisson-like
    counts = np.clip(rng.poisson(avg, size=n), 1, None)
    rows = _row_major_expand(counts)
    spread = max(int(np.sqrt(n)), 2)
    offs = (rng.normal(0, spread, size=rows.size)).astype(np.int64)
    cols = np.clip(rows + offs, 0, n - 1)
    return _scatter(n, n, rows, cols, rng)


def _gen_denseband(n: int, avg: float, rng) -> np.ndarray:
    # contiguous fully-dense diagonal band of width ~avg: every row has
    # exactly the same count and its nonzeros are consecutive columns. The
    # most ELL/BELL-friendly structure a matrix can have (uniform width,
    # dense tiles) — the "dense band" half of the partitioned-SpMV
    # heterogeneity studies.
    w = int(np.clip(int(avg), 1, n))
    starts = np.clip(np.arange(n) - w // 2, 0, n - w)
    # group starts so 8-row sublane slabs share a column offset (tile-dense)
    starts = (starts // 8) * 8
    rows = np.repeat(np.arange(n), w)
    cols = (starts[:, None] + np.arange(w)[None, :]).reshape(-1)
    dense = np.zeros((n, n), dtype=np.float32)
    dense[rows, cols] = rng.uniform(0.1, 1.0, size=rows.size).astype(np.float32)
    return dense


def _gen_denserows(n: int, avg: float, rng) -> np.ndarray:
    counts = np.clip(rng.normal(avg, avg * 0.3, size=n).astype(np.int64), 1, n - 1)
    rows = _row_major_expand(counts)
    cols = rng.integers(0, n, size=rows.size)
    return _scatter(n, n, rows, cols, rng)


def _gen_webgraph(n: int, avg: float, rng) -> np.ndarray:
    # directed web adjacency A[i, j] = weight of link j -> i (column j holds
    # node j's out-edges, the orientation PageRank multiplies): power-law
    # out-degrees with preferential attachment on the targets (hub *rows*),
    # plus ~2% dangling nodes (all-zero columns) so the solver's
    # dangling-mass redistribution is actually exercised
    raw = rng.zipf(1.9, size=n).astype(np.float64)
    out_deg = np.clip(raw * (avg / raw.mean()), 1, n // 2).astype(np.int64)
    dangling = rng.random(n) < 0.02
    out_deg[dangling] = 0
    cols = _row_major_expand(out_deg)  # source node per edge
    # preferential attachment: half the edges land on zipf-ranked hub
    # targets, half uniformly (keeps the graph connected enough to mix)
    n_edges = cols.size
    hub = rng.random(n_edges) < 0.5
    hub_targets = np.minimum(rng.zipf(1.5, size=n_edges) - 1, n - 1)
    uni_targets = rng.integers(0, n, size=n_edges)
    rows = np.where(hub, hub_targets, uni_targets).astype(np.int64)
    off_diag = rows != cols  # no self-links
    return _scatter(n, n, rows[off_diag], cols[off_diag], rng)


def _gen_prunedffn(n: int, avg: float, rng) -> np.ndarray:
    # magnitude-pruned LM FFN weight: global top-k over a Gaussian matrix.
    # Unlike the graph/FEM patterns the support is i.i.d. (no banding, no
    # hubs) but the row-count distribution is the binomial an unstructured
    # topk induces — tight around avg with no empty rows at these densities,
    # the regime the sparse LM serving path feeds through serve_optimize.
    from repro.optim.compress import magnitude_prune

    w = rng.normal(size=(n, n)).astype(np.float32)
    pruned, _ = magnitude_prune(w, min(avg / n, 1.0))
    return pruned


def normalize_columns(dense: np.ndarray) -> np.ndarray:
    """Column-stochastic normalization: each nonzero column sums to 1.

    Zero columns (dangling nodes) are left zero — PageRank's recurrence
    redistributes their mass explicitly, so the operator must keep them
    visible rather than papering over them with a uniform column."""
    dense = np.asarray(dense, dtype=np.float32)
    sums = dense.sum(axis=0)
    safe = np.where(sums > 0, sums, 1.0)
    return dense / safe[None, :]


def _gen_bipartite(n: int, avg: float, rng) -> np.ndarray:
    # constant-degree structured stencil (simplicial boundary operator-like)
    k = max(int(avg), 1)
    stride = max(n // (k + 1), 1)
    base = np.arange(n)[:, None] + (np.arange(k) * stride)[None, :]
    rows = np.repeat(np.arange(n), k)
    cols = (base % n).reshape(-1)
    return _scatter(n, n, rows, cols.astype(np.int64), rng)


_PATTERNS = {
    "banded": _gen_banded,
    "fem": _gen_fem,
    "powerlaw": _gen_powerlaw,
    "block": _gen_block,
    "geometric": _gen_geometric,
    "denseband": _gen_denseband,
    "denserows": _gen_denserows,
    "bipartite": _gen_bipartite,
    "webgraph": _gen_webgraph,
    "prunedffn": _gen_prunedffn,
}

PATTERN_NAMES = tuple(_PATTERNS)


def generate_dense(spec: MatrixSpec, scale: float = 1.0, max_elems: int = 200_000_000) -> np.ndarray:
    """Materialize the (scaled) dense matrix for ``spec``.

    ``scale`` shrinks n (rows/cols) while holding avg_nnz fixed, except when
    avg_nnz would exceed the scaled n, in which case the density saturates
    (documented behaviour; affects only denserows presets at tiny scales).
    """
    n = max(int(spec.n * scale), 64)
    avg = min(spec.avg_nnz, n / 2)
    if n * n > max_elems:
        raise ValueError(
            f"{spec.name}: scaled dense size {n}x{n} exceeds max_elems={max_elems}; "
            "lower `scale`"
        )
    rng = np.random.default_rng(spec.seed)
    return _PATTERNS[spec.pattern](n, avg, rng)


def generate_by_name(name: str, scale: float = 1.0, **kwargs) -> np.ndarray:
    return generate_dense(SUITE[name], scale=scale, **kwargs)


def random_matrix(
    n: int, avg_nnz: float, pattern: str = "fem", seed: int = 0
) -> np.ndarray:
    """Free-form generator for tests and the dataset harness."""
    rng = np.random.default_rng(seed)
    return _PATTERNS[pattern](n, min(avg_nnz, n / 2), rng)
