"""Pluggable sparse-format registry: one ``FormatSpec`` from converter to
bandit arm.

Historically every layer of the reproduction hard-coded the four seed
formats: ``if fmt == "csr"`` chains in the kernel wrapper, per-format
footprint branches in the cost model, ``FORMAT_NAMES`` literals in the
tuning space, and ``"csr"`` defaults through session / predictor / bandit /
serve CLI. The SpMV literature catalogues dozens of formats (Gao et al.,
arXiv:2404.06047; Koza et al.'s CMRS, arXiv:1203.2946), so format count must
be a *runtime* property: this module defines the ``FormatSpec`` contract
that bundles everything the system branches on per format, and every
dispatch site consumes the registry instead of a literal.

Adding a format is one call::

    from repro.sparse.registry import FormatSpec, register_format

    register_format(FormatSpec(
        name="myfmt",
        container=MyFmt,            # jax-pytree dataclass
        from_dense=myfmt_from_dense,
        to_dense=myfmt_to_dense,
        prepare=my_prepare,         # (dense, schedule) -> MyFmt, aligned
        spmv=my_spmv,               # (mat, x, schedule, *, interpret) -> y
        reference=my_reference,     # pure-jnp oracle, (mat, x) -> y
        footprint=my_footprint,     # (MatrixStats, schedule) -> KernelFootprint
    ))

and the format then appears in ``full_space()``, the tuning dataset,
classifier labels, the serving bandit's arm set, and the SpMV server —
no edits to any of those layers. ``repro/sparse/bcsr.py`` is the proof:
a fifth format (blocked-CSR) registered exactly this way.

Contract notes for plugin authors (enforced by the shared suite in
``tests/test_format_registry.py``):

* ``from_dense``/``to_dense`` must round-trip exactly;
* ``prepare`` aligns storage geometry to the ``KernelSchedule`` and raises
  ``InfeasibleConfig`` when storage would blow up (``check_storage_bytes``);
* ``spmv`` on storage prepared with a *different* schedule must either
  compute the exact result or raise ``InfeasibleConfig`` — never silently
  corrupt;
* ``footprint`` must return finite, non-negative statistics with
  ``useful_flops == 2 * nnz``.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache
from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.kernels.common import (
    LANE,
    VMEM_BYTES,
    InfeasibleConfig,
    KernelSchedule,
    ceil_to,
    pad_axis,
)

__all__ = [
    "FormatSpec",
    "InfeasibleConfig",
    "KernelFootprint",
    "MatrixStats",
    "MAX_STORAGE_BYTES",
    "check_storage_bytes",
    "default_format",
    "format_names",
    "get_format",
    "register_format",
    "registered_specs",
    "spec_for",
    "unregister_format",
]

MAX_STORAGE_BYTES = 512 * 1024 * 1024  # refuse >512 MiB single-format storage


def check_storage_bytes(estimate: int, what: str) -> None:
    """Shared feasibility guard for ``FormatSpec.prepare`` implementations."""
    if estimate > MAX_STORAGE_BYTES:
        raise InfeasibleConfig(f"{what} storage would be {estimate/1e6:.0f} MB")


# ---------------------------------------------------------------------------
# Matrix statistics + footprint model (the cost model's per-format inputs)
# ---------------------------------------------------------------------------


class MatrixStats:
    """Cached structural statistics of one matrix (host-side numpy).

    The duck-typed interface ``FormatSpec.footprint`` implementations rely
    on: ``n_rows``, ``n_cols``, ``nnz``, ``max_nnz``, ``row_counts``, plus
    the cached ``block_occupancy(br, bc)`` and ``sell_storage(C, q)``
    reductions.
    """

    def __init__(self, dense: np.ndarray):
        dense = np.asarray(dense)
        self.n_rows, self.n_cols = dense.shape
        self.row_counts = (dense != 0).sum(axis=1).astype(np.int64)
        self.nnz = int(self.row_counts.sum())
        self.max_nnz = int(self.row_counts.max(initial=0))
        self._mask = dense != 0

    @lru_cache(maxsize=16)
    def block_occupancy(self, br: int, bc: int) -> tuple[int, int]:
        """(#occupied blocks, max occupied blocks per block-row)."""
        pr, pc = ceil_to(self.n_rows, br), ceil_to(self.n_cols, bc)
        m = np.zeros((pr, pc), dtype=bool)
        m[: self.n_rows, : self.n_cols] = self._mask
        occ = m.reshape(pr // br, br, pc // bc, bc).any(axis=(1, 3))
        per_row = occ.sum(axis=1)
        return int(occ.sum()), int(per_row.max(initial=0))

    @lru_cache(maxsize=16)
    def sell_storage(self, C: int, q: int) -> tuple[int, int]:
        """(total stored elems, max width) for SELL-C-q."""
        n_slices = (self.n_rows + C - 1) // C
        total, maxw = 0, 0
        for s in range(n_slices):
            w = int(self.row_counts[s * C : (s + 1) * C].max(initial=0))
            w = ceil_to(max(w, 1), q)
            total += w * C
            maxw = max(maxw, w)
        return total, maxw


@dataclass(frozen=True)
class KernelFootprint:
    """Work/traffic summary of one (matrix, format, schedule) point."""

    useful_flops: float
    total_flops: float  # includes padding compute
    hbm_bytes: float  # format storage + X + Y traffic
    gather_elems: float  # in-kernel dynamic gathers
    scatter_elems: float  # in-kernel scatter-adds
    grid_steps: float
    mxu_fraction: float  # fraction of FLOPs running on the MXU
    vmem_resident_bytes: float  # steady-state VMEM requirement
    feasible: bool
    note: str = ""


# ---------------------------------------------------------------------------
# The FormatSpec contract + registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class FormatSpec:
    """Everything the system needs to know about one sparse format.

    ``priority`` orders ``format_names()`` and picks ``default_format()``
    (lowest wins); plugins default to 100 so they never displace the seed
    default unless they ask to.
    """

    name: str
    container: type  # the jax-pytree storage dataclass
    from_dense: Callable  # (dense, **kw) -> container
    to_dense: Callable  # (mat) -> np.ndarray (exact inverse)
    prepare: Callable  # (dense, KernelSchedule) -> container, tile-aligned
    spmv: Callable  # (mat, x, KernelSchedule, *, interpret) -> y
    reference: Callable  # (mat, x) -> y — pure-jnp oracle
    footprint: Callable  # (MatrixStats, KernelSchedule) -> KernelFootprint
    priority: int = 100
    description: str = ""


_REGISTRY: dict[str, FormatSpec] = {}
_BY_CONTAINER: dict[type, FormatSpec] = {}
_INSERTION: dict[str, int] = {}
_counter = 0


def register_format(spec: FormatSpec, *, overwrite: bool = False) -> FormatSpec:
    """Register ``spec``; after this call the format is live everywhere
    (tuning space, dataset harness, cost model, bandit arms, serving)."""
    global _counter
    if not spec.name or not spec.name.isidentifier():
        raise ValueError(f"format name must be an identifier, got {spec.name!r}")
    if spec.name in _REGISTRY and not overwrite:
        raise ValueError(
            f"format {spec.name!r} already registered; pass overwrite=True"
        )
    bound = _BY_CONTAINER.get(spec.container)
    if bound is not None and bound.name != spec.name:
        raise ValueError(
            f"container {spec.container.__name__} already bound to format "
            f"{bound.name!r}"
        )
    prev = _REGISTRY.get(spec.name)
    if prev is not None:
        _BY_CONTAINER.pop(prev.container, None)
        _evict_prepared_kernels(spec.name)
    _REGISTRY[spec.name] = spec
    _BY_CONTAINER[spec.container] = spec
    if spec.name not in _INSERTION:
        _INSERTION[spec.name] = _counter
        _counter += 1
    return spec


def unregister_format(name: str) -> None:
    spec = _REGISTRY.pop(name, None)
    if spec is None:
        raise ValueError(f"format {name!r} is not registered")
    _BY_CONTAINER.pop(spec.container, None)
    _INSERTION.pop(name, None)
    _evict_prepared_kernels(name)


def _evict_prepared_kernels(name: str) -> None:
    """A memoized ``PreparedSpmv`` must not outlive the spec that built it."""
    from repro.kernels.ops import evict_kernel_memo_format

    evict_kernel_memo_format(name)


def get_format(name: str) -> FormatSpec:
    spec = _REGISTRY.get(name)
    if spec is None:
        raise ValueError(
            f"unknown format {name!r}; registered formats: {format_names()}"
        )
    return spec


def format_names() -> tuple[str, ...]:
    """Registered format names, ordered by (priority, registration order)."""
    return tuple(
        sorted(_REGISTRY, key=lambda n: (_REGISTRY[n].priority, _INSERTION[n]))
    )


def default_format() -> str:
    """The format the system holds/serves when nothing better is known."""
    names = format_names()
    if not names:
        raise RuntimeError("no sparse formats registered")
    return names[0]


def registered_specs() -> tuple[FormatSpec, ...]:
    return tuple(_REGISTRY[n] for n in format_names())


def spec_for(mat) -> FormatSpec:
    """Resolve the spec governing a storage container instance."""
    spec = _BY_CONTAINER.get(type(mat))
    if spec is None:
        raise TypeError(
            f"no registered format for container {type(mat).__name__}; "
            f"registered: {format_names()}"
        )
    return spec


# ---------------------------------------------------------------------------
# Seed formats: CSR / ELL / BELL / SELL
#
# Everything below is ordinary plugin code — it uses only the public
# machinery above, exactly as third-party formats do. The kernel entrypoints
# are imported here (after the machinery is defined) so that the
# kernels <-> sparse import cycle resolves cleanly in either direction.
# ---------------------------------------------------------------------------

from repro.kernels.bell import bell_spmv_pallas  # noqa: E402
from repro.kernels.csr import csr_spmv_pallas  # noqa: E402
from repro.kernels.ell import ell_spmv_pallas  # noqa: E402
from repro.kernels.sell import sell_spmv_pallas  # noqa: E402
from repro.sparse.formats import (  # noqa: E402
    BELL,
    CSR,
    ELL,
    SELL,
    bell_from_dense,
    bell_to_dense,
    csr_from_dense,
    csr_to_dense,
    ell_from_dense,
    ell_to_dense,
    sell_from_dense,
    sell_to_dense,
)
from repro.sparse.spmv import (  # noqa: E402  (pure-jnp oracles)
    spmv_bell as _ref_bell,
    spmv_csr as _ref_csr,
    spmv_ell as _ref_ell,
    spmv_sell as _ref_sell,
)

_VAL_B, _IDX_B = 4.0, 4.0  # fp32 values, int32 indices


# --- CSR -------------------------------------------------------------------


def _csr_prepare(dense: np.ndarray, schedule: KernelSchedule) -> CSR:
    return csr_from_dense(np.asarray(dense))


def _csr_spmv(mat: CSR, x, schedule: KernelSchedule, *, interpret: bool = True):
    n_rows, _ = mat.shape
    nt = schedule.nnz_tile
    nnz = mat.data.shape[0]
    nnz_pad = ceil_to(max(nnz, 1), nt)
    data = pad_axis(np.asarray(mat.data), 0, nnz_pad)
    indices = pad_axis(np.asarray(mat.indices), 0, nnz_pad)
    row_ids = pad_axis(np.asarray(mat.row_ids), 0, nnz_pad, fill=n_rows)
    y = csr_spmv_pallas(
        jnp.asarray(data),
        jnp.asarray(indices),
        jnp.asarray(row_ids),
        jnp.asarray(x),
        n_rows,
        schedule,
        interpret=interpret,
    )
    return y[:n_rows]


def _csr_footprint(stats: MatrixStats, schedule: KernelSchedule) -> KernelFootprint:
    n, m, nnz = stats.n_rows, stats.n_cols, stats.nnz
    nt = schedule.nnz_tile
    x_bytes, y_bytes = m * _VAL_B, n * _VAL_B
    nnz_pad = ceil_to(max(nnz, 1), nt)
    stored = float(nnz_pad)
    # data + cols + row_ids + indptr + x + y
    hbm = stored * (_VAL_B + 2 * _IDX_B) + (n + 1) * _IDX_B + x_bytes + y_bytes
    steps = nnz_pad / nt
    tile_b = nt * (_VAL_B + 2 * _IDX_B)
    vmem = 2 * tile_b + x_bytes + (n + 1) * _VAL_B  # y resident too
    return KernelFootprint(
        2.0 * nnz, 2 * stored, hbm, stored, stored, steps, 0.0, vmem,
        vmem <= VMEM_BYTES and schedule.x_residency == "vmem",
        note="" if schedule.x_residency == "vmem"
        else "CSR requires VMEM-resident X and Y on TPU",
    )


# --- ELL -------------------------------------------------------------------


def _ell_prepare(dense: np.ndarray, schedule: KernelSchedule) -> ELL:
    dense = np.asarray(dense)
    n_rows, _ = dense.shape
    rpb, nt = schedule.rows_per_block, schedule.nnz_tile
    counts_max = int((dense != 0).sum(axis=1).max(initial=0))
    width = ceil_to(max(counts_max, 1), nt)
    check_storage_bytes(ceil_to(n_rows, rpb) * width * 8, "ELL")
    mat = ell_from_dense(dense, min_width=width)
    data = pad_axis(np.asarray(mat.data), 0, ceil_to(n_rows, rpb))
    cols = pad_axis(np.asarray(mat.cols), 0, ceil_to(n_rows, rpb))
    return ELL(jnp.asarray(data), jnp.asarray(cols), shape=mat.shape)


def _ell_spmv(mat: ELL, x, schedule: KernelSchedule, *, interpret: bool = True):
    n_rows, _ = mat.shape
    rpb, nt = schedule.rows_per_block, schedule.nnz_tile
    R, W = mat.data.shape
    if R % rpb or W % nt:
        raise InfeasibleConfig(
            f"ELL planes ({R},{W}) not aligned to schedule ({rpb},{nt}); "
            "use prepare() with the same schedule"
        )
    y = ell_spmv_pallas(mat.data, mat.cols, jnp.asarray(x), schedule, interpret=interpret)
    return y[:n_rows]


def _ell_footprint(stats: MatrixStats, schedule: KernelSchedule) -> KernelFootprint:
    n, m, nnz = stats.n_rows, stats.n_cols, stats.nnz
    rpb, nt = schedule.rows_per_block, schedule.nnz_tile
    x_bytes, y_bytes = m * _VAL_B, n * _VAL_B
    width = ceil_to(max(stats.max_nnz, 1), nt)
    rows = ceil_to(n, rpb)
    stored = float(rows) * width
    hbm = stored * (_VAL_B + _IDX_B) + x_bytes + y_bytes
    steps = (rows / rpb) * (width / nt)
    tile_b = rpb * nt * (_VAL_B + _IDX_B)
    vmem = 2 * tile_b + (x_bytes if schedule.x_residency == "vmem" else 0) + rpb * _VAL_B
    return KernelFootprint(
        2.0 * nnz, 2 * stored, hbm, stored, 0.0, steps, 0.0, vmem,
        vmem <= VMEM_BYTES and schedule.x_residency == "vmem",
        note="" if schedule.x_residency == "vmem"
        else "ELL requires VMEM-resident X on TPU",
    )


# --- BELL ------------------------------------------------------------------


def _bell_prepare(dense: np.ndarray, schedule: KernelSchedule) -> BELL:
    dense = np.asarray(dense)
    n_rows, n_cols = dense.shape
    br = min(schedule.rows_per_block, 256)
    nbr = ceil_to(n_rows, br) // br
    # upper-bound occupancy estimate before materializing
    occ_bound = min((dense != 0).sum(), nbr * (ceil_to(n_cols, LANE) // LANE))
    check_storage_bytes(int(occ_bound) * br * LANE * 8 // max(nbr, 1) * nbr, "BELL")
    return bell_from_dense(dense, br=br, bc=LANE)


def _bell_spmv(mat: BELL, x, schedule: KernelSchedule, *, interpret: bool = True):
    n_rows, n_cols = mat.shape
    x = jnp.asarray(x)
    xp = jnp.zeros(ceil_to(n_cols, mat.bc), x.dtype).at[:n_cols].set(x)
    x_panels = xp.reshape(-1, mat.bc)
    y = bell_spmv_pallas(mat.data, mat.block_cols, x_panels, schedule, interpret=interpret)
    return y.reshape(-1)[:n_rows]


def _bell_footprint(stats: MatrixStats, schedule: KernelSchedule) -> KernelFootprint:
    n, m, nnz = stats.n_rows, stats.n_cols, stats.nnz
    x_bytes, y_bytes = m * _VAL_B, n * _VAL_B
    br, bc = min(schedule.rows_per_block, 256), LANE
    n_blocks, max_blocks = stats.block_occupancy(br, bc)
    nbr = ceil_to(n, br) // br
    stored_blocks = float(nbr) * max(max_blocks, 1)
    stored = stored_blocks * br * bc
    x_traffic = (
        stored_blocks * bc * _VAL_B  # streamed panels (scalar-prefetch DMA)
        if schedule.x_residency == "stream"
        else x_bytes
    )
    hbm = stored * _VAL_B + stored_blocks * _IDX_B + x_traffic + y_bytes
    steps = stored_blocks
    tile_b = br * bc * _VAL_B + bc * _VAL_B
    vmem = 2 * tile_b + br * _VAL_B + (x_bytes if schedule.x_residency == "vmem" else 0)
    return KernelFootprint(
        2.0 * nnz, 2 * stored, hbm, 0.0, 0.0, steps, 1.0, vmem,
        vmem <= VMEM_BYTES,
    )


# --- SELL ------------------------------------------------------------------


def _sell_prepare(dense: np.ndarray, schedule: KernelSchedule) -> SELL:
    return sell_from_dense(
        np.asarray(dense), C=schedule.rows_per_block, q=schedule.nnz_tile
    )


def _sell_spmv(mat: SELL, x, schedule: KernelSchedule, *, interpret: bool = True):
    n_rows, _ = mat.shape
    nt = schedule.nnz_tile
    C = mat.C
    blk = nt * C
    sp = np.asarray(mat.slice_ptr)
    sw = np.asarray(mat.slice_width)
    if mat.data.shape[0] % blk or (sp % blk).any() or (sw % nt).any():
        raise InfeasibleConfig(
            f"SELL storage quantum mismatch with nnz_tile={nt}; "
            "convert with prepare(..., schedule) so widths are nt-aligned"
        )
    width_tiles = (sw // nt).astype(np.int32)
    tile_ptr = (sp[:-1] // blk).astype(np.int32)
    y = sell_spmv_pallas(
        mat.data,
        mat.cols,
        jnp.asarray(tile_ptr),
        jnp.asarray(width_tiles),
        jnp.asarray(x),
        n_slices=mat.n_slices,
        C=C,
        max_width_tiles=int(width_tiles.max(initial=1)),
        schedule=schedule,
        interpret=interpret,
    )
    return y.reshape(-1)[:n_rows]


def _sell_footprint(stats: MatrixStats, schedule: KernelSchedule) -> KernelFootprint:
    n, m, nnz = stats.n_rows, stats.n_cols, stats.nnz
    rpb, nt = schedule.rows_per_block, schedule.nnz_tile
    x_bytes, y_bytes = m * _VAL_B, n * _VAL_B
    C = rpb
    total, maxw = stats.sell_storage(C, nt)
    n_slices = (n + C - 1) // C
    stored = float(total)
    hbm = stored * (_VAL_B + _IDX_B) + x_bytes + y_bytes
    steps = n_slices * (maxw / nt)  # grid includes masked tiles
    tile_b = nt * C * (_VAL_B + _IDX_B)
    vmem = 2 * tile_b + (x_bytes if schedule.x_residency == "vmem" else 0) + C * _VAL_B
    return KernelFootprint(
        2.0 * nnz, 2 * stored, hbm, stored, 0.0, steps, 0.0, vmem,
        vmem <= VMEM_BYTES and schedule.x_residency == "vmem",
        note="" if schedule.x_residency == "vmem"
        else "SELL requires VMEM-resident X on TPU",
    )


register_format(FormatSpec(
    name="csr",
    container=CSR,
    from_dense=csr_from_dense,
    to_dense=csr_to_dense,
    prepare=_csr_prepare,
    spmv=_csr_spmv,
    reference=_ref_csr,
    footprint=_csr_footprint,
    priority=0,
    description="Compressed Sparse Row (flat segmented-sum kernel)",
))
register_format(FormatSpec(
    name="ell",
    container=ELL,
    from_dense=ell_from_dense,
    to_dense=ell_to_dense,
    prepare=_ell_prepare,
    spmv=_ell_spmv,
    reference=_ref_ell,
    footprint=_ell_footprint,
    priority=10,
    description="ELLPACK dense value/column planes",
))
register_format(FormatSpec(
    name="bell",
    container=BELL,
    from_dense=bell_from_dense,
    to_dense=bell_to_dense,
    prepare=_bell_prepare,
    spmv=_bell_spmv,
    reference=_ref_bell,
    footprint=_bell_footprint,
    priority=20,
    description="Blocked ELL over 8x128 MXU tiles",
))
register_format(FormatSpec(
    name="sell",
    container=SELL,
    from_dense=sell_from_dense,
    to_dense=sell_to_dense,
    prepare=_sell_prepare,
    spmv=_sell_spmv,
    reference=_ref_sell,
    footprint=_sell_footprint,
    priority=30,
    description="Sliced ELL (SELL-C-q) ragged storage",
))
