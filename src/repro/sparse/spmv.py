"""Pure-jnp SpMV per format — the numerical oracles for the Pallas kernels.

Every function computes ``y = A @ x`` for its format and matches the dense
product to float tolerance. These are also the measured implementations the
dataset harness times on CPU (paper §6.3 protocol) — they are written to be
jit-compiled once per (format, shape).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.sparse.formats import BELL, CSR, ELL, SELL, SparseFormat


@functools.partial(jax.jit, static_argnames=("n_rows",))
def _csr_impl(data, indices, row_ids, x, *, n_rows):
    prods = data * x[indices]
    return jax.ops.segment_sum(prods, row_ids, num_segments=n_rows)


def spmv_csr(mat: CSR, x: jax.Array) -> jax.Array:
    """CSR SpMV via flat gather + segmented sum (TPU-idiomatic CSR)."""
    return _csr_impl(mat.data, mat.indices, mat.row_ids, x, n_rows=mat.shape[0])


@jax.jit
def _ell_impl(data, cols, x):
    return jnp.sum(data * x[cols], axis=1)


def spmv_ell(mat: ELL, x: jax.Array) -> jax.Array:
    return _ell_impl(mat.data, mat.cols, x)


@functools.partial(jax.jit, static_argnames=("bc", "n_rows"))
def _bell_impl(data, block_cols, x, *, bc, n_rows):
    n_cols_pad = ((x.shape[0] + bc - 1) // bc) * bc
    xp = jnp.zeros(n_cols_pad, x.dtype).at[: x.shape[0]].set(x)
    xseg = xp.reshape(-1, bc)[block_cols]  # (nbr, maxb, bc)
    y = jnp.einsum("ijrc,ijc->ir", data, xseg)  # block matvec on MXU shapes
    return y.reshape(-1)[:n_rows]


def spmv_bell(mat: BELL, x: jax.Array) -> jax.Array:
    return _bell_impl(mat.data, mat.block_cols, x, bc=mat.bc, n_rows=mat.shape[0])


@functools.partial(jax.jit, static_argnames=("n_rows",))
def _sell_impl(data, cols, row_ids, x, *, n_rows):
    prods = data * x[cols]
    # padding slots carry row_id == n_rows -> dropped by the extra segment
    return jax.ops.segment_sum(prods, row_ids, num_segments=n_rows + 1)[:n_rows]


def spmv_sell(mat: SELL, x: jax.Array) -> jax.Array:
    return _sell_impl(mat.data, mat.cols, mat.row_ids, x, n_rows=mat.shape[0])


_DISPATCH = {CSR: spmv_csr, ELL: spmv_ell, BELL: spmv_bell, SELL: spmv_sell}


def spmv(mat: SparseFormat, x: jax.Array) -> jax.Array:
    """Format-dispatching SpMV.

    Routed through the registry so an overwritten or plugin spec's
    ``reference`` is honored; the static table only serves containers the
    registry does not know (e.g. a seed format that was unregistered)."""
    from repro.sparse.registry import spec_for

    try:
        spec = spec_for(mat)
    except TypeError:
        fn = _DISPATCH.get(type(mat))
        if fn is None:
            raise
        return fn(mat, x)
    return spec.reference(mat, x)


@jax.jit
def _ell_spmm_impl(data, cols, X):
    # X: (n_cols, k). Gather rows of X per stored nonzero, contract width.
    Xg = X[cols]  # (n_rows, width, k)
    return jnp.einsum("rw,rwk->rk", data, Xg)


def spmm_ell(mat: ELL, X: jax.Array) -> jax.Array:
    """ELL SpMM (multi-vector SpMV) — the MoE-dispatch-shaped variant."""
    return _ell_spmm_impl(mat.data, mat.cols, X)
