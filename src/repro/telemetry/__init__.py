"""Telemetry + adaptive reoptimization: the predict→measure→relearn loop.

Auto-SpMV's premise is that the classifier is only as good as its dataset of
measured outcomes (§5.4, §6.1) — yet a cached plan, once wrong, would be
served forever. This package turns every served request into a labelled
measurement and every measurement into a better plan:

* ``recorder``  — per-request ``MeasurementRecord``s with EWMA/percentile
  aggregation per (bucket, objective, format) arm and restart-surviving
  JSONL persistence;
* ``adaptive``  — a UCB bandit layered over the classifier's prior, with a
  bounded exploration budget and a sustained-drift detector that evicts
  stale ``TuningCache`` entries;
* ``feedback``  — exports telemetry as §5.4 ``TuningRecord``s and drives
  incremental refit of the format classifier through ``ml/model_zoo``.

Wiring: ``AutoSpmvSession`` (record/consult hooks + cache invalidation),
``SpmvServer`` (timed execution + observe), ``launch/serve.py``
(``--telemetry`` / ``--telemetry-log`` / ``--adaptive``).
"""

from repro.telemetry.adaptive import (
    AdaptiveConfig,
    AdaptiveFormatSelector,
    ArmState,
    CellState,
    block_arm_bucket,
    phase_arm_bucket,
)
from repro.telemetry.feedback import (
    FeedbackConfig,
    FeedbackLoop,
    telemetry_records,
)
from repro.telemetry.recorder import (
    ArmAggregate,
    MeasurementRecord,
    TelemetryRecorder,
)

__all__ = [
    "AdaptiveConfig",
    "AdaptiveFormatSelector",
    "ArmAggregate",
    "ArmState",
    "CellState",
    "FeedbackConfig",
    "FeedbackLoop",
    "MeasurementRecord",
    "TelemetryRecorder",
    "block_arm_bucket",
    "phase_arm_bucket",
    "telemetry_records",
]
