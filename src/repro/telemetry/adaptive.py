"""UCB bandit over the classifier's prior + model-drift cache invalidation.

The serving path (PR 1) trusts the format classifier forever: a mispredicted
plan is cached and served until the process dies. This module closes the
loop the way adaptive SpMV selection does online (Li et al.,
arXiv:2006.16767): the *cached plan is the incumbent arm*, alternate formats
receive a bounded exploration budget, and measured wall times decide.

Two signals can evict a stale plan:

* **arm regret** — a challenger format's measured mean beats the incumbent's
  EWMA by more than ``drift_threshold`` (relative), sustained for
  ``drift_window`` consecutive incumbent observations;
* **model drift** — the incumbent's measured wall time exceeds the model's
  own latency estimate by more than ``drift_threshold``, sustained the same
  way (the §5.3 overhead/gain arithmetic is wrong for this bucket).

On invalidation the selector *promotes* the measured-best format to
incumbent (measurements outrank the model) and the caller drops the
``TuningCache`` entries so the next request re-plans — against predictors
the feedback loop may meanwhile have refit.

All rewards are measured wall times, minimized regardless of the tuning
objective: energy/power are not observable host-side, but every objective's
plan still has to be *executed*, so latency is the one universally measured
signal (the recorder keeps the per-objective aggregation for the dataset
export).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

from repro.obs.metrics import get_metrics
from repro.utils.logging import get_logger
from repro.utils.timing import RollingStats

log = get_logger("telemetry.adaptive")

# fleet-visible bandit economics: how many pulls left the incumbent, and how
# often measurement overturned the model's plan
_M_EXPLORE = get_metrics().counter("spmv_bandit_explore_total")
_M_EXPLOIT = get_metrics().counter("spmv_bandit_exploit_total")
_M_PROMOTIONS = get_metrics().counter("spmv_drift_promotions_total")

CellKey = tuple[str, str]  # (bucket, objective)


def block_arm_bucket(bucket: str, index: int, n_blocks: int) -> str:
    """Bandit cell key for one row block of a partitioned plan.

    Partitioned serving (repro.partition + ``AutoSpmvSession``'s
    ``serve_partitioned``/``observe_partitioned``) scopes every bandit cell
    to a block, so each (block, format) pair is its own arm: block 2 of a
    heterogeneous matrix can drift to SELL while block 0 keeps BELL, and a
    sustained-drift eviction re-plans the composite without touching the
    monolithic cells for the same feature bucket. ``n_blocks`` is part of
    the key — a 4-way and an 8-way split of the same bucket measure
    different row populations and must not share statistics."""
    return f"{bucket}#blk{index}of{n_blocks}"


def phase_arm_bucket(bucket: str, phase: int, n_phases: int) -> str:
    """Bandit cell key for one frontier-density phase of an iterative solve.

    The SpMV↔SpMSpV policy (``repro.solvers.adaptive``) bins each
    iteration by input-vector density and treats the two execution paths
    as arms *within that phase*: a webgraph family can learn that phase 0
    (frontier under 2% dense) belongs to SpMSpV while phase 5 (near-dense)
    belongs to SpMV, with the crossover point emerging from measurements
    instead of a hardcoded threshold. ``n_phases`` is part of the key for
    the same reason as ``block_arm_bucket``'s ``n_blocks``: re-binning the
    density axis changes what each phase measures."""
    return f"{bucket}#ph{phase}of{n_phases}"


@dataclass
class AdaptiveConfig:
    exploration_bonus: float = 0.5  # UCB width, in units of the best arm's mean
    exploration_fraction: float = 0.25  # max fraction of pulls spent off-incumbent
    prior_weight: int = 2  # pseudo-pulls crediting the model's estimate to the incumbent
    min_challenger_pulls: int = 2  # observations before a challenger can evict
    drift_window: int = 4  # consecutive drifted incumbent observations to invalidate
    drift_threshold: float = 0.25  # relative margin for both drift signals
    window: int = 64  # RollingStats window per arm
    ewma_alpha: float = 0.3


@dataclass
class ArmState:
    stats: RollingStats  # LOCALLY MEASURED samples only — neither priors nor
    # absorbed peer evidence ever contaminate it (exported fleet shards carry
    # exactly these pulls, so fleet-merged counts stay echo-free)
    pulls: int = 0  # real local observations
    prior_pulls: int = 0  # pseudo-pull credit from the model's estimate
    prior_value: float | None = None  # the estimate itself (UCB value until
    # the first real pull; model scale may differ from measured scale, so it
    # must never be averaged into the measured mean)
    disabled: bool = False  # conversion infeasible for this cell: never pick
    absorbed_pulls: int = 0  # peer-measured pulls installed by absorb()
    absorbed_value: float | None = None  # pull-weighted peer mean (measured
    # scale, same clock as stats — peers run the same serving path)

    @property
    def n_eff(self) -> int:
        return self.pulls + self.prior_pulls + self.absorbed_pulls

    @property
    def measured_pulls(self) -> int:
        """Local + absorbed peer observations (prior pseudo-pulls excluded)."""
        return self.pulls + self.absorbed_pulls

    def measured_mean(self) -> float | None:
        """Pull-weighted mean over local + absorbed measurements."""
        n, total = 0, 0.0
        if self.pulls:
            n += self.pulls
            total += self.stats.mean * self.pulls
        if self.absorbed_pulls and self.absorbed_value is not None:
            n += self.absorbed_pulls
            total += self.absorbed_value * self.absorbed_pulls
        return total / n if n else None

    def value(self) -> float | None:
        """Mean for UCB scoring: measured when available, else the prior."""
        measured = self.measured_mean()
        return measured if measured is not None else self.prior_value


@dataclass
class CellState:
    """Bandit state for one (bucket, objective) plan-cache cell."""

    incumbent: str
    arms: dict[str, ArmState] = field(default_factory=dict)
    total_pulls: int = 0
    exploration_pulls: int = 0
    drift_strikes: int = 0
    model_drift_strikes: int = 0  # measured > modeled subset of the strikes:
    # high while arm-regret strikes stay low means the cost-model *scale* is
    # off, not the plan — the signal that it is time to recalibrate
    # (``CalibratedCostModel`` puts predicted_s on the measured scale, which
    # collapses these without touching real plan regressions)
    promoted: bool = False  # incumbent came from measurement, not the model
    invalidations: int = 0


class AdaptiveFormatSelector:
    """Per-cell UCB1 with an incumbent prior and a sustained-drift evictor."""

    def __init__(self, config: AdaptiveConfig | None = None):
        self.config = config or AdaptiveConfig()
        self._cells: dict[CellKey, CellState] = {}

    # ------------------------------------------------------------- internals
    def _cell(
        self, bucket: str, objective: str, incumbent: str, prior_value: float | None
    ) -> CellState:
        key = (bucket, objective)
        cell = self._cells.get(key)
        if cell is None:
            cell = CellState(incumbent=incumbent)
            self._cells[key] = cell
            self._seed_prior(cell, incumbent, prior_value)
        elif not cell.promoted and incumbent != cell.incumbent:
            # the plan changed under us (cache invalidation + re-plan, or a
            # refit predictor): adopt it and credit its estimate
            cell.incumbent = incumbent
            cell.drift_strikes = 0
            self._seed_prior(cell, incumbent, prior_value)
        elif cell.promoted and incumbent == cell.incumbent:
            cell.promoted = False  # the model caught up with the measurements
        return cell

    def _seed_prior(self, cell: CellState, fmt: str, prior_value: float | None) -> None:
        arm = self._arm(cell, fmt)
        if prior_value is None or prior_value <= 0 or arm.prior_pulls:
            return
        arm.prior_value = float(prior_value)
        arm.prior_pulls = self.config.prior_weight

    def _arm(self, cell: CellState, fmt: str) -> ArmState:
        arm = cell.arms.get(fmt)
        if arm is None:
            arm = ArmState(RollingStats(self.config.window, self.config.ewma_alpha))
            cell.arms[fmt] = arm
        return arm

    @staticmethod
    def _best_measured(cell: CellState, min_pulls: int = 1) -> str | None:
        cands = [
            (arm.measured_mean(), fmt)
            for fmt, arm in cell.arms.items()
            if arm.measured_pulls >= min_pulls
            and not arm.disabled
            and arm.measured_mean() is not None
        ]
        return min(cands)[1] if cands else None

    def disable(
        self, bucket: str, objective: str, fmt: str, *, fallback: str | None = None
    ) -> None:
        """Mark a format unservable for this cell (conversion infeasible):
        ``choose`` will never pick it again, so a failed exploration is paid
        once per cell, not once per request. If the *incumbent* itself is
        disabled (the cached plan was infeasible), the measured-best arm —
        or ``fallback``, the format the caller actually served (defaulting
        to the registry's default format) — takes over, so a budget-closed
        ``choose`` never returns an unservable arm."""
        if fallback is None:
            from repro.sparse.registry import default_format

            fallback = default_format()
        cell = self._cells.get((bucket, objective))
        if cell is None:
            return
        self._arm(cell, fmt).disabled = True
        if fmt == cell.incumbent:
            cell.incumbent = self._best_measured(cell) or fallback
            cell.promoted = True
            cell.drift_strikes = 0

    # ----------------------------------------------------------------- choose
    def choose(
        self,
        bucket: str,
        objective: str,
        incumbent: str,
        candidates: tuple[str, ...],
        *,
        prior_value: float | None = None,
    ) -> tuple[str, bool]:
        """Pick the format to serve this request; returns (fmt, exploratory).

        ``incumbent`` is the cached plan's format, ``prior_value`` the
        model's latency estimate for it (seeds the incumbent arm so the
        classifier's opinion is the starting point, not ignored).
        """
        cfg = self.config
        cell = self._cell(bucket, objective, incumbent, prior_value)
        # bounded exploration: off-incumbent pulls may not exceed the budget
        budget_open = cell.exploration_pulls < max(
            cfg.exploration_fraction * (cell.total_pulls + 1), 1.0
        )
        if not budget_open and not self._arm(cell, cell.incumbent).disabled:
            _M_EXPLOIT.inc()
            return cell.incumbent, False
        best_ref = None
        for fmt in candidates:
            v = self._arm(cell, fmt).value()
            if v is not None and (best_ref is None or v < best_ref):
                best_ref = v
        ref = best_ref if best_ref and best_ref > 0 else 1.0
        ln_n = math.log(cell.total_pulls + 1.0 + len(candidates))
        best_fmt, best_score = None, -math.inf
        for fmt in candidates:
            arm = self._arm(cell, fmt)
            if arm.disabled:
                continue
            v = arm.value()
            if v is None:
                # untried, prior-less arm: forced (budget-gated) pull —
                # unless the budget is closed and we are only here because
                # the incumbent is unservable
                score = math.inf if budget_open else -math.inf
            else:
                width = cfg.exploration_bonus * ref * math.sqrt(ln_n / arm.n_eff)
                score = -v + width
            if score > best_score:
                best_fmt, best_score = fmt, score
        if best_fmt is None:  # everything disabled: serve the incumbent as-is
            best_fmt = cell.incumbent
        exploratory = best_fmt != cell.incumbent
        (_M_EXPLORE if exploratory else _M_EXPLOIT).inc()
        return best_fmt, exploratory

    # ----------------------------------------------------------------- update
    def update(
        self,
        bucket: str,
        objective: str,
        fmt: str,
        measured_s: float,
        *,
        predicted_s: float | None = None,
    ) -> None:
        """Fold one measured outcome into the bandit state."""
        cell = self._cells.get((bucket, objective))
        if cell is None:  # observation without a prior choose() — adopt it
            cell = self._cell(bucket, objective, fmt, predicted_s)
        arm = self._arm(cell, fmt)
        arm.stats.add(float(measured_s))
        arm.pulls += 1
        cell.total_pulls += 1
        if fmt != cell.incumbent:
            cell.exploration_pulls += 1
            return
        # drift detection runs on incumbent observations only
        cfg = self.config
        model_drift = (
            predicted_s is not None
            and predicted_s > 0
            and measured_s > predicted_s * (1.0 + cfg.drift_threshold)
        )
        cell.model_drift_strikes = cell.model_drift_strikes + 1 if model_drift else 0
        drifted = model_drift
        inc_ewma = arm.stats.ewma if arm.stats.ewma is not None else arm.stats.mean
        for other_fmt, other in cell.arms.items():
            if other_fmt == fmt or other.pulls < cfg.min_challenger_pulls:
                continue
            drifted |= other.stats.mean * (1.0 + cfg.drift_threshold) < inc_ewma
        cell.drift_strikes = cell.drift_strikes + 1 if drifted else 0

    # ----------------------------------------------------------------- review
    def review(self, bucket: str, objective: str) -> str | None:
        """Return the measured-best challenger if the incumbent should be
        evicted (sustained drift), else None. Idempotent until ``promote``.

        Eviction requires the challenger to beat the incumbent's measured
        EWMA by the full ``drift_threshold`` margin: model-drift strikes
        alone (e.g. a wrong cost-model scale, which makes every measurement
        exceed its estimate) or a noise-level difference between near-equal
        formats must never thrash the cache."""
        cell = self._cells.get((bucket, objective))
        if cell is None or cell.drift_strikes < self.config.drift_window:
            return None
        challenger = self._best_measured(cell, self.config.min_challenger_pulls)
        inc = cell.arms.get(cell.incumbent)
        inc_val = None
        if inc is not None and inc.pulls:
            inc_val = inc.stats.ewma if inc.stats.ewma is not None else inc.stats.mean
        margin_beaten = (
            challenger is not None
            and challenger != cell.incumbent
            and inc_val is not None
            and cell.arms[challenger].measured_mean()
            * (1.0 + self.config.drift_threshold)
            < inc_val
        )
        if not margin_beaten:
            cell.drift_strikes = 0
            return None
        return challenger

    def promote(self, bucket: str, objective: str, fmt: str) -> None:
        """Install the measured-best format as incumbent after an eviction."""
        cell = self._cells.get((bucket, objective))
        if cell is None:
            return
        log.info(
            "promoting %s over %s for bucket=%s objective=%s after %d strikes",
            fmt,
            cell.incumbent,
            bucket,
            objective,
            cell.drift_strikes,
        )
        cell.incumbent = fmt
        cell.promoted = True
        cell.drift_strikes = 0
        cell.exploration_pulls = 0
        cell.invalidations += 1
        _M_PROMOTIONS.inc()

    # ------------------------------------------------------------- fleet sync
    def absorb(
        self, bucket: str, objective: str, fmt: str, *, pulls: int, value: float
    ) -> None:
        """Install peer-measured evidence for one arm (idempotent setter).

        ``pulls``/``value`` are the *cumulative* totals over the current
        peer shard set for this arm — ``FleetSync`` recomputes them from
        scratch each sync, so absorbing the same shards twice changes
        nothing and a vanished peer's evidence ages out with its shard.
        Peer evidence lands in ``absorbed_*``, never in the local ``stats``:
        exported shards carry only locally-measured pulls, which keeps
        fleet-merged pull counts equal to the per-instance sum (no echo
        amplification through sync round-trips)."""
        if pulls <= 0 or value is None or value <= 0:
            return
        key = (bucket, objective)
        cell = self._cells.get(key)
        if cell is None:
            # a bucket this instance has never served: adopt the peer's arm
            # as a provisional incumbent until a local plan claims the cell
            cell = CellState(incumbent=fmt)
            self._cells[key] = cell
        arm = self._arm(cell, fmt)
        arm.absorbed_pulls = int(pulls)
        arm.absorbed_value = float(value)

    def reconcile(self, bucket: str, objective: str) -> str | None:
        """Promote the measured-best arm (local + absorbed) over the
        incumbent when it wins by the drift margin — ``review``'s fleet
        counterpart, minus the strike counting: peer evidence arrives in
        batches of pulls, not one incumbent observation at a time, so a
        single sync can carry a whole drift window's worth of proof."""
        cell = self._cells.get((bucket, objective))
        if cell is None:
            return None
        best = self._best_measured(cell, self.config.min_challenger_pulls)
        if best is None or best == cell.incumbent:
            return None
        inc = cell.arms.get(cell.incumbent)
        inc_val = inc.measured_mean() if inc is not None else None
        if inc_val is None and inc is not None:
            inc_val = inc.prior_value
        best_val = cell.arms[best].measured_mean()
        if inc_val is None or best_val * (1.0 + self.config.drift_threshold) < inc_val:
            self.promote(bucket, objective, best)
            return best
        return None

    # ---------------------------------------------------------------- queries
    def cells(self) -> dict[CellKey, CellState]:
        """Live cell map (posterior export reads arms/incumbents off it)."""
        return dict(self._cells)

    def incumbent(self, bucket: str, objective: str) -> str | None:
        cell = self._cells.get((bucket, objective))
        return cell.incumbent if cell is not None else None

    def warm_start(self, recorder) -> int:
        """Seed arm statistics from a replayed ``TelemetryRecorder`` so a
        restarted server does not re-pay exploration it already logged."""
        seeded = 0
        for (bucket, objective, fmt), agg in recorder.arms().items():
            cell = self._cells.get((bucket, objective))
            if cell is None:
                cell = CellState(incumbent=fmt)
                self._cells[(bucket, objective)] = cell
            arm = self._arm(cell, fmt)
            if arm.pulls:
                continue
            arm.stats.add(agg.stats.mean)
            arm.pulls += 1
            cell.total_pulls += 1
            seeded += 1
        return seeded

    def summary(self) -> dict:
        return {
            "cells": len(self._cells),
            "pulls": sum(c.total_pulls for c in self._cells.values()),
            "exploration_pulls": sum(
                c.exploration_pulls for c in self._cells.values()
            ),
            "promotions": sum(c.invalidations for c in self._cells.values()),
            "promoted_cells": sum(1 for c in self._cells.values() if c.promoted),
            "model_drift_strikes": sum(
                c.model_drift_strikes for c in self._cells.values()
            ),
            "absorbed_pulls": sum(
                a.absorbed_pulls
                for c in self._cells.values()
                for a in c.arms.values()
            ),
        }
