"""Telemetry → dataset → refit: the relearn side of the feedback loop.

The paper's pipeline (§5.4, §6.1) improves its predictors by collecting
labelled (matrix × config) outcomes offline. In serving, the telemetry
recorder produces exactly that label material for free: every arm aggregate
is a measured outcome of one (features, format, schedule) cell. This module

1. exports arm aggregates as ``TuningRecord``s (``source="telemetry"``,
   unmeasured objectives NaN — the same convention ``measured_cpu`` records
   already use) and appends them to a ``TuningDataset``, so the offline
   pipeline can retrain from fleet traffic;
2. drives *incremental refit* of the format classifier: per (bucket,
   objective) cell with enough measured coverage, the measured-best format
   becomes a training label, merged with the base dataset's labels, and the
   classifier is refit through the same ``ml/model_zoo`` path the paper's
   offline stage uses.

``FeedbackLoop.maybe_refit`` gates on new-observation count so a server can
call it after every batch at negligible cost.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from pathlib import Path

import numpy as np

from repro.core.dataset import TuningDataset, TuningRecord
from repro.core.features import SparsityFeatures
from repro.core.predictor import OBJECTIVES
from repro.core.tuning_space import TuningConfig
from repro.kernels.common import DEFAULT_SCHEDULE, KernelSchedule
from repro.telemetry.recorder import TelemetryRecorder
from repro.utils.logging import get_logger

log = get_logger("telemetry.feedback")


@dataclass
class FeedbackConfig:
    min_pulls: int = 2  # observations an arm needs before it can label
    min_formats: int = 2  # measured formats a cell needs to be informative
    label_weight: int = 3  # telemetry labels repeated this often vs base labels
    refit_every: int = 16  # new observations between maybe_refit() refits


def _schedule_of(raw: dict) -> KernelSchedule:
    return KernelSchedule(**raw) if raw else DEFAULT_SCHEDULE


def telemetry_records(
    recorder: TelemetryRecorder, *, min_pulls: int = 1
) -> list[TuningRecord]:
    """Arm aggregates as §5.4 dataset rows (latency measured, rest NaN)."""
    out: list[TuningRecord] = []
    for (bucket, objective, fmt), agg in sorted(recorder.arms().items()):
        if agg.stats.count < min_pulls:
            continue
        feats_raw = recorder.bucket_features(bucket)
        if not feats_raw:
            continue  # replayed from a log that predates feature capture
        out.append(
            TuningRecord(
                matrix=f"telemetry/{bucket}",
                features=SparsityFeatures(**feats_raw),
                config=TuningConfig(fmt, _schedule_of(agg.schedule)),
                latency=agg.stats.mean,
                energy=math.nan,
                power=math.nan,
                efficiency=math.nan,
                feasible=True,
                source=f"telemetry_{objective}",
            )
        )
    return out


@dataclass
class FeedbackLoop:
    """Glues a recorder to a predictor + dataset for online relearning."""

    recorder: TelemetryRecorder
    base_dataset: TuningDataset | None = None
    dataset_path: str | Path | None = None
    config: FeedbackConfig = field(default_factory=FeedbackConfig)
    refits: int = 0
    _last_refit_obs: int = field(default=0, repr=False)

    # ------------------------------------------------------------- dataset IO
    def export_dataset(self, dataset: TuningDataset | None = None) -> TuningDataset:
        """Append current telemetry records to ``dataset`` (or a fresh one);
        earlier telemetry rows for the same cells are superseded in place."""
        ds = dataset if dataset is not None else TuningDataset(meta={"source": "telemetry"})
        fresh = telemetry_records(self.recorder, min_pulls=1)
        fresh_keys = {(r.matrix, r.config.fmt, r.source) for r in fresh}
        ds.records = [
            r
            for r in ds.records
            if not (
                r.source.startswith("telemetry")
                and (r.matrix, r.config.fmt, r.source) in fresh_keys
            )
        ]
        ds.records.extend(fresh)
        ds.meta["telemetry_observations"] = self.recorder.total_observations()
        if self.dataset_path is not None:
            ds.save(self.dataset_path)
            log.info(
                "appended %d telemetry records -> %s (%d total)",
                len(fresh),
                self.dataset_path,
                len(ds),
            )
        return ds

    # ----------------------------------------------------------------- labels
    def _measured_labels(self, objective: str) -> tuple[list[SparsityFeatures], list[str]]:
        cfg = self.config
        by_bucket: dict[str, dict[str, float]] = {}
        for (bucket, obj, fmt), agg in self.recorder.arms().items():
            if obj != objective or agg.stats.count < cfg.min_pulls:
                continue
            by_bucket.setdefault(bucket, {})[fmt] = agg.stats.mean
        feats, labels = [], []
        for bucket, means in by_bucket.items():
            raw = self.recorder.bucket_features(bucket)
            if len(means) < cfg.min_formats or not raw:
                continue
            feats.append(SparsityFeatures(**raw))
            labels.append(min(means, key=means.get))
        return feats, labels

    @staticmethod
    def _base_labels(
        dataset: TuningDataset, objective: str
    ) -> tuple[list[SparsityFeatures], list[str]]:
        feats, labels = [], []
        for m in dataset.matrices:
            recs = dataset.for_matrix(m)
            if not any(r.feasible for r in recs):
                continue
            try:
                best = dataset.best_record(m, objective)
            except ValueError:
                continue
            feats.append(recs[0].features)
            labels.append(best.config.fmt)
        return feats, labels

    # ------------------------------------------------------------------ refit
    def refit_format_classifier(
        self, predictor, objectives: tuple[str, ...] = OBJECTIVES
    ) -> dict[str, int]:
        """Refit ``predictor.format_clf_[obj]`` from measured + base labels.

        Telemetry labels are repeated ``label_weight``× so a handful of real
        measurements can overrule a misfit prior without discarding the base
        dataset's coverage of unseen feature regions. Returns the number of
        telemetry labels used per refit objective.
        """
        used: dict[str, int] = {}
        for objective in objectives:
            t_feats, t_labels = self._measured_labels(objective)
            if not t_labels:
                continue
            feats = list(t_feats) * self.config.label_weight
            labels = list(t_labels) * self.config.label_weight
            if self.base_dataset is not None:
                b_feats, b_labels = self._base_labels(self.base_dataset, objective)
                feats.extend(b_feats)
                labels.extend(b_labels)
            X = np.stack([f.log_vector() for f in feats])
            y = np.array(labels)
            # same zoo/HPO path the offline §5.4 stage uses
            predictor.format_clf_[objective] = predictor._fit_classifier(X, y)
            used[objective] = len(t_labels)
        if used:
            self.refits += 1
            self._last_refit_obs = self.recorder.total_observations()
            log.info("refit format classifiers from telemetry: %s", used)
        return used

    def maybe_refit(self, predictor) -> dict[str, int]:
        """Refit when ``refit_every`` new observations accumulated."""
        new = self.recorder.total_observations() - self._last_refit_obs
        if new < self.config.refit_every:
            return {}
        return self.refit_format_classifier(predictor)
