"""Per-request measurement records + per-arm aggregation (telemetry core).

Every served SpMV request becomes a ``MeasurementRecord``: which plan was
served (feature bucket, objective, format, schedule), what the model
predicted, and what the wall clock actually measured. The recorder folds
records into per-*arm* aggregates — an arm is a (bucket, objective, format)
cell, exactly the granularity the paper's §5.4 dataset labels — keeping an
all-time mean, an EWMA that tracks drift, and windowed percentiles
(``repro.utils.timing.RollingStats``).

Persistence is a JSONL append-log. Appends are line-atomic in practice and
``load``/``replay`` skip a torn trailing line (the one thing a crash during
an append can produce), so telemetry state survives restarts the same way
the ``TuningCache`` does; a full rewrite via temp-file + ``os.replace``
would be crash-safe too but O(total records) per flush, which an append-log
exists to avoid. Replaying the log rebuilds every aggregate, so there is no
separate snapshot file to corrupt.
"""

from __future__ import annotations

import json
import os
from collections import deque
from dataclasses import asdict, dataclass, field
from pathlib import Path

from repro.utils.logging import get_logger
from repro.utils.timing import RollingStats

log = get_logger("telemetry.recorder")

TELEMETRY_LOG_VERSION = 1

# Per-format window of (predicted_s, measured_s) pairs kept for cost-model
# calibration (``CalibratedCostModel.fit_from_telemetry``). Windowed so a
# long-running server calibrates against recent hardware behaviour, not the
# full history.
CALIBRATION_WINDOW = 256

ArmKey = tuple[str, str, str]  # (bucket, objective, fmt)


@dataclass(frozen=True)
class MeasurementRecord:
    """One served request turned into a labelled measurement."""

    seq: int  # monotonically increasing per recorder lifetime
    bucket: str  # feature bucket (plan-cache key component)
    objective: str
    fmt: str  # format actually served
    measured_s: float  # measured kernel wall time
    predicted_s: float | None = None  # model's latency estimate for the plan
    plan_id: str = ""  # "bucket/objective/mode" the plan resolved to
    exploratory: bool = False  # bandit exploration pull, not the incumbent
    schedule: dict = field(default_factory=dict)  # KernelSchedule.as_dict()
    features: dict = field(default_factory=dict)  # Table-2 features (dataset export)
    source: str = "serve"

    def as_json(self) -> str:
        return json.dumps(asdict(self), sort_keys=True)


@dataclass
class ArmAggregate:
    """Aggregated outcomes for one (bucket, objective, fmt) arm."""

    key: ArmKey
    stats: RollingStats
    schedule: dict = field(default_factory=dict)  # representative schedule
    exploratory_pulls: int = 0

    def as_dict(self) -> dict:
        d = self.stats.as_dict()
        d["exploratory_pulls"] = self.exploratory_pulls
        return d


class TelemetryRecorder:
    """Low-overhead measurement sink with JSONL persistence.

    Parameters
    ----------
    log_path:
        Optional JSONL file. If it exists, its records are replayed into
        the aggregates on construction (restart survival); new records are
        appended in batches of ``flush_every``.
    window / ewma_alpha:
        Per-arm ``RollingStats`` parameters.
    """

    def __init__(
        self,
        log_path: str | Path | None = None,
        *,
        window: int = 128,
        ewma_alpha: float = 0.2,
        flush_every: int = 32,
    ):
        self.log_path = Path(log_path) if log_path is not None else None
        self.window = int(window)
        self.ewma_alpha = float(ewma_alpha)
        self.flush_every = max(int(flush_every), 1)
        self.seq = 0
        self.records_dropped = 0  # malformed lines skipped at load
        self._arms: dict[ArmKey, ArmAggregate] = {}
        self._bucket_features: dict[str, dict] = {}
        self._pending: list[MeasurementRecord] = []
        # must exist before _replay: replayed records fold calibration pairs
        self._calibration: dict[str, deque] = {}
        self._calibration_totals: dict[str, int] = {}  # monotonic, survives
        # window resets — pollers diff against it to find fresh pairs
        if self.log_path is not None and self.log_path.exists():
            self._replay(self.log_path)

    # ---------------------------------------------------------------- record
    def observe(
        self,
        *,
        bucket: str,
        objective: str,
        fmt: str,
        measured_s: float,
        predicted_s: float | None = None,
        plan_id: str = "",
        exploratory: bool = False,
        schedule: dict | None = None,
        features: dict | None = None,
        source: str = "serve",
    ) -> MeasurementRecord:
        """Build + record a measurement (kwargs keep callers import-free)."""
        rec = MeasurementRecord(
            seq=self.seq,
            bucket=bucket,
            objective=objective,
            fmt=fmt,
            measured_s=float(measured_s),
            predicted_s=None if predicted_s is None else float(predicted_s),
            plan_id=plan_id,
            exploratory=bool(exploratory),
            schedule=dict(schedule or {}),
            features=dict(features or {}),
            source=source,
        )
        self.record(rec)
        return rec

    def record(self, rec: MeasurementRecord) -> None:
        self.seq = max(self.seq, rec.seq) + 1
        self._fold(rec)
        if self.log_path is not None:
            self._pending.append(rec)
            if len(self._pending) >= self.flush_every:
                self.flush()

    def _fold(self, rec: MeasurementRecord) -> None:
        key: ArmKey = (rec.bucket, rec.objective, rec.fmt)
        arm = self._arms.get(key)
        if arm is None:
            arm = ArmAggregate(
                key, RollingStats(self.window, self.ewma_alpha), dict(rec.schedule)
            )
            self._arms[key] = arm
        arm.stats.add(rec.measured_s)
        if rec.schedule:
            arm.schedule = dict(rec.schedule)
        if rec.exploratory:
            arm.exploratory_pulls += 1
        if rec.features:
            self._bucket_features[rec.bucket] = dict(rec.features)
        if (
            rec.predicted_s is not None
            and rec.predicted_s > 0.0
            and rec.measured_s > 0.0
        ):
            pairs = self._calibration.get(rec.fmt)
            if pairs is None:
                pairs = self._calibration[rec.fmt] = deque(maxlen=CALIBRATION_WINDOW)
            pairs.append((rec.predicted_s, rec.measured_s))
            self._calibration_totals[rec.fmt] = (
                self._calibration_totals.get(rec.fmt, 0) + 1
            )

    # --------------------------------------------------------------- queries
    def arm(self, bucket: str, objective: str, fmt: str) -> ArmAggregate | None:
        return self._arms.get((bucket, objective, fmt))

    def arms(self) -> dict[ArmKey, ArmAggregate]:
        return dict(self._arms)

    def arms_for(self, bucket: str, objective: str) -> dict[str, ArmAggregate]:
        """Per-format aggregates of one (bucket, objective) cell."""
        return {
            k[2]: a for k, a in self._arms.items() if k[0] == bucket and k[1] == objective
        }

    def bucket_features(self, bucket: str) -> dict | None:
        return self._bucket_features.get(bucket)

    def calibration_samples(
        self, fmt: str | None = None
    ) -> dict[str, list[tuple[float, float]]] | list[tuple[float, float]]:
        """(predicted_s, measured_s) pairs per format — the calibration input.

        Only records that carried a model prediction contribute; pairs are
        windowed to the most recent ``CALIBRATION_WINDOW`` per format.
        """
        if fmt is not None:
            return list(self._calibration.get(fmt, ()))
        return {f: list(pairs) for f, pairs in self._calibration.items()}

    def calibration_totals(self) -> dict[str, int]:
        """Monotonic per-format count of calibration pairs ever folded.

        ``calibration_samples`` is a bounded window, so a poller (the
        ``obs/anomaly.py`` watchdog) cannot tell fresh pairs from ones it
        already judged; diffing against these totals can. Window resets do
        not rewind them."""
        return dict(self._calibration_totals)

    def reset_calibration(self, fmt: str | None = None) -> int:
        """Drop the windowed calibration pairs (one format, or all).

        The anomaly watchdog calls this when a format's residuals say its
        pairs were produced by a lying cost model — the next
        ``fit_from_telemetry`` must not least-squares over the lying era.
        Returns the number of pairs dropped; totals stay monotonic."""
        if fmt is not None:
            return len(self._calibration.pop(fmt, ()))
        dropped = sum(len(p) for p in self._calibration.values())
        self._calibration.clear()
        return dropped

    def total_observations(self) -> int:
        return sum(a.stats.count for a in self._arms.values())

    def summary(self) -> dict:
        expl = sum(a.exploratory_pulls for a in self._arms.values())
        return {
            "observations": self.total_observations(),
            "arms": len(self._arms),
            "buckets": len({k[0] for k in self._arms}),
            "exploratory_pulls": expl,
            "records_dropped": self.records_dropped,
            "pending": len(self._pending),
            "calibration_samples": sum(
                len(p) for p in self._calibration.values()
            ),
        }

    # ----------------------------------------------------------- persistence
    def flush(self) -> int:
        """Append pending records to the JSONL log; returns lines written."""
        if self.log_path is None or not self._pending:
            n = len(self._pending)
            self._pending.clear()
            return n
        self.log_path.parent.mkdir(parents=True, exist_ok=True)
        chunk = "".join(r.as_json() + "\n" for r in self._pending)
        # a crash mid-append can leave the file without a trailing newline;
        # appending onto that torn line would corrupt the next record too
        if self.log_path.exists() and self.log_path.stat().st_size:
            with open(self.log_path, "rb") as f:
                f.seek(-1, os.SEEK_END)
                if f.read(1) != b"\n":
                    chunk = "\n" + chunk
        with open(self.log_path, "a") as f:
            f.write(chunk)
            f.flush()
            os.fsync(f.fileno())
        n = len(self._pending)
        self._pending.clear()
        return n

    def _replay(self, path: Path) -> None:
        loaded = 0
        for line in path.read_text().splitlines():
            line = line.strip()
            if not line:
                continue
            try:
                raw = json.loads(line)
                rec = MeasurementRecord(**raw)
            except (ValueError, TypeError):
                # torn trailing line from an interrupted append, or a
                # foreign/newer schema row: telemetry is advisory, skip it
                self.records_dropped += 1
                continue
            self.seq = max(self.seq, rec.seq + 1)
            self._fold(rec)
            loaded += 1
        log.info(
            "replayed %d telemetry records from %s (%d dropped)",
            loaded,
            path,
            self.records_dropped,
        )

    def close(self) -> None:
        self.flush()
