from repro.train.trainer import TrainConfig, Trainer, make_loss_fn, make_train_step
from repro.train.serve import BatchedServer, ServeConfig

__all__ = [
    "TrainConfig",
    "Trainer",
    "make_loss_fn",
    "make_train_step",
    "BatchedServer",
    "ServeConfig",
]
