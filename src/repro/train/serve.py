"""Batched serving: LM slot scheduler + the multi-matrix SpMV pipeline.

``BatchedServer``: fixed B decode slots; new requests are admitted by
prefilling into a free slot (per-slot cache surgery over the batch-leading
cache pytree), and all occupied slots decode together each step. Greedy
sampling. The serve path can optimize for energy efficiency instead of
latency via the Auto-SpMV objective plumbing (paper finding 5: the
latency-optimal configuration is not the power-optimal one).

``SpmvServer``: the Auto-SpMV serving pipeline. Every request carries a
matrix + vector; instead of compiling a kernel inline per request, the
server consults a shared ``AutoSpmvSession`` — batches are deduplicated by
matrix fingerprint, plans come from the feature-bucketed cache (persisted
across restarts), and prepared kernels are reused from the process memo. The
tuning cost is thereby paid once per unique matrix per fleet, which is the
paper's §5.3 amortization argument turned into a serving layer.

With telemetry attached to the session (repro/telemetry) the server times
every kernel execution and feeds it back via ``session.observe``: requests
become labelled measurements, the bandit explores alternate formats within
budget, drifted plans are evicted, and an optional ``FeedbackLoop``
incrementally refits the format classifier from the accumulated records —
the predict→measure→relearn loop closed inside the serving path.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.session import AutoSpmvSession
from repro.models import decode_step, prefill
from repro.models.model import init_cache
from repro.models.sparse_linear import SLO_PRIORITY, slo_objective
from repro.obs.energy import EnergyAccountant
from repro.obs.http import ObsHTTPServer
from repro.obs.metrics import get_metrics
from repro.obs.trace import get_tracer, span as _span
from repro.sparse.registry import default_format
from repro.utils.logging import get_logger

log = get_logger("serve")


@dataclass
class ServeConfig:
    batch_slots: int = 4
    max_len: int = 512
    max_new_tokens: int = 32
    objective: str = "latency"  # latency | efficiency (Auto-SpMV objective)


@dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    slo: str = "latency-critical"  # SLO class (models/sparse_linear.py)
    generated: list[int] = field(default_factory=list)
    done: bool = False
    latency_s: float = 0.0


class BatchedServer:
    """Slot-batched LM decode; optionally sparse-served.

    With ``engine`` (a ``SparseInferenceEngine`` over pruned FFN weights)
    every decode tick routes its FFN matmuls through planned SpMV kernels.
    Each request carries an SLO class; a shared tick runs under the
    highest-priority class among the occupied slots (``SLO_PRIORITY``), one
    jitted decode graph per objective, while the energy accounting keys each
    request's share of the tick by its *own* class — mixed traffic shows who
    burned the joules. Prefill stays dense: the weights themselves are
    pruned, so the prompt pass is numerically identical either way.
    """

    def __init__(
        self, params: Any, cfg: ModelConfig, sc: ServeConfig, *, engine=None,
        slo=None,  # optional repro.obs.slo.SloTracker
    ):
        self.params = params
        self.cfg = cfg
        self.sc = sc
        self.engine = engine
        self.slo = slo
        self.cache = init_cache(cfg, sc.batch_slots, sc.max_len)
        self.slot_req: list[Request | None] = [None] * sc.batch_slots
        self.slot_pos = np.zeros(sc.batch_slots, np.int32)
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(p, cfg, c, t, pos)
        )
        # one jitted decode graph per objective, closing over the bound
        # engine handle (built lazily: mixed traffic may never touch some)
        self._decode_by_objective: dict[str, Any] = {}
        self._prefill_cache = init_cache(cfg, 1, sc.max_len)
        self.ticks = 0
        self.requests_served = 0
        self._slo_counts: dict[str, int] = {}
        self.metrics = get_metrics()
        self.energy = EnergyAccountant(self.metrics)

    # ------------------------------------------------------------ admission
    def _admit(self, req: Request, slot: int):
        tokens = jnp.asarray(np.array(req.prompt, np.int32)[None, :])
        pc = init_cache(self.cfg, 1, self.sc.max_len)  # fresh, correct inits
        logits, pc, _ = prefill(self.params, self.cfg, pc, tokens=tokens)
        first = int(jnp.argmax(logits[0, -1]))
        req.generated.append(first)
        # slot surgery: write the prefilled cache into slot `slot`
        self.cache = jax.tree.map(
            lambda c, p: c.at[slot].set(p[0].astype(c.dtype)), self.cache, pc
        )
        self.slot_req[slot] = req
        self.slot_pos[slot] = len(req.prompt)
        if self.engine is not None:
            slo_objective(req.slo)  # validate the class at admission
            self._slo_counts[req.slo] = self._slo_counts.get(req.slo, 0) + 1
            self.metrics.counter("lm_requests_total", slo=req.slo).inc()
        log.info("admitted request %d into slot %d (prompt %d tokens)", req.rid, slot, len(req.prompt))

    def _free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    # ---------------------------------------------------------------- decode
    def _tick_objective(self) -> str:
        """The paper objective this tick decodes under: the highest-priority
        SLO class among the occupied slots wins the shared batch."""
        active = {r.slo for r in self.slot_req if r is not None}
        for slo in SLO_PRIORITY:
            if slo in active:
                if self.slo is not None:
                    # a firing class drags the shared tick to the violated
                    # dimension's objective until the burn clears
                    return self.slo.effective_objective(slo)
                return slo_objective(slo)
        return self.sc.objective

    def _decode_for(self, objective: str):
        fn = self._decode_by_objective.get(objective)
        if fn is None:
            # plan eagerly: format conversion must not run under the trace
            self.engine.plan_all(objective)
            handle = self.engine.bind(objective)
            cfg = self.cfg
            fn = jax.jit(
                lambda p, c, t, pos: decode_step(
                    p, cfg, c, t, pos, unroll_layers=True, engine=handle
                )
            )
            self._decode_by_objective[objective] = fn
        return fn

    def _decode_tick(self):
        B = self.sc.batch_slots
        toks = np.zeros((B, 1), np.int32)
        for i, r in enumerate(self.slot_req):
            if r is not None:
                toks[i, 0] = r.generated[-1]
        pos = jnp.asarray(self.slot_pos[:, None])
        if self.engine is None:
            t0 = time.perf_counter()
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(toks), pos
            )
            if self.slo is not None:
                # dense decode has no per-objective engine to escalate, but
                # the burn-rate windows still need the measured latency —
                # a tracker that never sees samples can never alert
                logits = jax.block_until_ready(logits)
                dt = time.perf_counter() - t0
                active = [r for r in self.slot_req if r is not None]
                share = dt / max(len(active), 1)
                for r in active:
                    self.slo.observe(r.slo, latency_s=share)
                self.slo.evaluate()
        else:
            objective = self._tick_objective()
            t0 = time.perf_counter()
            logits, self.cache = self._decode_for(objective)(
                self.params, self.cache, jnp.asarray(toks), pos
            )
            logits = jax.block_until_ready(logits)
            dt = time.perf_counter() - t0
            self._account_tick(objective, dt)
        self.ticks += 1
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for i, r in enumerate(self.slot_req):
            if r is None:
                continue
            r.generated.append(int(nxt[i]))
            self.slot_pos[i] += 1
            if self.engine is not None:
                self.metrics.counter("lm_tokens_total", slo=r.slo).inc()
            if (
                len(r.generated) >= r.max_new_tokens
                or self.slot_pos[i] >= self.sc.max_len - 1
            ):
                r.done = True
                self.slot_req[i] = None
                self.requests_served += 1
                log.info("request %d finished (%d tokens)", r.rid, len(r.generated))

    def _account_tick(self, objective: str, dt: float) -> None:
        """Split one measured tick across the active requests' own SLO
        classes. Each slot decodes its own token through every planned
        matrix, so the modeled per-token cost is the full per-pass estimate
        while the measured wall time is shared."""
        active = [r for r in self.slot_req if r is not None]
        if not active:
            return
        self.metrics.histogram(
            "lm_decode_tick_seconds", objective=objective
        ).observe(dt)
        fmt = self.engine.format_mix(objective)
        modeled = self.engine.modeled_objectives(objective)
        share = dt / len(active)
        for r in active:
            self.energy.observe(
                fmt=fmt,
                objective=slo_objective(r.slo),
                measured_s=share,
                modeled=modeled,
                block="lm",
            )
            if self.slo is not None:
                self.slo.observe(
                    r.slo, latency_s=share, energy_j=modeled.get("energy")
                )
        if self.slo is not None:
            self.slo.evaluate()

    # ------------------------------------------------------------------- run
    def run(self, requests: list[Request]) -> list[Request]:
        pending = list(requests)
        t0 = time.perf_counter()
        while pending or any(r is not None for r in self.slot_req):
            for slot in self._free_slots():
                if not pending:
                    break
                self._admit(pending.pop(0), slot)
            if any(r is not None for r in self.slot_req):
                self._decode_tick()
        for r in requests:
            r.latency_s = time.perf_counter() - t0
        return requests

    def summary(self) -> dict:
        """Serving stats for the CLI dump / CI assertions: SLO class mix,
        engine plan counts, session amortization counters, energy cells."""
        out: dict[str, Any] = {
            "requests": self.requests_served,
            "ticks": self.ticks,
            "slo_classes": dict(sorted(self._slo_counts.items())),
        }
        if self.slo is not None:
            out["slo"] = self.slo.snapshot()
        if self.engine is not None:
            out["engine"] = self.engine.summary()
            out["session"] = self.engine.session.stats.as_dict()
            cells = self.energy.summary().get("cells", {})
            if cells:
                out["energy"] = cells
            latency: dict[str, dict] = {}
            for hist in self.metrics.instruments(
                "histogram", "lm_decode_tick_seconds"
            ):
                if not hist.count:
                    continue
                labels = dict(hist.labels)
                latency[labels.get("objective", "")] = hist.as_dict()
            if latency:
                out["tick_latency"] = latency
        return out


# --------------------------------------------------------------------- SpMV
@dataclass
class SpmvRequest:
    """One SpMV serving request: y = A @ x, tuned for ``objective``."""

    rid: int
    dense: np.ndarray
    x: np.ndarray
    objective: str = "latency"
    slo: str | None = None  # SLO class; when set, the served objective is
    # resolved through the tracker (native, or escalated while firing)
    # outputs
    y: np.ndarray | None = None
    schedule: Any = None  # KernelSchedule the session picked
    fmt: str | None = None  # format served (telemetry/adaptive path)
    cache_hit: bool = False  # plan came from the session cache
    exploratory: bool = False  # served off-incumbent by the bandit
    latency_s: float = 0.0
    served_objective: str | None = None  # what the request actually ran under


class SpmvServer:
    """Batched multi-matrix SpMV serving on top of an ``AutoSpmvSession``.

    ``run`` takes one batch of requests, groups them by objective, asks the
    session to tune each group via ``optimize_many`` (fingerprint dedup +
    plan cache + kernel memo), then executes every request with its shared
    prepared kernel. The server never compiles inline — all tuning economics
    live in the session, so a restart with a warm ``cache_path`` skips the
    predictor inferences entirely.
    """

    def __init__(
        self,
        session: AutoSpmvSession,
        *,
        adaptive: bool | None = None,
        feedback=None,  # optional repro.telemetry.FeedbackLoop
        partition: bool = False,
        max_blocks: int = 8,
        fused: bool = False,
        calibrate_every: int = 0,
        slo=None,  # optional repro.obs.slo.SloTracker
        anomaly: bool = False,  # attach a CostModelWatchdog (needs telemetry)
        fleet=None,  # optional repro.obs.sync.FleetSync
    ):
        self.session = session
        # default: take the observed path whenever the session can consume
        # measurements (telemetry recorder and/or bandit attached)
        self.adaptive = (
            adaptive
            if adaptive is not None
            else (session.telemetry is not None or session.adaptive is not None)
        )
        self.feedback = feedback
        self.partition = partition
        self.max_blocks = max_blocks
        # single-launch composite executor on the non-adaptive partitioned
        # path (the adaptive path needs per-block timing, which one launch
        # cannot provide)
        self.fused = fused
        # recalibrate the session's cost model every N served requests
        # (0 = never); requires telemetry on the session
        self.calibrate_every = int(calibrate_every)
        self.calibrations = 0
        self._served_since_calibration = 0
        self.batches_served = 0
        self.requests_served = 0
        # observability: request counters + latency histograms live in the
        # process metrics registry; modeled-energy accounting per cell
        self.metrics = get_metrics()
        self.energy = EnergyAccountant(self.metrics)
        self._obs_http: ObsHTTPServer | None = None
        # active observability: burn-rate alerting + escalation, cost-model
        # residual watchdog, live fleet posterior sync — all evaluated once
        # per served batch
        self.slo = slo
        self.fleet = fleet
        self.watchdog = None
        if anomaly:
            from repro.obs.anomaly import CostModelWatchdog

            self.watchdog = CostModelWatchdog(session)
        self.anomaly_fires = 0

    def _resolve_objective(self, req: SpmvRequest) -> str:
        if req.slo is None:
            return req.objective
        if self.slo is not None:
            return self.slo.effective_objective(req.slo)
        return slo_objective(req.slo)

    def _account(
        self,
        objective: str,
        fmt: str,
        measured_s: float,
        modeled: dict | None,
        *,
        block: str = "",
        slo: str | None = None,
    ) -> None:
        """Fold one served execution into counters/histograms/energy cells
        (and, when the request carries an SLO class, its burn windows)."""
        self.metrics.counter("spmv_requests_total", fmt=fmt, objective=objective).inc()
        self.metrics.histogram(
            "spmv_request_latency_seconds", objective=objective
        ).observe(measured_s)
        self.energy.observe(
            fmt=fmt,
            objective=objective,
            measured_s=measured_s,
            modeled=modeled,
            block=block,
        )
        if self.slo is not None and slo is not None:
            self.slo.observe(
                slo,
                latency_s=measured_s,
                energy_j=(modeled or {}).get("energy"),
            )

    def _run_observed(self, objective: str, group: list[SpmvRequest]) -> None:
        """Per-request serve + measure + observe (telemetry/adaptive mode).

        Requests are timed individually — the measurement *is* the product
        here, so the batch dedup of ``optimize_many`` gives way to per-call
        timing; plan/kernel reuse still comes from the session caches."""
        for req in group:
            with _span("server.request", rid=req.rid, objective=objective, mode="observed"):
                plan = self.session.serve_optimize(req.dense, objective)
                with _span("kernel.execute", fmt=plan.fmt):
                    t0 = time.perf_counter()
                    y = np.asarray(plan.kernel(jnp.asarray(req.x)))
                    dt = time.perf_counter() - t0
                req.y = y
                req.schedule = plan.schedule
                req.fmt = plan.fmt
                req.cache_hit = plan.cache_hit
                req.exploratory = plan.exploratory
                req.latency_s = dt
                self.session.observe(plan, dt)
                self._account(objective, plan.fmt, dt, plan.predicted, slo=req.slo)
        if self.feedback is not None:
            refit = self.feedback.maybe_refit(self.session.tuner.predictor)
            if refit:
                log.info("telemetry refit after batch: %s", refit)

    def _run_partitioned(self, objective: str, group: list[SpmvRequest]) -> None:
        """Per-request partitioned serve. On the observed path (telemetry
        and/or bandit consuming measurements) blocks are timed individually
        so each (block, format) arm learns its own wall time; otherwise the
        composite kernel runs as one call — no per-block host sync is paid
        for measurements nothing would consume."""
        for req in group:
            x = jnp.asarray(req.x)
            with _span(
                "server.request", rid=req.rid, objective=objective, mode="partitioned"
            ):
                if self.adaptive:
                    res = self.session.serve_partitioned(
                        req.dense, objective, max_blocks=self.max_blocks
                    )
                    y, block_times = res.kernel.timed_call(x)
                    dt = sum(block_times)
                    self.session.observe_partitioned(res, block_times)
                    # per-block energy attribution: each row block's modeled
                    # estimate against its own measured slice
                    for bp, fmt, bt in zip(res.plan.blocks, res.formats, block_times):
                        self.energy.observe(
                            fmt=fmt,
                            objective=objective,
                            measured_s=bt,
                            modeled=bp.modeled.as_dict(),
                            block=str(bp.block.index),
                        )
                else:
                    res = self.session.partitioned_optimize(
                        req.dense, objective, max_blocks=self.max_blocks,
                        fused=self.fused,
                    )
                    t0 = time.perf_counter()
                    y = np.asarray(jax.block_until_ready(res.kernel(x)))
                    dt = time.perf_counter() - t0
                req.y = y
                req.schedule = res.plan.blocks[0].schedule
                req.fmt = "+".join(res.formats)
                req.cache_hit = res.cache_hit
                req.exploratory = any(res.exploratory)
                req.latency_s = dt
                self._account(
                    objective, req.fmt, dt, res.plan.modeled.as_dict(), slo=req.slo
                )
        if self.feedback is not None:
            refit = self.feedback.maybe_refit(self.session.tuner.predictor)
            if refit:
                log.info("telemetry refit after batch: %s", refit)

    def run(self, requests: list[SpmvRequest]) -> list[SpmvRequest]:
        by_objective: dict[str, list[SpmvRequest]] = {}
        for r in requests:
            # SLO-classed requests resolve through the tracker: the class's
            # native objective, or the violated dimension's while firing
            r.served_objective = self._resolve_objective(r)
            by_objective.setdefault(r.served_objective, []).append(r)
        for objective, group in by_objective.items():
            if self.partition:
                self._run_partitioned(objective, group)
                continue
            if self.adaptive:
                self._run_observed(objective, group)
                continue
            t_group = time.perf_counter()
            seen_keys = {
                (e.bucket, e.objective, e.mode) for e in self.session.cache.entries()
            }
            results = self.session.optimize_many(
                [r.dense for r in group], objective, mode="compile"
            )
            for req, res in zip(group, results):
                with _span(
                    "server.request", rid=req.rid, objective=objective, mode="batch"
                ):
                    req.schedule = res.schedule
                    with _span("kernel.execute", fmt=default_format()):
                        t_exec = time.perf_counter()
                        req.y = np.asarray(res.kernel(jnp.asarray(req.x)))
                        exec_s = time.perf_counter() - t_exec
                    # a request is a hit if its plan existed before the batch
                    # OR was produced for an earlier request in this batch
                    key = self.session.plan_key(res.features, objective)
                    req.cache_hit = key in seen_keys
                    seen_keys.add(key)
                    self._account(
                        objective, default_format(), exec_s, res.predicted,
                        slo=req.slo,
                    )
            # latency covers this group's tuning + execution only, not other
            # objective groups tuned later in the same batch
            dt = time.perf_counter() - t_group
            for req in group:
                req.latency_s = dt
        self.batches_served += 1
        self.requests_served += len(requests)
        self._served_since_calibration += len(requests)
        if (
            self.calibrate_every > 0
            and self.session.telemetry is not None
            and self._served_since_calibration >= self.calibrate_every
        ):
            self.session.calibrate()
            self.calibrations += 1
            self._served_since_calibration = 0
        # active observability, once per batch: advance the alert state
        # machines, let the residual watchdog judge fresh calibration pairs,
        # and sync the fleet posterior when the request budget says so
        if self.slo is not None:
            self.slo.evaluate()
        if self.watchdog is not None:
            fired = self.watchdog.poll()
            if fired:
                self.anomaly_fires += len(fired)
        if self.fleet is not None:
            self.fleet.maybe_sync(len(requests))
        log.info(
            "spmv batch: %d requests, %d unique kernels compiled so far, %s",
            len(requests),
            self.session.stats.kernel_compiles,
            self.session.cache.stats(),
        )
        return requests

    def summary(self) -> dict:
        """Server-level stats incl. telemetry/bandit state (serve CLI dump)."""
        out = {
            "batches": self.batches_served,
            "requests": self.requests_served,
            "session": self.session.stats.as_dict(),
            "cache": self.session.cache.stats(),
        }
        if self.session.telemetry is not None:
            out["telemetry"] = self.session.telemetry.summary()
        if self.session.adaptive is not None:
            out["adaptive"] = self.session.adaptive.summary()
        if self.feedback is not None:
            out["refits"] = self.feedback.refits
        if self.calibrate_every > 0:
            out["calibrations"] = self.calibrations
        if self.slo is not None:
            out["slo"] = self.slo.snapshot()
        if self.watchdog is not None:
            out["anomaly"] = self.watchdog.summary()
        if self.fleet is not None:
            out["fleet"] = self.fleet.summary()
        latency: dict[str, dict] = {}
        for hist in self.metrics.instruments("histogram", "spmv_request_latency_seconds"):
            if not hist.count:
                continue
            labels = dict(hist.labels)
            latency[labels.get("objective", "")] = hist.as_dict()
        if latency:
            out["latency"] = latency
        energy = self.energy.per_format()
        if energy:
            out["energy"] = {f: c.as_dict() for f, c in sorted(energy.items())}
        return out

    # --------------------------------------------------------- observability
    def dump_obs(
        self, out_dir, *, instance: str = "server"
    ) -> dict[str, str]:
        """Export this instance's observability shards (fleet aggregation
        input): a metrics JSONL shard, a trace JSONL shard, and the summary
        (with energy/latency aggregates) as JSON. Returns path strings."""
        import json
        from pathlib import Path

        from repro.utils.io import atomic_write_text

        out_dir = Path(out_dir)
        out_dir.mkdir(parents=True, exist_ok=True)
        metrics_path = out_dir / f"metrics-{instance}.jsonl"
        trace_path = out_dir / f"trace-{instance}.jsonl"
        summary_path = out_dir / f"summary-{instance}.json"
        self.metrics.write_shard(metrics_path, instance)
        get_tracer().export_jsonl(trace_path)
        atomic_write_text(
            summary_path, json.dumps(self.summary(), indent=1, default=float)
        )
        log.info("observability shards -> %s", out_dir)
        return {
            "metrics": str(metrics_path),
            "trace": str(trace_path),
            "summary": str(summary_path),
        }

    def start_metrics_server(
        self, port: int = 0, *, host: str = "127.0.0.1"
    ) -> ObsHTTPServer:
        """Serve ``/metrics`` + ``/healthz`` + ``/obs`` (+ ``/slo`` when a
        tracker is attached) from a daemon thread."""
        if self._obs_http is None:
            self._obs_http = ObsHTTPServer(
                self.metrics,
                extra=self.summary,
                slo=self.slo.snapshot if self.slo is not None else None,
                host=host,
                port=port,
            )
            self._obs_http.start()
            log.info("metrics endpoint at %s/metrics", self._obs_http.url)
        return self._obs_http

    def stop_metrics_server(self) -> None:
        if self._obs_http is not None:
            self._obs_http.stop()
            self._obs_http = None
