"""Training loop with large-scale fault-tolerance posture:

* checkpoint every N steps (atomic, keep-K) + preemption hook (SIGTERM ->
  save at the next step boundary, then exit cleanly);
* stateless data pipeline resume (step-indexed PRNG, no pipeline state in
  the checkpoint);
* step-time watchdog: a step slower than ``watchdog_factor`` x the running
  median is logged as a straggler event (the single-process analogue of
  slow-host detection; on a real fleet the same hook feeds the scheduler);
* optional top-k gradient compression with error feedback.

``make_train_step`` builds the pure (params, opt, batch) -> (params, opt,
metrics) function that both this trainer and the multi-pod dry-run lower.
"""

from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLMDataset
from repro.models import forward, init_params, model_specs
from repro.optim import AdamWConfig, apply_adamw, init_opt_state
from repro.optim.compress import compress_gradients, init_error_feedback
from repro.utils.logging import get_logger

log = get_logger("train")


def make_loss_fn(cfg: ModelConfig, *, aux_weight: float = 0.01,
                 unroll_attn: bool = False, unroll_layers: bool = False):
    """Masked next-token cross entropy + MoE load-balance aux."""

    def loss_fn(params, batch):
        kw = {}
        if cfg.train_input == "embeds":
            kw["embeds"] = batch["embeds"]
        else:
            kw["tokens"] = batch["tokens"]
        if cfg.prefix_len:
            kw["prefix_embeds"] = batch["prefix_embeds"]
        logits, aux = forward(
            params, cfg, unroll_attn=unroll_attn, unroll_layers=unroll_layers, **kw
        )
        labels = batch["labels"]
        T = labels.shape[1]
        logits = logits[:, -T:]  # drop prefix positions (vlm)
        logp = jax.nn.log_softmax(logits, axis=-1)
        from repro.dist.partition import hint

        nll = hint(
            -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0],
            ("batch", None),
        )
        mask = batch.get("loss_mask")
        if mask is not None:
            nll = jnp.where(mask, nll, 0.0)
            loss = nll.sum() / jnp.maximum(mask.sum(), 1)
        else:
            loss = nll.mean()
        return loss + aux_weight * aux["moe_aux"], aux

    return loss_fn


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    *,
    compress_frac: float = 0.0,
    unroll_attn: bool = False,
    unroll_layers: bool = False,
) -> Callable:
    loss_fn = make_loss_fn(cfg, unroll_attn=unroll_attn, unroll_layers=unroll_layers)

    def train_step(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        if compress_frac > 0.0:
            grads, new_err, cmetrics = compress_gradients(
                grads, opt_state["error"], compress_frac
            )
        inner = {k: opt_state[k] for k in ("m", "v", "step")}
        params, inner, metrics = apply_adamw(params, grads, inner, opt_cfg)
        new_state = dict(inner)
        if compress_frac > 0.0:
            new_state["error"] = new_err
            metrics.update(cmetrics)
        metrics["loss"] = loss
        metrics["moe_aux"] = aux["moe_aux"]
        return params, new_state, metrics

    return train_step


def init_train_state(cfg: ModelConfig, opt_cfg: AdamWConfig, seed: int = 0,
                     compress_frac: float = 0.0):
    params = init_params(model_specs(cfg), jax.random.PRNGKey(seed), cfg.param_dtype)
    opt_state = init_opt_state(params, opt_cfg)
    if compress_frac > 0.0:
        opt_state["error"] = init_error_feedback(params)
    return params, opt_state


@dataclass
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str = "checkpoints"
    keep_checkpoints: int = 3
    seed: int = 0
    watchdog_factor: float = 3.0
    compress_frac: float = 0.0
    aux_weight: float = 0.01


class Trainer:
    """Single-process orchestrator (the launch CLI wires meshes/sharding)."""

    def __init__(
        self,
        model_cfg: ModelConfig,
        data_cfg: DataConfig,
        opt_cfg: AdamWConfig,
        train_cfg: TrainConfig,
        *,
        jit_step: Callable | None = None,
        to_device: Callable[[dict], dict] | None = None,
    ):
        self.cfg = model_cfg
        self.data_cfg = data_cfg
        self.opt_cfg = opt_cfg
        self.tc = train_cfg
        self.dataset = SyntheticLMDataset(data_cfg)
        self.ckpt = CheckpointManager(train_cfg.ckpt_dir, keep=train_cfg.keep_checkpoints)
        self.step_fn = jit_step or jax.jit(
            make_train_step(model_cfg, opt_cfg, compress_frac=train_cfg.compress_frac)
        )
        self.to_device = to_device or (lambda b: b)
        self._preempted = False
        self.history: list[dict] = []

    def _install_preemption_hook(self):
        def handler(signum, frame):
            log.warning("SIGTERM received: checkpoint at next step boundary")
            self._preempted = True

        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # non-main thread (tests)

    def run(self, params, opt_state, start_step: int | None = None) -> tuple:
        self._install_preemption_hook()
        # resume from the latest checkpoint when present
        step0 = 0
        latest = self.ckpt.latest_step()
        if start_step is not None:
            step0 = start_step
        elif latest is not None:
            (params, opt_state), extra = self.ckpt.restore((params, opt_state))
            step0 = int(extra.get("next_step", latest + 1))
            log.info("resumed from checkpoint at step %d", step0)
        prefetch = Prefetcher(self.dataset, start_step=step0)
        step_times: list[float] = []
        try:
            for step in range(step0, self.tc.steps):
                t0 = time.perf_counter()
                data_step, batch = prefetch.next()
                assert data_step == step, (data_step, step)
                batch = self.to_device(batch)
                params, opt_state, metrics = self.step_fn(params, opt_state, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                step_times.append(dt)
                med = float(np.median(step_times[-32:]))
                if len(step_times) > 4 and dt > self.tc.watchdog_factor * med:
                    log.warning(
                        "straggler: step %d took %.2fs (median %.2fs)", step, dt, med
                    )
                self.history.append({"step": step, "loss": loss, "time_s": dt})
                if step % self.tc.log_every == 0:
                    log.info("step %5d loss %.4f (%.2fs/step)", step, loss, dt)
                must_save = (
                    self._preempted
                    or (step + 1) % self.tc.ckpt_every == 0
                    or step + 1 == self.tc.steps
                )
                if must_save:
                    self.ckpt.save(step + 1, (params, opt_state), {"next_step": step + 1})
                if self._preempted:
                    log.warning("exiting after preemption checkpoint (step %d)", step)
                    break
        finally:
            prefetch.close()
        return params, opt_state
