from repro.utils.logging import get_logger
from repro.utils.timing import Timer, measure_wall_time

__all__ = ["get_logger", "Timer", "measure_wall_time"]
