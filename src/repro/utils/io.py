"""Crash-safe file writing.

A serving fleet persists its tuning cache and telemetry state while live
traffic is in flight; a plain ``write_text`` interrupted mid-write (OOM
kill, preemption, power loss) leaves a truncated JSON file that forces the
next process into a cold start — exactly the degradation the cache exists
to avoid. ``atomic_write_text`` writes to a temporary file in the *same
directory* (same filesystem, so the rename is atomic) and ``os.replace``s
it into place: readers see either the old complete file or the new one,
never a torn write.
"""

from __future__ import annotations

import os
import tempfile
from pathlib import Path


def atomic_write_text(path: str | Path, text: str) -> Path:
    """Write ``text`` to ``path`` atomically (temp file + ``os.replace``)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(
        prefix=f".{path.name}.", suffix=".tmp", dir=path.parent
    )
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return path
