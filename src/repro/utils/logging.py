"""Structured logging for the repro framework.

One logger per subsystem; format includes wall-clock so multi-hour runs
(dataset collection, dry-run sweeps) are auditable after the fact.

Two knobs, settable programmatically (``configure``) or via environment:

* level — ``REPRO_LOG_LEVEL`` (default INFO);
* JSON-line mode — ``REPRO_LOG_JSON=1`` emits one JSON object per record
  (``ts``/``level``/``logger``/``msg`` + exception text when present), the
  shape log shippers and the obs aggregation tooling ingest without regex.
"""

from __future__ import annotations

import json
import logging
import os
import sys
import time

_FORMAT = "%(asctime)s %(levelname).1s %(name)s: %(message)s"
_configured = False
_handler: logging.Handler | None = None


class JsonLineFormatter(logging.Formatter):
    """One JSON object per record — machine-parseable structured logs."""

    def format(self, record: logging.LogRecord) -> str:
        rec = {
            "ts": round(record.created, 6),
            "time": time.strftime(
                "%Y-%m-%dT%H:%M:%S", time.localtime(record.created)
            ),
            "level": record.levelname,
            "logger": record.name,
            "msg": record.getMessage(),
        }
        if record.exc_info:
            rec["exc"] = self.formatException(record.exc_info)
        return json.dumps(rec, default=str)


def _configure_root() -> None:
    global _configured, _handler
    if _configured:
        return
    _handler = logging.StreamHandler(sys.stderr)
    if os.environ.get("REPRO_LOG_JSON", "").strip() in ("1", "true", "yes"):
        _handler.setFormatter(JsonLineFormatter())
    else:
        _handler.setFormatter(logging.Formatter(_FORMAT, datefmt="%H:%M:%S"))
    root = logging.getLogger("repro")
    root.addHandler(_handler)
    root.setLevel(os.environ.get("REPRO_LOG_LEVEL", "INFO").upper())
    root.propagate = False
    _configured = True


def configure(
    level: str | int | None = None, *, json_lines: bool | None = None
) -> None:
    """Reconfigure the repro root logger after the fact.

    ``level`` accepts a name ("DEBUG") or a numeric level; ``json_lines``
    switches the single stderr handler between the human format and
    one-JSON-object-per-line. Either argument may be omitted to leave that
    aspect unchanged."""
    _configure_root()
    root = logging.getLogger("repro")
    if level is not None:
        root.setLevel(level.upper() if isinstance(level, str) else level)
    if json_lines is not None and _handler is not None:
        _handler.setFormatter(
            JsonLineFormatter()
            if json_lines
            else logging.Formatter(_FORMAT, datefmt="%H:%M:%S")
        )


def get_logger(name: str) -> logging.Logger:
    """Return a logger namespaced under ``repro``."""
    _configure_root()
    if not name.startswith("repro"):
        name = f"repro.{name}"
    return logging.getLogger(name)
