"""Wall-time measurement helpers + streaming sample statistics.

The paper measures kernel latency by repeated runs and averaging (Section
6.3, 500-200000 reps per kernel). ``measure_wall_time`` reproduces that
protocol for host-side (CPU) measurement: warmup, then ``reps`` timed calls
with ``block_until_ready`` so async dispatch does not hide work.

``ewma`` / ``percentile`` / ``RollingStats`` are the aggregation primitives
the telemetry recorder builds per-arm latency estimates from: all-time
count/mean (Welford), an exponentially-weighted moving average that tracks
drift, and percentiles over a bounded recent window.
"""

from __future__ import annotations

import math
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Sequence

import jax


def ewma(prev: float | None, sample: float, alpha: float = 0.2) -> float:
    """One EWMA step; the first sample initializes the average.

    ``alpha`` is the weight of the new sample (0 < alpha <= 1): higher
    tracks drift faster, lower smooths measurement noise harder.
    """
    if not 0.0 < alpha <= 1.0:
        raise ValueError(f"alpha must be in (0, 1], got {alpha}")
    if prev is None:
        return float(sample)
    return alpha * float(sample) + (1.0 - alpha) * prev


def percentile(samples: Sequence[float], q: float) -> float:
    """Linear-interpolated percentile of ``samples`` (q in [0, 100]).

    Returns NaN for an empty window and the sample itself for a single
    observation — callers treat NaN as "no signal yet", not as zero.
    """
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"q must be in [0, 100], got {q}")
    xs = sorted(float(s) for s in samples)
    if not xs:
        return math.nan
    if len(xs) == 1:
        return xs[0]
    pos = (q / 100.0) * (len(xs) - 1)
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(xs) - 1)
    frac = pos - lo
    return xs[lo] * (1.0 - frac) + xs[hi] * frac


class RollingStats:
    """Streaming sample aggregator: all-time mean + EWMA + windowed percentiles.

    ``count``/``mean`` cover every sample ever added (Welford update, no
    storage); ``ewma`` weights recent samples; ``percentile(q)`` and ``min``/
    ``max`` are computed over the last ``window`` samples only, bounding
    memory per telemetry arm.
    """

    def __init__(self, window: int = 128, ewma_alpha: float = 0.2):
        if window < 1:
            raise ValueError("window must be >= 1")
        self.window = int(window)
        self.ewma_alpha = float(ewma_alpha)
        self.count = 0
        self.mean = 0.0
        self._m2 = 0.0  # Welford sum of squared deviations
        self.ewma: float | None = None
        self.last: float | None = None
        self._recent: deque[float] = deque(maxlen=self.window)

    def add(self, sample: float) -> None:
        x = float(sample)
        self.count += 1
        delta = x - self.mean
        self.mean += delta / self.count
        self._m2 += delta * (x - self.mean)
        self.ewma = ewma(self.ewma, x, self.ewma_alpha)
        self.last = x
        self._recent.append(x)

    @property
    def std(self) -> float:
        if self.count < 2:
            return 0.0
        return math.sqrt(self._m2 / (self.count - 1))

    def percentile(self, q: float) -> float:
        return percentile(self._recent, q)

    def window_mean(self) -> float:
        """Mean over the last ``window`` samples only (NaN when empty) — the
        short-horizon signal burn-rate windows need, where the all-time
        ``mean`` would dilute a fresh overload with ancient history."""
        if not self._recent:
            return math.nan
        return sum(self._recent) / len(self._recent)

    def window_min(self) -> float:
        return min(self._recent) if self._recent else math.nan

    def window_max(self) -> float:
        return max(self._recent) if self._recent else math.nan

    def as_dict(self) -> dict[str, float]:
        return {
            "count": self.count,
            "mean": self.mean,
            "std": self.std,
            "ewma": math.nan if self.ewma is None else self.ewma,
            "p50": self.percentile(50),
            "p95": self.percentile(95),
        }


@dataclass
class Timer:
    """Accumulating context-manager timer, reusable across sections."""

    elapsed: float = 0.0
    _t0: float = field(default=0.0, repr=False)

    def __enter__(self) -> "Timer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: Any) -> None:
        self.elapsed += time.perf_counter() - self._t0


def _block(out: Any) -> None:
    for leaf in jax.tree.leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def measure_wall_time(
    fn: Callable[[], Any],
    *,
    warmup: int = 2,
    reps: int = 5,
    min_time_s: float = 0.0,
) -> dict[str, float]:
    """Time ``fn`` with warmup; returns mean/min/std seconds over reps.

    ``min_time_s`` keeps measuring past ``reps`` until the accumulated timed
    window reaches the floor — the paper's variable 500-200000 rep protocol,
    bounded for CPU practicality.
    """
    for _ in range(warmup):
        _block(fn())
    samples: list[float] = []
    total = 0.0
    while len(samples) < reps or total < min_time_s:
        t0 = time.perf_counter()
        _block(fn())
        dt = time.perf_counter() - t0
        samples.append(dt)
        total += dt
        if len(samples) >= 10000:  # hard cap
            break
    n = len(samples)
    mean = sum(samples) / n
    var = sum((s - mean) ** 2 for s in samples) / max(n - 1, 1)
    return {
        "mean_s": mean,
        "min_s": min(samples),
        "std_s": var**0.5,
        "reps": float(n),
    }
