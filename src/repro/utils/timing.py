"""Wall-time measurement helpers.

The paper measures kernel latency by repeated runs and averaging (Section
6.3, 500-200000 reps per kernel). ``measure_wall_time`` reproduces that
protocol for host-side (CPU) measurement: warmup, then ``reps`` timed calls
with ``block_until_ready`` so async dispatch does not hide work.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax


@dataclass
class Timer:
    """Accumulating context-manager timer, reusable across sections."""

    elapsed: float = 0.0
    _t0: float = field(default=0.0, repr=False)

    def __enter__(self) -> "Timer":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: Any) -> None:
        self.elapsed += time.perf_counter() - self._t0


def _block(out: Any) -> None:
    for leaf in jax.tree.leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def measure_wall_time(
    fn: Callable[[], Any],
    *,
    warmup: int = 2,
    reps: int = 5,
    min_time_s: float = 0.0,
) -> dict[str, float]:
    """Time ``fn`` with warmup; returns mean/min/std seconds over reps.

    ``min_time_s`` keeps measuring past ``reps`` until the accumulated timed
    window reaches the floor — the paper's variable 500-200000 rep protocol,
    bounded for CPU practicality.
    """
    for _ in range(warmup):
        _block(fn())
    samples: list[float] = []
    total = 0.0
    while len(samples) < reps or total < min_time_s:
        t0 = time.perf_counter()
        _block(fn())
        dt = time.perf_counter() - t0
        samples.append(dt)
        total += dt
        if len(samples) >= 10000:  # hard cap
            break
    n = len(samples)
    mean = sum(samples) / n
    var = sum((s - mean) ** 2 for s in samples) / max(n - 1, 1)
    return {
        "mean_s": mean,
        "min_s": min(samples),
        "std_s": var**0.5,
        "reps": float(n),
    }
