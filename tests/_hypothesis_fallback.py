"""Stand-in ``hypothesis`` module for environments without the real package.

conftest.py registers this in ``sys.modules`` under the name ``hypothesis``
when the real library is missing, so test modules can keep their plain
``from hypothesis import given, settings, strategies as st`` imports.
Property-based tests then collect normally but are *skipped* (the ``given``
decorator replaces the test body with a ``pytest.skip``); everything else in
those modules runs. Install the real dependency with ``pip install -e
.[test]`` to run the property-based tests.
"""

from __future__ import annotations

import inspect
import sys
import types

import pytest

SKIP_REASON = "hypothesis not installed (pip install -e .[test])"


class _Strategy:
    """Absorbs any strategy construction/combination at decoration time."""

    def __getattr__(self, name):  # .map, .filter, .flatmap, ...
        return self

    def __call__(self, *args, **kwargs):
        return self

    def __or__(self, other):
        return self

    def __repr__(self):
        return "<stub strategy>"


class _StrategiesModule(types.ModuleType):
    def __init__(self):
        super().__init__("hypothesis.strategies")

    def __getattr__(self, name):  # st.integers, st.floats, st.builds, ...
        return _Strategy()


def given(*given_args, **given_kwargs):
    def decorate(fn):
        def skipped(*a, **k):
            pytest.skip(SKIP_REASON)

        # Mirror hypothesis: the wrapper's signature is the test's signature
        # minus the strategy-supplied parameters, so pytest.mark.parametrize
        # args on the same test still resolve during collection.
        sig = inspect.signature(fn)
        params = [p for n, p in sig.parameters.items() if n not in given_kwargs]
        if given_args:  # positional strategies fill from the right
            params = params[: -len(given_args)] if len(given_args) <= len(params) else []
        skipped.__signature__ = sig.replace(parameters=params)
        skipped.__name__ = fn.__name__
        skipped.__doc__ = fn.__doc__
        return skipped

    return decorate


class settings:  # noqa: N801 - mirrors the hypothesis API
    def __init__(self, *args, **kwargs):
        pass

    def __call__(self, fn):
        return fn

    @staticmethod
    def register_profile(*args, **kwargs):
        pass

    @staticmethod
    def load_profile(*args, **kwargs):
        pass


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"


def assume(condition) -> bool:
    return bool(condition)


def install() -> types.ModuleType:
    """Register the stub as ``hypothesis`` (+``.strategies``) in sys.modules."""
    mod = types.ModuleType("hypothesis")
    strategies = _StrategiesModule()
    mod.given = given
    mod.settings = settings
    mod.HealthCheck = HealthCheck
    mod.assume = assume
    mod.strategies = strategies
    mod.__is_fallback_stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
    return mod
