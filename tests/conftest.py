"""Shared test fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches
must see the single real CPU device; only launch/dryrun.py forces 512
placeholder devices (and only when executed as a script).

``hypothesis`` is an optional test dependency (the ``[test]`` extra). When
absent, a stub is installed so the suite still collects; property-based
tests are skipped instead of killing collection with an ImportError."""

import numpy as np
import pytest

try:
    from hypothesis import settings, HealthCheck
except ModuleNotFoundError:
    import _hypothesis_fallback

    _hyp = _hypothesis_fallback.install()
    settings, HealthCheck = _hyp.settings, _hyp.HealthCheck

# single-core container: keep hypothesis example counts modest by default
settings.register_profile(
    "repro",
    max_examples=20,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("repro")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
