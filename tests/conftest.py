"""Shared test fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches
must see the single real CPU device; only launch/dryrun.py forces 512
placeholder devices (and only when executed as a script)."""

import numpy as np
import pytest
from hypothesis import settings, HealthCheck

# single-core container: keep hypothesis example counts modest by default
settings.register_profile(
    "repro",
    max_examples=20,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("repro")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
