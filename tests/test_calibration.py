"""Measurement-calibrated cost model: affine fit quality, uncalibrated
fallback identity, persistence round-trip, the block-count flip once launch
overhead is charged, and the session calibrate() loop (telemetry -> fit ->
cache eviction -> persisted sibling artifact)."""

import numpy as np
import pytest

from repro.core.objectives import (
    HARDWARE,
    TPU_V4,
    CalibratedCostModel,
    FormatCalibration,
    MatrixStats,
    TpuCostModel,
)
from repro.core.session import AutoSpmvSession, _calibration_path
from repro.kernels.common import DEFAULT_SCHEDULE
from repro.partition import plan_partitioned
from repro.sparse.generate import random_matrix
from repro.sparse.registry import format_names
from repro.telemetry import TelemetryRecorder

from tests.test_partition import StubPredictor, hetero_matrix, stub_tuner


@pytest.fixture(scope="module")
def stats():
    dense = random_matrix(256, 6.0, "powerlaw", seed=4).astype(np.float32)
    return MatrixStats(dense)


# ----------------------------------------------------------------- fallback


def test_uncalibrated_equals_base_model(stats):
    base, uncal = TpuCostModel(), CalibratedCostModel()
    for fmt in format_names():
        for sched in (DEFAULT_SCHEDULE, DEFAULT_SCHEDULE.replace(unroll=4)):
            assert uncal.evaluate(stats, fmt, sched) == base.evaluate(
                stats, fmt, sched
            )


def test_unknown_format_falls_back(stats):
    cal = CalibratedCostModel(
        corrections={"ell": FormatCalibration(1.0, 2.0, samples=8)}
    )
    assert cal.evaluate(stats, "csr", DEFAULT_SCHEDULE) == TpuCostModel().evaluate(
        stats, "csr", DEFAULT_SCHEDULE
    )


# ---------------------------------------------------------------------- fit


def test_affine_fit_recovers_overhead_and_scale(stats):
    preds = [1e-5 * (1 + i) for i in range(32)]
    samples = {"csr": [(p, 4.0 * p + 3e-4) for p in preds]}
    cal = CalibratedCostModel.fit(samples)
    c = cal.corrections["csr"]
    assert c.latency_scale == pytest.approx(4.0)
    assert c.launch_overhead_s == pytest.approx(3e-4)
    assert c.samples == 32
    base = TpuCostModel().evaluate(stats, "csr", DEFAULT_SCHEDULE)
    corrected = cal.evaluate(stats, "csr", DEFAULT_SCHEDULE)
    assert corrected.latency == pytest.approx(3e-4 + 4.0 * base.latency)
    assert corrected.energy == base.energy  # energy stays modeled


def test_single_sample_is_scale_only():
    cal = CalibratedCostModel.fit({"ell": [(1e-4, 5e-4)]})
    c = cal.corrections["ell"]
    assert c.latency_scale == pytest.approx(5.0)
    assert c.launch_overhead_s == 0.0


def test_degenerate_fit_falls_back_to_rescale():
    # measured DECREASES with predicted: the affine fit would extrapolate
    # negative for small kernels, so the safe pure rescale must win
    pairs = [(1e-5 * (1 + i), 1e-3 / (1 + i)) for i in range(8)]
    cal = CalibratedCostModel.fit({"csr": pairs})
    c = cal.corrections["csr"]
    assert c.launch_overhead_s == 0.0 and c.latency_scale > 0


def test_error_shrinks_as_telemetry_accumulates():
    """Mean relative error vs measured is monotone non-increasing (within
    noise) as synthetic telemetry accumulates: more pairs, better fit."""
    rng = np.random.default_rng(0)
    true_scale, true_overhead = 6.0, 5e-4
    preds = 1e-5 * (1 + rng.random(256) * 40)
    meas = true_overhead + true_scale * preds * (1 + 0.05 * rng.standard_normal(256))

    def mre(n):
        cal = CalibratedCostModel.fit({"csr": list(zip(preds[:n], meas[:n]))})
        c = cal.corrections["csr"]
        fitted = c.launch_overhead_s + c.latency_scale * preds
        return float(np.mean(np.abs(fitted - meas) / meas))

    errs = [mre(n) for n in (2, 8, 32, 256)]
    assert errs[-1] <= errs[0]
    # and the calibrated model beats the raw model by far more than 2x
    raw_err = float(np.mean(np.abs(preds - meas) / meas))
    assert errs[-1] <= raw_err / 2


# ------------------------------------------------------------- persistence


def test_save_load_round_trip(tmp_path):
    cal = CalibratedCostModel.fit(
        {"csr": [(1e-5, 2e-4), (2e-5, 3e-4)], "sell": [(1e-5, 9e-5)]},
        hw=TPU_V4,
    )
    path = tmp_path / "cal.json"
    cal.save(path)
    loaded = CalibratedCostModel.load(path)
    assert loaded.hw is HARDWARE["tpu_v4"]
    assert loaded.corrections == cal.corrections
    with pytest.raises(ValueError):
        path.write_text('{"version": 99}')
        CalibratedCostModel.load(path)


# --------------------------------------------------------------- plan flip


def test_block_count_flips_once_launch_overhead_is_calibrated():
    """The uncalibrated planner partitions the hetero matrix; charging a
    large measured per-launch cost makes k launches lose to one."""
    dense = hetero_matrix()
    uncal = plan_partitioned(StubPredictor(), dense, "latency")
    assert uncal.partitioned and uncal.n_blocks > 1

    overhead = 10.0 * uncal.monolithic.latency
    cal = CalibratedCostModel(
        corrections={
            f: FormatCalibration(launch_overhead_s=overhead, samples=4)
            for f in format_names()
        }
    )
    flipped = plan_partitioned(StubPredictor(), dense, "latency", cost_model=cal)
    assert not flipped.partitioned and flipped.n_blocks == 1


# ------------------------------------------------------------------ session


def test_session_calibrate_closes_the_loop(tmp_path):
    cache_path = tmp_path / "tuning.json"
    session = AutoSpmvSession(
        stub_tuner(), cache_path=cache_path, telemetry=TelemetryRecorder()
    )
    assert session.cost_model is None
    dense = hetero_matrix()
    res = session.partitioned_optimize(dense, "latency")
    assert res.n_blocks > 1

    # telemetry says every block really costs a large fixed launch overhead
    overhead = 10.0 * res.plan.monolithic.latency
    for bp in res.plan.blocks:
        pred = max(bp.modeled.latency, 1e-7)
        for rep in range(3):
            session.telemetry.observe(
                bucket=f"b{bp.block.index}",
                objective="latency",
                fmt=bp.fmt,
                measured_s=overhead + pred * (1 + 0.01 * rep),
                predicted_s=pred,
            )

    model = session.calibrate()
    assert session.cost_model is model and model.corrections
    # the stale composite plan was evicted: the next request re-plans with
    # the calibrated model and stops fantasizing that launches are free
    assert session.cache.peek(res.bucket, "latency", res.mode) is None
    res2 = session.partitioned_optimize(dense, "latency")
    assert not res2.cache_hit
    assert res2.n_blocks == 1

    # persisted next to the cache; a restarted session auto-loads it
    cal_path = _calibration_path(cache_path)
    assert cal_path.exists()
    session.save()
    warm = AutoSpmvSession(stub_tuner(), cache_path=cache_path)
    assert warm.cost_model is not None
    assert warm.cost_model.corrections.keys() == model.corrections.keys()


def test_session_calibrate_requires_telemetry():
    session = AutoSpmvSession(stub_tuner())
    with pytest.raises(ValueError):
        session.calibrate()
