"""Top-k sparsification edge cases + the magnitude_prune export helper
(optim/compress.py) — the weight-pruning substrate of the sparse LM
serving path."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.compress import _topk_sparsify, magnitude_prune


# ----------------------------------------------------------- _topk_sparsify
def test_topk_frac_zero_keeps_nothing():
    g = jnp.asarray([3.0, -1.0, 2.0, 0.5])
    out = _topk_sparsify(g, 0.0)
    assert np.array_equal(np.asarray(out), np.zeros(4, np.float32))
    out = _topk_sparsify(g, -0.25)
    assert not np.any(np.asarray(out))


def test_topk_frac_one_returns_unchanged():
    g = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)).astype(np.float32))
    for frac in (1.0, 1.5):
        out = _topk_sparsify(g, frac)
        assert np.array_equal(np.asarray(out), np.asarray(g))


def test_topk_small_positive_frac_keeps_at_least_one():
    g = jnp.asarray([3.0, -1.0, 2.0, 0.5])
    out = np.asarray(_topk_sparsify(g, 1e-6))
    assert np.count_nonzero(out) == 1
    assert out[0] == 3.0  # the largest magnitude survives


def test_topk_keeps_all_threshold_ties():
    # four entries tie at the threshold magnitude: the >= compare keeps them
    # all, so realized density exceeds frac (documented determinism choice)
    g = jnp.asarray([2.0, -2.0, 2.0, 2.0, 1.0, -1.0, 0.5, 0.25])
    out = np.asarray(_topk_sparsify(g, 0.25))  # k = 2, but 4 entries tie
    assert np.count_nonzero(out) == 4
    assert np.array_equal(out[:4], np.asarray([2.0, -2.0, 2.0, 2.0], np.float32))


# ---------------------------------------------------------- magnitude_prune
def test_magnitude_prune_basic_density():
    w = np.random.default_rng(1).normal(size=(32, 32)).astype(np.float32)
    pruned, density = magnitude_prune(w, 0.1)
    k = int(round(0.1 * w.size))
    assert np.count_nonzero(pruned) == k
    assert density == pytest.approx(k / w.size)
    # kept entries are exactly the k largest magnitudes, values unchanged
    kept = np.abs(pruned[pruned != 0])
    assert kept.min() >= np.sort(np.abs(w).reshape(-1))[-k]
    assert np.all((pruned == 0) | (pruned == w))


def test_magnitude_prune_edges():
    w = np.asarray([[1.0, -2.0], [0.0, 3.0]], np.float32)
    full, d_full = magnitude_prune(w, 1.0)
    assert np.array_equal(full, w)
    assert d_full == pytest.approx(3 / 4)  # reports ACTUAL density incl. zeros
    zero, d_zero = magnitude_prune(w, 0.0)
    assert not np.any(zero) and d_zero == 0.0
    empty, d_empty = magnitude_prune(np.zeros((0,), np.float32), 0.5)
    assert empty.size == 0 and d_empty == 0.0


def test_magnitude_prune_tie_break_deterministic_exact_k():
    # all magnitudes equal: unlike _topk_sparsify, the helper keeps EXACTLY
    # k entries, earlier flat index first (stable argsort contract)
    w = np.full((4, 4), 2.0, np.float32)
    pruned, density = magnitude_prune(w, 0.25)
    assert np.count_nonzero(pruned) == 4
    assert np.count_nonzero(pruned.reshape(-1)[:4]) == 4  # first flat indices win
    assert density == pytest.approx(0.25)
    again, _ = magnitude_prune(w, 0.25)
    assert np.array_equal(pruned, again)


def test_magnitude_prune_achieved_density_below_request():
    # zeros among the top-k magnitudes: achieved density falls below request
    w = np.zeros((4, 4), np.float32)
    w[0, 0] = 1.0
    pruned, density = magnitude_prune(w, 0.5)
    assert np.count_nonzero(pruned) == 1
    assert density == pytest.approx(1 / 16)
    assert density < 0.5


def test_magnitude_prune_output_is_float32_copy():
    w = np.random.default_rng(2).normal(size=(8,)).astype(np.float64)
    pruned, _ = magnitude_prune(w, 0.5)
    assert pruned.dtype == np.float32
    pruned[:] = 0  # mutating the output must not touch the input
    assert np.any(w)
