"""Tests for the Auto-SpMV core: objectives, tuning space, dataset,
predictor, overhead rule, end-to-end modes."""

import math

import numpy as np
import pytest

from repro.core import (
    ALL_KNOBS,
    DEFAULT_CONFIG,
    KNOBS,
    MINIMIZE,
    OBJECTIVES,
    AutoSpMV,
    AutoSpmvPredictor,
    MatrixStats,
    OverheadPredictor,
    PredictorConfig,
    TpuCostModel,
    TPU_V4,
    TPU_V5E,
    TuningConfig,
    collect_dataset,
    compile_time_space,
    extract_features,
    footprint,
    full_space,
    measure_overheads,
)
from repro.core.tuning_space import space_size
from repro.kernels.common import DEFAULT_SCHEDULE, KernelSchedule
from repro.sparse.generate import MATRIX_NAMES, generate_by_name, random_matrix

SCALE = 0.0015


@pytest.fixture(scope="module")
def small_dataset():
    return collect_dataset(scale=SCALE, names=MATRIX_NAMES[:8], n_extra=4)


@pytest.fixture(scope="module")
def predictor(small_dataset):
    return AutoSpmvPredictor(PredictorConfig(max_regressor_samples=1500)).fit(small_dataset)


# ------------------------------------------------------------------ objectives
def test_footprint_feasibility_rules():
    stats = MatrixStats(random_matrix(256, 8.0, "fem", seed=0))
    ok = footprint(stats, "ell", DEFAULT_SCHEDULE)
    assert ok.feasible and ok.useful_flops == 2.0 * stats.nnz
    stream = footprint(stats, "ell", DEFAULT_SCHEDULE.replace(x_residency="stream"))
    assert not stream.feasible  # ELL needs VMEM-resident X on TPU
    bell_stream = footprint(stats, "bell", DEFAULT_SCHEDULE.replace(x_residency="stream"))
    assert bell_stream.feasible  # BELL streams via scalar-prefetch DMA


def test_cost_model_objective_identities():
    stats = MatrixStats(random_matrix(300, 10.0, "fem", seed=1))
    model = TpuCostModel()
    for fmt in ("csr", "ell", "bell", "sell"):
        v = model.evaluate(stats, fmt, DEFAULT_SCHEDULE)
        assert v.feasible
        assert v.latency > 0 and v.energy > 0
        # power = dynamic energy / latency, idle excluded (paper §6.3)
        assert v.power <= TPU_V5E.p_max - TPU_V5E.p_static + 1e-9
        if v.power < TPU_V5E.p_max - TPU_V5E.p_static - 1e-9:
            assert v.power == pytest.approx(v.energy / v.latency, rel=1e-6)
        # efficiency = useful MFLOPS / W
        fp = footprint(stats, fmt, DEFAULT_SCHEDULE)
        assert v.efficiency == pytest.approx(
            fp.useful_flops / v.latency / 1e6 / v.power, rel=1e-6
        )


def test_cost_model_padding_penalty():
    """Power-law matrices must hurt ELL (padding) more than CSR — the
    paper's core motivation for format selection."""
    model = TpuCostModel()
    skew = MatrixStats(random_matrix(512, 8.0, "powerlaw", seed=2))
    regular = MatrixStats(random_matrix(512, 8.0, "fem", seed=2))
    ell_vs_csr_skew = (
        model.evaluate(skew, "ell", DEFAULT_SCHEDULE).energy
        / model.evaluate(skew, "csr", DEFAULT_SCHEDULE).energy
    )
    ell_vs_csr_reg = (
        model.evaluate(regular, "ell", DEFAULT_SCHEDULE).energy
        / model.evaluate(regular, "csr", DEFAULT_SCHEDULE).energy
    )
    assert ell_vs_csr_skew > ell_vs_csr_reg


def test_hardware_profiles_differ():
    stats = MatrixStats(random_matrix(256, 8.0, "fem", seed=3))
    v5e = TpuCostModel(TPU_V5E).evaluate(stats, "bell", DEFAULT_SCHEDULE)
    v4 = TpuCostModel(TPU_V4).evaluate(stats, "bell", DEFAULT_SCHEDULE)
    assert v5e.latency != v4.latency


# ---------------------------------------------------------------- tuning space
def test_space_sizes():
    assert space_size() == 1792  # 4 fmt x 7 rpb x 4 nt x (4,2,1,1 valid unrolls)x2x2
    csr_only = list(compile_time_space())
    assert all(c.fmt == "csr" for c in csr_only)
    assert DEFAULT_CONFIG in list(full_space())


def test_knob_mapping_covers_paper_params():
    assert set(("tb_size", "maxrregcount", "memory")) <= set(KNOBS)
    cfg = DEFAULT_CONFIG
    assert KNOBS["tb_size"][0] == "rows_per_block"
    assert getattr(cfg.schedule, KNOBS["maxrregcount"][0]) == cfg.schedule.unroll


# --------------------------------------------------------------------- dataset
def test_dataset_shape_and_labels(small_dataset):
    ds = small_dataset
    assert len(ds) == len(ds.matrices) * 1792
    for obj in OBJECTIVES:
        best = ds.best_record(ds.matrices[0], obj)
        assert best.feasible
        default = ds.default_record(ds.matrices[0])
        if MINIMIZE[obj]:
            assert best.objective(obj) <= default.objective(obj) + 1e-12
        else:
            assert best.objective(obj) >= default.objective(obj) - 1e-12


def test_dataset_roundtrip(tmp_path, small_dataset):
    p = tmp_path / "ds.json"
    small_dataset.save(p)
    from repro.core import TuningDataset

    ds2 = TuningDataset.load(p)
    assert len(ds2) == len(small_dataset)
    r1, r2 = small_dataset.records[5], ds2.records[5]
    assert r1.config == r2.config and r1.latency == pytest.approx(r2.latency)


# ------------------------------------------------------------------- predictor
def test_predictor_formats_valid(predictor, small_dataset):
    for m in small_dataset.matrices[:4]:
        f = small_dataset.for_matrix(m)[0].features
        for obj in OBJECTIVES:
            fmt = predictor.predict_format(f, obj)
            assert fmt in ("csr", "ell", "bell", "sell")
            sched = predictor.predict_schedule(f, obj)
            assert isinstance(sched, KernelSchedule)


def test_predictor_training_accuracy(predictor, small_dataset):
    """On its own training matrices the tuned tree must recover the best
    format for most matrices (the paper reports 100% on 30 matrices)."""
    hits = total = 0
    for m in small_dataset.matrices:
        f = small_dataset.for_matrix(m)[0].features
        want = small_dataset.best_record(m, "latency").config.fmt
        hits += predictor.predict_format(f, "latency") == want
        total += 1
    assert hits / total >= 0.8


def test_regressor_magnitude(predictor, small_dataset):
    m = small_dataset.matrices[0]
    f = small_dataset.for_matrix(m)[0].features
    est = predictor.estimate_objective(f, DEFAULT_CONFIG, "latency")
    act = small_dataset.default_record(m).latency
    assert est == pytest.approx(act, rel=1.0)  # within 2x on train data


# ----------------------------------------------------------- overhead decision
def test_overhead_predictor_accuracy():
    names = MATRIX_NAMES[:8]
    samples = [measure_overheads(generate_by_name(n, scale=SCALE), n) for n in names]
    op = OverheadPredictor().fit(samples)
    # in-sample sanity: predictions positive and ~right order of magnitude
    for s in samples:
        est = op.predict_f(s.features)
        assert est >= 0.0
        assert op.total_overhead(s.features, "ell") > 0.0


def test_runtime_mode_decision_rule(predictor, small_dataset):
    dense = generate_by_name(MATRIX_NAMES[0], scale=SCALE)
    samples = [
        measure_overheads(generate_by_name(n, scale=SCALE), n) for n in MATRIX_NAMES[:6]
    ]
    tuner = AutoSpMV(predictor, OverheadPredictor().fit(samples))
    few = tuner.run_time_optimize(dense, "efficiency", n_iterations=1)
    many = tuner.run_time_optimize(dense, "efficiency", n_iterations=10_000_000)
    # with a million x more iterations the conversion can only become more
    # attractive; a decision to convert at n=1 must persist at n=1e7
    if few.convert:
        assert many.convert
    if many.best_format == "csr":
        assert not many.convert  # no conversion to the format we hold


def test_compile_time_mode_end_to_end(predictor):
    dense = generate_by_name("consph", scale=SCALE)
    tuner = AutoSpMV(predictor)
    res = tuner.compile_time_optimize(dense, "latency")
    x = np.random.default_rng(0).normal(size=dense.shape[1]).astype(np.float32)
    y = np.asarray(res.kernel(x))
    # the tuner may legitimately pick bf16 accumulation for latency
    tol = 5e-2 if res.schedule.accum_dtype == "bfloat16" else 1e-4
    ref = dense @ x
    scale = np.abs(ref).max() + 1e-9
    np.testing.assert_allclose(y / scale, ref / scale, rtol=tol, atol=tol)
    assert set(res.predicted) == set(OBJECTIVES)
    assert all(math.isfinite(v) for v in res.predicted.values())
