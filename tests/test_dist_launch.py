"""Tests for sharding rules, HLO collective parsing, input specs, and the
roofline math (the dry-run pieces that don't need 512 devices)."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, applicable, cells_for, get_config
from repro.dist.sharding import abstract_mesh, spec_for
from repro.launch.hlo_analysis import (
    CollectiveOp,
    parse_collectives,
    summarize_collectives,
)
from repro.launch.specs import model_flops, train_batch_specs

# abstract_mesh() papers over the AbstractMesh constructor change across jax
# releases (pairs tuple in <=0.4.x, (sizes, names) afterwards)
MESH1 = abstract_mesh((16, 16), ("data", "model"))
MESH2 = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


# ------------------------------------------------------------- sharding rules
def test_fsdp_tp_weight_sharding():
    # llama3 W_q: (d_model, heads, head_dim)
    spec = spec_for(MESH1, (4096, 32, 128), ("embed", "heads", None))
    assert spec == P("data", "model")
    spec2 = spec_for(MESH2, (4096, 32, 128), ("embed", "heads", None))
    assert spec2 == P(("pod", "data"), "model")


def test_kv_heads_replicated_when_indivisible():
    # kv=8 on a 16-way model axis -> replicated (kv-repeat convention)
    spec = spec_for(MESH1, (4096, 8, 128), ("embed", "kv", None))
    assert spec == P("data")
    # kv=32 divides -> sharded
    spec = spec_for(MESH1, (4096, 32, 128), ("embed", "kv", None))
    assert spec == P("data", "model")


def test_duplicate_axis_not_reused():
    # sLSTM w_down: ("embed", "embed") — second occurrence must replicate
    spec = spec_for(MESH1, (2048, 2048), ("embed", "embed"))
    assert spec == P("data")


def test_vocab_sharding():
    spec = spec_for(MESH1, (128256, 4096), ("vocab", "embed"))
    assert spec == P("model", "data")


def test_indivisible_batch_replicated():
    spec = spec_for(MESH2, (1, 128), ("batch", None))  # long_500k batch=1
    assert spec == P()


# --------------------------------------------------------------- HLO analysis
HLO_SAMPLE = """
  %all-gather.1 = bf16[16,512]{1,0} all-gather(bf16[16,32]{1,0} %p0), channel_id=1, replica_groups=[16,16]<=[256], dimensions={1}
  %all-reduce.2 = f32[128,64]{1,0} all-reduce(f32[128,64]{1,0} %p1), channel_id=2, replica_groups={{0,1,2,3}}, to_apply=%add
  %reduce-scatter.3 = f32[8,16]{1,0} reduce-scatter(f32[8,256]{1,0} %p2), channel_id=3, replica_groups=[1,16]<=[16], dimensions={1}
  %collective-permute.4 = bf16[4,4]{1,0} collective-permute(bf16[4,4]{1,0} %p3), channel_id=4, source_target_pairs={{0,1}}
  %fusion.9 = f32[2,2]{1,0} fusion(f32[2,2]{1,0} %p4), kind=kLoop
"""


def test_parse_collectives_kinds_and_sizes():
    ops = parse_collectives(HLO_SAMPLE)
    kinds = sorted(o.kind for o in ops)
    assert kinds == ["all-gather", "all-reduce", "collective-permute", "reduce-scatter"]
    ag = next(o for o in ops if o.kind == "all-gather")
    assert ag.group_size == 16
    assert ag.result_bytes == 16 * 512 * 2
    assert ag.operand_bytes == ag.result_bytes // 16
    ar = next(o for o in ops if o.kind == "all-reduce")
    assert ar.group_size == 4 and ar.operand_bytes == 128 * 64 * 4
    rs = next(o for o in ops if o.kind == "reduce-scatter")
    assert rs.group_size == 16 and rs.operand_bytes == 8 * 16 * 4 * 16


def test_ring_model_bytes():
    op = CollectiveOp("all-reduce", "f32", 1000, 1000, 4)
    assert op.ring_link_bytes == pytest.approx(2 * 1000 * 3 / 4)
    op = CollectiveOp("all-gather", "f32", 4000, 1000, 4)
    assert op.ring_link_bytes == pytest.approx(3000)
    assert CollectiveOp("all-reduce", "f32", 10, 10, 1).ring_link_bytes == 0.0


def test_summarize_collectives():
    s = summarize_collectives(parse_collectives(HLO_SAMPLE))
    assert s["n_ops"] == 4
    assert s["operand_bytes"] > 0 and s["ring_link_bytes"] > 0


# ------------------------------------------------------------------ cell specs
def test_cell_grid_counts():
    """10 archs x 4 shapes with the documented long_500k skips = 32 runnable
    cells; every skip is a pure full-attention arch."""
    runnable = sum(len(cells_for(get_config(a))) for a in ARCH_IDS)
    assert runnable == 32
    for a in ARCH_IDS:
        cfg = get_config(a)
        ok, reason = applicable(cfg, "long_500k")
        if not ok:
            assert cfg.family not in ("ssm", "hybrid")
            assert "full-attention" in reason


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_batch_specs_match_shape(arch):
    cfg = get_config(arch)
    shape = SHAPES["train_4k"]
    specs = train_batch_specs(cfg, shape)
    total = shape.seq_len
    text = total - (cfg.prefix_len or 0)
    assert specs["labels"].shape == (shape.global_batch, text)
    if cfg.train_input == "embeds":
        assert specs["embeds"].shape == (shape.global_batch, text, cfg.d_model)
    if cfg.prefix_len:
        assert specs["prefix_embeds"].shape[1] == cfg.prefix_len


def test_model_flops_scaling():
    cfg = get_config("llama3-8b")
    train = model_flops(cfg, SHAPES["train_4k"])
    prefill = model_flops(cfg, SHAPES["prefill_32k"])
    decode = model_flops(cfg, SHAPES["decode_32k"])
    # same token count -> train is 3x prefill (fwd+bwd vs fwd)
    assert train / prefill == pytest.approx(3.0)
    # decode computes one token per sequence
    assert decode == pytest.approx(prefill * 128 / (32 * 32768))
    # magnitude: 6 * ~7.5B * 1M tokens
    assert 3e16 < train < 8e16
