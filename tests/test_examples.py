"""Subprocess smoke for the runnable examples: ``examples/serve_lm.py`` must
serve a tiny request stream to completion in both dense and sparse modes
(the sparse mode also runs its built-in dense-vs-sparse numerics check)."""

import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def _run_serve_lm(extra: list[str]) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, str(REPO / "examples" / "serve_lm.py"),
         "--requests", "2", "--slots", "1", "--max-new-tokens", "2", *extra],
        capture_output=True, text=True, timeout=560, cwd=str(REPO), env=env,
    )


@pytest.mark.parametrize("mode", ["dense", "sparse"])
def test_serve_lm_example_smoke(mode):
    proc = _run_serve_lm(["--sparse"] if mode == "sparse" else [])
    assert proc.returncode == 0, proc.stderr[-2000:]
    # both requests generated tokens and the aggregate line printed
    assert "req 0" in proc.stdout and "req 1" in proc.stdout
    assert "4 tokens in" in proc.stdout, proc.stdout
    if mode == "sparse":
        assert "dense-vs-sparse decode logits" in proc.stdout
        assert "energy cells" in proc.stdout
