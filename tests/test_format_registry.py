"""Format-registry contract suite + the fifth-format (BCSR) e2e proof.

The contract tests are parametrized over *every* registered format (the four
seeds plus the BCSR plugin), so any future ``register_format()`` plugin that
is added to ``CONTRACT_FORMATS`` is validated for free:

* dense round-trip through ``from_dense``/``to_dense`` is exact;
* ``prepare`` + ``spmv`` matches the dense oracle (``kernels/ref.py``) over
  schedules, including bf16 accumulation and empty rows;
* the pure-jnp ``reference`` oracle matches the dense product;
* running ``spmv`` on storage prepared with a *different* schedule either
  computes the exact result or raises ``InfeasibleConfig`` — never silently
  corrupts;
* the ``footprint`` model returns finite, non-negative statistics with
  ``useful_flops == 2 * nnz``, and the cost model evaluates it.

``test_bcsr_flows_end_to_end`` is the API-redesign acceptance check: a
format registered *only* via ``register_format()`` (no edits to
ops/tuning_space/objectives/session/adaptive) appears in ``full_space()``,
the tuning dataset, the bandit arm set, and serves correctly through
``SpmvServer``.
"""

import math
import re
from dataclasses import dataclass
from pathlib import Path

import numpy as np
import pytest

from repro.core import (
    MatrixStats,
    TpuCostModel,
    TuningConfig,
    collect_dataset,
    footprint,
    full_space,
)
from repro.core.autotuner import AutoSpMV
from repro.core.predictor import AutoSpmvPredictor, PredictorConfig
from repro.core.session import AutoSpmvSession
from repro.kernels.common import DEFAULT_SCHEDULE, KernelSchedule
from repro.sparse import registry as reg
from repro.sparse.generate import MATRIX_NAMES, random_matrix

SEED_FORMATS = ("csr", "ell", "bell", "sell")
CONTRACT_FORMATS = SEED_FORMATS + ("bcsr",)

CONTRACT_SCHEDULES = [
    DEFAULT_SCHEDULE,
    KernelSchedule(rows_per_block=32, nnz_tile=256, unroll=2),
    KernelSchedule(rows_per_block=16, nnz_tile=128, accum_dtype="bfloat16"),
]


@pytest.fixture()
def with_bcsr():
    """Activate the BCSR plugin for one test, then restore the seed set.

    ``unregister_format`` itself evicts the plugin's memoized kernels, so
    no manual memo hygiene is needed here."""
    from repro.sparse import bcsr

    bcsr.register()
    yield
    reg.unregister_format("bcsr")


def _dense(pattern="fem", n=150, avg=7.0, seed=3):
    return random_matrix(n, avg, pattern, seed=seed).astype(np.float32)


# ------------------------------------------------------------------ contracts
@pytest.mark.parametrize("fmt", CONTRACT_FORMATS)
def test_contract_roundtrip_exact(fmt, with_bcsr):
    spec = reg.get_format(fmt)
    for pattern in ("fem", "powerlaw"):
        dense = _dense(pattern)
        np.testing.assert_array_equal(spec.to_dense(spec.from_dense(dense)), dense)


@pytest.mark.parametrize("fmt", CONTRACT_FORMATS)
def test_contract_prepare_spmv_matches_dense(fmt, with_bcsr):
    spec = reg.get_format(fmt)
    dense = _dense("powerlaw", n=200, avg=8.0, seed=9)
    x = np.random.default_rng(0).normal(size=dense.shape[1]).astype(np.float32)
    ref = dense @ x
    scale = np.abs(ref).max() + 1e-9
    for sched in CONTRACT_SCHEDULES:
        mat = spec.prepare(dense, sched)
        y = np.asarray(spec.spmv(mat, x, sched))
        assert y.shape == (dense.shape[0],)
        tol = 3e-2 if sched.accum_dtype == "bfloat16" else 1e-4
        np.testing.assert_allclose(y / scale, ref / scale, atol=tol, rtol=tol)


@pytest.mark.parametrize("fmt", CONTRACT_FORMATS)
def test_contract_empty_rows_exact_zero(fmt, with_bcsr):
    spec = reg.get_format(fmt)
    dense = np.zeros((64, 64), dtype=np.float32)
    dense[10, 3] = 2.0
    dense[50, 60] = -1.5
    x = np.ones(64, dtype=np.float32)
    mat = spec.prepare(dense, DEFAULT_SCHEDULE)
    y = np.asarray(spec.spmv(mat, x, DEFAULT_SCHEDULE))
    np.testing.assert_allclose(y, dense @ x, atol=1e-6)


@pytest.mark.parametrize("fmt", CONTRACT_FORMATS)
def test_contract_reference_oracle(fmt, with_bcsr):
    spec = reg.get_format(fmt)
    dense = _dense("fem", n=120, avg=6.0, seed=5)
    x = np.random.default_rng(1).normal(size=dense.shape[1]).astype(np.float32)
    y = np.asarray(spec.reference(spec.from_dense(dense), x))
    np.testing.assert_allclose(y, dense @ x, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("fmt", CONTRACT_FORMATS)
def test_contract_misaligned_schedule_exact_or_infeasible(fmt, with_bcsr):
    """A (format, schedule) mismatch must never silently corrupt the result:
    either the kernel re-aligns/computes exactly, or it raises
    ``InfeasibleConfig``."""
    spec = reg.get_format(fmt)
    dense = _dense("fem", n=100, avg=6.0, seed=5)
    x = np.ones(dense.shape[1], dtype=np.float32)
    mat = spec.prepare(dense, KernelSchedule(nnz_tile=128))
    for other in (KernelSchedule(nnz_tile=512), KernelSchedule(rows_per_block=256)):
        try:
            y = np.asarray(spec.spmv(mat, x, other))
        except reg.InfeasibleConfig:
            continue
        np.testing.assert_allclose(y, dense @ x, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("fmt", CONTRACT_FORMATS)
def test_contract_footprint_finite_and_costed(fmt, with_bcsr):
    stats = MatrixStats(_dense("powerlaw", n=256, avg=8.0, seed=0))
    model = TpuCostModel()
    for sched in CONTRACT_SCHEDULES:
        fp = footprint(stats, fmt, sched)
        vals = (
            fp.useful_flops, fp.total_flops, fp.hbm_bytes, fp.gather_elems,
            fp.scatter_elems, fp.grid_steps, fp.mxu_fraction,
            fp.vmem_resident_bytes,
        )
        assert all(math.isfinite(v) and v >= 0.0 for v in vals)
        assert fp.useful_flops == 2.0 * stats.nnz
        assert fp.total_flops >= fp.useful_flops
        assert fp.hbm_bytes > 0 and fp.grid_steps > 0
    # the default schedule must be a feasible point the cost model can rank
    v = model.evaluate(stats, fmt, DEFAULT_SCHEDULE)
    assert v.feasible and v.latency > 0 and v.energy > 0 and v.efficiency > 0


# --------------------------------------------------------------- registry API
def test_registry_seed_state():
    assert reg.format_names() == SEED_FORMATS
    assert reg.default_format() == "csr"
    specs = reg.registered_specs()
    assert tuple(s.name for s in specs) == SEED_FORMATS


def test_registry_rejects_duplicates_and_unknowns():
    csr_spec = reg.get_format("csr")
    with pytest.raises(ValueError, match="already registered"):
        reg.register_format(csr_spec)
    with pytest.raises(ValueError, match="unknown format"):
        reg.get_format("nope")
    with pytest.raises(ValueError, match="not registered"):
        reg.unregister_format("nope")
    with pytest.raises(TypeError, match="no registered format"):
        reg.spec_for(object())


def test_register_dummy_format_appears_everywhere():
    @dataclass(frozen=True)
    class DummyMat:
        dense: np.ndarray
        shape: tuple

    fail = lambda *a, **kw: (_ for _ in ()).throw(NotImplementedError)
    spec = reg.FormatSpec(
        name="dummyfmt",
        container=DummyMat,
        from_dense=lambda d, **kw: DummyMat(np.asarray(d), np.asarray(d).shape),
        to_dense=lambda m: m.dense,
        prepare=fail,
        spmv=fail,
        reference=fail,
        footprint=fail,
        priority=999,
    )
    reg.register_format(spec)
    try:
        assert "dummyfmt" in reg.format_names()
        assert reg.format_names()[-1] == "dummyfmt"  # priority-ordered
        assert reg.default_format() == "csr"  # plugins never displace it
        assert reg.spec_for(DummyMat(np.zeros((1, 1)), (1, 1))).name == "dummyfmt"
        # the tuning space picks it up with zero edits
        assert any(c.fmt == "dummyfmt" for c in full_space())
        # duplicate container under a different name is rejected
        clone = reg.FormatSpec(**{**spec.__dict__, "name": "dummyfmt2"})
        with pytest.raises(ValueError, match="already bound"):
            reg.register_format(clone)
    finally:
        reg.unregister_format("dummyfmt")
    assert "dummyfmt" not in reg.format_names()


def test_unregister_evicts_memoized_kernels(with_bcsr):
    """A memoized PreparedSpmv must not outlive its FormatSpec: serving a
    stale hit after unregistration would crash (or run the old code)."""
    from repro.kernels.ops import compile_spmv, kernel_memoized

    dense = _dense()
    compile_spmv(dense, "bcsr", DEFAULT_SCHEDULE, memo_key="reg-evict")
    compile_spmv(dense, "csr", DEFAULT_SCHEDULE, memo_key="reg-evict")
    assert kernel_memoized("reg-evict", "bcsr", DEFAULT_SCHEDULE)
    reg.unregister_format("bcsr")
    try:
        assert not kernel_memoized("reg-evict", "bcsr", DEFAULT_SCHEDULE)
        # unrelated formats' entries survive
        assert kernel_memoized("reg-evict", "csr", DEFAULT_SCHEDULE)
    finally:
        from repro.sparse import bcsr

        bcsr.register()  # the fixture teardown unregisters again


def test_default_config_tracks_registry_default(with_bcsr):
    """DEFAULT_CONFIG is resolved per access: a plugin registering below the
    seeds' priority becomes the baseline everywhere at once."""
    import repro.core.tuning_space as ts
    from repro.core import compile_time_space

    assert ts.DEFAULT_CONFIG.fmt == "csr"
    bcsr_spec = reg.get_format("bcsr")
    reg.register_format(
        reg.FormatSpec(**{**bcsr_spec.__dict__, "priority": -1}), overwrite=True
    )
    try:
        assert reg.default_format() == "bcsr"
        assert ts.DEFAULT_CONFIG.fmt == "bcsr"
        import repro.core

        assert repro.core.DEFAULT_CONFIG.fmt == "bcsr"
        assert all(c.fmt == "bcsr" for c in compile_time_space())
    finally:
        reg.register_format(bcsr_spec, overwrite=True)
    assert ts.DEFAULT_CONFIG.fmt == "csr"


def test_ops_storage_bound_alias_reads_registry():
    import repro.kernels.ops as ops

    assert ops.MAX_STORAGE_BYTES == reg.MAX_STORAGE_BYTES
    with pytest.raises(AttributeError):
        ops.no_such_attribute


# -------------------------------------------------------- fifth format: e2e
def test_bcsr_row_compression_beats_bell_on_skew(with_bcsr):
    """The CMRS argument: on skewed block occupancy BCSR stores fewer
    blocks than BELL's per-block-row ELL padding."""
    dense = _dense("powerlaw", n=1024, avg=3.0, seed=2)
    stats = MatrixStats(dense)
    sched = KernelSchedule(rows_per_block=8)  # fine-grained 8x128 blocks
    fp_bell = footprint(stats, "bell", sched)
    fp_bcsr = footprint(stats, "bcsr", sched)
    assert fp_bcsr.total_flops < fp_bell.total_flops
    bell = reg.get_format("bell").from_dense(dense, br=8)
    bcsr = reg.get_format("bcsr").from_dense(dense, br=8)
    assert bcsr.data.size < bell.data.size


def test_bcsr_flows_end_to_end(with_bcsr):
    from repro.telemetry import AdaptiveConfig, AdaptiveFormatSelector, TelemetryRecorder
    from repro.train.serve import SpmvRequest, SpmvServer

    assert reg.format_names() == CONTRACT_FORMATS
    assert reg.default_format() == "csr"

    # 1. tuning space: bcsr configs appear with zero edits
    assert {c.fmt for c in full_space()} == set(CONTRACT_FORMATS)

    # 2. dataset + classifier labels over the extended space
    scheds = [DEFAULT_SCHEDULE, KernelSchedule(rows_per_block=32, nnz_tile=256, unroll=2)]
    space = [TuningConfig(f, s) for f in reg.format_names() for s in scheds]
    ds = collect_dataset(scale=0.0012, names=MATRIX_NAMES[:3], n_extra=0, space=space)
    bcsr_recs = [r for r in ds.records if r.config.fmt == "bcsr"]
    assert bcsr_recs and any(r.feasible for r in bcsr_recs)
    pred = AutoSpmvPredictor(PredictorConfig(max_regressor_samples=500)).fit(ds)
    assert pred.format_names_ == CONTRACT_FORMATS
    for obj in ("latency", "energy"):
        est = pred.estimate_objective(
            ds.records[0].features, TuningConfig("bcsr", DEFAULT_SCHEDULE), obj
        )
        assert math.isfinite(est) and est > 0

    # 3. bandit arm set + end-to-end serving through SpmvServer
    sel = AdaptiveFormatSelector(AdaptiveConfig(exploration_fraction=1.0))
    session = AutoSpmvSession(
        AutoSpMV(pred), telemetry=TelemetryRecorder(), adaptive=sel
    )
    server = SpmvServer(session)
    dense = _dense("block", n=180, avg=7.0, seed=4)
    rng = np.random.default_rng(0)
    reqs = [
        SpmvRequest(
            rid=i, dense=dense, x=rng.normal(size=dense.shape[1]).astype(np.float32)
        )
        for i in range(8)
    ]
    done = server.run(reqs)
    for r in done:
        ref = r.dense @ r.x
        scale = np.abs(ref).max() + 1e-9
        np.testing.assert_allclose(r.y / scale, ref / scale, rtol=1e-4, atol=1e-4)
    served = [r.fmt for r in done]
    # with the exploration budget wide open every arm gets pulled: the
    # plugin format was actually served (not just registered)
    assert "bcsr" in served
    arms = {fmt for (_, _, fmt) in session.telemetry.arms()}
    assert "bcsr" in arms


# -------------------------------------------------------------------- hygiene
def test_no_format_literal_dispatch_outside_registry():
    """The CI guard, enforced in-tree too: no new ``fmt == "..."`` dispatch
    chains may appear in src/ outside sparse/registry.py."""
    src = Path(__file__).resolve().parent.parent / "src"
    pat = re.compile(r"""fmt\s*==\s*["']""")
    offenders = []
    for p in sorted(src.rglob("*.py")):
        if p.parts[-2:] == ("sparse", "registry.py"):
            continue
        for i, line in enumerate(p.read_text().splitlines(), 1):
            if pat.search(line):
                offenders.append(f"{p.relative_to(src)}:{i}: {line.strip()}")
    assert not offenders, "format-literal dispatch outside the registry:\n" + "\n".join(
        offenders
    )
