"""Tests for the from-scratch HPO (random + TPE) — the Optuna substitute."""

import numpy as np

from repro.core.hpo import (
    StudyResult,
    grid_iter,
    kfold_indices,
    random_search,
    tpe_search,
    tune_model,
)
from repro.ml.metrics import accuracy_score
from repro.ml.model_zoo import CLASSIFIER_ZOO

SPACE = {"a": [0, 1, 2, 3], "b": ["x", "y"], "c": [0.1, 0.2, 0.3]}


def _objective(params):
    # optimum at a=2, b='y', c=0.2
    return -(
        (params["a"] - 2) ** 2
        + (0 if params["b"] == "y" else 1)
        + 10 * (params["c"] - 0.2) ** 2
    )


def test_grid_iter_covers_space():
    combos = list(grid_iter(SPACE))
    assert len(combos) == 4 * 2 * 3
    assert {tuple(sorted(c.items())) for c in combos} == {
        tuple(sorted(c.items())) for c in combos
    }


def test_random_search_finds_good_region():
    res = random_search(_objective, SPACE, n_trials=24, seed=0)
    assert isinstance(res, StudyResult)
    assert res.best_value >= -1.0
    assert res.n_trials == 24


def test_random_search_budget_capped_by_space():
    res = random_search(lambda p: -p["a"], {"a": [0, 1]}, n_trials=50, seed=0)
    assert res.n_trials == 2
    assert res.best_params == {"a": 0}


def test_tpe_finds_optimum():
    res = tpe_search(_objective, SPACE, n_trials=24, n_warmup=6, seed=1)
    assert res.best_params["b"] == "y"
    assert abs(res.best_params["a"] - 2) <= 1
    assert res.best_value > -1.1


def test_tpe_beats_or_matches_random_on_average():
    space = {"a": list(range(8)), "b": list(range(8))}

    def obj(p):
        return -((p["a"] - 5) ** 2 + (p["b"] - 3) ** 2)

    r_vals, t_vals = [], []
    for seed in range(5):
        r_vals.append(random_search(obj, space, n_trials=16, seed=seed).best_value)
        t_vals.append(tpe_search(obj, space, n_trials=16, n_warmup=6, seed=seed).best_value)
    assert np.mean(t_vals) >= np.mean(r_vals) - 1.0


def test_kfold_partitions():
    folds = list(kfold_indices(20, 4, seed=0))
    assert len(folds) == 4
    all_val = np.concatenate([v for _, v in folds])
    assert sorted(all_val) == list(range(20))
    for tr, va in folds:
        assert set(tr).isdisjoint(va)


def test_tune_model_improves_or_matches_defaults():
    rng = np.random.default_rng(0)
    centers = np.array([[0, 0], [4, 4], [0, 4]])
    y = rng.integers(0, 3, 150)
    X = centers[y] + rng.normal(0, 0.8, (150, 2))
    res = tune_model(
        CLASSIFIER_ZOO["decision_tree"], X, y, accuracy_score, n_trials=6, cv=3, seed=0
    )
    assert res.best_value > 0.8
    assert set(res.best_params) <= set(CLASSIFIER_ZOO["decision_tree"]["space"])
