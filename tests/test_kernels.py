"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode).

Every Pallas kernel is swept over shapes, dtypes, sparsity patterns and
schedules and checked against ref.py. bf16 accumulation uses a loose
tolerance (long-reduction precision, see kernel taxonomy Part E)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import (
    DEFAULT_SCHEDULE,
    InfeasibleConfig,
    KernelSchedule,
    compile_spmv,
    prepare,
    spmm_pallas,
    spmv_pallas,
)
from repro.kernels.ref import spmm_dense, spmv_dense
from repro.sparse import FORMAT_NAMES
from repro.sparse.generate import random_matrix

FORMATS = list(FORMAT_NAMES)

SCHEDULES = [
    DEFAULT_SCHEDULE,
    KernelSchedule(rows_per_block=8, nnz_tile=128, unroll=1),
    KernelSchedule(rows_per_block=32, nnz_tile=256, unroll=2),
    KernelSchedule(rows_per_block=128, nnz_tile=512, unroll=4),
    KernelSchedule(rows_per_block=16, nnz_tile=128, unroll=1, accum_dtype="bfloat16"),
    KernelSchedule(rows_per_block=64, nnz_tile=128, dimension_semantics="parallel"),
]


def _check(dense, fmt, sched, x=None, tol=None):
    rng = np.random.default_rng(0)
    x = rng.normal(size=dense.shape[1]).astype(np.float32) if x is None else x
    ref = np.asarray(spmv_dense(dense, x))
    mat = prepare(dense, fmt, sched)
    y = np.asarray(spmv_pallas(mat, x, sched))
    assert y.shape == (dense.shape[0],)
    tol = tol or (3e-2 if sched.accum_dtype == "bfloat16" else 1e-4)
    scale = np.abs(ref).max() + 1e-9
    np.testing.assert_allclose(y / scale, ref / scale, atol=tol, rtol=tol)


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("sched_i", range(len(SCHEDULES)))
def test_schedule_sweep(fmt, sched_i):
    dense = random_matrix(250, 11.0, "fem", seed=42).astype(np.float32)
    _check(dense, fmt, SCHEDULES[sched_i])


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("pattern", ["fem", "powerlaw", "block", "banded", "denserows"])
def test_pattern_sweep(fmt, pattern):
    dense = random_matrix(200, 8.0, pattern, seed=9).astype(np.float32)
    _check(dense, fmt, DEFAULT_SCHEDULE)


@pytest.mark.parametrize("fmt", FORMATS)
@settings(max_examples=8)
@given(
    n=st.integers(8, 300),
    avg=st.floats(1.0, 24.0),
    seed=st.integers(0, 10_000),
)
def test_random_shapes(fmt, n, avg, seed):
    dense = random_matrix(n, avg, "fem", seed=seed).astype(np.float32)
    _check(dense, fmt, KernelSchedule(rows_per_block=8, nnz_tile=128))


@pytest.mark.parametrize("fmt", FORMATS)
def test_empty_rows(fmt):
    """Rows with zero nonzeros must produce exact zeros."""
    dense = np.zeros((64, 64), dtype=np.float32)
    dense[10, 3] = 2.0
    dense[50, 60] = -1.5
    x = np.ones(64, dtype=np.float32)
    mat = prepare(dense, fmt, DEFAULT_SCHEDULE)
    y = np.asarray(spmv_pallas(mat, x, DEFAULT_SCHEDULE))
    ref = dense @ x
    np.testing.assert_allclose(y, ref, atol=1e-6)


@pytest.mark.parametrize("fmt", FORMATS)
def test_input_dtypes(fmt):
    dense = random_matrix(100, 6.0, "fem", seed=5).astype(np.float32)
    rng = np.random.default_rng(2)
    for dt, tol in [(np.float32, 1e-4), (np.float64, 1e-4)]:
        x = rng.normal(size=dense.shape[1]).astype(dt)
        _check(dense.astype(dt), fmt, DEFAULT_SCHEDULE, x=x.astype(np.float32), tol=tol)


def test_spmm_matches_dense():
    dense = random_matrix(96, 7.0, "powerlaw", seed=11).astype(np.float32)
    X = np.random.default_rng(1).normal(size=(dense.shape[1], 16)).astype(np.float32)
    mat = prepare(dense, "ell", DEFAULT_SCHEDULE)
    Y = np.asarray(spmm_pallas(mat, X))
    np.testing.assert_allclose(Y, np.asarray(spmm_dense(dense, X)), rtol=1e-4, atol=1e-4)


def test_misaligned_schedule_rejected():
    dense = random_matrix(100, 6.0, "fem", seed=5).astype(np.float32)
    mat = prepare(dense, "ell", KernelSchedule(nnz_tile=128))
    with pytest.raises(InfeasibleConfig):
        spmv_pallas(mat, np.ones(dense.shape[1], np.float32), KernelSchedule(nnz_tile=512))


def test_sell_nnz_tile_mismatch_rejected():
    dense = random_matrix(100, 6.0, "fem", seed=5).astype(np.float32)
    mat = prepare(dense, "sell", KernelSchedule(nnz_tile=128))
    with pytest.raises(InfeasibleConfig):
        spmv_pallas(mat, np.ones(dense.shape[1], np.float32), KernelSchedule(nnz_tile=256))


def test_compile_spmv_end_to_end():
    dense = random_matrix(128, 9.0, "block", seed=8).astype(np.float32)
    x = np.random.default_rng(3).normal(size=dense.shape[1]).astype(np.float32)
    fn = compile_spmv(dense, "bell", KernelSchedule(rows_per_block=16))
    np.testing.assert_allclose(
        np.asarray(fn(x)), dense @ x, rtol=1e-4, atol=1e-4
    )


def test_schedule_validation():
    with pytest.raises(ValueError):
        KernelSchedule(rows_per_block=10)  # not a sublane multiple
    with pytest.raises(ValueError):
        KernelSchedule(nnz_tile=100)  # not a lane multiple
    with pytest.raises(ValueError):
        KernelSchedule(unroll=3)  # must divide nnz_tile
    with pytest.raises(ValueError):
        KernelSchedule(accum_dtype="float16")
