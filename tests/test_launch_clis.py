"""Coverage for the launch CLIs (train/serve) on the host mesh — the same
entry points a fleet run uses, at reduced scale."""

import numpy as np

from repro.launch.serve import main as serve_main
from repro.launch.train import main as train_main


def test_train_cli_runs_and_improves(tmp_path):
    trainer = train_main([
        "--arch", "qwen3-0.6b",
        "--steps", "4",
        "--seq-len", "32",
        "--batch", "2",
        "--ckpt-dir", str(tmp_path),
        "--ckpt-every", "4",
    ])
    losses = [h["loss"] for h in trainer.history]
    assert len(losses) == 4 and all(np.isfinite(losses))
    assert trainer.ckpt.latest_step() == 4


def test_train_cli_moe_with_dispatch_override(tmp_path):
    trainer = train_main([
        "--arch", "deepseek-moe-16b",
        "--steps", "2",
        "--seq-len", "32",
        "--batch", "2",
        "--dispatch-format", "sell",
        "--ckpt-dir", str(tmp_path),
    ])
    assert trainer.cfg.dispatch_format == "sell"
    assert len(trainer.history) == 2


def test_serve_cli_generates():
    done = serve_main([
        "--arch", "llama3-8b",
        "--requests", "2",
        "--slots", "2",
        "--max-new-tokens", "3",
        "--max-len", "64",
    ])
    assert all(r.done and len(r.generated) == 3 for r in done)


def test_serve_cli_spmv_adaptive_telemetry(tmp_path):
    """SpMV serving with the full telemetry loop switched on: requests are
    answered correctly, the tuning cache and telemetry log are persisted."""
    done = serve_main([
        "--spmv",
        "--requests", "6",
        "--spmv-train-matrices", "2",
        "--spmv-scale", "0.001",
        "--spmv-cache", str(tmp_path / "tuning.json"),
        "--adaptive",
        "--telemetry-log", str(tmp_path / "telemetry.jsonl"),
        "--refit-every", "4",
    ])
    assert len(done) == 6
    for r in done:
        ref = r.dense @ r.x
        err = np.abs(r.y - ref).max() / (np.abs(ref).max() + 1e-9)
        assert err < 0.05  # bfloat16 schedules allowed; must still be SpMV
        assert r.fmt is not None and r.latency_s > 0
    assert (tmp_path / "tuning.json").exists()
    log_lines = (tmp_path / "telemetry.jsonl").read_text().splitlines()
    assert len(log_lines) == 6


def test_serve_cli_spmv_partitioned(tmp_path):
    """SpMV serving with composite partitioned plans: outputs stay correct
    and the per-request format reports the per-block routing."""
    done = serve_main([
        "--spmv",
        "--requests", "4",
        "--spmv-train-matrices", "2",
        "--spmv-scale", "0.001",
        "--spmv-cache", str(tmp_path / "tuning.json"),
        "--partition",
        "--max-blocks", "4",
    ])
    assert len(done) == 4
    for r in done:
        ref = r.dense @ r.x
        err = np.abs(r.y - ref).max() / (np.abs(ref).max() + 1e-9)
        assert err < 0.05  # bfloat16 schedules allowed; must still be SpMV
        assert r.fmt and r.latency_s > 0  # "fmtA+fmtB..." composite report
    assert (tmp_path / "tuning.json").exists()
