"""Coverage for the launch CLIs (train/serve) on the host mesh — the same
entry points a fleet run uses, at reduced scale."""

import numpy as np

from repro.launch.serve import main as serve_main
from repro.launch.train import main as train_main


def test_train_cli_runs_and_improves(tmp_path):
    trainer = train_main([
        "--arch", "qwen3-0.6b",
        "--steps", "4",
        "--seq-len", "32",
        "--batch", "2",
        "--ckpt-dir", str(tmp_path),
        "--ckpt-every", "4",
    ])
    losses = [h["loss"] for h in trainer.history]
    assert len(losses) == 4 and all(np.isfinite(losses))
    assert trainer.ckpt.latest_step() == 4


def test_train_cli_moe_with_dispatch_override(tmp_path):
    trainer = train_main([
        "--arch", "deepseek-moe-16b",
        "--steps", "2",
        "--seq-len", "32",
        "--batch", "2",
        "--dispatch-format", "sell",
        "--ckpt-dir", str(tmp_path),
    ])
    assert trainer.cfg.dispatch_format == "sell"
    assert len(trainer.history) == 2


def test_serve_cli_generates():
    done = serve_main([
        "--arch", "llama3-8b",
        "--requests", "2",
        "--slots", "2",
        "--max-new-tokens", "3",
        "--max-len", "64",
    ])
    assert all(r.done and len(r.generated) == 3 for r in done)
