"""Tests for the from-scratch ML library (the paper's learning substrate)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.ml import (
    BayesianRidge,
    DecisionTreeClassifier,
    DecisionTreeRegressor,
    GradientBoostingClassifier,
    Lars,
    Lasso,
    MLPClassifier,
    MLPRegressor,
    NearestCentroid,
    NonlinearSVM,
    RandomForestClassifier,
    RandomForestRegressor,
    Ridge,
    StandardScaler,
    accuracy_score,
    confusion_matrix,
    f1_score,
    mean_squared_error,
    r2_score,
    train_test_split,
)
from repro.ml.model_zoo import CLASSIFIER_ZOO, REGRESSOR_ZOO


def _blobs(n=180, k=3, d=4, spread=0.5, seed=0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, 3, (k, d))
    y = rng.integers(0, k, n)
    X = centers[y] + rng.normal(0, spread, (n, d))
    return X, y


# --------------------------------------------------------------------- metrics
def test_accuracy_and_confusion():
    y_true = np.array([0, 0, 1, 1, 2])
    y_pred = np.array([0, 1, 1, 1, 2])
    assert accuracy_score(y_true, y_pred) == pytest.approx(0.8)
    cm = confusion_matrix(y_true, y_pred)
    assert cm.sum() == 5 and cm[0, 1] == 1 and cm[1, 1] == 2


def test_f1_perfect_and_degenerate():
    assert f1_score([0, 1, 2], [0, 1, 2]) == pytest.approx(1.0)
    assert f1_score([0, 0, 0], [1, 1, 1]) == pytest.approx(0.0)


def test_r2_mse_basics():
    y = np.array([1.0, 2.0, 3.0])
    assert r2_score(y, y) == pytest.approx(1.0)
    assert mean_squared_error(y, y + 1) == pytest.approx(1.0)
    assert r2_score(y, np.full_like(y, y.mean())) == pytest.approx(0.0)


# ----------------------------------------------------------------- classifiers
@pytest.mark.parametrize("name", sorted(CLASSIFIER_ZOO))
def test_classifier_separable(name):
    X, y = _blobs(seed=1)
    entry = CLASSIFIER_ZOO[name]
    kw = dict(entry["defaults"])
    if name == "mlp":  # keep CPU time low
        kw.update(epochs=120, n_layers=2, hidden_layer_size=32)
    if name == "gradient_boosting":
        kw.update(n_estimators=30)
    model = entry["ctor"](**kw)
    Xtr, Xte, ytr, yte = train_test_split(X, y, 0.25, seed=2)
    model.fit(Xtr, ytr)
    assert accuracy_score(yte, model.predict(Xte)) > 0.8


def test_tree_respects_max_depth():
    X, y = _blobs(n=200, seed=3)
    tree = DecisionTreeClassifier(max_depth=2).fit(X, y)
    assert tree.depth() <= 2


def test_tree_criteria_and_splitters():
    X, y = _blobs(n=120, seed=4)
    for crit in ("gini", "entropy", "log_loss"):
        for splitter in ("best", "random"):
            t = DecisionTreeClassifier(criterion=crit, splitter=splitter).fit(X, y)
            assert t.score(X, y) > 0.9


def test_tree_pure_node_shortcut():
    X = np.array([[0.0], [1.0], [2.0]])
    y = np.array([5, 5, 5])
    t = DecisionTreeClassifier().fit(X, y)
    assert (t.predict(X) == 5).all()


def test_centroid_metrics_differ_only_in_distance():
    X, y = _blobs(n=90, seed=5)
    for metric in ("manhattan", "euclidean", "minkowski"):
        m = NearestCentroid(metric=metric).fit(X, y)
        assert m.score(X, y) > 0.85


def test_svm_kernels():
    X, y = _blobs(n=100, k=2, seed=6)
    for kernel in ("linear", "rbf", "poly", "sigmoid"):
        m = NonlinearSVM(kernel=kernel, n_iter=150).fit(X, y)
        assert m.score(X, y) > 0.75, kernel


def test_boosting_improves_with_stages():
    X, y = _blobs(n=150, spread=1.5, seed=7)
    weak = GradientBoostingClassifier(n_estimators=2, max_depth=1, seed=0).fit(X, y)
    strong = GradientBoostingClassifier(n_estimators=40, max_depth=1, seed=0).fit(X, y)
    assert strong.score(X, y) >= weak.score(X, y)


def test_forest_majority_vote_shape():
    X, y = _blobs(n=80, seed=8)
    m = RandomForestClassifier(n_estimators=10).fit(X, y)
    proba = m.predict_proba(X)
    assert proba.shape == (80, len(np.unique(y)))
    np.testing.assert_allclose(proba.sum(axis=1), 1.0, atol=1e-9)


def test_mlp_multiclass():
    X, y = _blobs(n=150, seed=9)
    m = MLPClassifier(hidden_layer_size=32, n_layers=2, epochs=150).fit(X, y)
    assert m.score(X, y) > 0.9


# ------------------------------------------------------------------ regressors
def _linear_data(n=120, d=5, noise=0.05, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, d))
    w = rng.normal(size=d)
    y = X @ w + noise * rng.normal(size=n)
    return X, y


@pytest.mark.parametrize("cls", [Ridge, BayesianRidge, Lasso, Lars])
def test_linear_models_recover_linear_signal(cls):
    X, y = _linear_data(seed=11)
    kw = {"alpha": 0.01} if cls is Lasso else {}
    m = cls(**kw).fit(X, y)
    assert r2_score(y, m.predict(X)) > 0.95


def test_tree_regressor_fits_steps():
    X = np.linspace(0, 1, 128)[:, None]
    y = (X[:, 0] > 0.5).astype(float)
    m = DecisionTreeRegressor(max_depth=2).fit(X, y)
    assert r2_score(y, m.predict(X)) > 0.99


def test_forest_regressor_beats_single_tree_on_noise():
    rng = np.random.default_rng(13)
    X = rng.normal(size=(200, 4))
    y = np.sin(2 * X[:, 0]) + 0.3 * rng.normal(size=200)
    Xtr, Xte, ytr, yte = train_test_split(X, y, 0.3, seed=1)
    forest = RandomForestRegressor(n_estimators=20, seed=0).fit(Xtr, ytr)
    assert r2_score(yte, forest.predict(Xte)) > 0.3


def test_mlp_regressor():
    X, y = _linear_data(n=150, seed=14)
    m = MLPRegressor(hidden_layer_size=32, n_layers=2, epochs=200).fit(X, y)
    assert r2_score(y, m.predict(X)) > 0.9


# ------------------------------------------------------------------- utilities
def test_scaler_roundtrip_stats():
    X = np.random.default_rng(2).normal(3.0, 2.0, size=(100, 3))
    Xs = StandardScaler().fit_transform(X)
    np.testing.assert_allclose(Xs.mean(axis=0), 0.0, atol=1e-9)
    np.testing.assert_allclose(Xs.std(axis=0), 1.0, atol=1e-9)


@given(frac=st.floats(0.1, 0.5), seed=st.integers(0, 100))
@settings(max_examples=10)
def test_split_partition(frac, seed):
    X = np.arange(50, dtype=float)[:, None]
    y = np.arange(50)
    Xtr, Xte, ytr, yte = train_test_split(X, y, frac, seed=seed)
    assert len(Xtr) + len(Xte) == 50
    assert set(ytr).isdisjoint(set(yte)) or len(set(ytr) | set(yte)) == 50
    assert sorted(np.concatenate([ytr, yte])) == list(range(50))


def test_zoo_defaults_construct():
    for entry in list(CLASSIFIER_ZOO.values()) + list(REGRESSOR_ZOO.values()):
        entry["ctor"](**entry["defaults"])
