"""Model-zoo correctness tests: flash attention vs naive, chunked mLSTM vs
sequential recurrence, RG-LRU scan vs step recurrence, MoE dispatch formats,
decode/forward consistency, and a smoke test per assigned architecture."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (
    abstract_params,
    decode_step,
    forward,
    init_params,
    model_specs,
    param_count,
    prefill,
)
from repro.models.model import init_cache
from repro.models.layers import flash_attention
from repro.models.moe import moe_ffn, moe_specs, select_dispatch_format
from repro.models.recurrent import _mlstm_core


def _naive_attention(q, k, v, q_pos, kv_pos, kv_valid, window=0, prefix_len=0):
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale, k.astype(jnp.float32))
    ok = kv_pos[:, None, :] <= q_pos[:, :, None]
    if window:
        ok &= kv_pos[:, None, :] > q_pos[:, :, None] - window
    if prefix_len:
        ok |= kv_pos[:, None, :] < prefix_len
    ok &= kv_valid[:, None, :]
    scores = jnp.where(ok[:, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))


@pytest.mark.parametrize("window,prefix", [(0, 0), (16, 0), (0, 8), (24, 8)])
def test_flash_attention_matches_naive(window, prefix):
    rng = np.random.default_rng(0)
    B, T, H, dh = 2, 96, 4, 16  # 96 not divisible by chunk 32 -> tests padding
    q = jnp.asarray(rng.normal(size=(B, T, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, dh)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    valid = jnp.ones((B, T), bool)
    out = flash_attention(
        q, k, v, q_pos=pos, kv_pos=pos, kv_valid=valid,
        window=window, prefix_len=prefix, chunk=32,
    )
    ref = _naive_attention(q, k, v, pos, pos, valid, window, prefix)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-4)


def test_flash_attention_unrolled_identical():
    rng = np.random.default_rng(1)
    B, T, H, dh = 1, 64, 2, 8
    q = jnp.asarray(rng.normal(size=(B, T, H, dh)), jnp.float32)
    k, v = q + 0.1, q - 0.2
    pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    valid = jnp.ones((B, T), bool)
    a = flash_attention(q, k, v, q_pos=pos, kv_pos=pos, kv_valid=valid, chunk=16, unroll=False)
    b = flash_attention(q, k, v, q_pos=pos, kv_pos=pos, kv_valid=valid, chunk=16, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


def _mlstm_sequential(q, k, v, i_g, f_g):
    """Step-by-step reference of the sigma-gated mLSTM recurrence."""
    B, T, H, dh = q.shape
    scale = dh**-0.5
    C = np.zeros((B, H, dh, dh))
    n = np.zeros((B, H, dh))
    out = np.zeros((B, T, H, dh))
    q, k, v = np.asarray(q, np.float64), np.asarray(k, np.float64), np.asarray(v, np.float64)
    i_g, f_g = np.asarray(i_g, np.float64), np.asarray(f_g, np.float64)
    for t in range(T):
        C = f_g[:, t, :, None, None] * C + np.einsum(
            "bhk,bhv->bhkv", k[:, t] * i_g[:, t, :, None], v[:, t]
        )
        n = f_g[:, t, :, None] * n + k[:, t] * i_g[:, t, :, None]
        qt = q[:, t] * scale
        num = np.einsum("bhk,bhkv->bhv", qt, C)
        den = np.maximum(np.abs(np.einsum("bhk,bhk->bh", qt, n))[..., None], 1.0)
        out[:, t] = num / den
    return out


def test_mlstm_chunked_matches_sequential():
    rng = np.random.default_rng(2)
    B, T, H, dh = 2, 32, 2, 8
    q = jnp.asarray(rng.normal(size=(B, T, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, dh)), jnp.float32)
    i_g = jnp.asarray(rng.uniform(0.2, 1.0, size=(B, T, H)), jnp.float32)
    f_g = jnp.asarray(rng.uniform(0.8, 0.999, size=(B, T, H)), jnp.float32)
    out, (C, n) = _mlstm_core(q, k, v, i_g, f_g, chunk=8)
    ref = _mlstm_sequential(q, k, v, i_g, f_g)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-3, atol=2e-3)


def test_mlstm_decode_continues_train_state():
    """State from a chunked pass + one decode step == sequential T+1 pass."""
    rng = np.random.default_rng(3)
    B, T, H, dh = 1, 16, 2, 4
    mk = lambda s: jnp.asarray(rng.normal(size=s), jnp.float32)
    q, k, v = mk((B, T + 1, H, dh)), mk((B, T + 1, H, dh)), mk((B, T + 1, H, dh))
    i_g = jnp.asarray(rng.uniform(0.2, 1.0, size=(B, T + 1, H)), jnp.float32)
    f_g = jnp.asarray(rng.uniform(0.8, 0.999, size=(B, T + 1, H)), jnp.float32)
    _, state = _mlstm_core(q[:, :T], k[:, :T], v[:, :T], i_g[:, :T], f_g[:, :T], chunk=8)
    step_out, _ = _mlstm_core(
        q[:, T:], k[:, T:], v[:, T:], i_g[:, T:], f_g[:, T:], chunk=8, state=state
    )
    ref = _mlstm_sequential(q, k, v, i_g, f_g)[:, T]
    np.testing.assert_allclose(np.asarray(step_out[:, 0]), ref, rtol=2e-3, atol=2e-3)


def test_rglru_decode_matches_train():
    """Running T steps through decode must equal the associative-scan path."""
    cfg = get_config("recurrentgemma-2b", reduced_config=True)
    from repro.models.model import block_cache_spec
    from repro.models.recurrent import rglru, rglru_specs
    from repro.models.param import init_params as ip

    params = ip(rglru_specs(cfg), jax.random.PRNGKey(0), "float32")
    rng = np.random.default_rng(4)
    B, T = 1, 8
    x = jnp.asarray(rng.normal(size=(B, T, cfg.d_model)) * 0.3, jnp.float32)
    y_train, _ = rglru(params, x, cfg, cache=None)
    cache = {
        "h": jnp.zeros((B, cfg.rnn_dim), jnp.float32),
        "conv": jnp.zeros((B, cfg.conv1d_size - 1, cfg.rnn_dim), jnp.float32),
    }
    outs = []
    for t in range(T):
        y, cache = rglru(params, x[:, t : t + 1], cfg, cache=cache)
        outs.append(y)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_train), rtol=2e-3, atol=2e-3)


# ------------------------------------------------------------------------ MoE
def _moe_setup(fmt, capacity_factor=8.0):
    cfg = get_config("deepseek-moe-16b", reduced_config=True).replace(
        dispatch_format=fmt, capacity_factor=capacity_factor
    )
    params = init_params(moe_specs(cfg), jax.random.PRNGKey(0), "float32")
    x = jnp.asarray(np.random.default_rng(5).normal(size=(2, 16, cfg.d_model)) * 0.3, jnp.float32)
    return cfg, params, x


def test_moe_ell_matches_dense_with_ample_capacity():
    """With capacity >> load, ELL dispatch computes exactly the dense
    (every-expert) result restricted to the top-k experts."""
    cfg_d, params, x = _moe_setup("dense")
    cfg_e, _, _ = _moe_setup("ell")
    y_dense, _, _ = moe_ffn(params, x, cfg_d)
    y_ell, _, counts = moe_ffn(params, x, cfg_e)
    np.testing.assert_allclose(np.asarray(y_ell), np.asarray(y_dense), rtol=2e-3, atol=2e-3)
    assert float(counts.sum()) == 2 * 16 * cfg_e.top_k


def test_moe_sell_matches_dense_with_ample_capacity():
    cfg_d, params, x = _moe_setup("dense")
    cfg_s, _, _ = _moe_setup("sell")
    y_dense, _, _ = moe_ffn(params, x, cfg_d)
    y_sell, _, _ = moe_ffn(params, x, cfg_s)
    np.testing.assert_allclose(np.asarray(y_sell), np.asarray(y_dense), rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_tokens_gracefully():
    cfg, params, x = _moe_setup("ell", capacity_factor=0.25)
    y, aux, _ = moe_ffn(params, x, cfg)
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(aux))


def test_dispatch_format_selector():
    assert select_dispatch_format(np.full(16, 10)) == "ell"  # uniform routing
    skew = np.zeros(16); skew[0] = 100; skew[1:] = 2
    assert select_dispatch_format(skew) == "sell"  # hot-expert skew


# ------------------------------------------------------- per-arch smoke tests
EXPECTED_PARAMS_B = {
    "deepseek-moe-16b": (15.0, 18.0),
    "kimi-k2-1t-a32b": (950.0, 1100.0),
    "codeqwen1.5-7b": (7.0, 9.0),
    "llama3-8b": (7.5, 8.7),
    "qwen3-0.6b": (0.45, 0.8),
    "stablelm-12b": (11.0, 13.5),
    "xlstm-1.3b": (1.0, 4.0),
    "recurrentgemma-2b": (2.0, 3.3),
    "musicgen-large": (1.8, 3.3),
    "paligemma-3b": (2.0, 3.2),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_count(arch):
    n = param_count(model_specs(get_config(arch))) / 1e9
    lo, hi = EXPECTED_PARAMS_B[arch]
    assert lo <= n <= hi, f"{arch}: {n:.2f}B params outside [{lo},{hi}]"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_grad(arch):
    """Reduced config: one forward + one grad step on CPU, shape + NaN check
    (the assigned-architecture smoke-test requirement)."""
    cfg = get_config(arch, reduced_config=True)
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    B, T = 2, 32
    kw = {}
    if cfg.train_input == "embeds":
        kw["embeds"] = jnp.full((B, T, cfg.d_model), 0.02, jnp.float32)
    else:
        kw["tokens"] = jnp.ones((B, T), jnp.int32)
    if cfg.prefix_len:
        kw["prefix_embeds"] = jnp.full((B, cfg.prefix_len, cfg.d_model), 0.02, jnp.float32)
    labels = jnp.ones((B, T), jnp.int32)

    def loss_fn(p):
        logits, aux = forward(p, cfg, **kw)
        logits = logits[:, -T:]  # text positions only (vlm prefix)
        lp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.mean(jnp.take_along_axis(lp, labels[..., None], axis=-1))
        return nll + 0.01 * aux["moe_aux"]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss)), arch
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0, arch
    T_out = T + (cfg.prefix_len or 0)
    logits, _ = forward(params, cfg, **kw)
    assert logits.shape == (B, T_out, cfg.vocab_size)


@pytest.mark.parametrize("arch", ["llama3-8b", "recurrentgemma-2b", "xlstm-1.3b"])
def test_decode_consistency_with_forward(arch):
    """Teacher-forcing check: prefill+decode logits == train-forward logits
    at the same position."""
    cfg = get_config(arch, reduced_config=True)
    params = init_params(model_specs(cfg), jax.random.PRNGKey(1), cfg.param_dtype)
    B, T = 1, 12
    tokens = jnp.asarray(np.random.default_rng(6).integers(0, cfg.vocab_size, (B, T + 1)), jnp.int32)
    full_logits, _ = forward(params, cfg, tokens=tokens)
    cache = init_cache(cfg, B, 64)
    pre_logits, cache, _ = prefill(params, cfg, cache, tokens=tokens[:, :T])
    step_logits, _ = decode_step(
        params, cfg, cache, tokens[:, T : T + 1], jnp.full((B, 1), T, jnp.int32)
    )
    np.testing.assert_allclose(
        np.asarray(step_logits[:, 0]), np.asarray(full_logits[:, T]), rtol=5e-3, atol=5e-3
    )
    np.testing.assert_allclose(
        np.asarray(pre_logits[:, -1]), np.asarray(full_logits[:, T - 1]), rtol=5e-3, atol=5e-3
    )
