"""Observability subsystem: span nesting, disabled-mode no-ops, JSONL
round-trips with torn-line recovery, Prometheus text validity, energy
accounting against the cost model, fleet shard aggregation, and the
``/metrics`` scrape surface end to end through ``SpmvServer``."""

import json
import math
import re
import threading
import urllib.request

import numpy as np
import pytest

from repro.core.session import AutoSpmvSession
from repro.kernels.common import DEFAULT_SCHEDULE
from repro.kernels.ops import clear_kernel_memo
from repro.obs import set_obs_enabled
from repro.obs.aggregate import merge_shards
from repro.obs.energy import EnergyAccountant
from repro.obs.http import ObsHTTPServer
from repro.obs.metrics import MetricsRegistry, get_metrics, reset_metrics
from repro.obs.trace import (
    NOOP_SPAN,
    Tracer,
    get_tracer,
    load_spans,
    span_children,
)
from repro.sparse.generate import random_matrix
from repro.sparse.registry import MatrixStats
from repro.train.serve import SpmvRequest, SpmvServer

from tests.test_partition import hetero_matrix
from tests.test_telemetry import _fake_tuner, _mat


@pytest.fixture(autouse=True)
def _clean_obs():
    """Process-global tracer/registry: isolate every test, leave obs on."""
    set_obs_enabled(True)
    get_tracer().clear()
    reset_metrics()
    yield
    set_obs_enabled(True)
    get_tracer().clear()
    reset_metrics()


# ------------------------------------------------------------------- tracing


def test_span_nesting_and_ordering():
    tracer = Tracer()
    with tracer.span("session.optimize", objective="latency") as outer:
        with tracer.span("cache.lookup"):
            pass
        with tracer.span("kernel.compile", fmt="csr"):
            pass
        outer.set(cache_hit=False)
    spans = tracer.spans()
    # children close before the parent, so the parent is recorded last
    assert [s["name"] for s in spans] == [
        "cache.lookup", "kernel.compile", "session.optimize",
    ]
    root = spans[-1]
    assert root["parent"] is None
    assert root["attrs"] == {"objective": "latency", "cache_hit": False}
    kids = span_children(spans, root["id"])
    assert {s["name"] for s in kids} == {"cache.lookup", "kernel.compile"}
    assert all(s["dur_s"] >= 0 for s in spans)
    # sibling ordering: cache.lookup entered (and exited) first
    assert kids[0]["ts"] <= kids[1]["ts"]


def test_span_records_error_and_unwinds_stack():
    tracer = Tracer()
    with pytest.raises(ValueError):
        with tracer.span("outer"):
            with tracer.span("inner"):
                raise ValueError("boom")
    spans = tracer.spans()
    assert [s["name"] for s in spans] == ["inner", "outer"]
    assert spans[0]["error"] == "ValueError"
    # the stack fully unwound: a new span is a root again
    with tracer.span("fresh"):
        pass
    assert tracer.spans()[-1]["parent"] is None


def test_spans_are_per_thread_trees():
    tracer = Tracer()

    def worker():
        with tracer.span("thread.root"):
            with tracer.span("thread.child"):
                pass

    with tracer.span("main.root"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    spans = {s["name"]: s for s in tracer.spans()}
    # the worker's root must not become a child of the main thread's span
    assert spans["thread.root"]["parent"] is None
    assert spans["thread.child"]["parent"] == spans["thread.root"]["id"]


def test_disabled_tracer_and_registry_are_noops():
    tracer = Tracer(enabled=False)
    s = tracer.span("anything", attr=1)
    assert s is NOOP_SPAN  # the shared singleton: zero allocation per span
    with s as ctx:
        ctx.set(more=2)
    assert tracer.spans() == []

    reg = MetricsRegistry(enabled=False)
    c = reg.counter("spmv_cache_hits_total")
    c.inc()
    c.inc(5)
    assert c.value == 0.0
    h = reg.histogram("spmv_request_latency_seconds")
    h.observe(0.5)
    assert h.count == 0
    g = reg.gauge("g")
    g.set(3.0)
    assert math.isnan(g.value)


def test_trace_jsonl_roundtrip_with_torn_line(tmp_path):
    tracer = Tracer()
    with tracer.span("a"):
        with tracer.span("b"):
            pass
    path = tmp_path / "trace.jsonl"
    assert tracer.export_jsonl(path) == 2
    assert tracer.export_jsonl(path) == 0  # nothing fresh: no duplicate lines

    # crash simulation: a torn, newline-less partial record at the tail
    with open(path, "a") as f:
        f.write('{"name": "torn", "dur')
    with tracer.span("c"):
        pass
    assert tracer.export_jsonl(path) == 1

    spans = load_spans(path)
    assert [s["name"] for s in spans] == ["b", "a", "c"]
    assert spans[0]["parent"] == spans[1]["id"]


def test_tracer_bounded_buffer_counts_drops():
    tracer = Tracer(max_spans=4)
    for i in range(10):
        with tracer.span(f"s{i}"):
            pass
    assert len(tracer.spans()) == 4
    assert tracer.drops == 6
    assert tracer.summary()["drops"] == 6


# ------------------------------------------------------------------- metrics

_PROM_LINE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^}]*\})? (NaN|[-+0-9.eE]+)$"
)


def test_prometheus_text_validity():
    reg = MetricsRegistry()
    reg.counter("spmv_cache_hits_total").inc(3)
    reg.gauge("spmv_avg_power_watts", fmt="csr", objective="latency").set(1.5)
    h = reg.histogram("spmv_request_latency_seconds", objective="latency")
    for v in np.linspace(0.001, 0.1, 100):
        h.observe(float(v))
    text = reg.to_prometheus()
    lines = text.strip().splitlines()
    for line in lines:
        if line.startswith("# TYPE "):
            assert line.split()[-1] in ("counter", "gauge", "summary")
            continue
        assert _PROM_LINE.match(line), f"invalid exposition line: {line!r}"
    assert "# TYPE spmv_cache_hits_total counter" in text
    assert "spmv_cache_hits_total 3" in text
    assert 'spmv_avg_power_watts{fmt="csr",objective="latency"} 1.5' in text
    for q in ("0.5", "0.9", "0.99"):
        assert f'quantile="{q}"' in text
    assert "spmv_request_latency_seconds_count{objective=\"latency\"} 100" in text


def test_registry_reset_keeps_instrument_handles():
    reg = MetricsRegistry()
    c = reg.counter("spmv_cache_hits_total")
    c.inc(7)
    reg.reset()
    assert c.value == 0.0  # zeroed IN PLACE: cached handles stay live
    c.inc()
    assert reg.counter("spmv_cache_hits_total") is c
    assert reg.snapshot()["counters"]["spmv_cache_hits_total"] == 1.0


def test_labelled_instruments_are_distinct():
    reg = MetricsRegistry()
    reg.counter("spmv_requests_total", fmt="csr", objective="latency").inc()
    reg.counter("spmv_requests_total", fmt="ell", objective="latency").inc(2)
    snap = reg.snapshot()["counters"]
    assert snap['spmv_requests_total{fmt="csr",objective="latency"}'] == 1.0
    assert snap['spmv_requests_total{fmt="ell",objective="latency"}'] == 2.0


# -------------------------------------------------------------------- energy


def test_energy_accounting_against_cost_model():
    from repro.core.objectives import TpuCostModel

    dense = random_matrix(256, 8.0, "fem", seed=3).astype(np.float32)
    modeled = TpuCostModel().evaluate(MatrixStats(dense), "csr", DEFAULT_SCHEDULE)
    assert modeled.feasible and modeled.energy > 0

    reg = MetricsRegistry()
    acc = EnergyAccountant(reg)
    measured = 2.0 * modeled.latency  # kernel ran slower than modeled
    for _ in range(3):
        acc.observe(
            fmt="csr", objective="latency",
            measured_s=measured, modeled=modeled.as_dict(),
        )
    cell = acc.cell("csr", "latency")
    assert cell.requests == 3
    assert cell.energy_j == pytest.approx(3 * modeled.energy)
    # energy stays modeled; average power re-derives from MEASURED time, so
    # a 2x-slower kernel shows half the modeled average power
    assert cell.avg_power_w == pytest.approx(modeled.power / 2.0, rel=1e-6)
    # efficiency = useful FLOP rate per watt; the useful-work numerator is
    # inverted from the modeled triple (eff * P * t * 1e6)
    useful = modeled.efficiency * modeled.power * modeled.latency * 1e6
    expect_eff = (3 * useful) / cell.latency_s / 1e6 / cell.avg_power_w
    assert cell.efficiency_mflops_per_w == pytest.approx(expect_eff, rel=1e-6)

    # aggregates mirrored into gauges for the /metrics scrape
    g = reg.gauge("spmv_energy_joules_total", fmt="csr", objective="latency")
    assert g.value == pytest.approx(cell.energy_j)
    summary = acc.summary()
    assert summary["per_format"]["csr"]["requests"] == 3


def test_energy_accounting_degrades_without_model():
    acc = EnergyAccountant(MetricsRegistry())
    cell = acc.observe(fmt="ell", objective="energy", measured_s=0.01, modeled=None)
    assert cell.requests == 1
    assert cell.energy_j == 0.0
    assert cell.avg_power_w == 0.0
    assert cell.efficiency_mflops_per_w == 0.0


# ----------------------------------------------------------------- aggregate


def test_aggregate_merges_multi_instance_shards(tmp_path):
    shards = []
    rngs = np.random.default_rng(0)
    for instance in ("inst-a", "inst-b"):
        reg = MetricsRegistry()
        reg.counter("spmv_cache_hits_total").inc(4)
        reg.gauge("spmv_avg_power_watts", fmt="csr").set(
            2.0 if instance == "inst-a" else 4.0
        )
        h = reg.histogram("spmv_request_latency_seconds")
        for v in rngs.uniform(0.001, 0.1, size=100):
            h.observe(float(v))
        path = tmp_path / f"metrics-{instance}.jsonl"
        reg.write_shard(path, instance)
        shards.append(path)

    tracer = Tracer()
    with tracer.span("session.optimize"):
        with tracer.span("kernel.compile"):
            pass
    trace_path = tmp_path / "trace-inst-a.jsonl"
    tracer.export_jsonl(trace_path)
    shards.append(trace_path)
    # torn line in one shard: dropped, never fatal
    with open(shards[0], "a") as f:
        f.write('{"kind": "count')

    report = merge_shards(shards)
    assert report["instances"] == ["inst-a", "inst-b"]
    assert report["dropped_lines"] == 1
    assert report["counters"]["spmv_cache_hits_total"] == 8.0
    g = report["gauges"]['spmv_avg_power_watts{fmt="csr"}']
    assert g == {"mean": 3.0, "min": 2.0, "max": 4.0, "instances": 2}
    hist = report["histograms"]["spmv_request_latency_seconds"]
    assert hist["count"] == 200
    assert hist["window_samples"] == 200  # percentiles over the CONCATENATED
    # windows, not averaged per-instance percentiles
    assert 0.001 <= hist["p50"] <= hist["p90"] <= hist["p99"] <= 0.1
    assert report["spans"]["total"] == 2
    assert report["spans"]["by_name"]["kernel.compile"]["count"] == 1


def test_aggregate_cli_writes_report(tmp_path):
    from repro.obs.aggregate import main

    reg = MetricsRegistry()
    reg.counter("spmv_cache_hits_total").inc()
    shard = tmp_path / "m.jsonl"
    reg.write_shard(shard, "solo")
    out = tmp_path / "report.json"
    assert main([str(shard), "-o", str(out)]) == 0
    report = json.loads(out.read_text())
    assert report["counters"]["spmv_cache_hits_total"] == 1.0


# ----------------------------------------------------------- http + serving


def _get(url: str) -> tuple[int, str]:
    with urllib.request.urlopen(url, timeout=10) as resp:
        return resp.status, resp.read().decode()


def test_http_scrape_surface():
    reg = MetricsRegistry()
    reg.counter("spmv_cache_hits_total").inc(2)
    srv = ObsHTTPServer(reg, extra=lambda: {"custom": 1}, port=0).start()
    try:
        code, body = _get(f"{srv.url}/metrics")
        assert code == 200
        assert "spmv_cache_hits_total 2" in body
        code, body = _get(f"{srv.url}/healthz")
        assert code == 200 and json.loads(body) == {"status": "ok"}
        code, body = _get(f"{srv.url}/obs")
        payload = json.loads(body)
        assert payload["custom"] == 1
        assert payload["metrics"]["counters"]["spmv_cache_hits_total"] == 2.0
        with pytest.raises(urllib.error.HTTPError):
            _get(f"{srv.url}/nope")
    finally:
        srv.stop()


def _serve(server, mats, objective="latency"):
    reqs = [
        SpmvRequest(
            rid=i,
            dense=m,
            x=np.random.default_rng(i).normal(size=m.shape[1]).astype(np.float32),
            objective=objective,
        )
        for i, m in enumerate(mats)
    ]
    return server.run(reqs)


def test_server_metrics_endpoint_e2e():
    """Acceptance: a served SpmvServer exposes Prometheus-parseable /metrics
    with cache hit/miss counters, latency quantiles, and energy gauges."""
    from repro.telemetry import AdaptiveFormatSelector, TelemetryRecorder

    clear_kernel_memo()
    session = AutoSpmvSession(
        _fake_tuner(),
        telemetry=TelemetryRecorder(),
        adaptive=AdaptiveFormatSelector(),
    )
    server = SpmvServer(session)
    _serve(server, [_mat(0), _mat(0), _mat(1)])
    srv = server.start_metrics_server(0)
    assert server.start_metrics_server(0) is srv  # idempotent
    try:
        code, body = _get(f"{srv.url}/metrics")
        assert code == 200
        for line in body.strip().splitlines():
            if not line.startswith("#"):
                assert _PROM_LINE.match(line), f"invalid line: {line!r}"
        assert re.search(r"spmv_cache_hits_total [1-9]", body)
        assert "spmv_cache_misses_total" in body
        assert re.search(
            r'spmv_request_latency_seconds\{objective="latency",quantile="0.5"\} '
            r"[0-9.eE+-]+",
            body,
        )
        assert 'quantile="0.99"' in body
        assert re.search(r'spmv_energy_joules_total\{fmt="[a-z]+"', body)
        assert re.search(r'spmv_avg_power_watts\{fmt="[a-z]+"', body)
    finally:
        server.stop_metrics_server()
    assert server._obs_http is None

    summary = server.summary()
    lat = summary["latency"]["latency"]
    assert lat["count"] == 3
    assert lat["p50"] <= lat["p99"]
    assert summary["energy"]  # per-format cells populated


def test_session_trace_monolithic_and_fused_paths(tmp_path):
    """Acceptance: the trace JSONL shows session.optimize -> kernel.compile
    nesting and a kernel.execute span for BOTH the monolithic and the
    fused-partitioned serving paths."""
    tracer = get_tracer()
    clear_kernel_memo()
    session = AutoSpmvSession(_fake_tuner())
    dense = hetero_matrix(256)

    # monolithic compile-time path
    res = session.compile_time_optimize(dense, "latency")
    res.kernel(np.ones(dense.shape[1], np.float32))

    # fused-partitioned path (one Pallas launch)
    part = session.partitioned_optimize(dense, "latency", max_blocks=4, fused=True)
    part.kernel(np.ones(dense.shape[1], np.float32))

    path = tmp_path / "trace.jsonl"
    tracer.export_jsonl(path)
    spans = load_spans(path)
    by_id = {s["id"]: s for s in spans}

    def named(name, **attrs):
        return [
            s for s in spans
            if s["name"] == name
            and all((s.get("attrs") or {}).get(k) == v for k, v in attrs.items())
        ]

    mono = named("session.optimize", mode="compile")
    assert mono and mono[0]["attrs"]["cache_hit"] is False
    mono_children = span_children(spans, mono[0]["id"])
    assert {"cache.lookup", "plan.compute", "kernel.compile"} <= {
        s["name"] for s in mono_children
    }

    fused = named("session.optimize", mode="partitioned", fused=True)
    assert fused
    fused_children = {s["name"] for s in span_children(spans, fused[0]["id"])}
    assert "kernel.compile" in fused_children
    compile_span = next(
        s for s in spans
        if s["name"] == "kernel.compile" and s["parent"] == fused[0]["id"]
    )
    assert compile_span["attrs"]["fused"] is True

    execs = named("kernel.execute", mode="fused")
    assert execs and execs[0]["attrs"]["n_blocks"] == part.n_blocks
    assert execs[0]["attrs"]["formats"]  # per-block formats, "+"-joined
    # executions happen after optimize returned: roots, not optimize children
    for s in execs:
        assert s["parent"] is None or by_id[s["parent"]]["name"] != "session.optimize"


def test_cache_and_memo_counters_flow():
    clear_kernel_memo()
    reg = get_metrics()
    hits = reg.counter("spmv_cache_hits_total")
    misses = reg.counter("spmv_cache_misses_total")
    compiles = reg.counter("spmv_kernel_memo_compiles_total")
    memo_hits = reg.counter("spmv_kernel_memo_hits_total")
    h0, m0, c0, mh0 = hits.value, misses.value, compiles.value, memo_hits.value

    session = AutoSpmvSession(_fake_tuner())
    dense = random_matrix(128, 6.0, "fem", seed=5).astype(np.float32)
    session.compile_time_optimize(dense, "latency")
    assert misses.value == m0 + 1 and compiles.value == c0 + 1
    session.compile_time_optimize(dense, "latency")
    assert hits.value == h0 + 1 and memo_hits.value == mh0 + 1


def test_set_obs_enabled_gates_everything():
    set_obs_enabled(False)
    clear_kernel_memo()
    session = AutoSpmvSession(_fake_tuner())
    dense = random_matrix(96, 5.0, "fem", seed=6).astype(np.float32)
    session.compile_time_optimize(dense, "latency")
    assert get_tracer().spans() == []
    assert get_metrics().counter("spmv_cache_misses_total").value == 0.0
    set_obs_enabled(True)
