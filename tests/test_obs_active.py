"""Active observability: SLO burn-rate alerting (config loading, burn math,
the ok→warning→firing machine with hysteresis, objective escalation, the
``/slo`` scrape), the cost-model residual watchdog's fire→recalibrate→evict
loop with MRE recovery, fleet posterior sync (no-echo shards, idempotent
absorption, reconcile promotion, aggregate merging), and the concurrency of
the scrape/export surfaces under live accounting."""

import json
import math
import threading
import urllib.request

import numpy as np
import pytest

from repro.core.objectives import CalibratedCostModel, FormatCalibration
from repro.core.session import AutoSpmvSession
from repro.obs import set_obs_enabled
from repro.obs.aggregate import merge_shards, read_shard_lines
from repro.obs.anomaly import AnomalyConfig, CostModelWatchdog
from repro.obs.http import ObsHTTPServer
from repro.obs.metrics import get_metrics, reset_metrics
from repro.obs.slo import (
    DEFAULT_TARGETS,
    FIRING,
    OK,
    SLO_CLASSES,
    WARNING,
    SloConfig,
    SloTarget,
    SloTracker,
)
from repro.obs.sync import FleetSync, posterior_lines, write_fleet_shard
from repro.obs.trace import Tracer, get_tracer, load_spans
from repro.telemetry import AdaptiveFormatSelector, TelemetryRecorder
from repro.train.serve import SpmvRequest, SpmvServer
from repro.utils.timing import RollingStats

from tests.test_partition import hetero_matrix, stub_tuner
from tests.test_telemetry import _fake_tuner, _mat


@pytest.fixture(autouse=True)
def _clean_obs():
    """Process-global tracer/registry: isolate every test, leave obs on."""
    set_obs_enabled(True)
    get_tracer().clear()
    reset_metrics()
    yield
    set_obs_enabled(True)
    get_tracer().clear()
    reset_metrics()


# ------------------------------------------------------------------- helpers


def _power_only_config(**over) -> SloConfig:
    """A single-dimension (power) config: mean-based burn makes the
    warning/firing boundary exactly computable in tests."""
    kw = dict(
        fast_window=8,
        slow_window=64,
        min_samples=4,
        targets={"power-capped": SloTarget(avg_power_w=100.0)},
    )
    kw.update(over)
    return SloConfig(**kw)


# -------------------------------------------------------------------- config


def test_slo_config_load_merges_over_defaults(tmp_path):
    path = tmp_path / "slo.json"
    path.write_text(json.dumps({
        "fast_window": 16,
        "fire_burn": 1.5,
        "targets": {"latency-critical": {"p99_latency_s": 0.05}},
    }))
    cfg = SloConfig.load(path)
    assert cfg.fast_window == 16 and cfg.fire_burn == 1.5
    assert cfg.slow_window == SloConfig().slow_window  # untouched default
    assert cfg.targets["latency-critical"].p99_latency_s == 0.05
    # the other classes keep their defaults
    assert cfg.targets["energy-saving"] == DEFAULT_TARGETS["energy-saving"]


@pytest.mark.parametrize("raw", [
    {"fats_window": 16},                                   # typo'd scalar
    {"targets": {"latency-critcal": {"p99_latency_s": 1}}},  # typo'd class
    {"targets": {"balanced": {"p99_latency": 1.0}}},       # typo'd field
])
def test_slo_config_rejects_unknown_keys(tmp_path, raw):
    path = tmp_path / "slo.json"
    path.write_text(json.dumps(raw))
    with pytest.raises(ValueError):
        SloConfig.load(path)


def test_default_targets_track_every_class():
    tracker = SloTracker(SloConfig())
    for slo in SLO_CLASSES:
        assert tracker.state(slo) == OK
        assert slo in tracker.snapshot()["classes"]


# ----------------------------------------------------------------- burn math


def test_rolling_stats_window_mean():
    rs = RollingStats(window=4)
    assert math.isnan(rs.window_mean())
    for v in (1.0, 2.0, 3.0, 4.0, 5.0):
        rs.add(v)
    # the last `window` samples only, not the all-time mean
    assert rs.window_mean() == pytest.approx((2 + 3 + 4 + 5) / 4)
    assert rs.mean == pytest.approx(3.0)


def test_burn_rates_latency_p99_and_power_mean():
    cfg = SloConfig(
        fast_window=8, slow_window=16, min_samples=4,
        targets={"balanced": SloTarget(p99_latency_s=1.0, avg_power_w=100.0)},
    )
    tracker = SloTracker(cfg)
    for _ in range(8):
        tracker.observe("balanced", latency_s=0.5, power_w=50.0)
    burns = tracker.burn_rates("balanced")
    assert burns["latency"]["fast"] == pytest.approx(0.5)  # p99/target
    assert burns["power"]["fast"] == pytest.approx(0.5)  # mean/cap
    # power defaults to energy/latency when not given explicitly
    tracker2 = SloTracker(cfg)
    for _ in range(8):
        tracker2.observe("balanced", latency_s=0.5, energy_j=40.0)
    assert tracker2.burn_rates("balanced")["power"]["fast"] == pytest.approx(0.8)


# -------------------------------------------------------------- state machine


def test_ok_warning_firing_and_hysteresis():
    cfg = _power_only_config()
    tracker = SloTracker(cfg)
    seen = []
    tracker.on_transition(lambda slo, old, new, dim: seen.append((old, new, dim)))

    def feed(power, n):
        for _ in range(n):
            tracker.observe("power-capped", latency_s=0.1, power_w=power)
        return tracker.evaluate()

    feed(50.0, 64)  # healthy history fills the slow window
    assert tracker.state("power-capped") == OK
    # spike: fast window hot (mean 200), slow still cool -> warning only
    feed(200.0, 8)
    assert tracker.state("power-capped") == WARNING
    # sustained: slow mean crosses the cap too -> firing, on the power dim
    feed(200.0, 40)
    assert tracker.state("power-capped") == FIRING
    snap = tracker.snapshot()["classes"]["power-capped"]
    assert snap["firing_dimension"] == "power" and snap["alerts"] == 1
    # hysteresis: fast burn 0.9 is below fire but above warn -> still firing
    feed(90.0, 8)
    assert tracker.state("power-capped") == FIRING
    # cooled below the warning threshold -> straight to ok, no warning stop
    feed(10.0, 8)
    assert tracker.state("power-capped") == OK
    assert [(o, n) for o, n, _ in seen] == [
        (OK, WARNING), (WARNING, FIRING), (FIRING, OK),
    ]
    # entering firing counted exactly once
    counters = {
        c.labels: c.value
        for c in get_metrics().instruments("counter", "slo_alerts_total")
    }
    assert counters[(("slo", "power-capped"),)] == 1


def test_effective_objective_escalates_only_while_firing():
    cfg = SloConfig(
        fast_window=4, slow_window=8, min_samples=2,
        targets={"energy-saving": SloTarget(p99_latency_s=1.0)},
    )
    tracker = SloTracker(cfg)
    assert tracker.effective_objective("energy-saving") == "energy"
    for _ in range(8):
        tracker.observe("energy-saving", latency_s=5.0)
    tracker.evaluate()
    assert tracker.state("energy-saving") == FIRING
    assert tracker.effective_objective("energy-saving") == "latency"
    esc = [
        c.value
        for c in get_metrics().instruments(
            "counter", "slo_escalated_requests_total"
        )
    ]
    assert esc == [1]
    # recovery: healthy samples flush the fast window, the alert clears
    for _ in range(4):
        tracker.observe("energy-saving", latency_s=0.01)
    tracker.evaluate()
    assert tracker.state("energy-saving") == OK
    assert tracker.effective_objective("energy-saving") == "energy"


def test_untracked_class_is_always_ok():
    tracker = SloTracker(_power_only_config())
    tracker.observe("balanced", latency_s=99.0)  # silently ignored
    assert tracker.state("balanced") == OK
    # never escalates: the class's native objective always wins
    assert tracker.effective_objective("balanced") == "efficiency"


# ------------------------------------------------------------- /slo endpoint


def test_slo_endpoint_serves_tracker_snapshot():
    tracker = SloTracker(_power_only_config())
    for _ in range(8):
        tracker.observe("power-capped", latency_s=0.1, power_w=250.0)
    tracker.evaluate()
    server = ObsHTTPServer(slo=tracker.snapshot).start()
    try:
        with urllib.request.urlopen(f"{server.url}/slo", timeout=5) as resp:
            assert resp.status == 200
            payload = json.loads(resp.read())
        assert payload["classes"]["power-capped"]["state"] == FIRING
        assert payload["config"]["fast_window"] == 8
    finally:
        server.stop()


def test_slo_endpoint_404_without_tracker():
    server = ObsHTTPServer().start()
    try:
        with pytest.raises(urllib.error.HTTPError) as e:
            urllib.request.urlopen(f"{server.url}/slo", timeout=5)
        assert e.value.code == 404
    finally:
        server.stop()


# --------------------------------------------------- server escalation (e2e)


def test_server_escalates_slo_classed_requests():
    cfg = SloConfig(
        fast_window=4, slow_window=8, min_samples=2,
        targets={"energy-saving": SloTarget(p99_latency_s=1.0)},
    )
    tracker = SloTracker(cfg)
    server = SpmvServer(AutoSpmvSession(_fake_tuner()), slo=tracker)

    def batch():
        dense = _mat()
        x = np.ones(dense.shape[1], np.float32)
        return [SpmvRequest(rid=0, dense=dense, x=x, slo="energy-saving")]

    done = server.run(batch())
    assert done[0].served_objective == "energy"
    # synthetic overload: the class's latency SLO goes to firing
    for _ in range(8):
        tracker.observe("energy-saving", latency_s=5.0)
    tracker.evaluate()
    done = server.run(batch())
    assert done[0].served_objective == "latency"
    assert server.summary()["slo"]["classes"]["energy-saving"]["alerts"] == 1


# ------------------------------------------------------------------ watchdog


def _healthy_pairs(rng, n=12, scale=2.0, noise=0.03):
    """(predicted, measured) pairs from a well-behaved affine relation."""
    preds = 1e-4 * (1 + rng.random(n) * 10)
    meas = scale * preds * (1 + noise * rng.standard_normal(n))
    return list(zip(preds.tolist(), np.abs(meas).tolist()))


def _feed(recorder, fmt, pairs):
    for p, m in pairs:
        recorder.observe(
            bucket="b", objective="latency", fmt=fmt, measured_s=m, predicted_s=p
        )


def test_watchdog_stays_quiet_on_healthy_residuals():
    session = AutoSpmvSession(stub_tuner(), telemetry=TelemetryRecorder())
    dog = CostModelWatchdog(session, AnomalyConfig(min_samples=4, sustain=2))
    rng = np.random.default_rng(0)
    for _ in range(4):
        _feed(session.telemetry, "csr", _healthy_pairs(rng))
        assert dog.poll() == []
    st = dog.summary()["formats"]["csr"]
    assert st["anomalies"] == 0 and st["baseline_samples"] > 0


def test_watchdog_requires_telemetry():
    with pytest.raises(ValueError):
        CostModelWatchdog(AutoSpmvSession(stub_tuner()))


def test_watchdog_fire_recalibrate_mre_recovers():
    """Acceptance loop (b): a corrupted cost model floods the calibration
    stream with lying predictions; the watchdog fires, drops the poisoned
    window, recalibrates (base-model fallback), evicts the format's cached
    plans — and after healthy traffic returns, the refit MRE lands within
    2x of the pre-corruption fit."""
    session = AutoSpmvSession(stub_tuner(), telemetry=TelemetryRecorder())
    dog = CostModelWatchdog(
        session, AnomalyConfig(min_samples=4, sustain=2, rel_threshold=0.5)
    )
    rng = np.random.default_rng(1)

    # a real cached plan for the format under test (monolithic csr fallback)
    res = session.partitioned_optimize(hetero_matrix(256), "latency")
    assert session.cache.peek(res.bucket, "latency", res.mode) is not None

    # healthy era: learn the baseline, fit the reference calibration
    for _ in range(3):
        _feed(session.telemetry, "csr", _healthy_pairs(rng))
        assert dog.poll() == []
    mre_healthy = session.calibrate(save=False).corrections["csr"].mean_rel_err

    # corruption: predictions shrink 100x (a lying CalibratedCostModel),
    # so |measured - predicted| / predicted explodes
    lying = [(p / 100.0, m) for p, m in _healthy_pairs(rng)]
    _feed(session.telemetry, "csr", lying[:6])
    assert dog.poll() == []  # strike one: anomalous but not yet sustained
    _feed(session.telemetry, "csr", lying[6:])
    assert dog.poll() == ["csr"]

    # the fire dropped the poisoned window, recalibrated, and evicted
    assert session.telemetry.calibration_samples("csr") == []
    assert "csr" not in session.cost_model.corrections  # base-model fallback
    assert session.cache.peek(res.bucket, "latency", res.mode) is None
    assert dog.recalibrations == 1
    assert dog.summary()["formats"]["csr"]["anomalies"] == 1

    # recovery: healthy pairs only (the reset guarantees no lying-era pair
    # can be least-squares'd into this fit)
    for _ in range(3):
        _feed(session.telemetry, "csr", _healthy_pairs(rng))
        assert dog.poll() == []
    mre_recovered = session.calibrate(save=False).corrections["csr"].mean_rel_err
    assert mre_recovered <= 2.0 * max(mre_healthy, 1e-9)


def test_server_wires_watchdog_and_counts_fires():
    session = AutoSpmvSession(stub_tuner(), telemetry=TelemetryRecorder())
    server = SpmvServer(session, anomaly=True)
    assert server.watchdog is not None
    rng = np.random.default_rng(2)
    for _ in range(3):
        _feed(session.telemetry, "ell", _healthy_pairs(rng))
        server.watchdog.poll()
    _feed(session.telemetry, "ell", [(p / 100, m) for p, m in _healthy_pairs(rng)])
    server.watchdog.poll()
    _feed(session.telemetry, "ell", [(p / 100, m) for p, m in _healthy_pairs(rng)])
    assert server.watchdog.poll() == ["ell"]
    assert server.summary()["anomaly"]["recalibrations"] == 1


# ---------------------------------------------------------------- fleet sync


def _measured_selector(updates: dict[str, list[float]]) -> AdaptiveFormatSelector:
    sel = AdaptiveFormatSelector()
    for fmt, times in updates.items():
        for t in times:
            sel.update("b1", "latency", fmt, t)
    return sel


def test_posterior_lines_export_local_pulls_only():
    sel = _measured_selector({"csr": [1.0, 1.2]})
    sel.absorb("b1", "latency", "ell", pulls=50, value=0.5)  # peer evidence
    recs = [json.loads(line) for line in posterior_lines(sel, "a")]
    # only the locally measured arm is exported — absorbed evidence must
    # never echo back into the fleet
    assert [(r["fmt"], r["pulls"]) for r in recs] == [("csr", 2)]
    assert recs[0]["value"] == pytest.approx(1.1)
    assert recs[0]["instance"] == "a"


def test_absorb_is_idempotent_and_reconcile_promotes():
    sel = _measured_selector({"ell": [1.0] * 4})
    assert sel.incumbent("b1", "latency") == "ell"
    for _ in range(3):  # re-absorbing the same shard set changes nothing
        sel.absorb("b1", "latency", "csr", pulls=5, value=0.1)
    cell = sel.cells()[("b1", "latency")]
    assert cell.arms["csr"].absorbed_pulls == 5
    assert cell.arms["csr"].pulls == 0  # local stats untouched
    assert sel.reconcile("b1", "latency") == "csr"
    assert sel.incumbent("b1", "latency") == "csr"
    assert sel.reconcile("b1", "latency") is None  # already the incumbent


def test_absorb_unseen_bucket_adopts_provisional_incumbent():
    sel = AdaptiveFormatSelector()
    sel.absorb("b9", "latency", "sell", pulls=3, value=0.2)
    assert sel.incumbent("b9", "latency") == "sell"
    sel.absorb("b9", "latency", "bad", pulls=0, value=0.0)  # rejected
    assert "bad" not in sel.cells()[("b9", "latency")].arms


def test_fleet_shard_roundtrip_and_aggregate_merge(tmp_path):
    sel_a = _measured_selector({"csr": [1.0] * 3, "ell": [2.0]})
    sel_b = _measured_selector({"csr": [1.1] * 5})
    rec = TelemetryRecorder()
    rec.observe(bucket="b1", objective="latency", fmt="csr",
                measured_s=2e-4, predicted_s=1e-4)
    a = tmp_path / "shard-a.jsonl"
    b = tmp_path / "shard-b.jsonl"
    write_fleet_shard(a, selector=sel_a, recorder=rec, instance="a")
    write_fleet_shard(b, selector=sel_b, instance="b")

    report = merge_shards([a, b])
    post = report["posteriors"]["b1|latency"]
    # merged pulls are exactly the per-instance sums
    assert post["arms"]["csr"]["pulls"] == 3 + 5
    assert post["arms"]["ell"]["pulls"] == 1
    assert post["pulls"] == 9
    # values merge pull-weighted
    assert post["arms"]["csr"]["value"] == pytest.approx(
        (1.0 * 3 + 1.1 * 5) / 8
    )
    assert post["incumbents"] == {"a": "csr", "b": "csr"}
    assert post["converged"] is True
    assert report["calibration"]["csr"]["samples"] == 1
    assert report["instances"] == ["a", "b"]


def test_fleet_sync_two_instances_converge(tmp_path):
    """Acceptance loop (c): two instances sharing a fleet dir end with
    identical incumbents and the merged posterior's pulls equal to the
    per-instance sum — evidence is shared, never echoed or amplified."""
    fleet_dir = tmp_path / "fleet"

    def instance(name, updates):
        session = AutoSpmvSession(
            _fake_tuner(),
            telemetry=TelemetryRecorder(),
            adaptive=_measured_selector(updates),
        )
        return FleetSync(session, fleet_dir, instance=name)

    # A measured csr fast; B only ever measured ell (slow)
    a = instance("a", {"csr": [0.001] * 4})
    b = instance("b", {"ell": [0.010] * 4})

    a.sync()                   # A exports; no peers yet
    stats_b = b.sync()         # B absorbs A's csr evidence -> promotion
    assert stats_b["peers"] == 1 and stats_b["promotions"] == 1
    assert b.session.adaptive.incumbent("b1", "latency") == "csr"
    stats_a = a.sync()         # A absorbs B's ell arm; csr stays incumbent
    assert stats_a["peers"] == 1 and stats_a["promotions"] == 0
    assert a.session.adaptive.incumbent("b1", "latency") == "csr"

    # repeated syncing is idempotent: absorbed totals are setters
    for _ in range(2):
        a.sync()
        b.sync()
    cell_a = a.session.adaptive.cells()[("b1", "latency")]
    cell_b = b.session.adaptive.cells()[("b1", "latency")]
    assert cell_a.arms["ell"].absorbed_pulls == 4
    assert cell_b.arms["csr"].absorbed_pulls == 4

    # every shard still carries only its instance's own measurements, so
    # the fleet-merged pulls are exactly the per-instance sum
    report = merge_shards(sorted(fleet_dir.glob("shard-*.jsonl")))
    post = report["posteriors"]["b1|latency"]
    assert post["arms"]["csr"]["pulls"] == 4
    assert post["arms"]["ell"]["pulls"] == 4
    assert post["pulls"] == 8
    assert post["converged"] is True
    assert set(post["incumbents"].values()) == {"csr"}


def test_fleet_sync_requires_adaptive(tmp_path):
    session = AutoSpmvSession(_fake_tuner(), telemetry=TelemetryRecorder())
    with pytest.raises(ValueError):
        FleetSync(session, tmp_path / "fleet")


def test_maybe_sync_counts_served_requests(tmp_path):
    session = AutoSpmvSession(_fake_tuner(), adaptive=AdaptiveFormatSelector())
    fleet = FleetSync(session, tmp_path / "fleet", instance="a", sync_every=4)
    assert fleet.maybe_sync(3) is None
    assert fleet.maybe_sync(1) is not None  # 4th request triggers
    assert fleet.syncs == 1
    assert fleet.shard_path.exists()


def test_read_shard_lines_streams_and_counts_torn_lines(tmp_path):
    path = tmp_path / "shard.jsonl"
    good = [json.dumps({"kind": "counter", "name": "x", "value": i}) for i in range(3)]
    path.write_text("\n".join(good + ['{"torn": tru', ""]) + "\n")
    records, dropped = read_shard_lines([path])
    assert len(records) == 3 and dropped == 1


# --------------------------------------------------------------- concurrency


def test_scrape_surfaces_survive_concurrent_accounting():
    """/metrics and /slo scraped from multiple threads while the serving
    thread keeps mutating the registry and the tracker's windows."""
    registry = get_metrics()
    tracker = SloTracker(_power_only_config())
    server = ObsHTTPServer(registry, slo=tracker.snapshot).start()
    stop = threading.Event()
    errors: list[Exception] = []

    def scrape(path, parse):
        try:
            while not stop.is_set():
                with urllib.request.urlopen(f"{server.url}{path}", timeout=5) as r:
                    assert r.status == 200
                    parse(r.read())
        except Exception as exc:  # surfaced after join
            errors.append(exc)

    threads = [
        threading.Thread(target=scrape, args=("/metrics", lambda b: b.decode())),
        threading.Thread(target=scrape, args=("/metrics", lambda b: b.decode())),
        threading.Thread(target=scrape, args=("/slo", json.loads)),
        threading.Thread(target=scrape, args=("/slo", json.loads)),
    ]
    try:
        for t in threads:
            t.start()
        for i in range(300):  # the accounting hot loop
            registry.counter("spmv_requests_total", fmt="csr").inc()
            registry.histogram("spmv_request_latency_seconds").observe(1e-3 * i)
            tracker.observe("power-capped", latency_s=1e-3, power_w=float(i % 200))
            if i % 10 == 0:
                tracker.evaluate()
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=10)
        server.stop()
    assert errors == []


def test_trace_export_during_concurrent_appends(tmp_path):
    tracer = Tracer()
    stop = threading.Event()
    errors: list[Exception] = []

    def appender():
        try:
            while not stop.is_set():
                with tracer.span("hot.span", k=1):
                    pass
        except Exception as exc:
            errors.append(exc)

    threads = [threading.Thread(target=appender) for _ in range(2)]
    path = tmp_path / "spans.jsonl"
    try:
        for t in threads:
            t.start()
        for _ in range(20):
            tracer.export_jsonl(path)
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=10)
    assert errors == []
    spans = load_spans(path)
    assert spans and all(s["name"] == "hot.span" for s in spans)


# ----------------------------------------------- corrupted-model sanity unit


def test_corrupted_calibration_is_visible_in_residuals():
    """The exact signal the watchdog keys on: a corrupted correction makes
    the model's predictions diverge from measurements by construction."""
    honest = CalibratedCostModel()
    corrupted = CalibratedCostModel(
        corrections={"csr": FormatCalibration(launch_overhead_s=0.0,
                                              latency_scale=100.0, samples=8)}
    )
    from repro.core.objectives import MatrixStats
    from repro.kernels.common import DEFAULT_SCHEDULE

    stats = MatrixStats(hetero_matrix(128))
    base = honest.evaluate(stats, "csr", DEFAULT_SCHEDULE).latency
    lie = corrupted.evaluate(stats, "csr", DEFAULT_SCHEDULE).latency
    residual = abs(base - lie) / base
    assert residual > 10.0  # far past any AnomalyConfig threshold
