"""Partitioned heterogeneous-format SpMV: partitioner invariants and edge
cases, composite planning (hetero win + homogeneous monolithic fallback),
per-format exactness of the concatenated executor output, and the session /
cache / telemetry integration."""

import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # conftest installs the fallback stub
    from hypothesis import given, settings, strategies as st  # noqa: F811

from repro.core.autotuner import AutoSpMV
from repro.core.features import row_nnz_counts
from repro.core.objectives import ObjectiveValues
from repro.core.session import AutoSpmvSession
from repro.kernels.common import DEFAULT_SCHEDULE
from repro.partition import (
    CompositePlan,
    PartitionedSpmv,
    compile_partitioned,
    partition_rows,
    plan_partitioned,
)
from repro.partition.plan import BlockPlan
from repro.sparse.generate import random_matrix
from repro.sparse.registry import format_names
from repro.telemetry import AdaptiveFormatSelector, TelemetryRecorder, block_arm_bucket


class StubPredictor:
    """Deterministic predictor: fixed format + the default schedule, so plan
    tests exercise the partition/cost-model logic, not classifier fitting."""

    def __init__(self, fmt: str = "csr"):
        self.fmt = fmt

    def predict_format(self, feats, objective):
        return self.fmt

    def predict_schedule(self, feats, objective):
        return DEFAULT_SCHEDULE


def stub_tuner() -> AutoSpMV:
    return AutoSpMV(predictor=StubPredictor())


def hetero_matrix(n: int = 512) -> np.ndarray:
    top = random_matrix(n, n // 4, "denseband", seed=1)[: n // 2]
    bot = random_matrix(n, 3.0, "powerlaw", seed=2)[n // 2 :]
    return np.vstack([top, bot]).astype(np.float32)


# ---------------------------------------------------------------- partitioner


def _check_invariants(part, n_rows):
    assert part.blocks[0].row_start == 0
    assert part.blocks[-1].row_end == n_rows
    for a, b in zip(part.blocks, part.blocks[1:]):
        assert a.row_end == b.row_start
    if n_rows:
        assert all(b.n_rows >= 1 for b in part.blocks)


@pytest.mark.parametrize("pattern", ["banded", "powerlaw", "denseband"])
@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_partition_covers_rows(pattern, k):
    dense = random_matrix(160, 6.0, pattern, seed=7).astype(np.float32)
    part = partition_rows(dense, k)
    _check_invariants(part, 160)
    assert part.n_blocks == k
    assert part.nnz == int((dense != 0).sum())
    # per-block features describe exactly that block's rows
    counts = row_nnz_counts(dense)
    for b in part.blocks:
        assert b.features.n == b.n_rows
        assert b.nnz == int(counts[b.row_start : b.row_end].sum())


def test_partition_empty_matrix():
    part = partition_rows(np.zeros((0, 8), np.float32), 4)
    assert part.n_blocks == 1
    assert part.blocks[0].row_start == part.blocks[0].row_end == 0
    assert part.nnz == 0


def test_partition_all_empty_rows():
    part = partition_rows(np.zeros((40, 40), np.float32), 4)
    _check_invariants(part, 40)
    assert part.n_blocks == 4
    # even row split when there is no nnz signal
    assert max(b.n_rows for b in part.blocks) <= 2 * (40 // 4)


def test_partition_all_nnz_in_one_row():
    dense = np.zeros((32, 32), np.float32)
    dense[11, :] = 1.0
    part = partition_rows(dense, 4)
    _check_invariants(part, 32)
    # exactly one block owns every nonzero
    assert sorted(b.nnz for b in part.blocks) == [0, 0, 0, 32]


def test_partition_more_blocks_than_rows():
    part = partition_rows(np.eye(3, dtype=np.float32), 8)
    _check_invariants(part, 3)
    assert part.n_blocks == 3  # clamped: a block must own at least one row
    with pytest.raises(ValueError):
        partition_rows(np.eye(3, dtype=np.float32), 0)


def test_partition_balances_nnz():
    dense = random_matrix(256, 8.0, "banded", seed=3).astype(np.float32)
    part = partition_rows(dense, 4)
    assert part.imbalance() < 1.5  # near-even nnz split on uniform rows


def test_refinement_does_not_worsen_balance():
    for seed in range(4):
        dense = random_matrix(192, 6.0, "powerlaw", seed=seed).astype(np.float32)
        raw = partition_rows(dense, 4, refine=False)
        refined = partition_rows(dense, 4, refine=True)
        assert refined.imbalance() <= raw.imbalance() + 1e-9


@given(st.integers(min_value=0, max_value=60), st.integers(min_value=1, max_value=9),
       st.integers(min_value=0, max_value=4))
@settings(max_examples=20, deadline=None)
def test_partition_invariants_property(n_rows, k, seed):
    rng = np.random.default_rng(seed)
    dense = (rng.random((n_rows, 12)) < 0.2).astype(np.float32)
    part = partition_rows(dense, k)
    _check_invariants(part, n_rows)
    assert part.n_blocks == (min(k, n_rows) if n_rows else 1)
    assert part.nnz == int(dense.sum())


# ----------------------------------------------------------------------- plan


def test_plan_heterogeneous_beats_monolithic():
    plan = plan_partitioned(StubPredictor(), hetero_matrix(), "latency")
    assert plan.partitioned and plan.n_blocks > 1
    assert plan.gain() > 0
    assert plan.modeled.latency < plan.monolithic.latency


def test_plan_homogeneous_falls_back_to_monolithic():
    homo = random_matrix(256, 8.0, "powerlaw", seed=5).astype(np.float32)
    plan = plan_partitioned(StubPredictor(), homo, "latency")
    assert not plan.partitioned and plan.n_blocks == 1
    # the fallback IS the best single-format baseline: zero regression
    assert plan.modeled.latency == plan.monolithic.latency
    assert plan.formats == (plan.monolithic_fmt,)


def test_plan_respects_block_count_budget():
    plan = plan_partitioned(
        StubPredictor(), hetero_matrix(), "latency", block_counts=(1, 2)
    )
    assert plan.n_blocks <= 2


# ------------------------------------------------------------------- executor


def _forced_plan(dense: np.ndarray, fmt: str, k: int = 3) -> CompositePlan:
    part = partition_rows(dense, k)
    ov = ObjectiveValues(0.0, 0.0, 0.0, 0.0)
    blocks = tuple(BlockPlan(b, fmt, DEFAULT_SCHEDULE, ov, fmt) for b in part.blocks)
    return CompositePlan("latency", part, blocks, ov, ov, fmt)


@pytest.mark.parametrize("fmt", format_names())
@pytest.mark.parametrize("pattern", ["fem", "powerlaw"])
def test_partitioned_output_matches_dense_reference(fmt, pattern, rng):
    """Concatenated per-block output == dense reference, for every
    registered format (heterogeneity cannot corrupt row ranges)."""
    dense = random_matrix(160, 6.0, pattern, seed=11).astype(np.float32)
    x = rng.normal(size=dense.shape[1]).astype(np.float32)
    ref = dense @ x
    kernel = compile_partitioned(dense, _forced_plan(dense, fmt))
    y = np.asarray(kernel(x))
    assert y.shape == ref.shape
    np.testing.assert_allclose(y, ref, rtol=0, atol=2e-3 * np.abs(ref).max())


def test_mixed_formats_exactness(rng):
    dense = hetero_matrix(256)
    part = partition_rows(dense, 4)
    fmts = ["csr", "ell", "bell", "sell"]
    ov = ObjectiveValues(0.0, 0.0, 0.0, 0.0)
    blocks = tuple(
        BlockPlan(b, fmts[i % 4], DEFAULT_SCHEDULE, ov, fmts[i % 4])
        for i, b in enumerate(part.blocks)
    )
    plan = CompositePlan("latency", part, blocks, ov, ov, "csr")
    kernel = compile_partitioned(dense, plan)
    assert kernel.formats == tuple(fmts[: part.n_blocks])
    x = rng.normal(size=dense.shape[1]).astype(np.float32)
    ref = dense @ x
    y, times = kernel.timed_call(x)
    np.testing.assert_allclose(y, ref, rtol=0, atol=2e-3 * np.abs(ref).max())
    # ordinal/shape checks only — no wall-clock thresholds (CI runners are
    # arbitrarily loaded); timing *quality* is covered by the warmup
    # regression test in test_partition_fused.py
    assert len(times) == part.n_blocks and all(t > 0 for t in times)


# -------------------------------------------------------------------- session


def test_session_partitioned_cache_roundtrip(tmp_path):
    dense = hetero_matrix()
    x = np.random.default_rng(0).normal(size=dense.shape[1]).astype(np.float32)
    ref = dense @ x
    session = AutoSpmvSession(stub_tuner())
    r1 = session.partitioned_optimize(dense, "latency")
    assert not r1.cache_hit and session.stats.plans_computed == 1
    np.testing.assert_allclose(
        np.asarray(r1.kernel(x)), ref, rtol=0, atol=2e-2 * np.abs(ref).max()
    )
    r2 = session.partitioned_optimize(dense, "latency")
    assert r2.cache_hit and session.stats.plans_computed == 1
    assert r2.plan.formats == r1.plan.formats

    # JSON round-trip: a fresh session replays the composite decisions
    path = session.save(tmp_path / "cache.json")
    from repro.core.cache import TuningCache

    warm = AutoSpmvSession(stub_tuner(), cache=TuningCache.load(path))
    r3 = warm.partitioned_optimize(dense, "latency")
    assert r3.cache_hit and warm.stats.plans_computed == 0
    assert r3.plan.formats == r1.plan.formats
    assert r3.n_blocks == r1.n_blocks


def test_session_partition_mode_keyed_by_budget():
    dense = hetero_matrix()
    session = AutoSpmvSession(stub_tuner())
    r8 = session.partitioned_optimize(dense, "latency", max_blocks=8)
    r2 = session.partitioned_optimize(dense, "latency", max_blocks=2)
    assert r8.mode != r2.mode  # budgets must not alias cache entries
    assert r2.n_blocks <= 2


def test_serve_partitioned_reports_per_block_identity():
    dense = hetero_matrix()
    session = AutoSpmvSession(
        stub_tuner(),
        telemetry=TelemetryRecorder(),
        adaptive=AdaptiveFormatSelector(),
    )
    res = session.serve_partitioned(dense, "latency")
    k = res.n_blocks
    assert k > 1
    assert len(res.formats) == k and len(res.exploratory) == k
    x = np.random.default_rng(0).normal(size=dense.shape[1]).astype(np.float32)
    y, times = res.kernel.timed_call(x)
    assert len(times) == k and all(t > 0 for t in times)
    # feed SYNTHETIC per-block times: the arm bookkeeping under test is
    # independent of this runner's wall clock, so the assertions stay
    # deterministic on loaded CI machines
    session.observe_partitioned(res, [0.01] * k)
    assert session.stats.observations == 1
    # one telemetry/bandit cell per block, keyed by block_arm_bucket
    cells = {block_arm_bucket(res.bucket, i, k) for i in range(k)}
    assert cells <= {key[0] for key in session.adaptive._cells}


def test_observe_partitioned_block_arms_and_drift_eviction():
    """Each (block, format) pair is its own bandit arm; sustained measured
    drift on a block evicts the composite plan so the next request
    re-plans, and the block's cell promotes the measured-best format."""
    dense = hetero_matrix()
    session = AutoSpmvSession(
        stub_tuner(),
        telemetry=TelemetryRecorder(),
        adaptive=AdaptiveFormatSelector(),
    )
    res = session.partitioned_optimize(dense, "latency")
    k = res.n_blocks
    assert k > 1
    # establish incumbent cells with on-plan measurements
    session.observe_partitioned(res, [0.01] * k)

    # a challenger format measures 10x faster on every block ...
    challenger = "sell" if "sell" not in res.plan.formats else "bcsr_none"
    assert challenger == "sell"  # stub plans never pick sell here
    probe = dataclasses.replace(
        res, served_formats=(challenger,) * k, exploratory=(True,) * k
    )
    for _ in range(3):
        session.observe_partitioned(probe, [0.001] * k)
    # ... while the incumbent keeps drifting: sustained -> eviction
    for _ in range(6):
        session.observe_partitioned(res, [0.01] * k)
    assert session.stats.invalidations >= 1
    assert session.cache.peek(res.bucket, "latency", res.mode) is None
    # the promoted block cell now serves the measured-best format
    promoted = session.adaptive.incumbent(
        block_arm_bucket(res.bucket, 0, k), "latency"
    )
    assert promoted == challenger


def test_serve_partitioned_without_adaptive_is_plain_optimize():
    dense = hetero_matrix()
    session = AutoSpmvSession(stub_tuner())
    res = session.serve_partitioned(dense, "latency")
    assert res.served_formats == ()
    assert res.formats == res.plan.formats


def test_partitioned_spmv_rejects_empty():
    with pytest.raises(ValueError):
        PartitionedSpmv([], 0)
