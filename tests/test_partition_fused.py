"""Fused single-launch partitioned SpMV: equivalence against the sequential
executor and the dense reference across formats / partitions / dtypes,
work-descriptor invariants, composite-plan memoization, and the
``timed_call`` warmup fix (measurement-poisoning regression)."""

import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # conftest installs the fallback stub
    from hypothesis import given, settings, strategies as st  # noqa: F811

from repro.core.objectives import ObjectiveValues
from repro.core.session import AutoSpmvSession
from repro.kernels.common import DEFAULT_SCHEDULE, LANE
from repro.kernels.fused import FusedSpmv, flatten_block, lower_fused
from repro.kernels.ops import (
    compile_spmv_fused,
    evict_kernel_memo_format,
    kernel_memo_stats,
    prepare,
)
from repro.partition import (
    CompositePlan,
    FusedPartitionedSpmv,
    compile_fused_partitioned,
    compile_partitioned,
    partition_rows,
)
from repro.partition.plan import BlockPlan
from repro.sparse.generate import random_matrix
from repro.sparse.registry import format_names

from tests.test_partition import StubPredictor, hetero_matrix, stub_tuner

_ZERO = ObjectiveValues(0.0, 0.0, 0.0, 0.0)


def forced_plan(
    dense: np.ndarray,
    fmts: list[str],
    k: int,
    schedule=DEFAULT_SCHEDULE,
) -> CompositePlan:
    """A CompositePlan with formats assigned round-robin over ``k`` blocks —
    executor tests force the routing so they exercise lowering, not planning."""
    part = partition_rows(dense, k)
    blocks = tuple(
        BlockPlan(b, fmts[i % len(fmts)], schedule, _ZERO, fmts[i % len(fmts)])
        for i, b in enumerate(part.blocks)
    )
    return CompositePlan("latency", part, blocks, _ZERO, _ZERO, fmts[0], schedule)


def _assert_equivalent(dense, plan, x, atol_scale=2e-3):
    ref = dense.astype(np.float64) @ x.astype(np.float64)
    tol = atol_scale * max(np.abs(ref).max(), 1e-6)
    fused = compile_fused_partitioned(dense, plan)
    seq = compile_partitioned(dense, plan)
    y_fused = np.asarray(fused(x))
    y_seq = np.asarray(seq(x))
    np.testing.assert_allclose(y_seq, ref, rtol=0, atol=tol)
    np.testing.assert_allclose(y_fused, ref, rtol=0, atol=tol)
    np.testing.assert_allclose(y_fused, y_seq, rtol=0, atol=tol)
    return fused


# ------------------------------------------------------------- equivalence


@pytest.mark.parametrize("fmt", format_names())
@pytest.mark.parametrize("k", [1, 2, 4])
def test_fused_matches_sequential_per_format(fmt, k, rng):
    dense = random_matrix(160, 6.0, "powerlaw", seed=11).astype(np.float32)
    x = rng.normal(size=dense.shape[1]).astype(np.float32)
    fused = _assert_equivalent(dense, forced_plan(dense, [fmt], k), x)
    assert fused.formats == (fmt,) * min(k, fused.n_blocks)


@pytest.mark.parametrize(
    "fmts",
    [["csr", "ell"], ["sell", "bell"], ["csr", "ell", "bell", "sell"]],
)
def test_fused_heterogeneous_formats(fmts, rng):
    dense = hetero_matrix(256)
    x = rng.normal(size=dense.shape[1]).astype(np.float32)
    fused = _assert_equivalent(dense, forced_plan(dense, fmts, 4), x)
    assert fused.n_blocks == 4
    assert set(fused.formats) == set(fmts)


def test_fused_bf16_accumulation(rng):
    dense = random_matrix(192, 5.0, "banded", seed=3).astype(np.float32)
    x = rng.normal(size=dense.shape[1]).astype(np.float32)
    sched = DEFAULT_SCHEDULE.replace(accum_dtype="bfloat16")
    fused = _assert_equivalent(
        dense, forced_plan(dense, ["csr", "ell"], 2, sched), x, atol_scale=2e-2
    )
    assert fused.kernel.accum_dtype == "bfloat16"


# --------------------------------------------------------------- edge cases


def test_fused_single_block_plan(rng):
    dense = random_matrix(96, 4.0, "fem", seed=9).astype(np.float32)
    x = rng.normal(size=dense.shape[1]).astype(np.float32)
    fused = _assert_equivalent(dense, forced_plan(dense, ["csr"], 1), x)
    assert fused.n_blocks == 1


def test_fused_all_zero_matrix():
    dense = np.zeros((64, 64), np.float32)
    fused = compile_fused_partitioned(dense, forced_plan(dense, ["csr"], 2))
    y = np.asarray(fused(np.ones(64, np.float32)))
    assert y.shape == (64,) and not y.any()
    # a fully-empty composite still gets a (single, all-spill) work item
    assert fused.n_tiles == 1


def test_fused_empty_block_contributes_no_work(rng):
    # one populated row: the nnz balancer leaves the other blocks empty,
    # so their streams flatten to zero work items
    dense = np.zeros((64, 64), np.float32)
    dense[11] = rng.normal(size=64).astype(np.float32)
    plan = forced_plan(dense, ["csr"], 4)
    assert any(bp.block.nnz == 0 for bp in plan.blocks)
    x = rng.normal(size=64).astype(np.float32)
    fused = _assert_equivalent(dense, plan, x)
    populated = {bp.block.index for bp in plan.blocks if bp.block.nnz > 0}
    assert set(fused.kernel.block_of_tile) <= populated


def test_fused_single_hub_row(rng):
    dense = np.zeros((48, 48), np.float32)
    dense[17] = rng.normal(size=48).astype(np.float32)
    x = rng.normal(size=48).astype(np.float32)
    _assert_equivalent(dense, forced_plan(dense, ["csr", "ell"], 4), x)


# ------------------------------------------------------- work descriptor


def test_work_descriptor_invariants():
    dense = hetero_matrix(256)
    plan = forced_plan(dense, ["csr", "ell", "bell", "sell"], 4)
    fused = lower_fused(dense, plan)
    assert isinstance(fused, FusedSpmv)
    n_tiles, tile = fused.n_tiles, fused.tile
    assert tile % LANE == 0 and tile % fused.unroll == 0
    # one flat stream, one tile quantum: program p's operands live at
    # [tile_map[p] * tile, (tile_map[p] + 1) * tile)
    assert fused.data.shape[0] == n_tiles * tile
    tmap = np.asarray(fused.tile_map)
    assert sorted(tmap.tolist()) == list(range(n_tiles))
    # block ownership is contiguous in program order (prefix-sum layout)
    assert list(fused.block_of_tile) == sorted(fused.block_of_tile)
    assert len(fused.block_of_tile) == n_tiles
    # padding slots are inert: value 0 aimed at the spill row
    rows = np.asarray(fused.rows)
    data = np.asarray(fused.data)
    assert (rows[data == 0] == fused.n_rows).all() or (data != 0).all()
    assert (rows <= fused.n_rows).all()


def test_flatten_block_is_nnz_exact():
    dense = random_matrix(96, 7.0, "powerlaw", seed=5).astype(np.float32)
    nnz = int((dense != 0).sum())
    for fmt in format_names():
        mat = prepare(dense, fmt, DEFAULT_SCHEDULE)
        data, cols, rows = flatten_block(mat, 10)
        # padding filtered: the stream is exactly the stored nonzeros
        assert data.size == cols.size == rows.size
        assert data.size <= nnz and (data != 0).all()
        recon = np.zeros((106, dense.shape[1]), np.float64)
        np.add.at(recon, (rows, cols), data.astype(np.float64))
        np.testing.assert_allclose(recon[10 : 10 + 96], dense, rtol=0, atol=1e-6)


# ------------------------------------------------------------------- memo


def test_fused_kernel_memoized_per_plan():
    dense = hetero_matrix(128)
    plan = forced_plan(dense, ["csr", "ell"], 2)
    key = "fused-memo-test"
    before = kernel_memo_stats()
    k1 = compile_spmv_fused(dense, plan, memo_key=key)
    k2 = compile_spmv_fused(dense, plan, memo_key=key)
    after = kernel_memo_stats()
    assert k1 is k2  # ONE memo entry for the whole composite
    assert after["compiles"] == before["compiles"] + 1
    assert after["hits"] == before["hits"] + 1
    # a different plan over the same matrix is a different entry
    other = forced_plan(dense, ["sell"], 2)
    k3 = compile_spmv_fused(dense, other, memo_key=key)
    assert k3 is not k1

    # retiring ANY constituent format retires the fused composite
    assert evict_kernel_memo_format("ell") >= 1
    k4 = compile_spmv_fused(dense, plan, memo_key=key)
    assert k4 is not k1


# ---------------------------------------------------------------- session


def test_session_fused_partitioned_optimize(rng):
    dense = hetero_matrix()
    x = rng.normal(size=dense.shape[1]).astype(np.float32)
    session = AutoSpmvSession(stub_tuner())
    res = session.partitioned_optimize(dense, "latency", fused=True)
    assert isinstance(res.kernel, FusedPartitionedSpmv)
    assert res.kernel.n_blocks == res.n_blocks
    assert res.kernel.formats == res.plan.formats
    ref = dense @ x
    np.testing.assert_allclose(
        np.asarray(res.kernel(x)), ref, rtol=0, atol=2e-3 * np.abs(ref).max()
    )
    desc = res.kernel.descriptor()
    assert len(desc["tile_map"]) == res.kernel.n_tiles
    assert len(desc["block_ranges"]) == res.n_blocks
    # the fused and sequential executors cache-share the same plan entry
    res2 = session.partitioned_optimize(dense, "latency", fused=False)
    assert res2.cache_hit and res2.plan.formats == res.plan.formats


# ------------------------------------------------- timed_call measurement


def test_timed_call_warms_up_before_measuring(rng):
    """Regression: the first measured window must not include trace/compile
    (it used to seed bandit arms with launch-setup garbage)."""
    dense = hetero_matrix(256)
    plan = forced_plan(dense, ["csr", "ell"], 2)
    kernel = compile_partitioned(dense, plan)
    calls = []

    def counting(f, idx):
        def run(x):
            calls.append(idx)
            return f(x)

        return run

    kernel.blocks = [
        dataclasses.replace(b, kernel=counting(b.kernel, i))
        for i, b in enumerate(kernel.blocks)
    ]
    x = rng.normal(size=dense.shape[1]).astype(np.float32)
    y, times = kernel.timed_call(x)
    # first timed_call: one untimed warmup + one timed execution per block
    assert sorted(calls) == [0, 0, 1, 1]
    assert len(times) == 2 and all(t > 0 for t in times)
    np.testing.assert_allclose(
        y, dense @ x, rtol=0, atol=2e-3 * np.abs(dense @ x).max()
    )
    calls.clear()
    kernel.timed_call(x)
    assert sorted(calls) == [0, 1]  # warmed: no extra executions

    fresh = compile_partitioned(dense, plan)
    first = fresh.timed_call(x)[1]
    steady = [fresh.timed_call(x)[1] for _ in range(4)]
    med = np.median([t for ts in steady for t in ts])
    # interpret-mode sanity: the first recorded sample sits within a sane
    # multiple of steady state rather than orders of magnitude above it
    assert max(first) <= 50 * max(med, 1e-5)


def test_timed_call_opt_out_keeps_cold_measurement(rng):
    dense = hetero_matrix(128)
    kernel = compile_partitioned(dense, forced_plan(dense, ["csr"], 2))
    calls = []

    def counting(f, idx):
        def run(x):
            calls.append(idx)
            return f(x)

        return run

    kernel.blocks = [
        dataclasses.replace(b, kernel=counting(b.kernel, i))
        for i, b in enumerate(kernel.blocks)
    ]
    x = rng.normal(size=dense.shape[1]).astype(np.float32)
    kernel.timed_call(x, warmup=False)
    assert sorted(calls) == [0, 1]  # no warmup executions


# ------------------------------------------------------------- hypothesis


@given(
    st.integers(min_value=1, max_value=48),
    st.integers(min_value=1, max_value=5),
    st.sampled_from(["csr", "ell", "bell", "sell"]),
    st.sampled_from(["csr", "ell", "bell", "sell"]),
    st.integers(min_value=0, max_value=6),
)
@settings(max_examples=25, deadline=None)
def test_fused_equivalence_property(n_rows, k, fmt_a, fmt_b, seed):
    rng = np.random.default_rng(seed)
    dense = (rng.random((n_rows, 64)) < 0.15).astype(np.float32)
    dense *= rng.normal(size=dense.shape).astype(np.float32)
    x = rng.normal(size=64).astype(np.float32)
    plan = forced_plan(dense, [fmt_a, fmt_b], k)
    _assert_equivalent(dense, plan, x)
