"""Multi-device partitioned SpMV (`shard_map` executor).

Runs meaningfully only with several devices; CI provides them on CPU via
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` in a dedicated job
(conftest deliberately sets no XLA flags, so the tier-1 run sees the real
single device and these tests skip)."""

import numpy as np
import pytest

import jax

from repro.dist.sharding import SPMV_RULES, spec_for, spmv_mesh
from repro.partition import partition_rows, shard_partitioned
from repro.sparse.generate import random_matrix

multidevice = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >= 2 devices (XLA_FLAGS=--xla_force_host_platform_device_count=4)",
)


def _hetero(n: int = 256) -> np.ndarray:
    top = random_matrix(n, n // 4, "denseband", seed=1)[: n // 2]
    bot = random_matrix(n, 3.0, "powerlaw", seed=2)[n // 2 :]
    return np.vstack([top, bot]).astype(np.float32)


def test_spmv_rules_map_blocks_to_data_axis():
    mesh = spmv_mesh(1)
    from jax.sharding import PartitionSpec as P

    assert spec_for(mesh, (4, 8, 16), ("blocks", None, None), SPMV_RULES) == P("data")
    assert spec_for(mesh, (64,), (None,), SPMV_RULES) == P()  # X replicated


@multidevice
def test_sharded_executor_matches_dense_reference(rng):
    n_dev = len(jax.devices())
    dense = _hetero(256)
    x = rng.normal(size=dense.shape[1]).astype(np.float32)
    ref = dense @ x
    sharded = shard_partitioned(dense, partition_rows(dense, n_dev))
    assert sharded.n_blocks == n_dev
    y = sharded(x)
    np.testing.assert_allclose(y, ref, rtol=0, atol=2e-3 * np.abs(ref).max())


@multidevice
def test_sharded_y_shards_stay_local():
    n_dev = len(jax.devices())
    dense = _hetero(256)
    x = np.random.default_rng(1).normal(size=dense.shape[1]).astype(np.float32)
    sharded = shard_partitioned(dense, partition_rows(dense, n_dev))
    y = sharded.sharded_call(x)
    # one row-block shard per device, none replicated
    assert y.shape[0] == n_dev
    devices = {s.device for s in y.addressable_shards}
    assert len(devices) == n_dev
    assert {s.data.shape[0] for s in y.addressable_shards} == {1}


@multidevice
def test_sharded_repartitions_to_mesh_extent():
    n_dev = len(jax.devices())
    dense = _hetero(256)
    x = np.random.default_rng(2).normal(size=dense.shape[1]).astype(np.float32)
    ref = dense @ x
    # a partition with the "wrong" block count is re-cut to one per device
    sharded = shard_partitioned(dense, partition_rows(dense, 2 * n_dev))
    assert sharded.n_blocks == n_dev
    y = sharded(x)
    np.testing.assert_allclose(y, ref, rtol=0, atol=2e-3 * np.abs(ref).max())


@multidevice
def test_sharded_from_composite_plan():
    """The CompositePlan input path: carrier schedule from block 0."""
    from repro.kernels.common import DEFAULT_SCHEDULE
    from repro.partition import plan_partitioned

    class _Stub:
        def predict_format(self, feats, objective):
            return "csr"

        def predict_schedule(self, feats, objective):
            return DEFAULT_SCHEDULE

    dense = _hetero(512)
    plan = plan_partitioned(_Stub(), dense, "latency")
    x = np.random.default_rng(3).normal(size=dense.shape[1]).astype(np.float32)
    ref = dense @ x
    sharded = shard_partitioned(dense, plan)
    y = sharded(x)
    assert np.abs(y - ref).max() <= 2e-2 * np.abs(ref).max()
