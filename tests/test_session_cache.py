"""Tests for the autotuning session layer: TuningCache semantics, batched
dedup in optimize_many, save/load warm restarts, and the amortized-overhead
conversion decision (paper §5.3 paid-once economics)."""

import numpy as np
import pytest

from repro.core import (
    AutoSpMV,
    AutoSpmvPredictor,
    AutoSpmvSession,
    CacheEntry,
    OverheadPredictor,
    PredictorConfig,
    TuningCache,
    collect_dataset,
    extract_features,
    feature_bucket,
    measure_overheads,
)
from repro.kernels.common import DEFAULT_SCHEDULE
from repro.kernels.ops import clear_kernel_memo, kernel_memo_stats
from repro.sparse.generate import MATRIX_NAMES, generate_by_name, random_matrix

SCALE = 0.0015
N_UNIQUE = 5


# --------------------------------------------------------------------- fakes
class _FakePredictor:
    """Deterministic predictor: 'ell' always wins by 2x on every objective."""

    def predict_format(self, feats, objective):
        return "ell"

    def predict_schedule(self, feats, objective):
        return DEFAULT_SCHEDULE

    def estimate_objective(self, feats, config, objective):
        return 0.5 if config.fmt == "ell" else 1.0


class _FakeOverhead:
    def __init__(self, total: float, c: float = 1.0):
        self.total = total
        self.c = c

    def total_overhead(self, feats, fmt):
        return self.total

    def predict_c(self, feats, fmt):
        return self.c


@pytest.fixture
def fake_tuner():
    return AutoSpMV(_FakePredictor(), _FakeOverhead(total=1e6, c=1.0))


@pytest.fixture(scope="module")
def real_tuner():
    ds = collect_dataset(scale=SCALE, names=MATRIX_NAMES[:6], n_extra=2)
    pred = AutoSpmvPredictor(PredictorConfig(max_regressor_samples=1000)).fit(ds)
    oh = OverheadPredictor().fit(
        [measure_overheads(generate_by_name(n, scale=SCALE), n)
         for n in MATRIX_NAMES[:6]]
    )
    return AutoSpMV(pred, oh)


def _unique_mats():
    """N_UNIQUE matrices engineered to land in distinct feature buckets."""
    mats = [
        random_matrix(96 * (i + 1), 4.0 * (i + 1), "fem", seed=i)
        for i in range(N_UNIQUE)
    ]
    buckets = {feature_bucket(extract_features(m)) for m in mats}
    assert len(buckets) == N_UNIQUE, "test matrices must span distinct buckets"
    return mats


# --------------------------------------------------------------- TuningCache
def test_feature_bucket_stable_and_discriminative():
    a = random_matrix(128, 6.0, "fem", seed=0)
    same = feature_bucket(extract_features(a))
    assert same == feature_bucket(extract_features(a.copy()))
    b = random_matrix(512, 24.0, "powerlaw", seed=1)
    assert feature_bucket(extract_features(b)) != same


def test_cache_hit_miss_accounting():
    cache = TuningCache()
    assert cache.get("b1", "latency", "compile") is None
    assert cache.stats() == {"entries": 0, "hits": 0, "misses": 1}
    entry = CacheEntry(
        bucket="b1", objective="latency", mode="compile",
        fmt="csr", schedule=DEFAULT_SCHEDULE.as_dict(),
    )
    cache.put(entry)
    got = cache.get("b1", "latency", "compile")
    assert got is entry and got.hits == 1
    assert got.kernel_schedule() == DEFAULT_SCHEDULE
    # different objective / mode are distinct keys
    assert cache.get("b1", "energy", "compile") is None
    assert cache.get("b1", "latency", "run:csr") is None
    assert cache.stats()["hits"] == 1 and cache.stats()["misses"] == 3


def test_cache_save_load_roundtrip(tmp_path):
    cache = TuningCache(resolution=0.25)
    cache.put(CacheEntry(
        bucket="b1", objective="latency", mode="compile", fmt="csr",
        schedule=DEFAULT_SCHEDULE.as_dict(), predicted={"latency": 1.5},
    ))
    cache.put(CacheEntry(
        bucket="b2", objective="energy", mode="run:csr", fmt="ell",
        schedule=DEFAULT_SCHEDULE.as_dict(),
        gain_per_iter=0.5, latency_gain_per_iter=1e-6, overhead_s=0.02,
    ))
    p = cache.save(tmp_path / "cache.json")
    loaded = TuningCache.load(p)
    assert loaded.resolution == 0.25 and len(loaded) == 2
    e = loaded.peek("b2", "energy", "run:csr")
    assert e.fmt == "ell" and e.overhead_s == pytest.approx(0.02)
    assert loaded.peek("b1", "latency", "compile").predicted == {"latency": 1.5}


def test_cache_load_rejects_unknown_version(tmp_path):
    p = tmp_path / "bad.json"
    p.write_text('{"version": 999, "resolution": 0.5, "entries": []}')
    with pytest.raises(ValueError):
        TuningCache.load(p)


# ------------------------------------------------------------------- session
def test_compile_time_hit_skips_planning(fake_tuner):
    session = AutoSpmvSession(fake_tuner)
    dense = random_matrix(128, 6.0, "fem", seed=0)
    r1 = session.compile_time_optimize(dense)
    r2 = session.compile_time_optimize(dense.copy())  # same bytes, new array
    assert session.stats.plans_computed == 1
    assert session.stats.feature_extractions == 1  # fingerprint memo
    assert session.stats.cache_hits == 1 and session.stats.cache_misses == 1
    assert r1.schedule == r2.schedule
    assert r2.kernel is r1.kernel  # process-wide kernel memo


def test_optimize_many_dedup_exact_pass_counts(fake_tuner):
    """The acceptance criterion: 20 matrices over 5 buckets -> exactly 5
    feature-extraction passes and 5 kernel-compile passes."""
    clear_kernel_memo()
    session = AutoSpmvSession(fake_tuner)
    uniques = _unique_mats()
    mats = [m for m in uniques for _ in range(4)]  # 20 requests
    rng = np.random.default_rng(0)
    mats = [mats[i] for i in rng.permutation(len(mats))]
    results = session.optimize_many(mats, "latency")
    assert len(results) == 20
    assert session.stats.requests == 20
    assert session.stats.feature_extractions == N_UNIQUE
    assert session.stats.kernel_compiles == N_UNIQUE
    assert session.stats.plans_computed == N_UNIQUE  # buckets are distinct
    # kernels fan back out: a repeated matrix gets the identical kernel object
    by_fp = {}
    for m, r in zip(mats, results):
        key = m.tobytes()
        by_fp.setdefault(key, r)
        assert r.kernel is by_fp[key].kernel


def test_optimize_many_matches_per_matrix_autospmv(real_tuner):
    """Batched results must agree with one-at-a-time AutoSpMV decisions."""
    mats = [generate_by_name(n, scale=SCALE) for n in MATRIX_NAMES[:4]]
    session = AutoSpmvSession(real_tuner)
    batched = session.optimize_many(mats, "latency")
    for dense, got in zip(mats, batched):
        solo = real_tuner.compile_time_optimize(dense, "latency")
        assert got.schedule == solo.schedule
        assert got.predicted == pytest.approx(solo.predicted)
        x = np.random.default_rng(0).normal(size=dense.shape[1]).astype(np.float32)
        np.testing.assert_allclose(
            np.asarray(got.kernel(x)), np.asarray(solo.kernel(x)), rtol=1e-5, atol=1e-5
        )


def test_optimize_many_run_mode(fake_tuner):
    session = AutoSpmvSession(fake_tuner)
    mats = _unique_mats()[:2] * 2
    results = session.optimize_many(
        mats, "latency", mode="run", n_iterations=10
    )
    assert len(results) == 4
    assert all(r.best_format == "ell" for r in results)
    with pytest.raises(ValueError):
        session.optimize_many(mats, mode="batch")


def test_warm_reload_answers_without_recompiling(fake_tuner, tmp_path):
    """A session restored from disk must serve compile_time_optimize from
    the plan cache + kernel memo: no predictor inference, no re-compile."""
    dense = random_matrix(160, 8.0, "banded", seed=3)
    path = tmp_path / "session.json"
    first = AutoSpmvSession(fake_tuner, cache_path=path)
    r1 = first.compile_time_optimize(dense)
    first.save()

    warm = AutoSpmvSession(fake_tuner, cache_path=path)
    assert len(warm.cache) == 1
    compiles_before = kernel_memo_stats()["compiles"]
    r2 = warm.compile_time_optimize(dense)
    assert warm.stats.plans_computed == 0  # plan from disk
    assert kernel_memo_stats()["compiles"] == compiles_before  # kernel from memo
    assert r2.schedule == r1.schedule and r2.kernel is r1.kernel


def test_save_requires_path(fake_tuner):
    with pytest.raises(ValueError):
        AutoSpmvSession(fake_tuner).save()


# -------------------------------------------------- amortized overhead (§5.3)
def test_amortized_overhead_flips_convert_decision(fake_tuner):
    """Cold call: the predicted f+c+o+p overhead (1e6 s) swamps the gain ->
    keep CSR. Warm call on the same bucket: the decision terms were already
    paid, only the conversion term (1 s, kernel not yet memoized) is charged
    -> convert to the predicted winner. Third call: kernel memoized, zero
    marginal overhead."""
    clear_kernel_memo()
    session = AutoSpmvSession(fake_tuner)
    dense = random_matrix(128, 6.0, "fem", seed=7)
    cold = session.run_time_optimize(dense, n_iterations=100)
    assert not cold.convert and cold.kernel is None
    assert cold.predicted_overhead == pytest.approx(1e6)

    warm = session.run_time_optimize(dense, n_iterations=100)
    assert warm.convert and warm.kernel is not None
    assert warm.best_format == "ell"
    assert warm.predicted_overhead == pytest.approx(1.0)  # c term only
    assert session.stats.overhead_paid_s == pytest.approx(1e6)
    assert session.stats.overhead_saved_s == pytest.approx(1e6 - 1.0)

    third = session.run_time_optimize(dense, n_iterations=100)
    assert third.convert and third.predicted_overhead == 0.0  # kernel memoized


def test_plan_miss_credits_already_memoized_kernel(fake_tuner):
    """A plan-cache miss for a *new objective* on a matrix whose converted
    kernel is already memoized must not re-charge the conversion term."""
    clear_kernel_memo()
    session = AutoSpmvSession(fake_tuner)
    dense = random_matrix(128, 6.0, "fem", seed=13)
    # converts on the warm (2nd) latency call -> ell kernel becomes memoized
    session.run_time_optimize(dense, "latency", n_iterations=100)
    warm = session.run_time_optimize(dense, "latency", n_iterations=100)
    assert warm.convert
    paid_before = session.stats.overhead_paid_s
    miss = session.run_time_optimize(dense, "energy", n_iterations=100)
    assert miss.predicted_overhead == pytest.approx(1e6 - 1.0)  # c credited
    assert session.stats.overhead_paid_s - paid_before == pytest.approx(1e6 - 1.0)


def test_reloaded_session_still_charges_conversion(fake_tuner, tmp_path):
    """After a JSON reload in a *fresh process* (kernel memo empty), a plan
    hit must still charge the c term: a 1-iteration workload whose gain
    cannot cover conversion must not convert."""
    clear_kernel_memo()
    path = tmp_path / "cache.json"
    dense = random_matrix(128, 6.0, "fem", seed=11)
    # gain/iter is 0.5 s (fake predictor); make conversion cost 10 s
    tuner = AutoSpMV(_FakePredictor(), _FakeOverhead(total=1e6, c=10.0))
    first = AutoSpmvSession(tuner, cache_path=path)
    first.run_time_optimize(dense, n_iterations=1)
    first.save()

    clear_kernel_memo()  # simulate process restart
    warm = AutoSpmvSession(tuner, cache_path=path)
    few = warm.run_time_optimize(dense, n_iterations=1)
    assert not few.convert  # 0.5 * 1 < 10: conversion still costs real time
    assert few.predicted_overhead == pytest.approx(10.0)
    many = warm.run_time_optimize(dense, n_iterations=1000)
    assert many.convert  # 0.5 * 1000 > 10


def test_kernel_memo_lru_bound():
    from repro.kernels.ops import (
        kernel_memo_size,
        kernel_memo_stats,
        set_kernel_memo_limit,
    )
    from repro.kernels.ops import compile_spmv

    clear_kernel_memo()
    old_limit = None
    try:
        from repro.kernels import ops

        old_limit = ops.kernel_memo_limit()
        set_kernel_memo_limit(2)
        mats = [random_matrix(96, 4.0, "fem", seed=s) for s in range(3)]
        for i, m in enumerate(mats):
            compile_spmv(m, "csr", DEFAULT_SCHEDULE, memo_key=f"m{i}")
        assert kernel_memo_size() == 2  # oldest evicted
        assert kernel_memo_stats()["evictions"] >= 1
        evictions = kernel_memo_stats()["evictions"]
        compile_spmv(mats[0], "csr", DEFAULT_SCHEDULE, memo_key="m0")  # re-compile
        assert kernel_memo_stats()["evictions"] == evictions + 1
    finally:
        if old_limit is not None:
            set_kernel_memo_limit(old_limit)
        clear_kernel_memo()


def test_run_time_cold_matches_unwrapped_tuner(real_tuner):
    dense = generate_by_name(MATRIX_NAMES[0], scale=SCALE)
    session = AutoSpmvSession(real_tuner)
    wrapped = session.run_time_optimize(dense, "efficiency", n_iterations=1000)
    direct = real_tuner.run_time_optimize(dense, "efficiency", n_iterations=1000)
    assert wrapped.best_format == direct.best_format
    assert wrapped.convert == direct.convert
    assert wrapped.predicted_gain_per_iter == pytest.approx(
        direct.predicted_gain_per_iter
    )
    assert wrapped.predicted_overhead == pytest.approx(direct.predicted_overhead)


def test_run_mode_key_distinguishes_current_format(fake_tuner):
    """Plans are cached per held format: tuning from 'ell' must not reuse
    the from-'csr' plan (the gain baseline differs)."""
    session = AutoSpmvSession(fake_tuner)
    dense = random_matrix(128, 6.0, "fem", seed=9)
    session.run_time_optimize(dense, current_format="csr", n_iterations=10)
    assert session.stats.plans_computed == 1
    session.run_time_optimize(dense, current_format="ell", n_iterations=10)
    assert session.stats.plans_computed == 2  # distinct cache key -> new plan
    session.run_time_optimize(dense, current_format="ell", n_iterations=10)
    assert session.stats.plans_computed == 2  # now cached


# ----------------------------------------------------------------- SpmvServer
def test_spmv_server_batches_and_reuses(fake_tuner):
    from repro.train.serve import SpmvRequest, SpmvServer

    session = AutoSpmvSession(fake_tuner)
    server = SpmvServer(session)
    uniques = _unique_mats()[:3]
    rng = np.random.default_rng(1)

    def batch(rid0):
        reqs = []
        for i, m in enumerate(uniques):
            x = rng.normal(size=m.shape[1]).astype(np.float32)
            reqs.append(SpmvRequest(rid=rid0 + i, dense=m, x=x))
        return reqs

    first = server.run(batch(0))
    assert all(not r.cache_hit for r in first)
    second = server.run(batch(10))
    assert all(r.cache_hit for r in second)
    assert session.stats.plans_computed == 3  # nothing re-planned
    for r in first + second:
        ref = r.dense @ r.x
        err = np.abs(r.y - ref).max() / (np.abs(ref).max() + 1e-9)
        assert err < 1e-3
