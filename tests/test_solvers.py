"""Tests for the iterative-solver subsystem (repro.solvers): reference
correctness of PageRank/CG/power, dangling-node stochasticity, the adaptive
SpMV<->SpMSpV policy's density routing and bandit-learned crossover, and
the one-plan amortization contract across a 50-iteration solve."""

import numpy as np
import pytest

from repro.core import AutoSpMV, AutoSpmvSession
from repro.kernels.common import DEFAULT_SCHEDULE, KernelSchedule
from repro.obs.trace import get_tracer
from repro.solvers import AdaptiveSpmvPolicy, IterativeSolver, cg, pagerank, power_iteration
from repro.solvers.adaptive import SPMSPV, SPMV
from repro.solvers.pagerank import pagerank_reference
from repro.sparse.generate import generate_by_name, normalize_columns, random_matrix
from repro.telemetry import AdaptiveFormatSelector, phase_arm_bucket

WEB_SCALE = 0.0002  # webgraph at n=175: interpret-mode-friendly


class _FakePredictor:
    def __init__(self, schedule=DEFAULT_SCHEDULE):
        self.schedule = schedule

    def predict_format(self, feats, objective):
        return "ell"

    def predict_schedule(self, feats, objective):
        return self.schedule

    def estimate_objective(self, feats, config, objective):
        return 0.5 if config.fmt == "ell" else 1.0


class _FakeOverhead:
    def total_overhead(self, feats, fmt):
        return 1e6

    def predict_c(self, feats, fmt):
        return 1.0


def _session(schedule=DEFAULT_SCHEDULE):
    return AutoSpmvSession(AutoSpMV(_FakePredictor(schedule), _FakeOverhead()))


@pytest.fixture
def session():
    return _session()


@pytest.fixture(scope="module")
def web():
    return generate_by_name("webgraph", scale=WEB_SCALE)


# ------------------------------------------------------------------ pagerank
def test_pagerank_matches_dense_reference(session, web):
    res = pagerank(session, web, tol=1e-9, max_iters=300)
    ref = pagerank_reference(web, tol=1e-12)
    assert res.converged
    assert np.abs(res.value - ref).max() < 1e-5
    # reference ranks and served ranks order the top nodes identically
    assert list(np.argsort(res.value)[-5:]) == list(np.argsort(ref)[-5:])


def test_pagerank_dangling_stochasticity(session, web):
    dangling = int((web.sum(axis=0) == 0).sum())
    assert dangling > 0, "webgraph generator must produce dangling nodes"
    res = pagerank(session, web, tol=1e-9, max_iters=300)
    assert res.extras["dangling_nodes"] == dangling
    # dangling-mass redistribution keeps the ranks a probability vector
    assert abs(res.extras["rank_sum"] - 1.0) < 1e-5
    assert np.all(res.value >= 0)


def test_normalize_columns_is_stochastic_except_dangling(web):
    P = normalize_columns(web)
    sums = P.sum(axis=0)
    nonzero = web.sum(axis=0) > 0
    np.testing.assert_allclose(sums[nonzero], 1.0, atol=1e-5)
    assert np.all(sums[~nonzero] == 0)


# ------------------------------------------------------------------------ cg
def _spd(n=128, seed=3):
    F = random_matrix(n, 6.0, "fem", seed=seed).astype(np.float32)
    S = (F + F.T) / 2
    margin = float(np.abs(S).sum(axis=1).max()) + 1.0
    return (S + margin * np.eye(n, dtype=np.float32)).astype(np.float32)


def test_cg_converges_with_decreasing_residuals(session):
    S = _spd()
    b = np.random.default_rng(0).standard_normal(128).astype(np.float32)
    res = cg(session, S, b, tol=1e-10, max_iters=200)
    assert res.converged
    x_ref = np.linalg.solve(S.astype(np.float64), b.astype(np.float64))
    assert np.abs(res.value - x_ref).max() < 1e-5
    # residual history trends down by orders of magnitude
    assert res.residuals[-1] < res.residuals[0] * 1e-6
    drops = sum(b2 < a2 for a2, b2 in zip(res.residuals, res.residuals[1:]))
    assert drops >= len(res.residuals) - 2  # near-monotonic decrease


# ----------------------------------------------------------- adaptive policy
def test_policy_phase_bins_and_threshold_prior():
    pol = AdaptiveSpmvPolicy()
    assert pol.n_phases == 6
    assert pol.phase_of(0.0) == 0
    assert pol.phase_of(0.03) == 1
    assert pol.phase_of(0.9) == 5
    assert pol.prior_kind(0.01) == SPMSPV
    assert pol.prior_kind(0.5) == SPMV
    assert phase_arm_bucket("b1", 2, 6) == "b1#ph2of6"


def test_adaptive_policy_flips_spmspv_to_spmv_as_frontier_densifies(session, web):
    pol = AdaptiveSpmvPolicy()
    res = power_iteration(session, web, tol=0.0, max_iters=12, policy=pol)
    kinds = res.matvec_kinds
    assert kinds[0] == SPMSPV, "seed frontier must route through SpMSpV"
    assert SPMV in kinds, "densified frontier must flip to SpMV"
    flip = kinds.index(SPMV)
    assert all(k == SPMSPV for k in kinds[:flip])
    assert all(k == SPMV for k in kinds[flip:]), "flip must be one-way"
    assert res.spmspv_calls >= 1 and res.spmv_calls >= 1
    # sparse-frontier iterations touched strictly less stored work
    assert res.modeled_work < res.spmv_work_equiv


def test_policy_bandit_learns_crossover():
    """Measured times overturn the threshold prior inside one density phase."""
    pol = AdaptiveSpmvPolicy(selector=AdaptiveFormatSelector())
    density = 0.05  # below threshold: prior says SpMSpV
    assert pol.prior_kind(density) == SPMSPV
    # feed measurements where SpMSpV is 10x slower than SpMV at this phase
    for _ in range(40):
        decision = pol.choose(density)
        pol.update(decision, 1.0 if decision.kind == SPMSPV else 0.1)
    finals = [pol.choose(density).kind for _ in range(8)]
    assert finals.count(SPMV) > finals.count(SPMSPV), (
        f"bandit failed to learn the crossover: {finals}"
    )


# ----------------------------------------------------- amortization contract
def test_fifty_iteration_solve_plans_exactly_once(session, web):
    tracer = get_tracer()
    tracer.clear()
    res = power_iteration(session, web, tol=0.0, max_iters=50)
    assert res.iterations == 50
    stats = session.stats
    assert stats.plans_computed == 1, (
        f"a 50-iteration solve must serve ONE plan, computed {stats.plans_computed}"
    )
    assert stats.observations == 50  # every iteration fed observe()
    spans = tracer.spans()
    iterate = [s for s in spans if s["name"] == "solver.iterate"]
    assert len(iterate) == 50
    assert {s["attrs"]["iteration"] for s in iterate} == set(range(1, 51))
    assert all(s["attrs"]["solver"] == "power" for s in iterate)
    # a second solve over the same matrix reuses the cached plan entirely
    res2 = power_iteration(session, web, tol=0.0, max_iters=5)
    assert session.stats.plans_computed == 1
    assert res2.cache_hit


def test_force_fp32_guard_recompiles_bf16_schedules(web):
    bf16 = KernelSchedule(accum_dtype="bfloat16")
    session = _session(schedule=bf16)
    driver = IterativeSolver(session, web, name="guard")
    plan = driver.setup()
    assert plan.schedule.accum_dtype == "bfloat16"
    assert driver._spmv_kernel.schedule.accum_dtype == "float32"
    # and the iteration results are fp32-grade
    x = np.random.default_rng(1).standard_normal(web.shape[1]).astype(np.float32)
    y = driver.matvec(x)
    ref = web.astype(np.float64) @ x.astype(np.float64)
    assert np.abs(y - ref).max() / (np.abs(ref).max() + 1e-9) < 1e-5
