"""Property + unit tests for the sparse substrate (formats, converters,
reference SpMV, suite generator, sparsity features)."""

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core.features import (
    FEATURE_NAMES,
    extract_features,
    features_from_assignment_histogram,
    features_from_csr_indptr,
)
from repro.sparse import FORMAT_NAMES, from_dense, spmv, to_dense
from repro.sparse.formats import CSR, SELL
from repro.sparse.generate import (
    MATRIX_NAMES,
    PATTERN_NAMES,
    SUITE,
    generate_by_name,
    random_matrix,
)


def _rand_dense(n_rows, n_cols, density, seed):
    rng = np.random.default_rng(seed)
    d = rng.normal(size=(n_rows, n_cols)).astype(np.float32)
    mask = rng.random((n_rows, n_cols)) < density
    return np.where(mask, d, 0.0).astype(np.float32)


dense_strategy = st.builds(
    _rand_dense,
    n_rows=st.integers(1, 120),
    n_cols=st.integers(1, 120),
    density=st.floats(0.0, 0.4),
    seed=st.integers(0, 2**31 - 1),
)


@pytest.mark.parametrize("fmt", FORMAT_NAMES)
@given(dense=dense_strategy)
def test_roundtrip(fmt, dense):
    mat = from_dense(dense, fmt)
    np.testing.assert_allclose(to_dense(mat), dense, rtol=0, atol=0)


@pytest.mark.parametrize("fmt", FORMAT_NAMES)
@given(dense=dense_strategy, seed=st.integers(0, 2**31 - 1))
def test_spmv_matches_dense(fmt, dense, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=dense.shape[1]).astype(np.float32)
    ref = dense @ x
    y = np.asarray(spmv(from_dense(dense, fmt), x))
    np.testing.assert_allclose(y, ref, rtol=1e-4, atol=1e-4)


@given(dense=dense_strategy)
def test_csr_structure(dense):
    mat = from_dense(dense, "csr")
    assert isinstance(mat, CSR)
    indptr = np.asarray(mat.indptr)
    assert indptr[0] == 0 and indptr[-1] == mat.nnz
    assert (np.diff(indptr) >= 0).all()
    # row_ids companion consistent with indptr
    counts = np.diff(indptr)
    np.testing.assert_array_equal(
        np.asarray(mat.row_ids), np.repeat(np.arange(dense.shape[0]), counts)
    )


@given(dense=dense_strategy)
def test_sell_storage_invariants(dense):
    mat = from_dense(dense, "sell")
    assert isinstance(mat, SELL)
    sp = np.asarray(mat.slice_ptr)
    sw = np.asarray(mat.slice_width)
    assert (np.diff(sp) == sw * mat.C).all()
    assert (sw % 128 == 0).all()  # lane-quantum padding
    assert mat.data.shape[0] == sp[-1]


@given(dense=dense_strategy)
def test_feature_invariants(dense):
    f = extract_features(dense)
    counts = (dense != 0).sum(axis=1)
    assert f.n == dense.shape[0]
    assert f.nnz == counts.sum()
    assert abs(f.avg_nnz * f.n - f.nnz) < 1e-6 * max(f.nnz, 1)
    assert 0.0 <= f.ell_ratio <= 1.0 + 1e-9
    assert abs(f.std_nnz**2 - f.var_nnz) < 1e-6 * max(f.var_nnz, 1.0)
    assert f.median <= counts.max(initial=0)
    vec = f.vector()
    assert vec.shape == (len(FEATURE_NAMES),)
    assert np.isfinite(vec).all()
    assert np.isfinite(f.log_vector()).all()


def test_features_from_indptr_matches_dense():
    dense = _rand_dense(64, 80, 0.1, 3)
    mat = from_dense(dense, "csr")
    f1 = extract_features(dense)
    f2 = features_from_csr_indptr(np.asarray(mat.indptr))
    np.testing.assert_allclose(f1.vector(), f2.vector())


def test_assignment_histogram_features():
    f = features_from_assignment_histogram(np.array([5, 0, 3, 8]))
    assert f.n == 4 and f.nnz == 16 and f.avg_nnz == 4.0


def test_suite_has_30_named_matrices():
    assert len(MATRIX_NAMES) == 30
    # paper §6.1 ranges
    ns = [SUITE[m].n for m in MATRIX_NAMES]
    nnzs = [SUITE[m].nnz for m in MATRIX_NAMES]
    assert min(ns) == 14_340 and max(ns) == 1_489_752
    assert min(nnzs) == 800_800 and max(nnzs) == 19_235_140


@pytest.mark.parametrize("name", MATRIX_NAMES[::6])
def test_suite_generation_scaled(name):
    d = generate_by_name(name, scale=0.003)
    assert d.shape[0] >= 64
    f = extract_features(d)
    assert f.nnz > 0


@pytest.mark.parametrize("pattern", PATTERN_NAMES)
def test_patterns_generate(pattern):
    d = random_matrix(128, 6.0, pattern, seed=1)
    assert (d != 0).sum() > 0


def test_pattern_diversity():
    """The generator must reproduce Fig. 7's spread: ELL ratio and std_nnz
    must differ strongly across pattern families."""
    f_fem = extract_features(random_matrix(512, 16.0, "fem", seed=2))
    f_pow = extract_features(random_matrix(512, 16.0, "powerlaw", seed=2))
    assert f_fem.ell_ratio > 3 * f_pow.ell_ratio
    assert f_pow.std_nnz > 3 * f_fem.std_nnz
