"""Sparse LM inference engine (models/sparse_linear.py): registration and
dispatch semantics, dense-vs-sparse numerics through the model stack, the
one-plan-per-(fingerprint, objective) amortization contract, SLO routing in
``BatchedServer``, and the pruned-ffn suite pattern."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core import AutoSpMV, AutoSpmvSession
from repro.kernels.common import DEFAULT_SCHEDULE
from repro.models.sparse_linear import (
    SLO_OBJECTIVES,
    SLO_PRIORITY,
    SparseInferenceEngine,
    prune_model_ffns,
    slo_objective,
)
from repro.optim.compress import magnitude_prune


# --------------------------------------------------------------------- fakes
class _FakePredictor:
    def predict_format(self, feats, objective):
        return "ell"

    def predict_schedule(self, feats, objective):
        return DEFAULT_SCHEDULE

    def estimate_objective(self, feats, config, objective):
        return 0.5 if config.fmt == "ell" else 1.0


class _FakeOverhead:
    def total_overhead(self, feats, fmt):
        return 1e6

    def predict_c(self, feats, fmt):
        return 1.0


def make_engine(**kwargs) -> SparseInferenceEngine:
    session = AutoSpmvSession(AutoSpMV(_FakePredictor(), _FakeOverhead()))
    return SparseInferenceEngine(session, **kwargs)


def sparse_weight(d_in: int, d_out: int, density: float = 0.1, seed: int = 0):
    w = np.random.default_rng(seed).normal(size=(d_in, d_out)).astype(np.float32)
    pruned, _ = magnitude_prune(w, density)
    return pruned


TINY = ModelConfig(
    name="tiny", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
    param_dtype="float32", compute_dtype="float32",
)

MOE = ModelConfig(
    name="tiny-moe", family="moe", n_layers=1, d_model=64, n_heads=4,
    n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
    n_experts=4, top_k=2, d_ff_expert=32, n_shared_experts=1,
    dispatch_format="dense", param_dtype="float32", compute_dtype="float32",
)


# ----------------------------------------------------------------- SLO maps
def test_slo_objective_mapping():
    assert set(SLO_OBJECTIVES) == set(SLO_PRIORITY)
    assert sorted(SLO_OBJECTIVES.values()) == sorted(
        ["latency", "power", "efficiency", "energy"]
    )
    assert slo_objective("latency-critical") == "latency"
    with pytest.raises(ValueError, match="unknown SLO class"):
        slo_objective("best-effort")


# ------------------------------------------------------------- registration
def test_register_eligibility_and_orientation():
    engine = make_engine(density_threshold=0.5)
    sparse = engine.register("a", sparse_weight(64, 96, density=0.1))
    assert sparse.spmv_eligible
    assert (sparse.d_in, sparse.d_out) == (64, 96)
    assert sparse.weight_t.shape == (96, 64)  # SpMV orientation: A = W.T
    assert sparse.density == pytest.approx(0.1, rel=0.1)

    dense = engine.register("b", np.ones((8, 8), np.float32))
    assert not dense.spmv_eligible  # density 1.0 > threshold
    zero = engine.register("c", np.zeros((8, 8), np.float32))
    assert not zero.spmv_eligible  # empty matrix: nothing to SpMV
    assert engine.stats.registered == 3
    assert engine.stats.spmv_layers == 1

    engine.register("a", sparse_weight(64, 96, density=0.1))  # replace
    assert engine.stats.registered == 3  # re-registering is not a new layer

    with pytest.raises(ValueError, match="2-D"):
        engine.register("d", np.zeros((2, 2, 2), np.float32))


# ------------------------------------------------------------------- matmul
def test_matmul_matches_dense_and_fallbacks():
    engine = make_engine(max_spmv_tokens=4)
    w = sparse_weight(64, 96, density=0.1)
    engine.register("lin", w)
    x = jnp.asarray(
        np.random.default_rng(1).normal(size=(1, 3, 64)).astype(np.float32)
    )
    ref = np.asarray(jnp.einsum("btd,df->btf", x, jnp.asarray(w)))

    y = np.asarray(engine.matmul("lin", x, jnp.asarray(w), "latency"))
    assert y.shape == (1, 3, 96)
    assert np.abs(y - ref).max() < 1e-5
    assert engine.stats.spmv_matmuls == 1
    assert engine.session.stats.requests == 1  # exactly one plan

    # unregistered name: dense contraction, no plan, no fallback counter
    y2 = engine.matmul("other", x, jnp.asarray(w), "latency")
    assert np.abs(np.asarray(y2) - ref).max() < 1e-6
    assert engine.stats.dense_fallbacks == 0

    # token count above the SpMV window: dense fallback, counted
    big = jnp.concatenate([x, x], axis=1)  # 6 tokens > max_spmv_tokens=4
    engine.matmul("lin", big, jnp.asarray(w), "latency")
    assert engine.stats.dense_fallbacks == 1
    assert engine.session.stats.requests == 1  # no new plan either


def test_plan_amortization_per_fingerprint_and_objective():
    engine = make_engine()
    w = sparse_weight(32, 48, density=0.2, seed=2)
    engine.register("a", w)
    engine.register("a_twin", w.copy())  # same bytes -> same fingerprint
    x = jnp.ones((1, 32), jnp.float32)
    for _ in range(3):
        engine.matmul("a", x, jnp.asarray(w), "latency")
        engine.matmul("a_twin", x, jnp.asarray(w), "latency")
    # twin shares the fingerprint: ONE serve_optimize for both, ever
    assert engine.session.stats.requests == 1
    engine.matmul("a", x, jnp.asarray(w), "energy")
    assert engine.session.stats.requests == 2  # new objective -> new plan
    assert engine.stats.plans == 2
    assert engine.format_mix("latency") in ("csr", "ell", "sell", "bell")
    modeled = engine.modeled_objectives("latency")
    assert set(modeled) == {"latency", "energy", "power", "efficiency"}


# --------------------------------------------------------------- model path
def test_decode_step_sparse_matches_dense_and_plans_stay_flat():
    from repro.models.model import decode_step, init_cache, model_specs, prefill
    from repro.models.param import init_params

    cfg = TINY
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    engine = make_engine()
    pruned = prune_model_ffns(params, cfg, engine, density=0.1)
    assert engine.stats.registered == 6  # 2 layers x 3 swiglu matrices
    assert engine.stats.spmv_layers == 6

    tokens = jnp.asarray(
        np.random.default_rng(3).integers(0, cfg.vocab_size, (1, 5)), jnp.int32
    )
    cache = init_cache(cfg, 1, 32)
    logits, cache, _ = prefill(pruned, cfg, cache, tokens=tokens)
    nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    pos = jnp.full((1, 1), 5, jnp.int32)

    ld, _ = decode_step(pruned, cfg, cache, nxt, pos)
    handle = engine.bind("latency")
    ls, cache_s = decode_step(
        pruned, cfg, cache, nxt, pos, unroll_layers=True, engine=handle
    )
    assert float(jnp.max(jnp.abs(ld - ls))) < 5e-4

    # the acceptance counter: one serve_optimize per distinct weight matrix
    # for the ENTIRE decode — further steps must not add plans
    assert engine.session.stats.requests == 6
    for _ in range(2):
        nxt = jnp.argmax(ls[:, -1:], axis=-1).astype(jnp.int32)
        pos = pos + 1
        ls, cache_s = decode_step(
            pruned, cfg, cache_s, nxt, pos, unroll_layers=True, engine=handle
        )
    assert engine.session.stats.requests == 6


def test_engine_requires_unrolled_groups():
    from repro.models.model import forward, model_specs
    from repro.models.param import init_params

    cfg = TINY
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    engine = make_engine()
    tokens = jnp.zeros((1, 4), jnp.int32)
    with pytest.raises(ValueError, match="unroll_layers=True"):
        forward(params, cfg, tokens=tokens, engine=engine.bind("latency"))


# ---------------------------------------------------------------------- MoE
def _moe_params(cfg, seed: int = 0):
    from repro.models.moe import moe_specs
    from repro.models.param import init_params

    return init_params(moe_specs(cfg), jax.random.PRNGKey(seed), "float32")


def _register_moe(engine, params, cfg, name: str, density: float = 0.2):
    out = dict(params)
    for k in ("w_gate", "w_up", "w_down"):
        stacked = np.asarray(params[k])
        pruned = np.stack(
            [magnitude_prune(stacked[e], density)[0] for e in range(cfg.n_experts)]
        )
        for e in range(cfg.n_experts):
            engine.register(f"{name}.moe.{k}.{e}", pruned[e])
        out[k] = pruned
    sh = dict(params["shared"])
    for k in ("w_gate", "w_up", "w_down"):
        sh[k] = magnitude_prune(np.asarray(sh[k]), density)[0]
        engine.register(f"{name}.moe.shared.{k}", sh[k])
    out["shared"] = sh
    return out


def test_moe_engine_matches_dense_dispatch():
    from repro.models.moe import moe_ffn

    cfg = MOE
    engine = make_engine()
    params = _register_moe(engine, _moe_params(cfg), cfg, "b0")
    x = jnp.asarray(
        np.random.default_rng(4).normal(size=(1, 4, cfg.d_model)).astype(np.float32)
    )
    y_dense, aux_d, counts_d = moe_ffn(params, x, cfg)
    y_sparse, aux_s, counts_s = moe_ffn(
        params, x, cfg, engine=engine.bind("latency"), name="b0"
    )
    assert float(jnp.max(jnp.abs(y_dense - y_sparse))) < 5e-4
    assert np.array_equal(np.asarray(counts_d), np.asarray(counts_s))
    # every expert slice + shared FFN planned exactly once
    assert engine.session.stats.requests == engine.stats.spmv_layers


def test_moe_engine_rejects_capacity_dispatch():
    from repro.models.moe import moe_ffn

    cfg = MOE.replace(dispatch_format="ell")
    engine = make_engine()
    params = _moe_params(cfg)
    x = jnp.zeros((1, 4, cfg.d_model), jnp.float32)
    with pytest.raises(ValueError, match="dispatch_format='dense'"):
        moe_ffn(params, x, cfg, engine=engine.bind("latency"), name="b0")


# ------------------------------------------------------------ serving layer
def test_batched_server_slo_summary_and_energy_cells():
    from repro.models.model import model_specs
    from repro.models.param import init_params
    from repro.train.serve import BatchedServer, Request, ServeConfig

    cfg = TINY
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    engine = make_engine()
    pruned = prune_model_ffns(params, cfg, engine, density=0.1)
    server = BatchedServer(
        pruned, cfg,
        ServeConfig(batch_slots=1, max_len=64, max_new_tokens=2),
        engine=engine,
    )
    reqs = [
        Request(rid=0, prompt=[1, 2, 3], max_new_tokens=2, slo="latency-critical"),
        Request(rid=1, prompt=[4, 5, 6, 7], max_new_tokens=2, slo="energy-saving"),
    ]
    done = server.run(reqs)
    assert all(len(r.generated) == 2 for r in done)

    s = server.summary()
    assert s["slo_classes"] == {"energy-saving": 1, "latency-critical": 1}
    assert s["requests"] == 2
    # one plan per (matrix, objective): two classes -> two objectives
    assert s["session"]["requests"] == engine.stats.spmv_layers * 2
    cells = s["energy"]
    objectives = {k.split("/")[1] for k in cells}
    assert objectives == {"latency", "energy"}  # each request's OWN class
    assert all(k.endswith("/lm") for k in cells)
    assert all(c["requests"] > 0 for c in cells.values())
    assert "tick_latency" in s


def test_batched_server_rejects_unknown_slo():
    from repro.models.model import model_specs
    from repro.models.param import init_params
    from repro.train.serve import BatchedServer, Request, ServeConfig

    cfg = TINY
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0), cfg.param_dtype)
    engine = make_engine()
    pruned = prune_model_ffns(params, cfg, engine, density=0.1)
    server = BatchedServer(
        pruned, cfg, ServeConfig(batch_slots=1, max_len=64, max_new_tokens=1),
        engine=engine,
    )
    with pytest.raises(ValueError, match="unknown SLO class"):
        server.run([Request(rid=0, prompt=[1, 2], max_new_tokens=1, slo="asap")])


# -------------------------------------------------------- pruned-ffn suite
def test_prunedffn_pattern_in_suite():
    from repro.sparse.generate import (
        MATRIX_NAMES,
        PATTERN_NAMES,
        SUITE,
        generate_by_name,
    )

    assert "pruned-ffn" in SUITE
    assert "prunedffn" in PATTERN_NAMES
    # the paper's §6.1 selection stays exactly the 30 Table-7 matrices
    assert "pruned-ffn" not in MATRIX_NAMES and len(MATRIX_NAMES) == 30

    spec = SUITE["pruned-ffn"]
    d = generate_by_name("pruned-ffn", scale=0.01)
    n = d.shape[0]
    assert d.shape == (n, n)
    density = np.count_nonzero(d) / d.size
    assert density == pytest.approx(min(spec.avg_nnz / n, 1.0), rel=0.05)
    # unstructured top-k: no empty rows at this density, counts near-binomial
    row_counts = np.count_nonzero(d, axis=1)
    assert row_counts.min() >= 1
    assert abs(row_counts.mean() - spec.avg_nnz) < 1.0
    assert np.array_equal(d, generate_by_name("pruned-ffn", scale=0.01))
