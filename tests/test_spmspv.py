"""Tests for the SpMSpV kernel path (kernels/spmspv.py + ops wiring):
exactness against the dense reference for any frontier occupancy, padding
and spill conventions, schedule variants (unroll, bf16), misalignment and
bounds behavior, and the process-memo integration of ``compile_spmspv``."""

import numpy as np
import pytest

import repro.sparse.registry as registry
from repro.kernels.common import DEFAULT_SCHEDULE, InfeasibleConfig, KernelSchedule
from repro.kernels.ops import (
    PreparedSpmspv,
    compile_spmspv,
    kernel_memo_stats,
    matrix_fingerprint,
    spmspv,
)
from repro.kernels.spmspv import (
    CscEll,
    _frontier_pad,
    col_nnz,
    csc_from_dense,
    csc_spmspv,
)
from repro.sparse.generate import random_matrix


def _matrix(n=96, avg=5.0, pattern="powerlaw", seed=0):
    return random_matrix(n, avg, pattern, seed=seed).astype(np.float32)


def _frontier(rng, n, k):
    active = rng.choice(n, size=k, replace=False).astype(np.int32) if k else (
        np.zeros(0, dtype=np.int32)
    )
    xvals = rng.standard_normal(k).astype(np.float32)
    return active, xvals


def _dense_ref(dense, active, xvals):
    x = np.zeros(dense.shape[1], dtype=np.float64)
    x[active] = xvals.astype(np.float64)
    return dense.astype(np.float64) @ x


# ----------------------------------------------------------------- exactness
@pytest.mark.parametrize("occupancy", ["empty", "singleton", "half", "full"])
@pytest.mark.parametrize("pattern", ["powerlaw", "fem", "webgraph"])
def test_exact_vs_dense(occupancy, pattern):
    dense = _matrix(pattern=pattern, seed=3)
    n = dense.shape[1]
    k = {"empty": 0, "singleton": 1, "half": n // 2, "full": n}[occupancy]
    rng = np.random.default_rng(k)
    active, xvals = _frontier(rng, n, k)
    mat = csc_from_dense(dense)
    y = np.asarray(csc_spmspv(mat, active, xvals))
    assert y.shape == (dense.shape[0],)
    np.testing.assert_allclose(y, _dense_ref(dense, active, xvals),
                               rtol=1e-5, atol=1e-5)


def test_unsorted_and_duplicate_free_frontier_order_irrelevant():
    dense = _matrix(seed=5)
    rng = np.random.default_rng(7)
    active, xvals = _frontier(rng, dense.shape[1], 17)
    mat = csc_from_dense(dense)
    y_fwd = np.asarray(csc_spmspv(mat, active, xvals))
    y_rev = np.asarray(csc_spmspv(mat, active[::-1], xvals[::-1]))
    np.testing.assert_allclose(y_fwd, y_rev, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("unroll", [2, 4])
def test_unroll_variants(unroll):
    sched = KernelSchedule(unroll=unroll)
    dense = _matrix(seed=11)
    rng = np.random.default_rng(0)
    active, xvals = _frontier(rng, dense.shape[1], 23)
    mat = csc_from_dense(dense, sched)
    y = np.asarray(csc_spmspv(mat, active, xvals, sched))
    np.testing.assert_allclose(y, _dense_ref(dense, active, xvals),
                               rtol=1e-5, atol=1e-5)


def test_bf16_accumulation_loose_tolerance():
    sched = KernelSchedule(accum_dtype="bfloat16")
    dense = _matrix(seed=13)
    rng = np.random.default_rng(1)
    active, xvals = _frontier(rng, dense.shape[1], 31)
    mat = csc_from_dense(dense, sched)
    y = np.asarray(csc_spmspv(mat, active, xvals, sched))
    ref = _dense_ref(dense, active, xvals)
    scale = np.abs(ref).max() + 1e-9
    assert np.abs(y - ref).max() / scale < 5e-2


# ----------------------------------------------------- storage + conventions
def test_cscell_spill_column_and_width_alignment():
    dense = _matrix(seed=17)
    sched = DEFAULT_SCHEDULE
    mat = csc_from_dense(dense, sched)
    n_rows, n_cols = mat.shape
    assert mat.data.shape == (n_cols + 1, mat.width)
    assert mat.width % sched.nnz_tile == 0
    # spill column (frontier padding target) holds exact zeros / spill rows
    assert np.all(np.asarray(mat.data[n_cols]) == 0)
    assert np.all(np.asarray(mat.rows[n_cols]) == n_rows)
    # padding slots inside real columns also point at the spill row
    counts = col_nnz(dense)
    rows_np = np.asarray(mat.rows)
    for c in (0, n_cols // 2, n_cols - 1):
        assert np.all(rows_np[c, int(counts[c]):] == n_rows)


def test_frontier_pad_is_pow2_min_sublane():
    assert _frontier_pad(1) == 8
    assert _frontier_pad(8) == 8
    assert _frontier_pad(9) == 16
    assert _frontier_pad(100) == 128


def test_misaligned_schedule_raises_infeasible():
    dense = _matrix(seed=19)
    mat = csc_from_dense(dense, KernelSchedule(nnz_tile=128))
    wider = KernelSchedule(nnz_tile=256)
    if mat.width % wider.nnz_tile == 0:
        pytest.skip("width happens to align; misalignment not constructible")
    with pytest.raises(InfeasibleConfig):
        csc_spmspv(mat, np.array([0], np.int32), np.array([1.0], np.float32), wider)


def test_storage_bound_rejects_blowup(monkeypatch):
    monkeypatch.setattr(registry, "MAX_STORAGE_BYTES", 1024)
    with pytest.raises(InfeasibleConfig):
        csc_from_dense(_matrix(seed=23))


def test_frontier_validation():
    dense = _matrix(seed=29)
    mat = csc_from_dense(dense)
    with pytest.raises(ValueError):
        csc_spmspv(mat, np.array([dense.shape[1]], np.int32),
                   np.array([1.0], np.float32))
    with pytest.raises(ValueError):
        csc_spmspv(mat, np.array([0, 1], np.int32), np.array([1.0], np.float32))


# ------------------------------------------------------------- ops.py wiring
def test_spmspv_entry_requires_cscell():
    with pytest.raises(TypeError):
        spmspv(object(), np.zeros(0, np.int32), np.zeros(0, np.float32))


def test_compile_spmspv_memoizes_and_counts():
    dense = _matrix(seed=31)
    fp = matrix_fingerprint(dense)
    before = kernel_memo_stats()
    p1 = compile_spmspv(dense, memo_key=fp)
    p2 = compile_spmspv(dense, memo_key=fp)
    after = kernel_memo_stats()
    assert p1 is p2
    assert after["compiles"] - before["compiles"] == 1
    assert after["hits"] - before["hits"] == 1
    # a different schedule is a different memo entry, not a collision
    p3 = compile_spmspv(dense, KernelSchedule(unroll=2), memo_key=fp)
    assert p3 is not p1


def test_prepared_spmspv_dense_call_and_modeled_work():
    dense = _matrix(seed=37)
    prepared = compile_spmspv(dense)
    assert isinstance(prepared, PreparedSpmspv)
    rng = np.random.default_rng(4)
    x = np.zeros(dense.shape[1], dtype=np.float32)
    active = rng.choice(dense.shape[1], size=13, replace=False)
    x[active] = rng.standard_normal(13).astype(np.float32)
    y = np.asarray(prepared(x))
    np.testing.assert_allclose(
        y, dense.astype(np.float64) @ x.astype(np.float64), rtol=1e-5, atol=1e-5
    )
    work = prepared.modeled_work(np.sort(active))
    assert work == int(col_nnz(dense)[np.sort(active)].sum())
    assert 0 < work <= int((dense != 0).sum())
